"""Differential validation of the multi-tile streaming discipline (ISSUE 5).

No Rust toolchain ships in the build container, so the scheduling
semantics implemented twice in Rust -- the closed-form layer composition
(`timing::layer_timing`) and the streaming cycle simulator
(`sa::stream::StreamingSim`) -- are validated here by a third,
independent implementation: a **single-clock tag-level machine** that
ticks every register of an R x C weight-stationary array, the fill
path, and the two weight banks cycle by cycle, across a whole tile
plan.  Nothing in the machine knows the closed form; stream hand-offs
happen when the controller *observes* (a) the previous tile drained and
(b) the preload delivered -- so agreement with the ported closed form
over randomized shapes, organisations (presets + custom (S, D, tail)
combos) and both double-buffer modes is genuine evidence, not
circularity.

Checks per case:
  * per-output cycles and per-tile durations vs the tile formula
    T = (M-1) + (C_used-1) + S*(R-1) + D + 1 + tail
  * whole-plan totals / exposed preload / drain vs the ported
    layer_timing composition (both double_buffer modes)
  * two-buffer constraint audited event-by-event (fill path free, target
    bank dead) -- the satellite-3 audit
  * serialized total == historical per-tile sum (R + T per tile)
  * under double buffering only the first fill is exposed (T > R)
  * assembled integer outputs == A x W exactly (K-pass folding with
    n-block offsets)

Run:  python3 python/tests/test_streaming_timing.py
"""

import random
import sys
from dataclasses import dataclass, field

# --------------------------------------------------------------------------
# Organisations: (name, spacing S, depth D, column tail)
# --------------------------------------------------------------------------
PRESETS = [
    ("regular-3a", 2, 2, 0),
    ("baseline-3b", 2, 2, 0),
    ("skewed", 1, 2, 1),
    ("transparent", 1, 2, 0),
    ("deep3", 2, 3, 0),
]
CUSTOM = [
    ("custom-s3d3", 3, 3, 0),
    ("custom-s1d4", 1, 4, 1),
    ("custom-s1d2t2", 1, 2, 2),
]
SPECS = PRESETS + CUSTOM


# --------------------------------------------------------------------------
# Ported closed form (timing/model.rs)
# --------------------------------------------------------------------------
def tile_cycles(S, D, tail, m, rows, n_used):
    return (m - 1) + (n_used - 1) + S * (rows - 1) + D + 1 + tail


def tile_plan(m, k, n, rows, cols):
    """Tiles in N-block-major, K-pass-minor order: (k0, k_len, n0, n_len)."""
    tiles = []
    for n0 in range(0, n, cols):
        n_len = min(cols, n - n0)
        for k0 in range(0, k, rows):
            k_len = min(rows, k - k0)
            tiles.append((k0, k_len, n0, n_len))
    return tiles


def layer_spans(S, D, tail, m, rows, tiles, double_buffer):
    spans = []
    drained = 0
    for t in tiles:
        if not spans:
            p_start = 0
        elif double_buffer:
            p_start = spans[-1][2]  # previous stream_start
        else:
            p_start = spans[-1][3]  # previous stream_done
        p_done = p_start + rows
        s_start = max(drained, p_done)
        s_done = s_start + tile_cycles(S, D, tail, m, rows, t[3])
        spans.append((p_start, p_done, s_start, s_done))
        drained = s_done
    return spans


def layer_timing(S, D, tail, m, rows, tiles, double_buffer):
    spans = layer_spans(S, D, tail, m, rows, tiles, double_buffer)
    total = spans[-1][3] if spans else 0
    compute = sum(s[3] - s[2] for s in spans)
    exposed, drained = 0, 0
    for s in spans:
        exposed += s[2] - drained
        drained = s[3]
    drain = sum((s[3] - s[2]) - min(s[3] - s[2], m) for s in spans)
    return total, compute, exposed, drain, spans


# --------------------------------------------------------------------------
# The single-clock tag-level machine
# --------------------------------------------------------------------------
@dataclass
class PE:
    w: int = 0
    w_shadow: int = 0
    # pipe[k]: element that completed stages 1..k+1, as (m, a, val|None)
    pipe: list = field(default_factory=list)
    out: tuple = None  # (m, val, taken)
    next_feed: int = 0


class Machine:
    """R x C array + fill engine + two weight banks, one global clock."""

    def __init__(self, S, D, tail, rows, cols, A, W, tiles, double_buffer):
        self.S, self.D, self.tail = S, D, tail
        self.rows, self.cols = rows, cols
        self.A, self.W = A, W  # A[m][k], W[k][n] small ints
        self.tiles = tiles
        self.db = double_buffer
        self.m_total = len(A)
        self.pes = [[PE(pipe=[None] * (D - 1)) for _ in range(cols)] for _ in range(rows)]
        self.round_q = [[] for _ in range(cols)]  # (ready, m, val)
        self.t = 0
        self.base = 0
        self.tile_idx = -1
        self.produced = 0
        self.n_live = 0
        self.outputs = {}  # (tile_idx, m, c_local) -> (cycle, val)
        self.y = [[0] * len(W[0]) for _ in range(self.m_total)]
        # fill engine: preload_jobs[i] = (start, done, bank); audited.
        self.fill_free_at = 0
        self.bank_free_at = [0, 0]
        self.preload = {}  # tile -> (start, done, bank)
        self.spans = []  # (p_start, p_done, s_start, s_done)
        self._schedule_preload(0, 0)

    def _schedule_preload(self, tile, start):
        bank = (tile % 2) if self.db else 0
        assert start >= self.fill_free_at, "fill path busy"
        assert start >= self.bank_free_at[bank], "bank still live"
        done = start + self.rows
        self.fill_free_at = done
        self.preload[tile] = (start, done, bank)

    def _tile_drained(self):
        return self.produced == self.m_total * self.n_live and not any(self.round_q)

    def _close_span(self):
        """Record the drained tile's end and free its weight bank; in
        serial mode the (single-bank) reload can only start now."""
        if self.tile_idx < 0 or self.spans[-1][3] is not None:
            return
        if not self._tile_drained():
            return
        ps, pd, ss = self.spans[-1][:3]
        self.spans[-1] = (ps, pd, ss, self.t_drained)
        bank = (self.tile_idx % 2) if self.db else 0
        self.bank_free_at[bank] = self.t_drained
        nxt = self.tile_idx + 1
        if not self.db and nxt < len(self.tiles):
            self._schedule_preload(nxt, self.t_drained)

    def _try_handoff(self):
        """Start the next tile's stream if its weights landed and the
        previous tile drained -- observed, not computed."""
        self._close_span()
        nxt = self.tile_idx + 1
        if nxt >= len(self.tiles):
            return False
        if self.tile_idx >= 0 and self.spans[-1][3] is None:
            return False  # previous tile still streaming
        if nxt not in self.preload:
            return False  # serial reload not yet launched
        p_start, p_done, bank = self.preload[nxt]
        if self.t < p_done:
            return False
        k0, k_len, n0, n_len = self.tiles[nxt]
        for r in range(self.rows):
            for c in range(self.cols):
                pe = self.pes[r][c]
                assert all(s is None for s in pe.pipe), "handoff with live pipe"
                assert pe.out is None or pe.out[2], "handoff with unconsumed psum"
                pe.out = None
                pe.next_feed = 0
                pe.w = self.W[k0 + r][n0 + c] if (r < k_len and c < n_len) else 0
        self.tile_idx = nxt
        self.base = self.t
        self.produced = 0
        self.n_live = n_len
        self.spans.append((p_start, p_done, self.t, None))
        # double-buffered: the following preload launches the moment this
        # stream starts (the fill path and the dead bank both freed up)
        if self.db and nxt + 1 < len(self.tiles):
            self._schedule_preload(nxt + 1, self.t)
        return True

    def a_bits(self, m, r):
        k0, k_len, _, _ = self.tiles[self.tile_idx]
        return self.A[m][k0 + r] if r < k_len else 0

    def tick(self):
        """One cycle of the dense two-phase tick (array.rs semantics)."""
        S, D, tail = self.S, self.D, self.tail
        rows, t, base = self.rows, self.t, self.base
        n_live = self.n_live
        capture = S == D
        psum_stage = D - S + 1
        scratch_out = [[None] * self.cols for _ in range(rows)]
        scratch_acc = [[None] * self.cols for _ in range(rows)]

        for r in range(rows):
            for c in range(n_live):
                pe = self.pes[r][c]
                if not capture:
                    slot = pe.pipe[psum_stage - 2]
                    if slot is not None:
                        m, a, _ = slot
                        if r == 0:
                            psum = 0
                        else:
                            up = self.pes[r - 1][c]
                            assert up.out is not None and up.out[0] == m, "out of order"
                            psum = up.out[1]
                            self.pes[r - 1][c].out = (up.out[0], up.out[1], True)
                        pe.pipe[psum_stage - 2] = (m, a, psum + a * pe.w)
                exit_slot = pe.pipe[D - 2]
                if exit_slot is not None:
                    m, a, val = exit_slot
                    assert val is not None
                    scratch_out[r][c] = (m, val, False)

        # south edge
        for c in range(n_live):
            last = self.pes[rows - 1][c]
            if last.out is not None and not last.out[2]:
                self.round_q[c].append((t + tail, last.out[0], last.out[1]))
                last.out = (last.out[0], last.out[1], True)
            while self.round_q[c] and self.round_q[c][0][0] <= t:
                ready, m, val = self.round_q[c].pop(0)
                _, _, n0, _ = self.tiles[self.tile_idx]
                self.outputs[(self.tile_idx, m, c)] = (ready, val)
                self.y[m][n0 + c] += val
                self.produced += 1
                if self._tile_drained():
                    self.t_drained = ready + 1

        # stage-1 acceptance
        for r in range(rows):
            for c in range(n_live):
                pe = self.pes[r][c]
                want = pe.next_feed
                if want >= self.m_total:
                    continue
                if r == 0:
                    ready, captured = True, 0
                elif capture:
                    up = self.pes[r - 1][c]
                    if up.out is not None and up.out[0] == want and not up.out[2]:
                        ready, captured = True, up.out[1]
                    else:
                        assert up.out is None or up.out[0] <= want, "out of order"
                        ready, captured = False, None
                else:
                    up = self.pes[r - 1][c]
                    s = up.pipe[S - 1]
                    ready, captured = (s is not None and s[0] == want), None
                if not ready:
                    continue
                if base + want + S * r + c > t:  # activation wavefront
                    continue
                if r > 0 and capture:
                    up = self.pes[r - 1][c]
                    self.pes[r - 1][c].out = (up.out[0], up.out[1], True)
                a = self.a_bits(want, r)
                val = captured + a * pe.w if capture else None
                scratch_acc[r][c] = (want, a, val)
                pe.next_feed = want + 1

        # commit
        for r in range(rows):
            for c in range(n_live):
                pe = self.pes[r][c]
                if scratch_out[r][c] is not None:
                    assert pe.out is None or pe.out[2], "psum overrun"
                    pe.out = scratch_out[r][c]
                for k in range(D - 2, 0, -1):
                    pe.pipe[k] = pe.pipe[k - 1]
                pe.pipe[0] = scratch_acc[r][c]
        self.t += 1

    def run(self, budget=200000):
        while True:
            while self._try_handoff():
                pass
            if self.tile_idx == len(self.tiles) - 1 and self._tile_drained():
                self._close_span()
                return
            assert self.t < budget, "machine wedged"
            self.tick()


# --------------------------------------------------------------------------
# The differential test
# --------------------------------------------------------------------------
def one_case(rng, name, S, D, tail, db):
    rows = rng.randint(max(2, S), 7)  # validate() requires S <= D and rows >= 1
    cols = rng.randint(1, 5)
    m = rng.randint(1, 6)
    k = rng.randint(1, 3 * rows)
    n = rng.randint(1, 2 * cols)
    A = [[rng.randint(-4, 4) for _ in range(k)] for _ in range(m)]
    W = [[rng.randint(-3, 3) for _ in range(n)] for _ in range(k)]
    tiles = tile_plan(m, k, n, rows, cols)
    mc = Machine(S, D, tail, rows, cols, A, W, tiles, db)
    mc.run()

    # numeric assembly
    for mi in range(m):
        for ni in range(n):
            want = sum(A[mi][ki] * W[ki][ni] for ki in range(k))
            assert mc.y[mi][ni] == want, f"{name}: y[{mi}][{ni}] {mc.y[mi][ni]} != {want}"

    # per-tile durations + per-output cycles on the tile formula
    total_model = layer_timing(S, D, tail, m, rows, tiles, db)
    t_total, t_compute, t_exposed, t_drain, spans_model = total_model
    for i, (tile, span) in enumerate(zip(tiles, mc.spans)):
        dur = span[3] - span[2]
        T = tile_cycles(S, D, tail, m, rows, tile[3])
        assert dur == T, f"{name} db={db}: tile {i} duration {dur} != {T}"
        for mi in range(m):
            for c in range(tile[3]):
                cyc, _ = mc.outputs[(i, mi, c)]
                want = span[2] + mi + S * (rows - 1) + c + D + tail
                assert cyc == want, f"{name}: output ({i},{mi},{c}) at {cyc} != {want}"

    # whole-plan composition vs the ported closed form
    assert mc.spans == spans_model, f"{name} db={db}: spans {mc.spans} != {spans_model}"
    total = mc.spans[-1][3]
    assert total == t_total, f"{name} db={db}: total {total} != {t_total}"

    # audit corollaries
    if db:
        exposed = sum(s[2] - (mc.spans[i - 1][3] if i else 0) for i, s in enumerate(mc.spans))
        assert exposed == rows, f"{name}: exposed {exposed} != first fill {rows}"
        for prev, cur in zip(mc.spans, mc.spans[1:]):
            assert cur[1] < prev[3], f"{name}: preload not hidden under the stream"
            assert cur[0] >= prev[1], f"{name}: fill path overlap"
    else:
        serial_sum = sum(rows + tile_cycles(S, D, tail, m, rows, t[3]) for t in tiles)
        assert total == serial_sum, f"{name}: serialized {total} != per-tile sum {serial_sum}"
    # db hides exactly (tiles-1)*R
    t_serial = layer_timing(S, D, tail, m, rows, tiles, False)[0]
    t_db = layer_timing(S, D, tail, m, rows, tiles, True)[0]
    assert t_serial - t_db == (len(tiles) - 1) * rows


def rect_case(rng, name, S, D, tail, db, rows, cols):
    """Directed rectangular/degenerate geometry: same checks as
    one_case but at a pinned R x C (tall, wide, 1xN, Rx1) — the shapes
    ISSUE 10's ArrayGeometry refactor makes first-class."""
    m = rng.randint(1, 4)
    k = rng.randint(1, 2 * rows + 1)
    n = rng.randint(1, cols + 2)
    A = [[rng.randint(-4, 4) for _ in range(k)] for _ in range(m)]
    W = [[rng.randint(-3, 3) for _ in range(n)] for _ in range(k)]
    tiles = tile_plan(m, k, n, rows, cols)
    mc = Machine(S, D, tail, rows, cols, A, W, tiles, db)
    mc.run()
    for mi in range(m):
        for ni in range(n):
            want = sum(A[mi][ki] * W[ki][ni] for ki in range(k))
            assert mc.y[mi][ni] == want, f"{name} {rows}x{cols}: y[{mi}][{ni}]"
    t_total, _, _, _, spans_model = layer_timing(S, D, tail, m, rows, tiles, db)
    assert mc.spans == spans_model, f"{name} {rows}x{cols} db={db}: spans diverge"
    assert mc.spans[-1][3] == t_total, f"{name} {rows}x{cols} db={db}: total"


def main():
    rng = random.Random(0x5EED_1559)
    cases = 0
    for name, S, D, tail in SPECS:
        for db in (True, False):
            for _ in range(40):
                one_case(rng, name, S, D, tail, db)
                cases += 1
    # Directed rectangular + degenerate geometries: tall, wide, single
    # row, single column.  The machine ticks every PE of the pinned
    # R x C plane, so agreement here validates the rectangular closed
    # form the geometry sweep and the heterogeneous fleet quote from.
    rect = 0
    for rows, cols in [(24, 3), (3, 24), (1, 6), (6, 1)]:
        for name, S, D, tail in SPECS:
            for db in (True, False):
                for _ in range(3):
                    rect_case(rng, name, S, D, tail, db, rows, cols)
                    rect += 1
    print(f"OK: {cases} randomized multi-tile streaming cases "
          f"({len(SPECS)} organisations x both double-buffer modes) "
          f"+ {rect} directed rectangular/degenerate-geometry cases "
          f"agree with the ported layer_timing composition")


if __name__ == "__main__":
    sys.exit(main())
