"""L1 kernel correctness: sa_matmul vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes/block sizes (DESIGN.md §9); the fixed
cases pin down the WS grid-ordering and accumulation semantics.
"""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import matmul_ref, sa_matmul, vmem_footprint_bytes

hypothesis.settings.register_profile(
    "kernel", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("kernel")


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype)


def _check(m, k, n, dtype, bm=128, bk=128, bn=128, seed=0):
    a = _rand((m, k), dtype, seed)
    w = _rand((k, n), dtype, seed + 1)
    got = sa_matmul(a, w, bm=bm, bk=bk, bn=bn)
    want = matmul_ref(a, w)
    assert got.shape == (m, n)
    assert got.dtype == jnp.float32
    # Accumulation order may differ across K-tiles: f32-level tolerance
    # scaled by reduction depth.
    tol = 1e-5 * max(1.0, np.sqrt(k))
    np.testing.assert_allclose(
        np.asarray(got, np.float64), np.asarray(want, np.float64), rtol=tol, atol=tol
    )


class TestFixedCases:
    def test_single_block(self):
        _check(8, 16, 8, jnp.bfloat16)

    def test_exact_multi_block(self):
        _check(64, 64, 64, jnp.bfloat16, bm=32, bk=32, bn=32)

    def test_ragged_edges(self):
        _check(70, 33, 50, jnp.bfloat16, bm=32, bk=16, bn=32)

    def test_k_deeper_than_block(self):
        # Multiple K-passes exercise the f32 accumulator re-entry.
        _check(16, 300, 16, jnp.bfloat16, bm=16, bk=64, bn=16)

    def test_f32_inputs(self):
        _check(24, 48, 24, jnp.float32, bm=16, bk=16, bn=16)

    def test_vector_shapes(self):
        _check(1, 128, 10, jnp.bfloat16, bm=1, bk=64, bn=10)

    def test_accumulates_in_f32_not_bf16(self):
        # K=512 of value 1/64 products: bf16 accumulation would collapse
        # (increments below bf16 ulp of the running sum); f32 keeps them.
        k = 512
        a = jnp.full((1, k), 0.125, jnp.bfloat16)
        w = jnp.full((k, 1), 0.125, jnp.bfloat16)
        y = float(sa_matmul(a, w, bm=1, bk=128, bn=1)[0, 0])
        assert abs(y - k * 0.125 * 0.125) < 1e-3, y

    def test_zero_inputs(self):
        a = jnp.zeros((8, 8), jnp.bfloat16)
        w = jnp.zeros((8, 8), jnp.bfloat16)
        assert float(jnp.abs(sa_matmul(a, w, bm=8, bk=8, bn=8)).max()) == 0.0

    def test_special_values_propagate(self):
        a = jnp.asarray([[jnp.inf, 1.0]], jnp.bfloat16)
        w = jnp.asarray([[1.0], [1.0]], jnp.bfloat16)
        assert np.isinf(float(sa_matmul(a, w, bm=1, bk=2, bn=1)[0, 0]))

    def test_contraction_mismatch_raises(self):
        a = jnp.zeros((4, 5), jnp.bfloat16)
        w = jnp.zeros((6, 4), jnp.bfloat16)
        with pytest.raises(AssertionError):
            sa_matmul(a, w)


class TestHypothesisSweeps:
    @given(
        m=st.integers(1, 96),
        k=st.integers(1, 96),
        n=st.integers(1, 96),
        seed=st.integers(0, 2**31),
    )
    def test_shapes_bf16(self, m, k, n, seed):
        _check(m, k, n, jnp.bfloat16, bm=32, bk=32, bn=32, seed=seed)

    @given(
        bm=st.sampled_from([1, 8, 16, 64]),
        bk=st.sampled_from([8, 16, 64]),
        bn=st.sampled_from([8, 16, 64]),
    )
    def test_block_shapes(self, bm, bk, bn):
        _check(40, 40, 40, jnp.bfloat16, bm=bm, bk=bk, bn=bn)

    @given(dtype=st.sampled_from([jnp.bfloat16, jnp.float16, jnp.float32]))
    @settings(max_examples=3)
    def test_dtypes(self, dtype):
        _check(17, 23, 19, dtype, bm=16, bk=16, bn=16)


def test_vmem_footprint_within_budget():
    # The default MXU-shaped blocks must fit comfortably in a TPU core's
    # ~16 MiB VMEM (DESIGN.md §10 roofline note).
    assert vmem_footprint_bytes() < 16 * 2**20 / 4
