"""AOT path: lowering, manifest integrity, staleness contract."""

import json
import pathlib

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build(out, only="gemm_bf16_8x16x8")
    return out, manifest


class TestLowering:
    def test_hlo_text_is_parseable_hlo(self, built):
        out, manifest = built
        text = (out / manifest["gemm_bf16_8x16x8"]["path"]).read_text()
        assert "HloModule" in text
        # The bf16 cast and f32 accumulation survive lowering.
        assert "bf16" in text
        assert "f32" in text

    def test_manifest_shapes(self, built):
        _, manifest = built
        spec = manifest["gemm_bf16_8x16x8"]
        assert spec["params"] == [[8, 16], [16, 8]]
        assert spec["result"] == [8, 8]

    def test_manifest_fingerprint_present(self, built):
        _, manifest = built
        assert len(manifest["_sources_fingerprint"]) == 64


class TestStaleness:
    def test_missing_dir_is_stale(self, tmp_path):
        assert aot.is_stale(tmp_path / "nope")

    def test_built_dir_is_fresh(self, built):
        out, _ = built
        # Only one artifact was built; a full-manifest check would be
        # fresh only for that subset, which build() recorded.
        assert not aot.is_stale(out)

    def test_source_change_invalidates(self, built, tmp_path):
        out, _ = built
        m = json.loads((out / "manifest.json").read_text())
        m["_sources_fingerprint"] = "0" * 64
        stale_dir = tmp_path / "stale"
        stale_dir.mkdir()
        (stale_dir / "manifest.json").write_text(json.dumps(m))
        assert aot.is_stale(stale_dir)

    def test_missing_artifact_file_invalidates(self, built, tmp_path):
        out, _ = built
        copy = tmp_path / "copy"
        copy.mkdir()
        (copy / "manifest.json").write_text((out / "manifest.json").read_text())
        assert aot.is_stale(copy)  # hlo file absent


def test_registry_is_consistent():
    for name, (fn, shapes, result) in model.ARTIFACTS.items():
        assert callable(fn), name
        assert all(isinstance(s, tuple) for s in shapes), name
        assert isinstance(result, tuple), name


def test_fingerprint_stable_across_calls():
    assert aot.sources_fingerprint() == aot.sources_fingerprint()
