"""Differential validation of the bounded log2-bucket histogram (ISSUE 7).

No Rust toolchain ships in the build container, so the quantile math in
`rust/src/obs/hist.rs` -- the bucket geometry (SUB_BITS=5: 32 exact
buckets below 32, then 32 linear sub-buckets per octave) and the
nearest-rank quantile read -- is validated here by an independent
Python port against exact sorted-sample percentiles.

Checks:
  * bucket_index is total-order preserving, bounded by BUCKETS, and
    bucket_lower_bound inverts it on every bucket edge
  * values < 32 are stored exactly (their own bucket)
  * one million samples per distribution (log-uniform latencies,
    uniform, bimodal): every standard quantile within the documented
    REL_QUANTILE_ERROR = 1/32 of the exact nearest-rank percentile --
    the same bound `tests/prop_obs.rs` and the LatencyRecorder
    regression pin on the Rust side
  * count/sum/min/max are exact; quantile(100) == max

Run:  python3 python/tests/test_obs_hist.py
"""

import math
import random
import sys

# --- port of rust/src/obs/hist.rs bucket geometry -------------------------

SUB_BITS = 5
SUBS = 1 << SUB_BITS
BUCKETS = SUBS + (64 - SUB_BITS) * SUBS
REL_QUANTILE_ERROR = 1.0 / SUBS


def bucket_index(v):
    if v < SUBS:
        return v
    e = v.bit_length() - 1  # floor(log2 v), e >= SUB_BITS
    sub = (v >> (e - SUB_BITS)) & (SUBS - 1)
    return (e - SUB_BITS + 1) * SUBS + sub


def bucket_lower_bound(i):
    if i < SUBS:
        return i
    e = i // SUBS + SUB_BITS - 1
    sub = i % SUBS
    return (SUBS + sub) << (e - SUB_BITS)


class Hist:
    """Port of Log2Histogram + HistSnapshot.quantile."""

    def __init__(self):
        self.buckets = [0] * BUCKETS
        self.count = 0
        self.sum = 0
        self.min = None
        self.max = 0

    def record(self, v):
        self.buckets[bucket_index(v)] += 1
        self.count += 1
        self.sum += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = max(self.max, v)

    def quantile(self, p):
        assert 0.0 < p <= 100.0
        if self.count == 0:
            return 0
        rank = min(max(math.ceil(p / 100.0 * self.count), 1), self.count)
        if rank == self.count:
            # The rank-selected sample is the tracked-exactly maximum.
            return self.max
        seen = 0
        for i, c in enumerate(self.buckets):
            seen += c
            if seen >= rank:
                return min(max(bucket_lower_bound(i), self.min), self.max)
        return self.max


def percentile_exact(sorted_vals, p):
    """Nearest-rank percentile, the `serve::percentile_ns` contract."""
    rank = min(max(math.ceil(p / 100.0 * len(sorted_vals)), 1), len(sorted_vals))
    return sorted_vals[rank - 1]


# --- structural invariants ------------------------------------------------

def check_geometry():
    for v in range(SUBS):
        assert bucket_index(v) == v, f"small value {v} not exact"
        assert bucket_lower_bound(v) == v
    for i in range(BUCKETS):
        lo = bucket_lower_bound(i)
        assert bucket_index(lo) == i, f"bucket {i}: lower bound {lo} does not invert"
    prev = 0
    v = 1
    while v < 2 ** 63:
        i = bucket_index(v)
        assert i >= prev, f"index not monotone at {v}"
        assert i < BUCKETS, f"index {i} out of range at {v}"
        assert bucket_lower_bound(i) <= v, f"lower bound above value at {v}"
        prev = i
        v = v * 3 + 7
    assert bucket_index(2 ** 64 - 1) < BUCKETS


# --- million-sample error-bound cross-validation --------------------------

def log_uniform(rng):
    # ~1us .. ~16ms in ns, crossing many octaves (the latency regime).
    e = 10 + rng.randrange(14)
    return (1 << e) + rng.randrange(1 << e)


def uniform(rng):
    return rng.randrange(5_000_000)


def bimodal(rng):
    # Cache-hit fast path vs slow path, 9:1.
    if rng.randrange(10) < 9:
        return 20_000 + rng.randrange(2_000)
    return 8_000_000 + rng.randrange(4_000_000)


def check_distribution(name, draw, n=1_000_000):
    rng = random.Random(0x0B5_1234)
    h = Hist()
    vals = []
    for _ in range(n):
        v = draw(rng)
        h.record(v)
        vals.append(v)
    vals.sort()
    assert h.count == n
    assert h.sum == sum(vals), f"{name}: sum not exact"
    assert h.min == vals[0] and h.max == vals[-1], f"{name}: min/max not exact"
    assert h.quantile(100.0) == h.max, f"{name}: p100 must be the exact max"
    for p in (1.0, 10.0, 50.0, 90.0, 95.0, 99.0, 99.9, 100.0):
        truth = percentile_exact(vals, p)
        got = h.quantile(p)
        err = abs(truth - got) / truth
        assert err <= REL_QUANTILE_ERROR, (
            f"{name} p{p}: got {got}, exact {truth}, err {err:.5f} "
            f"> {REL_QUANTILE_ERROR:.5f}"
        )


def main():
    check_geometry()
    for name, draw in (("log-uniform", log_uniform),
                       ("uniform", uniform),
                       ("bimodal", bimodal)):
        check_distribution(name, draw)
    print(f"OK: bucket geometry ({BUCKETS} buckets) inverts exactly; "
          f"3 distributions x 1M samples stay within the "
          f"{REL_QUANTILE_ERROR:.4f} documented quantile error")


if __name__ == "__main__":
    sys.exit(main())
