"""Independent port of the batched-lane band classification (PR 9).

No Rust toolchain ships in the build container, so the semantics that
gate the vectorized softfloat lane (`arith::kernel`) are re-implemented
here from the spec -- the RNE codec (`format::from_f64`/`encode_rne`),
the fast-path predicate (`format::is_fast_normal`) and the any-special
band mask of `mac_block` -- and validated over randomized boundary
cases:

  * codec round-trip: every storable pattern survives
    from_f64(to_f64(bits)) bit-exactly (canonical NaN aside),
  * nearest-representable: the ported encoder agrees with an
    enumerate-all-values + bisect + ties-to-even oracle for the 8- and
    16-bit formats, and with the C-cast RNE for FP32,
  * classification: is_fast_normal(bits) is exactly "decoded class is
    a *normal* finite away from the top exponent field" -- zeros,
    subnormals, Inf/NaN and the E4M3 top-exponent finites (256..448)
    all route to the slow path,
  * fast-product exactness: for fast-normal operands the const-generic
    product (sign xor, exponent add, integer significand multiply)
    equals the exact Fraction product -- the invariant that lets the
    monomorphized kernels skip re-classification,
  * band-mask semantics: a band is fast iff every element is; salting
    one special anywhere flips the whole band, and the chunked
    (lockstep, groups of 8) accumulation order is value-identical to
    per-column folds under exact arithmetic,
  * E4M3 saturation boundaries: 448 stays finite (0x7e), ties at 464
    round back to even (448), anything past saturates to NaN, and
    overflow never produces an Inf encoding.

Run:  python3 python/tests/test_kernel_band.py
"""

import random
import struct
from bisect import bisect_left
from fractions import Fraction

# --------------------------------------------------------------------------
# Ported format descriptors (arith/format.rs)
# --------------------------------------------------------------------------


class Fmt:
    def __init__(self, name, exp_bits, man_bits, ieee_specials):
        self.name = name
        self.exp_bits = exp_bits
        self.man_bits = man_bits
        self.ieee_specials = ieee_specials

    @property
    def width(self):
        return 1 + self.exp_bits + self.man_bits

    @property
    def bias(self):
        return (1 << (self.exp_bits - 1)) - 1

    @property
    def exp_field_max(self):
        return (1 << self.exp_bits) - 1

    @property
    def emin(self):
        return 1 - self.bias

    @property
    def emax(self):
        if self.ieee_specials:
            return self.exp_field_max - 1 - self.bias
        return self.exp_field_max - self.bias  # E4M3: top field is finite

    def nan_bits(self):
        if self.ieee_specials:
            return (self.exp_field_max << self.man_bits) | (1 << (self.man_bits - 1))
        return (self.exp_field_max << self.man_bits) | ((1 << self.man_bits) - 1)

    def inf_bits(self):
        if self.ieee_specials:
            return self.exp_field_max << self.man_bits
        return self.nan_bits()

    def max_finite_sig(self):
        full = (1 << (self.man_bits + 1)) - 1
        return full if self.ieee_specials else full - 1

    # class is one of "zero", "inf", "nan", "finite"
    def decode(self, bits):
        sign = (bits >> (self.width - 1)) & 1 == 1
        ef = (bits >> self.man_bits) & self.exp_field_max
        frac = bits & ((1 << self.man_bits) - 1)
        if self.ieee_specials and ef == self.exp_field_max:
            return ("inf" if frac == 0 else "nan", sign, 0, 0)
        if not self.ieee_specials and ef == self.exp_field_max and frac == (1 << self.man_bits) - 1:
            return ("nan", sign, 0, 0)
        if ef == 0:
            if frac == 0:
                return ("zero", sign, 0, 0)
            shift = self.man_bits + 1 - frac.bit_length()
            return ("finite", sign, self.emin - shift, frac << shift)
        return ("finite", sign, ef - self.bias, (1 << self.man_bits) | frac)

    def value(self, bits):
        """Exact Fraction value of a finite/zero pattern."""
        cls, sign, exp, sig = self.decode(bits)
        assert cls in ("finite", "zero"), cls
        v = Fraction(sig) * Fraction(2) ** (exp - self.man_bits)
        return -v if sign else v

    def is_fast_normal(self, bits):
        ef = (bits >> self.man_bits) & self.exp_field_max
        return ef != 0 and ef != self.exp_field_max

    def encode_rne(self, sign, exp, sig):
        """Port of format.rs encode_rne: sig = 1.xxx with man_bits+1+3 bits."""
        extra = 3
        sign_bit = int(sign) << (self.width - 1)
        if sig == 0:
            return sign_bit
        subnormal = False
        if exp < self.emin:
            sig = shift_right_sticky(sig, self.emin - exp)
            exp = self.emin
            subnormal = True
        lsb = 1 << extra
        halfway = lsb >> 1
        low = sig & (lsb - 1)
        q = sig >> extra
        if low > halfway or (low == halfway and q & 1 == 1):
            q += 1
        if q >> (self.man_bits + 1) != 0:
            q >>= 1
            exp += 1
        if subnormal and q >> self.man_bits == 0:
            return sign_bit | q
        overflow = exp > self.emax or (
            not self.ieee_specials and exp == self.emax and q > self.max_finite_sig()
        )
        if overflow:
            return sign_bit | (self.inf_bits() if self.ieee_specials else self.nan_bits())
        return sign_bit | ((exp + self.bias) << self.man_bits) | (q & ((1 << self.man_bits) - 1))

    def from_f64(self, x):
        bits = struct.unpack("<Q", struct.pack("<d", x))[0]
        sign = bits >> 63 == 1
        ef = (bits >> 52) & 0x7FF
        frac = bits & ((1 << 52) - 1)
        if ef == 0x7FF:
            special = self.inf_bits() if frac == 0 else self.nan_bits()
            return (int(sign) << (self.width - 1)) | special
        if ef == 0 and frac == 0:
            return int(sign) << (self.width - 1)
        if ef == 0:
            shift = 53 - frac.bit_length()
            exp, sig = -1022 - shift, frac << shift
        else:
            exp, sig = ef - 1023, (1 << 52) | frac
        target = self.man_bits + 1 + 3
        if 53 > target:
            sig = shift_right_sticky(sig, 53 - target)
        else:
            sig <<= target - 53
        return self.encode_rne(sign, exp, sig)

    def to_f64(self, bits):
        cls, sign, _exp, _sig = self.decode(bits)
        if cls == "zero":
            return -0.0 if sign else 0.0
        if cls == "inf":
            return float("-inf") if sign else float("inf")
        if cls == "nan":
            return float("nan")
        return float(self.value(bits))  # exact: every format embeds in f64


def shift_right_sticky(sig, shift):
    if shift >= 64:
        return 1 if sig != 0 else 0
    sticky = 1 if sig & ((1 << shift) - 1) != 0 else 0
    return (sig >> shift) | sticky


BF16 = Fmt("bf16", 8, 7, True)
FP16 = Fmt("fp16", 5, 10, True)
E4M3 = Fmt("fp8-e4m3", 4, 3, False)
E5M2 = Fmt("fp8-e5m2", 5, 2, True)
FP32 = Fmt("fp32", 8, 23, True)
ALL = [BF16, FP16, E4M3, E5M2, FP32]
SMALL = [BF16, FP16, E4M3, E5M2]  # exhaustively enumerable


# --------------------------------------------------------------------------
# Oracles
# --------------------------------------------------------------------------


def finite_table(fmt):
    """All finite (value, bits) pairs, sorted by exact value."""
    table = []
    for bits in range(1 << fmt.width):
        if fmt.decode(bits)[0] in ("finite", "zero"):
            table.append((fmt.value(bits), bits))
    table.sort(key=lambda t: t[0])
    return table


def nearest_rne(table, x):
    """Bisect oracle: nearest finite value, ties to even significand."""
    xs = [v for v, _ in table]
    i = bisect_left(xs, x)
    cands = [table[j] for j in (i - 1, i) if 0 <= j < len(table)]
    best = min(abs(v - x) for v, _ in cands)
    tied = [b for v, b in cands if abs(v - x) == best]
    if len(tied) == 1:
        return tied[0]
    even = [b for b in tied if b & 1 == 0]
    return even[0] if even else tied[0]


def check(cond, msg):
    if not cond:
        raise AssertionError(msg)


# --------------------------------------------------------------------------
# Tests
# --------------------------------------------------------------------------


def test_round_trip_all_patterns():
    for fmt in SMALL:
        for bits in range(1 << fmt.width):
            cls = fmt.decode(bits)[0]
            back = fmt.from_f64(fmt.to_f64(bits))
            if cls == "nan":
                sign_bit = bits & (1 << (fmt.width - 1))
                # f64 NaN loses the sign; canonical NaN comes back.
                check(back & ~(1 << (fmt.width - 1)) == fmt.nan_bits(),
                      f"{fmt.name} {bits:#x} nan round-trip -> {back:#x}")
                _ = sign_bit  # sign of NaN is unobservable through f64
            else:
                check(back == bits, f"{fmt.name} {bits:#x} -> {back:#x}")


def test_encoder_matches_bisect_oracle(rng):
    for fmt in SMALL:
        table = finite_table(fmt)
        vmax = float(table[-1][0])
        for _ in range(4000):
            kind = rng.randrange(4)
            if kind == 0:
                x = rng.gauss(0.0, 1.0)
            elif kind == 1:
                x = rng.gauss(0.0, 1e-3) * vmax
            elif kind == 2:
                # A representable value nudged by a fraction of its gap.
                v, _b = table[rng.randrange(1, len(table) - 1)]
                x = float(v) * (1.0 + rng.uniform(-1, 1) * 2.0 ** -(fmt.man_bits + 1))
            else:
                x = rng.uniform(-vmax, vmax)
            if abs(x) > vmax * 0.999:  # overflow handled separately
                continue
            got = fmt.from_f64(x)
            want = nearest_rne(table, Fraction(x))
            check(got == want,
                  f"{fmt.name} from_f64({x!r}) = {got:#x}, oracle {want:#x}")


def test_fp32_port_matches_c_cast(rng):
    for _ in range(4000):
        x = rng.gauss(0.0, 1.0) * 10.0 ** rng.randrange(-30, 30)
        got = FP32.from_f64(x)
        want = struct.unpack("<I", struct.pack("<f", x))[0]
        check(got == want, f"fp32 from_f64({x!r}) = {got:#x}, C cast {want:#x}")


def test_classification_matches_decode(rng):
    for fmt in SMALL:
        for bits in range(1 << fmt.width):
            cls, _s, exp, _sig = fmt.decode(bits)
            ef = (bits >> fmt.man_bits) & fmt.exp_field_max
            slow_value = (
                cls != "finite"
                or exp < fmt.emin  # subnormal (decode normalizes the sig)
                or ef == fmt.exp_field_max  # E4M3 top-exponent finites
            )
            check(fmt.is_fast_normal(bits) == (not slow_value),
                  f"{fmt.name} {bits:#x}: fast={fmt.is_fast_normal(bits)} cls={cls}")
    # FP32: sampled, same predicate.
    for _ in range(2000):
        bits = rng.getrandbits(FP32.width)
        cls, _s, exp, _sig = FP32.decode(bits)
        ef = (bits >> FP32.man_bits) & FP32.exp_field_max
        slow_value = cls != "finite" or exp < FP32.emin or ef == FP32.exp_field_max
        check(FP32.is_fast_normal(bits) == (not slow_value), f"fp32 {bits:#x}")


def fast_product(fmt, a, b):
    """Port of kernel::normal_product -- only valid on fast normals."""
    _, sa, ea, siga = fmt.decode(a)
    _, sb, eb, sigb = fmt.decode(b)
    sign = sa != sb
    exp = ea + eb
    sig = siga * sigb  # 2*man_bits fraction bits
    v = Fraction(sig) * Fraction(2) ** (exp - 2 * fmt.man_bits)
    return -v if sign else v


def random_fast(fmt, rng):
    while True:
        bits = rng.getrandbits(fmt.width)
        if fmt.is_fast_normal(bits):
            return bits


def test_fast_product_is_exact(rng):
    for fmt in ALL:
        for _ in range(1500):
            a, b = random_fast(fmt, rng), random_fast(fmt, rng)
            got = fast_product(fmt, a, b)
            want = fmt.value(a) * fmt.value(b)
            check(got == want, f"{fmt.name} product {a:#x}*{b:#x}: {got} != {want}")


def special_bits(fmt, rng):
    """One slow-path pattern: zero, subnormal, Inf/NaN or top-exponent."""
    choice = rng.randrange(4)
    if choice == 0:
        return rng.randrange(2) << (fmt.width - 1)  # +/- 0
    if choice == 1:
        return rng.getrandbits(fmt.man_bits)  # subnormal (or +0)
    if choice == 2:
        return fmt.nan_bits() if rng.randrange(2) else fmt.inf_bits()
    return (fmt.exp_field_max << fmt.man_bits) | rng.getrandbits(fmt.man_bits)


def test_band_mask_semantics(rng):
    block = 8  # kernel::BLOCK_LANES
    for fmt in ALL:
        for _ in range(300):
            k = rng.randrange(1, 33)
            cols = rng.randrange(1, 20)
            a = [random_fast(fmt, rng) for _ in range(k)]
            w = [[random_fast(fmt, rng) for _ in range(k)] for _ in range(cols)]
            band = a + [x for col in w for x in col]
            check(all(fmt.is_fast_normal(x) for x in band), "fast band must be all-normal")
            # Chunked lockstep (k-outer, lane-inner, groups of `block`)
            # vs dependent per-column folds: exact accumulation makes the
            # orders value-identical -- the indexing must agree.
            serial = [
                sum(fast_product(fmt, a[i], col[i]) for i in range(k)) for col in w
            ]
            lockstep = [Fraction(0)] * cols
            for j0 in range(0, cols, block):
                for i in range(k):
                    for j in range(j0, min(j0 + block, cols)):
                        lockstep[j] += fast_product(fmt, a[i], w[j][i])
            check(lockstep == serial, f"{fmt.name} lockstep != serial ({k}x{cols})")
            # Salting any single element makes the band slow.
            flat = list(band)
            flat[rng.randrange(len(flat))] = special_bits(fmt, rng)
            check(not all(fmt.is_fast_normal(x) for x in flat),
                  f"{fmt.name}: salted band still classified fast")


def test_e4m3_saturation_boundaries():
    check(E4M3.from_f64(448.0) == 0x7E, "448 must encode as the max finite")
    check(E4M3.from_f64(-448.0) == 0xFE, "-448 must encode as the max finite")
    check(E4M3.to_f64(0x7E) == 448.0, "0x7e must decode to 448")
    # 449..464 round back down to 448 (464 is the tie; 448 has the even
    # significand), strictly past 464 saturates to NaN -- never Inf.
    for x in (449.0, 456.0, 463.999, 464.0):
        check(E4M3.from_f64(x) == 0x7E, f"{x} must round to 448")
    for x in (464.001, 465.0, 480.0, 1e9, float("inf")):
        bits = E4M3.from_f64(x)
        check(E4M3.decode(bits)[0] == "nan", f"{x} must saturate to NaN, got {bits:#x}")
        check(bits == E4M3.nan_bits(), f"{x}: saturation must be canonical NaN")
    # The top-exponent finites exist (256..448) but are slow-path.
    for x in (256.0, 288.0, 448.0):
        bits = E4M3.from_f64(x)
        check(E4M3.decode(bits)[0] == "finite", f"{x} must stay finite")
        check(not E4M3.is_fast_normal(bits), f"{x} must be slow-path")
    check(E4M3.is_fast_normal(E4M3.from_f64(240.0)), "240 is a fast normal")
    # IEEE-like formats overflow to a true Inf instead (E5M2: ties at
    # 61440 round *up* -- the 57344 significand is odd).
    check(E5M2.from_f64(57344.0) == 0x7B, "E5M2 max finite")
    check(E5M2.from_f64(61440.0) == E5M2.inf_bits(), "E5M2 tie rounds up to Inf")
    check(E5M2.from_f64(61439.9) == 0x7B, "below the E5M2 tie stays finite")


def main():
    rng = random.Random(0x6B616E64)
    test_round_trip_all_patterns()
    test_encoder_matches_bisect_oracle(rng)
    test_fp32_port_matches_c_cast(rng)
    test_classification_matches_decode(rng)
    test_fast_product_is_exact(rng)
    test_band_mask_semantics(rng)
    test_e4m3_saturation_boundaries()
    print("test_kernel_band: all checks passed")


if __name__ == "__main__":
    main()
