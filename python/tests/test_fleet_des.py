#!/usr/bin/env python3
"""Independent Python port of the fleet discrete-event simulator.

Re-implements rust/src/fleet (event queue, arrival processes, token
buckets, batching policy, dispatch, mailbox backpressure) from the
written spec, shares nothing with the Rust code, and must land on the
*bit-identical* per-request history: `--emit-golden` writes
golden_fleet_des.json (headline counters + the FNV-1a fingerprint over
every request record), and `cargo test golden_python_port` replays the
same scenario in Rust against that file.  Run without arguments to
check the committed golden against this port (plus a same-seed
determinism replay).

Port boundary: fault injection, autoscaling and the health board are
asserted *off* in the scenario (fault_rate = fault_drop_rate = 0,
autoscale_interval = 0), so this port skips the health/energy surface
entirely — with zero faults those subsystems cannot affect any
fingerprinted field.  Everything else (Poisson/MMPP/trace/closed-loop
arrivals, bucket/watermark/capacity admission, anchor selection,
windowed coalescing, rr/ll/shape-aware routing, per-shard array
geometries, depth-2 mailboxes, blocked-batcher backpressure) is ported
exactly.

A second scenario (SCENARIO_HETERO → golden_fleet_hetero.json) runs a
heterogeneous pool: per-shard geometries and the shape-aware policy,
which quotes every batch's GEMM against each shard's geometry through
the rectangular timing model and routes to the minimum-cycle shard
(ties toward the lower index).  Its golden additionally pins the total
stream-cycle count.

Service times come from layer_timing in test_streaming_timing.py — the
same independent timing port the streaming cycle simulator is pinned
against — so the cross-language agreement covers the full path from
arrival draws down to per-batch service cycles.
"""

import json
import os
import struct
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from test_streaming_timing import layer_timing, tile_plan  # noqa: E402

MASK = (1 << 64) - 1

# Salt constants, mirrored from rust/src/fleet/sim.rs.
CONTENT_MIX = 0x9E3779B97F4A7C15
ARRIVAL_MIX = 0xCBF29CE484222325
TENANT_MIX = 0xA0761D6478BD642F

# Structural constants (rust/src/fleet/sim.rs, rust/src/serve/request.rs).
MAILBOX_DEPTH = 2
MAX_FRONT_BYPASS = 64

# (S, D, tail) stage parameters per pipeline kind — the same table
# test_streaming_timing.py validates against the Rust machine.
KIND_SPECS = {
    "regular-3a": (2, 2, 0),
    "baseline-3b": (2, 2, 0),
    "skewed": (1, 2, 1),
    "transparent": (1, 2, 0),
    "deep3": (2, 3, 0),
}

FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3

GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)), "golden_fleet_des.json")
GOLDEN_HETERO = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "golden_fleet_hetero.json"
)


# ---------------------------------------------------------------------------
# RNG: xoshiro256** seeded via SplitMix64 (rust/src/util/rng.rs).


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK


class Rng:
    def __init__(self, seed):
        self.s = []
        sm = seed & MASK
        for _ in range(4):
            sm = (sm + 0x9E3779B97F4A7C15) & MASK
            z = sm
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
            self.s.append(z ^ (z >> 31))

    def next_u64(self):
        s = self.s
        r = (_rotl((s[1] * 5) & MASK, 7) * 9) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return r

    def below(self, n):
        return (self.next_u64() * n) >> 64

    def unit_f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def chance(self, p):
        return self.unit_f64() < p


# ---------------------------------------------------------------------------
# Portable exponential sampling (rust/src/fleet/arrival.rs): -ln(u) from
# exactly-rounded IEEE-754 ops only, so Python and Rust draw identical
# integer gaps.

LN2 = 0.6931471805599453


def neg_ln(u):
    bits = struct.unpack("<Q", struct.pack("<d", u))[0]
    e = ((bits >> 52) & 0x7FF) - 1023
    m = struct.unpack("<d", struct.pack("<Q", (bits & 0x000FFFFFFFFFFFFF) | (1023 << 52)))[0]
    t = (m - 1.0) / (m + 1.0)
    t2 = t * t
    s = 0.0
    k = 27
    while k >= 1:
        s = s * t2 + 1.0 / k
        k -= 2
    ln_m = 2.0 * t * s
    return -(e * LN2 + ln_m)


def unit_open(rng):
    return ((rng.next_u64() >> 11) + 1) * (1.0 / (1 << 53))


def exp_gap(rng, mean_cycles):
    return max(1, int(mean_cycles * neg_ln(unit_open(rng))))


# ---------------------------------------------------------------------------
# Serving policy (rust/src/serve/policy.rs) — pure functions.


def should_shed(watermark, cls, queue_len):
    return watermark > 0 and cls == "batch" and queue_len >= watermark


def anchor_index(classes, front_bypassed, max_front_bypass):
    first_interactive = None
    n = 0
    for i, c in enumerate(classes):
        n += 1
        if first_interactive is None and c == "interactive":
            first_interactive = i
    if first_interactive is not None:
        if first_interactive > 0 and front_bypassed >= max_front_bypass:
            return 0
        return first_interactive
    return None if n == 0 else 0


def batch_caps_reached(parts, rows, max_requests, max_rows):
    return parts >= max_requests or rows >= max_rows


def member_fits(model, kind, rows, max_rows, c_model, c_kind, c_rows):
    return c_model == model and c_kind == kind and rows + c_rows <= max_rows


# ---------------------------------------------------------------------------
# Simulator state containers.


class TokenBucket:
    def __init__(self, capacity, refill_cycles):
        self.capacity = capacity
        self.refill = refill_cycles
        self.tokens = capacity
        self.last = 0

    def admit(self, now):
        if self.capacity == 0:
            return True
        periods = (now - self.last) // self.refill
        if periods > 0:
            self.tokens = min(self.tokens + periods, self.capacity)
            self.last += periods * self.refill
        if self.tokens > 0:
            self.tokens -= 1
            return True
        return False


class Tenant:
    def __init__(self, ti, spec, fleet_seed):
        self.spec = spec
        self.arrival = spec["arrival"]
        self.kinds = spec.get("kinds", "skewed").split(",")
        self.frac = min(1.0, max(0.0, spec.get("interactive_fraction", 0.2)))
        self.min_rows = max(1, spec.get("min_rows", 2))
        self.max_rows = max(self.min_rows, spec.get("max_rows", 8))
        self.bucket = TokenBucket(
            spec.get("bucket_capacity", 0), max(1, spec.get("bucket_refill", 0))
        )
        self.content = Rng(fleet_seed ^ ((ti + 1) * CONTENT_MIX & MASK))
        self.gaps = Rng(fleet_seed ^ ((ti + 1) * ARRIVAL_MIX & MASK))
        # MMPP dwell state: first calm dwell drawn at construction.
        self.burst = False
        self.dwell_end = 0
        if self.arrival["kind"] == "mmpp":
            self.dwell_end = exp_gap(self.gaps, self.arrival["mean_dwell_calm"])


class Record:
    __slots__ = ("id", "tenant", "status", "shard", "submit", "done", "batch_size", "service")

    def __init__(self, rid, tenant, status, submit):
        self.id = rid
        self.tenant = tenant
        self.status = status
        self.shard = None
        self.submit = submit
        self.done = submit if status == "shed" else 0
        self.batch_size = 0
        self.service = 0


class SimReq:
    __slots__ = ("id", "tenant", "client", "index", "submit", "model", "rows", "kind", "cls")

    def __init__(self, rid, tenant, client, index, submit, model, rows, kind, cls):
        self.id = rid
        self.tenant = tenant
        self.client = client
        self.index = index
        self.submit = submit
        self.model = model
        self.rows = rows
        self.kind = kind
        self.cls = cls


class Batch:
    __slots__ = ("parts", "service", "drop")

    def __init__(self, parts, service, drop):
        self.parts = parts
        self.service = service
        self.drop = drop


class Shard:
    __slots__ = ("running", "mailbox", "inflight")

    def __init__(self):
        self.running = None
        self.mailbox = []
        self.inflight = 0


STATUS_CODE = {"pending": 0, "served": 1, "shed": 2, "failed": 3}


def fingerprint(records):
    h = FNV_OFFSET
    for r in records:
        shard = r.shard if r.shard is not None else MASK
        for v in (r.id, STATUS_CODE[r.status], shard, r.submit, r.done, r.batch_size, r.service):
            for b in struct.pack("<Q", v & MASK):
                h = ((h ^ b) * FNV_PRIME) & MASK
    return h


# ---------------------------------------------------------------------------
# The simulator (rust/src/fleet/sim.rs, handler for handler).


class FleetSim:
    def __init__(self, run, fleet):
        assert fleet.get("fault_rate", 0.0) == 0.0, "port boundary: faults off"
        assert fleet.get("fault_drop_rate", 0.0) == 0.0, "port boundary: drops off"
        assert fleet.get("autoscale_interval", 0) == 0, "port boundary: autoscaler off"
        self.run_rows = run["rows"]
        self.run_cols = run["cols"]
        # Per-shard array geometry: "ROWSxCOLS" strings, repeating when
        # shorter than the pool; empty = every shard runs the run
        # geometry (rust/src/config FleetConfig::shard_geometry).
        self.shard_geoms = [
            tuple(int(x) for x in g.split("x"))
            for g in fleet.get("shard_geometries", [])
        ]
        self.double_buffer = run.get("double_buffer", True)
        self.cfg = fleet
        self.seed = fleet["seed"]
        self.horizon = fleet["horizon"]
        self.models = [(m["k"], m["n"]) for m in fleet["models"]]
        self.policy = fleet.get("shard_policy", "rr")
        self.tenants = [Tenant(ti, s, self.seed) for ti, s in enumerate(fleet["tenants"])]
        self.active = max(fleet["min_shards"], min(fleet["shards"], fleet["max_shards"]))
        self.shards = [Shard() for _ in range(fleet["max_shards"])]
        self.rr_next = 0
        self.fifo = []
        self.front_bypassed = 0
        self.batcher = ("idle",)
        self.next_batch_seq = 0
        self.batch_ids = 0
        self.outcomes = []
        self.svc_memo = {}
        self.heap = []
        self.pushed = 0
        self.now = 0
        self.submitted = 0
        self.served = 0
        self.failed = 0
        self.shed = {"bucket": 0, "watermark": 0, "capacity": 0}
        self.batches = 0
        self.batched_rows = 0
        self.max_batch = 0
        self.stream_cycles = 0

    # -- event queue: (time, push-seq) ordering, exactly like event.rs --

    def push(self, time, event):
        assert time >= self.now, "event scheduled in the past"
        self.heap.append((time, self.pushed, event))
        self.pushed += 1

    def run(self):
        self.seed_initial_events()
        import heapq

        heapq.heapify(self.heap)
        heap = self.heap
        while heap:
            time, _, ev = heapq.heappop(heap)
            self.now = time
            kind = ev[0]
            if kind == "arr":
                self.on_arrival(time, ev[1], ev[2], ev[3])
            elif kind == "win":
                self.on_window_close(time, ev[1])
            else:
                self.on_shard_done(time, ev[1])
        assert all(r.status != "pending" for r in self.outcomes), "pending after drain"
        return self.result()

    # NOTE: run() heapifies whatever seed_initial_events pushed, then
    # every later push must keep the heap invariant:

    def push_live(self, time, event):
        import heapq

        assert time >= self.now, "event scheduled in the past"
        heapq.heappush(self.heap, (time, self.pushed, event))
        self.pushed += 1

    def seed_initial_events(self):
        for ti, tr in enumerate(self.tenants):
            a = tr.arrival
            if a["kind"] == "closed":
                if a["requests_per_client"] == 0:
                    continue
                for c in range(a["clients"]):
                    self.push(0, ("arr", ti, c, 0))
            elif a["kind"] == "trace":
                reqs = a["requests"]
                if reqs and reqs[0]["at"] <= self.horizon:
                    self.push(reqs[0]["at"], ("arr", ti, 0, 0))
            else:
                t0 = self.next_open_arrival(ti, 0, 0)
                if t0 is not None and t0 <= self.horizon:
                    self.push(t0, ("arr", ti, 0, 0))

    def next_open_arrival(self, ti, now, index):
        tr = self.tenants[ti]
        a = tr.arrival
        k = a["kind"]
        if k == "trace":
            reqs = a["requests"]
            return reqs[index + 1]["at"] if index + 1 < len(reqs) else None
        if k == "closed":
            return None
        if k == "poisson":
            return now + exp_gap(tr.gaps, a["mean_gap"])
        while now >= tr.dwell_end:
            tr.burst = not tr.burst
            mean = a["mean_dwell_burst"] if tr.burst else a["mean_dwell_calm"]
            tr.dwell_end += exp_gap(tr.gaps, mean)
        mean = a["mean_gap_burst"] if tr.burst else a["mean_gap_calm"]
        return now + exp_gap(tr.gaps, mean)

    # -- arrival: content, next arrival, admission, poke (sim.rs order) --

    def on_arrival(self, t, tenant, client, index):
        model, rows, kind, cls = self.request_content(tenant, client, index)
        nxt = self.next_open_arrival(tenant, t, index)
        if nxt is not None and nxt <= self.horizon:
            self.push_live(nxt, ("arr", tenant, 0, index + 1))
        rid = len(self.outcomes)
        self.submitted += 1
        tr = self.tenants[tenant]
        if not tr.bucket.admit(t):
            reason = "bucket"
        elif should_shed(self.cfg["shed_watermark"], cls, len(self.fifo)):
            reason = "watermark"
        elif len(self.fifo) >= self.cfg["queue_cap"]:
            reason = "capacity"
        else:
            reason = None
        if reason is not None:
            self.shed[reason] += 1
            self.outcomes.append(Record(rid, tenant, "shed", t))
            self.push_closed_next(t, tenant, client, index)
        else:
            self.outcomes.append(Record(rid, tenant, "pending", t))
            self.fifo.append(SimReq(rid, tenant, client, index, t, model, rows, kind, cls))
        self.poke(t)

    def request_content(self, tenant, client, index):
        tr = self.tenants[tenant]
        a = tr.arrival
        if a["kind"] == "closed":
            return self.closed_draw(tr, tenant, client, index)
        if a["kind"] == "trace":
            r = a["requests"][index]
            return (
                r["model"],
                max(1, r["rows"]),
                r.get("pipeline", "skewed"),
                r.get("class", "batch"),
            )
        model = tr.content.below(len(self.models))
        rows = tr.min_rows + tr.content.below(tr.max_rows - tr.min_rows + 1)
        kind = tr.kinds[tr.content.below(len(tr.kinds))]
        cls = "interactive" if tr.content.chance(tr.frac) else "batch"
        return model, rows, kind, cls

    def closed_draw(self, tr, tenant, client, index):
        base = self.seed ^ (tenant * TENANT_MIX & MASK)
        rng = Rng(base ^ ((client + 1) * CONTENT_MIX & MASK) ^ ((index + 1) * ARRIVAL_MIX & MASK))
        model = rng.below(len(self.models))
        rows = tr.min_rows + rng.below(tr.max_rows - tr.min_rows + 1)
        kind = tr.kinds[rng.below(len(tr.kinds))]
        cls = "interactive" if rng.chance(tr.frac) else "batch"
        return model, rows, kind, cls

    def push_closed_next(self, t, tenant, client, index):
        a = self.tenants[tenant].arrival
        if a["kind"] == "closed" and index + 1 < a["requests_per_client"]:
            self.push_live(t, ("arr", tenant, client, index + 1))

    # -- batcher (poke loop mirrors sim.rs poke_batcher) --

    def on_window_close(self, t, batch_seq):
        if self.batcher[0] == "col" and self.batcher[1] == batch_seq:
            self.poke(t)

    def poke(self, t):
        cfg = self.cfg
        while True:
            st = self.batcher
            if st[0] == "blocked":
                return
            if st[0] == "idle":
                i = anchor_index(
                    (r.cls for r in self.fifo), self.front_bypassed, MAX_FRONT_BYPASS
                )
                if i is None:
                    return
                if i == 0:
                    self.front_bypassed = 0
                else:
                    self.front_bypassed += 1
                anchor = self.fifo.pop(i)
                window = (
                    cfg["interactive_window"]
                    if anchor.cls == "interactive"
                    else cfg["batch_window"]
                )
                seq = self.next_batch_seq
                self.next_batch_seq += 1
                self.batcher = (
                    "col",
                    seq,
                    anchor.model,
                    anchor.kind,
                    anchor.rows,
                    [anchor],
                    t + window,
                    False,
                )
                continue
            _, seq, model, kind, rows, parts, deadline, scheduled = st
            i = 0
            while i < len(self.fifo):
                if batch_caps_reached(
                    len(parts), rows, cfg["max_batch_requests"], cfg["max_batch_rows"]
                ):
                    break
                c = self.fifo[i]
                if member_fits(model, kind, rows, cfg["max_batch_rows"], c.model, c.kind, c.rows):
                    self.fifo.pop(i)
                    rows += c.rows
                    parts.append(c)
                else:
                    i += 1
            caps = batch_caps_reached(
                len(parts), rows, cfg["max_batch_requests"], cfg["max_batch_rows"]
            )
            waiting = any(r.cls == "interactive" for r in self.fifo)
            early = waiting or any(p.cls == "interactive" for p in parts[1:])
            if caps or early or t >= deadline:
                self.batcher = ("idle",)
                if not self.dispatch(t, model, kind, rows, parts):
                    return
            else:
                if not scheduled:
                    self.push_live(deadline, ("win", seq))
                self.batcher = ("col", seq, model, kind, rows, parts, deadline, True)
                return

    # -- dispatch + shard mailboxes (sim.rs dispatch/deliver) --

    def shard_geometry(self, s):
        if not self.shard_geoms:
            return (self.run_rows, self.run_cols)
        return self.shard_geoms[s % len(self.shard_geoms)]

    def service_cycles(self, model, kind, m_rows, geom):
        key = (model, kind, m_rows, geom)
        got = self.svc_memo.get(key)
        if got is None:
            k, n = self.models[model]
            s, d, tail = KIND_SPECS[kind]
            rows, cols = geom
            tiles = tile_plan(m_rows, k, n, rows, cols)
            got = layer_timing(s, d, tail, m_rows, rows, tiles, self.double_buffer)[0]
            self.svc_memo[key] = got
        return got

    def dispatch(self, t, model, kind, rows, parts):
        # Routing mirrors sim.rs dispatch: health ticks first on the
        # Rust side, but with faults asserted off (port boundary) the
        # board never excludes anyone, so eligible == the active pool.
        eligible = range(self.active)
        if self.policy in ("rr", "round_robin"):
            shard = self.rr_next % self.active
            self.rr_next += 1
        elif self.policy in ("shape", "shape_aware", "shape-aware"):
            # Best fit: min predicted stream cycles under each shard's
            # geometry, ties toward the lower index (serve/policy.rs
            # best_fit_shard) — deterministic, no load term.
            shard = min(
                eligible,
                key=lambda s: (self.service_cycles(model, kind, rows, self.shard_geometry(s)), s),
            )
        else:
            shard = min(eligible, key=lambda s: (self.shards[s].inflight, s))
        # The quote is always under the *chosen* shard's geometry.
        service = self.service_cycles(model, kind, rows, self.shard_geometry(shard))
        self.batch_ids += 1
        # Faults and drops are hash-draws against fault_rate == 0 here
        # (asserted in __init__), so every batch is clean by contract.
        self.batches += 1
        self.batched_rows += rows
        self.max_batch = max(self.max_batch, len(parts))
        self.stream_cycles += service
        batch = Batch(parts, service, False)
        self.shards[shard].inflight += 1
        return self.deliver(t, shard, batch)

    def deliver(self, t, shard, batch):
        sh = self.shards[shard]
        if sh.running is None and not sh.mailbox:
            self.push_live(t + batch.service, ("done", shard))
            sh.running = batch
            return True
        if len(sh.mailbox) < MAILBOX_DEPTH:
            sh.mailbox.append(batch)
            return True
        self.batcher = ("blocked", batch, shard)
        return False

    def on_shard_done(self, t, shard):
        sh = self.shards[shard]
        batch = sh.running
        sh.running = None
        size = len(batch.parts)
        for p in batch.parts:
            rec = self.outcomes[p.id]
            rec.shard = shard
            rec.done = t
            rec.batch_size = size
            rec.service = batch.service
            if batch.drop:
                rec.status = "failed"
                self.failed += 1
            else:
                rec.status = "served"
                self.served += 1
        sh.inflight -= 1
        if sh.mailbox:
            nxt = sh.mailbox.pop(0)
            self.push_live(t + nxt.service, ("done", shard))
            sh.running = nxt
        for p in batch.parts:
            self.push_closed_next(t, p.tenant, p.client, p.index)
        if self.batcher[0] == "blocked" and self.batcher[2] == shard:
            blocked = self.batcher[1]
            self.batcher = ("idle",)
            assert self.deliver(t, shard, blocked), "mailbox must have room after a completion"
        self.poke(t)

    def result(self):
        total_shed = sum(self.shed.values())
        assert self.submitted == self.served + total_shed + self.failed, "accounting"
        return {
            "submitted": self.submitted,
            "served": self.served,
            "shed": total_shed,
            "shed_bucket": self.shed["bucket"],
            "shed_watermark": self.shed["watermark"],
            "shed_capacity": self.shed["capacity"],
            "failed": self.failed,
            "batches": self.batches,
            "batched_rows": self.batched_rows,
            "max_batch": self.max_batch,
            "wall_cycles": self.now,
            "fingerprint": "%016x" % fingerprint(self.outcomes),
        }


# ---------------------------------------------------------------------------
# The golden scenario.  Every knob explicit; decimal literals restricted
# to values any digit-accumulation float parser lands on exactly.


SCENARIO = {
    "run": {"rows": 8, "cols": 8, "in_fmt": "bf16", "double_buffer": True},
    "fleet": {
        "shards": 2,
        "min_shards": 2,
        "max_shards": 2,
        "queue_cap": 12,
        "shed_watermark": 6,
        "batch_window": 400,
        "interactive_window": 40,
        "max_batch_requests": 4,
        "max_batch_rows": 16,
        "plan_cache_cap": 32,
        "shard_policy": "rr",
        "fault_rate": 0.0,
        "fault_drop_rate": 0.0,
        "horizon": 120000,
        "autoscale_interval": 0,
        "seed": 423009317,
        "record_limit": 4096,
        "models": [{"k": 24, "n": 16}, {"k": 40, "n": 8}],
        "tenants": [
            {
                "name": "steady",
                "arrival": {"kind": "poisson", "mean_gap": 700.0},
                "kinds": "skewed",
                "interactive_fraction": 0.25,
                "min_rows": 2,
                "max_rows": 6,
                "bucket_capacity": 0,
                "bucket_refill": 1,
            },
            {
                "name": "bursty",
                "arrival": {
                    "kind": "mmpp",
                    "mean_gap_calm": 3000.0,
                    "mean_gap_burst": 80.0,
                    "mean_dwell_calm": 20000.0,
                    "mean_dwell_burst": 8000.0,
                },
                "kinds": "baseline-3b,skewed",
                "interactive_fraction": 0.1,
                "min_rows": 1,
                "max_rows": 4,
                "bucket_capacity": 0,
                "bucket_refill": 1,
            },
            {
                "name": "capped",
                "arrival": {"kind": "poisson", "mean_gap": 300.0},
                "kinds": "skewed",
                "interactive_fraction": 0.2,
                "min_rows": 2,
                "max_rows": 5,
                "bucket_capacity": 3,
                "bucket_refill": 1500,
            },
            {
                "name": "replay",
                "arrival": {
                    "kind": "trace",
                    "requests": [
                        {"at": 0, "model": 0, "rows": 3, "pipeline": "skewed",
                         "class": "interactive"},
                        {"at": 50, "model": 0, "rows": 2, "pipeline": "skewed", "class": "batch"},
                        {"at": 60, "model": 1, "rows": 2, "pipeline": "skewed", "class": "batch"},
                        {"at": 70, "model": 1, "rows": 2, "pipeline": "skewed", "class": "batch"},
                        {"at": 90, "model": 1, "rows": 1, "pipeline": "baseline-3b",
                         "class": "interactive"},
                        {"at": 20000, "model": 0, "rows": 4, "pipeline": "skewed",
                         "class": "batch"},
                        {"at": 20010, "model": 0, "rows": 4, "pipeline": "skewed",
                         "class": "batch"},
                        {"at": 20020, "model": 0, "rows": 4, "pipeline": "skewed",
                         "class": "batch"},
                        {"at": 20030, "model": 0, "rows": 4, "pipeline": "skewed",
                         "class": "batch"},
                        {"at": 20040, "model": 0, "rows": 4, "pipeline": "skewed",
                         "class": "batch"},
                    ],
                },
                "kinds": "skewed",
                "interactive_fraction": 0.0,
                "min_rows": 1,
                "max_rows": 8,
                "bucket_capacity": 0,
                "bucket_refill": 1,
            },
            {
                "name": "loop",
                "arrival": {"kind": "closed", "clients": 2, "requests_per_client": 30},
                "kinds": "skewed",
                "interactive_fraction": 0.2,
                "min_rows": 2,
                "max_rows": 5,
                "bucket_capacity": 0,
                "bucket_refill": 1,
            },
        ],
    },
}


# The heterogeneous scenario: three shard geometries at one pool, the
# shape-aware policy, and three model shapes built so each geometry is
# the best fit for one of them (reduction-deep → tall 16x4, output-wide
# → wide 4x16, balanced → square 8x8).  Open-loop tenants draw models
# uniformly, so every shard earns real traffic and the fingerprint pins
# the full routing history.
SCENARIO_HETERO = {
    "run": {"rows": 8, "cols": 8, "in_fmt": "bf16", "double_buffer": True},
    "fleet": {
        "shards": 3,
        "min_shards": 3,
        "max_shards": 3,
        "queue_cap": 12,
        "shed_watermark": 6,
        "batch_window": 400,
        "interactive_window": 40,
        "max_batch_requests": 4,
        "max_batch_rows": 16,
        "plan_cache_cap": 32,
        "shard_policy": "shape",
        "shard_geometries": ["16x4", "4x16", "8x8"],
        "fault_rate": 0.0,
        "fault_drop_rate": 0.0,
        "horizon": 120000,
        "autoscale_interval": 0,
        "seed": 771002963,
        "record_limit": 4096,
        "models": [{"k": 64, "n": 4}, {"k": 4, "n": 64}, {"k": 24, "n": 16}],
        "tenants": [
            {
                "name": "decode",
                "arrival": {"kind": "poisson", "mean_gap": 600.0},
                "kinds": "skewed",
                "interactive_fraction": 0.5,
                "min_rows": 1,
                "max_rows": 4,
                "bucket_capacity": 0,
                "bucket_refill": 1,
            },
            {
                "name": "mixed",
                "arrival": {"kind": "poisson", "mean_gap": 900.0},
                "kinds": "baseline-3b,skewed",
                "interactive_fraction": 0.2,
                "min_rows": 2,
                "max_rows": 6,
                "bucket_capacity": 0,
                "bucket_refill": 1,
            },
            {
                "name": "loop",
                "arrival": {"kind": "closed", "clients": 2, "requests_per_client": 25},
                "kinds": "skewed",
                "interactive_fraction": 0.3,
                "min_rows": 2,
                "max_rows": 5,
                "bucket_capacity": 0,
                "bucket_refill": 1,
            },
        ],
    },
}


def simulate(scenario, with_stream=False):
    sim = FleetSim(scenario["run"], scenario["fleet"])
    res = sim.run()
    if with_stream:
        res = dict(res, stream_cycles=sim.stream_cycles)
    return res


def emit_golden():
    expect = simulate(SCENARIO)
    doc = {"run": SCENARIO["run"], "fleet": SCENARIO["fleet"], "expect": expect}
    with open(GOLDEN, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"wrote {GOLDEN}")
    for k, v in expect.items():
        print(f"  {k}: {v}")


def emit_golden_hetero():
    expect = simulate(SCENARIO_HETERO, with_stream=True)
    doc = {
        "run": SCENARIO_HETERO["run"],
        "fleet": SCENARIO_HETERO["fleet"],
        "expect": expect,
    }
    with open(GOLDEN_HETERO, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"wrote {GOLDEN_HETERO}")
    for k, v in expect.items():
        print(f"  {k}: {v}")


def check_golden():
    with open(GOLDEN) as f:
        doc = json.load(f)
    assert doc["run"] == SCENARIO["run"], "golden 'run' drifted from SCENARIO — re-emit"
    assert doc["fleet"] == SCENARIO["fleet"], "golden 'fleet' drifted from SCENARIO — re-emit"
    got = simulate({"run": doc["run"], "fleet": doc["fleet"]})
    again = simulate({"run": doc["run"], "fleet": doc["fleet"]})
    assert got == again, f"nondeterministic replay:\n{got}\nvs\n{again}"
    want = doc["expect"]
    assert got == want, "golden mismatch:\n" + "\n".join(
        f"  {k}: got {got.get(k)} want {want.get(k)}" for k in sorted(set(got) | set(want))
    )
    # Sanity: the scenario must actually exercise the admission paths.
    assert got["shed_bucket"] > 0, "scenario should bucket-shed"
    assert got["shed_watermark"] > 0, "scenario should watermark-shed"
    assert got["served"] > 100, "scenario should serve a real load"
    assert got["max_batch"] > 1, "scenario should coalesce batches"
    print(
        "OK: fleet DES port matches golden "
        f"({got['submitted']} requests, {got['batches']} batches, "
        f"fingerprint {got['fingerprint']})"
    )


def check_golden_hetero():
    with open(GOLDEN_HETERO) as f:
        doc = json.load(f)
    assert doc["run"] == SCENARIO_HETERO["run"], "hetero golden 'run' drifted — re-emit"
    assert doc["fleet"] == SCENARIO_HETERO["fleet"], "hetero golden 'fleet' drifted — re-emit"
    sim = FleetSim(doc["run"], doc["fleet"])
    got = dict(sim.run(), stream_cycles=sim.stream_cycles)
    again = simulate({"run": doc["run"], "fleet": doc["fleet"]}, with_stream=True)
    assert got == again, f"nondeterministic hetero replay:\n{got}\nvs\n{again}"
    want = doc["expect"]
    assert got == want, "hetero golden mismatch:\n" + "\n".join(
        f"  {k}: got {got.get(k)} want {want.get(k)}" for k in sorted(set(got) | set(want))
    )
    # Sanity: heterogeneity must actually show in the routing history.
    shards_used = {r.shard for r in sim.outcomes if r.shard is not None}
    assert shards_used == {0, 1, 2}, f"every geometry should win traffic, got {shards_used}"
    assert got["served"] > 50, "hetero scenario should serve a real load"
    assert got["max_batch"] > 1, "hetero scenario should coalesce batches"
    assert got["stream_cycles"] > 0
    print(
        "OK: heterogeneous shape-aware port matches golden "
        f"({got['submitted']} requests, {got['stream_cycles']} stream cycles, "
        f"fingerprint {got['fingerprint']})"
    )


def main():
    if "--emit-golden" in sys.argv[1:]:
        emit_golden()
        emit_golden_hetero()
    else:
        check_golden()
        check_golden_hetero()


if __name__ == "__main__":
    main()
