"""Independent validation of the ABFT tolerance model (ISSUE 6).

No Rust toolchain ships in the build container, so the analytic
column-checksum tolerance in `coordinator/verify/abft.rs` -- the line
that separates legitimate reduced-precision deviation from injected
corruption -- is re-derived here from the format parameters alone and
checked against ground truths the Rust code never states explicitly:

  * the published extrema of every supported format (BF16/FP16/FP8
    max-finite values, subnormal ULP floors) match the ported
    `max_finite` / `ulp_floor` closed forms;
  * exhaustive enumeration of all 65536 BF16 bit patterns shows the
    smallest deviation an exponent-MSB flip (`flip_exp_msb`) can
    produce is exactly 2.0 -- the injected-fault band;
  * the ported `column_tolerance` stays far below that band for the
    paper's BF16 evaluation chain across the whole magnitude range the
    integer test workloads can reach, so detection has margin on both
    sides (no false positives, no misses);
  * the tolerance is monotone in K, in the checksum length and in the
    column magnitude bound, and collapses toward the f64-noise floor
    as the workload shrinks.

Run:  python3 python/tests/test_abft_tolerance.py
"""

import math

SAFETY = 4.0  # abft.rs::SAFETY


# --------------------------------------------------------------------------
# Format parameters (arith/format.rs) and their closed forms
# --------------------------------------------------------------------------
class Fmt:
    def __init__(self, name, exp_bits, man_bits, ieee_specials):
        self.name = name
        self.exp_bits = exp_bits
        self.man_bits = man_bits
        self.ieee_specials = ieee_specials

    @property
    def bias(self):
        return (1 << (self.exp_bits - 1)) - 1

    @property
    def emin(self):
        return 1 - self.bias

    @property
    def emax(self):
        top = (1 << self.exp_bits) - 1
        return top - self.bias if not self.ieee_specials else top - 1 - self.bias

    def max_finite(self):
        full = (1 << (self.man_bits + 1)) - 1
        sig = full if self.ieee_specials else full - 1
        return sig * 2.0 ** (self.emax - self.man_bits)

    def ulp_floor(self):
        return 2.0 ** (self.emin - self.man_bits)


FP32 = Fmt("fp32", 8, 23, True)
BF16 = Fmt("bf16", 8, 7, True)
FP16 = Fmt("fp16", 5, 10, True)
FP8E4M3 = Fmt("fp8e4m3", 4, 3, False)
FP8E5M2 = Fmt("fp8e5m2", 5, 2, True)


def chain_window(in_fmt, out_fmt):
    """ChainCfg::new's canonical accumulator window for the pair."""
    return max(2 * in_fmt.man_bits + 4, out_fmt.man_bits + 4)


def column_tolerance(in_fmt, out_fmt, k, k_tiles, count, t_abs):
    """Port of abft.rs::element_tolerance (count = checksum length)."""
    window = chain_window(in_fmt, out_fmt)
    roundings = 2.0 * k_tiles - 1.0
    rel = k * 2.0 ** (3 - window) + roundings * 2.0 ** (1 - out_fmt.man_bits)
    floor = roundings * count * out_fmt.ulp_floor()
    fsum = (count + k + 4.0) * 2.0**-52 * t_abs
    return SAFETY * (rel * t_abs + floor + fsum)


# --------------------------------------------------------------------------
# Ground truths
# --------------------------------------------------------------------------
def test_published_format_extrema():
    # OCP / IEEE published constants, not derived from the Rust source.
    assert FP16.max_finite() == 65504.0
    assert FP8E4M3.max_finite() == 448.0
    assert FP8E5M2.max_finite() == 57344.0
    assert BF16.max_finite() == (255 / 128) * 2.0**127
    assert FP32.max_finite() == (2.0 - 2.0**-23) * 2.0**127
    assert FP32.ulp_floor() == 2.0**-149
    assert BF16.ulp_floor() == 2.0**-133
    assert FP16.ulp_floor() == 2.0**-24
    assert FP8E4M3.ulp_floor() == 2.0**-9
    assert FP8E5M2.ulp_floor() == 2.0**-16


def bf16_decode(bits):
    """Value of a BF16 bit pattern (math.inf / math.nan for specials)."""
    sign = -1.0 if bits >> 15 else 1.0
    e = (bits >> 7) & 0xFF
    f = bits & 0x7F
    if e == 0xFF:
        return math.nan if f else sign * math.inf
    if e == 0:
        return sign * (f / 128.0) * 2.0**-126
    return sign * (1.0 + f / 128.0) * 2.0 ** (e - 127)


def min_flip_deviation_bf16():
    """Smallest |flip_exp_msb(x) - x| over every finite BF16 pattern."""
    best = math.inf
    for bits in range(1 << 16):
        v = bf16_decode(bits)
        if math.isnan(v):
            continue
        flipped = bf16_decode(bits ^ (1 << 14))  # exponent MSB
        if math.isnan(flipped):
            continue
        dev = abs(flipped - v)
        if dev < best:
            best = dev
    return best


def test_exponent_msb_flip_band_is_2():
    # The minimizer is |x| = 2.0: clearing the exponent MSB lands on a
    # subnormal, a deviation of (almost exactly) the value itself.
    # Everything smaller in magnitude *gains* the MSB and jumps by
    # >= 2.0 instead.  Exhaustive over all 65536 patterns.
    assert min_flip_deviation_bf16() == 2.0


def test_tolerance_sits_far_below_the_flip_band():
    # The paper's evaluation chain: BF16 inputs, FP32 accumulator,
    # window 27.  At the chaos suite's scale (K <= 64, batches of
    # M <= 8 rows, integer operands |a| <= 8, |w| <= 4, so a column's
    # absolute magnitude bound t_abs stays below 8*8*4*K) the tolerance
    # keeps at least a 4x margin below the 2.0 flip band for any tiling
    # of K -- minimal flips are always detectable there.
    assert chain_window(BF16, FP32) == 27
    for k in (8, 12, 20, 64):
        for rows in (8, 16, 32):
            k_tiles = -(-k // rows)
            t_abs = 8 * 8 * 4 * k
            tol = column_tolerance(BF16, FP32, k, k_tiles, rows, t_abs)
            assert tol < 0.5, (k, rows, tol)
    # At the abft.rs unit-test scale (K=20, M=6): below 0.04 even at
    # the worst-case magnitude ceiling, and below that file's own 0.02
    # pin at the workload's typical column magnitude (mean |a| ~ 4,
    # mean |w| ~ 2 over 6 rows and K=20 gives t_abs ~ 960).
    assert column_tolerance(BF16, FP32, 20, 3, 6, 48 * 4 * 20) < 0.04
    assert column_tolerance(BF16, FP32, 20, 3, 6, 960.0) < 0.02
    # The relative band genuinely scales with magnitude: deep columns
    # of maximal stacked magnitude (K=128 split over a 8-row array,
    # 64 stacked rows) push the tolerance *past* a minimal 2.0 flip --
    # which is why the property suites inject corruption sized above
    # the tolerance rather than relying on the smallest possible flip.
    assert column_tolerance(BF16, FP32, 128, 16, 64, 8 * 64 * 4 * 128) > 2.0


def test_tolerance_monotonicity_and_floor():
    base = column_tolerance(BF16, FP32, 20, 3, 6, 1000.0)
    assert column_tolerance(BF16, FP32, 40, 3, 6, 1000.0) > base
    assert column_tolerance(BF16, FP32, 20, 5, 6, 1000.0) > base
    assert column_tolerance(BF16, FP32, 20, 3, 12, 1000.0) > base
    assert column_tolerance(BF16, FP32, 20, 3, 6, 2000.0) > base
    # A vanishing workload leaves only the subnormal + f64-noise floor.
    tiny = column_tolerance(BF16, FP32, 1, 1, 1, 0.0)
    assert 0.0 < tiny < 1e-40
    # Wider accumulators tighten the relative band: the FP8 chain
    # (window 14, FP16 accumulator) must be strictly looser than the
    # BF16/FP32 chain on the same workload.
    assert chain_window(FP8E4M3, FP16) == 14
    loose = column_tolerance(FP8E4M3, FP16, 20, 1, 6, 1000.0)
    strict = column_tolerance(BF16, FP32, 20, 1, 6, 1000.0)
    assert loose > 100.0 * strict


def main():
    tests = [
        test_published_format_extrema,
        test_exponent_msb_flip_band_is_2,
        test_tolerance_sits_far_below_the_flip_band,
        test_tolerance_monotonicity_and_floor,
    ]
    for t in tests:
        t()
        print(f"ok: {t.__name__}")
    print(f"PASS: {len(tests)} ABFT tolerance checks")


if __name__ == "__main__":
    main()
