"""L2 model correctness: conv-as-GEMM forward vs lax reference."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import conv_as_gemm_ref


def _rand(shape, seed, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype)


def _lax_conv(x, w, stride):
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


class TestConvReference:
    def test_im2col_ref_matches_lax_s1(self):
        x = _rand((2, 8, 8, 3), 0)
        w = _rand((3, 3, 3, 5), 1)
        got = conv_as_gemm_ref(x, w, stride=1)
        want = _lax_conv(x, w, 1)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_im2col_ref_matches_lax_s2(self):
        x = _rand((1, 16, 16, 4), 2)
        w = _rand((3, 3, 4, 8), 3)
        got = conv_as_gemm_ref(x, w, stride=2)
        want = _lax_conv(x, w, 2)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


class TestGemmEntry:
    def test_gemm_bf16_matches_ref(self):
        a = _rand((64, 128), 4)
        w = _rand((128, 64), 5)
        (y,) = model.gemm_bf16(a, w)
        want = jnp.matmul(
            a.astype(jnp.bfloat16), w.astype(jnp.bfloat16), preferred_element_type=jnp.float32
        )
        np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=2e-2, atol=2e-2)
        assert y.dtype == jnp.float32

    def test_gemm_is_jittable_and_stable(self):
        a = _rand((8, 16), 6)
        w = _rand((16, 8), 7)
        (y1,) = jax.jit(model.gemm_bf16)(a, w)
        (y2,) = model.gemm_bf16(a, w)
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


class TestTinyCnn:
    def _params(self):
        return (
            _rand((1, 16, 16, 4), 10),
            _rand((3, 3, 4, 8), 11) * 0.3,
            _rand((3, 3, 8, 16), 12) * 0.3,
            _rand((16, 10), 13) * 0.3,
        )

    def test_shapes_and_finiteness(self):
        (logits,) = model.tiny_cnn(*self._params())
        assert logits.shape == (1, 10)
        assert bool(jnp.isfinite(logits).all())

    def test_matches_bf16_lax_pipeline(self):
        x, w1, w2, wfc = self._params()

        def ref(x, w1, w2, wfc):
            def conv(x, w, s):
                return _lax_conv(
                    x.astype(jnp.bfloat16), w.astype(jnp.bfloat16), s
                ).astype(jnp.float32)

            h = jax.nn.relu(conv(x, w1, 2))
            h = jax.nn.relu(conv(h, w2, 2))
            pooled = h.mean(axis=(1, 2))
            return pooled.astype(jnp.bfloat16) @ wfc.astype(jnp.bfloat16)

        (got,) = model.tiny_cnn(x, w1, w2, wfc)
        want = ref(x, w1, w2, wfc)
        # bf16 rounding points differ slightly between the two lowerings.
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want, np.float32), rtol=0.06, atol=0.06
        )

    def test_every_artifact_entry_is_callable(self):
        for name, (fn, shapes, result) in model.ARTIFACTS.items():
            args = [_rand(s, hash(name) % 1000 + i) for i, s in enumerate(shapes)]
            (out,) = fn(*args)
            assert tuple(out.shape) == tuple(result), name
