"""AOT lowering: JAX → HLO text artifacts + manifest.

Runs ONCE at `make artifacts`; the rust runtime loads the outputs via
PJRT and python never touches the request path.

HLO **text** (not a serialized ``HloModuleProto``) is the interchange
format: jax ≥ 0.5 emits protos with 64-bit instruction ids which the
image's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md and DESIGN.md §3.

The manifest records parameter/result shapes (the rust loader validates
calls against them) and a content fingerprint of the python compile
sources, backing the Makefile's staleness contract.

Usage: ``python -m compile.aot --out-dir ../artifacts``
"""

import argparse
import hashlib
import json
import pathlib
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (xla_extension-0.5.1-safe)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def sources_fingerprint() -> str:
    """SHA-256 over every .py under compile/ (sorted), for staleness."""
    root = pathlib.Path(__file__).parent
    h = hashlib.sha256()
    for p in sorted(root.rglob("*.py")):
        h.update(p.name.encode())
        h.update(p.read_bytes())
    return h.hexdigest()


def build(out_dir: pathlib.Path, only: str | None = None) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest: dict = {"_sources_fingerprint": sources_fingerprint()}
    for name, (fn, param_shapes, result_shape) in model.ARTIFACTS.items():
        if only and only != name:
            continue
        specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in param_shapes]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        (out_dir / fname).write_text(text)
        manifest[name] = {
            "path": fname,
            "params": [list(s) for s in param_shapes],
            "result": list(result_shape),
        }
        print(f"  {name}: {len(text)} chars -> {fname}", file=sys.stderr)
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2, sort_keys=True))
    return manifest


def is_stale(out_dir: pathlib.Path) -> bool:
    """True when artifacts are missing or the compile sources changed."""
    mpath = out_dir / "manifest.json"
    if not mpath.is_file():
        return True
    try:
        manifest = json.loads(mpath.read_text())
    except json.JSONDecodeError:
        return True
    if manifest.get("_sources_fingerprint") != sources_fingerprint():
        return True
    return any(
        not (out_dir / spec["path"]).is_file()
        for key, spec in manifest.items()
        if not key.startswith("_")
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact output directory")
    ap.add_argument("--only", default=None, help="build a single artifact")
    ap.add_argument(
        "--check", action="store_true", help="exit 1 if artifacts are stale, else 0"
    )
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    if args.check:
        sys.exit(1 if is_stale(out_dir) else 0)
    if not is_stale(out_dir) and not args.only:
        print(f"artifacts in {out_dir} are up to date", file=sys.stderr)
        return
    build(out_dir, args.only)
    print(f"wrote manifest to {out_dir / 'manifest.json'}", file=sys.stderr)


if __name__ == "__main__":
    main()
