"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference here with identical calling
conventions; pytest sweeps shapes/dtypes (hypothesis) and asserts
allclose between kernel and reference.  The references also document the
numeric contract: bf16 elementwise inputs, f32 accumulation ("reduce in
double width, round once per output"), matching the paper's SA semantics
at the granularity XLA exposes.
"""

import jax.numpy as jnp


def matmul_ref(a: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Reference GEMM: bf16 (or any) inputs, f32 accumulation."""
    return jnp.matmul(a, w, preferred_element_type=jnp.float32)


def conv_as_gemm_ref(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1) -> jnp.ndarray:
    """Reference NHWC conv via explicit im2col + the reference GEMM.

    x: (n, h, w, cin); w: (kh, kw, cin, cout); "same" padding.
    """
    n, h, wdt, cin = x.shape
    kh, kw, _, cout = w.shape
    oh, ow = -(-h // stride), -(-wdt // stride)
    # XLA-convention SAME padding (asymmetric: excess goes after).
    pth = max((oh - 1) * stride + kh - h, 0)
    ptw = max((ow - 1) * stride + kw - wdt, 0)
    xp = jnp.pad(
        x, ((0, 0), (pth // 2, pth - pth // 2), (ptw // 2, ptw - ptw // 2), (0, 0))
    )
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            patch = xp[
                :,
                dy : dy + (oh - 1) * stride + 1 : stride,
                dx : dx + (ow - 1) * stride + 1 : stride,
                :,
            ]
            cols.append(patch)
    # (n, oh, ow, kh*kw*cin) with (dy, dx, cin) minor order.
    im2col = jnp.concatenate(cols, axis=-1)
    mat = im2col.reshape(n * oh * ow, kh * kw * cin)
    wmat = w.reshape(kh * kw * cin, cout)
    y = matmul_ref(mat, wmat)
    return y.reshape(n, oh, ow, cout)
