"""Layer-1 Pallas kernel: weight-stationary tiled matmul.

The paper's contribution is an ASIC pipeline reorganisation; on a TPU
there is no user-visible PE pipeline, so the transferable insight (see
DESIGN.md §8, Hardware-Adaptation) is mapped as:

* **K-reduction chain ↔ MXU systolic reduction** — blocks are shaped so
  the contraction feeds the 128-wide MXU the way the paper's column
  chains feed the 128-deep array;
* **"round once per column" ↔ f32 accumulation** — the output block is
  an f32 accumulator in VMEM; inputs stay bf16 and nothing rounds to
  bf16 between K-steps (`preferred_element_type=jnp.float32`);
* **weight-stationary reuse ↔ BlockSpec index maps** — the grid is
  ordered `(n, k, m)` with `m` innermost, so the weight block index
  `(k, n)` is invariant in the innermost loop and Pallas keeps the
  weight tile resident in VMEM while activations stream past — exactly
  the WS dataflow;
* **double-buffered weight reload ↔ Pallas pipelining** of the HBM→VMEM
  copies across grid steps.

`interpret=True` everywhere: the CPU PJRT client cannot run Mosaic
custom-calls; correctness is validated on the interpret path and real-
TPU performance is *estimated* from the VMEM footprint / MXU shape
(DESIGN.md §10).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block sizes: MXU-shaped (128×128 systolic array, matching the
# paper's SA dims).  Tests shrink them for small shapes.
DEF_BM, DEF_BK, DEF_BN = 128, 128, 128


def _kernel(a_ref, w_ref, o_ref, *, k_tiles: int):
    """One grid step: o[m,n] (+)= a[m,k] @ w[k,n] with f32 accumulation."""
    k = pl.program_id(1)  # grid = (n, k, m)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]
    w = w_ref[...]
    # bf16×bf16→f32 on the MXU; never round the accumulator to bf16.
    o_ref[...] += jnp.dot(a, w, preferred_element_type=jnp.float32)


def _pad_to(x: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    return jnp.pad(x, ((0, rows - x.shape[0]), (0, cols - x.shape[1])))


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def sa_matmul(
    a: jnp.ndarray,
    w: jnp.ndarray,
    *,
    bm: int = DEF_BM,
    bk: int = DEF_BK,
    bn: int = DEF_BN,
) -> jnp.ndarray:
    """Weight-stationary tiled matmul: `a (M×K) @ w (K×N) → f32 (M×N)`.

    Inputs of any float dtype (bf16 in the paper's configuration);
    accumulation and result are f32.  Shapes need not divide the block
    sizes (padded internally, sliced back).
    """
    m, k = a.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm_, bk_, bn_ = min(bm, m), min(bk, k), min(bn, n)
    mp, kp, np_ = -(-m // bm_) * bm_, -(-k // bk_) * bk_, -(-n // bn_) * bn_
    ap = _pad_to(a, mp, kp)
    wp = _pad_to(w, kp, np_)
    k_tiles = kp // bk_
    grid = (np_ // bn_, k_tiles, mp // bm_)  # (n, k, m): m innermost (WS)
    out = pl.pallas_call(
        functools.partial(_kernel, k_tiles=k_tiles),
        grid=grid,
        in_specs=[
            # Activations stream: block index depends on (m, k).
            pl.BlockSpec((bm_, bk_), lambda ni, ki, mi: (mi, ki)),
            # Weights stationary: invariant in the innermost (m) dim.
            pl.BlockSpec((bk_, bn_), lambda ni, ki, mi: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda ni, ki, mi: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU path; Mosaic lowering is TPU-only
    )(ap, wp)
    return out[:m, :n]


def vmem_footprint_bytes(bm: int = DEF_BM, bk: int = DEF_BK, bn: int = DEF_BN) -> int:
    """Estimated VMEM residency of one grid step (double-buffered inputs
    + f32 accumulator), used by the DESIGN.md §10 roofline notes."""
    a = bm * bk * 2  # bf16
    w = bk * bn * 2  # bf16 (stationary)
    o = bm * bn * 4  # f32 accumulator
    return 2 * (a + w) + o  # ×2: Pallas double-buffers the streamed copies
