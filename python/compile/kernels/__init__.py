"""Layer-1 Pallas kernels + pure-jnp references."""

from .ref import conv_as_gemm_ref, matmul_ref
from .sa_matmul import sa_matmul, vmem_footprint_bytes

__all__ = ["conv_as_gemm_ref", "matmul_ref", "sa_matmul", "vmem_footprint_bytes"]
