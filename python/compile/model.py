"""Layer-2 JAX model: conv-as-GEMM forward pass on the L1 kernel.

Build-time only — `aot.py` lowers the jitted entry points to HLO text
once; the rust coordinator executes the artifacts through PJRT and
python never runs at request time.

Entry points (all take/return f32 so the rust side never constructs
reduced-precision literals; the bf16 casts happen *inside* the lowered
computation, mirroring the SA's bf16-in / f32-reduce datapath):

* ``gemm_bf16`` — the golden GEMM used by coordinator verification;
* ``tiny_cnn`` — a 3-layer CNN head-to-tail forward (conv → relu → conv
  → relu → global-avg-pool → fc), proving the full conv-as-GEMM path
  composes through the kernel.
"""

import jax
import jax.numpy as jnp

from .kernels import sa_matmul


def gemm_bf16(a: jnp.ndarray, w: jnp.ndarray) -> tuple[jnp.ndarray]:
    """f32 in → bf16 cast → WS-tiled matmul → f32 out (1-tuple)."""
    y = sa_matmul(a.astype(jnp.bfloat16), w.astype(jnp.bfloat16))
    return (y,)


def _conv_same(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1) -> jnp.ndarray:
    """NHWC "same" conv lowered to im2col + the L1 kernel.

    x: (n, h, w, cin) f32; w: (kh, kw, cin, cout) f32.
    """
    n, h, wdt, cin = x.shape
    kh, kw, _, cout = w.shape
    oh, ow = -(-h // stride), -(-wdt // stride)
    # XLA-convention SAME padding (asymmetric: excess goes after).
    pth = max((oh - 1) * stride + kh - h, 0)
    ptw = max((ow - 1) * stride + kw - wdt, 0)
    xp = jnp.pad(
        x, ((0, 0), (pth // 2, pth - pth // 2), (ptw // 2, ptw - ptw // 2), (0, 0))
    )
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            patch = xp[
                :,
                dy : dy + (oh - 1) * stride + 1 : stride,
                dx : dx + (ow - 1) * stride + 1 : stride,
                :,
            ]
            cols.append(patch)
    mat = jnp.concatenate(cols, axis=-1).reshape(n * oh * ow, kh * kw * cin)
    wmat = w.reshape(kh * kw * cin, cout)
    y = sa_matmul(
        mat.astype(jnp.bfloat16),
        wmat.astype(jnp.bfloat16),
        bm=128,
        bk=min(128, kh * kw * cin),
        bn=min(128, cout),
    )
    return y.reshape(n, oh, ow, cout)


def tiny_cnn(x: jnp.ndarray, w1: jnp.ndarray, w2: jnp.ndarray, wfc: jnp.ndarray) -> tuple[jnp.ndarray]:
    """A small CNN forward: every MAC goes through the L1 kernel.

    x: (1, 16, 16, 4); w1: (3,3,4,8); w2: (3,3,8,16); wfc: (16, 10).
    Returns (logits (1, 10),).
    """
    h = jax.nn.relu(_conv_same(x, w1, stride=2))  # (1, 8, 8, 8)
    h = jax.nn.relu(_conv_same(h, w2, stride=2))  # (1, 4, 4, 16)
    pooled = h.mean(axis=(1, 2))  # (1, 16)
    logits = sa_matmul(
        pooled.astype(jnp.bfloat16), wfc.astype(jnp.bfloat16), bm=1, bk=16, bn=10
    )
    return (logits,)


#: AOT artifact registry: name → (callable, list of param shapes).
#: `aot.py` lowers each with f32 ShapeDtypeStructs of these shapes.
ARTIFACTS: dict[str, tuple] = {
    "gemm_bf16_8x16x8": (gemm_bf16, [(8, 16), (16, 8)], (8, 8)),
    "gemm_bf16_64x128x64": (gemm_bf16, [(64, 128), (128, 64)], (64, 64)),
    "gemm_bf16_128x256x128": (gemm_bf16, [(128, 256), (256, 128)], (128, 128)),
    "tiny_cnn_16x16x4": (
        tiny_cnn,
        [(1, 16, 16, 4), (3, 3, 4, 8), (3, 3, 8, 16), (16, 10)],
        (1, 10),
    ),
}
