//! End-to-end driver (experiment E8): full MobileNetV1 inference GEMM
//! stream through the coordinator on synthetic ImageNet-statistics
//! inputs, verifying numerics along the way and reporting the paper's
//! headline latency/energy comparison.
//!
//! ```text
//! cargo run --release --example e2e_mobilenet [-- --full]
//! ```
//!
//! Default: every layer runs with M capped at 512 streaming rows so the
//! example finishes in ~a minute; `--full` streams every output pixel
//! of every layer (exact paper workload, CPU-heavy).  Timing/energy are
//! *always* evaluated at the full layer shapes — the cap only bounds
//! the bit-accurate numeric simulation.  When `make artifacts` has been
//! run, matching layers are additionally cross-checked against the XLA
//! golden runtime.

use skewsa::arith::format::FpFormat;
use skewsa::config::RunConfig;
use skewsa::coordinator::Coordinator;
use skewsa::energy::{LayerComparison, NetworkTotals};
use skewsa::pe::PipelineKind;
use skewsa::runtime::GoldenRuntime;
use skewsa::sa::tile::{GemmShape, TilePlan};
use skewsa::util::table::{fnum, pct, Table};
use skewsa::workloads::gemm::GemmData;
use skewsa::workloads::mobilenet;
use std::sync::Arc;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let cfg = RunConfig::paper();
    let coord = Coordinator::new(cfg.clone());
    let golden = GoldenRuntime::try_open();
    if golden.is_some() {
        println!("XLA golden runtime: available (artifacts loaded)");
    } else {
        println!("XLA golden runtime: not built (run `make artifacts`) — oracle verify only");
    }

    let layers = mobilenet::layers();
    let mut table = Table::new(&[
        "layer", "gemm", "verified", "cyc-base", "cyc-skew", "lat", "E-delta",
    ])
    .numeric();
    let mut totals = NetworkTotals::default();
    let mut checked_total = 0usize;
    let t0 = std::time::Instant::now();

    for (i, l) in layers.iter().enumerate() {
        let shape = l.gemm();
        // Timing/energy at the full shape:
        let plan = TilePlan::new(shape, cfg.rows, cfg.cols);
        let cmp = LayerComparison::evaluate(&cfg.timing(), coord.power_model(), &plan);
        totals.add(&cmp);

        // Numerics with (optionally) capped M:
        let m_sim = if full { shape.m } else { shape.m.min(512) };
        let sim_shape = GemmShape::new(m_sim, shape.k, shape.n);
        let data = Arc::new(GemmData::cnn_like(sim_shape, FpFormat::BF16, 0xe2e + i as u64));
        let res = coord.run_gemm(PipelineKind::Skewed, &data);
        assert!(res.verify.ok(), "layer {} failed bit-exact verification", l.name);
        checked_total += res.verify.checked;

        table.row(&[
            l.name.clone(),
            format!("{}x{}x{}", shape.m, shape.k, shape.n),
            format!("{}/{}", res.verify.checked - res.verify.failures, res.verify.checked),
            cmp.baseline.timing.cycles.to_string(),
            cmp.skewed.timing.cycles.to_string(),
            pct(cmp.latency_delta()),
            pct(cmp.energy_delta()),
        ]);
    }
    println!("{}", table.render());
    println!(
        "MobileNetV1 totals: latency {} (paper −16%), energy {} (paper −8%)",
        pct(totals.latency_delta()),
        pct(totals.energy_delta())
    );
    println!(
        "energy: {} uJ -> {} uJ at {} GHz on a {}x{} array",
        fnum(totals.energy_baseline_uj, 1),
        fnum(totals.energy_skewed_uj, 1),
        cfg.clock_ghz,
        cfg.rows,
        cfg.cols
    );

    // Cross-check one representative GEMM against the XLA golden.
    if let Some(g) = &golden {
        let (m, k, n) = (64, 128, 64);
        let data = GemmData::cnn_like(GemmShape::new(m, k, n), FpFormat::BF16, 0x901d);
        let a: Vec<f32> = data.a.iter().flatten().map(|&b| FpFormat::BF16.to_f32(b)).collect();
        let w: Vec<f32> = data.w.iter().flatten().map(|&b| FpFormat::BF16.to_f32(b)).collect();
        if let Ok(Some(gold)) = g.run_gemm_f32(m, k, n, &a, &w) {
            let res = coord.run_gemm(PipelineKind::Skewed, &Arc::new(data));
            let mut max_rel = 0f32;
            for (&s, &x) in res.y.iter().zip(&gold) {
                max_rel = max_rel.max((s - x).abs() / (1.0 + x.abs()));
            }
            println!("XLA golden cross-check (64x128x64): max rel err {max_rel:.2e}");
            assert!(max_rel < 2e-2);
        }
    }

    println!(
        "e2e_mobilenet OK: {} layers, {} outputs bit-verified, wall {:?}",
        layers.len(),
        checked_total,
        t0.elapsed()
    );
}
