//! Reduced-precision format exploration (experiment E7, Fig. 1 context):
//! encodes/decodes every format of the paper's Fig. 1, shows the delay-
//! profile inversion that motivates the work (§II), and sweeps the
//! chained-FMA bit-identity across every input format.
//!
//! ```text
//! cargo run --release --example format_sweep
//! ```

use skewsa::arith::accum::RoundingUnit;
use skewsa::arith::fma::{BaselineFmaPath, ChainCfg, ChainDatapath, PsumSignal, SkewedFmaPath};
use skewsa::arith::format::FpFormat;
use skewsa::pe::delay::{BlockDelays, StageDelays, CLOCK_PERIOD_FO4};
use skewsa::pe::PipelineKind;
use skewsa::report;
use skewsa::util::rng::Rng;
use skewsa::util::table::{fnum, Table};

fn main() {
    // --- Fig. 1: the formats --------------------------------------------
    let mut t = Table::new(&["format", "bits", "e", "m", "bias", "max", "min-normal"]).numeric();
    for f in [FpFormat::FP32, FpFormat::BF16, FpFormat::FP16, FpFormat::FP8E4M3, FpFormat::FP8E5M2]
    {
        let (sig, exp) = f.max_finite();
        let max = sig as f64 * 2f64.powi(exp - f.man_bits as i32);
        t.row(&[
            f.name.to_string(),
            f.width().to_string(),
            f.exp_bits.to_string(),
            f.man_bits.to_string(),
            f.bias().to_string(),
            format!("{max:.3e}"),
            format!("{:.3e}", 2f64.powi(f.emin())),
        ]);
    }
    println!("{}", t.render());

    // --- §II: delay-profile inversion -----------------------------------
    print!("{}", report::format_sweep().render());

    // --- stage delays per pipeline per format ----------------------------
    let mut d = Table::new(&["chain", "3a-crit", "3b-crit", "skew-crit", "all@1GHz"]).numeric();
    for (inf, outf) in [
        (FpFormat::BF16, FpFormat::FP32),
        (FpFormat::FP16, FpFormat::FP32),
        (FpFormat::FP8E4M3, FpFormat::FP16),
        (FpFormat::FP8E5M2, FpFormat::FP16),
    ] {
        let chain = ChainCfg::new(inf, outf);
        let crits: Vec<f64> = PipelineKind::ALL
            .iter()
            .map(|&k| StageDelays::for_kind(k, &chain).critical())
            .collect();
        d.row(&[
            format!("{}->{}", inf.name, outf.name),
            fnum(crits[0], 1),
            fnum(crits[1], 1),
            fnum(crits[2], 1),
            if crits[1].max(crits[2]) <= CLOCK_PERIOD_FO4 { "3b+skew ok" } else { "MISS" }
                .to_string(),
        ]);
        let b = BlockDelays::for_cfg(&chain);
        println!(
            "{}: mult {:.1} FO4 vs exp+align {:.1} FO4 -> {}",
            inf.name,
            b.mult,
            b.exp_compute + b.align,
            if b.exp_compute + b.align > b.mult { "inverted (reduced-precision regime)" } else { "classic" }
        );
    }
    println!("\n{}", d.render());

    // --- bit-identity across every input format --------------------------
    let mut rng = Rng::new(0xf0f0);
    for (inf, outf) in [
        (FpFormat::BF16, FpFormat::FP32),
        (FpFormat::FP16, FpFormat::FP32),
        (FpFormat::FP8E4M3, FpFormat::FP16),
        (FpFormat::FP8E5M2, FpFormat::FP16),
    ] {
        let chain = ChainCfg::new(inf, outf);
        let ru = RoundingUnit::new(chain);
        let mut identical = 0usize;
        let total = 200;
        for _ in 0..total {
            let len = 1 + rng.below(64) as usize;
            let mut b = PsumSignal::zero(&chain);
            let mut s = PsumSignal::zero(&chain);
            for _ in 0..len {
                let a = loop {
                    let bits = rng.bits(inf.width());
                    if inf.decode(bits).is_finite() {
                        break bits;
                    }
                };
                let w = loop {
                    let bits = rng.bits(inf.width());
                    if inf.decode(bits).is_finite() {
                        break bits;
                    }
                };
                b = BaselineFmaPath.step(&chain, &b, a, w);
                s = SkewedFmaPath.step(&chain, &s, a, w);
            }
            if ru.round(&b) == ru.round(&s) {
                identical += 1;
            }
        }
        println!(
            "{} -> {}: {identical}/{total} random chains bit-identical",
            inf.name, outf.name
        );
        assert_eq!(identical, total);
    }
    println!("format_sweep OK");
}
