//! Quickstart: the five-minute tour of the public API.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! 1. encode values in a reduced-precision format (Fig. 1);
//! 2. run a chained multiply-add through both datapaths and watch them
//!    agree bit-for-bit (the paper's functional claim);
//! 3. run a cycle-accurate column and see the skewed pipeline halve the
//!    reduction latency;
//! 4. coordinate a small GEMM end-to-end with verification.

use skewsa::arith::fma::{BaselineFmaPath, ChainCfg, ChainDatapath, PsumSignal, SkewedFmaPath};
use skewsa::arith::format::FpFormat;
use skewsa::config::RunConfig;
use skewsa::coordinator::Coordinator;
use skewsa::pe::PipelineKind;
use skewsa::sa::column::ColumnSim;
use skewsa::sa::tile::GemmShape;
use skewsa::util::table::pct;
use skewsa::workloads::gemm::GemmData;
use std::sync::Arc;

fn main() {
    // --- 1. formats ------------------------------------------------------
    let bf16 = FpFormat::BF16;
    let x = 3.14159f64;
    let bits = bf16.from_f64(x);
    println!("bf16({x}) = {bits:#06x} -> {}", bf16.to_f64(bits));

    // --- 2. the two datapaths are bit-identical --------------------------
    let cfg = ChainCfg::BF16_FP32;
    let terms = [(1.5, 2.0), (-0.5, 4.0), (3.0, 0.125), (7.0, -1.0)];
    let mut base = PsumSignal::zero(&cfg);
    let mut skew = PsumSignal::zero(&cfg);
    for &(a, w) in &terms {
        base = BaselineFmaPath.step(&cfg, &base, bf16.from_f64(a), bf16.from_f64(w));
        skew = SkewedFmaPath.step(&cfg, &skew, bf16.from_f64(a), bf16.from_f64(w));
    }
    let ru = skewsa::arith::accum::RoundingUnit::new(cfg);
    println!(
        "chained Σ aᵢwᵢ: baseline {} | skewed {} (bit-identical: {})",
        ru.round_f32(&base),
        ru.round_f32(&skew),
        ru.round(&base) == ru.round(&skew),
    );

    // --- 3. cycle-accurate column: latency halves ------------------------
    let r = 32;
    let weights: Vec<u64> = (0..r).map(|i| bf16.from_f64(1.0 / (i + 1) as f64)).collect();
    let a: Vec<Vec<u64>> = vec![(0..r).map(|i| bf16.from_f64(i as f64)).collect()];
    for kind in [PipelineKind::Baseline3b, PipelineKind::Skewed] {
        let mut sim = ColumnSim::new(cfg, kind, &weights, a.clone());
        sim.run(10_000).unwrap();
        println!(
            "{:<12} column of {r}: {} cycles, result {}",
            kind.name(),
            sim.cycles(),
            f32::from_bits(sim.outputs()[0].bits as u32)
        );
    }

    // --- 4. coordinated GEMM with verification ---------------------------
    let mut rc = RunConfig::small();
    rc.rows = 16;
    rc.cols = 16;
    rc.verify_fraction = 1.0;
    let data = Arc::new(GemmData::cnn_like(GemmShape::new(32, 48, 24), FpFormat::BF16, 1));
    let res = Coordinator::new(rc).run_gemm(PipelineKind::Skewed, &data);
    println!(
        "coordinated 32x48x24 GEMM: verified {}/{} bit-exact; latency delta {}, energy delta {}",
        res.verify.checked - res.verify.failures,
        res.verify.checked,
        pct(res.comparison.latency_delta()),
        pct(res.comparison.energy_delta()),
    );
    assert!(res.verify.ok());
    println!("quickstart OK");
}
