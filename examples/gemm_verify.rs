//! Three-way GEMM verification (the L1/L2/L3 composition proof):
//! bit-accurate coordinator vs the cycle-accurate array vs the AOT-
//! compiled XLA artifact through PJRT.
//!
//! ```text
//! cargo run --release --example gemm_verify
//! ```
//!
//! Requires `make artifacts` for the XLA leg (skips it otherwise).

use skewsa::arith::format::FpFormat;
use skewsa::config::{NumericMode, RunConfig};
use skewsa::coordinator::Coordinator;
use skewsa::pe::PipelineKind;
use skewsa::runtime::GoldenRuntime;
use skewsa::sa::tile::GemmShape;
use skewsa::workloads::gemm::GemmData;
use std::sync::Arc;

fn main() {
    let (m, k, n) = (64, 128, 64);
    let shape = GemmShape::new(m, k, n);
    let data = Arc::new(GemmData::cnn_like(shape, FpFormat::BF16, 0x3a3a));

    // Leg 1: oracle-mode coordinator (value-level datapath semantics).
    let mut cfg = RunConfig::small();
    cfg.rows = 32;
    cfg.cols = 32;
    cfg.verify_fraction = 1.0;
    let r_oracle = Coordinator::new(cfg.clone()).run_gemm(PipelineKind::Skewed, &data);
    assert!(r_oracle.verify.ok());
    println!(
        "leg 1 (oracle coordinator): {} outputs, all bit-verified",
        r_oracle.verify.checked
    );

    // Leg 2: cycle-accurate mode — every register hand-off simulated.
    let mut cfg2 = cfg.clone();
    cfg2.mode = NumericMode::CycleAccurate;
    cfg2.verify_fraction = 0.0;
    let r_cycle = Coordinator::new(cfg2).run_gemm(PipelineKind::Skewed, &data);
    let same = r_oracle
        .y
        .iter()
        .zip(&r_cycle.y)
        .all(|(a, b)| a.to_bits() == b.to_bits());
    assert!(same, "cycle-accurate leg diverged from oracle leg");
    println!("leg 2 (cycle-accurate array): bit-identical to leg 1");

    // Leg 3: the XLA golden artifact through PJRT.
    match GoldenRuntime::try_open() {
        Some(g) => {
            let a: Vec<f32> =
                data.a.iter().flatten().map(|&b| FpFormat::BF16.to_f32(b)).collect();
            let w: Vec<f32> =
                data.w.iter().flatten().map(|&b| FpFormat::BF16.to_f32(b)).collect();
            let gold = g
                .run_gemm_f32(m, k, n, &a, &w)
                .expect("runtime execution")
                .expect("gemm artifact for 64x128x64");
            let mut max_rel = 0f32;
            for (&sim, &x) in r_oracle.y.iter().zip(&gold) {
                max_rel = max_rel.max((sim - x).abs() / (1.0 + x.abs()));
            }
            println!("leg 3 (XLA via PJRT): max rel err vs simulator {max_rel:.3e}");
            assert!(
                max_rel < 2e-2,
                "simulator and XLA golden disagree beyond rounding-order tolerance"
            );
        }
        None => println!("leg 3 (XLA via PJRT): skipped — run `make artifacts` first"),
    }
    println!("gemm_verify OK");
}
