//! ResNet-50 per-layer energy walk (the Fig. 8 scenario as a program):
//! evaluates all 54 compute layers on the paper's 128×128 array, prints
//! the per-stage breakdown, and highlights where the skewed design
//! crosses from costing energy to saving it.
//!
//! ```text
//! cargo run --release --example resnet50_energy
//! ```

use skewsa::arith::fma::ChainCfg;
use skewsa::energy::{AreaModel, LayerComparison, NetworkTotals, PowerModel};
use skewsa::sa::tile::TilePlan;
use skewsa::timing::model::TimingConfig;
use skewsa::util::table::{fnum, pct, Table};
use skewsa::workloads::resnet50;

fn main() {
    let tcfg = TimingConfig::PAPER;
    let pmodel = PowerModel::new(AreaModel::new(ChainCfg::BF16_FP32));
    let layers = resnet50::layers();

    let mut table = Table::new(&["layer", "M", "K", "N", "E-base(uJ)", "E-skew(uJ)", "delta"])
        .numeric();
    let mut totals = NetworkTotals::default();
    let mut crossover: Option<String> = None;
    let mut worst: (String, f64) = (String::new(), f64::INFINITY);
    for l in &layers {
        let shape = l.gemm();
        let plan = TilePlan::new(shape, tcfg.rows, tcfg.cols);
        let c = LayerComparison::evaluate(&tcfg, &pmodel, &plan);
        totals.add(&c);
        if c.energy_delta() < 0.0 && crossover.is_none() {
            crossover = Some(l.name.clone());
        }
        if c.energy_delta() < worst.1 {
            worst = (l.name.clone(), c.energy_delta());
        }
        table.row(&[
            l.name.clone(),
            shape.m.to_string(),
            shape.k.to_string(),
            shape.n.to_string(),
            fnum(c.baseline.energy_uj, 2),
            fnum(c.skewed.energy_uj, 2),
            pct(c.energy_delta()),
        ]);
    }
    println!("{}", table.render());
    println!(
        "ResNet50 totals: latency {} (paper −21%), energy {} (paper −11%)",
        pct(totals.latency_delta()),
        pct(totals.energy_delta())
    );
    if let Some(c) = crossover {
        println!("first energy-saving layer: {c} (the paper's early-lose/late-win shape)");
    }
    println!("largest per-layer saving: {} at {}", worst.0, pct(worst.1));

    // Stage-level summary (conv2..conv5 + stem + fc).
    let mut stage_table = Table::new(&["stage", "E-base(uJ)", "E-skew(uJ)", "delta"]).numeric();
    for prefix in ["conv1", "conv2", "conv3", "conv4", "conv5", "fc"] {
        let mut t = NetworkTotals::default();
        for l in layers.iter().filter(|l| l.name.starts_with(prefix)) {
            let plan = TilePlan::new(l.gemm(), tcfg.rows, tcfg.cols);
            t.add(&LayerComparison::evaluate(&tcfg, &pmodel, &plan));
        }
        if t.cycles_baseline == 0 {
            continue;
        }
        stage_table.row(&[
            prefix.to_string(),
            fnum(t.energy_baseline_uj, 1),
            fnum(t.energy_skewed_uj, 1),
            pct(t.energy_delta()),
        ]);
    }
    println!("\nper-stage:\n{}", stage_table.render());
    println!("resnet50_energy OK");
}
