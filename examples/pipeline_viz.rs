//! Pipeline interleaving visualisation (experiment E6): renders the
//! paper's Fig. 4 (serialized baseline) and Fig. 6 (skewed overlap)
//! as ASCII timelines from *actual* cycle-accurate traces — then
//! annotates the structural hand-offs.
//!
//! ```text
//! cargo run --release --example pipeline_viz [-- <rows> <elements>]
//! ```

use skewsa::arith::fma::ChainCfg;
use skewsa::arith::format::FpFormat;
use skewsa::pe::PipelineKind;
use skewsa::sa::column::ColumnSim;
use skewsa::sa::dataflow::WsSchedule;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let rows: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(4);
    let elems: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(3);
    let cfg = ChainCfg::BF16_FP32;
    let f = FpFormat::BF16;

    println!("Chained FP multiply-add across a {rows}-PE column, {elems} streamed elements.");
    println!("Cells: 1m = stage-1 (mul + exp) on element m; 2m = stage-2 (align+add+LZA).\n");

    for kind in [PipelineKind::Baseline3b, PipelineKind::Skewed] {
        let weights: Vec<u64> = (0..rows).map(|i| f.from_f64(0.5 + i as f64)).collect();
        let a: Vec<Vec<u64>> = (0..elems)
            .map(|m| (0..rows).map(|r| f.from_f64((1 + m + r) as f64 * 0.25)).collect())
            .collect();
        let mut sim = ColumnSim::new(cfg, kind, &weights, a).with_trace();
        sim.run(10_000).unwrap();
        let fig = if kind.is_skewed() { "Fig. 6" } else { "Fig. 4" };
        println!("--- {} ({fig}): chain spacing {} ---", kind.name(), kind.chain_spacing());
        println!("{}", sim.trace().unwrap().render(24));
        let tr = sim.trace().unwrap();
        let d = tr.stage1_cycle(1, 0).unwrap() - tr.stage1_cycle(0, 0).unwrap();
        match kind {
            PipelineKind::Skewed => {
                println!(
                    "PE1 starts element 0 just {d} cycle after PE0 — its stage-1 exponent \
                     compute reads the speculative ê from PE0's fix logic in the same cycle \
                     PE0's stage 2 runs; the raw sum + L arrive one cycle later.\n"
                );
            }
            _ => {
                println!(
                    "PE1 starts element 0 only {d} cycles after PE0 — it must wait for PE0's \
                     normalized output register (the §III-A serialization).\n"
                );
            }
        }
        println!(
            "column completes in {} cycles (closed form: {}); outputs: {:?}\n",
            sim.cycles(),
            WsSchedule::new(kind, rows, 1, elems).total_cycles(),
            sim.outputs().iter().map(|o| f32::from_bits(o.bits as u32)).collect::<Vec<_>>()
        );
    }
    println!("pipeline_viz OK");
}
