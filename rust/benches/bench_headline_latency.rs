//! E4 — the paper's headline claims (§I/§IV): whole-network latency
//! −16% (MobileNet) / −21% (ResNet50) and energy −8% / −11%, plus an
//! M-sweep showing *where* the saving comes from (the per-tile R−2
//! cycles amortizing differently across layer shapes).
//!
//! ```text
//! cargo bench --bench bench_headline_latency
//! ```

use skewsa::arith::fma::ChainCfg;
use skewsa::energy::{AreaModel, PowerModel};
use skewsa::pe::PipelineKind;
use skewsa::report;
use skewsa::sa::tile::GemmShape;
use skewsa::timing::model::{gemm_timing, TimingConfig};
use skewsa::util::table::{pct, Table};

fn main() {
    let tcfg = TimingConfig::PAPER;
    let pmodel = PowerModel::new(AreaModel::new(ChainCfg::BF16_FP32));
    print!("{}", report::headline(&tcfg, &pmodel).render());

    // Where the saving lives: sweep M at fixed K=N=512 (one weight-tile
    // column block) — the crossover from "noise" to ">20%".
    let mut t = Table::new(&["M", "cyc-base", "cyc-skew", "saving"]).numeric();
    for m in [1usize, 16, 49, 196, 784, 3136, 12544] {
        let shape = GemmShape::new(m, 512, 512);
        let b = gemm_timing(&tcfg, PipelineKind::Baseline3b, shape).cycles;
        let s = gemm_timing(&tcfg, PipelineKind::Skewed, shape).cycles;
        t.row(&[
            m.to_string(),
            b.to_string(),
            s.to_string(),
            pct(s as f64 / b as f64 - 1.0),
        ]);
    }
    println!("\nM-sweep at K=N=512 (small-M late layers win big):\n{}", t.render());

    // Array-size sweep: the saving scales with R.
    let mut t2 = Table::new(&["array", "tile-base", "tile-skew", "saved-cycles"]).numeric();
    for r in [32usize, 64, 128, 256] {
        let cfg = TimingConfig { rows: r, cols: r, ..tcfg };
        let shape = GemmShape::new(49, r, r);
        let b = gemm_timing(&cfg, PipelineKind::Baseline3b, shape).cycles;
        let s = gemm_timing(&cfg, PipelineKind::Skewed, shape).cycles;
        t2.row(&[
            format!("{r}x{r}"),
            b.to_string(),
            s.to_string(),
            (b - s).to_string(),
        ]);
    }
    println!("array-size sweep (saving = R−2 per tile):\n{}", t2.render());
}
