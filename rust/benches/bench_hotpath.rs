//! Hot-path microbenchmarks — the §Perf instrumentation.
//!
//! Tracks the three tiers the perf pass optimizes (EXPERIMENTS.md §Perf):
//!
//! 1. `oracle-mac` — the value-level chained multiply-add step (the
//!    coordinator's numeric inner loop);
//! 2. `column-sim` / `array-sim` — cycle-accurate PE-cycles per second;
//! 3. `executor` — coordinated GEMM throughput across the worker pool.
//!
//! ```text
//! cargo bench --bench bench_hotpath
//! ```

use skewsa::arith::accum::ColumnOracle;
use skewsa::arith::fma::{BaselineFmaPath, ChainCfg, ChainDatapath, PsumSignal, SkewedFmaPath};
use skewsa::arith::format::FpFormat;
use skewsa::config::RunConfig;
use skewsa::coordinator::Coordinator;
use skewsa::pe::PipelineKind;
use skewsa::sa::array::ArraySim;
use skewsa::sa::column::ColumnSim;
use skewsa::sa::tile::GemmShape;
use skewsa::util::bench::{measure, with_units};
use skewsa::util::rng::Rng;
use skewsa::workloads::gemm::GemmData;
use std::sync::Arc;

const CFG: ChainCfg = ChainCfg::BF16_FP32;

fn main() {
    let mut rng = Rng::new(0x407);
    let vals: Vec<(u64, u64)> = (0..1024)
        .map(|_| {
            (
                FpFormat::BF16.from_f64(rng.normal_scaled(0.0, 1.0)),
                FpFormat::BF16.from_f64(rng.normal_scaled(0.0, 0.2)),
            )
        })
        .collect();

    // --- 1. datapath step throughput ------------------------------------
    for (name, path) in [
        ("hot:baseline-step", &BaselineFmaPath as &dyn ChainDatapath),
        ("hot:skewed-step", &SkewedFmaPath as &dyn ChainDatapath),
    ] {
        let m = measure(name, 3, 200, 7, || {
            let mut s = PsumSignal::zero(&CFG);
            for &(a, w) in &vals {
                s = path.step(&CFG, &s, a, w);
            }
            std::hint::black_box(s.val.sig);
        });
        println!("{}", with_units(m, 1024.0, "macs").report());
    }

    // --- oracle column (step + rounding) ---------------------------------
    let m = measure("hot:oracle-column-128", 3, 200, 7, || {
        let mut o = ColumnOracle::new(CFG);
        for &(a, w) in vals.iter().take(128) {
            o.mac(a, w);
        }
        std::hint::black_box(o.result());
    });
    println!("{}", with_units(m, 128.0, "macs").report());

    // --- 2. cycle-accurate sims ------------------------------------------
    let data = GemmData::cnn_like(GemmShape::new(32, 32, 1), FpFormat::BF16, 1);
    let weights: Vec<u64> = (0..32).map(|k| data.w[k][0]).collect();
    let m = measure("hot:column-sim-32x32", 2, 20, 5, || {
        let mut sim = ColumnSim::new(CFG, PipelineKind::Skewed, &weights, data.a.clone());
        sim.run(100_000).unwrap();
        std::hint::black_box(sim.cycles());
    });
    // PE-cycles: cycles × 32 PEs.
    let cycles = {
        let mut sim = ColumnSim::new(CFG, PipelineKind::Skewed, &weights, data.a.clone());
        sim.run(100_000).unwrap();
        sim.cycles()
    };
    println!("{}", with_units(m, cycles as f64 * 32.0, "PE-cycles").report());

    let adata = GemmData::cnn_like(GemmShape::new(16, 32, 32), FpFormat::BF16, 2);
    let m = measure("hot:array-sim-32x32xM16", 1, 5, 5, || {
        let mut sim = ArraySim::new(CFG, PipelineKind::Skewed, &adata.w, adata.a.clone());
        sim.run(1_000_000).unwrap();
        std::hint::black_box(sim.cycles());
    });
    let acycles = {
        let mut sim = ArraySim::new(CFG, PipelineKind::Skewed, &adata.w, adata.a.clone());
        sim.run(1_000_000).unwrap();
        sim.cycles()
    };
    println!(
        "{}",
        with_units(m, acycles as f64 * (32.0 * 32.0), "PE-cycles").report()
    );

    // --- 3. coordinated GEMM throughput ----------------------------------
    for workers in [1usize, 4, 8] {
        let mut cfg = RunConfig::small();
        cfg.rows = 32;
        cfg.cols = 32;
        cfg.workers = workers;
        cfg.verify_fraction = 0.0;
        let shape = GemmShape::new(64, 128, 64);
        let gdata = Arc::new(GemmData::cnn_like(shape, FpFormat::BF16, 3));
        let coord = Coordinator::new(cfg);
        let m = measure(&format!("hot:executor-64x128x64-w{workers}"), 1, 3, 3, || {
            let r = coord.run_gemm(PipelineKind::Skewed, &gdata);
            std::hint::black_box(r.y.len());
        });
        println!("{}", with_units(m, shape.macs() as f64, "macs").report());
    }
}
