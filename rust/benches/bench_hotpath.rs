//! Hot-path microbenchmarks — the §Perf instrumentation.
//!
//! Tracks the four tiers the perf pass optimizes (EXPERIMENTS.md §Perf):
//!
//! 1. `oracle-mac` — the value-level chained multiply-add step (the
//!    coordinator's numeric inner loop);
//! 2. `column-sim` / `array-sim` — the dense reference simulators,
//!    PE-cycles per second;
//! 3. `fast-sim` — the allocation-free, wavefront-banded, column-parallel
//!    rewrite ([`skewsa::sa::fast::FastArraySim`]), including the
//!    paper-scale 128×128 tile the dense loop was never practical for;
//! 4. `stream` — the multi-tile streaming executor on a 4-tile
//!    paper-scale plan, serialized vs double-buffered weight preload
//!    (both pinned to the closed-form layer model);
//! 5. `executor` — coordinated GEMM throughput across the worker pool.
//!
//! plus the vectorized-kernel tiers of the monomorphized lane rewrite:
//! the 128×128 tile through the scalar per-lane reference driver vs the
//! batched banded kernels (`speedup_vectorized_vs_scalar_128`), and the
//! precision-oracle layer analysis, vectorized vs element-at-a-time.
//! A fixed integer spin tier (`hot:host-calib-spin`) calibrates the
//! host: dividing any PE-cycles/s tier by `host_spin_ops_per_sec`
//! host-normalizes it, so trajectories line up across machines.
//!
//! Every run appends its PE-cycles/sec numbers and the fast-vs-dense
//! speedups to `BENCH_hotpath.json` at the repo root, so the perf
//! trajectory is tracked across PRs (`skewsa bench-check` validates the
//! schema and flags >20% regressions).  Pass `--smoke` (or set
//! `SKEWSA_BENCH_SMOKE=1`) for a fast CI-grade run with reduced
//! iteration counts; the appended record is schema-complete either way.
//!
//! ```text
//! cargo bench --bench bench_hotpath
//! cargo bench --bench bench_hotpath -- --smoke
//! ```

use skewsa::arith::accum::ColumnOracle;
use skewsa::arith::fma::{BaselineFmaPath, ChainCfg, ChainDatapath, PsumSignal, SkewedFmaPath};
use skewsa::arith::format::FpFormat;
use skewsa::config::RunConfig;
use skewsa::coordinator::Coordinator;
use skewsa::pe::PipelineKind;
use skewsa::precision::{analyze_layer, analyze_layer_reference, AnalysisConfig};
use skewsa::sa::array::ArraySim;
use skewsa::sa::column::ColumnSim;
use skewsa::sa::fast::FastArraySim;
use skewsa::sa::geometry::ArrayGeometry;
use skewsa::sa::stream::StreamingSim;
use skewsa::sa::tile::{GemmShape, TilePlan};
use skewsa::util::bench::{append_json_run, measure, with_units, Measurement};
use skewsa::util::rng::Rng;
use skewsa::workloads::gemm::GemmData;
use skewsa::workloads::resnet50;
use std::sync::Arc;

const CFG: ChainCfg = ChainCfg::BF16_FP32;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var_os("SKEWSA_BENCH_SMOKE").is_some();
    // Iteration scaler: smoke runs keep every tier but cut the counts.
    let it = |full: u32| if smoke { (full / 10).max(1) } else { full };
    let mut tiers: Vec<(String, f64)> = Vec::new();
    fn record(m: &Measurement, tiers: &mut Vec<(String, f64)>) {
        println!("{}", m.report());
        tiers.push((m.name.clone(), m.throughput()));
    }

    // --- 0. host calibration ---------------------------------------------
    // A fixed integer LCG spin: pure single-core ALU throughput, no
    // memory traffic.  PE-cycles/s tiers divided by this rate give the
    // host-normalized figures the trajectory comparisons should use.
    let spin = measure("hot:host-calib-spin", 1, it(200), 7, || {
        let mut x = 0x9e37_79b9_7f4a_7c15u64;
        for _ in 0..4096 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        }
        std::hint::black_box(x);
    });
    let spin = with_units(spin, 4096.0, "ops");
    record(&spin, &mut tiers);
    let host_spin = spin.throughput().max(1e-9);

    let mut rng = Rng::new(0x407);
    let vals: Vec<(u64, u64)> = (0..1024)
        .map(|_| {
            (
                FpFormat::BF16.from_f64(rng.normal_scaled(0.0, 1.0)),
                FpFormat::BF16.from_f64(rng.normal_scaled(0.0, 0.2)),
            )
        })
        .collect();

    // --- 1. datapath step throughput ------------------------------------
    for (name, path) in [
        ("hot:baseline-step", &BaselineFmaPath as &dyn ChainDatapath),
        ("hot:skewed-step", &SkewedFmaPath as &dyn ChainDatapath),
    ] {
        let m = measure(name, 3, it(200), 7, || {
            let mut s = PsumSignal::zero(&CFG);
            for &(a, w) in &vals {
                s = path.step(&CFG, &s, a, w);
            }
            std::hint::black_box(s.val.sig);
        });
        record(&with_units(m, 1024.0, "macs"), &mut tiers);
    }

    // --- oracle column (step + rounding) ---------------------------------
    let m = measure("hot:oracle-column-128", 3, it(200), 7, || {
        let mut o = ColumnOracle::new(CFG);
        for &(a, w) in vals.iter().take(128) {
            o.mac(a, w);
        }
        std::hint::black_box(o.result());
    });
    record(&with_units(m, 128.0, "macs"), &mut tiers);

    // --- 2. dense reference sims -----------------------------------------
    let data = GemmData::cnn_like(GemmShape::new(32, 32, 1), FpFormat::BF16, 1);
    let weights: Vec<u64> = (0..32).map(|k| data.w[k][0]).collect();
    let cycles = {
        let mut sim = ColumnSim::new(CFG, PipelineKind::Skewed, &weights, data.a.clone());
        sim.run(100_000).unwrap();
        sim.cycles()
    };
    let m = measure("hot:column-sim-32x32", 2, it(20), 5, || {
        let mut sim = ColumnSim::new(CFG, PipelineKind::Skewed, &weights, data.a.clone());
        sim.run(100_000).unwrap();
        std::hint::black_box(sim.cycles());
    });
    record(&with_units(m, cycles as f64 * 32.0, "PE-cycles"), &mut tiers);

    let adata = GemmData::cnn_like(GemmShape::new(16, 32, 32), FpFormat::BF16, 2);
    let acycles = {
        let mut sim = ArraySim::new(CFG, PipelineKind::Skewed, &adata.w, adata.a.clone());
        sim.run(1_000_000).unwrap();
        sim.cycles()
    };
    let apes = acycles as f64 * (32.0 * 32.0);
    let m = measure("hot:array-sim-32x32xM16", 1, it(10), 5, || {
        let mut sim = ArraySim::new(CFG, PipelineKind::Skewed, &adata.w, adata.a.clone());
        sim.run(1_000_000).unwrap();
        std::hint::black_box(sim.cycles());
    });
    let dense32 = with_units(m, apes, "PE-cycles");
    record(&dense32, &mut tiers);

    // --- 3. fast banded simulator (same workload, then paper scale) ------
    let m = measure("hot:fast-sim-32x32xM16", 2, it(50), 5, || {
        let mut sim = FastArraySim::new(CFG, PipelineKind::Skewed, &adata.w, &adata.a);
        sim.run(1_000_000).unwrap();
        std::hint::black_box(sim.cycles());
    });
    let fast32 = with_units(m, apes, "PE-cycles");
    record(&fast32, &mut tiers);

    // All-kinds sweep: every registered pipeline organisation through
    // the fast simulator on the same tile, so the registry's per-kind
    // throughput trajectory lands in BENCH_hotpath.json (ISSUE 4).
    for kind in PipelineKind::ALL {
        let kcycles = {
            let mut sim = FastArraySim::new(CFG, kind, &adata.w, &adata.a);
            sim.run(1_000_000).unwrap();
            assert!(sim.latency_matches_schedule(), "{kind} off-formula");
            sim.cycles()
        };
        let m = measure(&format!("hot:fast-sim-32x32xM16-{}", kind.name()), 1, it(30), 5, || {
            let mut sim = FastArraySim::new(CFG, kind, &adata.w, &adata.a);
            sim.run(1_000_000).unwrap();
            std::hint::black_box(sim.cycles());
        });
        record(&with_units(m, kcycles as f64 * (32.0 * 32.0), "PE-cycles"), &mut tiers);
    }

    // Paper-scale 128×128 weight tile: the dense loop's practical limit
    // was ~64×64; the banded simulator runs it directly.
    let pdata = GemmData::cnn_like(GemmShape::new(32, 128, 128), FpFormat::BF16, 3);
    let pcycles = {
        let mut sim = FastArraySim::new(CFG, PipelineKind::Skewed, &pdata.w, &pdata.a);
        sim.run(1_000_000).unwrap();
        assert!(sim.latency_matches_schedule(), "fast sim must match the timing model");
        sim.cycles()
    };
    let ppes = pcycles as f64 * (128.0 * 128.0);
    let m = measure("hot:array-sim-128x128xM32", 0, it(10).min(2), 3, || {
        let mut sim = ArraySim::new(CFG, PipelineKind::Skewed, &pdata.w, pdata.a.clone());
        sim.run(1_000_000).unwrap();
        std::hint::black_box(sim.cycles());
    });
    let dense128 = with_units(m, ppes, "PE-cycles");
    record(&dense128, &mut tiers);

    let m = measure("hot:fast-sim-128x128xM32", 1, it(20), 5, || {
        let mut sim = FastArraySim::new(CFG, PipelineKind::Skewed, &pdata.w, &pdata.a);
        sim.run(1_000_000).unwrap();
        std::hint::black_box(sim.cycles());
    });
    let fast128 = with_units(m, ppes, "PE-cycles");
    record(&fast128, &mut tiers);

    // Scalar variant of the same tile: the per-lane generic-datapath
    // reference driver ([`FastArraySim::run_reference`]) instead of the
    // monomorphized banded kernels — the speedup the vectorized lane
    // rewrite buys, on identical bits (pinned by the parity suite).
    let m = measure("hot:fast-sim-128x128xM32-scalar", 1, it(20), 5, || {
        let mut sim = FastArraySim::new(CFG, PipelineKind::Skewed, &pdata.w, &pdata.a);
        sim.run_reference(1_000_000).unwrap();
        std::hint::black_box(sim.cycles());
    });
    let scalar128 = with_units(m, ppes, "PE-cycles");
    record(&scalar128, &mut tiers);

    // Fixed tier key (the worker count is machine-dependent and goes
    // into its own JSON field so trajectories line up across hosts).
    let workers = std::thread::available_parallelism().map_or(4, |n| n.get().min(8));
    let m = measure("hot:fast-sim-128x128xM32-par", 1, it(20), 5, || {
        let mut sim = FastArraySim::new(CFG, PipelineKind::Skewed, &pdata.w, &pdata.a);
        sim.run_parallel(1_000_000, workers).unwrap();
        std::hint::black_box(sim.cycles());
    });
    let fast128p = with_units(m, ppes, "PE-cycles");
    record(&fast128p, &mut tiers);

    // --- streaming tier: multi-tile 128×128 plan ------------------------
    // A 4-tile (2 K-passes × 2 N-blocks) paper-scale plan streamed as one
    // continuous run with double-buffered vs serialized weight preload
    // (ISSUE 5).  Simulated totals are pinned to the closed-form layer
    // model before the numbers are trusted.
    let sdata = GemmData::cnn_like(GemmShape::new(32, 256, 256), FpFormat::BF16, 5);
    let splan = TilePlan::new(GemmShape::new(32, 256, 256), 128, 128);
    assert_eq!(splan.tile_count(), 4);
    let stream_workers = std::thread::available_parallelism().map_or(4, |n| n.get().min(8));
    let mut stream_tiers: Vec<(&str, bool, f64)> = Vec::new();
    for (name, db) in [
        ("hot:stream-4x128x128-serial-preload", false),
        ("hot:stream-4x128x128-double-buffered", true),
    ] {
        let scycles = {
            let mut sim =
                StreamingSim::new(CFG, PipelineKind::Skewed, &splan, &sdata.w, &sdata.a, db);
            sim.run_parallel(10_000_000, stream_workers).unwrap();
            assert!(sim.matches_layer_timing(), "stream must match the layer model");
            sim.report().unwrap().cycles
        };
        let m = measure(name, 1, it(10), 3, || {
            let mut sim =
                StreamingSim::new(CFG, PipelineKind::Skewed, &splan, &sdata.w, &sdata.a, db);
            sim.run_parallel(10_000_000, stream_workers).unwrap();
            std::hint::black_box(sim.report().unwrap().cycles);
        });
        let m = with_units(m, scycles as f64 * (128.0 * 128.0), "PE-cycles");
        record(&m, &mut tiers);
        stream_tiers.push((name, db, scycles as f64));
    }
    let overlap_saving = 1.0 - stream_tiers[1].2 / stream_tiers[0].2;
    println!(
        "bench: double-buffered preload hides {:.1}% of the 4-tile stream ({} -> {} cycles)",
        overlap_saving * 100.0,
        stream_tiers[0].2,
        stream_tiers[1].2
    );

    // Tile-level parallelism: the same 4-tile plan with independent
    // K-pass/output tiles fanned across threads (the executor's default
    // cycle-accurate route), identical bits and report to the serial
    // stream by construction.
    let stream_db_cycles = stream_tiers[1].2;
    let m = measure("hot:stream-4x128x128-tile-par", 1, it(10), 3, || {
        let mut sim =
            StreamingSim::new(CFG, PipelineKind::Skewed, &splan, &sdata.w, &sdata.a, true);
        sim.run_tile_parallel(10_000_000, stream_workers).unwrap();
        std::hint::black_box(sim.report().unwrap().cycles);
    });
    record(&with_units(m, stream_db_cycles * (128.0 * 128.0), "PE-cycles"), &mut tiers);

    // --- precision-oracle layer analysis (vectorized vs reference) -------
    // One mid-network ResNet50 layer at the `skewsa precision` sampling
    // defaults: the wall time the planner pays per (layer, format) probe.
    let rlayers = resnet50::layers();
    let rlayer = &rlayers[rlayers.len() / 2];
    let acfg = AnalysisConfig { m_cap: 8, n_cap: 16, seed: 0 };
    let outputs = (acfg.m_cap * acfg.n_cap) as f64;
    let m = measure("hot:precision-resnet50-mid-vectorized", 1, it(10), 3, || {
        std::hint::black_box(analyze_layer(rlayer, FpFormat::BF16, &acfg).stats.samples);
    });
    let prec_vec = with_units(m, outputs, "outputs");
    record(&prec_vec, &mut tiers);
    let m = measure("hot:precision-resnet50-mid-scalar", 1, it(10), 3, || {
        std::hint::black_box(analyze_layer_reference(rlayer, FpFormat::BF16, &acfg).stats.samples);
    });
    let prec_ref = with_units(m, outputs, "outputs");
    record(&prec_ref, &mut tiers);

    let speedup32 = fast32.throughput() / dense32.throughput().max(1e-9);
    let speedup128 = fast128.throughput() / dense128.throughput().max(1e-9);
    let speedup128p = fast128p.throughput() / dense128.throughput().max(1e-9);
    let speedup_vec128 = fast128.throughput() / scalar128.throughput().max(1e-9);
    let speedup_prec = prec_vec.throughput() / prec_ref.throughput().max(1e-9);
    println!("bench: fast-vs-dense speedup   32x32xM16 {speedup32:>8.1}x");
    println!("bench: fast-vs-dense speedup 128x128xM32 {speedup128:>8.1}x (serial)");
    println!("bench: fast-vs-dense speedup 128x128xM32 {speedup128p:>8.1}x (par{workers})");
    println!("bench: vectorized-vs-scalar  128x128xM32 {speedup_vec128:>8.2}x (banded kernels)");
    println!("bench: precision analysis vectorized     {speedup_prec:>8.2}x (resnet50 mid)");

    // --- 4. coordinated GEMM throughput ----------------------------------
    for workers in [1usize, 4, 8] {
        let mut cfg = RunConfig::small();
        cfg.geometry = ArrayGeometry::new(32, 32);
        cfg.workers = workers;
        cfg.verify_fraction = 0.0;
        let shape = GemmShape::new(64, 128, 64);
        let gdata = Arc::new(GemmData::cnn_like(shape, FpFormat::BF16, 3));
        let coord = Coordinator::new(cfg);
        let m = measure(&format!("hot:executor-64x128x64-w{workers}"), 1, it(3).min(3), 3, || {
            let r = coord.run_gemm(PipelineKind::Skewed, &gdata);
            std::hint::black_box(r.y.len());
        });
        record(&with_units(m, shape.macs() as f64, "macs"), &mut tiers);
    }

    // --- trajectory file -------------------------------------------------
    let ts = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let mut entry = format!(
        "  {{\"bench\": \"hotpath\", \"unix_time\": {ts}, \"smoke\": {smoke}, \
         \"par_workers\": {workers}, \"host_spin_ops_per_sec\": {host_spin:.4e}, \
         \"kernel_vectorized_variant\": \"mono-banded\", \
         \"kernel_scalar_variant\": \"generic-serial\""
    );
    for (name, thru) in &tiers {
        entry.push_str(&format!(", \"{name}\": {thru:.4e}"));
    }
    entry.push_str(&format!(
        ", \"speedup_fast_vs_dense_32\": {speedup32:.2}, \
         \"speedup_fast_vs_dense_128\": {speedup128:.2}, \
         \"speedup_fast_par_vs_dense_128\": {speedup128p:.2}, \
         \"speedup_vectorized_vs_scalar_128\": {speedup_vec128:.3}, \
         \"speedup_precision_vectorized\": {speedup_prec:.3}, \
         \"stream_serial_cycles\": {}, \"stream_overlapped_cycles\": {}, \
         \"stream_overlap_saving\": {overlap_saving:.4}}}",
        stream_tiers[0].2, stream_tiers[1].2
    ));
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_hotpath.json");
    match append_json_run(&path, &entry) {
        Ok(()) => println!("bench: trajectory appended to {}", path.display()),
        Err(e) => eprintln!("bench: could not append trajectory: {e}"),
    }
}
