//! Serving bench: the serve tentpole's headline measurement.
//!
//! Drives the full serve stack (queue → batcher → plan cache → shards)
//! with a closed-loop client fleet over MobileNet+ResNet50 layer
//! models, then runs the *same* request list sequentially through
//! per-request `Coordinator` runs (equal total worker budget) — the
//! pre-serve architecture.  Reports p50/p95/p99 latency and request
//! throughput for the served path, the sequential baseline throughput,
//! and the speedup; a sampled subset of requests is re-run solo and
//! compared bit-for-bit against its served response.
//!
//! Every run appends to `BENCH_serve.json` at the repo root, mirroring
//! the `BENCH_hotpath.json` perf trajectory.  A second *chaos* tier
//! re-runs the fleet under seeded SDC injection + stragglers (ABFT on)
//! and appends a `serve_faults` entry: the detection/recovery ledger
//! and the throughput overhead against the clean run.  A final *fleet*
//! tier runs the discrete-event simulator at 100 and 1000 shards,
//! asserting bit-identical same-seed fingerprints and recording p99 /
//! goodput per scale.  Pass `--smoke` (or set `SKEWSA_BENCH_SMOKE=1`)
//! for the CI-grade quick run.
//!
//! ```text
//! cargo bench --bench bench_serve
//! cargo bench --bench bench_serve -- --smoke
//! ```

use skewsa::config::{FleetConfig, RunConfig, ServeConfig};
use skewsa::coordinator::FaultModel;
use skewsa::fleet::{FleetSim, TenantSpec};
use skewsa::report;
use skewsa::sa::geometry::ArrayGeometry;
use skewsa::serve::{
    gen_request, recv_response, run_closed_loop, DeadlineClass, LoadSpec, Server, ShardSnapshot,
};
use skewsa::util::bench::append_json_run;
use skewsa::workloads::serving::WeightStore;
use skewsa::workloads::{mobilenet, resnet50};
use skewsa::PipelineKind;
use std::sync::Arc;
use std::time::Instant;

const CAP: usize = 64;

fn run_cfg() -> RunConfig {
    let mut cfg = RunConfig::small();
    cfg.geometry = ArrayGeometry::new(32, 32);
    cfg.verify_fraction = 0.0;
    cfg
}

fn main() {
    let mut smoke = std::env::var_os("SKEWSA_BENCH_SMOKE").is_some();
    for a in std::env::args().skip(1) {
        match a.as_str() {
            "--smoke" => smoke = true,
            "--bench" => {} // appended by `cargo bench`
            other => {
                eprintln!("error: unknown option '{other}'\nusage: bench_serve [--smoke]");
                std::process::exit(2);
            }
        }
    }

    let cfg = run_cfg();
    let scfg = ServeConfig {
        shards: 2,
        workers_per_shard: 2,
        queue_cap: 256,
        batch_window_us: 500,
        interactive_window_us: 0,
        max_batch_requests: 16,
        max_batch_rows: 256,
        plan_cache_cap: 128,
        ..ServeConfig::default()
    };
    let mut layers = mobilenet::layers();
    layers.extend(resnet50::layers());
    let store = Arc::new(WeightStore::from_layers(&layers, cfg.in_fmt, CAP, CAP));
    let spec = LoadSpec {
        clients: 8,
        requests_per_client: if smoke { 6 } else { 30 },
        kinds: vec![PipelineKind::Baseline3b, PipelineKind::Skewed],
        interactive_fraction: 0.2,
        min_rows: 2,
        max_rows: 8,
        seed: 0x5e12e_2023,
    };
    let total_requests = spec.clients * spec.requests_per_client;
    println!(
        "bench: serve {} models (K/N<={CAP}) on {} shards x {} workers, \
         {} clients x {} requests{}",
        store.len(),
        scfg.shards,
        scfg.workers_per_shard,
        spec.clients,
        spec.requests_per_client,
        if smoke { " (smoke)" } else { "" },
    );

    // --- served path -----------------------------------------------------
    let server = Server::start(&cfg, &scfg, Arc::clone(&store));
    let load = run_closed_loop(&server, &spec);
    let stats = server.stats();
    assert_eq!(load.completed, total_requests, "every request must be served");
    let rep = report::serve_summary(&load, &server.metrics());
    print!("{}", rep.render());

    // --- sequential per-request Coordinator baseline ---------------------
    // Same request list, same total worker budget, one GEMM at a time —
    // the architecture before the serve layer existed.
    let mut seq_cfg = cfg.clone();
    seq_cfg.workers = scfg.shards * scfg.workers_per_shard;
    let t0 = Instant::now();
    for client in 0..spec.clients {
        for i in 0..spec.requests_per_client {
            let (model, kind, _class, a) = gen_request(&store, &spec, client, i);
            let bits = store.solo_reference_bits(&seq_cfg, model, kind, &a);
            std::hint::black_box(bits.len());
        }
    }
    let seq_wall = t0.elapsed().as_secs_f64();
    let seq_rps = total_requests as f64 / seq_wall;
    let serve_rps = load.latency.throughput_rps;
    let speedup = serve_rps / seq_rps.max(1e-9);
    println!("bench: sequential baseline {seq_rps:>10.1} req/s ({seq_wall:.2}s total)");
    println!("bench: served throughput   {serve_rps:>10.1} req/s");
    println!("bench: serve-vs-sequential {speedup:>10.2}x");

    // --- sampled bit-exactness: served == solo coordinator ---------------
    let samples = if smoke { 4 } else { 8 };
    for s in 0..samples {
        let client = s % spec.clients;
        let i = (s * 7) % spec.requests_per_client;
        let (model, kind, _class, a) = gen_request(&store, &spec, client, i);
        let rx = server.submit(model, kind, DeadlineClass::Interactive, a.clone());
        let resp = recv_response(&rx, "served sample");
        let got: Vec<u32> = resp.y.iter().map(|v| v.to_bits()).collect();
        let want = store.solo_reference_bits(&seq_cfg, model, kind, &a);
        assert_eq!(got, want, "served bits diverged from solo run (sample {s})");
    }
    println!("bench: bit-exactness      {samples} served samples == solo coordinator runs");

    // --- trajectory file -------------------------------------------------
    let ts = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let l = &load.latency;
    // Exact tile-retry count from the shard counters (not the
    // response-weighted LoadReport sum).
    let tile_retries: u64 = stats.shards.iter().map(|s| s.retries).sum();
    let entry = format!(
        "  {{\"bench\": \"serve\", \"unix_time\": {ts}, \"smoke\": {smoke}, \
         \"requests\": {total_requests}, \"clients\": {}, \"shards\": {}, \
         \"workers_per_shard\": {}, \"cap\": {CAP}, \
         \"p50_us\": {:.1}, \"p95_us\": {:.1}, \"p99_us\": {:.1}, \"mean_us\": {:.1}, \
         \"serve_rps\": {:.2}, \"seq_rps\": {:.2}, \"speedup\": {:.3}, \
         \"batched_fraction\": {:.3}, \"max_batch\": {}, \
         \"cache_hit_rate\": {:.3}, \"retries\": {}}}",
        spec.clients,
        scfg.shards,
        scfg.workers_per_shard,
        l.p50_us,
        l.p95_us,
        l.p99_us,
        l.mean_us,
        serve_rps,
        seq_rps,
        speedup,
        load.batched_fraction(),
        load.max_batch,
        stats.cache.hit_rate(),
        tile_retries,
    );
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_serve.json");
    match append_json_run(&path, &entry) {
        Ok(()) => println!("bench: trajectory appended to {}", path.display()),
        Err(e) => eprintln!("bench: could not append trajectory: {e}"),
    }

    // --- fault-tolerance tier --------------------------------------------
    // The same closed-loop fleet against a server under seeded chaos:
    // silent bit-flips into psums/outputs plus stragglers, with the
    // ABFT checksums verifying every assembled block.  Measures the
    // detection/recovery overhead against the clean served throughput
    // above and records the fault ledger alongside it.
    let mut fault_scfg = scfg.clone();
    fault_scfg.fault = FaultModel {
        sdc_rate: 0.05,
        slow_rate: 0.02,
        slow_us: 100,
        seed: 0xfa175,
        abft: true,
        ..FaultModel::none()
    };
    println!("bench: chaos tier, fault [{}]", fault_scfg.fault);
    let fault_server = Server::start(&cfg, &fault_scfg, Arc::clone(&store));
    let fault_load = run_closed_loop(&fault_server, &spec);
    let fault_stats = fault_server.stats();
    assert_eq!(
        fault_load.completed + fault_load.shed,
        total_requests,
        "every chaos request must be answered or explicitly shed"
    );
    let fsum = |f: fn(&ShardSnapshot) -> u64| -> u64 { fault_stats.shards.iter().map(f).sum() };
    assert_eq!(fsum(|s| s.sdc_unresolved), 0, "chaos run left corrupted blocks unresolved");
    let fault_rps = fault_load.latency.throughput_rps;
    let overhead = serve_rps / fault_rps.max(1e-9);
    println!(
        "bench: chaos sdc inj/det/rec {}/{}/{}, {} failed batches, {} quarantines, {} shed",
        fsum(|s| s.sdc_injected),
        fsum(|s| s.sdc_detected),
        fsum(|s| s.sdc_recovered),
        fsum(|s| s.failed_batches),
        fsum(|s| s.quarantines),
        fault_stats.shed,
    );
    println!("bench: chaos throughput    {fault_rps:>10.1} req/s ({overhead:.2}x slowdown)");
    let fl = &fault_load.latency;
    let fault_entry = format!(
        "  {{\"bench\": \"serve_faults\", \"unix_time\": {ts}, \"smoke\": {smoke}, \
         \"requests\": {total_requests}, \"sdc_rate\": 0.05, \"slow_rate\": 0.02, \
         \"p50_us\": {:.1}, \"p95_us\": {:.1}, \"p99_us\": {:.1}, \
         \"fault_rps\": {:.2}, \"clean_rps\": {:.2}, \"overhead\": {:.3}, \
         \"sdc_injected\": {}, \"sdc_detected\": {}, \"sdc_recovered\": {}, \
         \"sdc_unresolved\": {}, \"failed_batches\": {}, \"quarantines\": {}, \"shed\": {}}}",
        fl.p50_us,
        fl.p95_us,
        fl.p99_us,
        fault_rps,
        serve_rps,
        overhead,
        fsum(|s| s.sdc_injected),
        fsum(|s| s.sdc_detected),
        fsum(|s| s.sdc_recovered),
        fsum(|s| s.sdc_unresolved),
        fsum(|s| s.failed_batches),
        fsum(|s| s.quarantines),
        fault_stats.shed,
    );
    match append_json_run(&path, &fault_entry) {
        Ok(()) => println!("bench: chaos trajectory appended to {}", path.display()),
        Err(e) => eprintln!("bench: could not append chaos trajectory: {e}"),
    }

    // --- observability-overhead tier --------------------------------------
    // The same fleet with request tracing fully on (live spans + sink)
    // vs off (inert spans; metrics registry always on).  Tracing is a
    // few atomic stores per phase and one mutex push per finished span,
    // so it must stay effectively free: the smoke gate fails the build
    // when the measured throughput tax exceeds 3%.  Best-of-N per mode
    // to keep scheduler noise out of the comparison.
    let reps = if smoke { 3 } else { 2 };
    let best_rps = |mk_obs: fn() -> skewsa::obs::Obs| -> f64 {
        let mut best = 0.0f64;
        for _ in 0..reps {
            let server = Server::start_obs(&cfg, &scfg, Arc::clone(&store), mk_obs());
            let load = run_closed_loop(&server, &spec);
            assert_eq!(load.completed, total_requests, "obs tier must serve everything");
            if let Some(sink) = &server.obs().sink {
                assert_eq!(sink.spans().len(), total_requests, "one closed span per request");
            }
            best = best.max(load.latency.throughput_rps);
        }
        best
    };
    let rps_plain = best_rps(skewsa::obs::Obs::new);
    let rps_traced = best_rps(skewsa::obs::Obs::with_tracing);
    let obs_overhead_pct = (1.0 - rps_traced / rps_plain.max(1e-9)) * 100.0;
    println!(
        "bench: obs overhead        {obs_overhead_pct:>9.2}% \
         (traced {rps_traced:.1} vs plain {rps_plain:.1} req/s, best of {reps})"
    );
    let obs_entry = format!(
        "  {{\"bench\": \"serve_obs\", \"unix_time\": {ts}, \"smoke\": {smoke}, \
         \"requests\": {total_requests}, \"rps_traced\": {rps_traced:.2}, \
         \"rps_plain\": {rps_plain:.2}, \"obs_overhead_pct\": {obs_overhead_pct:.2}}}"
    );
    match append_json_run(&path, &obs_entry) {
        Ok(()) => println!("bench: obs trajectory appended to {}", path.display()),
        Err(e) => eprintln!("bench: could not append obs trajectory: {e}"),
    }
    if smoke && obs_overhead_pct > 3.0 {
        eprintln!("OBS OVERHEAD GATE FAILED: {obs_overhead_pct:.2}% > 3% throughput tax");
        std::process::exit(1);
    }

    // --- fleet tier --------------------------------------------------------
    // The discrete-event simulator at scales the threaded stack cannot
    // reach: the same admission/batching/routing policies over a
    // virtual clock, at 100 and 1000 Poisson-driven shards.  Each scale
    // runs twice with the same seed and must produce an identical
    // fingerprint — the bit-reproducibility the differential tests pin,
    // measured here at fleet size.
    for &shards in &[100usize, 1000] {
        let horizon: u64 = if smoke { 400_000 } else { 2_000_000 };
        let fcfg = FleetConfig {
            shards,
            min_shards: shards,
            max_shards: shards,
            horizon,
            tenants: vec![TenantSpec::poisson("bench", 20.0)],
            ..FleetConfig::default()
        };
        let t0 = Instant::now();
        let r1 = FleetSim::simulate(&cfg, &fcfg);
        let fleet_wall = t0.elapsed().as_secs_f64();
        let r2 = FleetSim::simulate(&cfg, &fcfg);
        assert_eq!(
            r1.fingerprint, r2.fingerprint,
            "fleet DES diverged across same-seed runs ({shards} shards)"
        );
        assert!(r1.accounting_balanced(), "fleet accounting imbalance at {shards} shards");
        let p99 = r1.latency.quantile(99.0);
        let goodput = r1.goodput_rps(cfg.clock_ghz);
        println!(
            "bench: fleet {shards:>4} shards  {} submitted, {} served, p99 {p99} cyc, \
             {goodput:.0} req/s goodput, {fleet_wall:.2}s wall",
            r1.submitted, r1.served,
        );
        let fleet_entry = format!(
            "  {{\"bench\": \"fleet\", \"unix_time\": {ts}, \"smoke\": {smoke}, \
             \"shards\": {shards}, \"horizon\": {horizon}, \"submitted\": {}, \
             \"served\": {}, \"shed\": {}, \"failed\": {}, \"p99_cycles\": {p99}, \
             \"goodput_rps\": {goodput:.2}, \"wall_s\": {fleet_wall:.3}, \
             \"fingerprint\": \"{:016x}\"}}",
            r1.submitted, r1.served, r1.shed, r1.failed, r1.fingerprint,
        );
        match append_json_run(&path, &fleet_entry) {
            Ok(()) => println!("bench: fleet trajectory appended to {}", path.display()),
            Err(e) => eprintln!("bench: could not append fleet trajectory: {e}"),
        }
    }

    // --- heterogeneous-fleet tier ------------------------------------------
    // Equal PE budget, different shapes: a mixed decode+CNN trace over a
    // uniform 4×128x128 round-robin fleet vs a [256x64, 64x256,
    // 128x128, 128x128] fleet under shape-aware routing.  The routing
    // policy scores each request's GEMM against every shard's geometry
    // through the plan cache, so the tall array absorbs the
    // reduction-deep decode projections and the squares keep the CNN
    // layers — the win must show on BOTH p99 latency and total stream
    // cycles, and it is asserted (the trace is deterministic).
    {
        use skewsa::coordinator::Policy;
        use skewsa::fleet::{ArrivalSpec, TraceReq};
        use skewsa::serve::DeadlineClass;
        let mut hrun = RunConfig::small();
        hrun.geometry = ArrayGeometry::new(128, 128);
        hrun.verify_fraction = 0.0;
        let n_req = if smoke { 60 } else { 200 };
        let requests: Vec<TraceReq> = (0..n_req)
            .map(|i| TraceReq {
                at: i as u64 * 4_000,
                model: i % 2,
                rows: 2,
                kind: PipelineKind::Skewed,
                class: DeadlineClass::Interactive,
            })
            .collect();
        let base = FleetConfig {
            shards: 4,
            min_shards: 4,
            max_shards: 4,
            horizon: n_req as u64 * 4_000 + 100_000,
            autoscale_interval: 0,
            models: vec![
                skewsa::fleet::ModelShape { k: 4096, n: 64 }, // decode projection
                skewsa::fleet::ModelShape { k: 512, n: 512 }, // CNN mid-layer
            ],
            tenants: vec![TenantSpec {
                arrival: ArrivalSpec::Trace { requests },
                ..TenantSpec::poisson("mixed", 1.0)
            }],
            ..FleetConfig::default()
        };
        let uniform = FleetConfig { shard_policy: Policy::RoundRobin, ..base.clone() };
        let hetero = FleetConfig {
            shard_policy: Policy::ShapeAware,
            shard_geometries: vec![
                ArrayGeometry::new(256, 64),
                ArrayGeometry::new(64, 256),
                ArrayGeometry::new(128, 128),
                ArrayGeometry::new(128, 128),
            ],
            ..base
        };
        let pe_budget = |f: &FleetConfig| -> usize {
            (0..4).map(|s| f.shard_geometry(s, hrun.geometry).pe_count()).sum()
        };
        assert_eq!(pe_budget(&uniform), pe_budget(&hetero), "the comparison is at equal silicon");
        let ru = FleetSim::simulate(&hrun, &uniform);
        let rh = FleetSim::simulate(&hrun, &hetero);
        assert!(ru.accounting_balanced() && rh.accounting_balanced());
        assert_eq!(ru.served, n_req as u64, "uniform fleet must serve the whole trace");
        assert_eq!(rh.served, n_req as u64, "hetero fleet must serve the whole trace");
        let (p99_u, p99_h) = (ru.latency.quantile(99.0), rh.latency.quantile(99.0));
        let hetero_speedup = ru.stream_cycles as f64 / rh.stream_cycles.max(1) as f64;
        println!(
            "bench: hetero fleet        p99 {p99_h} vs uniform {p99_u} cyc, \
             stream {} vs {} cyc ({hetero_speedup:.3}x)",
            rh.stream_cycles, ru.stream_cycles,
        );
        assert!(
            p99_h < p99_u && rh.stream_cycles < ru.stream_cycles,
            "shape-aware hetero fleet must beat the uniform square fleet on p99 \
             ({p99_h} vs {p99_u}) and stream cycles ({} vs {})",
            rh.stream_cycles,
            ru.stream_cycles,
        );
        // Per-geometry utilization of the hetero fleet (busy/wall per shape).
        let util_for = |r: &skewsa::fleet::FleetResult, g: ArrayGeometry| -> f64 {
            let (n, busy) = r
                .shard_geoms
                .iter()
                .zip(&r.shard_busy)
                .filter(|(&sg, _)| sg == g)
                .fold((0u64, 0u64), |(n, b), (_, &sb)| (n + 1, b + sb));
            if r.wall_cycles == 0 || n == 0 {
                0.0
            } else {
                busy as f64 / (r.wall_cycles * n) as f64
            }
        };
        let hetero_entry = format!(
            "  {{\"bench\": \"serve_hetero\", \"unix_time\": {ts}, \"smoke\": {smoke}, \
             \"requests\": {n_req}, \"pe_budget\": {}, \
             \"p99_uniform_cycles\": {p99_u}, \"p99_hetero_cycles\": {p99_h}, \
             \"stream_cycles_uniform\": {}, \"stream_cycles_hetero\": {}, \
             \"hetero_speedup\": {hetero_speedup:.4}, \
             \"util_tall\": {:.4}, \"util_wide\": {:.4}, \"util_square\": {:.4}, \
             \"util_uniform\": {:.4}}}",
            pe_budget(&hetero),
            ru.stream_cycles,
            rh.stream_cycles,
            util_for(&rh, ArrayGeometry::new(256, 64)),
            util_for(&rh, ArrayGeometry::new(64, 256)),
            util_for(&rh, ArrayGeometry::new(128, 128)),
            util_for(&ru, ArrayGeometry::new(128, 128)),
        );
        match append_json_run(&path, &hetero_entry) {
            Ok(()) => println!("bench: hetero trajectory appended to {}", path.display()),
            Err(e) => eprintln!("bench: could not append hetero trajectory: {e}"),
        }
    }
}
