//! Precision-planner bench: analysis throughput + planning outcomes.
//!
//! Measures the per-layer error-analysis rate (oracle MAC steps per
//! second — the planner's hot loop), then runs the full budgeted study
//! on MobileNetV1 and reports the planned mixed-precision energy
//! against the all-FP32 and all-BF16 uniform plans.
//!
//! Every run appends to `BENCH_precision.json` at the repo root,
//! mirroring the `BENCH_hotpath.json` / `BENCH_serve.json`
//! trajectories.  Pass `--smoke` (or set `SKEWSA_BENCH_SMOKE=1`) for
//! the CI-grade quick run.
//!
//! ```text
//! cargo bench --bench bench_precision
//! cargo bench --bench bench_precision -- --smoke
//! ```

use skewsa::precision::{analyze_layer, AnalysisConfig, PlannerConfig, PrecisionStudy};
use skewsa::timing::model::TimingConfig;
use skewsa::util::bench::{append_json_run, measure, with_units};
use skewsa::workloads::layer::LayerDef;
use skewsa::workloads::mobilenet;
use skewsa::FpFormat;
use skewsa::PipelineKind;
use std::time::Instant;

fn main() {
    let mut smoke = std::env::var_os("SKEWSA_BENCH_SMOKE").is_some();
    for a in std::env::args().skip(1) {
        match a.as_str() {
            "--smoke" => smoke = true,
            "--bench" => {} // appended by `cargo bench`
            other => {
                eprintln!("error: unknown option '{other}'\nusage: bench_precision [--smoke]");
                std::process::exit(2);
            }
        }
    }

    // --- tier 1: analysis throughput (the planner's hot loop) ------------
    let probe = LayerDef::conv("bench/conv", 16, 3, 1, 32, 32);
    let acfg = AnalysisConfig { m_cap: 4, n_cap: 8, seed: 1 };
    let shape = probe.gemm();
    let macs = (shape.m.min(acfg.m_cap) * shape.k * shape.n.min(acfg.n_cap)) as f64;
    let (iters, samples) = if smoke { (2, 2) } else { (8, 5) };
    for fmt in [FpFormat::BF16, FpFormat::FP8E4M3, FpFormat::FP32] {
        let m = measure(&format!("analyze-layer/{}", fmt.display_name()), 1, iters, samples, || {
            let a = analyze_layer(&probe, fmt, &acfg);
            std::hint::black_box(a.stats.samples);
        });
        println!("{}", with_units(m, macs, "mac").report());
    }

    // --- tier 2: the full MobileNet study at the paper point --------------
    let budget = 1e-2;
    let layers = mobilenet::layers();
    let pcfg = PlannerConfig {
        budget,
        kinds: vec![PipelineKind::Skewed],
        candidates: FpFormat::ALL.to_vec(),
        analysis: AnalysisConfig {
            m_cap: if smoke { 2 } else { 8 },
            n_cap: if smoke { 4 } else { 16 },
            seed: 0x5eed_2023,
        },
        tcfg: TimingConfig::PAPER,
    };
    let t0 = Instant::now();
    let study = PrecisionStudy::run(&layers, &pcfg);
    let study_s = t0.elapsed().as_secs_f64();
    let energy = |label: &str| {
        study
            .plans()
            .into_iter()
            .find(|p| p.label == label)
            .map(|p| p.total_energy_uj())
            .expect("study plan")
    };
    let (mixed_uj, fp32_uj, bf16_uj) = (energy("mixed"), energy("FP32"), energy("BF16"));
    let saving = 1.0 - mixed_uj / fp32_uj;
    println!(
        "bench: mobilenet study in {study_s:.2}s — mixed {mixed_uj:.1} uJ \
         vs FP32 {fp32_uj:.1} uJ ({:.1}% saved), BF16 uniform {bf16_uj:.1} uJ, \
         worst-rel {:.3e}, meets-budget {}",
        saving * 100.0,
        study.mixed.worst_rel(),
        study.mixed.meets_budget(),
    );
    assert!(bf16_uj < fp32_uj, "reduced-precision plans must cost less energy");
    assert!(mixed_uj <= fp32_uj, "the planner never beats FP32 on cost upward");

    // --- trajectory file -------------------------------------------------
    let ts = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let worst = study.mixed.worst_rel();
    // `inf` is not JSON: an over-budget plan records null here.
    let worst_json =
        if worst.is_finite() { format!("{worst:.4e}") } else { "null".to_string() };
    let entry = format!(
        "  {{\"bench\": \"precision\", \"unix_time\": {ts}, \"smoke\": {smoke}, \
         \"workload\": \"mobilenet\", \"budget\": {budget}, \
         \"m_cap\": {}, \"n_cap\": {}, \"study_s\": {study_s:.3}, \
         \"mixed_uj\": {mixed_uj:.2}, \"fp32_uj\": {fp32_uj:.2}, \
         \"bf16_uj\": {bf16_uj:.2}, \"energy_saving\": {saving:.4}, \
         \"worst_rel\": {worst_json}, \"meets_budget\": {}}}",
        pcfg.analysis.m_cap,
        pcfg.analysis.n_cap,
        study.mixed.meets_budget(),
    );
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_precision.json");
    match append_json_run(&path, &entry) {
        Ok(()) => println!("bench: trajectory appended to {}", path.display()),
        Err(e) => eprintln!("bench: could not append trajectory: {e}"),
    }
}
