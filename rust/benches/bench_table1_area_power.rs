//! E3 — the §IV hardware-cost paragraph as a table: PE/array area and
//! power for both designs, with the emergent overhead percentages the
//! paper quotes (+9% area, +7% power), plus the per-block breakdown
//! that attributes them (registers + fix logic).
//!
//! ```text
//! cargo bench --bench bench_table1_area_power
//! ```

use skewsa::arith::fma::ChainCfg;
use skewsa::energy::{AreaModel, PowerModel};
use skewsa::pe::PipelineKind;
use skewsa::report;
use skewsa::util::table::{fnum, pct, Table};

fn main() {
    let chain = ChainCfg::BF16_FP32;
    print!("{}", report::table1_area_power(chain, 128, 128).render());

    // Per-block attribution (the paper's explanation of the overhead).
    let area = AreaModel::new(chain);
    let b = area.pe_area(PipelineKind::Baseline3b);
    let s = area.pe_area(PipelineKind::Skewed);
    let mut t = Table::new(&["block", "baseline(GE)", "skewed(GE)", "delta"]).numeric();
    for (name, bb, ss) in [
        ("multiplier", b.mult, s.mult),
        ("exp-compute", b.exp, s.exp),
        ("shifters", b.shifters, s.shifters),
        ("adder", b.add, s.add),
        ("lza", b.lza, s.lza),
        ("fix-logic", b.fix, s.fix),
        ("registers", b.regs, s.regs),
        ("misc", b.misc, s.misc),
    ] {
        t.row(&[
            name.to_string(),
            fnum(bb, 0),
            fnum(ss, 0),
            if bb > 0.0 { pct(ss / bb - 1.0) } else { format!("+{ss:.0} GE") },
        ]);
    }
    t.row(&[
        "TOTAL".into(),
        fnum(b.total(), 0),
        fnum(s.total(), 0),
        pct(s.total() / b.total() - 1.0),
    ]);
    println!("\nper-block attribution:\n{}", t.render());

    // Power across the activity range (paper: +7% "on average").
    let power = PowerModel::new(area);
    let mut p = Table::new(&["activity", "base(mW)", "skew(mW)", "overhead"]).numeric();
    for alpha in [0.1, 0.3, 0.5, 0.7, 0.9, 1.0] {
        p.row(&[
            format!("{alpha:.1}"),
            fnum(power.array_power(PipelineKind::Baseline3b, 128, 128, alpha) / 1e3, 1),
            fnum(power.array_power(PipelineKind::Skewed, 128, 128, alpha) / 1e3, 1),
            pct(power.overhead(128, 128, alpha)),
        ]);
    }
    println!("power vs activity (128x128 @ 1 GHz):\n{}", p.render());
    println!(
        "paper: +9% area, +7% power | reproduced: {} area, {} power@0.7",
        pct(area.overhead(128, 128)),
        pct(power.overhead(128, 128, 0.7))
    );
}
