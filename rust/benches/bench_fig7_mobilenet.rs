//! E1 — regenerates the paper's **Fig. 7**: per-layer energy of
//! MobileNetV1 on the 128×128 bf16→fp32 array, baseline (Fig. 3b) vs
//! skewed, plus the emitted series as CSV for plotting.
//!
//! ```text
//! cargo bench --bench bench_fig7_mobilenet
//! ```

use skewsa::arith::fma::ChainCfg;
use skewsa::energy::{AreaModel, PowerModel};
use skewsa::report;
use skewsa::timing::model::TimingConfig;
use skewsa::util::bench::{measure, with_units};

fn main() {
    let tcfg = TimingConfig::PAPER;
    let pmodel = PowerModel::new(AreaModel::new(ChainCfg::BF16_FP32));

    let rep = report::fig7_mobilenet(&tcfg, &pmodel);
    print!("{}", rep.render());
    let tot = rep.totals.unwrap();
    println!(
        "paper: -16% latency / -8% energy | reproduced: {:+.1}% / {:+.1}%",
        tot.latency_delta() * 100.0,
        tot.energy_delta() * 100.0
    );

    // Wall-clock of the full figure evaluation (the analytic path the
    // coordinator uses for whole-CNN runs — perf-tracked in §Perf).
    let m = measure("fig7:full-evaluation", 2, 20, 5, || {
        let r = report::fig7_mobilenet(&tcfg, &pmodel);
        std::hint::black_box(r.table.n_rows());
    });
    println!("{}", with_units(m, 28.0, "layers").report());

    let csv = rep.table.to_csv();
    std::fs::create_dir_all("target/reports").ok();
    std::fs::write("target/reports/fig7_mobilenet.csv", &csv).ok();
    println!("series written to target/reports/fig7_mobilenet.csv");
}
