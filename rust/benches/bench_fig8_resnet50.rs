//! E2 — regenerates the paper's **Fig. 8**: per-layer energy of
//! ResNet-50, baseline vs skewed, with the CSV series.
//!
//! ```text
//! cargo bench --bench bench_fig8_resnet50
//! ```

use skewsa::arith::fma::ChainCfg;
use skewsa::energy::{AreaModel, PowerModel};
use skewsa::report;
use skewsa::timing::model::TimingConfig;
use skewsa::util::bench::{measure, with_units};

fn main() {
    let tcfg = TimingConfig::PAPER;
    let pmodel = PowerModel::new(AreaModel::new(ChainCfg::BF16_FP32));

    let rep = report::fig8_resnet50(&tcfg, &pmodel);
    print!("{}", rep.render());
    let tot = rep.totals.unwrap();
    println!(
        "paper: -21% latency / -11% energy | reproduced: {:+.1}% / {:+.1}%",
        tot.latency_delta() * 100.0,
        tot.energy_delta() * 100.0
    );

    let m = measure("fig8:full-evaluation", 2, 20, 5, || {
        let r = report::fig8_resnet50(&tcfg, &pmodel);
        std::hint::black_box(r.table.n_rows());
    });
    println!("{}", with_units(m, 54.0, "layers").report());

    std::fs::create_dir_all("target/reports").ok();
    std::fs::write("target/reports/fig8_resnet50.csv", rep.table.to_csv()).ok();
    println!("series written to target/reports/fig8_resnet50.csv");
}
