//! E5 — architecture ablation across every registered pipeline
//! organisation (see `skewsa pipelines`) and the four reduced-precision
//! formats:
//! stage delays / clock feasibility, column latency (cycle-accurate),
//! and the design-choice ablations DESIGN.md calls out (double-buffered
//! weight reloads, chain window width).
//!
//! ```text
//! cargo bench --bench bench_ablation_pipelines
//! ```

use skewsa::arith::fma::ChainCfg;
use skewsa::arith::format::FpFormat;
use skewsa::pe::PipelineKind;
use skewsa::report;
use skewsa::sa::column::ColumnSim;
use skewsa::sa::tile::GemmShape;
use skewsa::timing::model::{gemm_timing, TimingConfig};
use skewsa::util::rng::Rng;
use skewsa::util::table::{pct, Table};

fn main() {
    let tcfg = TimingConfig::PAPER;
    print!("{}", report::ablation_pipelines(ChainCfg::BF16_FP32, &tcfg).render());

    // Cycle-accurate column latency across formats and kinds.
    let mut rng = Rng::new(0xab1a);
    let mut t = Table::new(&["chain", "kind", "R", "col-cycles(M=4)", "vs-baseline"]).numeric();
    for (inf, outf) in [
        (FpFormat::BF16, FpFormat::FP32),
        (FpFormat::FP16, FpFormat::FP32),
        (FpFormat::FP8E4M3, FpFormat::FP16),
        (FpFormat::FP8E5M2, FpFormat::FP16),
    ] {
        let chain = ChainCfg::new(inf, outf);
        let r = 64;
        let mut base_cycles = 0u64;
        for kind in [
            PipelineKind::Baseline3b,
            PipelineKind::Skewed,
            PipelineKind::Transparent,
            PipelineKind::Deep3,
        ] {
            let weights: Vec<u64> = (0..r)
                .map(|_| loop {
                    let b = rng.bits(inf.width());
                    if inf.decode(b).is_finite() {
                        break b;
                    }
                })
                .collect();
            let a: Vec<Vec<u64>> = (0..4)
                .map(|_| {
                    (0..r)
                        .map(|_| loop {
                            let b = rng.bits(inf.width());
                            if inf.decode(b).is_finite() {
                                break b;
                            }
                        })
                        .collect()
                })
                .collect();
            let mut sim = ColumnSim::new(chain, kind, &weights, a);
            sim.run(100_000).unwrap();
            if kind == PipelineKind::Baseline3b {
                base_cycles = sim.cycles();
            }
            t.row(&[
                format!("{}->{}", inf.name, outf.name),
                kind.name().to_string(),
                r.to_string(),
                sim.cycles().to_string(),
                pct(sim.cycles() as f64 / base_cycles as f64 - 1.0),
            ]);
        }
    }
    println!("cycle-accurate column latency across formats:\n{}", t.render());

    // Ablation: double-buffered vs serialized weight reloads.
    let mut t2 = Table::new(&["reloads", "kind", "MobileNet-late-layer-cycles"]).numeric();
    for db in [true, false] {
        let cfg = TimingConfig { double_buffer: db, ..tcfg };
        for kind in [PipelineKind::Baseline3b, PipelineKind::Skewed] {
            let c = gemm_timing(&cfg, kind, GemmShape::new(49, 512, 512)).cycles;
            t2.row(&[
                if db { "double-buffered" } else { "serialized" }.to_string(),
                kind.name().to_string(),
                c.to_string(),
            ]);
        }
    }
    println!("weight-reload ablation (M=49, K=N=512):\n{}", t2.render());

    // Ablation: accumulator window width vs numeric agreement with the
    // exact chain (design choice behind ChainCfg::BF16_FP32.window).
    use skewsa::arith::accum::ColumnOracle;
    use skewsa::arith::softfloat::ExactChain;
    let mut t3 = Table::new(&["window", "exact-match-rate(K=128)"]).numeric();
    // 27 = out.man_bits + 4 is the structural minimum (rounding headroom).
    for window in [27u32, 28, 32, 40, 50] {
        let chain = ChainCfg { in_fmt: FpFormat::BF16, out_fmt: FpFormat::FP32, window };
        let mut matches = 0;
        let total = 300;
        let mut rng = Rng::new(7);
        for _ in 0..total {
            let mut o = ColumnOracle::new(chain);
            let mut e = ExactChain::new();
            for _ in 0..128 {
                let a = FpFormat::BF16.from_f64(rng.normal_scaled(0.0, 1.0));
                let w = FpFormat::BF16.from_f64(rng.normal_scaled(0.0, 0.2));
                o.mac(a, w);
                e.mac(FpFormat::BF16, a, w);
            }
            if o.result() == e.result(FpFormat::FP32) {
                matches += 1;
            }
        }
        t3.row(&[window.to_string(), format!("{matches}/{total}")]);
    }
    println!("accumulator-window ablation:\n{}", t3.render());
}
