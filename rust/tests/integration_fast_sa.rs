//! Fast-simulator integration: the allocation-free banded simulator must
//! agree with the dense reference loop, the value oracle, and the
//! closed-form timing model — at paper scale (128×128), both pipeline
//! kinds, serial and column-parallel.

use skewsa::arith::fma::ChainCfg;
use skewsa::arith::format::FpFormat;
use skewsa::pe::PipelineKind;
use skewsa::sa::array::ArraySim;
use skewsa::sa::dataflow::WsSchedule;
use skewsa::sa::fast::FastArraySim;
use skewsa::sa::tile::GemmShape;
use skewsa::util::prop::{Gen, Prop};
use skewsa::workloads::gemm::GemmData;

const CFG: ChainCfg = ChainCfg::BF16_FP32;

/// The ISSUE 1 headline case: one full paper-scale 128×128 weight tile,
/// simulated directly, bit-exact vs the oracle and cycle-exact vs the
/// closed-form schedule, for both pipeline kinds.
#[test]
fn paper_scale_128x128_bit_exact_and_on_schedule() {
    let (m, r, c) = (5usize, 128usize, 128usize);
    let data = GemmData::cnn_like(GemmShape::new(m, r, c), FpFormat::BF16, 0x128_128);
    let want = FastArraySim::oracle_bits(&CFG, &data.w, &data.a);
    let mut cycles = Vec::new();
    for kind in [PipelineKind::Baseline3b, PipelineKind::Skewed] {
        let sched = WsSchedule::new(kind, r, c, m);
        let mut sim = FastArraySim::new(CFG, kind, &data.w, &data.a);
        sim.run(sched.total_cycles() + 16).unwrap();
        assert_eq!(sim.result_bits(), want, "{kind}");
        assert_eq!(sim.cycles(), sched.total_cycles(), "{kind}");
        assert_eq!(sim.stalls(), 0, "{kind}");
        for col in 0..c {
            for mm in 0..m {
                assert_eq!(sim.output_cycle(mm, col), sched.output_cycle(col, mm), "{kind}");
            }
        }
        cycles.push(sim.cycles());
    }
    assert_eq!(cycles[0] - cycles[1], 126, "R−2 saving at R=128");
}

/// Column-parallel strips produce results identical to the serial run at
/// paper scale (adversarial data stresses the numeric paths too).
#[test]
fn paper_scale_parallel_matches_serial() {
    let data = GemmData::adversarial(GemmShape::new(4, 128, 128), FpFormat::BF16, 0xbead);
    for kind in [PipelineKind::Baseline3b, PipelineKind::Skewed] {
        let mut serial = FastArraySim::new(CFG, kind, &data.w, &data.a);
        serial.run(1_000_000).unwrap();
        let mut par = FastArraySim::new(CFG, kind, &data.w, &data.a);
        par.run_parallel(1_000_000, 8).unwrap();
        assert_eq!(par.result_bits(), serial.result_bits(), "{kind}");
        assert_eq!(par.cycles(), serial.cycles(), "{kind}");
        assert_eq!(par.stalls(), serial.stalls(), "{kind}");
        assert!(par.latency_matches_schedule(), "{kind}");
    }
}

/// Regression: the banded iteration reports the same `stalls` count (and
/// bits, cycles, and merged activity) as the dense loop, across shapes
/// where the band is respectively narrow (M ≪ R), wide (M ≫ R), and
/// degenerate (single PE).
#[test]
fn banded_matches_dense_loop_stalls_and_activity() {
    for kind in [PipelineKind::Baseline3b, PipelineKind::Skewed] {
        for &(m, r, c) in &[
            (1usize, 1usize, 1usize),
            (2, 48, 5),  // narrow band: deep array, short stream
            (40, 4, 6),  // wide band: steady state dominates
            (7, 16, 16), // square-ish
        ] {
            let data = GemmData::cnn_like(GemmShape::new(m, r, c), FpFormat::BF16, 77);
            let mut dense = ArraySim::new(CFG, kind, &data.w, data.a.clone());
            dense.run(1_000_000).unwrap();
            let mut fast = FastArraySim::new(CFG, kind, &data.w, &data.a);
            fast.run(1_000_000).unwrap();
            assert_eq!(fast.stalls(), dense.stalls, "{kind} M={m} R={r} C={c}");
            assert_eq!(fast.result_bits(), dense.result_bits(), "{kind} M={m} R={r} C={c}");
            assert_eq!(fast.cycles(), dense.cycles(), "{kind} M={m} R={r} C={c}");
            assert_eq!(fast.activity(), dense.activity(), "{kind} M={m} R={r} C={c}");
        }
    }
}

/// Property: on random dimensions and CNN-statistics data, the fast
/// simulator is bit- and cycle-identical to the dense loop and lands on
/// the closed-form schedule.
#[test]
fn prop_fast_matches_dense_and_schedule() {
    Prop::new("fast-vs-dense", 30).run(|g: &mut Gen| {
        let (m, r, c) = (g.usize_in(1, 20), g.usize_in(1, 24), g.usize_in(1, 10));
        let kind = *g.choose(&PipelineKind::ALL);
        let data = GemmData::cnn_like(GemmShape::new(m, r, c), FpFormat::BF16, g.bits(32));
        let mut dense = ArraySim::new(CFG, kind, &data.w, data.a.clone());
        if dense.run(1_000_000).is_err() {
            g.assert("dense sim must not violate its own schedule", false);
            return;
        }
        let mut fast = FastArraySim::new(CFG, kind, &data.w, &data.a);
        if fast.run(1_000_000).is_err() {
            g.assert("fast sim must not violate its own schedule", false);
            return;
        }
        g.assert_eq("bits", fast.result_bits(), dense.result_bits());
        g.assert_eq("cycles", fast.cycles(), dense.cycles());
        g.assert_eq("stalls", fast.stalls(), dense.stalls);
        g.assert("on schedule", fast.latency_matches_schedule());
    });
}
