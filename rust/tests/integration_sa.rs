//! Simulator integration: the cycle-accurate column/array sims, the
//! closed-form timing model, and the value oracles must all agree.

use skewsa::arith::accum::ColumnOracle;
use skewsa::arith::fma::ChainCfg;
use skewsa::arith::format::FpFormat;
use skewsa::pe::PipelineKind;
use skewsa::sa::array::ArraySim;
use skewsa::sa::column::ColumnSim;
use skewsa::sa::dataflow::WsSchedule;
use skewsa::sa::tile::GemmShape;
use skewsa::timing::model::{gemm_timing, TileTiming, TimingConfig};
use skewsa::util::rng::Rng;
use skewsa::workloads::gemm::GemmData;

const CFG: ChainCfg = ChainCfg::BF16_FP32;

/// The closed-form tile latency equals the cycle-accurate array run,
/// swept over (M, R, C) × every registered pipeline organisation.
#[test]
fn timing_model_equals_simulator_sweep() {
    let mut rng = Rng::new(0x715);
    for kind in PipelineKind::ALL {
        for &(m, r, c) in &[
            (1usize, 1usize, 1usize),
            (1, 16, 1),
            (7, 3, 5),
            (16, 8, 8),
            (33, 12, 7),
            (4, 24, 24),
            (64, 4, 2),
        ] {
            let data = GemmData::integer_valued(GemmShape::new(m, r, c), FpFormat::BF16, rng.next_u64());
            let mut sim = ArraySim::new(CFG, kind, &data.w, data.a.clone());
            sim.run(1_000_000).unwrap();
            let model = TileTiming::compute_cycles(kind, m, r, c);
            assert_eq!(sim.cycles(), model, "{kind} M={m} R={r} C={c}");
        }
    }
}

/// Column sim composes into the array sim: column c of the array equals
/// a standalone column on the same weights (values and cycle offsets).
#[test]
fn array_is_composition_of_columns() {
    let mut rng = Rng::new(0xc0c0);
    let (m, r, c) = (6usize, 10usize, 4usize);
    let data = GemmData::integer_valued(GemmShape::new(m, r, c), FpFormat::BF16, rng.next_u64());
    for kind in [PipelineKind::Baseline3b, PipelineKind::Skewed] {
        let mut arr = ArraySim::new(CFG, kind, &data.w, data.a.clone());
        arr.run(100_000).unwrap();
        let y = arr.result_bits();
        for col in 0..c {
            let weights: Vec<u64> = (0..r).map(|k| data.w[k][col]).collect();
            let mut colsim = ColumnSim::new(CFG, kind, &weights, data.a.clone());
            colsim.run(100_000).unwrap();
            for out in colsim.outputs() {
                assert_eq!(out.bits, y[out.m][col], "{kind} col={col} m={}", out.m);
                // Array output lands exactly `col` cycles later (East skew).
                let arr_out = arr
                    .outputs()
                    .iter()
                    .find(|o| o.m == out.m && o.col == col)
                    .unwrap();
                assert_eq!(arr_out.cycle, out.cycle + col as u64, "{kind} col={col}");
            }
        }
    }
}

/// Both pipeline kinds produce bit-identical matrices on CNN-statistics
/// data (the paper's functional claim at array scale).
#[test]
fn kinds_bit_identical_on_cnn_data() {
    for seed in 0..5 {
        let data = GemmData::cnn_like(GemmShape::new(12, 24, 16), FpFormat::BF16, seed);
        let mut b = ArraySim::new(CFG, PipelineKind::Baseline3b, &data.w, data.a.clone());
        let mut s = ArraySim::new(CFG, PipelineKind::Skewed, &data.w, data.a.clone());
        b.run(1_000_000).unwrap();
        s.run(1_000_000).unwrap();
        assert_eq!(b.result_bits(), s.result_bits(), "seed {seed}");
    }
}

/// The 128-deep column (paper's array depth) is bit-exact vs the oracle
/// for both kinds, on adversarial data.
#[test]
fn depth_128_column_bit_exact_adversarial() {
    let data = GemmData::adversarial(GemmShape::new(3, 128, 1), FpFormat::BF16, 0xad4e);
    let weights: Vec<u64> = (0..128).map(|k| data.w[k][0]).collect();
    let want: Vec<u64> = data
        .a
        .iter()
        .map(|row| {
            let mut o = ColumnOracle::new(CFG);
            for (k, &w) in weights.iter().enumerate() {
                o.mac(row[k], w);
            }
            o.result()
        })
        .collect();
    for kind in PipelineKind::ALL {
        let mut sim = ColumnSim::new(CFG, kind, &weights, data.a.clone());
        sim.run(100_000).unwrap();
        let got: Vec<u64> = sim.outputs().iter().map(|o| o.bits).collect();
        assert_eq!(got, want, "{kind}");
    }
}

/// Paper-scale sanity: one full 128×128 tile, cycle-accurate, both
/// kinds; latency matches the model and the R−2 saving appears.
#[test]
fn paper_scale_tile_cycle_accurate() {
    let (m, r, c) = (4usize, 128usize, 128usize);
    let data = GemmData::cnn_like(GemmShape::new(m, r, c), FpFormat::BF16, 0x128128);
    let mut cycles = Vec::new();
    let want = ArraySim::oracle_bits(&CFG, &data.w, &data.a);
    for kind in [PipelineKind::Baseline3b, PipelineKind::Skewed] {
        let mut sim = ArraySim::new(CFG, kind, &data.w, data.a.clone());
        sim.run(10_000_000).unwrap();
        assert_eq!(sim.result_bits(), want, "{kind}");
        assert_eq!(sim.cycles(), TileTiming::compute_cycles(kind, m, r, c), "{kind}");
        cycles.push(sim.cycles());
    }
    assert_eq!(cycles[0] - cycles[1], 126, "R−2 saving at R=128");
}

/// The layer-level model composes tile latencies consistently with a
/// tile-by-tile simulation of a multi-tile GEMM.
#[test]
fn layer_model_consistent_with_per_tile_sim() {
    let tcfg = TimingConfig { rows: 8, cols: 8, clock_ghz: 1.0, double_buffer: true };
    let shape = GemmShape::new(5, 20, 12); // 3 K-tiles × 2 N-tiles
    let data = GemmData::integer_valued(shape, FpFormat::BF16, 3);
    for kind in [PipelineKind::Baseline3b, PipelineKind::Skewed] {
        let lt = gemm_timing(&tcfg, kind, shape);
        // Sum per-tile sim latencies + the first preload (the others
        // overlap under double buffering).
        let plan = skewsa::sa::tile::TilePlan::new(shape, 8, 8);
        let mut sim_total = 8u64; // first preload
        for t in &plan.tiles {
            let w_slab = plan.weight_slab(&data.w, t);
            let a_slab = plan.activation_slab(&data.a, t);
            // Pad the weight slab to the full 8 rows (the array streams
            // zeros through unused rows, as the timing model assumes).
            let mut w_full = w_slab;
            while w_full.len() < 8 {
                w_full.push(vec![0u64; t.n_len]);
            }
            let mut a_full: Vec<Vec<u64>> = a_slab;
            for row in &mut a_full {
                while row.len() < 8 {
                    row.push(0);
                }
            }
            let mut sim = ArraySim::new(CFG, kind, &w_full, a_full);
            sim.run(1_000_000).unwrap();
            sim_total += sim.cycles();
        }
        assert_eq!(lt.cycles, sim_total, "{kind}");
    }
}

/// Input staircase obeys the chain spacing: feeding a baseline array
/// with data timed for the skewed staircase cannot go faster than the
/// baseline schedule allows (outputs still land on baseline cycles).
#[test]
fn baseline_cannot_consume_skewed_staircase_early() {
    let data = GemmData::integer_valued(GemmShape::new(4, 6, 1), FpFormat::BF16, 9);
    let weights: Vec<u64> = (0..6).map(|k| data.w[k][0]).collect();
    let mut sim = ColumnSim::new(CFG, PipelineKind::Baseline3b, &weights, data.a.clone());
    sim.run(10_000).unwrap();
    let sched = WsSchedule::new(PipelineKind::Baseline3b, 6, 1, 4);
    for o in sim.outputs() {
        assert_eq!(o.cycle, sched.output_cycle(0, o.m));
    }
}
