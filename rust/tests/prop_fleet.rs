//! Fleet-DES property pins: bit-identical replay at the 1000-shard /
//! 100k-request scale the threaded stack cannot reach, event-queue
//! ordering under adversarial push patterns, sampler statistics, exact
//! trace replay, and autoscaler bounds (DESIGN.md §18).

use skewsa::config::{FleetConfig, RunConfig};
use skewsa::fleet::{
    exp_gap, ArrivalSpec, Event, EventQueue, FleetSim, ModelShape, ReqStatus, TenantSpec, TraceReq,
};
use skewsa::pe::PipelineKind;
use skewsa::serve::DeadlineClass;
use skewsa::util::rng::Rng;

/// The ISSUE 8 acceptance run: 1000 shards, >100k Poisson requests,
/// finishing in seconds and replaying bit-for-bit.  The fingerprint
/// folds every request's id/status/shard/submit/done/batch/service, so
/// equality here is equality of the entire fleet history.
#[test]
fn thousand_shard_hundred_k_request_run_is_bit_identical() {
    let run = RunConfig::small();
    let fcfg = FleetConfig {
        shards: 1000,
        min_shards: 1000,
        max_shards: 1000,
        horizon: 2_400_000,
        autoscale_interval: 0,
        models: vec![ModelShape { k: 24, n: 16 }, ModelShape { k: 32, n: 8 }],
        tenants: vec![TenantSpec::poisson("load", 20.0)],
        ..FleetConfig::default()
    };
    let r1 = FleetSim::simulate(&run, &fcfg);
    let r2 = FleetSim::simulate(&run, &fcfg);
    assert!(r1.submitted >= 100_000, "want >=100k requests, got {}", r1.submitted);
    assert_eq!(r1.fingerprint, r2.fingerprint, "same seed, same history");
    assert_eq!(r1.submitted, r2.submitted);
    assert_eq!(r1.served, r2.served);
    assert_eq!(r1.wall_cycles, r2.wall_cycles);
    assert!(r1.accounting_balanced(), "served + shed + failed == submitted");
    assert!(r1.served > 0);
    // A 1000-shard round-robin fleet under an open Poisson load uses
    // far more than one shard.
    let shards: std::collections::BTreeSet<usize> =
        r1.records.iter().filter_map(|rec| rec.shard).collect();
    assert!(shards.len() > 100, "expected wide shard spread, got {}", shards.len());
}

/// The event queue pops strictly by `(time, push order)` no matter how
/// adversarially times are pushed — the root of the whole simulator's
/// determinism.
#[test]
fn event_queue_orders_by_time_then_push_order() {
    let mut q = EventQueue::new();
    let mut rng = Rng::new(0xE4E7);
    let n = 500u64;
    for i in 0..n {
        // batch_seq doubles as the push index so ties are checkable.
        q.push(rng.below(64), Event::WindowClose { batch_seq: i });
    }
    assert_eq!(q.pushed(), n);
    assert_eq!(q.len(), n as usize);
    let mut last = (0u64, 0u64);
    let mut popped = 0u64;
    while let Some((t, ev)) = q.pop() {
        let Event::WindowClose { batch_seq } = ev else { panic!("unexpected event") };
        assert!(t >= last.0, "time went backwards: {t} after {}", last.0);
        if popped > 0 && t == last.0 {
            assert!(batch_seq > last.1, "FIFO tie-break violated at t = {t}");
        }
        assert_eq!(q.now(), t);
        last = (t, batch_seq);
        popped += 1;
    }
    assert_eq!(popped, n);
    assert!(q.is_empty());
}

/// The integer exponential sampler's empirical mean converges on the
/// configured mean gap (law of large numbers over a fixed seed).
#[test]
fn exp_gap_empirical_mean_matches_configured_mean() {
    let mut rng = Rng::new(42);
    let n = 20_000u64;
    let mean_gap = 400.0;
    let sum: u64 = (0..n).map(|_| exp_gap(&mut rng, mean_gap)).sum();
    let mean = sum as f64 / n as f64;
    let err = (mean - mean_gap).abs() / mean_gap;
    // The Python port of the same sampler measures 401.20 for this
    // seed (0.3% off) — 1% headroom keeps the pin tight but stable.
    assert!(err < 0.01, "empirical mean {mean:.2} strays {:.1}% from {mean_gap}", err * 100.0);
    // And the sampler never returns a zero-cycle gap (time must move).
    let mut r2 = Rng::new(7);
    assert!((0..10_000).all(|_| exp_gap(&mut r2, 0.001) >= 1));
}

/// Trace replay is exact: every request's submit cycle equals its
/// scripted `at`, in trace order.
#[test]
fn trace_replay_preserves_exact_timestamps() {
    let ats = [0u64, 17, 17, 404, 90_000];
    let requests: Vec<TraceReq> = ats
        .iter()
        .map(|&at| TraceReq {
            at,
            model: 0,
            rows: 2,
            kind: PipelineKind::Skewed,
            class: DeadlineClass::Batch,
        })
        .collect();
    let fcfg = FleetConfig {
        shards: 2,
        min_shards: 2,
        max_shards: 2,
        horizon: 100_000,
        autoscale_interval: 0,
        models: vec![ModelShape { k: 24, n: 16 }],
        tenants: vec![TenantSpec {
            name: "replay".into(),
            arrival: ArrivalSpec::Trace { requests },
            bucket_capacity: 0,
            bucket_refill_cycles: 0,
            kinds: vec![PipelineKind::Skewed],
            interactive_fraction: 0.0,
            min_rows: 1,
            max_rows: 8,
        }],
        ..FleetConfig::default()
    };
    let r = FleetSim::simulate(&RunConfig::small(), &fcfg);
    assert_eq!(r.submitted, ats.len() as u64);
    assert_eq!(r.records.len(), ats.len());
    for (rec, &at) in r.records.iter().zip(&ats) {
        assert_eq!(rec.submit, at, "request {} submit cycle", rec.id);
        assert_eq!(rec.status, ReqStatus::Served);
        assert!(rec.done > rec.submit);
    }
    assert!(r.accounting_balanced());
}

/// The autoscaler never leaves `[min_shards, max_shards]`, never grows
/// by more than `autoscale_step` per tick, never shrinks by more than
/// one, and the run's final active count is the last decision's.
#[test]
fn autoscaler_stays_within_bounds_and_step_limits() {
    let fcfg = FleetConfig {
        shards: 2,
        min_shards: 1,
        max_shards: 6,
        queue_cap: 256,
        shed_watermark: 0,
        horizon: 600_000,
        autoscale_interval: 10_000,
        autoscale_step: 2,
        slo_p99: 2_000,
        models: vec![ModelShape { k: 64, n: 32 }],
        tenants: vec![TenantSpec::poisson("pressure", 120.0)],
        ..FleetConfig::default()
    };
    let r = FleetSim::simulate(&RunConfig::small(), &fcfg);
    assert!(!r.autoscale.is_empty(), "interval > 0 must produce evaluations");
    let mut active = fcfg.shards;
    for p in &r.autoscale {
        assert!(p.active >= fcfg.min_shards && p.active <= fcfg.max_shards, "t={}", p.t);
        if p.active > active {
            assert!(p.active - active <= fcfg.autoscale_step, "grow step at t={}", p.t);
        } else {
            assert!(active - p.active <= 1, "shrink step at t={}", p.t);
        }
        active = p.active;
    }
    assert_eq!(r.final_active, active, "final active mirrors the last decision");
    assert!(r.accounting_balanced());
}
