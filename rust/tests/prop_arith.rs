//! Property suite over the arithmetic substrate (DESIGN.md §9):
//! skewed ≡ baseline bit-identity, softfloat exactness, rounding and
//! LZA invariants — random plus adversarially-structured inputs.

use skewsa::arith::accum::{ColumnOracle, RoundingUnit};
use skewsa::arith::fma::{BaselineFmaPath, ChainCfg, ChainDatapath, PsumSignal, SkewedFmaPath};
use skewsa::arith::format::{FpClass, FpFormat};
use skewsa::arith::lza::{lza_anticipate, lzc};
use skewsa::arith::softfloat::{pow2, ExactChain};
use skewsa::util::prop::{Gen, Prop};

const CFG: ChainCfg = ChainCfg::BF16_FP32;

fn random_finite_bf16(g: &mut Gen) -> u64 {
    loop {
        let bits = g.bits(16);
        if FpFormat::BF16.decode(bits).is_finite() {
            return bits;
        }
    }
}

fn canon(s: &PsumSignal) -> (bool, i32, u64, bool) {
    if s.val.sig == 0 {
        return (false, 0, 0, s.val.sticky);
    }
    let l = lzc(s.val.sig, CFG.window);
    (s.val.sign, s.val.exp_top - l as i32, s.val.sig << l, s.val.sticky)
}

/// THE paper property: the skewed datapath's speculation + fix is exact,
/// so chained results are bit-identical to the baseline — over random
/// chains of arbitrary finite bf16 values (subnormals included).
#[test]
fn prop_skewed_equals_baseline_random_chains() {
    Prop::new("skew-eq-base", 400).run(|g| {
        let len = g.usize_in(1, 96);
        let mut b = PsumSignal::zero(&CFG);
        let mut s = PsumSignal::zero(&CFG);
        for _ in 0..len {
            let a = random_finite_bf16(g);
            let w = random_finite_bf16(g);
            b = BaselineFmaPath.step(&CFG, &b, a, w);
            s = SkewedFmaPath.step(&CFG, &s, a, w);
        }
        g.assert_eq("canonical signals equal", canon(&b), canon(&s));
        let ru = RoundingUnit::new(CFG);
        g.assert_eq("rounded bits equal", ru.round(&b), ru.round(&s));
    });
}

/// Same property under adversarial cancellation: pairs engineered to
/// cancel to a few ulps, forcing large LZA counts and deep speculation
/// corrections.
#[test]
fn prop_skewed_equals_baseline_cancellation() {
    Prop::new("skew-eq-base-cancel", 300).run(|g| {
        let f = FpFormat::BF16;
        let len = g.usize_in(2, 48);
        let mut b = PsumSignal::zero(&CFG);
        let mut s = PsumSignal::zero(&CFG);
        let mut last: Option<(u64, u64)> = None;
        for i in 0..len {
            let (a, w) = if i % 2 == 1 && g.chance(0.8) {
                // Near-perfect cancellation of the previous product.
                let (pa, pw) = last.unwrap();
                let tweak = if g.chance(0.5) { 0 } else { 1 };
                (pa ^ (1 << 15), pw ^ tweak)
            } else {
                (random_finite_bf16(g), random_finite_bf16(g))
            };
            last = Some((a, w));
            if !f.decode(a).is_finite() || !f.decode(w).is_finite() {
                continue;
            }
            b = BaselineFmaPath.step(&CFG, &b, a, w);
            s = SkewedFmaPath.step(&CFG, &s, a, w);
        }
        g.assert_eq("cancel chains equal", canon(&b), canon(&s));
    });
}

/// The skewed ê/L bundle is self-consistent: L always equals the true
/// leading-zero count of the forwarded raw sum, and ê−L equals the
/// baseline's corrected exponent.
#[test]
fn prop_speculative_bundle_consistent() {
    Prop::new("spec-bundle", 300).run(|g| {
        let len = g.usize_in(1, 32);
        let mut b = PsumSignal::zero(&CFG);
        let mut s = PsumSignal::zero(&CFG);
        for _ in 0..len {
            let a = random_finite_bf16(g);
            let w = random_finite_bf16(g);
            b = BaselineFmaPath.step(&CFG, &b, a, w);
            s = SkewedFmaPath.step(&CFG, &s, a, w);
            if s.val.sig != 0 {
                g.assert_eq("L == lzc(raw)", s.lza, lzc(s.val.sig, CFG.window));
                g.assert_eq("ê−L == corrected", s.corrected_top(), b.val.exp_top);
            }
        }
    });
}

/// Column oracle == exact chain when inputs are integer-valued (no
/// window loss), for any column depth.
#[test]
fn prop_oracle_equals_exact_on_integers() {
    Prop::new("oracle-exact-int", 250).run(|g| {
        let len = g.usize_in(1, 128);
        let mut o = ColumnOracle::new(CFG);
        let mut e = ExactChain::new();
        for _ in 0..len {
            let a = FpFormat::BF16.from_f64(g.i64_in(-64, 64) as f64);
            let w = FpFormat::BF16.from_f64(g.i64_in(-16, 16) as f64);
            o.mac(a, w);
            e.mac(FpFormat::BF16, a, w);
        }
        g.assert_eq("rounded results equal", o.result(), e.result(FpFormat::FP32));
    });
}

/// Softfloat format round-trip: decode∘encode is the identity on every
/// non-NaN pattern of every reduced format.
#[test]
fn prop_format_roundtrip() {
    Prop::new("format-roundtrip", 400).run(|g| {
        let fmt = *g.choose(&[
            FpFormat::BF16,
            FpFormat::FP16,
            FpFormat::FP8E4M3,
            FpFormat::FP8E5M2,
        ]);
        let bits = g.bits(fmt.width());
        let x = fmt.to_f64(bits);
        if x.is_nan() {
            g.assert("nan classifies", fmt.decode(bits).class == FpClass::Nan);
        } else {
            g.assert_eq("roundtrip", fmt.from_f64(x), bits);
        }
    });
}

/// LZA anticipator invariant: within one of the exact count, both
/// effective operations, across widths.
#[test]
fn prop_lza_within_one() {
    Prop::new("lza-within-one", 500).run(|g| {
        let width = g.usize_in(4, 48) as u32;
        let a = g.bits(width);
        let b = g.bits(width);
        let sum = a + b;
        if sum >> width == 0 && sum != 0 {
            let ant = lza_anticipate(a, b, width, false);
            g.assert("add ±1", ant.abs_diff(lzc(sum, width)) <= 1);
        }
        let (hi, lo) = if a >= b { (a, b) } else { (b, a) };
        if hi != lo {
            let ant = lza_anticipate(hi, lo, width, true);
            g.assert("sub ±1", ant.abs_diff(lzc(hi - lo, width)) <= 1);
        }
    });
}

/// Rounding unit: the final result is within half an output ulp of the
/// exact chain value (single-rounding bound), whenever no window loss
/// occurred (sticky clear).
#[test]
fn prop_single_rounding_bound() {
    Prop::new("round-half-ulp", 250).run(|g| {
        let len = g.usize_in(1, 24);
        let mut o = ColumnOracle::new(CFG);
        let mut e = ExactChain::new();
        for _ in 0..len {
            let a = FpFormat::BF16.from_f64(g.normal(0.0, 4.0));
            let w = FpFormat::BF16.from_f64(g.normal(0.0, 1.0));
            o.mac(a, w);
            e.mac(FpFormat::BF16, a, w);
        }
        if o.signal().val.sticky {
            return; // window loss: the bound below doesn't apply
        }
        let got = FpFormat::FP32.to_f64(o.result());
        let want = e.value_f64();
        let ulp = pow2((want.abs().log2().floor() as i32 - 23).clamp(-149, 127));
        g.assert(
            "within half ulp",
            (got - want).abs() <= 0.5 * ulp + f64::EPSILON * want.abs(),
        );
    });
}

/// Chain order sensitivity: permuting terms may change low bits but the
/// exact reference catches gross errors — sim result always within 2
/// fp32 ulps of the exact sum for CNN-like data.
#[test]
fn prop_chain_close_to_exact_cnn_data() {
    Prop::new("chain-close-exact", 200).run(|g| {
        let len = g.usize_in(1, 128);
        let mut o = ColumnOracle::new(CFG);
        let mut e = ExactChain::new();
        for _ in 0..len {
            let a = FpFormat::BF16.from_f64(g.normal(0.0, 1.0).max(0.0));
            let w = FpFormat::BF16.from_f64(g.normal(0.0, 0.2));
            o.mac(a, w);
            e.mac(FpFormat::BF16, a, w);
        }
        let got = FpFormat::FP32.to_f64(o.result()) ;
        let want = e.value_f64();
        let scale = want.abs().max(pow2(-20));
        g.assert("within 2^-21 relative", ((got - want) / scale).abs() < pow2(-21));
    });
}
