//! Energy-model cross-validation: the analytic activity factor α and
//! the latency/energy trends must agree with the cycle-accurate
//! simulator's *measured* activity counters — closing the loop between
//! the whole-CNN analytic path and the register-level truth.

use skewsa::arith::fma::ChainCfg;
use skewsa::arith::format::FpFormat;
use skewsa::energy::{layer_energy, AreaModel, LayerComparison, NetworkTotals, PowerModel};
use skewsa::pe::PipelineKind;
use skewsa::sa::array::ArraySim;
use skewsa::sa::tile::{GemmShape, TilePlan};
use skewsa::timing::model::TimingConfig;
use skewsa::workloads::gemm::GemmData;
use skewsa::workloads::{mobilenet, resnet50};

const CFG: ChainCfg = ChainCfg::BF16_FP32;

/// Analytic α (live-PE stage-slots / total stage-slots) vs the simulator's
/// measured PeActivity utilization, for a full-array single-tile GEMM.
#[test]
fn analytic_alpha_matches_simulated_utilization() {
    let (r, c) = (16usize, 16usize);
    let tcfg = TimingConfig { rows: r, cols: c, clock_ghz: 1.0, double_buffer: true };
    let pmodel = PowerModel::new(AreaModel::new(CFG));
    for m in [4usize, 32, 128] {
        let shape = GemmShape::new(m, r, c);
        let plan = TilePlan::new(shape, r, c);
        let le = layer_energy(&tcfg, &pmodel, PipelineKind::Skewed, &plan);

        let data = GemmData::cnn_like(shape, FpFormat::BF16, m as u64);
        let mut sim = ArraySim::new(CFG, PipelineKind::Skewed, &data.w, data.a);
        sim.run(1_000_000).unwrap();
        let measured = sim.activity().utilization();

        // The analytic α charges the layer's preload stall too; the sim
        // doesn't model preload. Compare on the sim's denominator.
        let analytic_sim_domain =
            (m * r * c) as f64 / (sim.cycles() as f64 * (r * c) as f64);
        assert!(
            (analytic_sim_domain - measured).abs() < 0.02,
            "M={m}: analytic α {analytic_sim_domain:.4} vs simulated {measured:.4}"
        );
        // And the layer-level α (with preload) is consistently lower but close.
        assert!(le.alpha <= analytic_sim_domain + 1e-9, "M={m}");
        assert!(le.alpha > 0.5 * analytic_sim_domain, "M={m}");
    }
}

/// Simulated utilization rises with M exactly as the energy model's
/// fill/drain amortization predicts — for both pipeline kinds, and the
/// skewed design is never *less* utilized than the baseline.
#[test]
fn utilization_monotone_in_m_and_kind() {
    let (r, c) = (8usize, 8usize);
    for kind in [PipelineKind::Baseline3b, PipelineKind::Skewed] {
        let mut last = 0.0;
        for m in [2usize, 8, 32, 128] {
            let data = GemmData::cnn_like(GemmShape::new(m, r, c), FpFormat::BF16, 7);
            let mut sim = ArraySim::new(CFG, kind, &data.w, data.a);
            sim.run(1_000_000).unwrap();
            let u = sim.activity().utilization();
            assert!(u > last, "{kind} M={m}: {u} !> {last}");
            last = u;
        }
    }
    // Same M: skewed drains sooner → higher utilization.
    let data = GemmData::cnn_like(GemmShape::new(8, 8, 8), FpFormat::BF16, 9);
    let util = |kind| {
        let mut sim = ArraySim::new(CFG, kind, &data.w, data.a.clone());
        sim.run(100_000).unwrap();
        sim.activity().utilization()
    };
    assert!(util(PipelineKind::Skewed) > util(PipelineKind::Baseline3b));
}

/// The paper's headline trend strengthens with array depth: larger R ⇒
/// larger whole-network latency saving (saving = R−2 per tile).
#[test]
fn savings_grow_with_array_size() {
    let pmodel = PowerModel::new(AreaModel::new(CFG));
    let mut last_saving = 0.0;
    for r in [32usize, 64, 128] {
        let tcfg = TimingConfig { rows: r, cols: r, clock_ghz: 1.0, double_buffer: true };
        let mut tot = NetworkTotals::default();
        for l in resnet50::layers() {
            let plan = TilePlan::new(l.gemm(), r, r);
            tot.add(&LayerComparison::evaluate(&tcfg, &pmodel, &plan));
        }
        let saving = -tot.latency_delta();
        assert!(saving > last_saving, "R={r}: {saving} !> {last_saving}");
        last_saving = saving;
    }
    assert!(last_saving > 0.15, "paper-scale saving {last_saving}");
}

/// Energy deltas are bounded: no layer of either CNN loses more than the
/// power premium (+8%) or saves more than the best-case latency bound.
#[test]
fn per_layer_energy_deltas_bounded() {
    let tcfg = TimingConfig::PAPER;
    let pmodel = PowerModel::new(AreaModel::new(CFG));
    for layers in [mobilenet::layers(), resnet50::layers()] {
        for l in &layers {
            let plan = TilePlan::new(l.gemm(), tcfg.rows, tcfg.cols);
            let c = LayerComparison::evaluate(&tcfg, &pmodel, &plan);
            let d = c.energy_delta();
            assert!(d < 0.085, "{}: energy delta {d}", l.name);
            assert!(d > -0.45, "{}: energy delta {d}", l.name);
            // Latency never regresses.
            assert!(c.latency_delta() <= 0.0, "{}", l.name);
        }
    }
}

/// Total MobileNet/ResNet cycle counts scale sanely with clock-invariant
/// structure: energy halves (≈) when the clock doubles (same cycles,
/// same power scale in the model's units).
#[test]
fn clock_scaling_consistency() {
    let pmodel = PowerModel::new(AreaModel::new(CFG));
    let shape = GemmShape::new(196, 512, 512);
    let t1 = TimingConfig { clock_ghz: 1.0, ..TimingConfig::PAPER };
    let t2 = TimingConfig { clock_ghz: 2.0, ..TimingConfig::PAPER };
    let e1 = layer_energy(&t1, &pmodel, PipelineKind::Skewed, &TilePlan::new(shape, 128, 128));
    let e2 = layer_energy(&t2, &pmodel, PipelineKind::Skewed, &TilePlan::new(shape, 128, 128));
    assert_eq!(e1.timing.cycles, e2.timing.cycles);
    assert!((e2.timing.ns - e1.timing.ns / 2.0).abs() < 1e-9);
}
