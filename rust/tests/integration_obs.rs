//! Observability end-to-end (ISSUE 7 acceptance): every submitted
//! request yields exactly one closed trace span whose wall-clock phase
//! durations partition the submit→response lifetime — on the served,
//! shed and failed paths alike — and whose cycle-domain attribution
//! (exposed preload + compute + drain + recovery) exactly matches the
//! closed-form timing model / streaming simulator for every batch.
//! The JSON-lines trace written by `--trace-out` round-trips through
//! the `skewsa trace` parser, and the unified metrics snapshot agrees
//! with the legacy per-subsystem counters it absorbed.

use skewsa::arith::format::FpFormat;
use skewsa::config::{NumericMode, RunConfig, ServeConfig};
use skewsa::coordinator::{FaultModel, FaultPlan, SdcTarget};
use skewsa::obs::{parse_jsonl, Obs, Phase, SpanStatus};
use skewsa::pe::PipelineKind;
use skewsa::sa::geometry::ArrayGeometry;
use skewsa::serve::{recv_response, DeadlineClass, ResponseStatus, Server};
use skewsa::util::rng::Rng;
use skewsa::workloads::mobilenet;
use skewsa::workloads::serving::WeightStore;
use std::sync::Arc;

fn run_cfg() -> RunConfig {
    let mut cfg = RunConfig::small();
    cfg.geometry = ArrayGeometry::new(16, 16);
    cfg.in_fmt = FpFormat::BF16;
    cfg.out_fmt = FpFormat::FP32;
    cfg.verify_fraction = 0.0;
    cfg
}

fn store() -> Arc<WeightStore> {
    // K=24 → 2 K-passes on the 16×16 array, N=16 → 1 N-block:
    // multi-tile plans on the traced path.
    Arc::new(WeightStore::from_layers(&mobilenet::layers()[..2], FpFormat::BF16, 24, 16))
}

#[test]
fn every_served_request_yields_exactly_one_closed_span() {
    let cfg = run_cfg();
    let store = store();
    let server = Server::start_obs(&cfg, &ServeConfig::small(), Arc::clone(&store), Obs::with_tracing());
    let mut rng = Rng::new(0x0b5);
    let mut elapsed_ns = Vec::new();
    for i in 0..6 {
        let model = i % 2;
        let a = store.gen_activations(model, 2 + i % 3, &mut rng);
        let t0 = std::time::Instant::now();
        let rx = server.submit(model, PipelineKind::Skewed, DeadlineClass::Interactive, a);
        let resp = recv_response(&rx, "span lifecycle");
        elapsed_ns.push(t0.elapsed().as_nanos() as u64);
        assert_eq!(resp.status, ResponseStatus::Ok);
    }
    let sink = server.obs().sink.as_ref().expect("tracing on");
    let spans = sink.spans();
    assert_eq!(spans.len(), 6, "exactly one closed span per submitted request");
    let mut ids: Vec<u64> = spans.iter().map(|s| s.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 6, "span ids are unique");
    for s in &spans {
        assert_eq!(s.status, SpanStatus::Ok);
        assert_eq!(s.kind, "skewed");
        assert_eq!(s.class, "interactive");
        assert!(s.shard.is_some(), "served span knows its shard");
        assert!(s.batch_size >= 1);
        // The partition invariant: phases sum exactly to the lifetime.
        assert_eq!(s.total_ns(), s.phases_ns.iter().sum::<u64>());
        assert!(s.total_ns() > 0);
        assert!(s.phases_ns[Phase::Execute as usize] > 0, "execution took time");
        // The span closes after the reply send, inside the client's
        // submit→recv bracket.
        let client_ns = elapsed_ns[s.id as usize];
        assert!(
            s.total_ns() <= client_ns,
            "span {} lifetime {}ns exceeds the client's observed {}ns",
            s.id,
            s.total_ns(),
            client_ns
        );
    }
}

#[test]
fn span_cycle_attribution_matches_timing_model_and_streaming_sim() {
    // The acceptance equality: for every batch, in both numeric modes
    // and both preload disciplines, the span's clean cycle legs sum to
    // the reported service time — which the timing-pin test already
    // ties to `layer_timing` and the streaming simulator.
    use skewsa::sa::tile::{GemmShape, TilePlan};
    use skewsa::timing::model::{layer_timing, TimingConfig};
    let store = store();
    for mode in [NumericMode::Oracle, NumericMode::CycleAccurate] {
        for db in [true, false] {
            let mut cfg = run_cfg();
            cfg.mode = mode;
            cfg.double_buffer = db;
            let server =
                Server::start_obs(&cfg, &ServeConfig::small(), Arc::clone(&store), Obs::with_tracing());
            let mut rng = Rng::new(0xa77 ^ db as u64);
            for model in 0..store.len() {
                let m = 3 + model;
                let a = store.gen_activations(model, m, &mut rng);
                let rx = server.submit(model, PipelineKind::Skewed, DeadlineClass::Interactive, a);
                let resp = recv_response(&rx, "cycle attribution");
                let span = server
                    .obs()
                    .sink
                    .as_ref()
                    .unwrap()
                    .spans()
                    .into_iter()
                    .find(|s| s.id == resp.id)
                    .expect("span closed with the response");
                assert_eq!(
                    span.cycles.stream_total(),
                    resp.batch_stream_cycles,
                    "mode={mode:?} db={db} model={model}: span legs != reported service time"
                );
                assert_eq!(span.cycles.recovery, 0, "clean run attributes no recovery");
                assert_eq!(span.cycles.total(), span.cycles.stream_total());
                let entry = store.get(model);
                let plan = TilePlan::for_geometry(GemmShape::new(m, entry.k, entry.n), cfg.geometry);
                let tcfg = TimingConfig {
                    geom: cfg.geometry,
                    clock_ghz: cfg.clock_ghz,
                    double_buffer: db,
                };
                let lt = layer_timing(&tcfg, PipelineKind::Skewed, &plan);
                assert_eq!(span.cycles.exposed_preload, lt.exposed_preload);
                assert_eq!(span.cycles.compute + span.cycles.drain, lt.compute_cycles);
                assert_eq!(span.cycles.stream_total(), lt.cycles);
            }
        }
    }
}

#[test]
fn failed_batches_close_their_spans_as_failed() {
    // One shard with a single always-failing worker: retry budgets
    // exhaust, the shard drops every batch, reply channels die — and
    // each span still closes, exactly once, as Failed via Drop.
    let cfg = run_cfg();
    let store = store();
    let mut scfg = ServeConfig::small();
    scfg.shards = 1;
    scfg.workers_per_shard = 1;
    scfg.fault = FaultModel::from_plan(FaultPlan::always(0));
    let server = Server::start_obs(&cfg, &scfg, Arc::clone(&store), Obs::with_tracing());
    let mut rng = Rng::new(0xdead);
    for i in 0..3 {
        let a = store.gen_activations(i % 2, 2, &mut rng);
        let rx = server.submit(i % 2, PipelineKind::Skewed, DeadlineClass::Interactive, a);
        assert!(rx.recv().is_err(), "request {i}: dropped batch closes the reply channel");
    }
    // The shard closes spans (via Drop) before dropping the reply
    // senders, so a client-side recv error implies the span is in.
    let spans = server.obs().sink.as_ref().unwrap().spans();
    assert_eq!(spans.len(), 3, "one span per failed request");
    for s in &spans {
        assert_eq!(s.status, SpanStatus::Failed);
        assert_eq!(s.shard, Some(0), "the batch reached its shard before dying");
        assert_eq!(s.total_ns(), s.phases_ns.iter().sum::<u64>());
    }
}

#[test]
fn shed_requests_close_their_spans_as_shed() {
    // A huge batch window parks the anchor request inside the batcher
    // while incompatible batch-class requests pile into the queue; with
    // the shed watermark at 1, everything past the first queued request
    // bounces immediately — each with a Shed span closed at submit.
    // Dropping the server flushes the accepted requests without waiting
    // out the window.
    let cfg = run_cfg();
    let store = store();
    let mut scfg = ServeConfig::small();
    scfg.batch_window_us = 2_000_000;
    scfg.shed_watermark = 1;
    let server = Server::start_obs(&cfg, &scfg, Arc::clone(&store), Obs::with_tracing());
    let mut rng = Rng::new(0x51ed);
    // Anchor: the batcher pops it and waits out the window.
    let a = store.gen_activations(0, 2, &mut rng);
    let rx_anchor = server.submit(0, PipelineKind::Skewed, DeadlineClass::Batch, a);
    std::thread::sleep(std::time::Duration::from_millis(100));
    // Incompatible (different model): queues behind the window.
    let a = store.gen_activations(1, 2, &mut rng);
    let rx_queued = server.submit(1, PipelineKind::Skewed, DeadlineClass::Batch, a);
    // Over the watermark: shed at submit.
    let mut shed_rxs = Vec::new();
    for _ in 0..3 {
        let a = store.gen_activations(1, 2, &mut rng);
        shed_rxs.push(server.submit(1, PipelineKind::Skewed, DeadlineClass::Batch, a));
    }
    for rx in shed_rxs {
        let resp = recv_response(&rx, "shed reply");
        assert_eq!(resp.status, ResponseStatus::Shed);
    }
    let snap = server.metrics();
    let obs = server.obs().clone();
    // Shutdown drains the two accepted requests as real responses.
    drop(server);
    assert_eq!(recv_response(&rx_anchor, "anchor").status, ResponseStatus::Ok);
    assert_eq!(recv_response(&rx_queued, "queued").status, ResponseStatus::Ok);
    let spans = obs.sink.as_ref().unwrap().spans();
    assert_eq!(spans.len(), 5, "every submit produced a span: 2 served + 3 shed");
    let shed: Vec<_> = spans.iter().filter(|s| s.status == SpanStatus::Shed).collect();
    assert_eq!(shed.len(), 3);
    for s in &shed {
        // Shed at submit: the whole (tiny) lifetime is queue time.
        assert_eq!(s.total_ns(), s.phases_ns[Phase::Queue as usize]);
        assert_eq!(s.shard, None, "a shed request never reached a shard");
    }
    assert_eq!(spans.iter().filter(|s| s.status == SpanStatus::Ok).count(), 2);
    assert_eq!(snap.counter("serve.shed"), 3);
}

#[test]
fn abft_recovery_cycles_are_attributed_and_bits_stay_exact() {
    // Saturating SDC injection with ABFT on: responses stay bit-exact,
    // and the spans now carry a non-zero recovery leg on top of the
    // unchanged clean stream total.
    let cfg = run_cfg();
    let store = store();
    let mut scfg = ServeConfig::small();
    scfg.fault = FaultModel {
        sdc_rate: 1.0,
        targets: SdcTarget::ALL.to_vec(),
        seed: 0xc4a05,
        abft: true,
        ..FaultModel::none()
    };
    let server = Server::start_obs(&cfg, &scfg, Arc::clone(&store), Obs::with_tracing());
    let mut rng = Rng::new(0x5dc);
    let kinds = [PipelineKind::Skewed, PipelineKind::Baseline3b];
    for i in 0..8 {
        let model = i % 2;
        let kind = kinds[i % 2];
        let a = store.gen_activations(model, 3, &mut rng);
        let rx = server.submit(model, kind, DeadlineClass::Interactive, a.clone());
        let resp = recv_response(&rx, "chaos attribution");
        assert_eq!(resp.status, ResponseStatus::Ok);
        let got: Vec<u32> = resp.y.iter().map(|v| v.to_bits()).collect();
        let want = store.solo_reference_bits(&cfg, model, kind, &a);
        assert_eq!(got, want, "request {i}: recovery changed served bits");
        let span = server
            .obs()
            .sink
            .as_ref()
            .unwrap()
            .spans()
            .into_iter()
            .find(|s| s.id == resp.id)
            .unwrap();
        // The clean legs still equal the reported service time; the
        // recovery leg rides on top.
        assert_eq!(span.cycles.stream_total(), resp.batch_stream_cycles);
        assert_eq!(span.cycles.total(), span.cycles.stream_total() + span.cycles.recovery);
        if span.sdc_detected > 0 {
            assert!(span.cycles.recovery > 0, "request {i}: detected SDCs but free recovery");
            assert_eq!(span.sdc_detected, span.sdc_recovered, "100% recall under trusted rerun");
        }
    }
    let spans = server.obs().sink.as_ref().unwrap().spans();
    assert_eq!(spans.len(), 8);
    assert!(
        spans.iter().any(|s| s.cycles.recovery > 0),
        "saturating injection never priced a recovery"
    );
    // The unified snapshot mirrors the legacy shard counters exactly.
    let snap = server.metrics();
    let stats = server.stats();
    let sum = |name: &str| -> u64 {
        (0..stats.shards.len()).map(|i| snap.counter(&format!("shard.{i}.{name}"))).sum()
    };
    assert_eq!(sum("sdc_detected"), stats.shards.iter().map(|s| s.sdc_detected).sum::<u64>());
    assert_eq!(sum("sdc_recovered"), stats.shards.iter().map(|s| s.sdc_recovered).sum::<u64>());
    assert_eq!(sum("sdc_unresolved"), 0);
    assert_eq!(snap.counter("serve.submitted"), 8);
}

#[test]
fn trace_jsonl_roundtrips_and_health_events_are_recorded() {
    // Sustained chaos with an aggressive health policy, tracing on:
    // quarantine transitions land as timestamped events, the
    // `health_transitions.*` counters agree, and the whole trace
    // survives the JSON-lines round trip the `skewsa trace` subcommand
    // depends on.
    let mut cfg = run_cfg();
    cfg.geometry = ArrayGeometry::new(8, 8);
    cfg.mode = NumericMode::CycleAccurate;
    let store =
        Arc::new(WeightStore::from_layers(&mobilenet::layers()[..2], FpFormat::BF16, 12, 8));
    let mut scfg = ServeConfig::small();
    scfg.health_window = 4;
    scfg.health_fault_threshold = 2;
    scfg.quarantine_batches = 4;
    scfg.probation_batches = 2;
    scfg.fault = FaultModel {
        sdc_rate: 1.0,
        targets: vec![SdcTarget::Output],
        seed: 0x9a7,
        abft: true,
        ..FaultModel::none()
    };
    let server = Server::start_obs(&cfg, &scfg, Arc::clone(&store), Obs::with_tracing());
    let mut rng = Rng::new(0xdead);
    for i in 0..12 {
        let a = store.gen_activations(i % 2, 2, &mut rng);
        let rx = server.submit(i % 2, PipelineKind::Skewed, DeadlineClass::Interactive, a);
        assert_eq!(recv_response(&rx, "health trace").status, ResponseStatus::Ok);
    }
    let sink = server.obs().sink.as_ref().unwrap();
    let events = sink.events();
    assert!(
        events.iter().any(|e| e.kind == "health" && e.label == "quarantined"),
        "sustained faults recorded no quarantine event: {events:?}"
    );
    assert!(events.windows(2).all(|w| w[0].t_ns <= w[1].t_ns), "event timestamps are monotone");
    let snap = server.metrics();
    assert_eq!(
        snap.counter("health_transitions.quarantined"),
        events.iter().filter(|e| e.label == "quarantined").count() as u64,
        "counter and event stream disagree"
    );
    // Full trace round trip: spans + events survive JSON lines.
    let text = sink.to_jsonl();
    let (spans, parsed_events) = parse_jsonl(&text).expect("trace parses back");
    assert_eq!(spans.len(), 12);
    assert_eq!(parsed_events.len(), events.len());
    for (orig, back) in sink.spans().iter().zip(&spans) {
        assert_eq!(orig, back, "span changed across the JSON-lines round trip");
    }
}

#[test]
fn tracing_off_records_nothing_but_metrics_still_flow() {
    let cfg = run_cfg();
    let store = store();
    let server = Server::start(&cfg, &ServeConfig::small(), Arc::clone(&store));
    let mut rng = Rng::new(1);
    let a = store.gen_activations(0, 2, &mut rng);
    let rx = server.submit(0, PipelineKind::Skewed, DeadlineClass::Interactive, a);
    assert_eq!(recv_response(&rx, "untraced").status, ResponseStatus::Ok);
    assert!(server.obs().sink.is_none(), "default server has no span sink");
    let snap = server.metrics();
    assert_eq!(snap.counter("serve.submitted"), 1);
    assert_eq!(snap.gauge("serve.shards") as usize, server.stats().shards.len());
}
