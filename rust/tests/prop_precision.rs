//! Property suite for the precision subsystem (DESIGN.md §12):
//! the error-analysis oracle path must agree bit-exactly with the
//! format codec, and ULP distances must behave like a metric over the
//! formats' value order.

use skewsa::arith::format::FpFormat;
use skewsa::precision::{quantize_oracle, ulp_distance};
use skewsa::util::prop::{Gen, Prop};

/// A random f64 with a bounded exponent (inside the BigFixed window and
/// spanning far past every format's overflow/underflow thresholds),
/// plus occasional exact zeros.
fn gen_f64(g: &mut Gen) -> f64 {
    if g.chance(0.02) {
        return if g.chance(0.5) { 0.0 } else { -0.0 };
    }
    let exp = g.i64_in(-320, 320) as i32;
    let frac = g.bits(52);
    let sign = if g.chance(0.5) { 1u64 } else { 0 };
    f64::from_bits((sign << 63) | (((exp + 1023) as u64) << 52) | frac)
}

/// THE satellite property: `encode_rne` reached through the error
/// analysis' exact-accumulator oracle path produces the same bits as
/// [`FpFormat::from_f64`], for every format, across the full exponent
/// range (underflow-to-zero, subnormals, normals, overflow-to-Inf and
/// E4M3 overflow-saturation-to-NaN included).
#[test]
fn prop_quantize_oracle_matches_from_f64_all_formats() {
    Prop::new("quantize-oracle-eq-codec", 2000).run(|g| {
        let x = gen_f64(g);
        for fmt in FpFormat::ALL {
            let oracle = quantize_oracle(fmt, x);
            let codec = fmt.from_f64(x);
            g.assert_eq(fmt.display_name(), oracle, codec);
        }
    });
}

/// Same property, adversarially centred on each format's rounding
/// boundaries: values a hair around representable midpoints, the
/// overflow threshold, and the subnormal floor.
#[test]
fn prop_quantize_oracle_matches_codec_near_boundaries() {
    Prop::new("quantize-oracle-boundaries", 800).run(|g| {
        for fmt in FpFormat::ALL {
            // A representable value, nudged by fractions of its ULP.
            let bits = g.bits(fmt.width()) & fmt.mask();
            let base = fmt.to_f64(bits);
            if base.is_nan() {
                continue;
            }
            let ulp = 2.0f64.powi(-(fmt.man_bits as i32));
            let nudge = g.f64_in(-1.0, 1.0) * ulp * base.abs().max(1e-40);
            let x = base + nudge;
            g.assert_eq(fmt.display_name(), quantize_oracle(fmt, x), fmt.from_f64(x));
            // Near the overflow cliff.
            let (sig, e) = fmt.max_finite();
            let max = sig as f64 * 2.0f64.powi(e - fmt.man_bits as i32);
            let y = max * g.f64_in(0.95, 1.1);
            g.assert_eq("overflow cliff", quantize_oracle(fmt, y), fmt.from_f64(y));
        }
    });
}

/// ULP distance is a metric consistent with the value order: for
/// value-sorted a ≤ b ≤ c, d(a,c) = d(a,b) + d(b,c); and the distance
/// between distinct representable values is ≥ 1.
#[test]
fn prop_ulp_distance_is_additive_along_the_value_order() {
    Prop::new("ulp-additive", 1500).run(|g| {
        let fmt = FpFormat::ALL[g.usize_in(0, FpFormat::ALL.len() - 1)];
        let mut pats: Vec<u64> = (0..3)
            .map(|_| loop {
                let b = g.bits(fmt.width()) & fmt.mask();
                if !fmt.to_f64(b).is_nan() {
                    break b;
                }
            })
            .collect();
        pats.sort_by(|&x, &y| fmt.to_f64(x).total_cmp(&fmt.to_f64(y)));
        let (a, b, c) = (pats[0], pats[1], pats[2]);
        g.assert_eq(
            "additivity",
            ulp_distance(fmt, a, c),
            ulp_distance(fmt, a, b) + ulp_distance(fmt, b, c),
        );
        g.assert_eq("symmetry", ulp_distance(fmt, a, c), ulp_distance(fmt, c, a));
        if fmt.to_f64(a) != fmt.to_f64(b) {
            g.assert("distinct values are >= 1 ULP apart", ulp_distance(fmt, a, b) >= 1);
        }
    });
}
