//! Property suite over the cycle-accurate simulator: randomized
//! (M, R, C) dimensions, both pipeline kinds, all invariants at once —
//! latency ≡ closed form, numerics ≡ oracle, array ≡ column
//! composition, schedule discipline.

use skewsa::arith::fma::ChainCfg;
use skewsa::arith::format::FpFormat;
use skewsa::pe::PipelineKind;
use skewsa::sa::array::ArraySim;
use skewsa::sa::column::ColumnSim;
use skewsa::sa::dataflow::WsSchedule;
use skewsa::sa::tile::GemmShape;
use skewsa::util::prop::{Gen, Prop};
use skewsa::workloads::gemm::GemmData;

const CFG: ChainCfg = ChainCfg::BF16_FP32;

fn kinds(g: &mut Gen) -> PipelineKind {
    *g.choose(&[PipelineKind::Baseline3b, PipelineKind::Skewed])
}

/// Random-dimension array runs: cycle count equals the closed form and
/// every output lands on its scheduled cycle.
#[test]
fn prop_array_latency_equals_schedule() {
    Prop::new("array-latency", 40).run(|g: &mut Gen| {
        let (m, r, c) = (g.usize_in(1, 24), g.usize_in(1, 20), g.usize_in(1, 12));
        let kind = kinds(g);
        let data = GemmData::cnn_like(GemmShape::new(m, r, c), FpFormat::BF16, g.bits(32));
        let mut sim = ArraySim::new(CFG, kind, &data.w, data.a);
        if sim.run(1_000_000).is_err() {
            g.assert("sim must not violate its own schedule", false);
            return;
        }
        let sched = WsSchedule::new(kind, r, c, m);
        g.assert_eq("total cycles", sim.cycles(), sched.total_cycles());
        for o in sim.outputs() {
            g.assert_eq("output cycle", o.cycle, sched.output_cycle(o.col, o.m));
        }
        g.assert_eq("no deep stalls", sim.stalls, 0);
    });
}

/// Random-dimension array runs are bit-exact against the value oracle,
/// for adversarial exponent-spread inputs.
#[test]
fn prop_array_bit_exact_vs_oracle() {
    Prop::new("array-vs-oracle", 25).run(|g: &mut Gen| {
        let (m, r, c) = (g.usize_in(1, 10), g.usize_in(1, 24), g.usize_in(1, 8));
        let kind = kinds(g);
        let data = GemmData::adversarial(GemmShape::new(m, r, c), FpFormat::BF16, g.bits(32));
        let want = ArraySim::oracle_bits(&CFG, &data.w, &data.a);
        let mut sim = ArraySim::new(CFG, kind, &data.w, data.a);
        sim.run(1_000_000).unwrap();
        g.assert_eq("result bits", sim.result_bits(), want);
    });
}

/// The two pipeline kinds agree bit-for-bit on identical random arrays.
#[test]
fn prop_kinds_agree_on_arrays() {
    Prop::new("kinds-agree", 25).run(|g: &mut Gen| {
        let (m, r, c) = (g.usize_in(1, 8), g.usize_in(1, 24), g.usize_in(1, 8));
        let data = GemmData::cnn_like(GemmShape::new(m, r, c), FpFormat::BF16, g.bits(32));
        let mut b = ArraySim::new(CFG, PipelineKind::Baseline3b, &data.w, data.a.clone());
        let mut s = ArraySim::new(CFG, PipelineKind::Skewed, &data.w, data.a);
        b.run(1_000_000).unwrap();
        s.run(1_000_000).unwrap();
        g.assert_eq("bits equal", b.result_bits(), s.result_bits());
        // Saving = R−2 per tile: the skewed design wins for R ≥ 3, ties
        // at R = 2, and pays its extra tail stage at R = 1 (there is no
        // chain to overlap — a degenerate array the paper never builds).
        g.assert_eq(
            "saving is R-2",
            b.cycles() as i64 - s.cycles() as i64,
            r as i64 - 2,
        );
    });
}

/// Column extraction: any column of a random array behaves exactly like
/// a standalone column sim on that column's weights.
#[test]
fn prop_column_extraction() {
    Prop::new("column-extraction", 20).run(|g: &mut Gen| {
        let (m, r, c) = (g.usize_in(1, 8), g.usize_in(1, 16), g.usize_in(2, 6));
        let kind = kinds(g);
        let col = g.usize_in(0, c - 1);
        let data = GemmData::cnn_like(GemmShape::new(m, r, c), FpFormat::BF16, g.bits(32));
        let mut arr = ArraySim::new(CFG, kind, &data.w, data.a.clone());
        arr.run(1_000_000).unwrap();
        let weights: Vec<u64> = (0..r).map(|k| data.w[k][col]).collect();
        let mut cs = ColumnSim::new(CFG, kind, &weights, data.a);
        cs.run(1_000_000).unwrap();
        let y = arr.result_bits();
        for o in cs.outputs() {
            g.assert_eq("column bits", o.bits, y[o.m][col]);
        }
    });
}

/// Different formats: the column sim is self-consistent (sim == oracle)
/// for every reduced input format, not just bf16.
#[test]
fn prop_formats_column_consistent() {
    Prop::new("formats-column", 30).run(|g: &mut Gen| {
        let (inf, outf) = *g.choose(&[
            (FpFormat::BF16, FpFormat::FP32),
            (FpFormat::FP16, FpFormat::FP32),
            (FpFormat::FP8E4M3, FpFormat::FP16),
            (FpFormat::FP8E5M2, FpFormat::FP16),
        ]);
        let chain = ChainCfg::new(inf, outf);
        let kind = kinds(g);
        let (m, r) = (g.usize_in(1, 6), g.usize_in(1, 32));
        let finite = |g: &mut Gen| loop {
            let b = g.bits(inf.width());
            if inf.decode(b).is_finite() {
                return b;
            }
        };
        let weights: Vec<u64> = (0..r).map(|_| finite(g)).collect();
        let a: Vec<Vec<u64>> = (0..m).map(|_| (0..r).map(|_| finite(g)).collect()).collect();
        let want: Vec<u64> = a
            .iter()
            .map(|row| {
                let mut o = skewsa::arith::accum::ColumnOracle::new(chain);
                for (k, &w) in weights.iter().enumerate() {
                    o.mac(row[k], w);
                }
                o.result()
            })
            .collect();
        let mut sim = ColumnSim::new(chain, kind, &weights, a);
        sim.run(1_000_000).unwrap();
        let got: Vec<u64> = sim.outputs().iter().map(|o| o.bits).collect();
        g.assert_eq("format column bits", got, want);
    });
}
