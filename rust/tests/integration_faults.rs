//! Fault-tolerant serving end-to-end (ISSUE 6 acceptance): under
//! saturating seeded SDC injection the served outputs must stay
//! bit-exact with the clean solo oracle — every corruption detected by
//! the ABFT checksums and recovered by trusted recomputation, zero left
//! unresolved — while sustained faults drive the shard health state
//! machine through quarantine without the pool ever refusing to serve.

use skewsa::arith::format::FpFormat;
use skewsa::config::{NumericMode, RunConfig, ServeConfig};
use skewsa::coordinator::{FaultModel, SdcTarget};
use skewsa::pe::PipelineKind;
use skewsa::sa::geometry::ArrayGeometry;
use skewsa::serve::{recv_response, DeadlineClass, ResponseStatus, Server, ShardSnapshot};
use skewsa::util::rng::Rng;
use skewsa::workloads::mobilenet;
use skewsa::workloads::serving::WeightStore;
use std::sync::Arc;

fn run_cfg() -> RunConfig {
    let mut cfg = RunConfig::small();
    cfg.geometry = ArrayGeometry::new(16, 16);
    cfg.in_fmt = FpFormat::BF16;
    cfg.out_fmt = FpFormat::FP32;
    cfg.verify_fraction = 0.0;
    cfg
}

fn sum(shards: &[ShardSnapshot], f: fn(&ShardSnapshot) -> u64) -> u64 {
    shards.iter().map(f).sum()
}

#[test]
fn chaos_serving_stays_bit_exact_under_saturating_sdc_injection() {
    // Every tile evaluation draws a flip (rate 1.0) across all three
    // injection sites.  Recovery recomputations are trusted (no
    // injection), so the outcome is deterministic: everything the
    // checksums flag is recovered and the served bits match the clean
    // solo reference exactly.
    let cfg = run_cfg();
    let store = Arc::new(WeightStore::from_layers(
        &mobilenet::layers()[..2],
        FpFormat::BF16,
        24, // 2 K-passes on the 16×16 array
        16,
    ));
    let mut scfg = ServeConfig::small();
    scfg.fault = FaultModel {
        sdc_rate: 1.0,
        targets: SdcTarget::ALL.to_vec(),
        seed: 0xc4a05,
        abft: true,
        ..FaultModel::none()
    };
    let server = Server::start(&cfg, &scfg, Arc::clone(&store));
    let mut rng = Rng::new(0x5dc);
    let kinds = [PipelineKind::Skewed, PipelineKind::Baseline3b];
    for i in 0..8 {
        let model = i % 2;
        let kind = kinds[i % 2];
        let a = store.gen_activations(model, 3, &mut rng);
        let rx = server.submit(model, kind, DeadlineClass::Interactive, a.clone());
        let resp = recv_response(&rx, "chaos bit-exactness");
        assert_eq!(resp.status, ResponseStatus::Ok, "request {i}");
        let got: Vec<u32> = resp.y.iter().map(|v| v.to_bits()).collect();
        let want = store.solo_reference_bits(&cfg, model, kind, &a);
        assert_eq!(got, want, "request {i}: SDC recovery changed served bits");
    }
    let stats = server.stats();
    assert!(sum(&stats.shards, |s| s.sdc_injected) >= 8, "{stats:?}");
    assert!(sum(&stats.shards, |s| s.sdc_detected) >= 1, "{stats:?}");
    assert_eq!(
        sum(&stats.shards, |s| s.sdc_detected),
        sum(&stats.shards, |s| s.sdc_recovered),
        "100% recall: every flagged block recomputed clean: {stats:?}"
    );
    assert_eq!(sum(&stats.shards, |s| s.sdc_unresolved), 0, "{stats:?}");
    assert_eq!(sum(&stats.shards, |s| s.failed_batches), 0, "{stats:?}");
}

#[test]
fn sustained_chaos_quarantines_shards_while_the_pool_keeps_serving() {
    // An aggressive health policy under saturating output corruption:
    // every batch records detected SDCs against its shard, so shards
    // cross the fault threshold and are quarantined — but exclusion is
    // void once every shard is out, and each response is still
    // bit-exact.  Runs the *cycle-accurate* streaming path so the
    // in-thread ABFT recovery is the one on trial.
    let mut cfg = run_cfg();
    cfg.geometry = ArrayGeometry::new(8, 8);
    cfg.mode = NumericMode::CycleAccurate;
    let store = Arc::new(WeightStore::from_layers(
        &mobilenet::layers()[..2],
        FpFormat::BF16,
        12,
        8,
    ));
    let mut scfg = ServeConfig::small();
    scfg.health_window = 4;
    scfg.health_fault_threshold = 2;
    scfg.quarantine_batches = 4;
    scfg.probation_batches = 2;
    scfg.fault = FaultModel {
        sdc_rate: 1.0,
        targets: vec![SdcTarget::Output],
        seed: 0x9a7,
        abft: true,
        ..FaultModel::none()
    };
    let server = Server::start(&cfg, &scfg, Arc::clone(&store));
    let mut rng = Rng::new(0xdead);
    for i in 0..12 {
        let model = i % 2;
        let a = store.gen_activations(model, 2, &mut rng);
        let rx = server.submit(model, PipelineKind::Skewed, DeadlineClass::Interactive, a.clone());
        let resp = recv_response(&rx, "degraded-pool serving");
        assert_eq!(resp.status, ResponseStatus::Ok, "request {i}");
        let got: Vec<u32> = resp.y.iter().map(|v| v.to_bits()).collect();
        let want = store.solo_reference_bits(&cfg, model, PipelineKind::Skewed, &a);
        assert_eq!(got, want, "request {i}: degraded pool changed served bits");
    }
    let stats = server.stats();
    // 12 sequential batches over 2 shards: at least one shard saw >= 2
    // faulty batches inside its 4-batch window and was quarantined.
    assert!(
        sum(&stats.shards, |s| s.quarantines) >= 1,
        "sustained faults never tripped the health board: {stats:?}"
    );
    assert_eq!(sum(&stats.shards, |s| s.sdc_unresolved), 0, "{stats:?}");
    assert_eq!(stats.submitted, 12);
    assert_eq!(sum(&stats.shards, |s| s.requests), 12, "no request was dropped: {stats:?}");
}
