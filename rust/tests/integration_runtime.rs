//! Runtime integration: artifact load/execute round-trip vs host math.
//!
//! These tests need `make artifacts` to have run; when artifacts are
//! absent they skip (printing why) rather than fail, so `cargo test`
//! stays green on a fresh checkout.

use skewsa::runtime::GoldenRuntime;
use skewsa::sa::geometry::ArrayGeometry;
use skewsa::util::rng::Rng;

fn golden() -> Option<GoldenRuntime> {
    let g = GoldenRuntime::try_open();
    if g.is_none() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
    }
    g
}

fn host_gemm_bf16(a: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    // Mirror the artifact semantics: bf16-quantized inputs, f32 products
    // accumulated in f32 (XLA rounds after every add).
    let q = |x: f32| -> f32 {
        let bits = skewsa::arith::format::FpFormat::BF16.from_f32(x);
        skewsa::arith::format::FpFormat::BF16.to_f32(bits)
    };
    let mut y = vec![0f32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let av = q(a[i * k + kk]);
            for j in 0..n {
                y[i * n + j] += av * q(w[kk * n + j]);
            }
        }
    }
    y
}

#[test]
fn manifest_lists_expected_artifacts() {
    let Some(g) = golden() else { return };
    assert!(g.artifacts.len() >= 4, "artifacts: {:?}", g.artifacts.names().collect::<Vec<_>>());
    assert!(g.artifacts.all_present());
    assert!(g.artifacts.find_gemm(64, 128, 64).is_some());
}

#[test]
fn gemm_artifact_round_trip_small() {
    let Some(g) = golden() else { return };
    let (m, k, n) = (8, 16, 8);
    let mut rng = Rng::new(0xfeed);
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
    let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
    let y = g.run_gemm_f32(m, k, n, &a, &w).expect("execute").expect("artifact exists");
    let want = host_gemm_bf16(&a, &w, m, k, n);
    for (i, (&got, &want)) in y.iter().zip(&want).enumerate() {
        let tol = 1e-2 * (1.0 + want.abs());
        assert!((got - want).abs() <= tol, "y[{i}]: {got} vs {want}");
    }
}

#[test]
fn gemm_artifact_round_trip_large() {
    let Some(g) = golden() else { return };
    let (m, k, n) = (64, 128, 64);
    let mut rng = Rng::new(0xdead);
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
    let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
    let y = g.run_gemm_f32(m, k, n, &a, &w).expect("execute").expect("artifact exists");
    let want = host_gemm_bf16(&a, &w, m, k, n);
    let mut max_rel = 0.0f32;
    for (&got, &want) in y.iter().zip(&want) {
        max_rel = max_rel.max((got - want).abs() / (1.0 + want.abs()));
    }
    // XLA may reassociate the K loop; bf16 products in f32 keep this small.
    assert!(max_rel < 2e-2, "max rel err {max_rel}");
}

#[test]
fn tiny_cnn_artifact_executes() {
    let Some(g) = golden() else { return };
    let exe = g.load("tiny_cnn_16x16x4").expect("load tiny_cnn");
    let mut rng = Rng::new(7);
    let x: Vec<f32> = (0..16 * 16 * 4).map(|_| rng.normal() as f32).collect();
    let w1: Vec<f32> = (0..3 * 3 * 4 * 8).map(|_| rng.normal() as f32 * 0.3).collect();
    let w2: Vec<f32> = (0..3 * 3 * 8 * 16).map(|_| rng.normal() as f32 * 0.3).collect();
    let wfc: Vec<f32> = (0..16 * 10).map(|_| rng.normal() as f32 * 0.3).collect();
    let y = exe
        .run_f32(&[
            (&x, &[1, 16, 16, 4]),
            (&w1, &[3, 3, 4, 8]),
            (&w2, &[3, 3, 8, 16]),
            (&wfc, &[16, 10]),
        ])
        .expect("execute tiny_cnn");
    assert_eq!(y.len(), 10);
    assert!(y.iter().all(|v| v.is_finite()), "{y:?}");
    assert!(y.iter().any(|&v| v != 0.0));
}

#[test]
fn shape_validation_rejects_bad_calls() {
    let Some(g) = golden() else { return };
    let exe = g.load("gemm_bf16_8x16x8").expect("load");
    let a = vec![0f32; 8 * 16];
    let w = vec![0f32; 16 * 8];
    // Wrong declared shape.
    assert!(exe.run_f32(&[(&a, &[16, 8]), (&w, &[16, 8])]).is_err());
    // Wrong arity.
    assert!(exe.run_f32(&[(&a, &[8, 16])]).is_err());
}

#[test]
fn coordinator_matches_runtime_golden() {
    // The end-to-end golden path (DESIGN §7): bit-accurate simulator
    // output vs the XLA artifact, tolerance-based.
    let Some(g) = golden() else { return };
    use skewsa::arith::format::FpFormat;
    use skewsa::config::RunConfig;
    use skewsa::coordinator::Coordinator;
    use skewsa::pe::PipelineKind;
    use skewsa::sa::tile::GemmShape;
    use skewsa::workloads::gemm::GemmData;
    use std::sync::Arc;

    let (m, k, n) = (64, 128, 64);
    let mut cfg = RunConfig::small();
    cfg.geometry = ArrayGeometry::new(32, 32);
    let data = Arc::new(GemmData::cnn_like(GemmShape::new(m, k, n), FpFormat::BF16, 99));
    let r = Coordinator::new(cfg).run_gemm(PipelineKind::Skewed, &data);
    assert!(r.verify.ok());

    // Feed the same (bf16-rounded) values to the artifact as f32.
    let a: Vec<f32> = data.a.iter().flatten().map(|&b| FpFormat::BF16.to_f32(b)).collect();
    let w: Vec<f32> = data.w.iter().flatten().map(|&b| FpFormat::BF16.to_f32(b)).collect();
    let gold = g.run_gemm_f32(m, k, n, &a, &w).expect("execute").expect("artifact");
    let mut max_rel = 0.0f32;
    for (&sim, &x) in r.y.iter().zip(&gold) {
        max_rel = max_rel.max((sim - x).abs() / (1.0 + x.abs()));
    }
    // Simulator rounds once per column; XLA rounds per add: ≤ 2 ulp-ish.
    assert!(max_rel < 2e-2, "sim vs XLA golden max rel err {max_rel}");
}
