//! Property suite over the coordinator (DESIGN.md §9): tile assembly ≡
//! full-matrix oracle, completion-order invariance, router balance.

use skewsa::arith::format::FpFormat;
use skewsa::config::{NumericMode, RunConfig};
use skewsa::coordinator::scheduler::Scheduler;
use skewsa::coordinator::state::{RunState, TileResult};
use skewsa::coordinator::{eval_tile, verify_oracle_sampled, Coordinator, Policy, Router};
use skewsa::pe::PipelineKind;
use skewsa::sa::tile::{GemmShape, TilePlan};
use skewsa::util::prop::{Gen, Prop};
use skewsa::workloads::gemm::GemmData;
use std::sync::Arc;

/// Assembled tile results equal the whole-matrix oracle for random
/// shapes/seeds (bit-exact, sampled exhaustively for small outputs).
#[test]
fn prop_assembly_equals_oracle() {
    Prop::new("assembly-eq-oracle", 12).run(|g: &mut Gen| {
        let shape = GemmShape::new(g.usize_in(1, 12), g.usize_in(1, 40), g.usize_in(1, 14));
        let seed = g.bits(32);
        let mut cfg = RunConfig::small();
        cfg.verify_fraction = 1.0;
        cfg.workers = g.usize_in(1, 4);
        let data = Arc::new(GemmData::cnn_like(shape, FpFormat::BF16, seed));
        let r = Coordinator::new(cfg).run_gemm(PipelineKind::Skewed, &data);
        g.assert("verified bit-exact", r.verify.ok());
        g.assert_eq("checked all", r.verify.checked, shape.m * shape.n);
    });
}

/// Assembly is invariant to tile completion order: folding results in
/// any permutation produces identical bits.
#[test]
fn prop_assembly_order_invariant() {
    Prop::new("assembly-order", 25).run(|g: &mut Gen| {
        let shape = GemmShape::new(g.usize_in(1, 6), g.usize_in(9, 40), g.usize_in(9, 20));
        let data = GemmData::cnn_like(shape, FpFormat::BF16, g.bits(32));
        let plan = TilePlan::new(shape, 8, 8);
        let sched = Scheduler::new(&plan);
        let chain = RunConfig::small().chain();
        let results: Vec<TileResult> = sched
            .jobs()
            .iter()
            .map(|&job| TileResult {
                job,
                y_part: eval_tile(&chain, NumericMode::Oracle, PipelineKind::Skewed, &data, &job),
                worker: 0,
            })
            .collect();
        // In-order assembly.
        let mut st1 = RunState::new(shape.m, shape.n, 8, results.len());
        for r in &results {
            st1.accept(r.clone());
        }
        let y1 = st1.into_result();
        // Shuffled assembly.
        let mut order: Vec<usize> = (0..results.len()).collect();
        for i in (1..order.len()).rev() {
            let j = g.usize_in(0, i);
            order.swap(i, j);
        }
        let mut st2 = RunState::new(shape.m, shape.n, 8, results.len());
        for &i in &order {
            st2.accept(results[i].clone());
        }
        let y2 = st2.into_result();
        let b1: Vec<u32> = y1.iter().map(|v| v.to_bits()).collect();
        let b2: Vec<u32> = y2.iter().map(|v| v.to_bits()).collect();
        g.assert_eq("order-invariant bits", b1, b2);
    });
}

/// Numeric mode equivalence: oracle-mode and cycle-accurate-mode tiles
/// produce identical bits (the sim IS the oracle with timing).
#[test]
fn prop_modes_equivalent() {
    Prop::new("modes-equivalent", 8).run(|g: &mut Gen| {
        let shape = GemmShape::new(g.usize_in(1, 6), g.usize_in(1, 24), g.usize_in(1, 10));
        let seed = g.bits(32);
        let data = Arc::new(GemmData::adversarial(shape, FpFormat::BF16, seed));
        let mut cfg = RunConfig::small();
        cfg.verify_fraction = 0.0;
        let mut c1 = cfg.clone();
        c1.mode = NumericMode::Oracle;
        let mut c2 = cfg;
        c2.mode = NumericMode::CycleAccurate;
        let y1 = Coordinator::new(c1).run_gemm(PipelineKind::Skewed, &data).y;
        let y2 = Coordinator::new(c2).run_gemm(PipelineKind::Skewed, &data).y;
        let b1: Vec<u32> = y1.iter().map(|v| v.to_bits()).collect();
        let b2: Vec<u32> = y2.iter().map(|v| v.to_bits()).collect();
        g.assert_eq("oracle == cycle bits", b1, b2);
    });
}

/// Router balance bounds: round-robin never skews by more than 1 job
/// without completions; least-loaded never exceeds the ideal by more
/// than 1 under random completion patterns.
#[test]
fn prop_router_balance() {
    Prop::new("router-balance", 120).run(|g: &mut Gen| {
        let workers = g.usize_in(1, 8);
        let jobs = g.usize_in(1, 200);
        let rr = Router::new(Policy::RoundRobin, workers);
        for _ in 0..jobs {
            rr.dispatch();
        }
        g.assert("rr imbalance ≤ 1", rr.imbalance() <= 1);

        let ll = Router::new(Policy::LeastLoaded, workers);
        let mut inflight: Vec<usize> = Vec::new();
        let mut max_seen = 0usize;
        for _ in 0..jobs {
            inflight.push(ll.dispatch());
            for w in 0..workers {
                max_seen = max_seen.max(ll.load(w));
            }
            // Randomly complete some jobs.
            while !inflight.is_empty() && g.chance(0.5) {
                let idx = g.usize_in(0, inflight.len() - 1);
                ll.complete(inflight.swap_remove(idx));
            }
        }
        // Upper bound: ceil(jobs/workers)+1 at any instant.
        let bound = jobs.div_ceil(workers) + 1;
        g.assert("ll bounded", max_seen <= bound);
    });
}

/// Sampled verification catches random single-bit corruption with the
/// exhaustive fraction.
#[test]
fn prop_verification_catches_corruption() {
    Prop::new("verify-catches", 15).run(|g: &mut Gen| {
        let shape = GemmShape::new(g.usize_in(2, 6), g.usize_in(4, 24), g.usize_in(2, 8));
        let data = Arc::new(GemmData::cnn_like(shape, FpFormat::BF16, g.bits(32)));
        let mut cfg = RunConfig::small();
        cfg.verify_fraction = 0.0;
        let coord = Coordinator::new(cfg.clone());
        let mut r = coord.run_gemm(PipelineKind::Baseline3b, &data);
        // Flip a mantissa bit somewhere.
        let idx = g.usize_in(0, r.y.len() - 1);
        let flipped = f32::from_bits(r.y[idx].to_bits() ^ 1);
        r.y[idx] = flipped;
        let plan = TilePlan::for_geometry(shape, cfg.geometry);
        let rep = verify_oracle_sampled(&cfg.chain(), &plan, &data, &r.y, 1.0, 1);
        g.assert("corruption detected", !rep.ok());
    });
}
