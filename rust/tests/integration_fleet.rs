//! Fleet-DES differential pins (DESIGN.md §18): the discrete-event
//! simulator must agree with the *real threaded server* wherever their
//! observable surfaces overlap — shard routing order, batch
//! composition, quoted service cycles — and with the committed
//! cross-language golden produced by the independent Python port
//! (`python/tests/test_fleet_des.py`).  Plus a fully hand-traced
//! structural pin of the watermark-shed + mailbox-backpressure path.

use skewsa::arith::format::FpFormat;
use skewsa::config::{FleetConfig, RunConfig, ServeConfig};
use skewsa::coordinator::Policy;
use skewsa::fleet::{
    ArrivalSpec, FleetSim, ModelShape, ReqStatus, TenantSpec, TraceReq, MAILBOX_DEPTH,
};
use skewsa::pe::PipelineKind;
use skewsa::sa::geometry::ArrayGeometry;
use skewsa::serve::{gen_request, recv_response, DeadlineClass, LoadSpec, Server};
use skewsa::util::mini_json::Json;
use skewsa::workloads::mobilenet;
use skewsa::workloads::serving::WeightStore;
use std::sync::Arc;

fn run_cfg(fmt: FpFormat) -> RunConfig {
    let mut cfg = RunConfig::small();
    cfg.geometry = ArrayGeometry::new(16, 16);
    cfg.in_fmt = fmt;
    cfg.out_fmt = FpFormat::FP32;
    cfg.verify_fraction = 0.0;
    cfg
}

/// Mirror a [`WeightStore`]'s model shapes into the DES config so both
/// worlds quote service times for the exact same GEMMs.
fn models_of(store: &WeightStore) -> Vec<ModelShape> {
    (0..store.len())
        .map(|m| {
            let e = store.get(m);
            ModelShape { k: e.k, n: e.n }
        })
        .collect()
}

/// One virtual client replaying the threaded load generator's closed
/// loop must reproduce the threaded server request-for-request: same
/// content draws (shared `gen_request` derivation), same round-robin
/// shard sequence (the router starts at shard 0 and advances once per
/// batch on both sides), same quoted service cycles (shared plan cache
/// and streaming-cycle model).
#[test]
fn sequential_closed_loop_matches_threaded_rr_server() {
    let cfg = run_cfg(FpFormat::BF16);
    let store =
        Arc::new(WeightStore::from_layers(&mobilenet::layers()[..3], FpFormat::BF16, 24, 16));
    let spec = LoadSpec {
        clients: 1,
        requests_per_client: 12,
        kinds: vec![PipelineKind::Baseline3b, PipelineKind::Skewed],
        interactive_fraction: 0.3,
        min_rows: 2,
        max_rows: 6,
        seed: 0xd1ff_5eed,
    };

    // Threaded side: zero windows + sequential submits means every
    // request dispatches alone, in order, round-robin from shard 0.
    let mut scfg = ServeConfig::small();
    scfg.shards = 3;
    scfg.shard_policy = Policy::RoundRobin;
    scfg.batch_window_us = 0;
    scfg.interactive_window_us = 0;
    scfg.shed_watermark = 0;
    let server = Server::start(&cfg, &scfg, Arc::clone(&store));
    let mut threaded = Vec::new();
    for i in 0..spec.requests_per_client {
        let (model, kind, class, a) = gen_request(&store, &spec, 0, i);
        let rx = server.submit(model, kind, class, a);
        threaded.push(recv_response(&rx, "sequential closed loop"));
    }
    drop(server);

    // DES side: the same closed loop as tenant 0 (whose content-draw
    // base is exactly `seed`, matching `gen_request`).
    let fcfg = FleetConfig {
        shards: 3,
        min_shards: 3,
        max_shards: 3,
        queue_cap: 64,
        shed_watermark: 0,
        batch_window: 0,
        interactive_window: 0,
        max_batch_requests: 8,
        max_batch_rows: 64,
        shard_policy: Policy::RoundRobin,
        horizon: 1_000_000,
        autoscale_interval: 0,
        seed: spec.seed,
        models: models_of(&store),
        tenants: vec![TenantSpec {
            name: "closed".into(),
            arrival: ArrivalSpec::ClosedLoop { clients: 1, requests_per_client: 12 },
            bucket_capacity: 0,
            bucket_refill_cycles: 0,
            kinds: spec.kinds.clone(),
            interactive_fraction: spec.interactive_fraction,
            min_rows: spec.min_rows,
            max_rows: spec.max_rows,
        }],
        ..FleetConfig::default()
    };
    let r = FleetSim::simulate(&cfg, &fcfg);

    assert_eq!(r.submitted, 12);
    assert_eq!(r.served, 12);
    assert_eq!(r.records.len(), threaded.len());
    for (i, (rec, resp)) in r.records.iter().zip(&threaded).enumerate() {
        assert_eq!(rec.status, ReqStatus::Served, "request {i} status");
        assert_eq!(rec.batch_size, 1, "request {i}: sequential loop never batches");
        assert_eq!(resp.batch_size, 1, "request {i}: threaded side never batches");
        assert_eq!(rec.shard, Some(i % 3), "request {i}: DES round-robin from shard 0");
        assert_eq!(resp.shard, i % 3, "request {i}: threaded round-robin from shard 0");
        assert_eq!(
            rec.service, resp.batch_stream_cycles,
            "request {i}: quoted service cycles must match the threaded shard"
        );
    }
    assert!(r.accounting_balanced());
}

/// Deadline-windowed batching composes the same batch in both worlds:
/// four compatible batch-class requests coalesce into one 4-member
/// batch (the request cap closes the window early), and the DES quotes
/// exactly the service time the threaded shard measures for it.
#[test]
fn windowed_batch_composition_matches_threaded() {
    let cfg = run_cfg(FpFormat::BF16);
    let store =
        Arc::new(WeightStore::from_layers(&mobilenet::layers()[..1], FpFormat::BF16, 27, 16));

    let mut scfg = ServeConfig::small();
    scfg.batch_window_us = 2_000_000;
    scfg.max_batch_requests = 4;
    let server = Server::start(&cfg, &scfg, Arc::clone(&store));
    let mut rng = skewsa::util::rng::Rng::new(7);
    let rxs: Vec<_> = (0..4)
        .map(|_| {
            let a = store.gen_activations(0, 2, &mut rng);
            server.submit(0, PipelineKind::Skewed, DeadlineClass::Batch, a)
        })
        .collect();
    let resps: Vec<_> = rxs.iter().map(|rx| recv_response(rx, "windowed batch")).collect();
    drop(server);
    let service = resps[0].batch_stream_cycles;
    for resp in &resps {
        assert_eq!(resp.batch_size, 4, "threaded cap closes the window at 4 members");
        assert_eq!(resp.batch_stream_cycles, service);
    }

    // DES side: the same four requests as a trace, arriving inside one
    // long window; the 4-request cap dispatches at the last arrival.
    let requests: Vec<TraceReq> = (0..4)
        .map(|i| TraceReq {
            at: i,
            model: 0,
            rows: 2,
            kind: PipelineKind::Skewed,
            class: DeadlineClass::Batch,
        })
        .collect();
    let fcfg = FleetConfig {
        shards: 1,
        min_shards: 1,
        max_shards: 1,
        queue_cap: 64,
        shed_watermark: 0,
        batch_window: 1_000,
        interactive_window: 0,
        max_batch_requests: 4,
        max_batch_rows: 64,
        shard_policy: Policy::RoundRobin,
        horizon: 100_000,
        autoscale_interval: 0,
        seed: 1,
        models: models_of(&store),
        tenants: vec![TenantSpec {
            name: "trace".into(),
            arrival: ArrivalSpec::Trace { requests },
            bucket_capacity: 0,
            bucket_refill_cycles: 0,
            kinds: vec![PipelineKind::Skewed],
            interactive_fraction: 0.0,
            min_rows: 1,
            max_rows: 8,
        }],
        ..FleetConfig::default()
    };
    let r = FleetSim::simulate(&cfg, &fcfg);

    assert_eq!(r.batches, 1, "one composed batch");
    assert_eq!(r.max_batch, 4);
    assert_eq!(r.batched_rows, 8);
    for rec in &r.records {
        assert_eq!(rec.status, ReqStatus::Served);
        assert_eq!(rec.shard, Some(0));
        assert_eq!(rec.batch_size, 4);
        assert_eq!(
            rec.service, service,
            "DES quotes the threaded shard's cycles for the composed batch"
        );
        assert_eq!(rec.done, 3 + service, "cap closes at the last arrival (t = 3)");
    }
    assert!(r.accounting_balanced());
}

/// Hand-traced watermark pin on one shard: 8 simultaneous batch-class
/// arrivals against a depth-2 mailbox and watermark 2.  Batches 0-2
/// occupy the shard + mailbox, batch 3 blocks the batcher, requests
/// 4-5 queue (depth 1, 2), and requests 6-7 hit the watermark and are
/// shed with `done == submit` and no shard.  The survivors then drain
/// strictly serially: request `i` completes at `(i + 1) * service`.
#[test]
fn watermark_shed_and_mailbox_backpressure_pin() {
    assert_eq!(MAILBOX_DEPTH, 2, "the hand trace below assumes a depth-2 mailbox");
    let cfg = run_cfg(FpFormat::BF16);
    let store =
        Arc::new(WeightStore::from_layers(&mobilenet::layers()[..1], FpFormat::BF16, 24, 16));
    let requests: Vec<TraceReq> = (0..8)
        .map(|_| TraceReq {
            at: 0,
            model: 0,
            rows: 2,
            kind: PipelineKind::Skewed,
            class: DeadlineClass::Batch,
        })
        .collect();
    let fcfg = FleetConfig {
        shards: 1,
        min_shards: 1,
        max_shards: 1,
        queue_cap: 64,
        shed_watermark: 2,
        batch_window: 0,
        interactive_window: 0,
        max_batch_requests: 8,
        max_batch_rows: 64,
        shard_policy: Policy::RoundRobin,
        horizon: 100_000,
        autoscale_interval: 0,
        seed: 9,
        models: models_of(&store),
        tenants: vec![TenantSpec {
            name: "burst".into(),
            arrival: ArrivalSpec::Trace { requests },
            bucket_capacity: 0,
            bucket_refill_cycles: 0,
            kinds: vec![PipelineKind::Skewed],
            interactive_fraction: 0.0,
            min_rows: 1,
            max_rows: 8,
        }],
        ..FleetConfig::default()
    };
    let r = FleetSim::simulate(&cfg, &fcfg);

    assert_eq!(r.submitted, 8);
    assert_eq!(r.served, 6);
    assert_eq!(r.shed, 2);
    assert_eq!(r.shed_watermark, 2);
    assert_eq!(r.shed_bucket, 0);
    assert_eq!(r.shed_capacity, 0);
    assert_eq!(r.batches, 6, "zero-window anchors dispatch alone");
    assert_eq!(r.max_batch, 1);
    assert_eq!(r.batched_rows, 12);
    let service = r.records[0].service;
    assert!(service > 0);
    for (i, rec) in r.records.iter().take(6).enumerate() {
        assert_eq!(rec.status, ReqStatus::Served, "request {i}");
        assert_eq!(rec.shard, Some(0), "request {i}");
        assert_eq!(rec.batch_size, 1, "request {i}");
        assert_eq!(rec.service, service, "request {i}: identical shape, identical quote");
        assert_eq!(rec.done, (i as u64 + 1) * service, "request {i}: strictly serial drain");
    }
    for (i, rec) in r.records.iter().enumerate().skip(6) {
        assert_eq!(rec.status, ReqStatus::Shed, "request {i}");
        assert_eq!(rec.shard, None, "request {i}: shed requests never touch a shard");
        assert_eq!(rec.done, rec.submit, "request {i}: rejection is immediate");
        assert_eq!(rec.batch_size, 0, "request {i}");
    }
    assert_eq!(r.wall_cycles, 6 * service);
    assert!(r.accounting_balanced());
}

/// Replay one committed cross-language golden (`python/tests/
/// test_fleet_des.py --emit-golden`): rebuild the exact scenario the
/// independent Python port ran and require every headline counter —
/// and the full per-record FNV fingerprint — to match bit-for-bit.
/// `expect.stream_cycles` is checked when present (the heterogeneous
/// golden records it; the original golden predates the field).
fn replay_golden(file: &str) {
    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../python/tests").join(file);
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
    let j = Json::parse(&text).unwrap_or_else(|e| panic!("{file} parses: {e:?}"));

    let mut run = RunConfig::small();
    run.apply_json(j.get("run").expect("golden 'run' section")).expect("run section applies");
    let mut fcfg = FleetConfig::default();
    fcfg.apply_json(j.get("fleet").expect("golden 'fleet' section"))
        .expect("fleet section applies");
    let r = FleetSim::simulate(&run, &fcfg);

    let exp = j.get("expect").expect("golden 'expect' section");
    let want = |key: &str| -> u64 {
        exp.get(key)
            .and_then(Json::as_f64)
            .unwrap_or_else(|| panic!("golden expect.{key} missing")) as u64
    };
    assert_eq!(r.submitted, want("submitted"), "submitted");
    assert_eq!(r.served, want("served"), "served");
    assert_eq!(r.shed_bucket, want("shed_bucket"), "shed_bucket");
    assert_eq!(r.shed_watermark, want("shed_watermark"), "shed_watermark");
    assert_eq!(r.shed_capacity, want("shed_capacity"), "shed_capacity");
    assert_eq!(r.failed, want("failed"), "failed");
    assert_eq!(r.batches, want("batches"), "batches");
    assert_eq!(r.batched_rows, want("batched_rows"), "batched_rows");
    assert_eq!(r.max_batch as u64, want("max_batch"), "max_batch");
    assert_eq!(r.wall_cycles, want("wall_cycles"), "wall_cycles");
    if exp.get("stream_cycles").is_some() {
        assert_eq!(r.stream_cycles, want("stream_cycles"), "stream_cycles");
    }
    let fp = exp.get("fingerprint").and_then(Json::as_str).expect("expect.fingerprint");
    assert_eq!(
        format!("{:016x}", r.fingerprint),
        fp,
        "cross-language per-record fingerprint"
    );
    assert!(r.accounting_balanced());
}

#[test]
fn golden_python_port_scenario_reproduces() {
    replay_golden("golden_fleet_des.json");
}

/// The heterogeneous golden: per-shard geometries plus shape-aware
/// routing, exercised through the Python port's independent
/// implementation of the scoring policy and the rectangular timing
/// model.
#[test]
fn golden_python_hetero_scenario_reproduces() {
    replay_golden("golden_fleet_hetero.json");
}

/// Shape-aware routing joins the §18 differential pin: the threaded
/// server and the DES both score each request's GEMM against every
/// shard's geometry through the plan cache, so a sequential closed loop
/// must land request-for-request on the same shards with the same
/// quoted service cycles.  The two models are built to disagree — one
/// reduction-deep (K≫N, wants the 16×4 shard), one output-wide (N≫K,
/// wants the 4×16 shard) — so a policy divergence cannot hide.
#[test]
fn shape_aware_routing_matches_threaded_server() {
    use skewsa::workloads::layer::LayerDef;
    let cfg = run_cfg(FpFormat::BF16);
    let geoms = vec![ArrayGeometry::new(16, 4), ArrayGeometry::new(4, 16)];
    let layers =
        [LayerDef::gemm_layer("tall", 1, 64, 4), LayerDef::gemm_layer("wide", 1, 4, 64)];
    let store = Arc::new(WeightStore::from_layers(&layers, FpFormat::BF16, 64, 64));

    let mut scfg = ServeConfig::small();
    scfg.shards = 2;
    scfg.shard_policy = Policy::ShapeAware;
    scfg.shard_geometries = geoms.clone();
    scfg.batch_window_us = 0;
    scfg.interactive_window_us = 0;
    scfg.shed_watermark = 0;
    let server = Server::start(&cfg, &scfg, Arc::clone(&store));
    let mut rng = skewsa::util::rng::Rng::new(3);
    let mut threaded = Vec::new();
    for i in 0..10usize {
        let a = store.gen_activations(i % 2, 2, &mut rng);
        let rx = server.submit(i % 2, PipelineKind::Skewed, DeadlineClass::Interactive, a);
        threaded.push(recv_response(&rx, "shape-aware sequential loop"));
    }
    drop(server);

    let requests: Vec<TraceReq> = (0..10)
        .map(|i| TraceReq {
            at: i as u64 * 10_000,
            model: i % 2,
            rows: 2,
            kind: PipelineKind::Skewed,
            class: DeadlineClass::Interactive,
        })
        .collect();
    let fcfg = FleetConfig {
        shards: 2,
        min_shards: 2,
        max_shards: 2,
        queue_cap: 64,
        shed_watermark: 0,
        batch_window: 0,
        interactive_window: 0,
        max_batch_requests: 8,
        max_batch_rows: 64,
        shard_policy: Policy::ShapeAware,
        shard_geometries: geoms,
        horizon: 1_000_000,
        autoscale_interval: 0,
        seed: 3,
        models: models_of(&store),
        tenants: vec![TenantSpec {
            name: "trace".into(),
            arrival: ArrivalSpec::Trace { requests },
            bucket_capacity: 0,
            bucket_refill_cycles: 0,
            kinds: vec![PipelineKind::Skewed],
            interactive_fraction: 1.0,
            min_rows: 1,
            max_rows: 8,
        }],
        ..FleetConfig::default()
    };
    let r = FleetSim::simulate(&cfg, &fcfg);

    assert_eq!(r.served, 10);
    assert_eq!(r.records.len(), threaded.len());
    for (i, (rec, resp)) in r.records.iter().zip(&threaded).enumerate() {
        let best = i % 2; // tall model → tall shard 0, wide model → wide shard 1
        assert_eq!(resp.shard, best, "request {i}: threaded shape-aware pick");
        assert_eq!(rec.shard, Some(best), "request {i}: DES shape-aware pick");
        assert_eq!(
            rec.service, resp.batch_stream_cycles,
            "request {i}: both worlds quote the chosen geometry's cycles"
        );
    }
    assert!(r.accounting_balanced());
}

/// The ISSUE 10 acceptance pin: on a mixed decode+CNN trace at equal PE
/// budget, a heterogeneous fleet under shape-aware routing must beat
/// the uniform all-square round-robin fleet on BOTH p99 latency and
/// total stream cycles.  The trace is deterministic and uncongested
/// (arrivals spaced past every service time), so the comparison
/// isolates shape fit from queueing luck — the same contract the
/// `serve_hetero` bench tier asserts at scale.
#[test]
fn hetero_fleet_beats_uniform_square_on_the_mixed_trace() {
    let mut run = RunConfig::small();
    run.geometry = ArrayGeometry::new(128, 128);
    run.verify_fraction = 0.0;
    let requests: Vec<TraceReq> = (0..40)
        .map(|i| TraceReq {
            at: i as u64 * 4_000,
            model: i % 2,
            rows: 2,
            kind: PipelineKind::Skewed,
            class: DeadlineClass::Interactive,
        })
        .collect();
    let base = FleetConfig {
        shards: 4,
        min_shards: 4,
        max_shards: 4,
        horizon: 400_000,
        autoscale_interval: 0,
        models: vec![ModelShape { k: 4096, n: 64 }, ModelShape { k: 512, n: 512 }],
        tenants: vec![TenantSpec {
            arrival: ArrivalSpec::Trace { requests },
            ..TenantSpec::poisson("mixed", 1.0)
        }],
        ..FleetConfig::default()
    };
    let uniform = FleetConfig { shard_policy: Policy::RoundRobin, ..base.clone() };
    let hetero = FleetConfig {
        shard_policy: Policy::ShapeAware,
        shard_geometries: vec![
            ArrayGeometry::new(256, 64),
            ArrayGeometry::new(64, 256),
            ArrayGeometry::new(128, 128),
            ArrayGeometry::new(128, 128),
        ],
        ..base
    };
    let budget = |f: &FleetConfig| -> usize {
        (0..4).map(|s| f.shard_geometry(s, run.geometry).pe_count()).sum()
    };
    assert_eq!(budget(&uniform), budget(&hetero), "the comparison is at equal silicon");

    let ru = FleetSim::simulate(&run, &uniform);
    let rh = FleetSim::simulate(&run, &hetero);
    assert_eq!(ru.served, 40);
    assert_eq!(rh.served, 40);
    assert!(ru.accounting_balanced() && rh.accounting_balanced());
    let (p99_u, p99_h) = (ru.latency.quantile(99.0), rh.latency.quantile(99.0));
    assert!(p99_h < p99_u, "hetero p99 {p99_h} must beat uniform {p99_u} on the mixed trace");
    assert!(
        rh.stream_cycles < ru.stream_cycles,
        "hetero stream cycles {} must beat uniform {}",
        rh.stream_cycles,
        ru.stream_cycles
    );
    // The decode projections all land on the tall shard and the CNN
    // layers on a square; nothing on this trace prefers the wide array.
    assert!(rh.shard_busy[0] > 0, "tall shard absorbed the decode stream");
    assert_eq!(rh.shard_busy[1], 0, "no request on this trace prefers the wide shard");
}
