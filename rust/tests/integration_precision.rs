//! Integration tests for the mixed-precision planner (DESIGN.md §12):
//! budget-extreme behaviour, the paper-workload acceptance run (every
//! planned layer meets its budget against the f64 oracle, and reduced-
//! precision plans are strictly cheaper in modeled energy), and the
//! serve-layer deployment of a mixed plan.

use skewsa::arith::format::FpFormat;
use skewsa::config::{RunConfig, ServeConfig};
use skewsa::pe::PipelineKind;
use skewsa::precision::{
    analyze_layer, layer_format_energy, plan_layers, AnalysisConfig, PlannerConfig,
    PrecisionStudy,
};
use skewsa::serve::{DeadlineClass, Server};
use skewsa::timing::model::TimingConfig;
use skewsa::workloads::mobilenet;
use skewsa::workloads::serving::WeightStore;
use std::sync::Arc;

fn planner_cfg(budget: f64) -> PlannerConfig {
    PlannerConfig {
        budget,
        kinds: vec![PipelineKind::Skewed],
        candidates: FpFormat::ALL.to_vec(),
        // Small sampled slice (full K): keeps the debug-mode oracle
        // sweep fast while still exercising every layer's real
        // accumulation depth.
        analysis: AnalysisConfig { m_cap: 4, n_cap: 4, seed: 0x5eed },
        tcfg: TimingConfig::PAPER,
    }
}

#[test]
fn zero_budget_always_plans_fp32() {
    let layers = mobilenet::layers();
    let plan = plan_layers(&layers[..6], &planner_cfg(0.0));
    for l in &plan.layers {
        assert_eq!(l.fmt, FpFormat::FP32, "{}", l.layer);
        assert!(!l.within_budget, "even FP32 quantizes inputs; zero budget is unmeetable");
    }
}

#[test]
fn infinite_budget_always_plans_the_cheapest_format() {
    let cfg = planner_cfg(f64::INFINITY);
    let layers = mobilenet::layers();
    let plan = plan_layers(&layers[..6], &cfg);
    for l in &plan.layers {
        let cheapest = FpFormat::ALL
            .iter()
            .map(|&f| (f, layer_format_energy(&cfg.tcfg, cfg.kinds[0], f, l.shape).0))
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .unwrap()
            .0;
        assert_eq!(l.fmt, cheapest, "{}", l.layer);
        assert!(l.within_budget);
    }
}

/// The acceptance run: `skewsa precision --workload mobilenet
/// --budget 1e-2` semantics — every layer of the emitted plan meets its
/// error budget (re-measured here against the f64 oracle), and the
/// reduced-precision uniform plans are strictly cheaper in modeled
/// energy than all-FP32.
#[test]
fn mobilenet_budget_1e2_meets_budget_and_beats_fp32_energy() {
    let cfg = planner_cfg(1e-2);
    let layers = mobilenet::layers();
    let study = PrecisionStudy::run(&layers, &cfg);
    let plan = &study.mixed;
    assert_eq!(plan.layers.len(), layers.len());
    assert!(plan.meets_budget(), "worst {}", plan.worst_rel());
    for (layer, lp) in layers.iter().zip(&plan.layers) {
        // Independent re-measurement against the f64 oracle.
        let again = analyze_layer(layer, lp.fmt, &cfg.analysis);
        assert!(
            again.stats.meets(cfg.budget),
            "{} in {}: {} > {}",
            lp.layer,
            lp.fmt.display_name(),
            again.stats.worst(),
            cfg.budget
        );
        assert_eq!(again.stats.max_rel, lp.stats.max_rel, "analysis must be deterministic");
    }
    // A 1% budget must admit reduced precision somewhere (MobileNet's
    // shallow depthwise layers are easy); all-FP32 would be a planner
    // regression.
    assert!(
        plan.layers.iter().any(|l| l.fmt != FpFormat::FP32),
        "1e-2 budget planned all-FP32"
    );

    // Pareto acceptance: BF16/FP8 uniform plans strictly cheaper in
    // modeled energy than the all-FP32 plan, and the mixed plan never
    // costs more than FP32.
    let energy = |name: &str| {
        study
            .plans()
            .into_iter()
            .find(|p| p.label == name)
            .map(|p| p.total_energy_uj())
            .unwrap()
    };
    let fp32 = energy("FP32");
    for reduced in ["BF16", "FP16", "FP8-E4M3", "FP8-E5M2"] {
        assert!(energy(reduced) < fp32, "{reduced} must undercut FP32 ({fp32} uJ)");
    }
    assert!(energy("mixed") <= fp32);
    assert!(energy("mixed") < fp32, "with reduced formats admitted, mixed must save energy");

    // Latency is format-independent: every plan shows the same cycles.
    let cycles: Vec<u64> = study.plans().iter().map(|p| p.total_cycles()).collect();
    assert!(cycles.windows(2).all(|w| w[0] == w[1]), "{cycles:?}");
}

/// Deploy a mixed-precision plan through the serving stack: each layer
/// registers in its planned format, requests ride the format-keyed plan
/// cache, and every served response stays bit-exact with a solo
/// coordinator run of the same request under the same chain.
#[test]
fn mixed_plan_serves_bit_exact_per_layer_formats() {
    let layers = &mobilenet::layers()[..3];
    let mut cfg = planner_cfg(f64::INFINITY);
    cfg.analysis.m_cap = 2;
    cfg.analysis.n_cap = 2;
    // Force a genuinely mixed assignment: plan under an infinite budget
    // (cheapest formats), then pin distinct formats per layer.
    let mut plan = plan_layers(layers, &cfg);
    plan.layers[0].fmt = FpFormat::BF16;
    plan.layers[1].fmt = FpFormat::FP8E5M2;
    plan.layers[2].fmt = FpFormat::FP16;

    let mut run = RunConfig::small();
    run.verify_fraction = 0.0;
    let store = Arc::new(WeightStore::from_plan(layers, &plan, 24, 16));
    assert_eq!(store.get(0).fmt, FpFormat::BF16);
    assert_eq!(store.get(1).fmt, FpFormat::FP8E5M2);
    assert_eq!(store.get(2).fmt, FpFormat::FP16);

    let server = Server::start(&run, &ServeConfig::small(), Arc::clone(&store));
    let mut rng = skewsa::util::rng::Rng::new(42);
    let mut pending = Vec::new();
    for model in 0..3 {
        for _ in 0..2 {
            let a = store.gen_activations(model, 3, &mut rng);
            let rx =
                server.submit(model, PipelineKind::Skewed, DeadlineClass::Interactive, a.clone());
            pending.push((model, a, rx));
        }
    }
    for (model, a, rx) in pending {
        let resp = rx.recv().expect("served");
        let got: Vec<u32> = resp.y.iter().map(|v| v.to_bits()).collect();
        let want = store.solo_reference_bits(&run, model, PipelineKind::Skewed, &a);
        assert_eq!(got, want, "model {model} served bits diverged from solo run");
    }
    let stats = server.stats();
    // Three distinct formats (and shapes) cannot share cache entries.
    assert!(stats.cache.misses >= 3, "{:?}", stats.cache);
}
