//! Property tests for the serve-layer plan cache: caching must be
//! invisible — a cache-hit plan is structurally identical to a freshly
//! built one across a random sweep of shapes × formats × pipeline kinds
//! × array geometries, including under eviction churn.

use skewsa::arith::format::FpFormat;
use skewsa::pe::PipelineKind;
use skewsa::sa::geometry::ArrayGeometry;
use skewsa::sa::tile::{GemmShape, TilePlan};
use skewsa::serve::{CachedPlan, PlanCache, PlanKey};
use skewsa::util::prop::{Gen, Prop};

const FMTS: [FpFormat; 5] = [
    FpFormat::BF16,
    FpFormat::FP16,
    FpFormat::FP8E4M3,
    FpFormat::FP8E5M2,
    FpFormat::FP32,
];
const KINDS: [PipelineKind; 2] = [PipelineKind::Baseline3b, PipelineKind::Skewed];

fn random_key(g: &mut Gen) -> PlanKey {
    PlanKey {
        shape: GemmShape::new(g.usize_in(1, 64), g.usize_in(1, 300), g.usize_in(1, 300)),
        fmt: *g.choose(&FMTS),
        kind: *g.choose(&KINDS),
        geom: ArrayGeometry::new(g.usize_in(1, 128), g.usize_in(1, 128)),
    }
}

#[test]
fn cache_hit_plans_structurally_identical_across_sweep() {
    // Roomy capacity: nothing is evicted, every second lookup must hit.
    let cache = PlanCache::new(1 << 14);
    Prop::new("plan-cache-structural-identity", 300).run(|g: &mut Gen| {
        let key = random_key(g);
        let (first, _) = cache.get(key);
        let (second, hit) = cache.get(key);
        g.assert("second lookup is a hit", hit);
        g.assert("hit equals first lookup", *first == *second);
        let fresh = CachedPlan::build(&key);
        g.assert("cached plan == fresh plan", second.plan == fresh.plan);
        g.assert("cached schedules == fresh schedules", second.schedules == fresh.schedules);
        g.assert_eq(
            "overlapped stream cycles",
            second.stream_cycles_overlapped,
            fresh.stream_cycles_overlapped,
        );
        g.assert_eq(
            "serialized stream cycles",
            second.stream_cycles_serialized,
            fresh.stream_cycles_serialized,
        );
        g.assert(
            "both disciplines match the timing model",
            second.stream_cycles(true) == fresh.plan.stream_cycles(key.kind, true)
                && second.stream_cycles(false) == fresh.plan.stream_cycles(key.kind, false),
        );
        g.assert(
            "fresh build is the canonical TilePlan",
            fresh.plan == TilePlan::for_geometry(key.shape, key.geom),
        );
        g.assert_eq("one schedule per tile", second.schedules.len(), second.plan.tile_count());
    });
    let stats = cache.stats();
    assert!(stats.hits >= 300, "every case re-looked its key up: {stats:?}");
    assert_eq!(stats.evictions, 0, "capacity was never exceeded: {stats:?}");
}

#[test]
fn small_cache_under_eviction_churn_still_builds_correct_plans() {
    let cache = PlanCache::new(8);
    Prop::new("plan-cache-churn", 200).run(|g: &mut Gen| {
        let key = random_key(g);
        let (p, _) = cache.get(key);
        g.assert("churned entry equals fresh build", *p == CachedPlan::build(&key));
    });
    let stats = cache.stats();
    assert!(stats.evictions > 0, "200 random keys must evict from 8 slots: {stats:?}");
    assert!(stats.entries <= 8, "{stats:?}");
}
