//! Property suite for the multi-tile streaming executor (ISSUE 5): a
//! whole [`TilePlan`] streamed through one array with double-buffered
//! weight preload must be
//!
//! 1. **bit-exact** against the per-tile oracle assembly (column-oracle
//!    tiles folded in K-pass order),
//! 2. **on the closed form**: total cycles, compute, exposed preload,
//!    drain and every per-tile span equal to
//!    [`skewsa::timing::layer_timing`] — for every registered
//!    [`PipelineKind`] *and* custom `(S, D, tail)` specs, in both
//!    `double_buffer` modes,
//! 3. stall-free, with the only exposed preload under double buffering
//!    being the first fill (`T > R` for every full-chain tile), and
//! 4. activity-consistent with running each tile through the single-tile
//!    fast simulator (serial-vs-streaming parity).
//!
//! This is the contract that lets the serve layer quote
//! `batch_stream_cycles` straight from the timing model: the simulator,
//! the closed form, and the reported service time are one number.

use skewsa::arith::accum::ColumnOracle;
use skewsa::arith::fma::ChainCfg;
use skewsa::arith::format::FpFormat;
use skewsa::pe::spec::{blk, Block, DatapathId, PipelineSpec, StageBlocks};
use skewsa::pe::{spec, PipelineKind};
use skewsa::sa::fast::FastArraySim;
use skewsa::sa::stream::StreamingSim;
use skewsa::sa::tile::{GemmShape, TilePlan};
use skewsa::timing::model::{layer_spans, layer_timing_spec, TimingConfig};
use skewsa::util::prop::{Gen, Prop};

const CFG: ChainCfg = ChainCfg::BF16_FP32;

fn bf(g: &mut Gen) -> u64 {
    FpFormat::BF16.from_f64(g.normal(0.0, 1.5))
}

fn random_gemm(g: &mut Gen, shape: GemmShape) -> (Vec<Vec<u64>>, Vec<Vec<u64>>) {
    let w = (0..shape.k).map(|_| (0..shape.n).map(|_| bf(g)).collect()).collect();
    let a = (0..shape.m).map(|_| (0..shape.k).map(|_| bf(g)).collect()).collect();
    (w, a)
}

/// Kind-independent reference: each tile's columns through the value
/// oracle, folded across K-passes in pass order with f32 adds — the
/// coordinator's assembly semantics, no cycle machinery at all.
fn oracle_assembly(plan: &TilePlan, w: &[Vec<u64>], a: &[Vec<u64>]) -> Vec<u32> {
    let shape = plan.shape;
    let mut y = vec![0.0f32; shape.m * shape.n];
    for t in &plan.tiles {
        for m in 0..shape.m {
            for j in 0..t.n_len {
                let mut o = ColumnOracle::new(CFG);
                for k in t.k0..t.k0 + t.k_len {
                    o.mac(a[m][k], w[k][t.n0 + j]);
                }
                y[m * shape.n + t.n0 + j] += f32::from_bits(o.result() as u32);
            }
        }
    }
    y.iter().map(|v| v.to_bits()).collect()
}

fn tcfg(plan: &TilePlan, double_buffer: bool) -> TimingConfig {
    TimingConfig { rows: plan.rows, cols: plan.cols, clock_ghz: 1.0, double_buffer }
}

/// Properties 1 + 2 over random multi-tile shapes, every registered
/// organisation, both preload disciplines.
#[test]
fn streaming_bit_exact_and_on_model_every_kind() {
    Prop::new("stream-bit-exact-on-model", 12).run(|g: &mut Gen| {
        let rows = g.usize_in(2, 10);
        let cols = g.usize_in(1, 8);
        let shape = GemmShape::new(
            g.usize_in(1, 8),
            g.usize_in(1, 3 * rows),  // up to 3 K-passes, edge tiles likely
            g.usize_in(1, 2 * cols),  // up to 2 N-blocks
        );
        let plan = TilePlan::new(shape, rows, cols);
        let (w, a) = random_gemm(g, shape);
        let want = oracle_assembly(&plan, &w, &a);
        for kind in PipelineKind::ALL {
            for db in [true, false] {
                let mut sim = StreamingSim::new(CFG, kind, &plan, &w, &a, db);
                let rep = sim.run(1_000_000).expect("stream run");
                let got: Vec<u32> = sim.result_f32().iter().map(|v| v.to_bits()).collect();
                g.assert(&format!("{kind} db={db}: bits == per-tile oracle"), got == want);
                g.assert(
                    &format!("{kind} db={db}: composition == layer_timing"),
                    sim.matches_layer_timing(),
                );
                let model = layer_timing_spec(&tcfg(&plan, db), *kind.spec(), &plan);
                g.assert_eq(
                    &format!("{kind} db={db}: total cycles"),
                    rep.cycles,
                    model.cycles,
                );
                g.assert(
                    &format!("{kind} db={db}: spans"),
                    rep.spans == layer_spans(&tcfg(&plan, db), *kind.spec(), &plan),
                );
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Custom (S, D, tail) organisations — the registry's extensibility axis.
// ---------------------------------------------------------------------------

/// A 4-stage table for the depth-4 custom spec (stage content only
/// feeds the delay/area models, which these properties don't touch).
const STAGES4: &[StageBlocks] = &[
    &[&[&[blk(Block::Mult)]]],
    &[&[&[blk(Block::ExpCompute)]]],
    &[&[&[blk(Block::Align)]], &[&[blk(Block::Add)], &[blk(Block::Lza)]]],
    &[&[&[blk(Block::Norm)]]],
];

/// Custom combos: capture at S=D=3, deep late-read (1,4,1), and a
/// tail-heavy skewed variant (1,2,2).
const CUSTOM: [PipelineSpec; 3] = [
    PipelineSpec {
        name: "custom-s3d3",
        aliases: &[],
        summary: "capture discipline at spacing 3",
        spacing: 3,
        depth: 3,
        column_tail: 0,
        stages: spec::DEEP3.stages,
        regs: spec::DEEP3.regs,
        datapath: DatapathId::Baseline,
    },
    PipelineSpec {
        name: "custom-s1d4",
        aliases: &[],
        summary: "deep late-read: S=1, D=4, tail 1",
        spacing: 1,
        depth: 4,
        column_tail: 1,
        stages: STAGES4,
        regs: spec::DEEP3.regs,
        datapath: DatapathId::Baseline,
    },
    PipelineSpec {
        name: "custom-s1d2t2",
        aliases: &[],
        summary: "skewed datapath with a 2-cycle column tail",
        spacing: 1,
        depth: 2,
        column_tail: 2,
        stages: spec::SKEWED.stages,
        regs: spec::SKEWED.regs,
        datapath: DatapathId::Skewed,
    },
];

#[test]
fn streaming_custom_spec_combos_on_model() {
    Prop::new("stream-custom-specs", 10).run(|g: &mut Gen| {
        let shape = GemmShape::new(g.usize_in(1, 6), g.usize_in(1, 20), g.usize_in(1, 10));
        let plan = TilePlan::new(shape, 8, 4);
        let (w, a) = random_gemm(g, shape);
        let want = oracle_assembly(&plan, &w, &a);
        for sp in CUSTOM {
            sp.validate();
            for db in [true, false] {
                let mut sim = StreamingSim::with_spec(CFG, sp, &plan, &w, &a, db);
                sim.run(1_000_000).expect("custom stream run");
                let got: Vec<u32> = sim.result_f32().iter().map(|v| v.to_bits()).collect();
                g.assert(&format!("{} db={db}: bits", sp.name), got == want);
                g.assert(
                    &format!("{} db={db}: on model", sp.name),
                    sim.matches_layer_timing(),
                );
            }
        }
    });
}

/// Property 3: under double buffering the only exposed preload is the
/// first fill (every full-chain stream covers the next fill, `T > R`),
/// and no lane ever stalls in either discipline.
#[test]
fn double_buffering_exposes_only_the_first_fill() {
    Prop::new("stream-overlap-hides-fills", 15).run(|g: &mut Gen| {
        let rows = g.usize_in(2, 12);
        let cols = g.usize_in(1, 6);
        let shape = GemmShape::new(
            g.usize_in(1, 6),
            g.usize_in(rows + 1, 4 * rows), // ≥ 2 K-pass tiles
            g.usize_in(1, cols),
        );
        let plan = TilePlan::new(shape, rows, cols);
        assert!(plan.tile_count() >= 2);
        let (w, a) = random_gemm(g, shape);
        let kind = *g.choose(&PipelineKind::ALL);
        let mut sim = StreamingSim::new(CFG, kind, &plan, &w, &a, true);
        let rep = sim.run(1_000_000).expect("run");
        g.assert_eq(
            &format!("{kind}: exposed == first fill"),
            rep.exposed_preload,
            rows as u64,
        );
        g.assert_eq(&format!("{kind}: zero stalls"), sim.stalls(), 0);
        let mut ser = StreamingSim::new(CFG, kind, &plan, &w, &a, false);
        let rep_s = ser.run(1_000_000).expect("run serial");
        g.assert_eq(&format!("{kind}: serial zero stalls"), ser.stalls(), 0);
        g.assert_eq(
            &format!("{kind}: overlap hides (tiles-1) fills"),
            rep_s.cycles - rep.cycles,
            (plan.tile_count() as u64 - 1) * rows as u64,
        );
    });
}

/// Property 4: serial-vs-streaming activity parity.  Each tile through
/// the single-tile fast simulator (zero-padded to the full chain, as
/// the stream runs it) accounts the same evaluations; the stream's
/// extra bubbles are exactly the idle-lane and preload-gap slots.
#[test]
fn activity_parity_with_per_tile_fast_sim() {
    Prop::new("stream-activity-parity", 10).run(|g: &mut Gen| {
        let rows = g.usize_in(2, 8);
        let cols = g.usize_in(2, 6);
        let shape = GemmShape::new(
            g.usize_in(1, 6),
            g.usize_in(1, 2 * rows),
            g.usize_in(1, 2 * cols),
        );
        let plan = TilePlan::new(shape, rows, cols);
        let (w, a) = random_gemm(g, shape);
        let kind = *g.choose(&PipelineKind::ALL);

        let mut stream = StreamingSim::new(CFG, kind, &plan, &w, &a, true);
        let rep = stream.run(1_000_000).expect("stream");
        let sact = stream.activity();

        let mut evals = 0u64;
        let mut bubbles = 0u64;
        let mut tile_cycles = 0u64;
        let mut live_slots = 0u64;
        for t in &plan.tiles {
            // Zero-padded to the full chain, exactly as the stream runs.
            let w_slab: Vec<Vec<u64>> = (0..rows)
                .map(|r| {
                    (0..t.n_len)
                        .map(|j| if r < t.k_len { w[t.k0 + r][t.n0 + j] } else { 0 })
                        .collect()
                })
                .collect();
            let a_slab: Vec<Vec<u64>> = a
                .iter()
                .map(|row| {
                    (0..rows)
                        .map(|r| if r < t.k_len { row[t.k0 + r] } else { 0 })
                        .collect()
                })
                .collect();
            let mut sim = FastArraySim::new(CFG, kind, &w_slab, &a_slab);
            sim.run(1_000_000).unwrap();
            let act = sim.activity();
            evals += act.s1_evals;
            bubbles += act.s1_bubbles;
            tile_cycles += sim.cycles();
            live_slots += (rows * t.n_len) as u64 * sim.cycles();
        }
        g.assert_eq(&format!("{kind}: eval parity"), sact.s1_evals, evals);
        g.assert_eq(&format!("{kind}: compute = sum of tiles"), rep.compute_cycles, tile_cycles);
        // Streaming bubbles = per-tile bubbles + slots the full array
        // spent outside each tile's live lanes (idle edge lanes and
        // preload gaps).
        let extra = (rows * cols) as u64 * rep.cycles - live_slots;
        g.assert_eq(&format!("{kind}: bubble parity"), sact.s1_bubbles, bubbles + extra);
    });
}

/// The serialized composition equals the historical per-tile sum — the
/// ablation number is unchanged by the fix; only the (correct)
/// double-buffered default moved.
#[test]
fn serialized_total_is_the_per_tile_sum() {
    Prop::new("stream-serialized-sum", 20).run(|g: &mut Gen| {
        let rows = g.usize_in(1, 16);
        let cols = g.usize_in(1, 16);
        let shape =
            GemmShape::new(g.usize_in(1, 32), g.usize_in(1, 64), g.usize_in(1, 48));
        let plan = TilePlan::new(shape, rows, cols);
        let kind = *g.choose(&PipelineKind::ALL);
        let sum: u64 = plan
            .schedules(kind)
            .iter()
            .map(|s| s.preload_cycles() + s.total_cycles())
            .sum();
        g.assert_eq("serialized == Σ(preload + stream)", plan.stream_cycles(kind, false), sum);
        g.assert(
            "overlapped ≤ serialized, gap = (tiles−1)·R",
            sum - plan.stream_cycles(kind, true) == (plan.tile_count() as u64 - 1) * rows as u64,
        );
    });
}
