//! Rectangular-geometry property suite (ISSUE 10): everything the
//! stack used to exercise only on square arrays must hold on tall,
//! wide and degenerate (`1×N`, `R×1`) geometries —
//!
//! 1. [`TilePlan`] partitions the `K×N` weight plane exactly, with the
//!    remainders on the edge tiles, for any geometry;
//! 2. the streaming simulator is bit-exact against the per-tile oracle
//!    assembly *and* lands on [`layer_timing_spec`]'s closed form for
//!    every registered organisation, both preload disciplines;
//! 3. at a fixed PE budget the closed form orders shapes the way the
//!    `skewsa geometry` sweep relies on: a reduction-deep decode GEMM
//!    runs strictly faster on the tall array than on the square, and
//!    square beats wide;
//! 4. ABFT detection/localization works on rectangular plans (block
//!    indices follow the plan's `cols`, not a hardcoded square).

use skewsa::arith::accum::ColumnOracle;
use skewsa::arith::fma::ChainCfg;
use skewsa::arith::format::FpFormat;
use skewsa::config::{NumericMode, RunConfig};
use skewsa::coordinator::{abft_check, Executor};
use skewsa::pe::PipelineKind;
use skewsa::precision::error::max_finite_f64;
use skewsa::sa::geometry::{sweep_geometries, ArrayGeometry};
use skewsa::sa::stream::StreamingSim;
use skewsa::sa::tile::{GemmShape, TilePlan};
use skewsa::timing::model::{layer_timing_spec, TimingConfig};
use skewsa::util::prop::{Gen, Prop};
use skewsa::workloads::gemm::GemmData;
use std::sync::Arc;

const CFG: ChainCfg = ChainCfg::BF16_FP32;

fn bf(g: &mut Gen) -> u64 {
    FpFormat::BF16.from_f64(g.normal(0.0, 1.5))
}

fn random_gemm(g: &mut Gen, shape: GemmShape) -> (Vec<Vec<u64>>, Vec<Vec<u64>>) {
    let w = (0..shape.k).map(|_| (0..shape.n).map(|_| bf(g)).collect()).collect();
    let a = (0..shape.m).map(|_| (0..shape.k).map(|_| bf(g)).collect()).collect();
    (w, a)
}

/// Kind-independent reference (same semantics as `prop_streaming.rs`):
/// each tile's columns through the value oracle, folded across K-passes
/// in pass order with f32 adds.
fn oracle_assembly(plan: &TilePlan, w: &[Vec<u64>], a: &[Vec<u64>]) -> Vec<u32> {
    let shape = plan.shape;
    let mut y = vec![0.0f32; shape.m * shape.n];
    for t in &plan.tiles {
        for m in 0..shape.m {
            for j in 0..t.n_len {
                let mut o = ColumnOracle::new(CFG);
                for k in t.k0..t.k0 + t.k_len {
                    o.mac(a[m][k], w[k][t.n0 + j]);
                }
                y[m * shape.n + t.n0 + j] += f32::from_bits(o.result() as u32);
            }
        }
    }
    y.iter().map(|v| v.to_bits()).collect()
}

#[test]
fn tile_plan_partitions_any_geometry_exactly() {
    let geoms = [(256, 32), (32, 256), (1, 7), (7, 1), (128, 128), (5, 3)];
    let shapes = [GemmShape::new(4, 100, 50), GemmShape::new(1, 7, 13), GemmShape::new(9, 1, 1)];
    for &(r, c) in &geoms {
        let geom = ArrayGeometry::new(r, c);
        for &shape in &shapes {
            let plan = TilePlan::for_geometry(shape, geom);
            assert_eq!(plan.geometry(), geom);
            assert_eq!(plan.k_tiles(), shape.k.div_ceil(r), "{geom} {shape:?}");
            assert_eq!(plan.n_tiles(), shape.n.div_ceil(c), "{geom} {shape:?}");
            assert_eq!(plan.tile_count(), plan.k_tiles() * plan.n_tiles());
            // The tiles partition the K×N weight plane exactly: full
            // tiles carry (r, c), edge tiles the remainders, and the
            // areas sum back to K·N.
            let mut area = 0usize;
            for t in &plan.tiles {
                assert!(t.k_len >= 1 && t.k_len <= r, "{geom}: k_len {}", t.k_len);
                assert!(t.n_len >= 1 && t.n_len <= c, "{geom}: n_len {}", t.n_len);
                assert!(t.k0 + t.k_len <= shape.k && t.n0 + t.n_len <= shape.n);
                area += t.k_len * t.n_len;
            }
            assert_eq!(area, shape.k * shape.n, "{geom} {shape:?}: not a partition");
        }
    }
}

#[test]
fn streaming_matches_oracle_and_model_on_random_rectangles() {
    Prop::new("geometry-stream-bit-exact-on-model", 10).run(|g: &mut Gen| {
        // Bias toward asymmetric and degenerate geometries: the square
        // path is already covered by prop_streaming.
        let (rows, cols) = match g.usize_in(0, 3) {
            0 => (1, g.usize_in(2, 7)),
            1 => (g.usize_in(2, 9), 1),
            2 => (g.usize_in(5, 9), g.usize_in(1, 3)),
            _ => (g.usize_in(1, 3), g.usize_in(4, 7)),
        };
        let shape = GemmShape::new(
            g.usize_in(1, 5),
            g.usize_in(1, 3 * rows),
            g.usize_in(1, 2 * cols),
        );
        let plan = TilePlan::for_geometry(shape, ArrayGeometry::new(rows, cols));
        let (w, a) = random_gemm(g, shape);
        let want = oracle_assembly(&plan, &w, &a);
        for kind in PipelineKind::ALL {
            for db in [true, false] {
                let mut sim = StreamingSim::new(CFG, kind, &plan, &w, &a, db);
                let rep = sim.run(1_000_000).expect("stream run");
                let got: Vec<u32> = sim.result_f32().iter().map(|v| v.to_bits()).collect();
                g.assert(&format!("{rows}x{cols} {kind} db={db}: bits"), got == want);
                let tcfg = TimingConfig { rows, cols, clock_ghz: 1.0, double_buffer: db };
                g.assert_eq(
                    &format!("{rows}x{cols} {kind} db={db}: cycles"),
                    rep.cycles,
                    layer_timing_spec(&tcfg, *kind.spec(), &plan).cycles,
                );
            }
        }
    });
}

#[test]
fn fixed_budget_ordering_tall_beats_square_beats_wide_on_decode() {
    // The premise the geometry subcommand and the hetero fleet monetize:
    // a K≫N decode projection at a fixed PE budget prefers rows.  The
    // sweep is tall-to-wide, so the closed-form totals must be strictly
    // increasing across it for this shape — and strictly decreasing for
    // the transposed (output-wide) GEMM.
    let geoms = sweep_geometries(16384, 4.0);
    assert_eq!(
        geoms,
        [ArrayGeometry::new(256, 64), ArrayGeometry::new(128, 128), ArrayGeometry::new(64, 256)]
    );
    let decode = GemmShape::new(4, 4096, 64);
    let wide_out = GemmShape::new(4, 64, 4096);
    for kind in [PipelineKind::Baseline3b, PipelineKind::Skewed] {
        for db in [true, false] {
            let cyc = |shape: GemmShape, g: ArrayGeometry| {
                TilePlan::for_geometry(shape, g).stream_cycles(kind, db)
            };
            let d: Vec<u64> = geoms.iter().map(|&g| cyc(decode, g)).collect();
            assert!(d[0] < d[1] && d[1] < d[2], "{kind} db={db}: decode {d:?}");
            let w: Vec<u64> = geoms.iter().map(|&g| cyc(wide_out, g)).collect();
            assert!(w[0] > w[1] && w[1] > w[2], "{kind} db={db}: wide-out {w:?}");
        }
    }
}

#[test]
fn abft_localizes_corruption_on_rectangular_plans() {
    let shape = GemmShape::new(5, 12, 9); // single K-pass on every geometry below
    for (r, c) in [(16, 4), (12, 3), (16, 2)] {
        let mut cfg = RunConfig::small();
        cfg.geometry = ArrayGeometry::new(r, c);
        cfg.verify_fraction = 0.0;
        cfg.mode = NumericMode::Oracle;
        let chain = cfg.chain();
        let plan = TilePlan::for_geometry(shape, cfg.geometry);
        let data = GemmData::integer_valued(shape, cfg.in_fmt, 0x9e0 + r as u64);
        let ex = Executor::new(cfg, PipelineKind::Skewed);
        let mut y = ex.run(&Arc::new(data.clone()), &plan).y;
        assert!(abft_check(&chain, &plan, &data, &y).clean(), "{r}x{c}: clean false positive");
        let n_blocks = shape.n.div_ceil(c);
        assert!(n_blocks >= 3, "sweep must cover multi-block localization");
        let loud =
            f32::from_bits(chain.out_fmt.from_f64(0.5 * max_finite_f64(chain.out_fmt)) as u32);
        for blk in 0..n_blocks {
            let i = blk * c;
            let old = y[i];
            y[i] = loud;
            let rep = abft_check(&chain, &plan, &data, &y);
            assert_eq!(rep.suspect_blocks, vec![blk], "{r}x{c}: block {blk} mislocalized");
            y[i] = old;
        }
        assert!(abft_check(&chain, &plan, &data, &y).clean());
    }
}
