//! Property suite for the pipeline-organisation registry (ISSUE 4):
//! every registered [`PipelineSpec`] must drive the cycle simulators
//! bit-exactly against the value oracle AND land every output on the
//! generalized closed-form schedule
//! `T = (M−1) + (C_used−1) + S·(R−1) + D + 1 + tail`,
//! with zero stalls, on random shapes — including the edge tiles a
//! `TilePlan` produces.  This is the contract that makes the registry
//! extensible: a new organisation that satisfies `PipelineSpec::validate`
//! and these properties is a first-class citizen of every layer above.

use skewsa::arith::accum::ColumnOracle;
use skewsa::arith::fma::ChainCfg;
use skewsa::arith::format::FpFormat;
use skewsa::pe::PipelineKind;
use skewsa::sa::array::ArraySim;
use skewsa::sa::column::ColumnSim;
use skewsa::sa::dataflow::WsSchedule;
use skewsa::sa::fast::FastArraySim;
use skewsa::sa::tile::{GemmShape, TilePlan};
use skewsa::util::prop::{Gen, Prop};

const CFG: ChainCfg = ChainCfg::BF16_FP32;

fn bf(g: &mut Gen) -> u64 {
    FpFormat::BF16.from_f64(g.normal(0.0, 1.5))
}

fn random_case(g: &mut Gen, m: usize, r: usize, c: usize) -> (Vec<Vec<u64>>, Vec<Vec<u64>>) {
    let w: Vec<Vec<u64>> = (0..r).map(|_| (0..c).map(|_| bf(g)).collect()).collect();
    let a: Vec<Vec<u64>> = (0..m).map(|_| (0..r).map(|_| bf(g)).collect()).collect();
    (w, a)
}

fn oracle_bits(w: &[Vec<u64>], a: &[Vec<u64>]) -> Vec<Vec<u64>> {
    a.iter()
        .map(|arow| {
            (0..w[0].len())
                .map(|c| {
                    let mut o = ColumnOracle::new(CFG);
                    for (r, wrow) in w.iter().enumerate() {
                        o.mac(arow[r], wrow[c]);
                    }
                    o.result()
                })
                .collect()
        })
        .collect()
}

/// Fast sim vs oracle + closed form, all registered kinds, random shapes.
#[test]
fn every_spec_fast_sim_matches_oracle_and_formula() {
    Prop::new("pipelines-fast-oracle-formula", 40).run(|g| {
        let m = g.usize_in(1, 12);
        let r = g.usize_in(1, 24);
        let c = g.usize_in(1, 10);
        let (w, a) = random_case(g, m, r, c);
        let want = oracle_bits(&w, &a);
        for kind in PipelineKind::ALL {
            let sp = kind.spec();
            let mut sim = FastArraySim::new(CFG, kind, &w, &a);
            let sched = *sim.schedule();
            if sim.run(1_000_000).is_err() {
                g.assert(&format!("{kind}: run must not error"), false);
                continue;
            }
            g.assert_eq(&format!("{kind}: bits m={m} r={r} c={c}"), sim.result_bits(), want.clone());
            let t = (m as u64 - 1)
                + (c as u64 - 1)
                + sp.spacing * (r as u64 - 1)
                + sp.depth
                + 1
                + sp.column_tail;
            g.assert_eq(&format!("{kind}: total cycles"), sim.cycles(), t);
            g.assert_eq(&format!("{kind}: stalls"), sim.stalls(), 0);
            g.assert(&format!("{kind}: per-output schedule"), sim.latency_matches_schedule());
            g.assert_eq(&format!("{kind}: model agrees"), sched.total_cycles(), t);
        }
    });
}

/// Dense reference loop parity: bits, cycles, stalls, merged activity.
#[test]
fn every_spec_dense_and_fast_agree() {
    Prop::new("pipelines-dense-fast-parity", 15).run(|g| {
        let m = g.usize_in(1, 8);
        let r = g.usize_in(1, 12);
        let c = g.usize_in(1, 6);
        let (w, a) = random_case(g, m, r, c);
        for kind in PipelineKind::ALL {
            let mut dense = ArraySim::new(CFG, kind, &w, a.clone());
            if dense.run(1_000_000).is_err() {
                g.assert(&format!("{kind}: dense run must not error"), false);
                continue;
            }
            let mut fast = FastArraySim::new(CFG, kind, &w, &a);
            if fast.run(1_000_000).is_err() {
                g.assert(&format!("{kind}: fast run must not error"), false);
                continue;
            }
            g.assert_eq(&format!("{kind}: bits"), fast.result_bits(), dense.result_bits());
            g.assert_eq(&format!("{kind}: cycles"), fast.cycles(), dense.cycles());
            g.assert_eq(&format!("{kind}: stalls"), fast.stalls(), dense.stalls);
            g.assert_eq(&format!("{kind}: activity"), fast.activity(), dense.activity());
        }
    });
}

/// Column chains: every output lands on `output_cycle`, bit-exact.
#[test]
fn every_spec_column_on_schedule() {
    Prop::new("pipelines-column-schedule", 40).run(|g| {
        let m = g.usize_in(1, 20);
        let r = g.usize_in(1, 32);
        let (w2, a) = random_case(g, m, r, 1);
        let w: Vec<u64> = w2.iter().map(|row| row[0]).collect();
        let want: Vec<u64> = oracle_bits(&w2, &a).iter().map(|row| row[0]).collect();
        for kind in PipelineKind::ALL {
            let mut sim = ColumnSim::new(CFG, kind, &w, a.clone());
            if sim.run(1_000_000).is_err() {
                g.assert(&format!("{kind}: column run must not error"), false);
                continue;
            }
            let got: Vec<u64> = sim.outputs().iter().map(|o| o.bits).collect();
            g.assert_eq(&format!("{kind}: column bits m={m} r={r}"), got, want.clone());
            let sched = WsSchedule::new(kind, r, 1, m);
            g.assert_eq(&format!("{kind}: column cycles"), sim.cycles(), sched.total_cycles());
            for o in sim.outputs() {
                g.assert_eq(
                    &format!("{kind}: output {} cycle", o.m),
                    o.cycle,
                    sched.output_cycle(0, o.m),
                );
            }
            g.assert_eq(&format!("{kind}: column stalls"), sim.stalls, 0);
        }
    });
}

/// Edge tiles from a real `TilePlan` (short K- and N-edges): the slab
/// the executor would run stays bit-exact and on-formula for every
/// registered organisation.
#[test]
fn every_spec_edge_tiles_bit_exact() {
    Prop::new("pipelines-edge-tiles", 12).run(|g| {
        let rows = g.usize_in(2, 8);
        let cols = g.usize_in(2, 8);
        // Shapes that do NOT divide the array evenly → edge tiles.
        let shape = GemmShape::new(
            g.usize_in(1, 6),
            rows * g.usize_in(1, 2) + g.usize_in(1, rows - 1),
            cols * g.usize_in(1, 2) + g.usize_in(1, cols - 1),
        );
        let plan = TilePlan::new(shape, rows, cols);
        let w: Vec<Vec<u64>> =
            (0..shape.k).map(|_| (0..shape.n).map(|_| bf(g)).collect()).collect();
        let a: Vec<Vec<u64>> =
            (0..shape.m).map(|_| (0..shape.k).map(|_| bf(g)).collect()).collect();
        // The last tile is short on both axes by construction.
        let tile = *plan.tiles.last().unwrap();
        g.assert("edge tile is short", tile.k_len < rows && tile.n_len < cols);
        let w_slab = plan.weight_slab(&w, &tile);
        let a_slab = plan.activation_slab(&a, &tile);
        let want = oracle_bits(&w_slab, &a_slab);
        for kind in PipelineKind::ALL {
            let mut sim = FastArraySim::new(CFG, kind, &w_slab, &a_slab);
            if sim.run(1_000_000).is_err() {
                g.assert(&format!("{kind}: edge-tile run must not error"), false);
                continue;
            }
            g.assert_eq(&format!("{kind}: edge-tile bits"), sim.result_bits(), want.clone());
            g.assert(&format!("{kind}: edge-tile schedule"), sim.latency_matches_schedule());
            g.assert_eq(&format!("{kind}: edge-tile stalls"), sim.stalls(), 0);
        }
    });
}
