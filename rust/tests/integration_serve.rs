//! Serve-layer integration: served results must be bit-exact with a
//! direct `Coordinator::run_gemm` of the same request (the serving
//! stack may batch, cache and shard, but it may never change a bit),
//! across pipeline kinds and formats; plus batching, shard-spread,
//! plan-cache and fault-resilience behaviour end-to-end.

use skewsa::arith::format::FpFormat;
use skewsa::config::{NumericMode, RunConfig, ServeConfig};
use skewsa::coordinator::{FaultPlan, Policy};
use skewsa::pe::PipelineKind;
use skewsa::sa::geometry::ArrayGeometry;
use skewsa::serve::{recv_response, DeadlineClass, Server};
use skewsa::util::rng::Rng;
use skewsa::workloads::mobilenet;
use skewsa::workloads::serving::WeightStore;
use std::sync::Arc;

fn run_cfg(fmt: FpFormat) -> RunConfig {
    let mut cfg = RunConfig::small();
    cfg.geometry = ArrayGeometry::new(16, 16);
    cfg.in_fmt = fmt;
    cfg.out_fmt = FpFormat::FP32;
    cfg.verify_fraction = 0.0;
    cfg
}

/// Run one request's GEMM directly through a fresh coordinator: the
/// golden reference the serving path must match bit-for-bit (the
/// canonical helper shared with `bench_serve`).
fn solo_bits(
    cfg: &RunConfig,
    store: &WeightStore,
    model: usize,
    kind: PipelineKind,
    a: &[Vec<u64>],
) -> Vec<u32> {
    store.solo_reference_bits(cfg, model, kind, a)
}

#[test]
fn served_bit_exact_vs_coordinator_all_formats_and_kinds() {
    // Acceptance sweep: both pipeline kinds × FP32 + BF16 + FP8-E4M3.
    for fmt in [FpFormat::FP32, FpFormat::BF16, FpFormat::FP8E4M3] {
        let cfg = run_cfg(fmt);
        // K=40 → 3 K-passes, N=24 → 2 N-blocks on the 16×16 array:
        // multi-tile assembly is on the served path.
        let store =
            Arc::new(WeightStore::from_layers(&mobilenet::layers()[..4], fmt, 40, 24));
        let server = Server::start(&cfg, &ServeConfig::small(), Arc::clone(&store));
        let mut rng = Rng::new(0x1234 ^ fmt.man_bits as u64);
        for kind in [PipelineKind::Baseline3b, PipelineKind::Skewed] {
            for model in 0..store.len() {
                let a = store.gen_activations(model, 3, &mut rng);
                let rx = server.submit(model, kind, DeadlineClass::Interactive, a.clone());
                let resp = recv_response(&rx, "format/kind sweep");
                let got: Vec<u32> = resp.y.iter().map(|v| v.to_bits()).collect();
                let want = solo_bits(&cfg, &store, model, kind, &a);
                assert_eq!(got, want, "{} {kind} model {model}", fmt.name);
            }
        }
    }
}

#[test]
fn batched_requests_stay_bit_exact_per_member() {
    let cfg = run_cfg(FpFormat::BF16);
    let store = Arc::new(WeightStore::from_layers(
        &mobilenet::layers()[..1],
        FpFormat::BF16,
        27,
        16,
    ));
    let mut scfg = ServeConfig::small();
    // A long window that the request cap closes early: all six
    // pre-submitted compatible requests coalesce, deterministically.
    scfg.batch_window_us = 2_000_000;
    scfg.max_batch_requests = 6;
    let server = Server::start(&cfg, &scfg, Arc::clone(&store));
    let mut rng = Rng::new(7);
    let mut submitted = Vec::new();
    for _ in 0..6 {
        let a = store.gen_activations(0, 2, &mut rng);
        let rx = server.submit(0, PipelineKind::Skewed, DeadlineClass::Batch, a.clone());
        submitted.push((a, rx));
    }
    let mut max_batch = 0usize;
    for (a, rx) in submitted {
        let resp = recv_response(&rx, "batched member");
        max_batch = max_batch.max(resp.batch_size);
        let got: Vec<u32> = resp.y.iter().map(|v| v.to_bits()).collect();
        let want = solo_bits(&cfg, &store, 0, PipelineKind::Skewed, &a);
        assert_eq!(got, want, "batched member diverged from its solo run");
    }
    assert!(max_batch >= 2, "dynamic batching coalesced nothing");
    let stats = server.stats();
    let batches: u64 = stats.shards.iter().map(|s| s.batches).sum();
    assert!(batches < 6, "six requests ran as {batches} batches — no coalescing");
}

#[test]
fn cycle_accurate_serving_matches_oracle_serving() {
    let store = Arc::new(WeightStore::from_layers(
        &mobilenet::layers()[..2],
        FpFormat::BF16,
        12,
        8,
    ));
    let serve_bits = |mode: NumericMode| -> Vec<Vec<u32>> {
        let mut cfg = run_cfg(FpFormat::BF16);
        cfg.geometry = ArrayGeometry::new(8, 8);
        cfg.mode = mode;
        let server = Server::start(&cfg, &ServeConfig::small(), Arc::clone(&store));
        let mut out = Vec::new();
        let mut rng = Rng::new(0xc1c1e);
        for model in 0..store.len() {
            let a = store.gen_activations(model, 2, &mut rng);
            let rx = server.submit(model, PipelineKind::Skewed, DeadlineClass::Interactive, a);
            let resp = recv_response(&rx, "mode cross-check");
            out.push(resp.y.iter().map(|v| v.to_bits()).collect());
        }
        out
    };
    assert_eq!(serve_bits(NumericMode::Oracle), serve_bits(NumericMode::CycleAccurate));
}

#[test]
fn batched_cycle_accurate_serving_stays_bit_exact_per_member() {
    // Row-independence under stacking is exactly what batching relies
    // on (DESIGN.md §7/§11); assert it holds on the *cycle-accurate*
    // path too: a coalesced batch through the multi-tile streaming
    // simulator must reproduce each member's solo cycle-accurate run
    // bit-for-bit.
    let mut cfg = run_cfg(FpFormat::BF16);
    cfg.geometry = ArrayGeometry::new(8, 8);
    cfg.mode = NumericMode::CycleAccurate;
    let store = Arc::new(WeightStore::from_layers(
        &mobilenet::layers()[..1],
        FpFormat::BF16,
        12,
        8,
    ));
    let mut scfg = ServeConfig::small();
    scfg.batch_window_us = 2_000_000;
    scfg.max_batch_requests = 4;
    let server = Server::start(&cfg, &scfg, Arc::clone(&store));
    let mut rng = Rng::new(0xbc1c1e);
    let mut submitted = Vec::new();
    for _ in 0..4 {
        let a = store.gen_activations(0, 2, &mut rng);
        let rx = server.submit(0, PipelineKind::Skewed, DeadlineClass::Batch, a.clone());
        submitted.push((a, rx));
    }
    let mut max_batch = 0usize;
    for (a, rx) in submitted {
        let resp = recv_response(&rx, "cycle-accurate batched member");
        max_batch = max_batch.max(resp.batch_size);
        let got: Vec<u32> = resp.y.iter().map(|v| v.to_bits()).collect();
        let want = solo_bits(&cfg, &store, 0, PipelineKind::Skewed, &a);
        assert_eq!(got, want, "cycle-accurate batched member diverged from its solo run");
    }
    assert!(max_batch >= 2, "cycle-accurate requests did not coalesce");
}

#[test]
fn reported_service_time_pins_the_overlapped_timing_model() {
    // ISSUE 5 acceptance: `skewsa serve`'s batch_stream_cycles must be
    // the same number as the closed-form layer timing — which the
    // streaming cycle simulator pins exactly (and, in cycle-accurate
    // mode, re-derives by simulation on the serve path itself, asserted
    // inside the shard).  Covers both double_buffer modes.
    use skewsa::sa::tile::{GemmShape, TilePlan};
    use skewsa::timing::model::{layer_timing, TimingConfig};
    let store = Arc::new(WeightStore::from_layers(
        &mobilenet::layers()[..2],
        FpFormat::BF16,
        40, // 3 K-passes on the 16×16 array
        24, // 2 N-blocks
    ));
    for mode in [NumericMode::Oracle, NumericMode::CycleAccurate] {
        for db in [true, false] {
            let mut cfg = run_cfg(FpFormat::BF16);
            cfg.mode = mode;
            cfg.double_buffer = db;
            let server = Server::start(&cfg, &ServeConfig::small(), Arc::clone(&store));
            let mut rng = Rng::new(0x7157 ^ db as u64);
            for model in 0..store.len() {
                let m = 3 + model;
                let a = store.gen_activations(model, m, &mut rng);
                let rx = server.submit(model, PipelineKind::Skewed, DeadlineClass::Interactive, a);
                let resp = recv_response(&rx, "timing pin");
                assert_eq!(resp.batch_size, 1, "quiet server: request runs alone");
                let entry = store.get(model);
                let shape = GemmShape::new(m, entry.k, entry.n);
                let plan = TilePlan::for_geometry(shape, cfg.geometry);
                assert!(plan.tile_count() >= 2, "multi-tile on the served path");
                let tcfg = TimingConfig {
                    geom: cfg.geometry,
                    clock_ghz: cfg.clock_ghz,
                    double_buffer: db,
                };
                let model_cycles = layer_timing(&tcfg, PipelineKind::Skewed, &plan).cycles;
                assert_eq!(
                    resp.batch_stream_cycles, model_cycles,
                    "mode={mode:?} db={db} model={model}: serve and timing model disagree"
                );
                assert_eq!(
                    resp.batch_stream_cycles,
                    plan.stream_cycles(PipelineKind::Skewed, db),
                    "mode={mode:?} db={db}: TilePlan::stream_cycles drifted"
                );
            }
        }
    }
}

#[test]
fn round_robin_shards_split_sequential_batches_evenly() {
    let cfg = run_cfg(FpFormat::BF16);
    let store = Arc::new(WeightStore::from_layers(
        &mobilenet::layers()[..3],
        FpFormat::BF16,
        24,
        16,
    ));
    let mut scfg = ServeConfig::small();
    scfg.shards = 3;
    scfg.shard_policy = Policy::RoundRobin;
    scfg.batch_window_us = 0;
    let server = Server::start(&cfg, &scfg, Arc::clone(&store));
    let mut rng = Rng::new(11);
    for i in 0..12 {
        let class = if i % 2 == 0 { DeadlineClass::Interactive } else { DeadlineClass::Batch };
        let kind =
            if i % 3 == 0 { PipelineKind::Baseline3b } else { PipelineKind::Skewed };
        let a = store.gen_activations(i % 3, 2, &mut rng);
        // Sequential closed loop: every request runs as its own batch.
        let resp = recv_response(&server.submit(i % 3, kind, class, a), "round-robin");
        assert_eq!(resp.batch_size, 1);
        assert!(resp.shard < 3);
    }
    let stats = server.stats();
    assert_eq!(stats.submitted, 12);
    for (i, s) in stats.shards.iter().enumerate() {
        assert_eq!(s.batches, 4, "round-robin splits 12 batches 4/4/4, shard {i}: {stats:?}");
    }
}

#[test]
fn hot_shapes_hit_the_plan_cache() {
    let cfg = run_cfg(FpFormat::BF16);
    let store = Arc::new(WeightStore::from_layers(
        &mobilenet::layers()[..1],
        FpFormat::BF16,
        27,
        16,
    ));
    let server = Server::start(&cfg, &ServeConfig::small(), Arc::clone(&store));
    let mut rng = Rng::new(3);
    for i in 0..5 {
        // Same model, same row count, sequential: one hot shape.
        let a = store.gen_activations(0, 4, &mut rng);
        let rx = server.submit(0, PipelineKind::Skewed, DeadlineClass::Interactive, a);
        let resp = recv_response(&rx, "plan-cache hit");
        assert_eq!(resp.cache_hit, i > 0, "request {i}");
    }
    let stats = server.stats();
    assert_eq!(stats.cache.misses, 1);
    assert_eq!(stats.cache.hits, 4);
    assert_eq!(stats.cache.entries, 1);
}

#[test]
fn serving_survives_an_always_failing_worker_in_every_shard() {
    let cfg = run_cfg(FpFormat::BF16);
    let store = Arc::new(WeightStore::from_layers(
        &mobilenet::layers()[..2],
        FpFormat::BF16,
        24,
        16,
    ));
    let server = Server::start_with_fault(
        &cfg,
        &ServeConfig::small(),
        Arc::clone(&store),
        FaultPlan::always(0),
    );
    let mut rng = Rng::new(0xfa11);
    for i in 0..6 {
        let a = store.gen_activations(i % 2, 3, &mut rng);
        let rx = server.submit(i % 2, PipelineKind::Skewed, DeadlineClass::Interactive, a.clone());
        let resp = recv_response(&rx, "served despite faults");
        assert!(resp.retries >= 1, "worker 0 always fails first: request {i}");
        let got: Vec<u32> = resp.y.iter().map(|v| v.to_bits()).collect();
        let want = solo_bits(&cfg, &store, i % 2, PipelineKind::Skewed, &a);
        assert_eq!(got, want, "fault recovery changed bits on request {i}");
    }
}
