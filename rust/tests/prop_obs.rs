//! Property tests for the metrics registry (DESIGN.md §17):
//! counter snapshots are monotone — both across successive snapshots
//! under concurrent writers (the invariant `MetricsRegistry::snapshot`
//! documents) and under out-of-order `absorb` publishing — and the
//! log2 histogram's quantiles stay inside the documented one-sub-bucket
//! relative error across seeds.

use skewsa::obs::{Log2Histogram, MetricsRegistry, REL_QUANTILE_ERROR};
use skewsa::serve::percentile_ns;
use skewsa::util::rng::Rng;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

#[test]
fn counter_snapshots_are_monotone_under_concurrent_writers() {
    const WRITERS: usize = 4;
    const OPS: u64 = 20_000;
    let reg = Arc::new(MetricsRegistry::new());
    let done = Arc::new(AtomicBool::new(false));
    let writers: Vec<_> = (0..WRITERS)
        .map(|t| {
            let reg = Arc::clone(&reg);
            std::thread::spawn(move || {
                let own = reg.counter(&format!("w{t}.ops"));
                let shared = reg.counter("shared.total");
                let hwm = reg.counter("shared.hwm");
                let mut rng = Rng::new(0x0b5 + t as u64);
                for _ in 0..OPS {
                    own.add(1 + rng.below(3));
                    shared.inc();
                    // Out-of-order publishing of a monotone source: the
                    // running max must still never regress.
                    hwm.absorb(rng.below(1_000_000));
                }
            })
        })
        .collect();
    // Reader: successive snapshots never show any counter going down.
    let reader = {
        let reg = Arc::clone(&reg);
        let done = Arc::clone(&done);
        std::thread::spawn(move || {
            let mut prev = reg.snapshot();
            let mut rounds = 0u64;
            while !done.load(Ordering::Relaxed) {
                let next = reg.snapshot();
                for (name, &v) in &next.counters {
                    let was = prev.counter(name);
                    assert!(
                        v >= was,
                        "counter `{name}` regressed across snapshots: {was} -> {v}"
                    );
                }
                prev = next;
                rounds += 1;
            }
            rounds
        })
    };
    for w in writers {
        w.join().unwrap();
    }
    done.store(true, Ordering::Relaxed);
    let rounds = reader.join().unwrap();
    assert!(rounds > 0, "the reader never got to observe a snapshot");
    // The final snapshot is exact where the arithmetic is knowable.
    let snap = reg.snapshot();
    assert_eq!(snap.counter("shared.total"), WRITERS as u64 * OPS);
    assert_eq!(snap.counter_sum("shared."), snap.counter("shared.total") + snap.counter("shared.hwm"));
    for t in 0..WRITERS {
        let v = snap.counter(&format!("w{t}.ops"));
        assert!((OPS..=3 * OPS).contains(&v), "w{t}.ops = {v} outside its add range");
    }
}

#[test]
fn absorb_tracks_the_running_max_under_any_order() {
    for seed in 0..20u64 {
        let reg = MetricsRegistry::new();
        let c = reg.counter("hwm");
        let mut rng = Rng::new(0xab5 ^ seed);
        let mut max = 0u64;
        for _ in 0..500 {
            let v = rng.below(1 << 40);
            c.absorb(v);
            max = max.max(v);
            assert_eq!(c.get(), max, "seed {seed}: absorb is not a running max");
        }
        assert_eq!(reg.snapshot().counter("hwm"), max);
    }
}

#[test]
fn histogram_quantiles_stay_within_documented_error_across_seeds() {
    for seed in 0..8u64 {
        let h = Log2Histogram::new();
        let mut rng = Rng::new(0x4157 ^ seed.wrapping_mul(0x9e37_79b9));
        let mut exact: Vec<u64> = Vec::with_capacity(50_000);
        for _ in 0..50_000 {
            // Log-uniform across ~18 octaves, exercising both the exact
            // low buckets and the sub-bucketed octaves.
            let v = 1u64 << rng.below(18);
            let v = v + rng.below(v.max(1));
            h.record(v);
            exact.push(v);
        }
        exact.sort_unstable();
        let snap = h.snapshot();
        assert_eq!(snap.count, 50_000);
        assert_eq!(snap.sum, exact.iter().sum::<u64>(), "the sum is tracked exactly");
        for p in [10.0, 50.0, 90.0, 95.0, 99.0, 99.9, 100.0] {
            let got = snap.quantile(p) as f64;
            let want = percentile_ns(&exact, p) as f64;
            assert!(
                (got - want).abs() <= want * REL_QUANTILE_ERROR,
                "seed {seed} p{p}: got {got} want {want} (±{:.1}%)",
                REL_QUANTILE_ERROR * 100.0
            );
        }
    }
}
