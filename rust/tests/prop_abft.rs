//! ABFT property suite: across every supported format × every
//! registered pipeline organisation, a clean executor run must produce
//! zero ABFT false positives (the tolerance covers legitimate
//! reduced-precision deviation — including `deep3`, which shares the
//! oracle semantics), while corrupting any N-block of the assembled
//! result far above the tolerance must be detected and localized to
//! exactly that block.

use skewsa::arith::fma::ChainCfg;
use skewsa::config::{NumericMode, RunConfig};
use skewsa::coordinator::{abft_check, Executor};
use skewsa::precision::chain_for;
use skewsa::precision::error::max_finite_f64;
use skewsa::sa::tile::{GemmShape, TilePlan};
use skewsa::workloads::gemm::GemmData;
use skewsa::{FpFormat, PipelineKind};
use std::sync::Arc;

/// Run one clean GEMM through the real executor (no fault injection)
/// under the format's canonical accumulation chain.
fn clean_run(
    fmt: FpFormat,
    kind: PipelineKind,
    shape: GemmShape,
    seed: u64,
) -> (ChainCfg, TilePlan, GemmData, Vec<f32>) {
    let mut cfg = RunConfig::small();
    cfg.in_fmt = fmt;
    cfg.out_fmt = chain_for(fmt).out_fmt;
    cfg.verify_fraction = 0.0;
    cfg.mode = NumericMode::Oracle;
    // Integer-valued operands are exact in every format down to
    // FP8-E5M2, so the sweep exercises the checker's tolerance rather
    // than quantization noise.
    let data = GemmData::integer_valued(shape, fmt, seed);
    let plan = TilePlan::for_geometry(shape, cfg.geometry);
    let chain = cfg.chain();
    let ex = Executor::new(cfg, kind);
    let out = ex.run(&Arc::new(data.clone()), &plan);
    (chain, plan, data, out.y)
}

/// A corruption far above any clean tolerance, encoded the way the
/// executor stores output words (an `out_fmt` bit pattern in the f32
/// container; a genuine f32 when the accumulator is FP32).
fn loud_word(chain: &ChainCfg) -> f32 {
    f32::from_bits(chain.out_fmt.from_f64(0.5 * max_finite_f64(chain.out_fmt)) as u32)
}

#[test]
fn clean_runs_never_false_positive_across_formats_and_kinds() {
    // Shape 1: single K-pass — the checker never declines, so every
    // format (FP16/FP8 accumulators included) gets a real verdict.
    // Shape 2: 3 K-passes × 2 N-blocks — the multi-pass merge path.
    for shape in [GemmShape::new(6, 8, 12), GemmShape::new(6, 20, 12)] {
        for fmt in FpFormat::ALL {
            for kind in PipelineKind::ALL {
                let seed = 0xab ^ ((fmt.width() as u64) << 8) ^ shape.k as u64;
                let (chain, plan, data, y) = clean_run(fmt, kind, shape, seed);
                let rep = abft_check(&chain, &plan, &data, &y);
                assert!(
                    rep.clean(),
                    "{} {kind} K={}: clean run raised a false positive {rep:?}",
                    fmt.name,
                    shape.k
                );
                if rep.skipped {
                    // Only the non-FP32-accumulator multi-pass combos
                    // may decline — never the single-pass shape.
                    assert!(plan.k_tiles() > 1, "{} {kind} declined a single pass", fmt.name);
                } else if rep.cols_checked > 0 {
                    assert!(
                        rep.max_ratio < 1.0,
                        "{} {kind}: clean margin ratio {}",
                        fmt.name,
                        rep.max_ratio
                    );
                }
            }
        }
    }
}

#[test]
fn above_tolerance_corruption_is_detected_and_localized() {
    let shape = GemmShape::new(6, 8, 12); // single pass: no format declines
    for fmt in FpFormat::ALL {
        for kind in PipelineKind::ALL {
            let (chain, plan, data, mut y) = clean_run(fmt, kind, shape, 0x77);
            let n_blocks = shape.n.div_ceil(plan.cols);
            assert!(n_blocks >= 2, "sweep must cover multi-block localization");
            for blk in 0..n_blocks {
                // Corrupt one word of this block (row 0, first column of
                // the block) far above the clean band, check, restore.
                let g = blk * plan.cols;
                let old = y[g];
                y[g] = loud_word(&chain);
                let rep = abft_check(&chain, &plan, &data, &y);
                assert_eq!(
                    rep.suspect_blocks,
                    vec![blk],
                    "{} {kind}: corruption in block {blk} mislocalized: {rep:?}",
                    fmt.name
                );
                y[g] = old;
            }
            // And the restored result is clean again (the harness did
            // not perturb neighbouring words).
            assert!(abft_check(&chain, &plan, &data, &y).clean());
        }
    }
}
