//! Parity suite for the monomorphized hot-path kernels (`arith::kernel`,
//! DESIGN.md §19).  The vectorized lane is a *performance* change with a
//! zero-bit-drift contract, so every kernel is pinned against the generic
//! datapath it replaces:
//!
//! 1. [`MonoKernel`]`<E, M, SKEWED>` step-for-step against the dynamic
//!    `BaselineFmaPath` / `SkewedFmaPath` for every [`FpFormat`],
//!    including zeros, subnormals, NaN/Inf and E4M3 top-exponent finites;
//! 2. the E4M3 saturation boundary (448 / 449⁺ saturates-to-NaN) nudged
//!    from both sides, where the fast-product predicate must bail;
//! 3. [`mac_slice`] / [`mac_block`] (the banded lockstep driver) against
//!    dependent per-column chains — fast all-normal bands and salted
//!    slow bands alike;
//! 4. [`quantize_matrix`] element-for-element against the precision
//!    oracle's `quantize_oracle` (the codec-independence pin);
//! 5. `StreamingSim::run_tile_parallel` against the serial streamer for
//!    every registered [`PipelineKind`] in both preload disciplines —
//!    identical reports, output bits, and timing-model agreement.

use skewsa::arith::fma::{BaselineFmaPath, ChainCfg, ChainDatapath, PsumSignal, SkewedFmaPath};
use skewsa::arith::format::FpFormat;
use skewsa::arith::kernel::{
    decode_matrix, mac_block, mac_slice, quantize_matrix, MacKernel, MonoKernel,
};
use skewsa::pe::PipelineKind;
use skewsa::precision::quantize_oracle;
use skewsa::sa::stream::StreamingSim;
use skewsa::sa::tile::{GemmShape, TilePlan};
use skewsa::util::prop::{Gen, Prop};

const CFG: ChainCfg = ChainCfg::BF16_FP32;

/// The accumulator pairing the rest of the repo uses: 8-bit inputs
/// accumulate in FP16 windows, wider inputs in FP32.
fn chain_for(fmt: FpFormat) -> ChainCfg {
    if fmt.width() == 8 {
        ChainCfg::new(fmt, FpFormat::FP16)
    } else {
        ChainCfg::new(fmt, FpFormat::FP32)
    }
}

/// Adversarial operand mix: every class the any-special prescan must
/// route off the fast path, plus uniform bit noise.
fn operand(g: &mut Gen, fmt: FpFormat) -> u64 {
    match g.usize_in(0, 7) {
        0 => 0,                             // +0
        1 => 1u64 << (fmt.width() - 1),     // -0
        2 => g.bits(fmt.man_bits),          // subnormal
        3 => fmt.inf_bits(),                // Inf (E4M3: NaN)
        4 => fmt.nan_bits(),                // NaN
        5 => fmt.inf_bits() - 1,            // largest finite
        6 => fmt.from_f64(g.normal(0.0, 400.0)), // near E4M3 saturation
        _ => g.bits(fmt.width()),
    }
}

fn probe_steps<const E: u32, const M: u32>(g: &mut Gen, fmt: FpFormat) {
    let cfg = chain_for(fmt);
    let mut base = PsumSignal::zero(&cfg);
    let mut mono_b = base;
    let mut skew = PsumSignal::zero(&cfg);
    let mut mono_s = skew;
    for _ in 0..64 {
        let a = operand(g, fmt);
        let w = operand(g, fmt);
        base = BaselineFmaPath.step(&cfg, &base, a, w);
        mono_b = MonoKernel::<E, M, false>::step(&cfg, &mono_b, a, w);
        g.assert_eq(fmt.display_name(), mono_b, base);
        skew = SkewedFmaPath.step(&cfg, &skew, a, w);
        mono_s = MonoKernel::<E, M, true>::step(&cfg, &mono_s, a, w);
        g.assert_eq(fmt.display_name(), mono_s, skew);
    }
}

/// Pin 1: monomorphized step kernels are bit-identical to the generic
/// datapaths across all formats × both pipeline datapaths, under the
/// adversarial operand mix.
#[test]
fn prop_mono_kernel_bit_identical_to_generic() {
    Prop::new("mono-kernel-eq-generic", 250).run(|g| {
        probe_steps::<8, 7>(g, FpFormat::BF16);
        probe_steps::<5, 10>(g, FpFormat::FP16);
        probe_steps::<4, 3>(g, FpFormat::FP8E4M3);
        probe_steps::<5, 2>(g, FpFormat::FP8E5M2);
        probe_steps::<8, 23>(g, FpFormat::FP32);
    });
}

/// Pin 2: E4M3 saturation-boundary nudges.  448 is the largest finite;
/// anything that rounds past it saturates to NaN, and the top-exponent
/// finites (256..448) must be excluded from the const-generic fast
/// product exactly as the dynamic predicate excludes them.
#[test]
fn prop_e4m3_saturation_boundary_nudges() {
    Prop::new("e4m3-saturation-boundary", 600).run(|g| {
        let fmt = FpFormat::FP8E4M3;
        let cfg = chain_for(fmt);
        let sign = if g.chance(0.5) { -1.0 } else { 1.0 };
        let mag = if g.chance(0.5) {
            448.0 * g.f64_in(0.9, 1.15) // straddles 448 / saturate-to-NaN
        } else {
            256.0 * g.f64_in(0.9, 1.1) // straddles the top-exponent field
        };
        let x = sign * mag;
        let a = fmt.from_f64(x);
        g.assert_eq("e4m3 quantize", quantize_oracle(fmt, x), a);
        let w = fmt.from_f64(g.normal(0.0, 2.0));
        let zero = PsumSignal::zero(&cfg);
        let want_b = BaselineFmaPath.step(&cfg, &zero, a, w);
        g.assert_eq("e4m3 baseline", MonoKernel::<4, 3, false>::step(&cfg, &zero, a, w), want_b);
        let want_s = SkewedFmaPath.step(&cfg, &zero, a, w);
        g.assert_eq("e4m3 skewed", MonoKernel::<4, 3, true>::step(&cfg, &zero, a, w), want_s);
    });
}

/// Pin 3: the batched entry points equal dependent per-column chains —
/// including bands salted with specials (scalar fallback) and all-normal
/// bands (lockstep fast path), with column counts crossing the chunk
/// width.
#[test]
fn prop_batched_block_equals_dependent_chains() {
    Prop::new("mac-block-eq-chains", 60).run(|g| {
        for fmt in FpFormat::ALL {
            let cfg = chain_for(fmt);
            let k = g.usize_in(1, 24);
            let cols = g.usize_in(1, 19); // crosses BLOCK_LANES = 8
            let all_normal = g.chance(0.5);
            let draw = |g: &mut Gen| {
                if all_normal {
                    loop {
                        let b = g.bits(fmt.width());
                        if fmt.is_fast_normal(b) {
                            break b;
                        }
                    }
                } else {
                    operand(g, fmt)
                }
            };
            let a: Vec<u64> = (0..k).map(|_| draw(g)).collect();
            let wdata: Vec<Vec<u64>> =
                (0..cols).map(|_| (0..k).map(|_| draw(g)).collect()).collect();
            let wcols: Vec<&[u64]> = wdata.iter().map(|w| w.as_slice()).collect();
            let mut got = vec![PsumSignal::zero(&cfg); cols];
            mac_block(&cfg, &a, &wcols, &mut got);
            for (j, w) in wdata.iter().enumerate() {
                let mut want = PsumSignal::zero(&cfg);
                for (&av, &wv) in a.iter().zip(w.iter()) {
                    want = BaselineFmaPath.step(&cfg, &want, av, wv);
                }
                g.assert_eq("mac_block column", got[j], want);
                let folded = mac_slice(&cfg, &PsumSignal::zero(&cfg), &a, w);
                g.assert_eq("mac_slice fold", folded, want);
            }
        }
    });
}

/// Pin 4: whole-matrix quantization is the codec the precision oracle
/// checks, element for element, and decode inverts it exactly.
#[test]
fn prop_quantize_matrix_matches_oracle() {
    Prop::new("quantize-matrix-eq-oracle", 150).run(|g| {
        for fmt in FpFormat::ALL {
            let xs: Vec<f64> = (0..32)
                .map(|_| match g.usize_in(0, 4) {
                    0 => g.normal(0.0, 1.0),
                    1 => g.normal(0.0, 1e-6),
                    2 => 448.0 * g.f64_in(0.9, 1.15),
                    3 => 0.0,
                    _ => g.normal(0.0, 1e6),
                })
                .collect();
            let q = quantize_matrix(fmt, &xs);
            for (x, &b) in xs.iter().zip(q.iter()) {
                g.assert_eq(fmt.display_name(), b, quantize_oracle(fmt, *x));
            }
            let d = decode_matrix(fmt, &q);
            for (&b, &v) in q.iter().zip(d.iter()) {
                g.assert_eq("decode", v.to_bits(), fmt.to_f64(b).to_bits());
            }
        }
    });
}

fn bf(g: &mut Gen) -> u64 {
    FpFormat::BF16.from_f64(g.normal(0.0, 1.5))
}

/// Pin 5: tile-level parallelism is invisible — the parallel streamer
/// produces the identical report, output bits, and timing-model match as
/// the serial one, for every organisation, both preload disciplines, and
/// thread counts above and below the tile count.
#[test]
fn prop_tile_parallel_streaming_equals_serial() {
    Prop::new("tile-parallel-eq-serial", 10).run(|g| {
        let shape = GemmShape::new(g.usize_in(2, 5), g.usize_in(9, 24), g.usize_in(9, 18));
        let plan = TilePlan::new(shape, 8, 8); // multi-tile in K and N
        let w: Vec<Vec<u64>> =
            (0..shape.k).map(|_| (0..shape.n).map(|_| bf(g)).collect()).collect();
        let a: Vec<Vec<u64>> =
            (0..shape.m).map(|_| (0..shape.k).map(|_| bf(g)).collect()).collect();
        let threads = g.usize_in(2, 16);
        for kind in PipelineKind::ALL {
            for db in [false, true] {
                let mut serial = StreamingSim::new(CFG, kind, &plan, &w, &a, db);
                let rep_s = serial.run(10_000_000).unwrap();
                let mut par = StreamingSim::new(CFG, kind, &plan, &w, &a, db);
                let rep_p = par.run_tile_parallel(10_000_000, threads).unwrap();
                g.assert_eq("stream report", &rep_p, &rep_s);
                g.assert("output bits", par.result_f32() == serial.result_f32());
                g.assert("stall-free", par.stalls() == 0);
                g.assert("timing model", par.matches_layer_timing());
            }
        }
    });
}
