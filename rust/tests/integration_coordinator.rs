//! Coordinator integration: multi-worker GEMM runs, backpressure,
//! failure injection, end-to-end layer sweeps.

use skewsa::arith::format::FpFormat;
use skewsa::config::{NumericMode, RunConfig};
use skewsa::coordinator::{verify_oracle_sampled, Coordinator, Executor, FaultPlan, Policy};
use skewsa::pe::PipelineKind;
use skewsa::sa::geometry::ArrayGeometry;
use skewsa::sa::tile::{GemmShape, TilePlan};
use skewsa::workloads::gemm::GemmData;
use skewsa::workloads::mobilenet;
use std::sync::Arc;

#[test]
fn multi_worker_multi_tile_gemm_verifies() {
    let mut cfg = RunConfig::small();
    cfg.workers = 4;
    cfg.verify_fraction = 1.0;
    let shape = GemmShape::new(24, 70, 40); // 9 K-tiles × 5 N-tiles
    let data = Arc::new(GemmData::cnn_like(shape, FpFormat::BF16, 0xabcd));
    let r = Coordinator::new(cfg).run_gemm(PipelineKind::Skewed, &data);
    assert!(r.verify.ok(), "{:?}", r.verify);
    assert_eq!(r.verify.checked, 24 * 40);
    // All workers contributed (45 jobs across 4 workers).
    assert!(r.per_worker.len() >= 2, "{:?}", r.per_worker);
}

#[test]
fn tiny_queue_backpressure_still_completes() {
    let mut cfg = RunConfig::small();
    cfg.workers = 2;
    cfg.queue_depth = 1; // maximal backpressure
    cfg.verify_fraction = 1.0;
    let shape = GemmShape::new(8, 33, 30);
    let data = Arc::new(GemmData::cnn_like(shape, FpFormat::BF16, 0x9911));
    let r = Coordinator::new(cfg).run_gemm(PipelineKind::Baseline3b, &data);
    assert!(r.verify.ok());
}

#[test]
fn worker_failures_recovered_transparently() {
    let mut cfg = RunConfig::small();
    cfg.workers = 3;
    let shape = GemmShape::new(6, 40, 24);
    let data = GemmData::integer_valued(shape, FpFormat::BF16, 0x77);
    let plan = TilePlan::for_geometry(shape, cfg.geometry);
    let mut ex = Executor::new(cfg, PipelineKind::Skewed);
    ex.fault = FaultPlan { worker: 1, failures: 3 };
    let out = ex.run(&Arc::new(data.clone()), &plan);
    assert!(out.retries >= 1 && out.retries <= 3 * Executor::MAX_RETRIES);
    // Numerics unharmed.
    let want = data.reference_f64();
    for m in 0..shape.m {
        for n in 0..shape.n {
            assert_eq!(out.y[m * shape.n + n] as f64, want[m][n]);
        }
    }
}

#[test]
fn paper_scale_least_loaded_backpressure_and_fault_injection() {
    // The paper's 128×128 array under Policy::LeastLoaded, maximal
    // backpressure (queue depth 1) and a worker that fails *every* job:
    // the run must stay bit-exact against the exact oracle and the
    // retry accounting must show worker 0 was routed around.
    let mut cfg = RunConfig::paper();
    cfg.workers = 3;
    cfg.queue_depth = 1;
    cfg.verify_fraction = 0.0;
    let chain = cfg.chain();
    let shape = GemmShape::new(6, 300, 200); // 3 K-passes × 2 N-blocks on 128×128
    let data = GemmData::cnn_like(shape, FpFormat::BF16, 0xfa17);
    let plan = TilePlan::for_geometry(shape, cfg.geometry);
    assert_eq!(plan.tile_count(), 6);
    let mut ex = Executor::new(cfg, PipelineKind::Skewed);
    ex.policy = Policy::LeastLoaded;
    ex.fault = FaultPlan::always(0);
    let out = ex.run(&Arc::new(data.clone()), &plan);
    // Bit-exact over every output element.
    let rep = verify_oracle_sampled(&chain, &plan, &data, &out.y, 1.0, 1);
    assert!(rep.ok(), "{rep:?}");
    assert_eq!(rep.checked, 6 * 200);
    // Retry accounting: each job fails at most once (on worker 0), then
    // succeeds elsewhere; worker 0 completes nothing.
    assert!(out.retries >= 1, "least-loaded offers worker 0 the first job");
    assert!(out.retries <= plan.tile_count(), "retries {}", out.retries);
    assert!(out.per_worker.iter().all(|&(w, _)| w != 0), "{:?}", out.per_worker);
    let done: usize = out.per_worker.iter().map(|&(_, n)| n).sum();
    assert_eq!(done, plan.tile_count());
}

#[test]
fn single_worker_equals_many_workers_bitwise() {
    let shape = GemmShape::new(10, 50, 20);
    let data = Arc::new(GemmData::adversarial(shape, FpFormat::BF16, 5));
    let run = |workers: usize| -> Vec<u32> {
        let mut cfg = RunConfig::small();
        cfg.workers = workers;
        cfg.verify_fraction = 0.0;
        let r = Coordinator::new(cfg).run_gemm(PipelineKind::Skewed, &data);
        r.y.iter().map(|v| v.to_bits()).collect()
    };
    assert_eq!(run(1), run(6), "determinism across pool sizes");
}

#[test]
fn mobilenet_first_block_end_to_end_scaled() {
    // The first three MobileNet layers, scaled to a 16×16 array, with
    // full verification — the e2e driver in miniature.
    let mut cfg = RunConfig::small();
    cfg.geometry = ArrayGeometry::new(16, 16);
    cfg.workers = 4;
    cfg.verify_fraction = 0.05;
    let coord = Coordinator::new(cfg.clone());
    for l in mobilenet::layers().iter().take(3) {
        let mut shape = l.gemm();
        // Scale M down so the test stays quick; K/N keep layer structure.
        shape = GemmShape::new(shape.m.min(64), shape.k, shape.n);
        let data = Arc::new(GemmData::cnn_like(shape, FpFormat::BF16, 0x600d));
        let r = coord.run_gemm(PipelineKind::Skewed, &data);
        assert!(r.verify.ok(), "layer {} failed verify", l.name);
    }
}

#[test]
fn cycle_mode_coordinator_run() {
    let mut cfg = RunConfig::small();
    cfg.mode = NumericMode::CycleAccurate;
    cfg.verify_fraction = 1.0;
    let shape = GemmShape::new(5, 20, 10);
    let data = Arc::new(GemmData::cnn_like(shape, FpFormat::BF16, 0xc1c1e));
    let r = Coordinator::new(cfg).run_gemm(PipelineKind::Skewed, &data);
    assert!(r.verify.ok());
}

#[test]
fn config_files_load_and_drive_runs() {
    use skewsa::util::mini_json::Json;
    // Every shipped config parses and applies cleanly.
    for path in ["configs/paper.json", "configs/small.json", "configs/fp8.json"] {
        let mut cfg = RunConfig::paper();
        cfg.apply_file(path).unwrap_or_else(|e| panic!("{path}: {e}"));
        // And round-trips through the JSON layer.
        let text = std::fs::read_to_string(path).unwrap();
        assert!(Json::parse(&text).is_ok(), "{path}");
    }
    // The fp8 config runs a verified reduced-precision GEMM end-to-end.
    let mut cfg = RunConfig::small();
    cfg.apply_file("configs/fp8.json").unwrap();
    cfg.geometry = ArrayGeometry::new(8, 8);
    cfg.verify_fraction = 1.0;
    assert_eq!(cfg.in_fmt, FpFormat::FP8E4M3);
    let data = Arc::new(GemmData::cnn_like(GemmShape::new(6, 16, 6), cfg.in_fmt, 1));
    let r = Coordinator::new(cfg).run_gemm(PipelineKind::Skewed, &data);
    assert!(r.verify.ok(), "{:?}", r.verify);
}
