//! `skewsa` — leader entrypoint / CLI.
//!
//! Subcommands map one-to-one onto the experiment index (DESIGN.md §5):
//!
//! ```text
//! skewsa fig7        # Fig. 7: MobileNet per-layer energy
//! skewsa fig8        # Fig. 8: ResNet50 per-layer energy
//! skewsa table1      # §IV area/power overheads
//! skewsa headline    # whole-network latency/energy totals
//! skewsa ablation    # Fig. 3a / 3b / skewed stage delays + latency
//! skewsa formats     # Fig. 1 formats + delay inversion
//! skewsa sweep       # design-space sweep: array size x format
//! skewsa run         # coordinate a GEMM end-to-end (verify + report)
//! skewsa viz         # pipeline interleaving trace (Figs. 4/6)
//! ```

use skewsa::arith::fma::ChainCfg;
use skewsa::config::RunConfig;
use skewsa::coordinator::Coordinator;
use skewsa::energy::{AreaModel, PowerModel};
use skewsa::pe::PipelineKind;
use skewsa::report;
use skewsa::sa::column::ColumnSim;
use skewsa::sa::tile::GemmShape;
use skewsa::util::cli::Cli;
use skewsa::util::table::pct;
use skewsa::workloads::gemm::GemmData;
use std::sync::Arc;

fn cli() -> Cli {
    Cli::new(
        "skewsa",
        "reduced-precision FP systolic arrays with skewed pipelines (AICAS'23 reproduction)",
    )
    .opt("rows", "array rows (default: config / 128)", None)
    .opt("cols", "array columns (default: config / 128)", None)
    .opt("seed", "workload RNG seed", None)
    .opt("workers", "coordinator worker threads", None)
    .opt("verify", "oracle verification fraction (0..1)", None)
    .opt("mode", "numeric mode: oracle|cycle", None)
    .opt("config", "JSON config file", None)
    .opt("m", "GEMM M (run)", Some("256"))
    .opt("k", "GEMM K (run)", Some("256"))
    .opt("n", "GEMM N (run)", Some("256"))
    .opt("pipeline", "pipeline kind: baseline|skewed", Some("skewed"))
    .opt("csv", "write the report table as CSV to this path", None)
    .flag("quiet", "suppress per-layer rows")
}

fn main() {
    let args = cli().parse_env();
    let mut cfg = RunConfig::paper();
    if let Some(path) = args.get("config") {
        if let Err(e) = cfg.apply_file(path) {
            eprintln!("config error: {e}");
            std::process::exit(2);
        }
    }
    cfg.apply_args(&args);
    let cmd = args.positional.first().map(String::as_str).unwrap_or("headline");

    let tcfg = cfg.timing();
    let pmodel = PowerModel::new(AreaModel::new(cfg.chain()));

    let rep = match cmd {
        "fig7" => report::fig7_mobilenet(&tcfg, &pmodel),
        "fig8" => report::fig8_resnet50(&tcfg, &pmodel),
        "table1" => report::table1_area_power(cfg.chain(), cfg.rows, cfg.cols),
        "headline" => report::headline(&tcfg, &pmodel),
        "ablation" => report::ablation_pipelines(cfg.chain(), &tcfg),
        "formats" => report::format_sweep(),
        "sweep" => report::design_sweep(cfg.clock_ghz),
        "run" => {
            run_gemm(&cfg, &args);
            return;
        }
        "viz" => {
            viz(&cfg);
            return;
        }
        other => {
            eprintln!("unknown subcommand '{other}'\n\n{}", cli().usage());
            std::process::exit(2);
        }
    };
    if args.has("quiet") {
        println!("== {} ==", rep.title);
        if let Some(t) = &rep.totals {
            println!(
                "total: latency {} energy {}",
                pct(t.latency_delta()),
                pct(t.energy_delta())
            );
        }
    } else {
        print!("{}", rep.render());
    }
    if let Some(path) = args.get("csv") {
        std::fs::write(path, rep.table.to_csv()).expect("writing CSV");
        eprintln!("wrote {path}");
    }
}

fn run_gemm(cfg: &RunConfig, args: &skewsa::util::cli::Args) {
    let shape = GemmShape::new(
        args.req_usize("m"),
        args.req_usize("k"),
        args.req_usize("n"),
    );
    let kind: PipelineKind =
        args.get("pipeline").unwrap_or("skewed").parse().unwrap_or(PipelineKind::Skewed);
    println!(
        "coordinating GEMM {}x{}x{} on {}x{} ({}), workers={} mode={:?}",
        shape.m, shape.k, shape.n, cfg.rows, cfg.cols, kind, cfg.workers, cfg.mode
    );
    let data = Arc::new(GemmData::cnn_like(shape, cfg.in_fmt, cfg.seed));
    let coord = Coordinator::new(cfg.clone());
    let t0 = std::time::Instant::now();
    let r = coord.run_gemm(kind, &data);
    let wall = t0.elapsed();
    println!(
        "done in {wall:?}: verify {}/{} ok, retries {}",
        r.verify.checked - r.verify.failures,
        r.verify.checked,
        r.retries
    );
    println!(
        "timing: baseline {} cyc, skewed {} cyc ({}); energy {:.2} uJ -> {:.2} uJ ({})",
        r.comparison.baseline.timing.cycles,
        r.comparison.skewed.timing.cycles,
        pct(r.comparison.latency_delta()),
        r.comparison.baseline.energy_uj,
        r.comparison.skewed.energy_uj,
        pct(r.comparison.energy_delta()),
    );
    if !r.verify.ok() {
        eprintln!("VERIFICATION FAILED");
        std::process::exit(1);
    }
}

fn viz(cfg: &RunConfig) {
    let chain = ChainCfg::new(cfg.in_fmt, cfg.out_fmt);
    let rows = cfg.rows.clamp(2, 4);
    println!("pipeline interleaving, {rows}-PE column, 3 elements (paper Figs. 4 & 6):\n");
    for kind in [PipelineKind::Baseline3b, PipelineKind::Skewed] {
        let weights: Vec<u64> = (0..rows).map(|i| cfg.in_fmt.from_f64(1.0 + i as f64)).collect();
        let a: Vec<Vec<u64>> = (0..3)
            .map(|m| (0..rows).map(|r| cfg.in_fmt.from_f64((m + r) as f64)).collect())
            .collect();
        let mut sim = ColumnSim::new(chain, kind, &weights, a).with_trace();
        sim.run(1000).expect("viz run");
        println!("--- {kind} (chain spacing {}) ---", kind.chain_spacing());
        println!("{}", sim.trace().unwrap().render(16));
    }
}
