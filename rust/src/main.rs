//! `skewsa` — leader entrypoint / CLI.
//!
//! Subcommands map one-to-one onto the experiment index (DESIGN.md §5):
//!
//! ```text
//! skewsa fig7        # Fig. 7: MobileNet per-layer energy
//! skewsa fig8        # Fig. 8: ResNet50 per-layer energy
//! skewsa table1      # §IV area/power overheads
//! skewsa headline    # whole-network latency/energy totals
//! skewsa pipelines   # the pipeline-organisation registry (specs table)
//! skewsa ablation    # per-organisation stage delays + latency
//! skewsa formats     # Fig. 1 formats + delay inversion
//! skewsa sweep       # design-space sweep: array size x format
//! skewsa geometry    # aspect-ratio sweep at a fixed PE budget
//! skewsa run         # coordinate a GEMM end-to-end (verify + report)
//! skewsa serve       # multi-tenant serving: batching + cache + shards
//! skewsa fleet       # fleet-scale DES: virtual-clock serving, autoscale
//! skewsa faults      # chaos run: SDC injection + ABFT + quarantine
//! skewsa precision   # mixed-precision planner: budget -> per-layer plan
//! skewsa stream      # multi-tile layer latency: serialized vs overlapped
//! skewsa viz         # pipeline interleaving trace (Figs. 4/6)
//! skewsa trace FILE  # summarize a --trace-out span file (p50/p99 path)
//! skewsa bench-check # validate BENCH_*.json schema, flag perf drops
//! ```
//!
//! `--pipeline` selects any registered organisation everywhere it
//! appears; `serve` and `precision` additionally accept comma lists,
//! `all`, and (serve only, historically) `both`.

use skewsa::arith::fma::ChainCfg;
use skewsa::config::RunConfig;
use skewsa::coordinator::Coordinator;
use skewsa::energy::{AreaModel, PowerModel};
use skewsa::pe::PipelineKind;
use skewsa::report;
use skewsa::sa::column::ColumnSim;
use skewsa::sa::tile::GemmShape;
use skewsa::util::cli::Cli;
use skewsa::util::table::pct;
use skewsa::workloads::gemm::GemmData;
use std::sync::Arc;

fn cli() -> Cli {
    Cli::new(
        "skewsa",
        "reduced-precision FP systolic arrays with skewed pipelines (AICAS'23 reproduction)",
    )
    .opt("rows", "array rows (default: config / 128)", None)
    .opt("cols", "array columns (default: config / 128)", None)
    .opt("geometry", "array geometry ROWSxCOLS, e.g. 256x64 (wins over --rows/--cols)", None)
    .opt(
        "shard-geometries",
        "serve/fleet: per-shard geometry list, e.g. 256x64,64x256,128x128 (repeats)",
        None,
    )
    .opt("pe-budget", "geometry: PE budget for the aspect sweep (default: rows*cols)", None)
    .opt("max-aspect", "geometry: max rows/cols aspect ratio in the sweep", Some("4"))
    .opt("seed", "workload RNG seed", None)
    .opt("workers", "coordinator worker threads", None)
    .opt("threads", "tile-parallel simulation threads (default: host parallelism)", None)
    .opt("verify", "oracle verification fraction (0..1)", None)
    .opt("mode", "numeric mode: oracle|cycle", None)
    .opt("config", "JSON config file", None)
    .opt("m", "GEMM M (run)", Some("256"))
    .opt("k", "GEMM K (run)", Some("256"))
    .opt("n", "GEMM N (run)", Some("256"))
    .opt(
        "pipeline",
        "pipeline organisation (see `skewsa pipelines`); serve/precision take comma lists or 'all'",
        None,
    )
    .opt("csv", "write the report table as CSV to this path", None)
    .opt("shards", "serve: array shards", None)
    .opt("shard-workers", "serve: worker threads per shard", None)
    .opt("shard-policy", "serve: shard routing policy rr|ll", None)
    .opt("batch-window-us", "serve: batch coalescing window", None)
    .opt("batch-max", "serve: max requests per batch", None)
    .opt("clients", "serve: closed-loop client threads", Some("4"))
    .opt("requests", "serve: requests per client", Some("32"))
    .opt("interactive", "serve: interactive request fraction", Some("0.25"))
    .opt("net", "serve: model set mobilenet|resnet50|decode|mix", Some("mix"))
    .opt("cap", "serve: K/N clamp for served layers", Some("128"))
    .opt("workload", "precision/stream/geometry: mobilenet|resnet50|decode", Some("mobilenet"))
    .opt("budget", "precision: per-layer error budget (peak-normalized)", Some("1e-2"))
    .opt("m-cap", "precision: sampled rows per layer (full K always)", Some("8"))
    .opt("n-cap", "precision: sampled columns per layer", Some("16"))
    .opt("fault", "serve/faults: fault model, e.g. sdc_rate=1e-3,seed=7", None)
    .opt("shed-watermark", "serve/faults/fleet: queue depth that sheds batch requests", None)
    .opt("trace-out", "serve/faults: write request trace spans as JSON lines", None)
    .opt("metrics-out", "serve/faults: write the metrics snapshot as JSON", None)
    .opt("min-shards", "fleet: autoscaler floor", None)
    .opt("max-shards", "fleet: provisioned shard slots (autoscaler ceiling)", None)
    .opt("horizon", "fleet: open-loop arrival horizon, cycles", None)
    .opt("arrival", "fleet: arrival process poisson|mmpp|closed", None)
    .opt("mean-gap", "fleet: mean inter-arrival gap, cycles", None)
    .opt("slo-p99", "fleet: autoscaler p99 latency SLO, cycles", None)
    .opt("autoscale-interval", "fleet: cycles between autoscaler ticks (0 = off)", None)
    .opt("fleet-out", "fleet: write the full result JSON here", None)
    .flag("smoke", "faults/fleet: small deterministic CI run with a hard gate")
    .flag("quiet", "suppress per-layer rows")
}

fn main() {
    let args = cli().parse_env();
    let mut cfg = RunConfig::paper();
    if let Some(path) = args.get("config") {
        if let Err(e) = cfg.apply_file(path) {
            eprintln!("config error: {e}");
            std::process::exit(2);
        }
    }
    if let Err(e) = cfg.apply_args(&args) {
        eprintln!("error: {e}");
        std::process::exit(2);
    }
    let cmd = args.positional.first().map(String::as_str).unwrap_or("headline");

    let tcfg = cfg.timing();
    let pmodel = PowerModel::new(AreaModel::new(cfg.chain()));

    let rep = match cmd {
        "fig7" => report::fig7_mobilenet(&tcfg, &pmodel),
        "fig8" => report::fig8_resnet50(&tcfg, &pmodel),
        "table1" => report::table1_area_power(cfg.chain(), cfg.geometry),
        "headline" => report::headline(&tcfg, &pmodel),
        "pipelines" => report::pipelines_registry(cfg.chain()),
        "ablation" => report::ablation_pipelines(cfg.chain(), &tcfg),
        "formats" => report::format_sweep(),
        "sweep" => report::design_sweep(cfg.clock_ghz, single_kind(&cfg, &args, "sweep")),
        "stream" => {
            let (net, layers) = workload_layers(&args, "mobilenet");
            let kind = single_kind(&cfg, &args, "stream");
            report::multi_tile_latency(
                &format!(
                    "Stream: {net} multi-tile latency, {kind} on {} \
                     (double-buffered vs serialized preload)",
                    cfg.geometry
                ),
                &layers,
                &tcfg,
                kind,
            )
        }
        "geometry" => {
            geometry_cmd(&cfg, &args);
            return;
        }
        "run" => {
            run_gemm(&cfg, &args);
            return;
        }
        "serve" => {
            serve(&cfg, &args);
            return;
        }
        "fleet" => {
            fleet(&cfg, &args);
            return;
        }
        "faults" => {
            faults(&cfg, &args);
            return;
        }
        "precision" => {
            precision(&cfg, &args);
            return;
        }
        "viz" => {
            viz(&cfg);
            return;
        }
        "trace" => {
            trace_cmd(&args);
            return;
        }
        "bench-check" => {
            bench_check(&args);
            return;
        }
        other => {
            eprintln!("unknown subcommand '{other}'\n\n{}", cli().usage());
            std::process::exit(2);
        }
    };
    if args.has("quiet") {
        println!("== {} ==", rep.title);
        if let Some(t) = &rep.totals {
            println!(
                "total: latency {} energy {}",
                pct(t.latency_delta()),
                pct(t.energy_delta())
            );
        }
    } else {
        print!("{}", rep.render());
    }
    if let Some(path) = args.get("csv") {
        std::fs::write(path, rep.table.to_csv()).expect("writing CSV");
        eprintln!("wrote {path}");
    }
}

/// Resolve a single-organisation `--pipeline` value: the flag when
/// given (hard error on typos, with the registry's suggestions), else
/// the config default.
fn single_kind(cfg: &RunConfig, args: &skewsa::util::cli::Args, cmd: &str) -> PipelineKind {
    match args.get("pipeline") {
        None => cfg.pipeline,
        Some(v) => match v.parse() {
            Ok(k) => k,
            Err(e) => {
                eprintln!("error: {e} ({cmd} takes a single organisation)");
                std::process::exit(2);
            }
        },
    }
}

/// Resolve a list-valued `--pipeline` (serve/precision): comma lists,
/// `all`, and `both` are accepted; defaults to the config organisation.
fn kind_list(cfg: &RunConfig, args: &skewsa::util::cli::Args, cmd: &str) -> Vec<PipelineKind> {
    let Some(v) = args.get("pipeline") else {
        return vec![cfg.pipeline];
    };
    let parsed = PipelineKind::parse_list(v);
    match parsed {
        Ok(kinds) => kinds,
        Err(e) => {
            eprintln!("error: {e} ({cmd} takes a comma list, 'all' or 'both')");
            std::process::exit(2);
        }
    }
}

/// Resolve `--workload` into a layer list (the subcommands sharing this
/// knob take exactly one network; `serve --net` has its own mix rules).
fn workload_layers(
    args: &skewsa::util::cli::Args,
    default: &str,
) -> (String, Vec<skewsa::workloads::layer::LayerDef>) {
    use skewsa::workloads::{decode, mobilenet, resnet50};
    let net = args.get("workload").unwrap_or(default);
    let layers = match net {
        "mobilenet" => mobilenet::layers(),
        "resnet50" => resnet50::layers(),
        "decode" => decode::layers(),
        other => {
            eprintln!("error: unknown workload '{other}' (mobilenet|resnet50|decode)");
            std::process::exit(2);
        }
    };
    (net.to_string(), layers)
}

/// Aspect-ratio sweep at a fixed PE budget (DESIGN.md §20): every
/// power-of-two ROWSxCOLS shape within `--max-aspect` of square gets the
/// full per-layer streaming-latency + energy evaluation, and the report
/// marks the Pareto-optimal shapes.  `--smoke` turns the sweep into the
/// CI gate: on the decode workload a tall array (rows > cols) must win
/// total latency, or the edge-effect model has regressed.
fn geometry_cmd(cfg: &RunConfig, args: &skewsa::util::cli::Args) {
    use skewsa::sa::geometry::sweep_geometries;

    let (net, layers) = workload_layers(args, if args.has("smoke") { "decode" } else { "mobilenet" });
    let kind = single_kind(cfg, args, "geometry");
    let pe_budget = args.get_usize("pe-budget").unwrap_or_else(|| cfg.geometry.pe_count());
    let max_aspect = args.get_f64("max-aspect").unwrap_or(4.0);
    if pe_budget < 4 || !(1.0..=1024.0).contains(&max_aspect) {
        eprintln!(
            "error: need --pe-budget >= 4 and --max-aspect in [1, 1024] \
             (got {pe_budget}, {max_aspect})"
        );
        std::process::exit(2);
    }
    let geoms = sweep_geometries(pe_budget, max_aspect);
    println!(
        "geometry sweep: {net}, {} shape(s) at {pe_budget} PEs (aspect <= {max_aspect}), {kind}",
        geoms.len(),
    );
    let (rep, choice) = report::geometry_sweep(&net, &layers, &geoms, cfg, kind);
    if args.has("quiet") {
        println!("== {} ==", rep.title);
    } else {
        print!("{}", rep.render());
    }
    println!(
        "latency-optimal {}  energy-optimal {}",
        choice.latency_best, choice.energy_best
    );
    if let Some(path) = args.get("csv") {
        std::fs::write(path, rep.table.to_csv()).expect("writing CSV");
        eprintln!("wrote {path}");
    }
    if args.has("smoke") && net == "decode" && choice.latency_best.rows <= choice.latency_best.cols
    {
        eprintln!(
            "GEOMETRY SMOKE FAILED: decode's latency-optimal shape is {}, expected tall \
             (rows > cols)",
            choice.latency_best
        );
        std::process::exit(1);
    }
}

fn run_gemm(cfg: &RunConfig, args: &skewsa::util::cli::Args) {
    let shape = GemmShape::new(
        args.req_usize("m"),
        args.req_usize("k"),
        args.req_usize("n"),
    );
    let kind = single_kind(cfg, args, "run");
    println!(
        "coordinating GEMM {}x{}x{} on {} ({}), workers={} threads={} mode={:?}",
        shape.m, shape.k, shape.n, cfg.geometry, kind, cfg.workers, cfg.threads, cfg.mode
    );
    let data = Arc::new(GemmData::cnn_like(shape, cfg.in_fmt, cfg.seed));
    let coord = Coordinator::new(cfg.clone());
    let t0 = std::time::Instant::now();
    let r = coord.run_gemm(kind, &data);
    let wall = t0.elapsed();
    println!(
        "done in {wall:?}: verify {}/{} ok, retries {}",
        r.verify.checked - r.verify.failures,
        r.verify.checked,
        r.retries
    );
    println!(
        "timing: baseline-3b {} cyc, {} {} cyc ({}); energy {:.2} uJ -> {:.2} uJ ({})",
        r.comparison.baseline.timing.cycles,
        kind.name(),
        r.comparison.skewed.timing.cycles,
        pct(r.comparison.latency_delta()),
        r.comparison.baseline.energy_uj,
        r.comparison.skewed.energy_uj,
        pct(r.comparison.energy_delta()),
    );
    if !r.verify.ok() {
        eprintln!("VERIFICATION FAILED");
        std::process::exit(1);
    }
}

fn serve(cfg: &RunConfig, args: &skewsa::util::cli::Args) {
    use skewsa::config::ServeConfig;
    use skewsa::serve::{run_closed_loop, LoadSpec, Server};
    use skewsa::workloads::serving::WeightStore;
    use skewsa::workloads::{mobilenet, resnet50};

    let mut scfg = ServeConfig::default();
    if let Some(path) = args.get("config") {
        // The run config already applied this file once; re-read it for
        // the serve-layer keys under the same error convention (no raw
        // panics for I/O races between the two reads).
        let applied = std::fs::read_to_string(path)
            .map_err(|e| format!("{path}: {e}"))
            .and_then(|text| {
                skewsa::util::mini_json::Json::parse(&text).map_err(|e| format!("{path}: {e}"))
            })
            .and_then(|j| scfg.apply_json(&j));
        if let Err(e) = applied {
            eprintln!("config error: {e}");
            std::process::exit(2);
        }
    }
    if let Err(e) = scfg.apply_args(args) {
        eprintln!("error: {e}");
        std::process::exit(2);
    }

    let cap = args.get_usize("cap").unwrap_or(128).max(1);
    let net = args.get("net").unwrap_or("mix");
    let layers = match net {
        "mobilenet" => mobilenet::layers(),
        "resnet50" => resnet50::layers(),
        "decode" => skewsa::workloads::decode::layers(),
        "mix" => {
            let mut l = mobilenet::layers();
            l.extend(resnet50::layers());
            l
        }
        other => {
            eprintln!("error: unknown net '{other}' (mobilenet|resnet50|decode|mix)");
            std::process::exit(2);
        }
    };
    let store = Arc::new(WeightStore::from_layers(&layers, cfg.in_fmt, cap, cap));
    let kinds = kind_list(cfg, args, "serve");
    let spec = LoadSpec {
        clients: args.get_usize("clients").unwrap_or(4).max(1),
        requests_per_client: args.get_usize("requests").unwrap_or(32).max(1),
        kinds,
        interactive_fraction: args.get_f64("interactive").unwrap_or(0.25).clamp(0.0, 1.0),
        min_rows: 2,
        max_rows: 8,
        seed: cfg.seed,
    };
    let geom_label = if scfg.shard_geometries.is_empty() {
        format!("{} array", cfg.geometry)
    } else {
        let shapes: Vec<String> =
            (0..scfg.shards).map(|s| scfg.shard_geometry(s, cfg.geometry).to_string()).collect();
        format!("arrays [{}]", shapes.join(", "))
    };
    println!(
        "serving {} models ({net}, K/N<={cap}) on {} shard(s) x {} worker(s), \
         {geom_label}, policy {}, window {}us",
        store.len(),
        scfg.shards,
        scfg.workers_per_shard,
        scfg.shard_policy,
        scfg.batch_window_us,
    );
    let server = Server::start_obs(cfg, &scfg, store, obs_for(&scfg));
    let load = run_closed_loop(&server, &spec);
    let snap = server.metrics();
    let rep = report::serve_summary(&load, &snap);
    print!("{}", rep.render());
    if let Some(path) = args.get("csv") {
        std::fs::write(path, rep.table.to_csv()).expect("writing CSV");
        eprintln!("wrote {path}");
    }
    write_obs_outputs(&server, &scfg, &snap);
}

/// Fleet-scale discrete-event simulation: the serve request path over
/// a virtual clock and thousands of simulated shards (DESIGN.md §18).
/// `--smoke` runs the small deterministic config and the exit code
/// turns into a CI gate: non-zero when the accounting conservation law
/// (submitted = served + shed + failed) breaks.
fn fleet(cfg: &RunConfig, args: &skewsa::util::cli::Args) {
    use skewsa::config::FleetConfig;
    use skewsa::fleet::FleetSim;

    let smoke = args.has("smoke");
    let mut fcfg = if smoke { FleetConfig::smoke() } else { FleetConfig::default() };
    if let Some(path) = args.get("config") {
        if let Err(e) = fcfg.apply_file(path) {
            eprintln!("config error: {e}");
            std::process::exit(2);
        }
    }
    if let Err(e) = fcfg.apply_args(args) {
        eprintln!("error: {e}");
        std::process::exit(2);
    }
    println!(
        "fleet: {} tenant(s), {} model shape(s), shards {} in [{}, {}], policy {}, \
         horizon {} cycles",
        fcfg.tenants.len(),
        fcfg.models.len(),
        fcfg.shards.clamp(fcfg.min_shards, fcfg.max_shards),
        fcfg.min_shards,
        fcfg.max_shards,
        fcfg.shard_policy,
        fcfg.horizon,
    );
    let t0 = std::time::Instant::now();
    let result = FleetSim::simulate(cfg, &fcfg);
    let wall = t0.elapsed();
    println!(
        "simulated {} virtual cycles ({} requests) in {wall:?}",
        result.wall_cycles, result.submitted
    );
    let rep = report::fleet_summary(&result, cfg.clock_ghz);
    print!("{}", rep.render());
    if let Some(path) = args.get("csv") {
        std::fs::write(path, rep.table.to_csv()).expect("writing CSV");
        eprintln!("wrote {path}");
    }
    if let Some(path) = args.get("fleet-out") {
        let text = result.to_json(cfg.clock_ghz).to_string_pretty();
        std::fs::write(path, text).expect("writing fleet result");
        eprintln!("wrote {path}");
    }
    if !result.accounting_balanced() {
        eprintln!(
            "FLEET ACCOUNTING IMBALANCE: submitted {} != served {} + shed {} + failed {}",
            result.submitted, result.served, result.shed, result.failed
        );
        std::process::exit(1);
    }
}

/// The observability handle a serve/faults run starts under: tracing on
/// exactly when `--trace-out` asks for the spans.
fn obs_for(scfg: &skewsa::config::ServeConfig) -> skewsa::obs::Obs {
    if scfg.trace_out.is_some() {
        skewsa::obs::Obs::with_tracing()
    } else {
        skewsa::obs::Obs::new()
    }
}

/// Write the `--trace-out` / `--metrics-out` artifacts after a
/// serve/faults run: closed spans + health events as JSON lines, and
/// the unified metrics snapshot as JSON.
fn write_obs_outputs(
    server: &skewsa::serve::Server,
    scfg: &skewsa::config::ServeConfig,
    snap: &skewsa::obs::MetricsSnapshot,
) {
    if let Some(path) = &scfg.trace_out {
        let sink = server.obs().sink.as_ref().expect("tracing is on when trace_out is set");
        std::fs::write(path, sink.to_jsonl()).expect("writing trace");
        eprintln!("wrote {path}");
    }
    if let Some(path) = &scfg.metrics_out {
        std::fs::write(path, snap.to_json().to_string_pretty()).expect("writing metrics");
        eprintln!("wrote {path}");
    }
}

/// Summarize a `--trace-out` JSON-lines file: the p50/p99 critical-path
/// breakdown across wall-clock phases and array-cycle buckets, plus any
/// health-transition events the run recorded.
fn trace_cmd(args: &skewsa::util::cli::Args) {
    let Some(path) = args.positional.get(1) else {
        eprintln!("usage: skewsa trace <spans.jsonl>   (written by serve/faults --trace-out)");
        std::process::exit(2);
    };
    let parsed = std::fs::read_to_string(path)
        .map_err(|e| format!("{path}: {e}"))
        .and_then(|text| skewsa::obs::parse_jsonl(&text).map_err(|e| format!("{path}: {e}")));
    let (spans, events) = match parsed {
        Ok(v) => v,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let rep = report::trace_summary(&spans);
    print!("{}", rep.render());
    if !events.is_empty() {
        println!("events:");
        for e in &events {
            println!(
                "  t+{:>12}ns  shard {}  {}:{}  (tick {})",
                e.t_ns, e.shard, e.kind, e.label, e.clock
            );
        }
    }
    if let Some(csv) = args.get("csv") {
        std::fs::write(csv, rep.table.to_csv()).expect("writing CSV");
        eprintln!("wrote {csv}");
    }
}

/// Chaos run: serve a closed-loop load under an injecting fault model
/// and report the SDC/health/shed lifecycle.  Exits non-zero when any
/// detected corruption stayed unresolved — the CI smoke gate.
fn faults(cfg: &RunConfig, args: &skewsa::util::cli::Args) {
    use skewsa::config::ServeConfig;
    use skewsa::coordinator::FaultModel;
    use skewsa::serve::{run_closed_loop, LoadSpec, Server};
    use skewsa::workloads::mobilenet;
    use skewsa::workloads::serving::WeightStore;

    let mut scfg = ServeConfig::default();
    if let Some(path) = args.get("config") {
        let applied = std::fs::read_to_string(path)
            .map_err(|e| format!("{path}: {e}"))
            .and_then(|text| {
                skewsa::util::mini_json::Json::parse(&text).map_err(|e| format!("{path}: {e}"))
            })
            .and_then(|j| scfg.apply_json(&j));
        if let Err(e) = applied {
            eprintln!("config error: {e}");
            std::process::exit(2);
        }
    }
    if let Err(e) = scfg.apply_args(args) {
        eprintln!("error: {e}");
        std::process::exit(2);
    }
    // A chaos run with nothing injected would only measure the happy
    // path: default to a representative mix (SDCs on all sites, a few
    // slow workers, ABFT on) unless the user configured their own.
    if !scfg.fault.injects() {
        scfg.fault = FaultModel {
            sdc_rate: 0.05,
            slow_rate: 0.02,
            slow_us: 200,
            seed: cfg.seed,
            abft: true,
            ..FaultModel::none()
        };
    }
    let smoke = args.has("smoke");
    let store = Arc::new(WeightStore::from_layers(&mobilenet::layers(), cfg.in_fmt, 64, 64));
    let kinds = kind_list(cfg, args, "faults");
    let spec = LoadSpec {
        clients: if smoke { 2 } else { 4 },
        requests_per_client: if smoke { 6 } else { 24 },
        kinds,
        interactive_fraction: 0.25,
        min_rows: 2,
        max_rows: 8,
        seed: cfg.seed,
    };
    println!(
        "chaos: {} models on {} shard(s) x {} worker(s), fault [{}]",
        store.len(),
        scfg.shards,
        scfg.workers_per_shard,
        scfg.fault,
    );
    let server = Server::start_obs(cfg, &scfg, store, obs_for(&scfg));
    let load = run_closed_loop(&server, &spec);
    let snap = server.metrics();
    let rep = report::faults_summary(&load, &snap);
    print!("{}", rep.render());
    if let Some(path) = args.get("csv") {
        std::fs::write(path, rep.table.to_csv()).expect("writing CSV");
        eprintln!("wrote {path}");
    }
    write_obs_outputs(&server, &scfg, &snap);
    let shards = snap.gauge("serve.shards") as usize;
    let unresolved: u64 =
        (0..shards).map(|i| snap.counter(&format!("shard.{i}.sdc_unresolved"))).sum();
    if unresolved > 0 {
        eprintln!("CHAOS RUN FAILED: {unresolved} corrupted block(s) left unresolved");
        std::process::exit(1);
    }
}

/// Validate the `BENCH_*.json` perf-trajectory files: the schema (a
/// JSON array of flat records with finite numbers) is a hard gate
/// (exit 1), while a >20% drop in any `hot:` tier between the two most
/// recent comparable records prints a non-fatal `::warning::` line —
/// the GitHub Actions annotation format, so CI surfaces the regression
/// without going red on host noise.
fn bench_check(args: &skewsa::util::cli::Args) {
    use skewsa::util::bench::check_trajectory;
    let defaults = ["BENCH_hotpath.json", "BENCH_serve.json", "BENCH_precision.json"];
    let explicit = args.positional.len() > 1;
    let files: Vec<String> = if explicit {
        args.positional[1..].to_vec()
    } else {
        defaults.iter().map(|s| s.to_string()).collect()
    };
    let mut failed = false;
    for f in &files {
        let path = std::path::Path::new(f);
        if !explicit && !path.exists() {
            println!("bench-check: {f}: absent, skipped (run the bench to seed it)");
            continue;
        }
        let c = check_trajectory(path);
        for w in &c.warnings {
            println!("::warning::{w}");
        }
        if c.errors.is_empty() {
            println!("bench-check: {f}: {} record(s), schema ok", c.entries);
        } else {
            failed = true;
            for e in &c.errors {
                eprintln!("bench-check: {f}: {e}");
            }
        }
    }
    if failed {
        std::process::exit(1);
    }
}

fn precision(cfg: &RunConfig, args: &skewsa::util::cli::Args) {
    use skewsa::precision::{AnalysisConfig, PlannerConfig, PrecisionStudy};
    use skewsa::FpFormat;

    let (net, layers) = workload_layers(args, "mobilenet");
    let kinds = kind_list(cfg, args, "precision");
    // The budget is the subcommand's central knob: a typo must not
    // silently plan at the default (same hard-error contract as
    // --workload/--pipeline above).
    let budget = match args.get_f64("budget") {
        Some(b) if b >= 0.0 => b,
        _ => {
            eprintln!(
                "error: invalid --budget '{}' (non-negative number, e.g. 1e-2)",
                args.get("budget").unwrap_or("")
            );
            std::process::exit(2);
        }
    };
    let cap = |key: &str| match args.get_usize(key) {
        Some(v) if v >= 1 => v,
        _ => {
            eprintln!(
                "error: invalid --{key} '{}' (positive integer)",
                args.get(key).unwrap_or("")
            );
            std::process::exit(2);
        }
    };
    let pcfg = PlannerConfig {
        budget,
        kinds,
        candidates: FpFormat::ALL.to_vec(),
        analysis: AnalysisConfig { m_cap: cap("m-cap"), n_cap: cap("n-cap"), seed: cfg.seed },
        tcfg: cfg.timing(),
    };
    println!(
        "planning {net}: budget {:.1e}, kinds {}, {} array, error sweep {}x{} \
         sampled outputs/layer at full reduction depth",
        pcfg.budget,
        pcfg.kinds_label(),
        cfg.geometry,
        pcfg.analysis.m_cap,
        pcfg.analysis.n_cap,
    );
    let study = PrecisionStudy::run(&layers, &pcfg);
    let per_layer = report::precision_per_layer(net, &study);
    if !args.has("quiet") {
        print!("{}", per_layer.render());
    }
    print!("{}", report::precision_pareto(net, &study).render());
    if !study.mixed.meets_budget() {
        eprintln!(
            "note: some layers fell back to FP32 over budget (see the in-budget column)"
        );
    }
    if let Some(path) = args.get("csv") {
        std::fs::write(path, per_layer.table.to_csv()).expect("writing CSV");
        eprintln!("wrote {path}");
    }
}

fn viz(cfg: &RunConfig) {
    let chain = ChainCfg::new(cfg.in_fmt, cfg.out_fmt);
    let rows = cfg.geometry.rows.clamp(2, 4);
    println!("pipeline interleaving, {rows}-PE column, 3 elements (paper Figs. 4 & 6):\n");
    for kind in [PipelineKind::Baseline3b, PipelineKind::Skewed] {
        let weights: Vec<u64> = (0..rows).map(|i| cfg.in_fmt.from_f64(1.0 + i as f64)).collect();
        let a: Vec<Vec<u64>> = (0..3)
            .map(|m| (0..rows).map(|r| cfg.in_fmt.from_f64((m + r) as f64)).collect())
            .collect();
        let mut sim = ColumnSim::new(chain, kind, &weights, a).with_trace();
        sim.run(1000).expect("viz run");
        println!("--- {kind} (chain spacing {}) ---", kind.chain_spacing());
        println!("{}", sim.trace().unwrap().render(16));
    }
}
