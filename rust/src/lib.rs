//! # skewsa — reduced-precision FP systolic arrays with skewed pipelines
//!
//! Library reproduction of Filippas et al., *"Reduced-Precision
//! Floating-Point Arithmetic in Systolic Arrays with Skewed Pipelines"*,
//! IEEE AICAS 2023.
//!
//! The crate is organised bottom-up:
//!
//! * [`arith`] — bit-accurate reduced-precision FP arithmetic: format
//!   codecs (Bfloat16, FP16, FP8-E4M3/E5M2, FP32), an exact softfloat
//!   core, leading-zero anticipation, and the two *structural* chained
//!   fused multiply-add datapaths the paper compares (the state-of-the-art
//!   two-stage pipeline of Fig. 3(b) and the proposed skewed pipeline of
//!   Figs. 5/6 with speculative exponent forwarding).
//! * [`pe`] — cycle-level pipelined processing-element models built on the
//!   datapaths.
//! * [`sa`] — the cycle-accurate weight-stationary systolic-array
//!   simulator: single-column reduction chains, full R×C arrays (dense
//!   reference loop + the allocation-free wavefront-banded
//!   column-parallel fast simulator), dataflow scheduling, GEMM tiling
//!   and cycle traces.
//! * [`timing`] — the closed-form latency model, validated against the
//!   cycle-accurate simulator by the test-suite.
//! * [`energy`] — block-level area / power / energy models from which the
//!   paper's +9% area and +7% power overheads *emerge*.
//! * [`workloads`] — CNN layer tables (MobileNetV1, ResNet50) and their
//!   im2col GEMM lowering.
//! * [`precision`] — mixed-precision analysis and planning: per-layer
//!   numerical-error measurement through the bit-exact `arith` path
//!   against an f64 oracle, and a greedy-by-energy per-layer format
//!   search under an error budget (the quality half of the paper's
//!   quality-vs-hardware-cost tradeoff, made searchable).
//! * [`coordinator`] — the L3 orchestrator: layer→tile scheduling, a
//!   worker pool of simulated arrays, result assembly and golden
//!   verification.
//! * [`serve`] — the multi-tenant GEMM serving layer: bounded request
//!   queue, deadline-windowed dynamic batching, a memoising plan cache,
//!   and multi-array sharding over persistent worker pools — the
//!   production-shaped path that turns the paper's per-tile latency win
//!   into end-to-end throughput.
//! * [`fleet`] — the fleet-scale discrete-event simulator: the serve
//!   request path replayed over a virtual clock and thousands of
//!   simulated shards, with pluggable arrival processes, token-bucket
//!   admission and a reactive p99 autoscaler — differentially pinned
//!   to the threaded serving layer.
//! * [`runtime`] — PJRT wrapper that loads the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) and executes them on the CPU
//!   client; the golden reference for end-to-end numerics.
//! * [`obs`] — observability: the unified metrics registry (counters,
//!   gauges, bounded log2 histograms) and per-request trace spans that
//!   attribute every wall-clock microsecond and every array cycle of a
//!   served request.
//! * [`report`] — emitters that regenerate every table and figure of the
//!   paper's evaluation section.
//! * [`util`] — std-only substrates (deterministic RNG, mini-JSON, CLI
//!   parsing, table rendering) and a small property-testing harness.

pub mod arith;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod fleet;
pub mod obs;
pub mod pe;
pub mod precision;
pub mod report;
pub mod runtime;
pub mod sa;
pub mod serve;
pub mod timing;
pub mod util;
pub mod workloads;

pub use arith::format::FpFormat;
pub use pe::{PipelineKind, PipelineSpec};
