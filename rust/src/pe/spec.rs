//! The data-driven PE micro-architecture descriptor.
//!
//! A [`PipelineSpec`] captures *everything* that distinguishes one
//! pipeline organisation from another — chain spacing, pipeline depth,
//! column tail, the per-stage datapath-block assignment, the
//! stage-boundary register inventory, and the value-level datapath —
//! so the delay model, the area/power models, the closed-form timing
//! formula and all three cycle simulators derive their behaviour from
//! one table instead of per-module `match` arms.
//!
//! The registry of *named* organisations lives in
//! [`crate::pe::PipelineKind`]; this module holds the descriptor type,
//! the composition rules, and the preset spec constants.  Registering a
//! new organisation is one const here plus one registry entry there
//! (see the README walkthrough).
//!
//! **Timing contract** (validated by `tests/prop_pipelines.rs` and the
//! cycle sims): a spec with spacing `S`, depth `D` and tail `τ` streams
//! an `M × R × C_used` tile in
//!
//! ```text
//! T = (M−1) + (C_used−1) + S·(R−1) + D + 1 + τ
//! ```
//!
//! and hands partial sums down the chain under one of two disciplines,
//! both fixed by `(S, D)`:
//!
//! * `S == D` — **capture**: PE `i+1` latches PE `i`'s output register
//!   at its own stage-1 acceptance (the Fig. 3(a)/(b) organisations).
//! * `S < D` — **late read**: PE `i+1` accepts the element while PE `i`
//!   is still mid-pipeline and reads the output register live during its
//!   own stage `D − S + 1` (the skewed/transparent organisations; for
//!   the paper's skewed PE the stage-1 overlap is what the speculative
//!   exponent forwarding buys).

use crate::arith::fma::{BaselineFmaPath, ChainCfg, ChainDatapath, SkewedFmaPath};

/// ceil(log2(n)) over positive integers (shared by the delay/area
/// width formulas).
pub(crate) fn clog2(n: u32) -> f64 {
    (n.max(2) as f64).log2().ceil()
}

/// A combinational datapath block of the FMA pipeline.  Delay and area
/// formulas per block live in [`crate::pe::delay::BlockDelays`] and
/// [`crate::energy::area::AreaModel`]; the spec only says *which* blocks
/// sit in *which* stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Block {
    /// Mantissa multiplier, (m+1)×(m+1).
    Mult,
    /// Exponent add + compare (max / difference).
    ExpCompute,
    /// Alignment barrel shifter across the accumulator window (also
    /// stands in for the skewed design's merged align/normalize shifter,
    /// which has the same single-barrel delay).
    Align,
    /// Wide significand adder.
    Add,
    /// LZA / LZC tree.
    Lza,
    /// Normalization barrel shifter.
    Norm,
    /// The skewed design's Fix Sign & Exponent block (paper §III-B).
    Fix,
}

/// One use of a block inside a stage.  `area_scale` lets a spec count a
/// merged or duplicated structure honestly in the area inventory while
/// keeping the *delay* of one barrel traversal — e.g. the skewed
/// design's direction-muxed left∥right shifter pair is 1.2× one
/// shifter's area but still one shift deep.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BlockUse {
    pub block: Block,
    pub area_scale: f64,
}

/// A plain block use (area scale 1).
pub const fn blk(block: Block) -> BlockUse {
    BlockUse { block, area_scale: 1.0 }
}

/// A block use with a non-unit area scale.
pub const fn blk_scaled(block: Block, area_scale: f64) -> BlockUse {
    BlockUse { block, area_scale }
}

/// A serial chain of blocks: delay = sum of block delays.
pub type PathBlocks = &'static [BlockUse];

/// Parallel alternatives: delay = max over paths; area = sum over paths
/// (every path physically exists).
pub type Segment = &'static [PathBlocks];

/// One pipeline stage: serial segments of parallel paths.
/// `delay(stage) = Σ_segments max_paths Σ_blocks delay(block)`.
pub type StageBlocks = &'static [Segment];

/// A register field crossing a stage boundary (beyond the activation
/// and stationary-weight registers every PE carries).  Widths are
/// functions of the chain configuration, so one inventory serves every
/// format.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RegField {
    /// Raw (unrounded) significand product, `2·(m+1)` bits.
    RawProduct,
    /// A sign bit.
    Sign,
    /// An exponent with overflow headroom, `e + 2` bits.
    Exponent,
    /// Alignment shift amount, `clog2(W) + 1` bits.
    ShiftAmount,
    /// Signed (left-or-right) shift amount, `clog2(W) + 2` bits — the
    /// skewed design's speculative `d′`.
    ShiftAmountSigned,
    /// The accumulator significand window, `W` bits.
    WindowSum,
    /// The sticky bit.
    Sticky,
    /// An LZA count, `clog2(W)` bits.
    LzaCount,
}

impl RegField {
    /// Field width in bits for a chain configuration.
    pub fn bits(self, cfg: &ChainCfg) -> u32 {
        let w = cfg.window;
        match self {
            RegField::RawProduct => 2 * (cfg.in_fmt.man_bits + 1),
            RegField::Sign => 1,
            RegField::Exponent => cfg.in_fmt.exp_bits + 2,
            RegField::ShiftAmount => clog2(w) as u32 + 1,
            RegField::ShiftAmountSigned => clog2(w) as u32 + 2,
            RegField::WindowSum => w,
            RegField::Sticky => 1,
            RegField::LzaCount => clog2(w) as u32,
        }
    }
}

/// The value-level datapath a spec executes.  All organisations are
/// bit-identical by construction (enforced in tests); the id selects
/// which structural path the simulators monomorphize over.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DatapathId {
    /// Normalized-psum forwarding (Figs. 3(a)/3(b) and retimed deep
    /// variants thereof).
    Baseline,
    /// Speculative-exponent forwarding with fix logic (Figs. 5/6).
    Skewed,
}

impl DatapathId {
    /// The executable datapath.
    pub fn handle(self) -> &'static dyn ChainDatapath {
        match self {
            DatapathId::Baseline => &BaselineFmaPath,
            DatapathId::Skewed => &SkewedFmaPath,
        }
    }

    /// Report name.
    pub fn name(self) -> &'static str {
        match self {
            DatapathId::Baseline => "baseline",
            DatapathId::Skewed => "speculative",
        }
    }
}

/// A complete pipeline-organisation descriptor.
///
/// Identity is the `name`: two specs compare (and hash) equal iff their
/// names match, so registry names must be unique — which also keeps
/// `f64` area scales out of `Eq`.
#[derive(Clone, Copy, Debug)]
pub struct PipelineSpec {
    /// Registry name (`--pipeline` value, report label, identity).
    pub name: &'static str,
    /// Accepted CLI/config aliases.
    pub aliases: &'static [&'static str],
    /// One-line description for the `skewsa pipelines` table.
    pub summary: &'static str,
    /// Chain spacing `S`: cycles between PE `i` starting an element and
    /// PE `i+1` being able to start the same element.
    pub spacing: u64,
    /// Pipeline depth `D` (stages per PE).
    pub depth: u64,
    /// Extra pipeline cycles at the column foot before rounding.
    pub column_tail: u64,
    /// Per-stage datapath-block assignment (`len == depth`); drives both
    /// the critical-path delay model and the area/power inventory.
    pub stages: &'static [StageBlocks],
    /// Stage-boundary register fields beyond the common activation +
    /// weight registers; drives the register-bit area inventory.
    pub regs: &'static [RegField],
    /// The value-level datapath.
    pub datapath: DatapathId,
}

impl PartialEq for PipelineSpec {
    fn eq(&self, other: &Self) -> bool {
        self.name == other.name
    }
}
impl Eq for PipelineSpec {}
impl std::hash::Hash for PipelineSpec {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        self.name.hash(state);
    }
}

impl std::fmt::Display for PipelineSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name)
    }
}

impl PipelineSpec {
    /// The pipeline stage (1-indexed) at which the incoming partial sum
    /// is acquired from the predecessor's output register:
    /// `D − S + 1`.  Stage 1 ⇒ the capture discipline (latched at
    /// acceptance); ≥ 2 ⇒ the late-read discipline.
    pub fn psum_stage(&self) -> u64 {
        self.depth - self.spacing + 1
    }

    /// Whether the incoming psum is captured at stage-1 acceptance
    /// (`S == D`) rather than read mid-pipeline.
    pub fn captures_at_accept(&self) -> bool {
        self.spacing == self.depth
    }

    /// Structural invariants every registered spec must satisfy; called
    /// by the simulator constructors, so a malformed custom spec fails
    /// fast instead of corrupting a run.
    pub fn validate(&self) {
        assert!(self.depth >= 2, "{}: depth must be >= 2 (two-phase PE)", self.name);
        assert!(
            self.spacing >= 1 && self.spacing <= self.depth,
            "{}: spacing must satisfy 1 <= S <= depth (got S={} D={})",
            self.name,
            self.spacing,
            self.depth
        );
        assert!(self.column_tail <= 2, "{}: column tail > 2 is not modeled", self.name);
        assert_eq!(
            self.stages.len(),
            self.depth as usize,
            "{}: stage table length must equal depth",
            self.name
        );
    }

    /// Total register bits per PE (common activation + weight registers
    /// plus the spec's stage-boundary fields).
    pub fn register_bits(&self, cfg: &ChainCfg) -> u32 {
        let common = 2 * cfg.in_fmt.width(); // a-reg + stationary weight
        common + self.regs.iter().map(|f| f.bits(cfg)).sum::<u32>()
    }

    /// Area-inventory count of a block across all stages (sum of
    /// `area_scale` over every use).
    pub fn block_count(&self, block: Block) -> f64 {
        self.stages
            .iter()
            .flat_map(|stage| stage.iter())
            .flat_map(|segment| segment.iter())
            .flat_map(|path| path.iter())
            .filter(|u| u.block == block)
            .map(|u| u.area_scale)
            .sum()
    }
}

// ---------------------------------------------------------------------------
// Preset stage tables.  Shorthand: a stage is a list of serial segments,
// each segment a list of parallel paths, each path a serial block chain.
// ---------------------------------------------------------------------------

use Block::{Add, Align, ExpCompute, Fix, Lza, Mult, Norm};

/// Fig. 3(a): stage 1 = mult ∥ (exp + align) — alignment rides under the
/// multiplier-dominance assumption; stage 2 = (add ∥ LZA) + norm.
const REGULAR_3A_STAGES: &[StageBlocks] = &[
    &[&[&[blk(Mult)], &[blk(ExpCompute), blk(Align)]]],
    &[&[&[blk(Add)], &[blk(Lza)]], &[&[blk(Norm)]]],
];

/// Fig. 3(b): stage 1 = mult ∥ exp; stage 2 = align + (add ∥ LZA) + norm.
const BASELINE_3B_STAGES: &[StageBlocks] = &[
    &[&[&[blk(Mult)], &[blk(ExpCompute)]]],
    &[&[&[blk(Align)]], &[&[blk(Add)], &[blk(Lza)]], &[&[blk(Norm)]]],
];

/// Figs. 5/6: stage 1 = mult ∥ speculative exp; stage 2 = fix + merged
/// align/normalize shifter (the 1.2×-area direction-muxed pair, one
/// barrel deep, in parallel with the right-only product aligner) +
/// (add ∥ LZA).  The separate normalizer is retimed away.
const SKEWED_STAGES: &[StageBlocks] = &[
    &[&[&[blk(Mult)], &[blk(ExpCompute)]]],
    &[
        &[&[blk(Fix)]],
        &[&[blk_scaled(Align, 1.2)], &[blk(Align)]],
        &[&[blk(Add)], &[blk(Lza)]],
    ],
];

/// ArrayFlex-style transparent chaining (arXiv 2211.12600): the psum
/// pipeline boundary between neighbouring PEs is made transparent, so
/// the successor starts one cycle after its predecessor (S = 1) with the
/// *baseline* datapath.  The price is that the exponent compare against
/// the late-arriving psum moves into stage 2, which therefore carries
/// exp + align + add + norm serially — a longer critical path that
/// trades clock slack for chain latency.
const TRANSPARENT_STAGES: &[StageBlocks] = &[
    &[&[&[blk(Mult)]]],
    &[
        &[&[blk(ExpCompute)]],
        &[&[blk(Align)]],
        &[&[blk(Add)], &[blk(Lza)]],
        &[&[blk(Norm)]],
    ],
];

/// Three-stage deep pipeline in the style of low-cost matrix-engine FMA
/// units with normalization split out (arXiv 2408.11997): stage 1 =
/// mult ∥ exp, stage 2 = align + (add ∥ LZA), stage 3 = norm.  Shorter
/// stages buy clock headroom for one extra cycle of fill latency and an
/// extra rank of pipeline registers.
const DEEP3_STAGES: &[StageBlocks] = &[
    &[&[&[blk(Mult)], &[blk(ExpCompute)]]],
    &[&[&[blk(Align)]], &[&[blk(Add)], &[blk(Lza)]]],
    &[&[&[blk(Norm)]]],
];

// ---------------------------------------------------------------------------
// Preset register inventories (what physically crosses stage boundaries;
// see the module docs of `energy::area` for the derivation).
// ---------------------------------------------------------------------------

use RegField::{
    Exponent, LzaCount, RawProduct, ShiftAmount, ShiftAmountSigned, Sign, Sticky, WindowSum,
};

/// Fig. 3(a)/(b): s1→s2 carries raw product + sign, computed ê, and the
/// alignment amount; the output register carries the normalized sum +
/// sign + sticky + exponent.
const BASELINE_REGS: &[RegField] =
    &[RawProduct, Sign, Exponent, ShiftAmount, WindowSum, Sign, Sticky, Exponent];

/// Skewed: s1→s2 forwards *both* `e_M` and `ê_{i−1}` plus the signed
/// speculative `d′`; the output register adds the LZA count `L` (the
/// extra cross-PE forwarding the paper charges the +9% area to).
const SKEWED_REGS: &[RegField] = &[
    RawProduct,
    Sign,
    Exponent,
    Exponent,
    ShiftAmountSigned,
    WindowSum,
    Sign,
    Sticky,
    Exponent,
    LzaCount,
];

/// Transparent: with the whole exponent path in stage 2 the s1→s2
/// boundary carries only the raw product + sign — transparency *saves*
/// register bits relative to Fig. 3(b).
const TRANSPARENT_REGS: &[RegField] =
    &[RawProduct, Sign, WindowSum, Sign, Sticky, Exponent];

/// Deep3: s1→s2 as the baseline minus the precomputed shift amount
/// (computed in stage 2); s2→s3 carries the unnormalized sum + L for the
/// stage-3 normalizer; the output register is baseline-shaped.  Two
/// boundary ranks ⇒ the register-area cost of the deeper pipeline.
const DEEP3_REGS: &[RegField] = &[
    RawProduct,
    Sign,
    Exponent,
    WindowSum,
    Sign,
    Sticky,
    Exponent,
    LzaCount,
    WindowSum,
    Sign,
    Sticky,
    Exponent,
];

// ---------------------------------------------------------------------------
// The preset specs.
// ---------------------------------------------------------------------------

/// Fig. 3(a): the traditional full-precision-oriented organisation.
pub const REGULAR_3A: PipelineSpec = PipelineSpec {
    name: "regular-3a",
    aliases: &["regular", "3a"],
    summary: "Fig. 3(a): align in stage 1 under the multiplier",
    spacing: 2,
    depth: 2,
    column_tail: 0,
    stages: REGULAR_3A_STAGES,
    regs: BASELINE_REGS,
    datapath: DatapathId::Baseline,
};

/// Fig. 3(b): the state-of-the-art reduced-precision baseline.
pub const BASELINE_3B: PipelineSpec = PipelineSpec {
    name: "baseline-3b",
    aliases: &["baseline", "3b"],
    summary: "Fig. 3(b): state-of-the-art reduced-precision baseline",
    spacing: 2,
    depth: 2,
    column_tail: 0,
    stages: BASELINE_3B_STAGES,
    regs: BASELINE_REGS,
    datapath: DatapathId::Baseline,
};

/// Figs. 5/6: the paper's proposed skewed pipeline.
pub const SKEWED: PipelineSpec = PipelineSpec {
    name: "skewed",
    aliases: &["skew"],
    summary: "Figs. 5/6: speculative-exponent skewed pipeline (the paper)",
    spacing: 1,
    depth: 2,
    column_tail: 1,
    stages: SKEWED_STAGES,
    regs: SKEWED_REGS,
    datapath: DatapathId::Skewed,
};

/// ArrayFlex-style transparent chaining (arXiv 2211.12600).
pub const TRANSPARENT: PipelineSpec = PipelineSpec {
    name: "transparent",
    aliases: &["arrayflex", "transparent-s1"],
    summary: "ArrayFlex-style transparent chaining: S=1, longer stage 2",
    spacing: 1,
    depth: 2,
    column_tail: 0,
    stages: TRANSPARENT_STAGES,
    regs: TRANSPARENT_REGS,
    datapath: DatapathId::Baseline,
};

/// Three-stage deep pipeline with split-out normalization
/// (arXiv 2408.11997 style).
pub const DEEP3: PipelineSpec = PipelineSpec {
    name: "deep3",
    aliases: &["3stage", "deep-3"],
    summary: "3-stage deep pipeline: norm split out, clock headroom",
    spacing: 2,
    depth: 3,
    column_tail: 0,
    stages: DEEP3_STAGES,
    regs: DEEP3_REGS,
    datapath: DatapathId::Baseline,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pe::PipelineKind;

    #[test]
    fn all_presets_validate() {
        for kind in PipelineKind::ALL {
            kind.spec().validate();
        }
    }

    #[test]
    fn preset_names_and_aliases_are_unique() {
        let mut seen = std::collections::HashSet::new();
        for kind in PipelineKind::ALL {
            let s = kind.spec();
            assert!(seen.insert(s.name), "duplicate name {}", s.name);
            for &a in s.aliases {
                assert!(seen.insert(a), "duplicate alias {a}");
            }
        }
    }

    #[test]
    fn psum_stage_encodes_the_two_disciplines() {
        // Capture at accept for S == D, late read at stage D−S+1 else.
        assert_eq!(BASELINE_3B.psum_stage(), 1);
        assert!(BASELINE_3B.captures_at_accept());
        assert_eq!(SKEWED.psum_stage(), 2);
        assert!(!SKEWED.captures_at_accept());
        assert_eq!(TRANSPARENT.psum_stage(), 2);
        assert_eq!(DEEP3.psum_stage(), 2);
        assert!(!DEEP3.captures_at_accept());
    }

    #[test]
    fn block_inventory_matches_the_figures() {
        // Fig. 3(a)/(b): one aligner + one normalizer.
        let shifters =
            |s: &PipelineSpec| s.block_count(Block::Align) + s.block_count(Block::Norm);
        assert_eq!(shifters(&BASELINE_3B), 2.0);
        assert_eq!(shifters(&REGULAR_3A), 2.0);
        // Fig. 6: merged pair (1.2×) + product aligner, no normalizer.
        assert!((shifters(&SKEWED) - 2.2).abs() < 1e-12);
        assert_eq!(SKEWED.block_count(Block::Fix), 1.0);
        assert_eq!(BASELINE_3B.block_count(Block::Fix), 0.0);
        // Every organisation has exactly one multiplier and one adder.
        for kind in PipelineKind::ALL {
            assert_eq!(kind.spec().block_count(Block::Mult), 1.0, "{kind}");
            assert_eq!(kind.spec().block_count(Block::Add), 1.0, "{kind}");
        }
    }

    #[test]
    fn spec_identity_is_the_name() {
        let mut renamed = SKEWED;
        renamed.name = "custom";
        assert_ne!(renamed, SKEWED);
        assert_eq!(SKEWED, *PipelineKind::Skewed.spec());
    }

    #[test]
    fn custom_spec_with_configurable_spacing_validates() {
        // The ArrayFlex axis the registry is built for: a const spec
        // with any 1 ≤ S ≤ D is a first-class organisation.
        const WIDE: PipelineSpec = PipelineSpec {
            name: "custom-s3",
            aliases: &[],
            summary: "spacing-3 capture organisation",
            spacing: 3,
            depth: 3,
            column_tail: 0,
            stages: DEEP3_STAGES,
            regs: DEEP3_REGS,
            datapath: DatapathId::Baseline,
        };
        WIDE.validate();
        assert_eq!(WIDE.psum_stage(), 1);
        assert!(WIDE.captures_at_accept());
    }

    #[test]
    #[should_panic]
    fn spacing_beyond_depth_is_rejected() {
        let mut bad = BASELINE_3B;
        bad.spacing = 3;
        bad.validate();
    }
}
