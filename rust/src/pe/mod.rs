//! Processing-element models.
//!
//! * [`PipelineKind`] — the three PE micro-architectures under study:
//!   the classic full-precision-oriented pipeline (Fig. 3a), the
//!   state-of-the-art reduced-precision pipeline (Fig. 3b, the paper's
//!   baseline), and the proposed skewed pipeline (Figs. 5/6).
//! * [`delay`] — the per-stage combinational delay model that captures
//!   the paper's motivating observation: in reduced precision the
//!   exponent/alignment logic no longer hides under the multiplier.
//! * [`cycle`] — the cycle-level PE with explicit stage registers, used
//!   by the cycle-accurate column/array simulators in [`crate::sa`].

pub mod cycle;
pub mod delay;

use crate::arith::fma::{BaselineFmaPath, ChainDatapath, SkewedFmaPath};

/// The PE pipeline organisations compared in the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PipelineKind {
    /// Fig. 3(a): multiply ∥ (exponent compute + align) in stage 1,
    /// add + LZA + normalize in stage 2.  The traditional organisation —
    /// assumes the multiplier delay hides the exponent/align logic, which
    /// fails for reduced-precision formats (§II).
    Regular3a,
    /// Fig. 3(b): multiply ∥ exponent compute in stage 1; align + add +
    /// LZA + normalize in stage 2.  The state-of-the-art reference design
    /// for reduced precision; chains serialize with spacing 2 (§III-A).
    Baseline3b,
    /// Figs. 5/6: speculative exponent forwarding + fix logic + retimed
    /// normalization.  Consecutive PEs overlap stages; spacing 1.
    Skewed,
}

impl PipelineKind {
    /// All kinds, in presentation order.
    pub const ALL: [PipelineKind; 3] =
        [PipelineKind::Regular3a, PipelineKind::Baseline3b, PipelineKind::Skewed];

    /// Report name.
    pub fn name(&self) -> &'static str {
        match self {
            PipelineKind::Regular3a => "regular-3a",
            PipelineKind::Baseline3b => "baseline-3b",
            PipelineKind::Skewed => "skewed",
        }
    }

    /// Chain spacing `S`: cycles between PE *i* starting an element and
    /// PE *i+1* being able to start the same element (§III; DESIGN §6).
    pub fn chain_spacing(&self) -> u64 {
        match self {
            PipelineKind::Regular3a | PipelineKind::Baseline3b => 2,
            PipelineKind::Skewed => 1,
        }
    }

    /// Pipeline depth of one PE (all three are two-stage designs at the
    /// paper's reduced-precision operating point).
    pub fn stages(&self) -> u64 {
        2
    }

    /// Extra pipeline cycles at the column foot before rounding: the
    /// skewed column needs the extra addition stage of Fig. 6 (last
    /// paragraph of §III-B).
    pub fn column_tail(&self) -> u64 {
        match self {
            PipelineKind::Regular3a | PipelineKind::Baseline3b => 0,
            PipelineKind::Skewed => 1,
        }
    }

    /// The value-level datapath executed by this PE kind.  Fig. 3(a) and
    /// Fig. 3(b) differ only in *where* alignment happens in time, not in
    /// the computed value, so both use the baseline datapath; the skewed
    /// PE uses the speculative datapath (bit-identical by construction —
    /// enforced in tests).
    pub fn datapath(&self) -> &'static dyn ChainDatapath {
        match self {
            PipelineKind::Regular3a | PipelineKind::Baseline3b => &BaselineFmaPath,
            PipelineKind::Skewed => &SkewedFmaPath,
        }
    }

    /// True for the paper's proposed design.
    pub fn is_skewed(&self) -> bool {
        matches!(self, PipelineKind::Skewed)
    }
}

impl std::fmt::Display for PipelineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for PipelineKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "regular-3a" | "regular" | "3a" => Ok(PipelineKind::Regular3a),
            "baseline-3b" | "baseline" | "3b" => Ok(PipelineKind::Baseline3b),
            "skewed" | "skew" => Ok(PipelineKind::Skewed),
            _ => Err(format!("unknown pipeline kind '{s}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spacing_matches_paper() {
        assert_eq!(PipelineKind::Baseline3b.chain_spacing(), 2);
        assert_eq!(PipelineKind::Regular3a.chain_spacing(), 2);
        assert_eq!(PipelineKind::Skewed.chain_spacing(), 1);
    }

    #[test]
    fn parse_roundtrip() {
        for k in PipelineKind::ALL {
            assert_eq!(k.name().parse::<PipelineKind>().unwrap(), k);
        }
        assert!("nope".parse::<PipelineKind>().is_err());
    }

    #[test]
    fn skewed_has_column_tail() {
        assert_eq!(PipelineKind::Skewed.column_tail(), 1);
        assert_eq!(PipelineKind::Baseline3b.column_tail(), 0);
    }
}
