//! Processing-element models.
//!
//! * [`spec`] — the data-driven [`PipelineSpec`] descriptor: chain
//!   spacing, pipeline depth, column tail, per-stage datapath-block
//!   assignment, stage-boundary register inventory, and the value-level
//!   datapath handle.  Every downstream model (delay, area/power,
//!   closed-form timing, all three cycle simulators) derives its
//!   behaviour from the spec.
//! * [`PipelineKind`] — the *named-preset registry* over specs: the
//!   paper's three organisations (Fig. 3(a) regular, Fig. 3(b)
//!   baseline, Figs. 5/6 skewed) plus two registered from related work
//!   (ArrayFlex-style transparent chaining, arXiv 2211.12600; a
//!   3-stage deep pipeline with split-out normalization,
//!   arXiv 2408.11997).  The `spec()` table below is the **only**
//!   `match` over `PipelineKind` in the crate.
//! * [`delay`] — the per-stage combinational delay model composed from
//!   the spec's block assignment.
//! * [`cycle`] — the cycle-level PE with explicit stage registers, used
//!   by the cycle-accurate simulators in [`crate::sa`].

pub mod cycle;
pub mod delay;
pub mod spec;

use crate::arith::fma::ChainDatapath;
pub use spec::{DatapathId, PipelineSpec};

/// The registered PE pipeline organisations.
///
/// This enum is only an *index* into the preset registry: all behaviour
/// lives in the [`PipelineSpec`] each variant names.  Registering a new
/// organisation = one spec const in [`spec`] + one variant + one
/// registry row here (see the README walkthrough).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PipelineKind {
    /// Fig. 3(a): the traditional organisation — alignment hides under
    /// the multiplier, which fails at reduced precision (§II).
    Regular3a,
    /// Fig. 3(b): the state-of-the-art reduced-precision baseline;
    /// chains serialize with spacing 2 (§III-A).
    Baseline3b,
    /// Figs. 5/6: the paper's skewed pipeline — speculative exponent
    /// forwarding, fix logic, retimed normalization; spacing 1.
    Skewed,
    /// ArrayFlex-style transparent chaining (arXiv 2211.12600):
    /// spacing 1 with the baseline datapath, trading cycle time for
    /// chain latency.
    Transparent,
    /// Three-stage deep pipeline with split-out normalization
    /// (arXiv 2408.11997 style): clock headroom for +1 fill cycle.
    Deep3,
}

impl PipelineKind {
    /// All registered kinds, in presentation order.
    pub const ALL: [PipelineKind; 5] = [
        PipelineKind::Regular3a,
        PipelineKind::Baseline3b,
        PipelineKind::Skewed,
        PipelineKind::Transparent,
        PipelineKind::Deep3,
    ];

    /// The preset registry: variant → spec.  The single `match` over
    /// `PipelineKind` in the crate.
    pub fn spec(&self) -> &'static PipelineSpec {
        match self {
            PipelineKind::Regular3a => &spec::REGULAR_3A,
            PipelineKind::Baseline3b => &spec::BASELINE_3B,
            PipelineKind::Skewed => &spec::SKEWED,
            PipelineKind::Transparent => &spec::TRANSPARENT,
            PipelineKind::Deep3 => &spec::DEEP3,
        }
    }

    /// Registry name.
    pub fn name(&self) -> &'static str {
        self.spec().name
    }

    /// Chain spacing `S` (§III; DESIGN §6).
    pub fn chain_spacing(&self) -> u64 {
        self.spec().spacing
    }

    /// Pipeline depth of one PE.
    pub fn stages(&self) -> u64 {
        self.spec().depth
    }

    /// Extra pipeline cycles at the column foot before rounding.
    pub fn column_tail(&self) -> u64 {
        self.spec().column_tail
    }

    /// The value-level datapath executed by this organisation.  All
    /// registered datapaths are bit-identical by construction (enforced
    /// in tests); they differ in *when* values move, not in the values.
    pub fn datapath(&self) -> &'static dyn ChainDatapath {
        self.spec().datapath.handle()
    }

    /// True for the paper's proposed design.
    pub fn is_skewed(&self) -> bool {
        self.spec().datapath == DatapathId::Skewed
    }

    /// Parse a comma-separated kind list; `all` expands to every
    /// registered organisation and `both` to the paper's baseline-vs-
    /// proposed pair (the historical `--pipeline both` serve spelling).
    pub fn parse_list(s: &str) -> Result<Vec<PipelineKind>, String> {
        match s {
            "all" => return Ok(PipelineKind::ALL.to_vec()),
            "both" => return Ok(vec![PipelineKind::Baseline3b, PipelineKind::Skewed]),
            _ => {}
        }
        s.split(',')
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .map(str::parse)
            .collect::<Result<Vec<_>, _>>()
            .and_then(|kinds| {
                if kinds.is_empty() {
                    Err(format!("empty pipeline list '{s}'"))
                } else {
                    Ok(kinds)
                }
            })
    }
}

impl std::fmt::Display for PipelineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for PipelineKind {
    type Err = String;

    /// Accepts every registry name and alias; an unknown name errors
    /// with the full valid-name list and a did-you-mean suggestion
    /// (edit distance ≤ 2, same contract as the CLI flag parser).
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        for kind in PipelineKind::ALL {
            let sp = kind.spec();
            if sp.name == s || sp.aliases.contains(&s) {
                return Ok(kind);
            }
        }
        let valid: Vec<&str> = PipelineKind::ALL.iter().map(|k| k.name()).collect();
        let hint = PipelineKind::ALL
            .iter()
            .flat_map(|k| std::iter::once(k.name()).chain(k.spec().aliases.iter().copied()))
            .map(|name| (crate::util::cli::edit_distance(s, name), name))
            .filter(|&(d, _)| d <= 2)
            .min_by_key(|&(d, _)| d)
            .map(|(_, name)| format!(" (did you mean '{name}'?)"))
            .unwrap_or_default();
        Err(format!("unknown pipeline kind '{s}'{hint}; valid: {}", valid.join("|")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spacing_matches_paper() {
        assert_eq!(PipelineKind::Baseline3b.chain_spacing(), 2);
        assert_eq!(PipelineKind::Regular3a.chain_spacing(), 2);
        assert_eq!(PipelineKind::Skewed.chain_spacing(), 1);
        // The related-work registrations.
        assert_eq!(PipelineKind::Transparent.chain_spacing(), 1);
        assert_eq!(PipelineKind::Deep3.chain_spacing(), 2);
        assert_eq!(PipelineKind::Deep3.stages(), 3);
    }

    #[test]
    fn parse_roundtrip() {
        for k in PipelineKind::ALL {
            assert_eq!(k.name().parse::<PipelineKind>().unwrap(), k);
            for &alias in k.spec().aliases {
                assert_eq!(alias.parse::<PipelineKind>().unwrap(), k, "{alias}");
            }
        }
        assert!("nope".parse::<PipelineKind>().is_err());
    }

    #[test]
    fn parse_errors_list_names_and_suggest() {
        let err = "skewd".parse::<PipelineKind>().unwrap_err();
        assert!(err.contains("did you mean 'skewed'?"), "{err}");
        assert!(err.contains("regular-3a|baseline-3b|skewed|transparent|deep3"), "{err}");
        let err = "transparnt".parse::<PipelineKind>().unwrap_err();
        assert!(err.contains("did you mean 'transparent'?"), "{err}");
        // Nothing close: names listed, no hint.
        let err = "zzzzzz".parse::<PipelineKind>().unwrap_err();
        assert!(!err.contains("did you mean"), "{err}");
        assert!(err.contains("valid:"), "{err}");
    }

    #[test]
    fn parse_list_forms() {
        assert_eq!(PipelineKind::parse_list("all").unwrap(), PipelineKind::ALL.to_vec());
        assert_eq!(
            PipelineKind::parse_list("both").unwrap(),
            vec![PipelineKind::Baseline3b, PipelineKind::Skewed]
        );
        assert_eq!(
            PipelineKind::parse_list("skewed, deep3").unwrap(),
            vec![PipelineKind::Skewed, PipelineKind::Deep3]
        );
        assert!(PipelineKind::parse_list("skewed,nope").is_err());
        assert!(PipelineKind::parse_list("").is_err());
    }

    #[test]
    fn skewed_has_column_tail() {
        assert_eq!(PipelineKind::Skewed.column_tail(), 1);
        assert_eq!(PipelineKind::Baseline3b.column_tail(), 0);
        assert_eq!(PipelineKind::Transparent.column_tail(), 0);
    }

    #[test]
    fn only_the_skewed_preset_runs_the_speculative_datapath() {
        for k in PipelineKind::ALL {
            assert_eq!(k.is_skewed(), k == PipelineKind::Skewed, "{k}");
        }
    }
}
