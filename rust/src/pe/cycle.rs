//! Cycle-level processing element with explicit stage registers.
//!
//! A [`CyclePe`] holds the pipeline registers of a
//! [`PipelineSpec`](crate::pe::PipelineSpec)-described FMA design — a
//! rank of [`StageReg`] slots per internal stage boundary plus the
//! [`OutReg`] handed down the chain — and per-stage activity counters.
//! The column/array simulators in [`crate::sa`] own the scheduling
//! (when a stage fires, where the incoming partial sum is read from —
//! which is exactly what distinguishes the organisations); the PE
//! provides the register state and the counters.
//!
//! An element accepted at cycle `t` occupies stage `k` (1-indexed)
//! during cycle `t + k − 1`: it sits in `pipe[k−1]` from the end of
//! that cycle, and lands in `out` at the end of cycle `t + depth − 1`.
//! The datapath value is computed at the spec's psum stage
//! (`depth − spacing + 1`) and carried in [`StageReg::val`] from there.

use crate::arith::fma::PsumSignal;
use crate::pe::PipelineKind;

/// An in-flight element inside the PE pipeline.
#[derive(Clone, Copy, Debug)]
pub struct StageReg {
    /// Element (input-row) index this PE is processing.
    pub m: usize,
    /// Activation bits (input format), needed until the psum stage runs
    /// the datapath.
    pub a: u64,
    /// The computed chained-FMA result, present from the psum stage
    /// onward (immediately on acceptance under the capture discipline).
    pub val: Option<PsumSignal>,
}

/// Output pipeline register: the partial sum handed South.
#[derive(Clone, Copy, Debug)]
pub struct OutReg {
    pub m: usize,
    pub sig: PsumSignal,
    /// Consumed-by-successor mark; a second write over an untaken value
    /// is a schedule violation (the psum would be lost in hardware).
    pub taken: bool,
}

/// Per-PE activity counters, accumulated across a run; the energy model
/// converts these into dynamic-energy estimates.  `s1` counts the entry
/// (multiplier) stage, `s2` the exit (result-commit) stage — the two
/// stages every organisation has.  Intermediate carry stages of deeper
/// pipelines contribute area/power through their register inventory,
/// not through these counters, which keeps the closed-form recovery in
/// the fast simulator depth-independent.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PeActivity {
    /// Entry-stage evaluations (multiplier + exponent logic fired).
    pub s1_evals: u64,
    /// Exit-stage evaluations (a result committed to the out register).
    pub s2_evals: u64,
    /// Cycles this PE had an empty entry stage (pipeline bubble).
    pub s1_bubbles: u64,
    /// Cycles this PE had an empty exit stage.
    pub s2_bubbles: u64,
}

impl PeActivity {
    pub fn merge(&mut self, o: &PeActivity) {
        self.s1_evals += o.s1_evals;
        self.s2_evals += o.s2_evals;
        self.s1_bubbles += o.s1_bubbles;
        self.s2_bubbles += o.s2_bubbles;
    }

    /// Utilization in [0,1]: fraction of stage-slots doing useful work.
    pub fn utilization(&self) -> f64 {
        let busy = (self.s1_evals + self.s2_evals) as f64;
        let total = busy + (self.s1_bubbles + self.s2_bubbles) as f64;
        if total == 0.0 {
            0.0
        } else {
            busy / total
        }
    }
}

/// A cycle-level PE: weight-stationary operand + the stage registers of
/// a `depth`-stage pipeline (`pipe.len() == depth − 1` internal
/// boundaries, plus `out`).
#[derive(Clone, Debug)]
pub struct CyclePe {
    /// The stationary weight (input-format bits).
    pub weight: u64,
    /// Internal stage-boundary registers: `pipe[k]` holds the element
    /// that has completed stages `1..=k+1`.
    pub pipe: Vec<Option<StageReg>>,
    pub out: Option<OutReg>,
    pub activity: PeActivity,
}

impl CyclePe {
    /// A PE of a registered organisation.
    pub fn new(kind: PipelineKind, weight: u64) -> Self {
        Self::with_depth(kind.stages() as usize, weight)
    }

    /// A PE with an explicit pipeline depth (custom specs).
    pub fn with_depth(depth: usize, weight: u64) -> Self {
        assert!(depth >= 2, "PE depth must be >= 2");
        CyclePe {
            weight,
            pipe: vec![None; depth - 1],
            out: None,
            activity: PeActivity::default(),
        }
    }

    /// Pipeline depth this PE was built for.
    pub fn depth(&self) -> usize {
        self.pipe.len() + 1
    }

    /// The register feeding the exit stage (`pipe[depth−2]`).
    pub fn exit_slot(&self) -> Option<StageReg> {
        self.pipe[self.pipe.len() - 1]
    }

    /// Record an entry-stage acceptance (the multiplier fires).
    pub fn accept_stage1(&mut self, next: StageReg) -> StageReg {
        self.activity.s1_evals += 1;
        next
    }

    /// Record an idle entry-stage cycle.
    pub fn stage1_bubble(&mut self) {
        self.activity.s1_bubbles += 1;
    }

    /// Advance the internal pipeline by one stage: `pipe[k] ← pipe[k−1]`,
    /// with `accepted` entering at `pipe[0]`.  The exit slot's previous
    /// content must already have been staged to `out` by the caller.
    pub fn shift(&mut self, accepted: Option<StageReg>) {
        for k in (1..self.pipe.len()).rev() {
            self.pipe[k] = self.pipe[k - 1];
        }
        self.pipe[0] = accepted;
    }

    /// Replace the weight (weight-tile reload) and clear in-flight state.
    pub fn reload(&mut self, weight: u64) {
        self.weight = weight;
        for slot in &mut self.pipe {
            *slot = None;
        }
        self.out = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::fma::{ChainCfg, ChainDatapath, SkewedFmaPath};
    use crate::arith::format::FpFormat;

    const CFG: ChainCfg = ChainCfg::BF16_FP32;

    fn bf(x: f64) -> u64 {
        FpFormat::BF16.from_f64(x)
    }

    #[test]
    fn depth_matches_registered_specs() {
        assert_eq!(CyclePe::new(PipelineKind::Baseline3b, 0).depth(), 2);
        assert_eq!(CyclePe::new(PipelineKind::Skewed, 0).depth(), 2);
        assert_eq!(CyclePe::new(PipelineKind::Deep3, 0).depth(), 3);
    }

    #[test]
    fn shift_advances_elements_toward_the_exit() {
        let mut pe = CyclePe::with_depth(3, bf(1.0));
        pe.shift(Some(StageReg { m: 0, a: bf(2.0), val: None }));
        assert_eq!(pe.pipe[0].unwrap().m, 0);
        assert!(pe.exit_slot().is_none());
        pe.shift(Some(StageReg { m: 1, a: bf(3.0), val: None }));
        assert_eq!(pe.pipe[0].unwrap().m, 1);
        assert_eq!(pe.exit_slot().unwrap().m, 0);
    }

    #[test]
    fn value_rides_the_pipeline_once_computed() {
        let mut psum = PsumSignal::zero(&CFG);
        psum = SkewedFmaPath.step(&CFG, &psum, bf(2.0), bf(5.0));
        let mut pe = CyclePe::with_depth(3, bf(1.0));
        pe.shift(Some(StageReg { m: 0, a: bf(4.0), val: Some(psum) }));
        pe.shift(None);
        let slot = pe.exit_slot().unwrap();
        assert_eq!(slot.val.unwrap().val.value_f64(CFG.window), 10.0);
    }

    #[test]
    fn counters_track_entry_and_exit_stages() {
        let mut pe = CyclePe::new(PipelineKind::Baseline3b, bf(1.0));
        pe.accept_stage1(StageReg { m: 0, a: bf(1.0), val: None });
        pe.stage1_bubble();
        pe.activity.s2_evals += 1;
        pe.activity.s2_bubbles += 1;
        assert_eq!(pe.activity.s1_evals, 1);
        assert_eq!(pe.activity.s1_bubbles, 1);
        assert!((pe.activity.utilization() - 0.5).abs() < 1e-12);
        assert_eq!(PeActivity::default().utilization(), 0.0);
    }

    #[test]
    fn reload_clears_pipeline_state() {
        let mut pe = CyclePe::new(PipelineKind::Skewed, bf(1.0));
        pe.shift(Some(StageReg { m: 0, a: bf(1.0), val: None }));
        pe.out = Some(OutReg { m: 0, sig: PsumSignal::zero(&CFG), taken: false });
        pe.reload(bf(2.0));
        assert!(pe.pipe.iter().all(Option::is_none));
        assert!(pe.out.is_none());
        assert_eq!(pe.weight, bf(2.0));
    }
}
