//! Cycle-level processing element with explicit stage registers.
//!
//! A [`CyclePe`] holds the two pipeline registers of the paper's
//! two-stage FMA designs plus per-block activity counters.  The
//! column/array simulators in [`crate::sa`] own the scheduling (when a
//! stage fires, where the incoming partial sum is read from — which is
//! exactly what distinguishes the baseline from the skewed organisation);
//! the PE provides the register state and the datapath evaluation.

use crate::arith::fma::{ChainCfg, PsumSignal};
use crate::pe::PipelineKind;

/// Stage-1 pipeline register: the element captured by the multiply /
/// exponent-compute stage.
#[derive(Clone, Copy, Debug)]
pub struct S1Reg {
    /// Element (input-row) index this PE is processing.
    pub m: usize,
    /// Activation bits (input format).
    pub a: u64,
    /// Incoming partial sum, captured at stage 1 — the baseline (Fig. 3b)
    /// latches the whole normalized psum here.  The skewed PE does *not*
    /// capture the sum at stage 1 (only the speculative exponent, which
    /// is folded into the datapath step); it reads the raw sum from the
    /// previous PE's output register during its stage 2.
    pub psum: Option<PsumSignal>,
}

/// Output (stage-2) pipeline register: the partial sum handed South.
#[derive(Clone, Copy, Debug)]
pub struct OutReg {
    pub m: usize,
    pub sig: PsumSignal,
    /// Consumed-by-successor mark; a second write over an untaken value
    /// is a schedule violation (the psum would be lost in hardware).
    pub taken: bool,
}

/// Per-block activity counters, accumulated across a run; the energy
/// model converts these into dynamic-energy estimates.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PeActivity {
    /// Stage-1 evaluations (multiplier + exponent logic fired).
    pub s1_evals: u64,
    /// Stage-2 evaluations (align/add/LZA — and normalize or fix).
    pub s2_evals: u64,
    /// Cycles this PE had an empty stage 1 (pipeline bubble).
    pub s1_bubbles: u64,
    /// Cycles this PE had an empty stage 2.
    pub s2_bubbles: u64,
}

impl PeActivity {
    pub fn merge(&mut self, o: &PeActivity) {
        self.s1_evals += o.s1_evals;
        self.s2_evals += o.s2_evals;
        self.s1_bubbles += o.s1_bubbles;
        self.s2_bubbles += o.s2_bubbles;
    }

    /// Utilization in [0,1]: fraction of stage-slots doing useful work.
    pub fn utilization(&self) -> f64 {
        let busy = (self.s1_evals + self.s2_evals) as f64;
        let total = busy + (self.s1_bubbles + self.s2_bubbles) as f64;
        if total == 0.0 {
            0.0
        } else {
            busy / total
        }
    }
}

/// A cycle-level PE: weight-stationary operand + the two stage registers.
#[derive(Clone, Debug)]
pub struct CyclePe {
    pub kind: PipelineKind,
    /// The stationary weight (input-format bits).
    pub weight: u64,
    pub s1: Option<S1Reg>,
    pub out: Option<OutReg>,
    pub activity: PeActivity,
}

impl CyclePe {
    pub fn new(kind: PipelineKind, weight: u64) -> Self {
        CyclePe { kind, weight, s1: None, out: None, activity: PeActivity::default() }
    }

    /// Evaluate stage 2 on the current stage-1 register, producing the
    /// next output-register value.  `psum_late` supplies the partial sum
    /// for organisations that read it at stage 2 (the skewed design reads
    /// the previous PE's raw adder output + `L` here); the baseline uses
    /// the psum captured in its own stage-1 register.
    ///
    /// Returns `None` when stage 1 is empty (bubble).
    pub fn eval_stage2(
        &mut self,
        cfg: &ChainCfg,
        psum_late: Option<&PsumSignal>,
    ) -> Option<OutReg> {
        let s1 = match self.s1 {
            Some(s) => s,
            None => {
                self.activity.s2_bubbles += 1;
                return None;
            }
        };
        let zero = PsumSignal::zero(cfg);
        let psum = match self.kind {
            PipelineKind::Regular3a | PipelineKind::Baseline3b => {
                s1.psum.as_ref().unwrap_or(&zero)
            }
            PipelineKind::Skewed => psum_late.unwrap_or(&zero),
        };
        let sig = self.kind.datapath().step(cfg, psum, s1.a, self.weight);
        self.activity.s2_evals += 1;
        Some(OutReg { m: s1.m, sig, taken: false })
    }

    /// Record a stage-1 acceptance (the multiplier fires this cycle).
    pub fn accept_stage1(&mut self, next: S1Reg) -> S1Reg {
        self.activity.s1_evals += 1;
        next
    }

    /// Record an idle stage-1 cycle.
    pub fn stage1_bubble(&mut self) {
        self.activity.s1_bubbles += 1;
    }

    /// Replace the weight (weight-tile reload) and clear in-flight state.
    pub fn reload(&mut self, weight: u64) {
        self.weight = weight;
        self.s1 = None;
        self.out = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::format::FpFormat;

    const CFG: ChainCfg = ChainCfg::BF16_FP32;

    fn bf(x: f64) -> u64 {
        FpFormat::BF16.from_f64(x)
    }

    #[test]
    fn baseline_stage2_uses_captured_psum() {
        let mut pe = CyclePe::new(PipelineKind::Baseline3b, bf(3.0));
        let mut seed = PsumSignal::zero(&CFG);
        // Pre-charge a psum of 10.0 via a forged capture.
        use crate::arith::fma::{BaselineFmaPath, ChainDatapath};
        seed = BaselineFmaPath.step(&CFG, &seed, bf(2.0), bf(5.0));
        pe.s1 = Some(S1Reg { m: 0, a: bf(4.0), psum: Some(seed) });
        let out = pe.eval_stage2(&CFG, None).unwrap();
        assert_eq!(out.sig.val.value_f64(CFG.window), 10.0 + 12.0);
        assert_eq!(pe.activity.s2_evals, 1);
    }

    #[test]
    fn skewed_stage2_uses_late_psum() {
        use crate::arith::fma::{ChainDatapath, SkewedFmaPath};
        let mut pe = CyclePe::new(PipelineKind::Skewed, bf(3.0));
        let mut psum = PsumSignal::zero(&CFG);
        psum = SkewedFmaPath.step(&CFG, &psum, bf(2.0), bf(5.0));
        pe.s1 = Some(S1Reg { m: 0, a: bf(4.0), psum: None });
        let out = pe.eval_stage2(&CFG, Some(&psum)).unwrap();
        assert_eq!(out.sig.val.value_f64(CFG.window), 22.0);
    }

    #[test]
    fn empty_stage1_is_a_bubble() {
        let mut pe = CyclePe::new(PipelineKind::Baseline3b, bf(1.0));
        assert!(pe.eval_stage2(&CFG, None).is_none());
        assert_eq!(pe.activity.s2_bubbles, 1);
    }

    #[test]
    fn utilization_mixes_evals_and_bubbles() {
        let a = PeActivity { s1_evals: 3, s2_evals: 3, s1_bubbles: 1, s2_bubbles: 1 };
        assert!((a.utilization() - 0.75).abs() < 1e-12);
        assert_eq!(PeActivity::default().utilization(), 0.0);
    }

    #[test]
    fn reload_clears_pipeline_state() {
        let mut pe = CyclePe::new(PipelineKind::Skewed, bf(1.0));
        pe.s1 = Some(S1Reg { m: 0, a: bf(1.0), psum: None });
        pe.reload(bf(2.0));
        assert!(pe.s1.is_none());
        assert_eq!(pe.weight, bf(2.0));
    }
}
