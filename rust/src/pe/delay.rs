//! Combinational delay model for the PE pipeline stages.
//!
//! The paper's motivation (§II) is a *delay-profile inversion*: in
//! full-precision FP the multiplier dominates and hides the exponent /
//! alignment logic; in reduced precision the mantissa is as narrow as
//! (or narrower than) the exponent, so the exponent-side logic stops
//! being free.  This module provides a technology-neutral gate-level
//! delay estimate (in FO4-equivalent units) per datapath block, and
//! composes them into per-stage critical paths from a
//! [`PipelineSpec`]'s stage tables: a stage's delay is the sum over its
//! serial segments of the max over each segment's parallel paths of the
//! path's serial block delays, plus register overhead.  The ablation
//! bench (E5) uses it to reproduce the paper's clock-feasibility
//! argument; the energy model uses the same block inventory for
//! area/power accounting.
//!
//! Delay formulas follow standard logic-synthesis rules of thumb:
//! a radix-4 Booth/Wallace multiplier of width `n` costs
//! `~4·log2(n) + 4` FO4, a carry-lookahead adder `~2·log2(n) + 4`, a
//! barrel shifter or LZC/LZA tree `~2·log2(n) + 2`, plus one FO4 of mux
//! per block hand-off.  Absolute numbers are *not* the claim — ratios
//! and crossovers are (DESIGN.md §2).

use super::spec::{clog2, Block, PipelineSpec};
use super::PipelineKind;
use crate::arith::fma::ChainCfg;

/// Per-block FO4 delay estimates for a given chain configuration.
#[derive(Clone, Copy, Debug)]
pub struct BlockDelays {
    /// Mantissa multiplier, (m+1)×(m+1).
    pub mult: f64,
    /// Exponent add + compare (max / difference) on `e`-bit exponents.
    pub exp_compute: f64,
    /// Alignment barrel shifter across the accumulator window.
    pub align: f64,
    /// Wide significand adder (window + carry).
    pub add: f64,
    /// LZA / LZC tree over the window.
    pub lza: f64,
    /// Normalization barrel shifter.
    pub norm: f64,
    /// The skewed design's Fix Sign & Exponent block: one short exponent
    /// adder + sign mux (paper §III-B).
    pub fix: f64,
    /// Register setup + clock-to-q overhead charged to every stage.
    pub reg_overhead: f64,
}

impl BlockDelays {
    /// Delay model for a chain configuration.
    pub fn for_cfg(cfg: &ChainCfg) -> BlockDelays {
        let m = cfg.in_fmt.man_bits + 1; // significand incl. hidden bit
        let e = cfg.in_fmt.exp_bits;
        let w = cfg.window;
        BlockDelays {
            mult: 4.0 * clog2(m) + 4.0,
            exp_compute: 2.0 * clog2(e) + 4.0,
            align: 2.0 * clog2(w) + 2.0,
            add: 2.0 * clog2(w) + 4.0,
            lza: 2.0 * clog2(w) + 2.0,
            norm: 2.0 * clog2(w) + 2.0,
            fix: 2.0 * clog2(e) + 2.0,
            reg_overhead: 3.0,
        }
    }

    /// FO4 delay of one datapath block.
    pub fn block(&self, b: Block) -> f64 {
        match b {
            Block::Mult => self.mult,
            Block::ExpCompute => self.exp_compute,
            Block::Align => self.align,
            Block::Add => self.add,
            Block::Lza => self.lza,
            Block::Norm => self.norm,
            Block::Fix => self.fix,
        }
    }
}

/// Critical-path summary for one pipeline organisation.
#[derive(Clone, Debug)]
pub struct StageDelays {
    /// Registry name of the organisation.
    pub name: &'static str,
    /// Per-stage critical paths (FO4), `stages[i]` = stage `i+1`.
    pub stages: Vec<f64>,
}

impl StageDelays {
    /// Compose per-stage critical paths for a registered kind.
    pub fn for_kind(kind: PipelineKind, cfg: &ChainCfg) -> StageDelays {
        Self::for_spec(kind.spec(), cfg)
    }

    /// Compose per-stage critical paths from any spec's stage tables:
    /// `delay(stage) = Σ_segments max_paths Σ_blocks delay(block)`
    /// `+ reg_overhead`.
    pub fn for_spec(spec: &PipelineSpec, cfg: &ChainCfg) -> StageDelays {
        let b = BlockDelays::for_cfg(cfg);
        let stages = spec
            .stages
            .iter()
            .map(|stage| {
                let logic: f64 = stage
                    .iter()
                    .map(|segment| {
                        segment
                            .iter()
                            .map(|path| path.iter().map(|u| b.block(u.block)).sum::<f64>())
                            .fold(0.0, f64::max)
                    })
                    .sum();
                logic + b.reg_overhead
            })
            .collect();
        StageDelays { name: spec.name, stages }
    }

    /// Stage `i` (1-indexed) critical path, `None` past the depth.
    pub fn stage(&self, i: usize) -> Option<f64> {
        (i >= 1).then(|| self.stages.get(i - 1).copied()).flatten()
    }

    /// Stage-1 critical path (every organisation has one).
    pub fn stage1(&self) -> f64 {
        self.stages[0]
    }

    /// Stage-2 critical path (every registered organisation has ≥ 2
    /// stages — enforced by [`PipelineSpec::validate`]).
    pub fn stage2(&self) -> f64 {
        self.stages[1]
    }

    /// The cycle-time bound (FO4) this organisation imposes.
    pub fn critical(&self) -> f64 {
        self.stages.iter().copied().fold(0.0, f64::max)
    }

    /// Whether the organisation closes timing at a clock period of
    /// `period_fo4` FO4 units.
    pub fn feasible_at(&self, period_fo4: f64) -> bool {
        self.critical() <= period_fo4
    }
}

/// The reference clock period used throughout the evaluation, in FO4
/// units.  Chosen as the paper's 1 GHz @ 45 nm operating point: with
/// FO4 ≈ 22 ps at 45 nm, 1 ns ≈ 45 FO4.
pub const CLOCK_PERIOD_FO4: f64 = 45.0;

/// FO4-to-picoseconds conversion at the modeled 45-nm node.
pub const FO4_PS: f64 = 22.0;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::format::FpFormat;

    #[test]
    fn reduced_precision_inverts_delay_profile() {
        // bf16: the exponent+align path exceeds the narrow multiplier —
        // the paper's core observation.
        let bf = ChainCfg::BF16_FP32;
        let b = BlockDelays::for_cfg(&bf);
        assert!(
            b.exp_compute + b.align > b.mult,
            "exp+align ({}) should exceed mult ({}) in bf16",
            b.exp_compute + b.align,
            b.mult
        );
        // fp32-in (full precision): multiplier dominates, hiding exp+align.
        let fp32 = ChainCfg { in_fmt: FpFormat::FP32, out_fmt: FpFormat::FP32, window: 52 };
        let f = BlockDelays::for_cfg(&fp32);
        assert!(f.mult > f.exp_compute, "full-precision mult must dominate");
    }

    #[test]
    fn fig3a_is_worse_than_fig3b_at_reduced_precision() {
        let cfg = ChainCfg::BF16_FP32;
        let a = StageDelays::for_kind(PipelineKind::Regular3a, &cfg);
        let b = StageDelays::for_kind(PipelineKind::Baseline3b, &cfg);
        // 3(a)'s stage-1 carries the alignment it can no longer hide.
        assert!(a.stage1() > b.stage1(), "3a s1 {} vs 3b s1 {}", a.stage1(), b.stage1());
    }

    #[test]
    fn spec_composition_reproduces_the_hand_formulas() {
        // The data-driven composition must equal the formulas the match
        // arms used to hard-code (the refactor's no-regression pin).
        let cfg = ChainCfg::BF16_FP32;
        let b = BlockDelays::for_cfg(&cfg);
        let d3a = StageDelays::for_kind(PipelineKind::Regular3a, &cfg);
        assert_eq!(d3a.stage1(), b.mult.max(b.exp_compute + b.align) + b.reg_overhead);
        assert_eq!(d3a.stage2(), b.add.max(b.lza) + b.norm + b.reg_overhead);
        let d3b = StageDelays::for_kind(PipelineKind::Baseline3b, &cfg);
        assert_eq!(d3b.stage1(), b.mult.max(b.exp_compute) + b.reg_overhead);
        assert_eq!(d3b.stage2(), b.align + b.add.max(b.lza) + b.norm + b.reg_overhead);
        let ds = StageDelays::for_kind(PipelineKind::Skewed, &cfg);
        assert_eq!(ds.stage1(), b.mult.max(b.exp_compute) + b.reg_overhead);
        assert_eq!(ds.stage2(), b.fix + b.align + b.add.max(b.lza) + b.reg_overhead);
    }

    #[test]
    fn all_reduced_kinds_close_timing_at_reference_clock() {
        // The paper assumes both contender designs are optimised to
        // 1 GHz (§IV); the deep3 registration closes timing with slack.
        let cfg = ChainCfg::BF16_FP32;
        for kind in [PipelineKind::Baseline3b, PipelineKind::Skewed, PipelineKind::Deep3] {
            let d = StageDelays::for_kind(kind, &cfg);
            assert!(
                d.feasible_at(CLOCK_PERIOD_FO4),
                "{} critical {} > {}",
                kind.name(),
                d.critical(),
                CLOCK_PERIOD_FO4
            );
        }
    }

    #[test]
    fn transparent_trades_clock_for_spacing() {
        // ArrayFlex-style transparency: the whole exponent path lands in
        // stage 2, which busts the 1 GHz reference clock — spacing 1 is
        // bought with cycle time, unlike the skewed organisation.
        let cfg = ChainCfg::BF16_FP32;
        let t = StageDelays::for_kind(PipelineKind::Transparent, &cfg);
        assert!(!t.feasible_at(CLOCK_PERIOD_FO4), "critical {}", t.critical());
        let s = StageDelays::for_kind(PipelineKind::Skewed, &cfg);
        assert!(s.feasible_at(CLOCK_PERIOD_FO4));
        assert!(t.stage2() > s.stage2());
    }

    #[test]
    fn deep3_shortens_the_critical_stage() {
        // Splitting normalization out buys clock headroom over the
        // baseline (the arXiv 2408.11997 motivation).
        let cfg = ChainCfg::BF16_FP32;
        let d3 = StageDelays::for_kind(PipelineKind::Deep3, &cfg);
        let b = StageDelays::for_kind(PipelineKind::Baseline3b, &cfg);
        assert_eq!(d3.stages.len(), 3);
        assert!(d3.critical() < b.critical(), "{} vs {}", d3.critical(), b.critical());
        assert!(d3.stage(3).is_some());
        assert_eq!(b.stage(3), None);
    }

    #[test]
    fn skewed_stage2_overhead_is_bounded() {
        // The fix logic adds delay, but the retimed normalization keeps
        // the skewed stage 2 within ~15% of the baseline's (the paper's
        // "minimal overhead" claim, enabled by Fig. 6).
        let cfg = ChainCfg::BF16_FP32;
        let b = StageDelays::for_kind(PipelineKind::Baseline3b, &cfg);
        let s = StageDelays::for_kind(PipelineKind::Skewed, &cfg);
        assert!(s.stage2() < b.stage2() * 1.15, "skewed s2 {} vs base s2 {}", s.stage2(), b.stage2());
    }

    #[test]
    fn delays_monotone_in_width() {
        let small = ChainCfg::new(FpFormat::FP8E4M3, FpFormat::FP16);
        let big = ChainCfg::BF16_FP32;
        let ds = BlockDelays::for_cfg(&small);
        let db = BlockDelays::for_cfg(&big);
        assert!(ds.mult <= db.mult);
        assert!(ds.add <= db.add);
    }
}
