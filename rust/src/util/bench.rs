//! Minimal wall-clock measurement harness for the `harness = false`
//! bench targets (criterion is not in the offline crate cache).
//!
//! Measures median-of-N with warmup, reports ns/iter and derived
//! throughput.  Deterministic iteration counts keep bench logs diffable.

use std::time::Instant;

/// One measured result.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// Median wall time per iteration (ns).
    pub ns_per_iter: f64,
    /// Iterations measured.
    pub iters: u32,
    /// Optional work units per iteration (for throughput lines).
    pub units_per_iter: f64,
    pub unit_name: &'static str,
}

impl Measurement {
    /// Units per second implied by the median time.
    pub fn throughput(&self) -> f64 {
        if self.ns_per_iter == 0.0 {
            0.0
        } else {
            self.units_per_iter * 1e9 / self.ns_per_iter
        }
    }

    /// One-line report, `bench:`-prefixed for grep.
    pub fn report(&self) -> String {
        let mut s = format!("bench: {:<44} {:>12.0} ns/iter", self.name, self.ns_per_iter);
        if self.units_per_iter > 0.0 {
            s.push_str(&format!(
                "  {:>12.3e} {}/s",
                self.throughput(),
                self.unit_name
            ));
        }
        s
    }
}

/// Measure `f` with `iters` timed iterations after `warmup` untimed
/// ones; returns the median of `samples` runs.
pub fn measure<F: FnMut()>(
    name: &str,
    warmup: u32,
    iters: u32,
    samples: u32,
    mut f: F,
) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<f64> = (0..samples.max(1))
        .map(|_| {
            let t0 = Instant::now();
            for _ in 0..iters.max(1) {
                f();
            }
            t0.elapsed().as_nanos() as f64 / iters.max(1) as f64
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Measurement {
        name: name.to_string(),
        ns_per_iter: times[times.len() / 2],
        iters,
        units_per_iter: 0.0,
        unit_name: "",
    }
}

/// Attach a throughput annotation to a measurement.
pub fn with_units(mut m: Measurement, units: f64, unit_name: &'static str) -> Measurement {
    m.units_per_iter = units;
    m.unit_name = unit_name;
    m
}

/// Append one run object to a JSON-array trajectory file (such as
/// `BENCH_hotpath.json`), creating the file as a fresh array on first
/// use.  `entry` must be a complete JSON object literal; the entry is
/// spliced before the closing bracket so the file stays a valid JSON
/// array without a parser round-trip.
pub fn append_json_run(path: &std::path::Path, entry: &str) -> std::io::Result<()> {
    let existing = std::fs::read_to_string(path).unwrap_or_default();
    let trimmed = existing.trim_end();
    let body = if trimmed.is_empty() {
        format!("[\n{entry}\n]\n")
    } else {
        let stripped = trimmed.strip_suffix(']').ok_or_else(|| {
            std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("{}: trajectory file is not a JSON array", path.display()),
            )
        })?;
        let stripped = stripped.trim_end();
        if stripped.ends_with('[') {
            format!("{stripped}\n{entry}\n]\n")
        } else {
            format!("{stripped},\n{entry}\n]\n")
        }
    };
    std::fs::write(path, body)
}

/// Result of validating one `BENCH_*.json` trajectory file
/// (the `skewsa bench-check` subcommand).
#[derive(Debug, Default)]
pub struct TrajectoryCheck {
    /// Records in the file (across all bench groups).
    pub entries: usize,
    /// Schema violations — the hard CI gate.
    pub errors: Vec<String>,
    /// Perf-regression notes (>20% tier drop) — advisory only.
    pub warnings: Vec<String>,
}

/// Validate one trajectory file written by [`append_json_run`]: the root
/// must be a JSON array of flat records — every record an object whose
/// `bench` is a string, whose `unix_time` is a number, and whose values
/// are finite numbers, strings, or booleans (nested containers and
/// nulls are schema errors; a NaN throughput would already fail the
/// parse).  Then, per `(bench, smoke)` group, the two most recent
/// records are compared tier by tier: a `hot:`-prefixed rate that
/// dropped more than 20% becomes an advisory warning — host noise makes
/// small swings routine, so the drop is flagged, never fatal.
pub fn check_trajectory(path: &std::path::Path) -> TrajectoryCheck {
    use crate::util::mini_json::Json;
    let mut c = TrajectoryCheck::default();
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            c.errors.push(format!("unreadable: {e}"));
            return c;
        }
    };
    let root = match Json::parse(&text) {
        Ok(j) => j,
        Err(e) => {
            c.errors.push(format!("invalid JSON: {e}"));
            return c;
        }
    };
    let Some(records) = root.as_arr() else {
        c.errors.push("root is not a JSON array".into());
        return c;
    };
    c.entries = records.len();
    let mut groups: std::collections::BTreeMap<(String, bool), Vec<usize>> = Default::default();
    for (i, rec) in records.iter().enumerate() {
        let Json::Obj(map) = rec else {
            c.errors.push(format!("record {i}: not an object"));
            continue;
        };
        let Some(bench) = rec.get("bench").and_then(Json::as_str) else {
            c.errors.push(format!("record {i}: missing string field 'bench'"));
            continue;
        };
        if rec.get("unix_time").and_then(Json::as_f64).is_none() {
            c.errors.push(format!("record {i} ({bench}): missing numeric field 'unix_time'"));
        }
        for (k, v) in map {
            let flat = matches!(v, Json::Num(x) if x.is_finite())
                || matches!(v, Json::Str(_) | Json::Bool(_));
            if !flat {
                c.errors.push(format!(
                    "record {i} ({bench}): field '{k}' must be a finite number, string, or bool"
                ));
            }
        }
        let smoke = rec.get("smoke").and_then(Json::as_bool).unwrap_or(false);
        groups.entry((bench.to_string(), smoke)).or_default().push(i);
    }
    for ((bench, smoke), idxs) in &groups {
        if idxs.len() < 2 {
            continue;
        }
        let (Json::Obj(prev), Json::Obj(last)) =
            (&records[idxs[idxs.len() - 2]], &records[idxs[idxs.len() - 1]])
        else {
            continue;
        };
        for (k, v) in last {
            if !k.starts_with("hot:") {
                continue;
            }
            let (Some(new), Some(old)) = (v.as_f64(), prev.get(k).and_then(Json::as_f64)) else {
                continue;
            };
            if old > 0.0 && new < 0.8 * old {
                c.warnings.push(format!(
                    "{}: {bench}{}: '{k}' dropped {:.0}% ({old:.3e} -> {new:.3e})",
                    path.display(),
                    if *smoke { " (smoke)" } else { "" },
                    (1.0 - new / old) * 100.0,
                ));
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut x = 0u64;
        let m = measure("spin", 1, 100, 3, || {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
            std::hint::black_box(x);
        });
        assert!(m.ns_per_iter > 0.0);
        assert!(m.report().contains("spin"));
    }

    #[test]
    fn append_json_run_builds_valid_array() {
        use crate::util::mini_json::Json;
        let path = std::env::temp_dir().join(format!("skewsa_bench_{}.json", std::process::id()));
        std::fs::remove_file(&path).ok();
        append_json_run(&path, "  {\"a\": 1}").unwrap();
        append_json_run(&path, "  {\"a\": 2.5e9}").unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let j = Json::parse(&text).expect("appended file must stay valid JSON");
        let arr = j.as_arr().expect("array root");
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("a").and_then(Json::as_f64), Some(1.0));
        assert_eq!(arr[1].get("a").and_then(Json::as_f64), Some(2.5e9));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bench_check_validates_and_flags_regressions() {
        let path =
            std::env::temp_dir().join(format!("skewsa_benchcheck_{}.json", std::process::id()));
        std::fs::remove_file(&path).ok();
        append_json_run(
            &path,
            "  {\"bench\": \"hotpath\", \"unix_time\": 1, \"smoke\": true, \"hot:tier\": 100.0}",
        )
        .unwrap();
        append_json_run(
            &path,
            "  {\"bench\": \"hotpath\", \"unix_time\": 2, \"smoke\": true, \"hot:tier\": 50.0}",
        )
        .unwrap();
        let c = check_trajectory(&path);
        assert!(c.errors.is_empty(), "{:?}", c.errors);
        assert_eq!(c.entries, 2);
        assert_eq!(c.warnings.len(), 1, "{:?}", c.warnings);
        assert!(c.warnings[0].contains("hot:tier"), "{}", c.warnings[0]);
        // A drop inside the 20% tolerance stays quiet (only the two most
        // recent records of the group are compared).
        append_json_run(
            &path,
            "  {\"bench\": \"hotpath\", \"unix_time\": 3, \"smoke\": true, \"hot:tier\": 45.0}",
        )
        .unwrap();
        let c = check_trajectory(&path);
        assert!(c.errors.is_empty(), "{:?}", c.errors);
        assert!(c.warnings.is_empty(), "{:?}", c.warnings);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bench_check_rejects_bad_schema() {
        let path = std::env::temp_dir()
            .join(format!("skewsa_benchcheck_bad_{}.json", std::process::id()));
        std::fs::write(
            &path,
            "[{\"unix_time\": 1}, {\"bench\": \"x\", \"unix_time\": 2, \"nested\": []}]",
        )
        .unwrap();
        let c = check_trajectory(&path);
        assert_eq!(c.errors.len(), 2, "{:?}", c.errors);
        // An empty array (a fresh trajectory seed) is schema-clean.
        std::fs::write(&path, "[]\n").unwrap();
        let c = check_trajectory(&path);
        assert!(c.errors.is_empty(), "{:?}", c.errors);
        assert_eq!(c.entries, 0);
        // A missing file is a schema error, not a panic.
        std::fs::remove_file(&path).ok();
        assert!(!check_trajectory(&path).errors.is_empty());
    }

    #[test]
    fn throughput_math() {
        let m = Measurement {
            name: "t".into(),
            ns_per_iter: 100.0,
            iters: 1,
            units_per_iter: 50.0,
            unit_name: "ops",
        };
        assert_eq!(m.throughput(), 50.0 * 1e7);
        assert!(m.report().contains("ops/s"));
    }
}
