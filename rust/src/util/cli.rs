//! Tiny CLI argument parser (clap is not in the offline crate cache).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with typed getters and a generated usage string.
//!
//! Unknown options are *hard errors*, including single-dash typos like
//! `-worker` (which used to fall through as positionals and be silently
//! ignored); the error suggests the nearest declared option when one is
//! within edit distance 2.  Negative numbers still parse as positionals.

use std::collections::BTreeMap;

/// Declared option (for usage text and validation).
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// A parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

/// CLI specification + parser.
#[derive(Clone, Debug, Default)]
pub struct Cli {
    pub name: &'static str,
    pub about: &'static str,
    specs: Vec<OptSpec>,
}

impl Cli {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Cli { name, about, specs: Vec::new() }
    }

    /// Declare a `--key <value>` option with an optional default.
    pub fn opt(mut self, name: &'static str, help: &'static str, default: Option<&'static str>) -> Self {
        self.specs.push(OptSpec { name, help, takes_value: true, default });
        self
    }

    /// Declare a boolean `--flag`.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec { name, help, takes_value: false, default: None });
        self
    }

    /// Usage text.
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for spec in &self.specs {
            let head = if spec.takes_value {
                format!("  --{} <value>", spec.name)
            } else {
                format!("  --{}", spec.name)
            };
            s.push_str(&format!("{head:<28}{}", spec.help));
            if let Some(d) = spec.default {
                s.push_str(&format!(" [default: {d}]"));
            }
            s.push('\n');
        }
        s
    }

    /// Parse an argv slice (without the program name).  Unknown options are
    /// an error; `--help` is reported via `Err(Help)`.
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut args = Args::default();
        for spec in &self.specs {
            if let Some(d) = spec.default {
                args.opts.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(CliError::Help(self.usage()));
            }
            if let Some(body) = a.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| CliError::Unknown(self.describe_unknown(key)))?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError::MissingValue(key.to_string()))?
                        }
                    };
                    args.opts.insert(key.to_string(), val);
                } else {
                    if inline_val.is_some() {
                        return Err(CliError::UnexpectedValue(key.to_string()));
                    }
                    args.flags.push(key.to_string());
                }
            } else if a.len() > 1 && a.starts_with('-') && a[1..].parse::<f64>().is_err() {
                // A single-dash token that is not a number is a typo'd
                // option (`-worker`), not a positional: reject it loudly
                // instead of silently ignoring it.  A key that exactly
                // matches a declared option gets the dash hint rather
                // than a self-contradictory "unknown --rows (did you
                // mean --rows?)".
                let key = a.trim_start_matches('-');
                if self.specs.iter().any(|s| s.name == key) {
                    return Err(CliError::SingleDash(key.to_string()));
                }
                return Err(CliError::Unknown(self.describe_unknown(key)));
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    /// Render an unknown option with a did-you-mean hint when a declared
    /// option is within edit distance 2.
    fn describe_unknown(&self, key: &str) -> String {
        match self.suggest(key) {
            Some(best) => format!("{key} (did you mean --{best}?)"),
            None => key.to_string(),
        }
    }

    /// Nearest declared option name within edit distance 2, if any.
    fn suggest(&self, key: &str) -> Option<&'static str> {
        self.specs
            .iter()
            .map(|s| (edit_distance(key, s.name), s.name))
            .filter(|&(d, _)| d <= 2)
            .min_by_key(|&(d, _)| d)
            .map(|(_, name)| name)
    }

    /// Parse `std::env::args()` and exit(2) on error / exit(0) on --help.
    pub fn parse_env(&self) -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        match self.parse(&argv) {
            Ok(a) => a,
            Err(CliError::Help(u)) => {
                println!("{u}");
                std::process::exit(0);
            }
            Err(e) => {
                eprintln!("error: {e}\n\n{}", self.usage());
                std::process::exit(2);
            }
        }
    }
}

impl Args {
    pub fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str) -> Option<usize> {
        self.get(key).and_then(|s| s.parse().ok())
    }

    pub fn get_u64(&self, key: &str) -> Option<u64> {
        self.get(key).and_then(|s| s.parse().ok())
    }

    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(|s| s.parse().ok())
    }

    /// Typed getter with a hard error message on parse failure.
    pub fn req_usize(&self, key: &str) -> usize {
        self.get_usize(key)
            .unwrap_or_else(|| panic!("missing or invalid --{key}"))
    }
}

/// Levenshtein edit distance (small inputs; O(|a|·|b|) rolling row).
/// Public because every name-like parser in the crate (CLI options
/// here, the `PipelineKind` registry, …) shares it for did-you-mean
/// suggestions.
pub fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    for (i, &ca) in a.iter().enumerate() {
        let mut cur = Vec::with_capacity(b.len() + 1);
        cur.push(i + 1);
        for (j, &cb) in b.iter().enumerate() {
            let cost = usize::from(ca != cb);
            cur.push((prev[j] + cost).min(prev[j + 1] + 1).min(cur[j] + 1));
        }
        prev = cur;
    }
    prev[b.len()]
}

/// CLI parse errors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CliError {
    Help(String),
    Unknown(String),
    /// A declared option written with one dash (`-rows`).
    SingleDash(String),
    MissingValue(String),
    UnexpectedValue(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Help(_) => write!(f, "help requested"),
            CliError::Unknown(k) => write!(f, "unknown option --{k}"),
            CliError::SingleDash(k) => write!(f, "option -{k} needs two dashes: --{k}"),
            CliError::MissingValue(k) => write!(f, "option --{k} needs a value"),
            CliError::UnexpectedValue(k) => write!(f, "flag --{k} takes no value"),
        }
    }
}
impl std::error::Error for CliError {}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("rows", "array rows", Some("128"))
            .opt("seed", "rng seed", None)
            .flag("verbose", "chatty")
    }

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cli().parse(&sv(&[])).unwrap();
        assert_eq!(a.get_usize("rows"), Some(128));
        assert_eq!(a.get("seed"), None);
        assert!(!a.has("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let a = cli().parse(&sv(&["--rows", "64", "--seed=7", "--verbose"])).unwrap();
        assert_eq!(a.get_usize("rows"), Some(64));
        assert_eq!(a.get_u64("seed"), Some(7));
        assert!(a.has("verbose"));
    }

    #[test]
    fn positional_args() {
        let a = cli().parse(&sv(&["fig7", "--rows=4", "extra"])).unwrap();
        assert_eq!(a.positional, vec!["fig7", "extra"]);
    }

    #[test]
    fn errors() {
        assert!(matches!(cli().parse(&sv(&["--nope"])), Err(CliError::Unknown(_))));
        assert!(matches!(cli().parse(&sv(&["--seed"])), Err(CliError::MissingValue(_))));
        assert!(matches!(cli().parse(&sv(&["--verbose=x"])), Err(CliError::UnexpectedValue(_))));
        assert!(matches!(cli().parse(&sv(&["--help"])), Err(CliError::Help(_))));
    }

    #[test]
    fn single_dash_typos_are_rejected() {
        // `-rows 4` used to pass silently as two positionals; the key
        // is declared, so the error teaches the dash count instead of
        // calling a known option unknown.
        let err = cli().parse(&sv(&["-rows", "4"])).unwrap_err();
        assert_eq!(err, CliError::SingleDash("rows".into()));
        assert!(err.to_string().contains("needs two dashes: --rows"), "{err}");
        assert!(matches!(cli().parse(&sv(&["-x"])), Err(CliError::Unknown(_))));
        // Negative numbers and a bare dash stay positional.
        let a = cli().parse(&sv(&["-3.5", "-42", "-"])).unwrap();
        assert_eq!(a.positional, vec!["-3.5", "-42", "-"]);
    }

    #[test]
    fn unknown_options_suggest_nearest_name() {
        let Err(CliError::Unknown(msg)) = cli().parse(&sv(&["--row"])) else {
            panic!("expected Unknown");
        };
        assert!(msg.contains("did you mean --rows?"), "{msg}");
        let Err(CliError::Unknown(msg)) = cli().parse(&sv(&["-seeed", "1"])) else {
            panic!("expected Unknown");
        };
        assert!(msg.contains("did you mean --seed?"), "{msg}");
        // Nothing close: no hint.
        let Err(CliError::Unknown(msg)) = cli().parse(&sv(&["--zzzzzz"])) else {
            panic!("expected Unknown");
        };
        assert!(!msg.contains("did you mean"), "{msg}");
    }

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("rows", "rows"), 0);
        assert_eq!(edit_distance("row", "rows"), 1);
        assert_eq!(edit_distance("worker", "workers"), 1);
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
    }

    #[test]
    fn usage_mentions_options() {
        let u = cli().usage();
        assert!(u.contains("--rows"));
        assert!(u.contains("default: 128"));
    }
}
