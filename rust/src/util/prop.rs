//! Minimal property-testing harness (proptest is not in the offline crate
//! cache).
//!
//! Usage mirrors the proptest style the DESIGN.md test strategy calls for
//! (`no_run`: doctest binaries don't carry the xla rpath in this image):
//!
//! ```no_run
//! use skewsa::util::prop::{Prop, Gen};
//! Prop::new("add-commutes", 1000).run(|g: &mut Gen| {
//!     let a = g.i64_in(-100, 100);
//!     let b = g.i64_in(-100, 100);
//!     g.assert_eq("a+b == b+a", a + b, b + a);
//! });
//! ```
//!
//! On failure the harness re-runs the case with the failing seed, shrinks
//! integer draws toward zero (a bounded "shrink-lite" pass), and panics
//! with the failing seed so the case is reproducible from the test log.

use super::rng::Rng;

/// Per-case generator handed to the property body.  Wraps the RNG and
/// records draws so the shrinker can replay them with smaller values.
pub struct Gen {
    rng: Rng,
    /// Scale in (0, 1]: shrink passes re-run with smaller scales, pulling
    /// integer ranges toward their midpoint/zero.
    scale: f64,
    failed: Option<String>,
}

impl Gen {
    fn new(seed: u64, scale: f64) -> Self {
        Gen { rng: Rng::new(seed), scale, failed: None }
    }

    /// Uniform i64 in `[lo, hi]`, range narrowed by the shrink scale.
    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        if self.scale >= 1.0 {
            return self.rng.range_i64(lo, hi);
        }
        // Shrink toward zero if the range spans it, else toward lo.
        let anchor = if lo <= 0 && hi >= 0 { 0 } else { lo };
        let lo2 = anchor + ((lo - anchor) as f64 * self.scale) as i64;
        let hi2 = anchor + ((hi - anchor) as f64 * self.scale) as i64;
        self.rng.range_i64(lo2.min(hi2), lo2.max(hi2))
    }

    /// Uniform usize in `[lo, hi]` (shrinks toward `lo`).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        let hi2 = if self.scale >= 1.0 {
            hi
        } else {
            lo + ((hi - lo) as f64 * self.scale) as usize
        };
        lo + self.rng.below((hi2 - lo + 1) as u64) as usize
    }

    /// Random bit pattern of `bits` width (not shrunk — bit patterns are
    /// structure, not magnitude).
    pub fn bits(&mut self, bits: u32) -> u64 {
        self.rng.bits(bits)
    }

    /// Uniform f64 in `[lo, hi)` (shrinks toward the midpoint).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let mid = 0.5 * (lo + hi);
        let half = 0.5 * (hi - lo) * self.scale;
        self.rng.uniform(mid - half, mid + half)
    }

    /// Gaussian draw (shrinks toward the mean).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        self.rng.normal_scaled(mean, std * self.scale)
    }

    /// Bernoulli.
    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// Pick one item from a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.below(items.len() as u64) as usize]
    }

    /// Record a failed assertion (does not unwind; the harness collects and
    /// reports with the seed).
    pub fn assert(&mut self, what: &str, ok: bool) {
        if !ok && self.failed.is_none() {
            self.failed = Some(what.to_string());
        }
    }

    /// Equality assertion with debug rendering of both sides.
    pub fn assert_eq<T: PartialEq + std::fmt::Debug>(&mut self, what: &str, a: T, b: T) {
        if a != b && self.failed.is_none() {
            self.failed = Some(format!("{what}: left={a:?} right={b:?}"));
        }
    }

    /// Approximate equality for floats (absolute + relative tolerance).
    pub fn assert_close(&mut self, what: &str, a: f64, b: f64, tol: f64) {
        let ok = if a.is_nan() || b.is_nan() {
            a.is_nan() && b.is_nan()
        } else {
            (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
        };
        if !ok && self.failed.is_none() {
            self.failed = Some(format!("{what}: left={a} right={b} tol={tol}"));
        }
    }
}

/// A named property with a case budget.
pub struct Prop {
    name: &'static str,
    cases: u64,
    seed: u64,
}

impl Prop {
    /// New property running `cases` random cases.  The base seed is derived
    /// from the name so distinct properties explore distinct streams but
    /// each run is deterministic.
    pub fn new(name: &'static str, cases: u64) -> Self {
        let mut seed = 0xcbf2_9ce4_8422_2325u64; // FNV offset
        for b in name.bytes() {
            seed ^= b as u64;
            seed = seed.wrapping_mul(0x100_0000_01b3);
        }
        Prop { name, cases, seed }
    }

    /// Override the base seed (used to reproduce logged failures).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Run the property; panics with the failing seed + message on failure.
    pub fn run<F: Fn(&mut Gen)>(self, body: F) {
        for case in 0..self.cases {
            let case_seed = self.seed.wrapping_add(case.wrapping_mul(0x9e37_79b9_7f4a_7c15));
            let mut g = Gen::new(case_seed, 1.0);
            body(&mut g);
            if let Some(msg) = g.failed {
                // Shrink-lite: replay the same seed at smaller scales and
                // keep the smallest still-failing rendition's message.
                let mut final_msg = msg;
                for scale in [0.5, 0.25, 0.1, 0.02] {
                    let mut gs = Gen::new(case_seed, scale);
                    body(&mut gs);
                    if let Some(m) = gs.failed {
                        final_msg = format!("{m} (shrunk, scale={scale})");
                    } else {
                        break;
                    }
                }
                panic!(
                    "property '{}' failed at case {case} (seed {case_seed:#x}): {final_msg}",
                    self.name
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        Prop::new("tautology", 200).run(|g| {
            let x = g.i64_in(-10, 10);
            g.assert("x is in range", (-10..=10).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        Prop::new("always-fails", 10).run(|g| {
            let x = g.i64_in(0, 100);
            g.assert("x < 0 (impossible)", x < 0);
        });
    }

    #[test]
    fn deterministic_reruns() {
        // Two runs of the same property observe identical draws.
        use std::sync::Mutex;
        static DRAWS: Mutex<Vec<i64>> = Mutex::new(Vec::new());
        let run = || {
            DRAWS.lock().unwrap().clear();
            Prop::new("record", 20).run(|g| {
                DRAWS.lock().unwrap().push(g.i64_in(-1000, 1000));
            });
            DRAWS.lock().unwrap().clone()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn assert_close_tolerances() {
        Prop::new("close", 1).run(|g| {
            g.assert_close("近い", 1.0, 1.0 + 1e-12, 1e-9);
        });
    }

    #[test]
    #[should_panic]
    fn assert_close_fails_when_far() {
        Prop::new("far", 1).run(|g| {
            g.assert_close("far apart", 1.0, 2.0, 1e-9);
        });
    }
}
