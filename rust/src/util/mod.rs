//! Std-only substrates: deterministic RNG, mini-JSON, CLI parsing, table
//! rendering and a property-testing harness.
//!
//! The offline build environment has no `rand`, `serde`, `clap`,
//! `criterion` or `proptest`; these modules replace exactly the slices of
//! those crates the rest of the repo needs (see DESIGN.md §2, environment
//! substitutions).

pub mod bench;
pub mod cli;
pub mod mini_json;
pub mod prop;
pub mod rng;
pub mod table;

pub use cli::{Args, Cli};
pub use mini_json::Json;
pub use prop::{Gen, Prop};
pub use rng::Rng;
pub use table::{fnum, pct, Align, Table};
