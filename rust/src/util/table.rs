//! Aligned plain-text table rendering for reports and benches.
//!
//! Every figure/table emitter in [`crate::report`] prints through this so
//! bench output lines up and stays grep-able (`row:` prefix per data row).

/// Column alignment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple text table: header + rows, column-aligned on render.
#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column headers; numeric-looking columns can
    /// be right-aligned via [`Table::align`].
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            aligns: vec![Align::Left; header.len()],
            rows: Vec::new(),
        }
    }

    /// Set per-column alignment (panics on length mismatch).
    pub fn align(mut self, aligns: &[Align]) -> Self {
        assert_eq!(aligns.len(), self.header.len());
        self.aligns = aligns.to_vec();
        self
    }

    /// Right-align every column except the first (the common report shape).
    pub fn numeric(mut self) -> Self {
        for (i, a) in self.aligns.iter_mut().enumerate() {
            *a = if i == 0 { Align::Left } else { Align::Right };
        }
        self
    }

    /// Append a row (panics on length mismatch).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: append a row of displayable items.
    pub fn rowd(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with aligned columns, a separator under the header, and a
    /// `row:`-prefixed body (machine-greppable).
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let fmt_row = |cells: &[String], prefix: &str| -> String {
            let mut line = String::from(prefix);
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                let pad = width[i] - c.chars().count();
                match self.aligns[i] {
                    Align::Left => {
                        line.push_str(c);
                        if i + 1 != ncol {
                            line.push_str(&" ".repeat(pad));
                        }
                    }
                    Align::Right => {
                        line.push_str(&" ".repeat(pad));
                        line.push_str(c);
                    }
                }
            }
            line
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header, "     "));
        out.push('\n');
        out.push_str("     ");
        out.push_str(&"-".repeat(width.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, "row: "));
            out.push('\n');
        }
        out
    }

    /// CSV rendering (no quoting needed for our numeric/identifier cells;
    /// commas in cells are replaced by `;`).
    pub fn to_csv(&self) -> String {
        let clean = |s: &str| s.replace(',', ";");
        let mut out = String::new();
        out.push_str(&self.header.iter().map(|h| clean(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| clean(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float with `prec` decimals, trimming to a compact form.
pub fn fnum(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

/// Format a ratio as a signed percentage, e.g. `-16.2%`.
pub fn pct(ratio: f64) -> String {
    format!("{:+.1}%", ratio * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["layer", "cycles"]).numeric();
        t.row(&["conv1".into(), "123".into()]);
        t.row(&["fc".into(), "7".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[2].starts_with("row: conv1"));
        // Right alignment of the numeric column:
        assert!(lines[3].ends_with("  7") || lines[3].ends_with("     7"), "{:?}", lines[3]);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["x,y".into(), "1".into()]);
        let csv = t.to_csv();
        assert_eq!(csv, "a,b\nx;y,1\n");
    }

    #[test]
    fn pct_and_fnum() {
        assert_eq!(pct(-0.162), "-16.2%");
        assert_eq!(pct(0.08), "+8.0%");
        assert_eq!(fnum(3.14159, 2), "3.14");
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
