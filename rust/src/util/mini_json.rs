//! Minimal JSON reader/writer (serde is not in the offline crate cache).
//!
//! Supports the full JSON data model minus `\u` surrogate pairs beyond the
//! BMP; numbers parse to `f64`.  Used by the config system, the report
//! emitters, and the artifact registry.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.  Object keys are kept sorted (BTreeMap) so serialisation
/// is deterministic — reports diff cleanly across runs.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object, builder-style (consumes and returns the
    /// value so `Json::obj().set(..).set(..)` chains); panics if `self`
    /// is not an object.
    pub fn set(mut self, key: &str, val: Json) -> Json {
        match &mut self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // Re-borrow as UTF-8: walk back one and take the char.
                    self.i -= 1;
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { offset: start, msg: format!("bad number '{text}'") })
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() {
        if x == x.trunc() && x.abs() < 1e15 {
            out.push_str(&format!("{}", x as i64));
        } else {
            out.push_str(&format!("{x}"));
        }
    } else {
        out.push_str("null"); // JSON has no Inf/NaN
    }
}

impl Json {
    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                if !items.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !map.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }

    /// Compact serialisation.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    /// Pretty (2-space) serialisation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Null));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\n\t\"\\A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\A"));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,"s"],"b":true,"n":null,"o":{"k":-3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.to_string_compact(), src);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn errors_carry_offsets() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert!(e.offset >= 6, "{e}");
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn unicode_content() {
        let v = Json::parse("\"π≈3\"").unwrap();
        assert_eq!(v.as_str(), Some("π≈3"));
        assert_eq!(Json::parse(&v.to_string_compact()).unwrap(), v);
    }

    #[test]
    fn builder_api() {
        let o = Json::obj().set("x", Json::Num(1.0)).set("y", Json::Str("z".into()));
        assert_eq!(o.to_string_compact(), r#"{"x":1,"y":"z"}"#);
    }
}
