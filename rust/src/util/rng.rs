//! Deterministic pseudo-random number generation (xoshiro256**).
//!
//! The offline crate cache has no `rand`, so the whole repo uses this
//! small, seedable, splittable generator.  Determinism matters more than
//! statistical perfection here: every experiment in EXPERIMENTS.md quotes
//! a seed, and re-running a bench must reproduce the same numbers.

/// xoshiro256** by Blackman & Vigna — 256-bit state, 64-bit output,
/// passes BigCrush; tiny and allocation-free.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that any u64 (including 0) gives a good state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`; `n > 0`.  Lemire-style rejection-free enough
    /// for simulation purposes (multiply-shift).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn unit_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.unit_f64()
    }

    /// Standard normal (Box–Muller, the allocation-free polar-less form).
    pub fn normal(&mut self) -> f64 {
        // Draw u1 in (0,1] to keep ln finite.
        let u1 = (self.next_u64() >> 11).wrapping_add(1) as f64 / (1u64 << 53) as f64;
        let u2 = self.unit_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Gaussian with the given mean and standard deviation.
    pub fn normal_scaled(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// A fresh generator split off this one (for per-worker streams).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Random bit pattern valid for width `bits` (≤ 64).
    pub fn bits(&mut self, bits: u32) -> u64 {
        debug_assert!(bits >= 1 && bits <= 64);
        if bits == 64 { self.next_u64() } else { self.next_u64() & ((1u64 << bits) - 1) }
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.unit_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
        // All residues reachable.
        let mut seen = [false; 13];
        for _ in 0..10_000 {
            seen[r.below(13) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn unit_f64_in_range_and_centered() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        let n = 100_000;
        for _ in 0..n {
            let x = r.unit_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Rng::new(5);
        let mut a = root.split();
        let mut b = root.split();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn range_i64_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let v = r.range_i64(-5, 5);
            assert!((-5..=5).contains(&v));
        }
    }
}
