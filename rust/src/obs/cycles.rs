//! Cycle-domain attribution: where a batch's array cycles went.
//!
//! The serve layer quotes one service-time number per batch
//! ([`crate::serve::CachedPlan::stream_cycles`]); this struct carries
//! its decomposition — the same taxonomy [`crate::timing::LayerTiming`]
//! computes — through a trace span, plus the ABFT recovery recompute
//! cycles the clean model does not know about:
//!
//! ```text
//! stream_total = exposed_preload + compute + drain      (clean service)
//! total        = stream_total + recovery                (with re-runs)
//! ```
//!
//! `compute` here is the *drain-free* streaming span
//! (`LayerTiming::compute_cycles − drain_cycles`), so the three clean
//! legs are disjoint and sum exactly to the layer total — the equality
//! the acceptance tests pin against `layer_timing` and the streaming
//! cycle simulator for every batch.

use crate::timing::LayerTiming;
use crate::util::mini_json::Json;

/// Disjoint cycle legs of one executed batch (see module docs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CycleAttribution {
    /// Non-overlapped weight-preload stall cycles.
    pub exposed_preload: u64,
    /// Streaming cycles with live West-edge injections (drain excluded).
    pub compute: u64,
    /// Pipeline drain cycles (wavefront past the last injection).
    pub drain: u64,
    /// ABFT recovery recompute cycles (suspect-block re-runs).
    pub recovery: u64,
}

impl CycleAttribution {
    /// The clean service-time identity: equals
    /// [`LayerTiming::cycles`] / the streaming simulator's total.
    pub fn stream_total(&self) -> u64 {
        self.exposed_preload + self.compute + self.drain
    }

    /// All cycles attributed to the batch, recovery included.
    pub fn total(&self) -> u64 {
        self.stream_total() + self.recovery
    }

    /// Decompose a clean layer timing (recovery starts at zero).
    pub fn from_layer_timing(lt: &LayerTiming) -> CycleAttribution {
        CycleAttribution {
            exposed_preload: lt.exposed_preload,
            compute: lt.compute_cycles - lt.drain_cycles,
            drain: lt.drain_cycles,
            recovery: 0,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("exposed_preload", Json::Num(self.exposed_preload as f64))
            .set("compute", Json::Num(self.compute as f64))
            .set("drain", Json::Num(self.drain as f64))
            .set("recovery", Json::Num(self.recovery as f64))
    }

    pub fn from_json(j: &Json) -> Result<CycleAttribution, String> {
        let num = |key: &str| -> Result<u64, String> {
            j.get(key)
                .and_then(Json::as_usize)
                .map(|v| v as u64)
                .ok_or_else(|| format!("cycles: bad `{key}`"))
        };
        Ok(CycleAttribution {
            exposed_preload: num("exposed_preload")?,
            compute: num("compute")?,
            drain: num("drain")?,
            recovery: num("recovery")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pe::PipelineKind;
    use crate::sa::tile::{GemmShape, TilePlan};
    use crate::timing::{layer_timing, TimingConfig};

    #[test]
    fn decomposition_matches_layer_timing_identity() {
        let cfg = TimingConfig { rows: 8, cols: 8, clock_ghz: 1.0, double_buffer: true };
        let plan = TilePlan::new(GemmShape::new(32, 16, 16), 8, 8);
        for kind in PipelineKind::ALL {
            let lt = layer_timing(&cfg, kind, &plan);
            let attr = CycleAttribution::from_layer_timing(&lt);
            assert_eq!(attr.stream_total(), lt.cycles, "{kind}");
            assert_eq!(attr.exposed_preload, lt.exposed_preload, "{kind}");
            assert_eq!(attr.compute + attr.drain, lt.compute_cycles, "{kind}");
            assert_eq!(attr.total(), lt.cycles, "{kind}: clean run has no recovery");
        }
    }

    #[test]
    fn json_roundtrip() {
        let a = CycleAttribution { exposed_preload: 8, compute: 90, drain: 30, recovery: 44 };
        let j = Json::parse(&a.to_json().to_string_compact()).unwrap();
        assert_eq!(CycleAttribution::from_json(&j).unwrap(), a);
        assert_eq!(a.total(), 8 + 90 + 30 + 44);
    }
}
