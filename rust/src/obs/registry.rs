//! Lock-light named-metric registry: counters, gauges, histograms.
//!
//! One [`MetricsRegistry`] per server absorbs the counters that used to
//! live scattered across the serve stack (`ServerStats` submit/shed
//! tallies, `ShardSnapshot` fault counts, plan-cache hit/miss, ABFT
//! detected/recovered/unresolved) behind a single [`snapshot`] that the
//! report layer renders and `skewsa serve --metrics-out` dumps as JSON.
//!
//! The locking discipline is the point: the registry's mutex is taken
//! only to *register* a name (cold, once per metric) and to snapshot;
//! the returned [`Counter`]/[`Gauge`]/[`Hist`] handles are `Arc`s over
//! atomics, so the hot path — a shard thread bumping a counter per
//! batch — is a relaxed atomic add with no shared lock.
//!
//! [`snapshot`]: MetricsRegistry::snapshot

use super::hist::{HistSnapshot, Log2Histogram};
use crate::util::mini_json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Monotone counter handle (cheap to clone; lock-free to bump).
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.add(1);
    }

    /// Absorb an externally maintained monotone tally: raises the
    /// counter to `v` if below (never lowers it), so mirroring a source
    /// counter at snapshot time keeps registry snapshots monotone even
    /// if the mirror races a concurrent reader.
    pub fn absorb(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge handle (current size, state code, …).
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Histogram handle backed by a bounded [`Log2Histogram`].
#[derive(Clone)]
pub struct Hist(Arc<Log2Histogram>);

impl Hist {
    pub fn record(&self, v: u64) {
        self.0.record(v);
    }

    pub fn count(&self) -> u64 {
        self.0.count()
    }
}

#[derive(Default)]
struct Registered {
    counters: BTreeMap<String, Arc<AtomicU64>>,
    gauges: BTreeMap<String, Arc<AtomicU64>>,
    hists: BTreeMap<String, Arc<Log2Histogram>>,
}

/// Named-metric registry; see the module docs for the locking story.
#[derive(Default)]
pub struct MetricsRegistry {
    inner: Mutex<Registered>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Get-or-register the counter named `name`.
    pub fn counter(&self, name: &str) -> Counter {
        let mut g = self.inner.lock().unwrap();
        Counter(Arc::clone(g.counters.entry(name.to_string()).or_default()))
    }

    /// Get-or-register the gauge named `name`.
    pub fn gauge(&self, name: &str) -> Gauge {
        let mut g = self.inner.lock().unwrap();
        Gauge(Arc::clone(g.gauges.entry(name.to_string()).or_default()))
    }

    /// Get-or-register the histogram named `name`.
    pub fn histogram(&self, name: &str) -> Hist {
        let mut g = self.inner.lock().unwrap();
        Hist(Arc::clone(g.hists.entry(name.to_string()).or_default()))
    }

    /// Point-in-time copy of every registered metric.  Counter values
    /// are monotone across successive snapshots (pinned by
    /// `tests/prop_obs.rs`).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        MetricsSnapshot {
            counters: g
                .counters
                .iter()
                .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
                .collect(),
            gauges: g
                .gauges
                .iter()
                .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
                .collect(),
            hists: g.hists.iter().map(|(k, v)| (k.clone(), v.snapshot())).collect(),
        }
    }
}

/// Immutable view of a [`MetricsRegistry`] (name-sorted maps).
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, u64>,
    pub hists: BTreeMap<String, HistSnapshot>,
}

impl MetricsSnapshot {
    /// Counter value, 0 when never registered.
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Gauge value, 0 when never registered.
    pub fn gauge(&self, name: &str) -> u64 {
        self.gauges.get(name).copied().unwrap_or(0)
    }

    /// Sum of every counter whose name starts with `prefix`.
    pub fn counter_sum(&self, prefix: &str) -> u64 {
        self.counters
            .iter()
            .filter(|(k, _)| k.starts_with(prefix))
            .map(|(_, v)| v)
            .sum()
    }

    /// The metrics dump `--metrics-out` writes: counters and gauges
    /// verbatim, histograms as their exact aggregates plus standard
    /// quantiles (bucket arrays stay internal).
    pub fn to_json(&self) -> Json {
        let mut counters = Json::obj();
        for (k, v) in &self.counters {
            counters = counters.set(k, Json::Num(*v as f64));
        }
        let mut gauges = Json::obj();
        for (k, v) in &self.gauges {
            gauges = gauges.set(k, Json::Num(*v as f64));
        }
        let mut hists = Json::obj();
        for (k, h) in &self.hists {
            let mut o = Json::obj()
                .set("count", Json::Num(h.count as f64))
                .set("mean", Json::Num(h.mean()));
            if h.count > 0 {
                o = o
                    .set("min", Json::Num(h.min as f64))
                    .set("max", Json::Num(h.max as f64))
                    .set("p50", Json::Num(h.quantile(50.0) as f64))
                    .set("p95", Json::Num(h.quantile(95.0) as f64))
                    .set("p99", Json::Num(h.quantile(99.0) as f64));
            }
            hists = hists.set(k, o);
        }
        Json::obj()
            .set("counters", counters)
            .set("gauges", gauges)
            .set("histograms", hists)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_handles_share_state_by_name() {
        let r = MetricsRegistry::new();
        let a = r.counter("serve.submitted");
        let b = r.counter("serve.submitted");
        a.add(3);
        b.inc();
        assert_eq!(r.counter("serve.submitted").get(), 4);
        assert_eq!(r.snapshot().counter("serve.submitted"), 4);
    }

    #[test]
    fn absorb_never_lowers() {
        let r = MetricsRegistry::new();
        let c = r.counter("x");
        c.absorb(10);
        c.absorb(7);
        assert_eq!(c.get(), 10);
        c.absorb(12);
        assert_eq!(c.get(), 12);
    }

    #[test]
    fn gauge_is_last_write_wins() {
        let r = MetricsRegistry::new();
        let g = r.gauge("cache.entries");
        g.set(5);
        g.set(2);
        assert_eq!(r.snapshot().gauge("cache.entries"), 2);
    }

    #[test]
    fn counter_sum_over_prefix() {
        let r = MetricsRegistry::new();
        r.counter("shard.0.rows").add(4);
        r.counter("shard.1.rows").add(6);
        r.counter("shard.1.retries").add(1);
        let s = r.snapshot();
        assert_eq!(s.counter_sum("shard.0.rows") + s.counter_sum("shard.1.rows"), 10);
        assert_eq!(s.counter_sum("shard."), 11);
    }

    #[test]
    fn snapshot_json_roundtrips() {
        let r = MetricsRegistry::new();
        r.counter("a.b").add(2);
        r.gauge("g").set(9);
        r.histogram("h").record(100);
        let j = r.snapshot().to_json();
        let parsed = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(parsed.get("counters").and_then(|c| c.get("a.b")).and_then(Json::as_usize), Some(2));
        assert_eq!(parsed.get("gauges").and_then(|c| c.get("g")).and_then(Json::as_usize), Some(9));
        let h = parsed.get("histograms").and_then(|c| c.get("h")).unwrap();
        assert_eq!(h.get("count").and_then(Json::as_usize), Some(1));
        assert_eq!(h.get("max").and_then(Json::as_usize), Some(100));
    }

    #[test]
    fn histogram_handle_records() {
        let r = MetricsRegistry::new();
        let h = r.histogram("lat");
        for v in [10u64, 20, 30] {
            h.record(v);
        }
        assert_eq!(h.count(), 3);
        let snap = r.snapshot();
        assert_eq!(snap.hists["lat"].quantile(100.0), 30);
    }
}
