//! Per-request trace spans: wall-clock phases + array-cycle attribution.
//!
//! A [`TraceSpan`] is opened when a request is submitted (just before
//! [`crate::serve::RequestQueue::push`]) and travels *inside* the
//! request through every stage of the serve stack.  Each stage marks a
//! phase boundary, so the span partitions the request's whole
//! submit→response lifetime into six contiguous wall-clock phases:
//!
//! | phase      | ends when                                            |
//! |------------|------------------------------------------------------|
//! | `queue`    | the batcher takes the request out of the queue       |
//! | `batch`    | the batch window closes (`Batcher::next_batch`)      |
//! | `plan`     | the plan-cache lookup returns                        |
//! | `dispatch` | the owning shard dequeues the batch from its mailbox |
//! | `execute`  | `WorkerPool::run_gemm` (incl. ABFT recovery) returns |
//! | `reply`    | the response is sent (span closes)                   |
//!
//! Phase durations are measured as deltas of one monotonic clock, so
//! they sum *exactly* to the span's total lifetime — the invariant the
//! span-lifecycle tests pin.  Alongside wall time, the execute phase
//! records the **cycle-domain** attribution the timing model computes
//! for the producing batch (exposed preload, streaming compute, drain,
//! ABFT recovery recompute), so one span answers both "where did the
//! microseconds go" and "where did the array cycles go".
//!
//! Every opened span closes exactly once: explicitly via
//! [`TraceSpan::finish`] on the ok/shed/closed paths, or — if a shard
//! drops the batch on a failed execution — implicitly on `Drop`, which
//! emits the span with [`SpanStatus::Failed`].  A span opened with
//! [`TraceSpan::disabled`] (tracing off) is a no-op everywhere.

use super::cycles::CycleAttribution;
use crate::util::mini_json::Json;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// The serve-path phases, in pipeline order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Queue = 0,
    Batch = 1,
    Plan = 2,
    Dispatch = 3,
    Execute = 4,
    Reply = 5,
}

impl Phase {
    pub const ALL: [Phase; 6] =
        [Phase::Queue, Phase::Batch, Phase::Plan, Phase::Dispatch, Phase::Execute, Phase::Reply];

    pub fn name(self) -> &'static str {
        match self {
            Phase::Queue => "queue",
            Phase::Batch => "batch",
            Phase::Plan => "plan",
            Phase::Dispatch => "dispatch",
            Phase::Execute => "execute",
            Phase::Reply => "reply",
        }
    }
}

/// How the span's request left the server.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanStatus {
    /// Served normally.
    Ok,
    /// Shed at the overload watermark.
    Shed,
    /// Turned away by a closing queue.
    Closed,
    /// The producing batch failed (reply channel dropped); the span was
    /// closed by `Drop`.
    Failed,
}

impl SpanStatus {
    pub fn name(self) -> &'static str {
        match self {
            SpanStatus::Ok => "ok",
            SpanStatus::Shed => "shed",
            SpanStatus::Closed => "closed",
            SpanStatus::Failed => "failed",
        }
    }

    pub fn parse(s: &str) -> Option<SpanStatus> {
        match s {
            "ok" => Some(SpanStatus::Ok),
            "shed" => Some(SpanStatus::Shed),
            "closed" => Some(SpanStatus::Closed),
            "failed" => Some(SpanStatus::Failed),
            _ => None,
        }
    }
}

/// A closed span, ready for JSON-lines emission / summary.
#[derive(Clone, Debug, PartialEq)]
pub struct SpanRecord {
    pub id: u64,
    pub model: usize,
    /// Pipeline organisation name (registry key).
    pub kind: String,
    /// Deadline class (`"interactive"` / `"batch"`).
    pub class: String,
    pub rows: usize,
    pub status: SpanStatus,
    /// Producing shard (`None` for requests that never reached one).
    pub shard: Option<usize>,
    pub batch_size: usize,
    pub cache_hit: bool,
    pub retries: usize,
    /// Wall-clock nanoseconds per phase, indexed by [`Phase`].
    pub phases_ns: [u64; 6],
    /// Cycle-domain attribution of the producing batch (zero for
    /// requests that never executed).
    pub cycles: CycleAttribution,
    pub sdc_detected: usize,
    pub sdc_recovered: usize,
    pub sdc_unresolved: usize,
}

impl SpanRecord {
    /// Total submit→close wall time: by construction, exactly the sum
    /// of the phase durations.
    pub fn total_ns(&self) -> u64 {
        self.phases_ns.iter().sum()
    }

    /// One JSON-lines object (compact, deterministic key order).
    pub fn to_json(&self) -> Json {
        let mut phases = Json::obj();
        for p in Phase::ALL {
            phases = phases.set(p.name(), Json::Num(self.phases_ns[p as usize] as f64));
        }
        Json::obj()
            .set("type", Json::Str("span".into()))
            .set("id", Json::Num(self.id as f64))
            .set("model", Json::Num(self.model as f64))
            .set("kind", Json::Str(self.kind.clone()))
            .set("class", Json::Str(self.class.clone()))
            .set("rows", Json::Num(self.rows as f64))
            .set("status", Json::Str(self.status.name().into()))
            .set(
                "shard",
                match self.shard {
                    Some(s) => Json::Num(s as f64),
                    None => Json::Null,
                },
            )
            .set("batch_size", Json::Num(self.batch_size as f64))
            .set("cache_hit", Json::Bool(self.cache_hit))
            .set("retries", Json::Num(self.retries as f64))
            .set("total_ns", Json::Num(self.total_ns() as f64))
            .set("phases_ns", phases)
            .set("cycles", self.cycles.to_json())
            .set("sdc_detected", Json::Num(self.sdc_detected as f64))
            .set("sdc_recovered", Json::Num(self.sdc_recovered as f64))
            .set("sdc_unresolved", Json::Num(self.sdc_unresolved as f64))
    }

    /// Parse one JSON-lines object back (the `skewsa trace` reader).
    pub fn from_json(j: &Json) -> Result<SpanRecord, String> {
        let num = |key: &str| -> Result<usize, String> {
            j.get(key).and_then(Json::as_usize).ok_or_else(|| format!("span: bad `{key}`"))
        };
        let phases = j.get("phases_ns").ok_or("span: missing phases_ns")?;
        let mut phases_ns = [0u64; 6];
        for p in Phase::ALL {
            phases_ns[p as usize] = phases
                .get(p.name())
                .and_then(Json::as_usize)
                .ok_or_else(|| format!("span: bad phase `{}`", p.name()))?
                as u64;
        }
        let status_str =
            j.get("status").and_then(Json::as_str).ok_or("span: missing status")?;
        Ok(SpanRecord {
            id: num("id")? as u64,
            model: num("model")?,
            kind: j.get("kind").and_then(Json::as_str).unwrap_or("?").to_string(),
            class: j.get("class").and_then(Json::as_str).unwrap_or("?").to_string(),
            rows: num("rows")?,
            status: SpanStatus::parse(status_str)
                .ok_or_else(|| format!("span: unknown status `{status_str}`"))?,
            shard: j.get("shard").and_then(Json::as_usize),
            batch_size: num("batch_size")?,
            cache_hit: j.get("cache_hit").and_then(Json::as_bool).unwrap_or(false),
            retries: num("retries")?,
            phases_ns,
            cycles: CycleAttribution::from_json(
                j.get("cycles").ok_or("span: missing cycles")?,
            )?,
            sdc_detected: num("sdc_detected")?,
            sdc_recovered: num("sdc_recovered")?,
            sdc_unresolved: num("sdc_unresolved")?,
        })
    }
}

/// A timestamped out-of-band trace event (shard health transitions).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    /// Nanoseconds since the sink was created.
    pub t_ns: u64,
    /// Event family (`"health"`).
    pub kind: String,
    /// What happened (`"quarantined"`, `"probation"`, `"healthy"`).
    pub label: String,
    pub shard: usize,
    /// The emitting subsystem's logical clock (health-board batch tick).
    pub clock: u64,
}

impl TraceEvent {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("type", Json::Str("event".into()))
            .set("t_ns", Json::Num(self.t_ns as f64))
            .set("kind", Json::Str(self.kind.clone()))
            .set("label", Json::Str(self.label.clone()))
            .set("shard", Json::Num(self.shard as f64))
            .set("clock", Json::Num(self.clock as f64))
    }

    pub fn from_json(j: &Json) -> Result<TraceEvent, String> {
        Ok(TraceEvent {
            t_ns: j.get("t_ns").and_then(Json::as_usize).ok_or("event: bad t_ns")? as u64,
            kind: j.get("kind").and_then(Json::as_str).unwrap_or("?").to_string(),
            label: j.get("label").and_then(Json::as_str).unwrap_or("?").to_string(),
            shard: j.get("shard").and_then(Json::as_usize).ok_or("event: bad shard")?,
            clock: j.get("clock").and_then(Json::as_usize).unwrap_or(0) as u64,
        })
    }
}

/// Collector for closed spans and trace events.
pub struct SpanSink {
    started: Instant,
    spans: Mutex<Vec<SpanRecord>>,
    events: Mutex<Vec<TraceEvent>>,
}

impl Default for SpanSink {
    fn default() -> Self {
        Self::new()
    }
}

impl SpanSink {
    pub fn new() -> SpanSink {
        SpanSink {
            started: Instant::now(),
            spans: Mutex::new(Vec::new()),
            events: Mutex::new(Vec::new()),
        }
    }

    fn record(&self, r: SpanRecord) {
        self.spans.lock().unwrap().push(r);
    }

    /// Record an out-of-band event stamped with the sink clock.
    pub fn event(&self, kind: &str, label: &str, shard: usize, clock: u64) {
        let t_ns = self.started.elapsed().as_nanos() as u64;
        self.events.lock().unwrap().push(TraceEvent {
            t_ns,
            kind: kind.to_string(),
            label: label.to_string(),
            shard,
            clock,
        });
    }

    /// Copy of all spans closed so far.
    pub fn spans(&self) -> Vec<SpanRecord> {
        self.spans.lock().unwrap().clone()
    }

    /// Copy of all events recorded so far.
    pub fn events(&self) -> Vec<TraceEvent> {
        self.events.lock().unwrap().clone()
    }

    /// The `--trace-out` payload: one compact JSON object per line,
    /// events first (they are rare), then spans in close order.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for e in self.events.lock().unwrap().iter() {
            out.push_str(&e.to_json().to_string_compact());
            out.push('\n');
        }
        for s in self.spans.lock().unwrap().iter() {
            out.push_str(&s.to_json().to_string_compact());
            out.push('\n');
        }
        out
    }
}

/// Parse a `--trace-out` JSON-lines payload back into spans + events.
pub fn parse_jsonl(text: &str) -> Result<(Vec<SpanRecord>, Vec<TraceEvent>), String> {
    let mut spans = Vec::new();
    let mut events = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let j = Json::parse(line).map_err(|e| format!("line {}: {e:?}", lineno + 1))?;
        match j.get("type").and_then(Json::as_str) {
            Some("span") => spans.push(
                SpanRecord::from_json(&j).map_err(|e| format!("line {}: {e}", lineno + 1))?,
            ),
            Some("event") => events.push(
                TraceEvent::from_json(&j).map_err(|e| format!("line {}: {e}", lineno + 1))?,
            ),
            other => return Err(format!("line {}: unknown record type {other:?}", lineno + 1)),
        }
    }
    Ok((spans, events))
}

struct SpanInner {
    sink: Arc<SpanSink>,
    opened: Instant,
    /// Start of the currently running phase.
    mark: Instant,
    /// Index of the currently running phase.
    cursor: usize,
    rec: SpanRecord,
}

/// Live span travelling inside a request (see the module docs).
///
/// Not `Clone`: exactly one holder closes it, exactly once.
pub struct TraceSpan {
    inner: Option<Box<SpanInner>>,
}

impl TraceSpan {
    /// A span that records nothing (tracing off) — every call no-ops.
    pub fn disabled() -> TraceSpan {
        TraceSpan { inner: None }
    }

    /// Open a live span; the `queue` phase starts now.
    pub fn open(
        sink: &Arc<SpanSink>,
        id: u64,
        model: usize,
        kind: &str,
        class: &str,
        rows: usize,
    ) -> TraceSpan {
        let now = Instant::now();
        TraceSpan {
            inner: Some(Box::new(SpanInner {
                sink: Arc::clone(sink),
                opened: now,
                mark: now,
                cursor: 0,
                rec: SpanRecord {
                    id,
                    model,
                    kind: kind.to_string(),
                    class: class.to_string(),
                    rows,
                    status: SpanStatus::Failed,
                    shard: None,
                    batch_size: 0,
                    cache_hit: false,
                    retries: 0,
                    phases_ns: [0; 6],
                    cycles: CycleAttribution::default(),
                    sdc_detected: 0,
                    sdc_recovered: 0,
                    sdc_unresolved: 0,
                },
            })),
        }
    }

    /// Is this span live (tracing enabled)?
    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Close the current phase `phase` and start the next one.  Phases
    /// skipped between the cursor and `phase` get zero duration, so the
    /// partition invariant holds whatever path the request takes.
    pub fn mark(&mut self, phase: Phase) {
        if let Some(s) = self.inner.as_deref_mut() {
            let now = Instant::now();
            let idx = phase as usize;
            if idx >= s.cursor {
                s.rec.phases_ns[idx] += (now - s.mark).as_nanos() as u64;
                s.cursor = idx + 1;
            }
            s.mark = now;
        }
    }

    /// Attach the producing shard/batch identity (dispatch time).
    pub fn set_batch(&mut self, shard: usize, batch_size: usize, cache_hit: bool) {
        if let Some(s) = self.inner.as_deref_mut() {
            s.rec.shard = Some(shard);
            s.rec.batch_size = batch_size;
            s.rec.cache_hit = cache_hit;
        }
    }

    /// Attach the execute-phase outcome: cycle attribution + fault
    /// tallies of the producing batch.
    pub fn set_exec(
        &mut self,
        cycles: CycleAttribution,
        retries: usize,
        sdc: (usize, usize, usize),
    ) {
        if let Some(s) = self.inner.as_deref_mut() {
            s.rec.cycles = cycles;
            s.rec.retries = retries;
            (s.rec.sdc_detected, s.rec.sdc_recovered, s.rec.sdc_unresolved) = sdc;
        }
    }

    /// Close the span: the still-open phase ends now, the record is
    /// emitted to the sink.  Idempotent only in the sense that the
    /// subsequent `Drop` does nothing.
    pub fn finish(&mut self, status: SpanStatus) {
        if let Some(mut s) = self.inner.take() {
            let now = Instant::now();
            let idx = s.cursor.min(Phase::Reply as usize);
            s.rec.phases_ns[idx] += (now - s.mark).as_nanos() as u64;
            s.rec.status = status;
            debug_assert_eq!(
                s.rec.total_ns(),
                (now - s.opened).as_nanos() as u64,
                "span phases must partition the lifetime"
            );
            s.sink.record(s.rec);
        }
    }
}

impl Drop for TraceSpan {
    /// A span dropped without `finish` closes as `Failed` — the shard
    /// dropped the batch (execution error), taking the reply senders
    /// with it.  This is what guarantees exactly one record per
    /// submitted request on *every* path.
    fn drop(&mut self) {
        self.finish(SpanStatus::Failed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sink() -> Arc<SpanSink> {
        Arc::new(SpanSink::new())
    }

    #[test]
    fn phases_partition_the_lifetime() {
        let sk = sink();
        let mut sp = TraceSpan::open(&sk, 1, 0, "skewed", "batch", 4);
        sp.mark(Phase::Queue);
        sp.mark(Phase::Batch);
        sp.mark(Phase::Plan);
        sp.mark(Phase::Dispatch);
        sp.mark(Phase::Execute);
        sp.finish(SpanStatus::Ok);
        let spans = sk.spans();
        assert_eq!(spans.len(), 1);
        let r = &spans[0];
        assert_eq!(r.status, SpanStatus::Ok);
        assert_eq!(r.total_ns(), r.phases_ns.iter().sum::<u64>());
    }

    #[test]
    fn early_finish_attributes_to_open_phase() {
        // A shed request closes straight from the queue phase.
        let sk = sink();
        let mut sp = TraceSpan::open(&sk, 2, 0, "skewed", "batch", 1);
        sp.finish(SpanStatus::Shed);
        let r = &sk.spans()[0];
        assert_eq!(r.status, SpanStatus::Shed);
        assert_eq!(r.total_ns(), r.phases_ns[Phase::Queue as usize]);
        for p in [Phase::Batch, Phase::Plan, Phase::Dispatch, Phase::Execute, Phase::Reply] {
            assert_eq!(r.phases_ns[p as usize], 0, "{}", p.name());
        }
    }

    #[test]
    fn dropped_span_closes_as_failed() {
        let sk = sink();
        {
            let mut sp = TraceSpan::open(&sk, 3, 1, "baseline-3reg", "interactive", 2);
            sp.mark(Phase::Queue);
            sp.mark(Phase::Batch);
            sp.mark(Phase::Plan);
            sp.mark(Phase::Dispatch);
            // Shard drops the batch mid-execute: no finish call.
        }
        let spans = sk.spans();
        assert_eq!(spans.len(), 1);
        let r = &spans[0];
        assert_eq!(r.status, SpanStatus::Failed);
        // The in-flight execute phase absorbed the remainder.
        assert_eq!(r.total_ns(), r.phases_ns.iter().sum::<u64>());
        assert!(r.phases_ns[Phase::Execute as usize] > 0);
    }

    #[test]
    fn disabled_span_is_inert() {
        let mut sp = TraceSpan::disabled();
        assert!(!sp.is_enabled());
        sp.mark(Phase::Queue);
        sp.set_batch(0, 1, false);
        sp.finish(SpanStatus::Ok);
        // No sink, nothing to assert beyond "did not panic".
    }

    #[test]
    fn record_json_roundtrip() {
        let r = SpanRecord {
            id: 42,
            model: 1,
            kind: "skewed".into(),
            class: "interactive".into(),
            rows: 6,
            status: SpanStatus::Ok,
            shard: Some(1),
            batch_size: 3,
            cache_hit: true,
            retries: 2,
            phases_ns: [10, 20, 30, 40, 50, 60],
            cycles: CycleAttribution {
                exposed_preload: 8,
                compute: 100,
                drain: 12,
                recovery: 4,
            },
            sdc_detected: 1,
            sdc_recovered: 1,
            sdc_unresolved: 0,
        };
        let line = r.to_json().to_string_compact();
        let back = SpanRecord::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn jsonl_parse_roundtrips_spans_and_events() {
        let sk = sink();
        sk.event("health", "quarantined", 1, 7);
        let mut sp = TraceSpan::open(&sk, 9, 0, "skewed", "batch", 1);
        sp.finish(SpanStatus::Closed);
        let text = sk.to_jsonl();
        let (spans, events) = parse_jsonl(&text).unwrap();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].id, 9);
        assert_eq!(spans[0].status, SpanStatus::Closed);
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].label, "quarantined");
        assert_eq!(events[0].shard, 1);
        assert_eq!(events[0].clock, 7);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_jsonl("{\"type\":\"mystery\"}").is_err());
        assert!(parse_jsonl("not json").is_err());
    }
}
