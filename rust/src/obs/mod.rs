//! Observability: unified metrics registry + per-request trace spans.
//!
//! Two halves (DESIGN.md §17):
//!
//! * **Metrics** — a lock-light [`MetricsRegistry`] of named counters,
//!   gauges and bounded log2-bucket histograms ([`Log2Histogram`]).
//!   The serve stack publishes its formerly scattered tallies (submit /
//!   shed counts, shard fault counters, plan-cache hit/miss, ABFT
//!   detected/recovered/unresolved, shard-health transitions) into one
//!   registry whose [`MetricsRegistry::snapshot`] feeds the report
//!   layer and the `--metrics-out` JSON dump.
//!
//! * **Tracing** — a [`TraceSpan`] opened per submitted request travels
//!   with it through queue → batcher → plan cache → shard dispatch →
//!   execution (+ ABFT recovery) → reply, recording wall-clock phase
//!   durations that sum exactly to the request latency *and* the
//!   cycle-domain attribution ([`CycleAttribution`]) of the producing
//!   batch.  Closed spans land in a [`SpanSink`], are written as
//!   JSON-lines via `--trace-out`, and `skewsa trace` renders the
//!   p50/p99 critical-path breakdown.
//!
//! The [`Obs`] handle bundles both halves and is what `Server::start`
//! variants thread through the stack; tracing is off (zero-cost spans)
//! unless explicitly enabled.

pub mod cycles;
pub mod hist;
pub mod registry;
pub mod span;

pub use cycles::CycleAttribution;
pub use hist::{HistSnapshot, Log2Histogram, REL_QUANTILE_ERROR};
pub use registry::{Counter, Gauge, Hist, MetricsRegistry, MetricsSnapshot};
pub use span::{parse_jsonl, Phase, SpanRecord, SpanSink, SpanStatus, TraceEvent, TraceSpan};

use std::sync::Arc;

/// The observability handle a server threads through its stack: always
/// a registry, optionally a span sink (tracing enabled).
#[derive(Clone, Default)]
pub struct Obs {
    pub registry: Arc<MetricsRegistry>,
    pub sink: Option<Arc<SpanSink>>,
}

impl Obs {
    /// Metrics only; spans are inert (the default for `Server::start`).
    pub fn new() -> Obs {
        Obs::default()
    }

    /// Metrics + live request tracing.
    pub fn with_tracing() -> Obs {
        Obs { registry: Arc::new(MetricsRegistry::new()), sink: Some(Arc::new(SpanSink::new())) }
    }

    /// Open a span for a submitted request: live when tracing is on,
    /// inert otherwise.
    pub fn open_span(
        &self,
        id: u64,
        model: usize,
        kind: &str,
        class: &str,
        rows: usize,
    ) -> TraceSpan {
        match &self.sink {
            Some(sink) => TraceSpan::open(sink, id, model, kind, class, rows),
            None => TraceSpan::disabled(),
        }
    }
}
