//! Bounded log2-bucket histogram: the registry's latency store.
//!
//! A [`Log2Histogram`] holds `u64` samples (nanoseconds, cycles, bytes —
//! any non-negative magnitude) in a **fixed** array of buckets, so memory
//! is bounded no matter how many samples a closed-loop run records — the
//! fix for `LatencyRecorder`'s old unbounded `Mutex<Vec<u64>>`.
//!
//! Bucket layout (HDR-style): values below `2^SUB_BITS` get one bucket
//! each (exact); larger values are split per power of two into
//! `2^SUB_BITS` linear sub-buckets.  A bucket holding value `v ≥ 32`
//! spans `2^(e-SUB_BITS)` values where `e = ⌊log2 v⌋`, so any quantile
//! read from the histogram is off by **less than one bucket width**:
//! a relative error below [`REL_QUANTILE_ERROR`] `= 2^-SUB_BITS =
//! 3.125%` (and *zero* for values `< 32`).  `min`, `max`, `count` and
//! `sum` (hence the mean) are tracked exactly.
//!
//! Recording is a handful of relaxed atomic adds — no lock, safe from
//! any thread — which is what keeps the serve-path instrumentation
//! overhead inside the bench gate.

use std::sync::atomic::{AtomicU64, Ordering};

/// Linear sub-buckets per power of two (as a bit count).
pub const SUB_BITS: u32 = 5;
/// Sub-buckets per octave (`2^SUB_BITS`).
pub const SUBS: usize = 1 << SUB_BITS;
/// Total bucket count: `SUBS` exact buckets for `v < SUBS`, then
/// `SUBS` per octave for exponents `SUB_BITS..=63`.
pub const BUCKETS: usize = SUBS + (64 - SUB_BITS as usize) * SUBS;

/// Documented worst-case relative quantile error (one bucket width).
pub const REL_QUANTILE_ERROR: f64 = 1.0 / SUBS as f64;

/// Bucket index for a value (total order preserving).
pub fn bucket_index(v: u64) -> usize {
    if v < SUBS as u64 {
        v as usize
    } else {
        let e = 63 - v.leading_zeros(); // e ≥ SUB_BITS
        let sub = ((v >> (e - SUB_BITS)) & (SUBS as u64 - 1)) as usize;
        (e - SUB_BITS + 1) as usize * SUBS + sub
    }
}

/// Inclusive lower bound of a bucket (its reported representative).
pub fn bucket_lower_bound(i: usize) -> u64 {
    if i < SUBS {
        i as u64
    } else {
        let e = (i / SUBS) as u32 + SUB_BITS - 1;
        let sub = (i % SUBS) as u64;
        (SUBS as u64 + sub) << (e - SUB_BITS)
    }
}

/// Fixed-size, lock-free histogram of `u64` samples.
pub struct Log2Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl Default for Log2Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Log2Histogram {
    pub fn new() -> Log2Histogram {
        Log2Histogram {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }

    /// Record one sample (relaxed atomics; callable from any thread).
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Point-in-time copy of the bucket counts and exact aggregates.
    pub fn snapshot(&self) -> HistSnapshot {
        let buckets: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        HistSnapshot {
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            min: self.min.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
            buckets,
        }
    }
}

/// Immutable view of a [`Log2Histogram`] at snapshot time.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistSnapshot {
    pub count: u64,
    /// Exact sum of all recorded samples.
    pub sum: u64,
    /// Exact minimum recorded sample (`u64::MAX` when empty).
    pub min: u64,
    /// Exact maximum recorded sample.
    pub max: u64,
    /// Per-bucket counts (see [`bucket_lower_bound`] for edges).
    pub buckets: Vec<u64>,
}

impl HistSnapshot {
    /// Exact mean of the recorded samples (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Nearest-rank quantile, `0 < p ≤ 100`.  Returns the lower bound
    /// of the bucket holding the rank-selected sample, clamped into
    /// `[min, max]` — within [`REL_QUANTILE_ERROR`] of the true sample
    /// (exact for samples `< 32`, and `p = 100` returns `max` exactly).
    ///
    /// # Panics
    /// On an out-of-domain `p` (matches
    /// [`crate::serve::percentile_ns`]'s contract).
    pub fn quantile(&self, p: f64) -> u64 {
        assert!(p.is_finite() && p > 0.0 && p <= 100.0, "quantile {p} outside (0, 100]");
        if self.count == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil() as u64;
        let rank = rank.clamp(1, self.count);
        if rank == self.count {
            // The rank-selected sample is the maximum, which is tracked
            // exactly — don't round it down to its bucket edge.
            return self.max;
        }
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_lower_bound(i).clamp(self.min, self.max);
            }
        }
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn small_values_are_exact() {
        for v in 0..SUBS as u64 {
            assert_eq!(bucket_lower_bound(bucket_index(v)), v);
        }
    }

    #[test]
    fn bucket_index_is_monotone_and_bounded() {
        let mut prev = 0usize;
        let mut v = 1u64;
        while v < u64::MAX / 2 {
            let i = bucket_index(v);
            assert!(i >= prev, "index not monotone at {v}");
            assert!(i < BUCKETS, "index {i} out of range at {v}");
            assert!(bucket_lower_bound(i) <= v, "lower bound above value at {v}");
            prev = i;
            v = v.wrapping_mul(3).wrapping_add(7);
        }
        assert!(bucket_index(u64::MAX) < BUCKETS);
    }

    #[test]
    fn lower_bound_inverts_index_on_bucket_edges() {
        for i in 0..BUCKETS {
            let lo = bucket_lower_bound(i);
            assert_eq!(bucket_index(lo), i, "bucket {i} lower bound {lo}");
        }
    }

    #[test]
    fn quantile_error_bound_at_1m_samples() {
        // The satellite regression: 1M synthetic latency samples, every
        // standard quantile within the documented relative error of the
        // exact nearest-rank percentile.
        let h = Log2Histogram::new();
        let mut rng = Rng::new(0x0b5_1234);
        let mut exact: Vec<u64> = Vec::with_capacity(1_000_000);
        for _ in 0..1_000_000 {
            // Log-uniform-ish latencies from ~1us to ~16ms in ns.
            let e = 10 + (rng.next_u64() % 14);
            let v = (1u64 << e) + rng.next_u64() % (1u64 << e);
            h.record(v);
            exact.push(v);
        }
        exact.sort_unstable();
        let snap = h.snapshot();
        assert_eq!(snap.count, 1_000_000);
        for p in [1.0, 10.0, 50.0, 90.0, 95.0, 99.0, 99.9, 100.0] {
            let truth = crate::serve::percentile_ns(&exact, p);
            let got = snap.quantile(p);
            let err = (truth as f64 - got as f64).abs() / truth as f64;
            assert!(
                err <= REL_QUANTILE_ERROR,
                "p{p}: got {got}, exact {truth}, err {err:.5}"
            );
        }
        assert_eq!(snap.max, *exact.last().unwrap());
        assert_eq!(snap.min, exact[0]);
        assert_eq!(snap.quantile(100.0), snap.max);
        let exact_mean = exact.iter().sum::<u64>() as f64 / exact.len() as f64;
        assert!((snap.mean() - exact_mean).abs() < 1e-6);
    }

    #[test]
    fn empty_histogram_quantiles_are_zero() {
        let snap = Log2Histogram::new().snapshot();
        assert_eq!(snap.quantile(50.0), 0);
        assert_eq!(snap.mean(), 0.0);
        assert_eq!(snap.count, 0);
    }

    #[test]
    #[should_panic(expected = "outside (0, 100]")]
    fn quantile_domain_is_enforced() {
        Log2Histogram::new().snapshot().quantile(0.0);
    }

    #[test]
    fn concurrent_records_all_land() {
        let h = std::sync::Arc::new(Log2Histogram::new());
        std::thread::scope(|s| {
            for t in 0..4 {
                let h = std::sync::Arc::clone(&h);
                s.spawn(move || {
                    for i in 0..10_000u64 {
                        h.record(t * 1000 + i);
                    }
                });
            }
        });
        assert_eq!(h.count(), 40_000);
        assert_eq!(h.snapshot().buckets.iter().sum::<u64>(), 40_000);
    }
}
