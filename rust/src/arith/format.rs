//! Reduced-precision floating-point formats (paper Fig. 1).
//!
//! A format is `1 + exp_bits + man_bits` wide: sign, biased exponent,
//! fraction.  All the formats of the paper's Fig. 1 are provided:
//!
//! | format   | e bits | m bits | bias | notes                            |
//! |----------|--------|--------|------|----------------------------------|
//! | FP32     | 8      | 23     | 127  | IEEE-754 single                  |
//! | BF16     | 8      | 7      | 127  | FP32 dynamic range, low precision|
//! | FP16     | 5      | 10     | 15   | IEEE-754 half                    |
//! | FP8-E4M3 | 4      | 3      | 7    | OCP FP8; no Inf, single NaN      |
//! | FP8-E5M2 | 5      | 2      | 15   | OCP FP8; IEEE-like specials      |
//!
//! Encoding/decoding is exact (subnormals included) and rounding is
//! round-to-nearest-even, matching both IEEE-754 and the OCP FP8 spec's
//! default behaviour.  The E4M3 deviation from IEEE (exponent-field
//! all-ones encodes *finite* values except mantissa all-ones = NaN) is
//! honoured.

/// Classification of a decoded floating-point value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FpClass {
    /// ±0.
    Zero,
    /// Finite non-zero (normal or subnormal).
    Finite,
    /// ±infinity.
    Inf,
    /// Not-a-number.
    Nan,
}

/// A floating-point *format descriptor*: field widths and special-value
/// conventions.  `FpFormat` is a value type so simulations can be swept
/// across formats at runtime (and hashed, so plan-cache keys can include
/// the format).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct FpFormat {
    /// Human-readable name, e.g. `"bf16"`.
    pub name: &'static str,
    /// Exponent field width in bits.
    pub exp_bits: u32,
    /// Fraction (explicit mantissa) field width in bits.
    pub man_bits: u32,
    /// `true` for formats with IEEE-like specials (exp all-ones = Inf/NaN).
    /// `false` for FP8-E4M3, where exp all-ones is finite except the
    /// mantissa-all-ones NaN, and which has no infinity.
    pub ieee_specials: bool,
}

impl FpFormat {
    /// IEEE-754 binary32.
    pub const FP32: FpFormat =
        FpFormat { name: "fp32", exp_bits: 8, man_bits: 23, ieee_specials: true };
    /// Bfloat16 (Google brain float).
    pub const BF16: FpFormat =
        FpFormat { name: "bf16", exp_bits: 8, man_bits: 7, ieee_specials: true };
    /// IEEE-754 binary16.
    pub const FP16: FpFormat =
        FpFormat { name: "fp16", exp_bits: 5, man_bits: 10, ieee_specials: true };
    /// OCP 8-bit FP, 4-bit exponent / 3-bit mantissa variant.
    pub const FP8E4M3: FpFormat =
        FpFormat { name: "fp8e4m3", exp_bits: 4, man_bits: 3, ieee_specials: false };
    /// OCP 8-bit FP, 5-bit exponent / 2-bit mantissa variant.
    pub const FP8E5M2: FpFormat =
        FpFormat { name: "fp8e5m2", exp_bits: 5, man_bits: 2, ieee_specials: true };

    /// All reduced-precision input formats examined in the paper.
    pub const REDUCED: [FpFormat; 4] =
        [Self::BF16, Self::FP16, Self::FP8E4M3, Self::FP8E5M2];

    /// Every supported input format (FP32 first, then the reduced set)
    /// — the candidate list the precision planner searches.
    pub const ALL: [FpFormat; 5] =
        [Self::FP32, Self::BF16, Self::FP16, Self::FP8E4M3, Self::FP8E5M2];

    /// Canonical human-facing name, used by **every** report table and
    /// summary so format spellings cannot drift between emitters (the
    /// machine-facing `name` field stays lowercase for CLI/JSON
    /// parsing).
    ///
    /// ```
    /// use skewsa::FpFormat;
    /// assert_eq!(FpFormat::FP8E4M3.display_name(), "FP8-E4M3");
    /// assert_eq!(FpFormat::BF16.to_string(), "BF16"); // Display delegates
    /// ```
    pub const fn display_name(&self) -> &'static str {
        match (self.exp_bits, self.man_bits) {
            (8, 23) => "FP32",
            (8, 7) => "BF16",
            (5, 10) => "FP16",
            (4, 3) => "FP8-E4M3",
            (5, 2) => "FP8-E5M2",
            _ => "FP?",
        }
    }

    /// Total storage width in bits (1 + exponent + fraction).
    pub const fn width(&self) -> u32 {
        1 + self.exp_bits + self.man_bits
    }

    /// Exponent bias.
    pub const fn bias(&self) -> i32 {
        (1 << (self.exp_bits - 1)) - 1
    }

    /// Maximum biased exponent field value (all ones).
    pub const fn exp_field_max(&self) -> u32 {
        (1 << self.exp_bits) - 1
    }

    /// Smallest unbiased exponent of a *normal* number.
    pub const fn emin(&self) -> i32 {
        1 - self.bias()
    }

    /// Largest unbiased exponent of a finite normal number.
    pub const fn emax(&self) -> i32 {
        if self.ieee_specials {
            self.exp_field_max() as i32 - 1 - self.bias()
        } else {
            // E4M3: exp field all-ones is still finite.
            self.exp_field_max() as i32 - self.bias()
        }
    }

    /// The largest finite magnitude, as significand (`1.f` scaled to an
    /// integer with `man_bits` fraction bits) and unbiased exponent.
    pub fn max_finite(&self) -> (u64, i32) {
        let full = (1u64 << (self.man_bits + 1)) - 1;
        if self.ieee_specials {
            (full, self.emax())
        } else {
            // E4M3: mantissa all-ones at top exponent is NaN, so the
            // largest finite has mantissa `111...0`.
            (full - 1, self.emax())
        }
    }

    /// Mask of valid storage bits.
    pub const fn mask(&self) -> u64 {
        (1u64 << self.width()) - 1
    }

    /// Canonical quiet-NaN bit pattern.
    pub fn nan_bits(&self) -> u64 {
        if self.ieee_specials {
            // Exp all ones, MSB of fraction set.
            ((self.exp_field_max() as u64) << self.man_bits)
                | (1u64 << (self.man_bits - 1).max(0))
        } else {
            // E4M3: S.1111.111.
            ((self.exp_field_max() as u64) << self.man_bits)
                | ((1u64 << self.man_bits) - 1)
        }
    }

    /// Positive-infinity bit pattern.  For E4M3 (no Inf) this returns the
    /// NaN pattern, matching OCP saturating-to-NaN conventions.
    pub fn inf_bits(&self) -> u64 {
        if self.ieee_specials {
            (self.exp_field_max() as u64) << self.man_bits
        } else {
            self.nan_bits()
        }
    }

    /// Raw biased exponent field of a stored bit pattern.
    #[inline]
    pub const fn exp_field_of(&self, bits: u64) -> u32 {
        ((bits >> self.man_bits) & (self.exp_field_max() as u64)) as u32
    }

    /// `true` iff `bits` encodes a *normal* finite number away from both
    /// exponent-field extremes — exactly the operand class eligible for
    /// the branch-free product fast path (`arith::kernel`).  Zeros,
    /// subnormals (field 0), and the top exponent field (IEEE specials;
    /// E4M3 top-exponent finites are conservatively excluded too, so one
    /// predicate serves every format) all return `false` and take the
    /// exact slow path.  The per-band "any-special" masks of the batched
    /// simulators are folds of this predicate.
    #[inline]
    pub const fn is_fast_normal(&self, bits: u64) -> bool {
        let ef = self.exp_field_of(bits);
        ef != 0 && ef != self.exp_field_max()
    }

    /// Decode a raw bit pattern into an [`Unpacked`] value.
    #[inline]
    pub fn decode(&self, bits: u64) -> Unpacked {
        let bits = bits & self.mask();
        let sign = (bits >> (self.width() - 1)) & 1 == 1;
        let exp_field = ((bits >> self.man_bits) & (self.exp_field_max() as u64)) as u32;
        let frac = bits & ((1u64 << self.man_bits) - 1);

        if self.ieee_specials && exp_field == self.exp_field_max() {
            return if frac == 0 {
                Unpacked { sign, exp: 0, sig: 0, class: FpClass::Inf }
            } else {
                Unpacked { sign, exp: 0, sig: 0, class: FpClass::Nan }
            };
        }
        if !self.ieee_specials
            && exp_field == self.exp_field_max()
            && frac == (1u64 << self.man_bits) - 1
        {
            return Unpacked { sign, exp: 0, sig: 0, class: FpClass::Nan };
        }

        if exp_field == 0 {
            if frac == 0 {
                return Unpacked { sign, exp: 0, sig: 0, class: FpClass::Zero };
            }
            // Subnormal: value = 0.frac × 2^emin.  Normalise so the MSB of
            // `sig` is the hidden bit (bit `man_bits`).
            let shift = self.man_bits + 1 - (64 - frac.leading_zeros());
            return Unpacked {
                sign,
                exp: self.emin() - shift as i32,
                sig: frac << shift,
                class: FpClass::Finite,
            };
        }

        Unpacked {
            sign,
            exp: exp_field as i32 - self.bias(),
            sig: (1u64 << self.man_bits) | frac,
            class: FpClass::Finite,
        }
    }

    /// Encode a finite value given as an *exact* significand/exponent pair
    /// plus a sticky bit, with round-to-nearest-even.
    ///
    /// `sig` holds the magnitude with its MSB anywhere; `exp` is the
    /// unbiased exponent of the MSB of `sig` interpreted as the `1.`
    /// position after normalisation — concretely, the value encoded is
    /// `(-1)^sign × sig × 2^(exp − (sig_msb_index))`... to keep call sites
    /// simple this helper instead takes (`sig`, `exp`) meaning
    /// `(-1)^sign × 1.xxx × 2^exp` where `sig` has exactly
    /// `man_bits + 1 + EXTRA` bits: the hidden bit at the top, then the
    /// fraction, then `EXTRA = 3` guard/round/sticky bits (callers fold any
    /// lower bits into the bottom sticky position).
    ///
    /// Returns the raw bit pattern (overflow ⇒ ±Inf, or ±max-finite for
    /// E4M3; underflow ⇒ subnormal/zero).
    pub fn encode_rne(&self, sign: bool, mut exp: i32, mut sig: u64) -> u64 {
        const EXTRA: u32 = 3;
        debug_assert!(sig == 0 || sig >> (self.man_bits + EXTRA) >= 1, "sig not normalised");
        debug_assert!(sig >> (self.man_bits + 1 + EXTRA) == 0, "sig too wide");
        let sign_bit = (sign as u64) << (self.width() - 1);
        if sig == 0 {
            return sign_bit;
        }

        // Gradual underflow: shift right until exp == emin, accumulating
        // sticky, then the normal rounding below produces a subnormal (or
        // zero) encoding with exp field 0.
        let mut subnormal = false;
        if exp < self.emin() {
            let shift = (self.emin() - exp) as u32;
            sig = shift_right_sticky(sig, shift);
            exp = self.emin();
            subnormal = true;
        }

        // Round to nearest even on the EXTRA low bits.
        let lsb = 1u64 << EXTRA;
        let halfway = lsb >> 1;
        let low = sig & (lsb - 1);
        let mut q = sig >> EXTRA;
        if low > halfway || (low == halfway && q & 1 == 1) {
            q += 1;
        }
        // Rounding may carry out (1.111.. -> 10.000..).
        if q >> (self.man_bits + 1) != 0 {
            q >>= 1;
            exp += 1;
        }

        if subnormal && q >> self.man_bits == 0 {
            // Still subnormal after rounding: exp field 0, fraction = q.
            return sign_bit | q;
        }
        // May have rounded *up into* the normal range.
        if exp > self.emax() || (!self.ieee_specials && exp == self.emax() && {
            let (maxsig, _) = self.max_finite();
            q > maxsig
        }) {
            return if self.ieee_specials {
                sign_bit | self.inf_bits()
            } else {
                // E4M3 saturates to NaN per OCP overflow convention when
                // rounding overflows (no Inf encoding exists).
                sign_bit | self.nan_bits()
            };
        }
        let exp_field = (exp + self.bias()) as u64;
        sign_bit | (exp_field << self.man_bits) | (q & ((1u64 << self.man_bits) - 1))
    }

    /// Convert an `f64` to this format with RNE (used by tests and input
    /// quantisation).  Exact for every `f64` input.
    ///
    /// Every representable value round-trips bit-exactly through
    /// [`FpFormat::to_f64`]:
    ///
    /// ```
    /// use skewsa::FpFormat;
    /// for fmt in FpFormat::ALL {
    ///     let bits = fmt.from_f64(1.5);
    ///     assert_eq!(fmt.to_f64(bits), 1.5);
    ///     assert_eq!(fmt.from_f64(fmt.to_f64(bits)), bits);
    /// }
    /// ```
    ///
    /// FP8-E4M3 has no infinity: overflow **saturates to NaN** per the
    /// OCP FP8 convention (`S.1111.111`), while the top exponent's other
    /// mantissa codes stay finite (448 is the max finite):
    ///
    /// ```
    /// use skewsa::FpFormat;
    /// let e4m3 = FpFormat::FP8E4M3;
    /// assert_eq!(e4m3.from_f64(448.0), 0x7e);          // max finite survives
    /// assert!(e4m3.to_f64(e4m3.from_f64(1e9)).is_nan()); // overflow -> NaN
    /// assert!(e4m3.to_f64(e4m3.from_f64(f64::INFINITY)).is_nan());
    /// // IEEE-like formats overflow to a real infinity instead.
    /// assert_eq!(FpFormat::FP8E5M2.to_f64(FpFormat::FP8E5M2.from_f64(1e9)),
    ///            f64::INFINITY);
    /// ```
    pub fn from_f64(&self, x: f64) -> u64 {
        let bits = x.to_bits();
        let sign = bits >> 63 == 1;
        let exp_field = ((bits >> 52) & 0x7ff) as i32;
        let frac = bits & ((1u64 << 52) - 1);
        if exp_field == 0x7ff {
            return if frac == 0 {
                ((sign as u64) << (self.width() - 1)) | self.inf_bits()
            } else {
                ((sign as u64) << (self.width() - 1)) | self.nan_bits()
            };
        }
        if exp_field == 0 && frac == 0 {
            return (sign as u64) << (self.width() - 1);
        }
        // Normalise (f64 subnormals included).
        let (exp, mut sig) = if exp_field == 0 {
            let shift = 53 - (64 - frac.leading_zeros());
            (-1022 - shift as i32, frac << shift)
        } else {
            (exp_field - 1023, (1u64 << 52) | frac)
        };
        // Reduce the 53-bit significand to man_bits+1+3 with sticky.
        let target = self.man_bits + 1 + 3;
        if 53 > target {
            sig = shift_right_sticky(sig, 53 - target);
        } else {
            sig <<= target - 53;
        }
        // `exp` refers to the hidden-bit position throughout.
        self.encode_rne(sign, exp, sig)
    }

    /// Convert a stored bit pattern to `f64` (exact: every format here is
    /// narrower than binary64).
    pub fn to_f64(&self, bits: u64) -> f64 {
        let u = self.decode(bits);
        match u.class {
            FpClass::Zero => {
                if u.sign { -0.0 } else { 0.0 }
            }
            FpClass::Inf => {
                if u.sign { f64::NEG_INFINITY } else { f64::INFINITY }
            }
            FpClass::Nan => f64::NAN,
            FpClass::Finite => {
                let mag = u.sig as f64 * (u.exp - self.man_bits as i32).exp2_f64();
                if u.sign { -mag } else { mag }
            }
        }
    }

    /// Convert an `f32` with RNE.
    pub fn from_f32(&self, x: f32) -> u64 {
        self.from_f64(x as f64)
    }

    /// Convert a stored pattern to `f32`.  Exact for every format except
    /// values outside f32 range (cannot occur: all formats ⊆ f32 range).
    pub fn to_f32(&self, bits: u64) -> f32 {
        self.to_f64(bits) as f32
    }
}

impl std::fmt::Display for FpFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.display_name())
    }
}

/// Integer power-of-two helper for exact `f64` scaling without `powi`
/// rounding concerns.
trait Exp2 {
    fn exp2_f64(self) -> f64;
}
impl Exp2 for i32 {
    fn exp2_f64(self) -> f64 {
        // Build the f64 directly from the exponent field when in range;
        // fall back to ldexp-style composition for the subnormal tail.
        if (-1022..=1023).contains(&self) {
            f64::from_bits(((self + 1023) as u64) << 52)
        } else if self < -1022 {
            f64::from_bits(((self + 1023 + 200) as u64) << 52) * (-200i32).exp2_f64_inner()
        } else {
            f64::INFINITY
        }
    }
}
trait Exp2Inner {
    fn exp2_f64_inner(self) -> f64;
}
impl Exp2Inner for i32 {
    fn exp2_f64_inner(self) -> f64 {
        f64::from_bits(((self + 1023) as u64) << 52)
    }
}

/// Right-shift preserving a sticky LSB: any 1 shifted out sets bit 0 of
/// the result.  Shifts ≥ 64 collapse to the pure sticky bit.
#[inline]
pub fn shift_right_sticky(x: u64, shift: u32) -> u64 {
    if shift == 0 {
        x
    } else if shift >= 64 {
        (x != 0) as u64
    } else {
        let lost = x & ((1u64 << shift) - 1);
        (x >> shift) | (lost != 0) as u64
    }
}

/// A decoded FP value: `(-1)^sign × sig × 2^(exp − man_bits)` where `sig`
/// includes the hidden bit (so normal values have `sig ∈ [2^man_bits,
/// 2^(man_bits+1))`).  Subnormals are normalised on decode (their `exp`
/// dips below `emin`), so downstream datapath code never branches on
/// subnormality.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Unpacked {
    pub sign: bool,
    /// Unbiased exponent of the hidden-bit position.
    pub exp: i32,
    /// Significand with hidden bit explicit; 0 for zero/inf/nan.
    pub sig: u64,
    pub class: FpClass,
}

impl Unpacked {
    pub fn is_finite(&self) -> bool {
        matches!(self.class, FpClass::Zero | FpClass::Finite)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn widths_and_biases() {
        assert_eq!(FpFormat::FP32.width(), 32);
        assert_eq!(FpFormat::BF16.width(), 16);
        assert_eq!(FpFormat::FP16.width(), 16);
        assert_eq!(FpFormat::FP8E4M3.width(), 8);
        assert_eq!(FpFormat::FP8E5M2.width(), 8);
        assert_eq!(FpFormat::FP32.bias(), 127);
        assert_eq!(FpFormat::BF16.bias(), 127);
        assert_eq!(FpFormat::FP16.bias(), 15);
        assert_eq!(FpFormat::FP8E4M3.bias(), 7);
        assert_eq!(FpFormat::FP8E5M2.bias(), 15);
    }

    #[test]
    fn display_names_are_canonical_and_distinct() {
        let names: Vec<&str> = FpFormat::ALL.iter().map(|f| f.display_name()).collect();
        assert_eq!(names, ["FP32", "BF16", "FP16", "FP8-E4M3", "FP8-E5M2"]);
        assert_eq!(format!("{}", FpFormat::FP8E5M2), "FP8-E5M2");
        // The machine names stay lowercase (CLI/JSON contract).
        for f in FpFormat::ALL {
            assert!(f.name.chars().all(|c| !c.is_ascii_uppercase()), "{}", f.name);
        }
    }

    #[test]
    fn bf16_is_truncated_fp32_range() {
        // BF16 shares the FP32 exponent range (the paper's Fig. 1 point).
        assert_eq!(FpFormat::BF16.emax(), FpFormat::FP32.emax());
        assert_eq!(FpFormat::BF16.emin(), FpFormat::FP32.emin());
    }

    #[test]
    fn e4m3_top_exponent_is_finite() {
        // 0x7E = S0.1111.110 = 448.0, the E4M3 max finite.
        assert_eq!(FpFormat::FP8E4M3.to_f64(0x7e), 448.0);
        // 0x7F is NaN.
        assert_eq!(FpFormat::FP8E4M3.decode(0x7f).class, FpClass::Nan);
        assert!(FpFormat::FP8E4M3.to_f64(0x7f).is_nan());
    }

    #[test]
    fn e5m2_has_inf() {
        assert_eq!(FpFormat::FP8E5M2.decode(0x7c).class, FpClass::Inf);
        assert_eq!(FpFormat::FP8E5M2.to_f64(0x7c), f64::INFINITY);
        assert_eq!(FpFormat::FP8E5M2.decode(0x7d).class, FpClass::Nan);
    }

    #[test]
    fn fp32_roundtrip_exhaustive_sample() {
        // Round-trip through decode/to_f64/from_f64 for a structured sweep
        // of fp32 patterns, including subnormals and specials.
        let f = FpFormat::FP32;
        let mut bits: u64 = 0;
        for _ in 0..200_000 {
            let x = f.to_f64(bits);
            if x.is_nan() {
                assert_eq!(f.decode(f.from_f64(x)).class, FpClass::Nan);
            } else {
                assert_eq!(f.from_f64(x), bits, "bits {bits:#x}");
            }
            bits = bits.wrapping_mul(6364136223846793005).wrapping_add(1) & f.mask();
        }
    }

    #[test]
    fn bf16_exhaustive_roundtrip() {
        let f = FpFormat::BF16;
        for bits in 0..=0xffffu64 {
            let x = f.to_f64(bits);
            if x.is_nan() {
                assert_eq!(f.decode(bits).class, FpClass::Nan);
            } else {
                let back = f.from_f64(x);
                assert_eq!(back, bits, "bits {bits:#x} -> {x} -> {back:#x}");
            }
        }
    }

    #[test]
    fn fp8_exhaustive_roundtrip_both_variants() {
        for f in [FpFormat::FP8E4M3, FpFormat::FP8E5M2] {
            for bits in 0..=0xffu64 {
                let x = f.to_f64(bits);
                if x.is_nan() {
                    assert_eq!(f.decode(bits).class, FpClass::Nan, "{} {bits:#x}", f.name);
                } else {
                    assert_eq!(f.from_f64(x), bits, "{} {bits:#x}", f.name);
                }
            }
        }
    }

    #[test]
    fn bf16_from_f32_matches_truncation_semantics() {
        // BF16 RNE from f32: compare against manual round-to-nearest-even
        // of the top 16 bits for a sample of values.
        let f = FpFormat::BF16;
        for &x in &[1.0f32, 1.5, 3.14159, -2.71828, 1e-20, 6.5e4, -0.0, 255.99] {
            let got = f.from_f32(x);
            let b = x.to_bits();
            let lower = b & 0xffff;
            let mut upper = (b >> 16) as u64;
            if lower > 0x8000 || (lower == 0x8000 && upper & 1 == 1) {
                upper += 1;
            }
            assert_eq!(got, upper, "x={x}");
        }
    }

    #[test]
    fn subnormal_decode_normalises() {
        let f = FpFormat::BF16;
        // Smallest BF16 subnormal: 0x0001 = 2^-133.
        let u = f.decode(0x0001);
        assert_eq!(u.class, FpClass::Finite);
        assert_eq!(u.sig, 1 << f.man_bits); // hidden bit explicit
        assert_eq!(u.exp, f.emin() - f.man_bits as i32);
        assert_eq!(f.to_f64(0x0001), (f.emin() - f.man_bits as i32).exp2_f64());
    }

    #[test]
    fn rounding_to_subnormal_and_zero() {
        let f = FpFormat::FP8E5M2;
        // Halfway between 0 and the smallest subnormal rounds to even (0).
        let tiny = f.to_f64(0x01) / 2.0;
        assert_eq!(f.from_f64(tiny), 0x00);
        // Slightly above halfway rounds up.
        assert_eq!(f.from_f64(tiny * 1.01), 0x01);
    }

    #[test]
    fn overflow_behaviour() {
        assert_eq!(FpFormat::FP8E5M2.from_f64(1e9), FpFormat::FP8E5M2.inf_bits());
        // E4M3 has no Inf: overflow lands on NaN per OCP.
        let e4 = FpFormat::FP8E4M3;
        let over = e4.from_f64(1e9);
        assert_eq!(over & 0x7f, e4.nan_bits() & 0x7f);
        // Max finite (448) must survive.
        assert_eq!(e4.from_f64(448.0), 0x7e);
    }

    #[test]
    fn fast_normal_predicate_matches_decode_class() {
        // The fast-path eligibility predicate must be a *subset* of
        // Finite, must exclude every zero/subnormal/special, and must
        // exclude the top exponent field even where E4M3 keeps it finite.
        for f in FpFormat::ALL {
            let probe = |bits: u64| {
                let u = f.decode(bits);
                let fast = f.is_fast_normal(bits);
                if fast {
                    assert_eq!(u.class, FpClass::Finite, "{} {bits:#x}", f.name);
                    assert!(u.exp >= f.emin(), "{} {bits:#x} subnormal", f.name);
                }
                let ef = f.exp_field_of(bits);
                assert_eq!(fast, ef != 0 && ef != f.exp_field_max());
            };
            if f.width() <= 16 {
                for bits in 0..=f.mask() {
                    probe(bits);
                }
            } else {
                let mut bits: u64 = 1;
                for _ in 0..50_000 {
                    probe(bits & f.mask());
                    bits = bits.wrapping_mul(6364136223846793005).wrapping_add(1);
                }
            }
        }
    }

    #[test]
    fn shift_right_sticky_properties() {
        assert_eq!(shift_right_sticky(0b1011, 2), 0b11); // lost 11 -> sticky
        assert_eq!(shift_right_sticky(0b1000, 3), 0b1);
        assert_eq!(shift_right_sticky(0b1000, 4), 0b1); // all lost, sticky
        assert_eq!(shift_right_sticky(0, 70), 0);
        assert_eq!(shift_right_sticky(u64::MAX, 64), 1);
        assert_eq!(shift_right_sticky(0b0100, 2), 0b01);
    }
}
