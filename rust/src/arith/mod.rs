//! Bit-accurate reduced-precision floating-point arithmetic.
//!
//! This is the functional substrate of the reproduction.  Everything the
//! simulator computes bottoms out here:
//!
//! * [`format`] — the FP formats of the paper's Fig. 1 (Bfloat16, FP16,
//!   FP8-E4M3, FP8-E5M2) plus IEEE-754 FP32, with exact encode/decode,
//!   subnormal support and round-to-nearest-even.
//! * [`softfloat`] — an exact integer-arithmetic softfloat core used as
//!   the *functional oracle* for the structural datapaths.
//! * [`lza`] — leading-zero counting / anticipation, the block whose
//!   output (`L_i`) the skewed pipeline forwards across PEs.
//! * [`fma`] — the two *structural* chained multiply-add datapaths under
//!   comparison: `BaselineFmaPath` (Fig. 3(b) signal ordering) and
//!   `SkewedFmaPath` (Figs. 5/6: speculative exponent forwarding + the
//!   `d_i = d'_i ± L_{i-1}` fix + retimed normalisation).  The paper's
//!   central functional claim — speculation is corrected *exactly* — is
//!   enforced by requiring the two paths to be bit-identical.
//! * [`accum`] — the double-width column accumulator semantics (one
//!   rounding per column, at the South edge) and the wide functional
//!   reference accumulator.
//! * [`kernel`] — monomorphized per-format hot-path kernels (const-generic
//!   over exponent/mantissa widths) plus batched slice/block MAC entry
//!   points; bit-identical to the generic datapaths by construction and
//!   pinned so by the parity suite.

pub mod accum;
pub mod fma;
pub mod format;
pub mod kernel;
pub mod lza;
pub mod softfloat;

pub use accum::{ColumnOracle, RoundingUnit};
pub use fma::{BaselineFmaPath, ChainDatapath, PsumSignal, SkewedFmaPath};
pub use format::{FpClass, FpFormat, Unpacked};
