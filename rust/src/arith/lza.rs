//! Leading-zero counting and anticipation (LZA).
//!
//! In the paper's pipelines the LZA block runs *in parallel* with the
//! adder and predicts the number of leading zeros of the (possibly
//! cancelling) sum, so normalisation can start without waiting for the
//! carry to resolve [27], [28].  Classic LZA over the pre-addition
//! operands is exact-to-within-one; real designs pair it with a 1-bit
//! correction mux driven by the adder's output.
//!
//! We model both pieces:
//!
//! * [`lza_anticipate`] — the anticipator, computed purely from the two
//!   *aligned* operands (never from the sum): for effective subtraction
//!   the Schmookler–Nowka P/G/Z indicator string over `a + !b` (carry-in
//!   absorbed by the `p_n = 1` boundary), whose count is exact or one
//!   *less* than the true count; for effective addition the
//!   `min(lzc(a), lzc(b))` position, which is exact or one *more* (the
//!   carry-out case).  Either way `|ant − exact| ≤ 1` — the property the
//!   1-bit correction mux relies on, enforced by the tests below and the
//!   property suite.
//! * [`lzc`] — an exact leading-zero count of the result window;
//! * [`Lza::count`] — the corrected pair, i.e. what the hardware's
//!   LZA + correction mux emits and what the datapaths consume as `L_i`.

/// Exact leading-zero count of `x` within a window of `width` bits.
///
/// Returns `width` for `x == 0` (the all-zero string), matching the
/// behaviour hardware LZC trees exhibit when the sum cancels completely.
#[inline]
pub fn lzc(x: u64, width: u32) -> u32 {
    debug_assert!(width <= 64);
    debug_assert!(width == 64 || x >> width == 0, "value wider than window");
    if x == 0 {
        width
    } else {
        width - (64 - x.leading_zeros())
    }
}

/// Leading-zero anticipation over two aligned magnitude operands.
///
/// `a` and `b` are magnitude bit-vectors of `width` bits; `sub` selects
/// effective subtraction (`a − b`, requires `a ≥ b` — callers compare
/// magnitudes first, as the datapath's sign logic does).  Returns the
/// anticipated leading-zero count of `|a ± b|`, correct to within one:
///
/// * `sub == true` (Schmookler–Nowka indicator): `ant ≤ exact ≤ ant + 1`;
/// * `sub == false` (min-position): `ant − 1 ≤ exact ≤ ant`.
pub fn lza_anticipate(a: u64, b: u64, width: u32, sub: bool) -> u32 {
    debug_assert!(width <= 63);
    debug_assert!(a >> width == 0 && b >> width == 0);
    if !sub {
        // Effective addition: the sum's MSB sits at the taller operand's
        // MSB or one above (carry-out).
        return lzc(a, width).min(lzc(b, width));
    }
    // Effective subtraction a − b, computed on a + !b with the +1 carry-in
    // absorbed by the indicator's boundary conditions (p_n = 1).
    let b_eff = !b & ((1u64 << width) - 1);
    let p = a ^ b_eff;
    let g = a & b_eff;
    let z = !(a | b_eff) & ((1u64 << width) - 1);
    let bit = |v: u64, i: i64| -> bool {
        if i < 0 || i >= width as i64 {
            false
        } else {
            (v >> i) & 1 == 1
        }
    };
    let mut count = 0;
    for i in (0..width as i64).rev() {
        // Boundary: p_{width} = 1 (the implicit carry-in position).
        let pi1 = if i + 1 >= width as i64 { true } else { bit(p, i + 1) };
        let f = if pi1 {
            (bit(g, i) && !bit(z, i - 1)) || (bit(z, i) && !bit(g, i - 1))
        } else {
            (bit(z, i) && !bit(z, i - 1)) || (bit(g, i) && !bit(g, i - 1))
        };
        if f {
            return count;
        }
        count += 1;
    }
    width
}

/// The LZA block as instantiated in a PE: anticipator + exact correction.
///
/// `width` is the adder/accumulator significand width the block spans.
#[derive(Clone, Copy, Debug)]
pub struct Lza {
    pub width: u32,
}

impl Lza {
    pub fn new(width: u32) -> Self {
        debug_assert!(width <= 63);
        Lza { width }
    }

    /// Corrected leading-zero count `L` of the magnitude sum `|a ± b|`.
    ///
    /// `sum` is the actual adder magnitude output; the anticipator is
    /// evaluated (for model fidelity + the tests' ±1 invariant) and then
    /// corrected against the exact count, exactly as the
    /// anticipate-then-fix hardware pair behaves.
    pub fn count(&self, a: u64, b: u64, sub: bool, sum: u64) -> u32 {
        let exact = lzc(sum, self.width);
        if cfg!(debug_assertions) && sum != 0 {
            let (hi, lo) = if sub && b > a { (b, a) } else { (a, b) };
            let ant = lza_anticipate(hi, lo, self.width, sub);
            debug_assert!(
                ant.abs_diff(exact) <= 1,
                "LZA invariant broken: ant={ant} exact={exact} a={a:#x} b={b:#x} sub={sub}"
            );
        }
        exact
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lzc_basics() {
        assert_eq!(lzc(0, 16), 16);
        assert_eq!(lzc(1, 16), 15);
        assert_eq!(lzc(0x8000, 16), 0);
        assert_eq!(lzc(0x00ff, 16), 8);
        assert_eq!(lzc(u64::MAX, 64), 0);
        assert_eq!(lzc(0, 64), 64);
    }

    #[test]
    fn anticipate_addition_no_cancellation() {
        // Addition of same-sign values: at most the carry-out bit appears;
        // anticipator must be exact or one more.
        let w = 24;
        for (a, b) in [(0x40_0000u64, 0x40_0000u64), (0x1a_bcdeu64, 0x12_3456u64), (1u64, 1u64)] {
            let sum = a + b;
            if sum >> w != 0 {
                continue; // carry-out handled by the aligner upstream
            }
            let ant = lza_anticipate(a, b, w, false);
            let exact = lzc(sum, w);
            assert!(ant == exact || ant == exact + 1, "a={a:#x} b={b:#x} ant={ant} exact={exact}");
        }
    }

    #[test]
    fn anticipate_subtraction_cancellation() {
        let w = 24;
        // Catastrophic cancellation: 0x800000 − 0x7fffff = 1 → 23 zeros.
        let (a, b) = (0x80_0000u64, 0x7f_ffffu64);
        let ant = lza_anticipate(a, b, w, true);
        let exact = lzc(a - b, w);
        assert!(ant == exact || ant + 1 == exact, "ant={ant} exact={exact}");
        assert_eq!(exact, 23);
    }

    #[test]
    fn anticipate_sweep_random_pairs() {
        let w = 30u32;
        let mut state = 0xdead_beefu64;
        for _ in 0..50_000 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            let a = (state >> 10) & ((1 << w) - 1);
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            let b = (state >> 10) & ((1 << w) - 1);
            // add
            let sum = a + b;
            if sum >> w == 0 {
                let ant = lza_anticipate(a, b, w, false);
                let exact = lzc(sum, w);
                assert!(
                    ant == exact || ant == exact + 1,
                    "add a={a:#x} b={b:#x} ant={ant} exact={exact}"
                );
            }
            // sub (ordered)
            let (hi, lo) = if a >= b { (a, b) } else { (b, a) };
            if hi != lo {
                let ant = lza_anticipate(hi, lo, w, true);
                let exact = lzc(hi - lo, w);
                assert!(
                    ant == exact || ant + 1 == exact,
                    "sub hi={hi:#x} lo={lo:#x} ant={ant} exact={exact}"
                );
            }
        }
    }

    #[test]
    fn anticipate_subtraction_structured_cases() {
        let w = 24u32;
        // Near-total and staggered cancellations across every shift amount.
        for shift in 0..w - 1 {
            let a = (1u64 << (w - 1)) | (1 << shift);
            let b = 1u64 << (w - 1);
            let ant = lza_anticipate(a, b, w, true);
            let exact = lzc(a - b, w);
            assert!(ant == exact || ant + 1 == exact, "shift={shift} ant={ant} exact={exact}");
        }
    }

    #[test]
    fn corrected_count_is_exact() {
        let l = Lza::new(24);
        assert_eq!(l.count(0x80_0000, 0x7f_ffff, true, 1), 23);
        assert_eq!(l.count(0x40_0000, 0x40_0000, false, 0x80_0000), 0);
        assert_eq!(l.count(0x123, 0x123, true, 0), 24); // total cancellation
    }
}
