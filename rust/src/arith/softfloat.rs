//! Exact integer softfloat core — the *functional oracle*.
//!
//! Everything here is value-level and exact: products are computed with
//! full-width integer mantissas and chained sums are accumulated in a wide
//! fixed-point window that spans the entire exponent range of the input
//! format, so **no rounding or truncation occurs until the final encode**.
//!
//! The structural datapaths in [`crate::arith::fma`] (the baseline and
//! skewed pipelines under comparison) are *finite-width* hardware models:
//! they keep a double-width accumulator and a sticky bit, exactly like the
//! paper's PEs.  This module provides two references against which they
//! are tested:
//!
//! * [`ExactChain`] — infinitely precise (big fixed-point) chained
//!   multiply-add, for measuring the *numerical error* of the hardware
//!   semantics;
//! * [`exact_product`] — the shared exact multiplier primitive (a
//!   reduced-precision mantissa product is always exact in `2(m+1)` bits,
//!   which is why the paper's PEs never round after the multiply).

use super::format::{shift_right_sticky, FpClass, FpFormat, Unpacked};

/// Special-value state that flows down a column alongside the partial sum.
///
/// The paper's datapath discussion is for finite values; specials are
/// resolved at the value level (IEEE semantics) and simply override the
/// numeric result at the column edge.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Special {
    /// No special value encountered.
    None,
    /// The chain has collapsed to ±Inf.
    Inf(bool),
    /// The chain has collapsed to NaN (Inf − Inf, NaN input, 0 × Inf…).
    Nan,
}

impl Special {
    /// Merge the special-state of a new product into the running state.
    #[inline]
    pub fn merge_product(self, a: &Unpacked, b: &Unpacked) -> Special {
        match self {
            Special::Nan => Special::Nan,
            s => match (a.class, b.class) {
                (FpClass::Nan, _) | (_, FpClass::Nan) => Special::Nan,
                (FpClass::Inf, FpClass::Zero) | (FpClass::Zero, FpClass::Inf) => Special::Nan,
                (FpClass::Inf, _) | (_, FpClass::Inf) => {
                    let psign = a.sign ^ b.sign;
                    match s {
                        Special::Inf(s0) if s0 != psign => Special::Nan,
                        Special::Inf(s0) => Special::Inf(s0),
                        _ => Special::Inf(psign),
                    }
                }
                _ => s,
            },
        }
    }
}

/// An exact product of two finite reduced-precision values.
///
/// `sig` is the full `2(m_a + m_b + 2)`-bit mantissa product (zero iff the
/// product is zero); `exp` is the unbiased exponent of bit
/// `man_bits_a + man_bits_b + 1` — i.e. the value is
/// `(-1)^sign × sig × 2^(exp − (m_a + m_b + 1))` *if* the top bit landed at
/// position `m_a + m_b + 1` (products of normals occupy the top one or two
/// bit positions; we do **not** normalise here, matching the hardware,
/// which feeds the raw product into the aligner).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExactProduct {
    pub sign: bool,
    /// Unbiased exponent of the `2^0` position of `1.x × 1.y`, i.e.
    /// `exp_a + exp_b`.
    pub exp: i32,
    /// Raw mantissa product, `(m_a+1) + (m_b+1)` bits, fraction point at
    /// bit `m_a + m_b` (so a product of two normals is in `[2^f, 2^(f+2))`
    /// with `f = m_a + m_b`).
    pub sig: u64,
    /// Number of fraction bits below the binary point in `sig`.
    pub frac_bits: u32,
    /// True if either input was zero (sig == 0).
    pub zero: bool,
}

/// Multiply two decoded finite values exactly.
///
/// Panics in debug if either input is Inf/NaN — specials are handled by
/// [`Special::merge_product`] before the numeric path runs.
#[inline]
pub fn exact_product(fmt_a: FpFormat, a: &Unpacked, fmt_b: FpFormat, b: &Unpacked) -> ExactProduct {
    debug_assert!(a.is_finite() && b.is_finite());
    let sig = a.sig * b.sig; // ≤ 2(m+1) bits each ⇒ fits u64 for all formats here
    ExactProduct {
        sign: a.sign ^ b.sign,
        exp: a.exp + b.exp,
        sig,
        frac_bits: fmt_a.man_bits + fmt_b.man_bits,
        zero: sig == 0,
    }
}

// ---------------------------------------------------------------------------
// Big fixed-point accumulator: the exact chained-sum reference.
// ---------------------------------------------------------------------------

/// Number of 64-bit limbs in the exact accumulator.  The window must cover
/// `2 × (emax − emin + man_bits)` of the widest format in play plus
/// headroom for carries across a 128-long column: FP32 products span
/// `[2^-298, 2^257)`; 16 limbs = 1024 bits is ample for every format the
/// paper considers and columns far deeper than 128.
const LIMBS: usize = 16;

/// Fixed-point binary point: bit index (from LSB of limb 0) representing
/// `2^EXP_ORIGIN`.  Chosen so the smallest product fraction bit of FP32
/// (`2^-298`) stays in-window and the largest (`2^257` plus carry headroom)
/// also fits: bit 0 = 2^-480, bit 1023 = 2^543.
const EXP_ORIGIN: i32 = -480;

/// Exact two's-complement fixed-point accumulator spanning the full
/// exponent range of the supported formats.  Used as the infinitely
/// precise reference for column sums.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BigFixed {
    limbs: [u64; LIMBS],
}

impl Default for BigFixed {
    fn default() -> Self {
        Self::zero()
    }
}

impl BigFixed {
    /// The zero value.
    pub fn zero() -> Self {
        BigFixed { limbs: [0; LIMBS] }
    }

    /// True iff the value is exactly zero.
    pub fn is_zero(&self) -> bool {
        self.limbs.iter().all(|&l| l == 0)
    }

    /// True iff the value is negative (two's complement sign).
    pub fn is_negative(&self) -> bool {
        self.limbs[LIMBS - 1] >> 63 == 1
    }

    fn add_inplace(&mut self, other: &BigFixed) {
        let mut carry = 0u64;
        for i in 0..LIMBS {
            let (s1, c1) = self.limbs[i].overflowing_add(other.limbs[i]);
            let (s2, c2) = s1.overflowing_add(carry);
            self.limbs[i] = s2;
            carry = (c1 as u64) + (c2 as u64);
        }
        // Wrap-around is a genuine overflow of the window — cannot happen
        // for in-range inputs by construction of LIMBS/EXP_ORIGIN.
        debug_assert!(carry == 0 || self.is_negative() != other.is_negative() || true);
    }

    fn negate_inplace(&mut self) {
        let mut carry = 1u64;
        for l in &mut self.limbs {
            let (inv, c) = (!*l).overflowing_add(carry);
            *l = inv;
            carry = c as u64;
        }
    }

    /// Add `(-1)^sign × sig × 2^exp_of_lsb` into the accumulator.
    ///
    /// `exp_of_lsb` is the unbiased exponent weight of bit 0 of `sig`.
    pub fn add_scaled(&mut self, sign: bool, sig: u64, exp_of_lsb: i32) {
        if sig == 0 {
            return;
        }
        let pos = exp_of_lsb - EXP_ORIGIN;
        assert!(
            pos >= 0 && (pos as usize) + 64 <= LIMBS * 64 - 2,
            "value out of BigFixed window (exp_of_lsb={exp_of_lsb})"
        );
        let limb = (pos / 64) as usize;
        let off = (pos % 64) as u32;
        let mut tmp = BigFixed::zero();
        tmp.limbs[limb] = sig << off;
        if off != 0 && limb + 1 < LIMBS {
            tmp.limbs[limb + 1] = sig >> (64 - off);
        }
        if sign {
            tmp.negate_inplace();
        }
        self.add_inplace(&tmp);
    }

    /// Decompose into `(sign, exp_of_msb, sig_window, sticky)` where
    /// `sig_window` holds the top `bits` significant bits of the magnitude
    /// (MSB-aligned at bit `bits − 1`) and `sticky` is true iff any lower
    /// magnitude bit is set.  Returns `None` for zero.
    pub fn to_magnitude(&self, bits: u32) -> Option<(bool, i32, u64, bool)> {
        if self.is_zero() {
            return None;
        }
        let mut mag = self.clone();
        let sign = mag.is_negative();
        if sign {
            mag.negate_inplace();
        }
        // Find MSB.
        let mut msb = 0usize;
        for i in (0..LIMBS).rev() {
            if mag.limbs[i] != 0 {
                msb = i * 64 + (63 - mag.limbs[i].leading_zeros() as usize);
                break;
            }
        }
        let exp_of_msb = msb as i32 + EXP_ORIGIN;
        // Extract top `bits` bits ending at msb.
        let lo = msb as i64 - (bits as i64 - 1); // bit index of window LSB (may be <0)
        let mut window = 0u64;
        let mut sticky = false;
        for b in 0..bits as i64 {
            let idx = lo + b;
            if idx < 0 {
                continue;
            }
            let bit = (mag.limbs[(idx / 64) as usize] >> (idx % 64)) & 1;
            window |= bit << b;
        }
        if lo > 0 {
            'outer: for i in 0..lo {
                if (mag.limbs[(i / 64) as usize] >> (i % 64)) & 1 == 1 {
                    sticky = true;
                    break 'outer;
                }
            }
        }
        Some((sign, exp_of_msb, window, sticky))
    }

    /// Round the accumulator to the given format with RNE (one rounding —
    /// this is the "round once at the South edge" semantics, taken to the
    /// exact limit).
    pub fn round_to(&self, fmt: FpFormat) -> u64 {
        match self.to_magnitude(fmt.man_bits + 2 + 3) {
            None => 0, // +0
            Some((sign, exp_msb, window, sticky)) => {
                // window has MSB at bit man_bits+4; encode_rne wants hidden
                // bit at man_bits+3 with 3 GRS bits below. Shift down by 1
                // folding into sticky.
                let w = fmt.man_bits + 2 + 3;
                debug_assert!(window >> (w - 1) == 1);
                let sig = (window >> 1) | ((window & 1) != 0 || sticky) as u64;
                fmt.encode_rne(sign, exp_msb, sig)
            }
        }
    }

    /// Exact conversion to `f64` when in range (used by tests; lossy if the
    /// magnitude needs more than 53 bits, in which case it rounds RNE like
    /// a hardware f64 convert would).
    pub fn to_f64(&self) -> f64 {
        match self.to_magnitude(55) {
            None => 0.0,
            Some((sign, exp_msb, window, sticky)) => {
                let mut x = 0.0f64;
                let mut w = window;
                // Fold sticky into the bottom bit for correct RNE via f64 ops.
                if sticky {
                    w |= 1;
                }
                let mut e = exp_msb - 54;
                while w != 0 {
                    let low = w & 0xff;
                    if low != 0 {
                        x += low as f64 * pow2(e);
                    }
                    w >>= 8;
                    e += 8;
                }
                if sign {
                    -x
                } else {
                    x
                }
            }
        }
    }
}

/// Exact `2^e` as f64 (e in f64's normal+subnormal range).
pub fn pow2(e: i32) -> f64 {
    if e >= -1022 {
        debug_assert!(e <= 1023);
        f64::from_bits(((e + 1023) as u64) << 52)
    } else {
        // Compose through a normal intermediate for the subnormal tail.
        f64::from_bits(((e + 200 + 1023) as u64) << 52) * f64::from_bits(((-200 + 1023) as u64) << 52)
    }
}

// ---------------------------------------------------------------------------
// Exact chained multiply-add (the column-sum value reference).
// ---------------------------------------------------------------------------

/// Exact chained multiply-add over a column: `Σ a_i × w_i` accumulated in
/// [`BigFixed`] with IEEE special-value semantics, rounded once at the end.
#[derive(Clone, Debug, Default)]
pub struct ExactChain {
    acc: BigFixed,
    special: Special,
}

impl Default for Special {
    fn default() -> Self {
        Special::None
    }
}

impl ExactChain {
    /// Fresh, empty chain (sum = +0).
    pub fn new() -> Self {
        Self { acc: BigFixed::zero(), special: Special::None }
    }

    /// Feed one `a × w` term, given as raw bit patterns in `fmt`.
    pub fn mac(&mut self, fmt: FpFormat, a_bits: u64, w_bits: u64) {
        let a = fmt.decode(a_bits);
        let w = fmt.decode(w_bits);
        self.special = self.special.merge_product(&a, &w);
        if a.is_finite() && w.is_finite() {
            let p = exact_product(fmt, &a, fmt, &w);
            self.acc
                .add_scaled(p.sign, p.sig, p.exp - p.frac_bits as i32);
        }
    }

    /// Current special-state of the chain.
    pub fn special(&self) -> Special {
        self.special
    }

    /// Exact accumulator (numeric part only).
    pub fn acc(&self) -> &BigFixed {
        &self.acc
    }

    /// Round the chain to `out_fmt` (RNE, single rounding), resolving
    /// specials first.
    pub fn result(&self, out_fmt: FpFormat) -> u64 {
        match self.special {
            Special::Nan => out_fmt.nan_bits(),
            Special::Inf(s) => ((s as u64) << (out_fmt.width() - 1)) | out_fmt.inf_bits(),
            Special::None => self.acc.round_to(out_fmt),
        }
    }

    /// The chain value as f64 (RNE if > 53 significant bits).
    pub fn value_f64(&self) -> f64 {
        match self.special {
            Special::Nan => f64::NAN,
            Special::Inf(s) => {
                if s {
                    f64::NEG_INFINITY
                } else {
                    f64::INFINITY
                }
            }
            Special::None => self.acc.to_f64(),
        }
    }
}

// ---------------------------------------------------------------------------
// Standalone value-level helpers used across the crate.
// ---------------------------------------------------------------------------

/// Round-to-nearest-even a `(sign, exp_of_msb, window_with_GRS, sticky)`
/// magnitude to `fmt`, where `window` is MSB-aligned at bit `msb_pos`.
/// Thin convenience over [`FpFormat::encode_rne`] used by the rounding
/// units.
pub fn round_magnitude_rne(
    fmt: FpFormat,
    sign: bool,
    exp_of_msb: i32,
    window: u64,
    msb_pos: u32,
    sticky: bool,
) -> u64 {
    if window == 0 {
        return (sign as u64) << (fmt.width() - 1);
    }
    debug_assert!(window >> msb_pos == 1, "window not MSB-aligned");
    let target = fmt.man_bits + 3; // hidden bit at man_bits+3 per encode_rne
    let sig = if msb_pos > target {
        shift_right_sticky(window, msb_pos - target) | sticky as u64
    } else {
        (window << (target - msb_pos)) | sticky as u64
    };
    fmt.encode_rne(sign, exp_of_msb, sig)
}

/// Decode `bits` in `fmt` and widen to f64 — convenience used everywhere
/// test vectors are produced.
pub fn bits_to_f64(fmt: FpFormat, bits: u64) -> f64 {
    fmt.to_f64(bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bf(x: f64) -> u64 {
        FpFormat::BF16.from_f64(x)
    }

    #[test]
    fn exact_product_small_values() {
        let f = FpFormat::BF16;
        let a = f.decode(bf(3.0));
        let b = f.decode(bf(5.0));
        let p = exact_product(f, &a, f, &b);
        assert!(!p.sign);
        // 1.1 × 1.01 = 1.111 → sig = 0b11 << 6 × 0b101 << 5 …
        let val = p.sig as f64 * pow2(p.exp - p.frac_bits as i32);
        assert_eq!(val, 15.0);
    }

    #[test]
    fn exact_product_signs_and_zero() {
        let f = FpFormat::BF16;
        let p = exact_product(f, &f.decode(bf(-2.0)), f, &f.decode(bf(3.0)));
        assert!(p.sign);
        let z = exact_product(f, &f.decode(bf(0.0)), f, &f.decode(bf(3.0)));
        assert!(z.zero);
    }

    #[test]
    fn bigfixed_add_and_roundtrip() {
        let mut acc = BigFixed::zero();
        acc.add_scaled(false, 3, 0); // +3
        acc.add_scaled(false, 5, -2); // +1.25
        assert_eq!(acc.to_f64(), 4.25);
        acc.add_scaled(true, 17, -2); // −4.25
        assert!(acc.is_zero());
    }

    #[test]
    fn bigfixed_cancellation_catastrophic() {
        let mut acc = BigFixed::zero();
        acc.add_scaled(false, 1, 100);
        acc.add_scaled(true, 1, 100);
        acc.add_scaled(false, 1, -100);
        assert_eq!(acc.to_f64(), pow2(-100));
    }

    #[test]
    fn bigfixed_negative_magnitudes() {
        let mut acc = BigFixed::zero();
        acc.add_scaled(true, 7, 0);
        let (s, e, w, st) = acc.to_magnitude(8).unwrap();
        assert!(s);
        assert_eq!(e, 2);
        assert_eq!(w, 0b1110_0000);
        assert!(!st);
    }

    #[test]
    fn bigfixed_sticky_detection() {
        let mut acc = BigFixed::zero();
        acc.add_scaled(false, 0b1_0000_0001, 0);
        let (_, e, w, st) = acc.to_magnitude(4).unwrap();
        assert_eq!(e, 8);
        assert_eq!(w, 0b1000);
        assert!(st);
    }

    #[test]
    fn exact_chain_matches_f64_for_small_sums() {
        let f = FpFormat::BF16;
        let mut ch = ExactChain::new();
        let terms = [(1.5, 2.0), (-0.5, 4.0), (3.0, 0.125), (7.0, -1.0)];
        let mut want = 0.0f64;
        for &(a, w) in &terms {
            let (ab, wb) = (bf(a), bf(w));
            ch.mac(f, ab, wb);
            want += f.to_f64(ab) * f.to_f64(wb);
        }
        assert_eq!(ch.value_f64(), want);
    }

    #[test]
    fn exact_chain_long_random_column_vs_f64() {
        // f64 accumulation of bf16 products is exact while partial sums
        // stay within 53 significant bits — engineered here by using small
        // integer-valued inputs.
        let f = FpFormat::BF16;
        let mut ch = ExactChain::new();
        let mut want = 0.0f64;
        let mut state = 0x1234_5678_u64;
        for _ in 0..128 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            let a = ((state >> 33) % 64) as f64 - 16.0;
            let w = ((state >> 43) % 8) as f64 - 4.0;
            let (ab, wb) = (bf(a), bf(w));
            ch.mac(f, ab, wb);
            want += f.to_f64(ab) * f.to_f64(wb);
        }
        assert_eq!(ch.value_f64(), want);
    }

    #[test]
    fn exact_chain_specials() {
        let f = FpFormat::BF16;
        let inf = f.inf_bits();
        let ninf = (1 << 15) | f.inf_bits();
        let one = bf(1.0);

        let mut ch = ExactChain::new();
        ch.mac(f, inf, one);
        assert_eq!(ch.special(), Special::Inf(false));
        assert_eq!(ch.result(FpFormat::FP32), FpFormat::FP32.inf_bits());

        // Inf − Inf → NaN.
        ch.mac(f, ninf, one);
        assert_eq!(ch.special(), Special::Nan);
        assert!(FpFormat::FP32
            .to_f64(ch.result(FpFormat::FP32))
            .is_nan());

        // 0 × Inf → NaN.
        let mut ch2 = ExactChain::new();
        ch2.mac(f, bf(0.0), inf);
        assert_eq!(ch2.special(), Special::Nan);
    }

    #[test]
    fn exact_chain_round_to_fp32_single_rounding() {
        // 1 + 2^-30: exact sum needs >24 bits; single RNE rounding to fp32
        // must round to 1.0 exactly once (no double-rounding artefacts).
        let f = FpFormat::BF16;
        let mut ch = ExactChain::new();
        ch.mac(f, bf(1.0), bf(1.0));
        ch.mac(f, bf(pow2(-15)), bf(pow2(-15)));
        let out = ch.result(FpFormat::FP32);
        assert_eq!(FpFormat::FP32.to_f64(out), 1.0);
        // but the exact value remembers the tail
        assert_eq!(ch.value_f64(), 1.0 + pow2(-30));
    }

    #[test]
    fn round_magnitude_rne_basic() {
        let f = FpFormat::BF16;
        // 1.0000001_1 (bit below LSB set, round up)
        let bits = round_magnitude_rne(f, false, 0, 0b1_0000001_1, 8, false);
        assert_eq!(f.to_f64(bits), 1.0 + 2.0 * pow2(-7));
        // ties to even
        let bits = round_magnitude_rne(f, false, 0, 0b1_0000001_1, 8, true);
        assert_eq!(f.to_f64(bits), 1.0 + 2.0 * pow2(-7));
    }

    #[test]
    fn pow2_extremes() {
        assert_eq!(pow2(0), 1.0);
        assert_eq!(pow2(-1), 0.5);
        assert_eq!(pow2(10), 1024.0);
        assert_eq!(pow2(-1074), f64::from_bits(1)); // smallest subnormal
        assert!(pow2(-1022).is_normal());
    }
}
