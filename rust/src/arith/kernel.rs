//! Monomorphized per-format hot-path kernels.
//!
//! Every cycle-accurate result in the repo funnels through one chained
//! multiply-add step (`arith::fma`).  The generic step reads the
//! [`FpFormat`] descriptor *per element* — exponent width, mantissa width,
//! bias, total width are all runtime loads feeding variable shifts.  This
//! module monomorphizes that inner loop over the five concrete formats the
//! paper considers, turning every field access into a compile-time
//! constant, while keeping the generic datapaths as the bit-exact
//! reference:
//!
//! * [`MonoKernel`]`<E, M, SKEWED>` — a zero-sized step kernel whose
//!   fast-product path is specialized by const exponent/mantissa widths
//!   and whose combine is the *extracted tail* of the corresponding
//!   generic datapath ([`baseline_combine`] / [`skewed_combine`]), so
//!   bit-identity holds by construction.  Zeros, subnormals, specials and
//!   E4M3 top-exponent finites fall through to the shared generic slow
//!   path ([`step_operands`]).
//! * [`mac_slice`] — one dependent chain over operand slices with the
//!   format dispatch hoisted out of the loop (the [`super::accum::ColumnOracle`]
//!   and executor-oracle hot path).
//! * [`mac_block`] — many *independent* chains advanced in lockstep over
//!   SoA operand columns, chunked so several partial sums are live at once
//!   (instruction-level parallelism the dependent chain cannot expose).
//!   An "any-special" prescan (a fold of [`FpFormat::is_fast_normal`])
//!   routes bands containing zeros/subnormals/specials to the scalar slow
//!   path per column.
//! * [`quantize_matrix`] / [`decode_matrix`] — whole-matrix codec
//!   round-trips for the precision oracle, replacing per-(i,j,kk)
//!   re-quantization inside triple loops.
//!
//! The parity suite (`tests/prop_kernels.rs`) pins every kernel against
//! the generic path across all `FpFormat` × datapath combinations,
//! including subnormals, NaN/Inf and E4M3 saturation-boundary nudges.

use super::fma::{
    baseline_combine, product_to_window, skewed_combine, step_operands, ChainCfg, ChainDatapath,
    PsumSignal,
};
use super::format::FpFormat;
use super::softfloat::ExactProduct;

/// Lockstep chunk width for [`mac_block`]: enough independent chains in
/// flight to hide the add/normalize latency, small enough that the live
/// state stays in registers.
pub const BLOCK_LANES: usize = 8;

/// Const-generic twin of `fma::fast_normal_product`: both operands must be
/// *normal* finite numbers (biased exponent field strictly between 0 and
/// the all-ones field).  `E`/`M` are the exponent/mantissa widths, so the
/// masks and shifts below are compile-time constants.
///
/// Returns `None` for zeros, subnormals, Inf/NaN encodings and (because
/// E4M3 spends its top exponent field on finites) E4M3 values ≥ 256 —
/// exactly the conservative predicate of [`FpFormat::is_fast_normal`].
#[inline(always)]
pub fn normal_product<const E: u32, const M: u32>(a: u64, b: u64) -> Option<ExactProduct> {
    let em = (1u64 << E) - 1;
    let bias = (1i32 << (E - 1)) - 1;
    let width = 1 + E + M;
    let ea = (a >> M) & em;
    let eb = (b >> M) & em;
    if ea == 0 || eb == 0 || ea == em || eb == em {
        return None;
    }
    let frac_mask = (1u64 << M) - 1;
    let fa = (1u64 << M) | (a & frac_mask);
    let fb = (1u64 << M) | (b & frac_mask);
    Some(ExactProduct {
        sign: ((a ^ b) >> (width - 1)) & 1 == 1,
        exp: ea as i32 + eb as i32 - 2 * bias,
        sig: fa * fb,
        frac_bits: 2 * M,
        zero: false,
    })
}

/// One chained multiply-add step, bit-identical to the generic datapath's
/// `step` for the matching format — the common interface the simulators
/// monomorphize over.
pub trait MacKernel {
    /// Kernel variant tag for benches/reports (`"mono"` vs `"generic"`).
    const VARIANT: &'static str;

    /// Execute one step: `psum + a×w` at the value level.
    fn step(cfg: &ChainCfg, psum: &PsumSignal, a_bits: u64, w_bits: u64) -> PsumSignal;
}

/// Per-format monomorphized step kernel.  `E`/`M` must match
/// `cfg.in_fmt`; `SKEWED` selects which datapath tail the product feeds
/// ([`skewed_combine`] vs [`baseline_combine`]).
pub struct MonoKernel<const E: u32, const M: u32, const SKEWED: bool>;

impl<const E: u32, const M: u32, const SKEWED: bool> MacKernel for MonoKernel<E, M, SKEWED> {
    const VARIANT: &'static str = "mono";

    #[inline(always)]
    fn step(cfg: &ChainCfg, psum: &PsumSignal, a_bits: u64, w_bits: u64) -> PsumSignal {
        debug_assert_eq!((cfg.in_fmt.exp_bits, cfg.in_fmt.man_bits), (E, M));
        let (special, pwin) = match normal_product::<E, M>(a_bits, w_bits) {
            Some(p) => (psum.special, product_to_window(cfg, &p)),
            // Slow path: the generic operand stage re-derives the same
            // classification (its own fast check fails identically) and
            // handles zeros/subnormals/specials.
            None => match step_operands(cfg, psum, a_bits, w_bits) {
                Ok(pair) => pair,
                Err(out) => return out,
            },
        };
        if SKEWED {
            skewed_combine(cfg, psum, special, pwin)
        } else {
            baseline_combine(cfg, psum, special, pwin)
        }
    }
}

/// Generic fallback kernel: defers to the dynamic datapath `step`.  Used
/// for formats outside the monomorphized set and as the scalar reference
/// variant in benches and parity tests.
pub struct GenericKernel<D>(core::marker::PhantomData<D>);

impl<D: ChainDatapath + Default> MacKernel for GenericKernel<D> {
    const VARIANT: &'static str = "generic";

    #[inline(always)]
    fn step(cfg: &ChainCfg, psum: &PsumSignal, a_bits: u64, w_bits: u64) -> PsumSignal {
        D::default().step(cfg, psum, a_bits, w_bits)
    }
}

/// Dispatch a monomorphized invocation on a format's `(exp_bits,
/// man_bits)` pair — the single runtime `match` that replaces the
/// per-element one.  `$go` is instantiated once per concrete format; the
/// `_` arm is the generic fallback expression.
macro_rules! dispatch_format {
    ($fmt:expr, $go:ident ( $($arg:expr),* ), $generic:expr) => {
        match ($fmt.exp_bits, $fmt.man_bits) {
            (8, 7) => $go::<8, 7>($($arg),*),
            (5, 10) => $go::<5, 10>($($arg),*),
            (4, 3) => $go::<4, 3>($($arg),*),
            (5, 2) => $go::<5, 2>($($arg),*),
            (8, 23) => $go::<8, 23>($($arg),*),
            _ => $generic,
        }
    };
}

/// Fold a whole operand slice through one dependent baseline chain with
/// the format dispatch hoisted: `init + Σ a[k]×w[k]`, bit-identical to
/// repeated `BaselineFmaPath::step`.
pub fn mac_slice(cfg: &ChainCfg, init: &PsumSignal, a: &[u64], w: &[u64]) -> PsumSignal {
    assert_eq!(a.len(), w.len(), "mac_slice operand length mismatch");
    #[inline(never)]
    fn go<const E: u32, const M: u32>(
        cfg: &ChainCfg,
        init: &PsumSignal,
        a: &[u64],
        w: &[u64],
    ) -> PsumSignal {
        let mut s = *init;
        for (&av, &wv) in a.iter().zip(w.iter()) {
            s = MonoKernel::<E, M, false>::step(cfg, &s, av, wv);
        }
        s
    }
    dispatch_format!(cfg.in_fmt, go(cfg, init, a, w), {
        let mut s = *init;
        for (&av, &wv) in a.iter().zip(w.iter()) {
            s = GenericKernel::<super::fma::BaselineFmaPath>::step(cfg, &s, av, wv);
        }
        s
    })
}

/// True iff every operand bit pattern is on the fast-product path — the
/// per-band "any-special" mask is the negation of this fold.
#[inline]
pub fn all_fast_normal(fmt: FpFormat, bits: &[u64]) -> bool {
    bits.iter().all(|&x| fmt.is_fast_normal(x))
}

/// Advance many independent baseline chains in lockstep over SoA operand
/// columns: `out[j] += Σ_k a[k] × wcols[j][k]`.
///
/// All-normal bands run a chunked (groups of [`BLOCK_LANES`]) k-outer /
/// lane-inner loop so several independent partial sums are in flight per
/// iteration; any band containing a zero/subnormal/special/E4M3-top
/// operand takes the scalar per-column slow path.  Chains are independent,
/// so both orders produce identical bits.
pub fn mac_block(cfg: &ChainCfg, a: &[u64], wcols: &[&[u64]], out: &mut [PsumSignal]) {
    assert_eq!(wcols.len(), out.len(), "mac_block column count mismatch");
    for w in wcols {
        assert_eq!(w.len(), a.len(), "mac_block operand length mismatch");
    }
    let fmt = cfg.in_fmt;
    let fast_band = all_fast_normal(fmt, a) && wcols.iter().all(|w| all_fast_normal(fmt, w));
    if !fast_band {
        // Scalar slow path: dependent chain per column (still
        // format-hoisted; the specials thread through `step_operands`).
        for (s, w) in out.iter_mut().zip(wcols.iter()) {
            *s = mac_slice(cfg, s, a, w);
        }
        return;
    }
    #[inline(never)]
    fn go<const E: u32, const M: u32>(
        cfg: &ChainCfg,
        a: &[u64],
        wcols: &[&[u64]],
        out: &mut [PsumSignal],
    ) {
        let mut j0 = 0;
        for chunk in out.chunks_mut(BLOCK_LANES) {
            let wchunk = &wcols[j0..j0 + chunk.len()];
            for (k, &av) in a.iter().enumerate() {
                for (s, w) in chunk.iter_mut().zip(wchunk.iter()) {
                    *s = MonoKernel::<E, M, false>::step(cfg, s, av, w[k]);
                }
            }
            j0 += chunk.len();
        }
    }
    dispatch_format!(fmt, go(cfg, a, wcols, out), {
        for (s, w) in out.iter_mut().zip(wcols.iter()) {
            *s = mac_slice(cfg, s, a, w);
        }
    })
}

/// Quantize a whole matrix (flat slice) of f64 samples into `fmt` bit
/// patterns via the codec's exact round-to-nearest-even.  Pinned
/// bit-for-bit to `precision::error::quantize_oracle` by the parity suite
/// — `from_f64` *is* the codec the oracle checks.
pub fn quantize_matrix(fmt: FpFormat, xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|&x| fmt.from_f64(x)).collect()
}

/// Decode a whole matrix of `fmt` bit patterns to exact f64 values (every
/// supported format embeds exactly in f64).
pub fn decode_matrix(fmt: FpFormat, bits: &[u64]) -> Vec<f64> {
    bits.iter().map(|&b| fmt.to_f64(b)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::fma::{fast_normal_product, BaselineFmaPath, SkewedFmaPath};
    use crate::arith::RoundingUnit;
    use crate::util::rng::Rng;

    fn chain_for(fmt: FpFormat) -> ChainCfg {
        if fmt.width() == 8 {
            ChainCfg::new(fmt, FpFormat::FP16)
        } else {
            ChainCfg::new(fmt, FpFormat::FP32)
        }
    }

    fn interesting_bits(fmt: FpFormat, rng: &mut Rng) -> u64 {
        match rng.below(8) {
            0 => 0,                         // +0
            1 => 1u64 << (fmt.width() - 1), // -0
            2 => rng.bits(fmt.man_bits),    // subnormal
            3 => fmt.inf_bits(),            // Inf (E4M3: NaN)
            4 => fmt.nan_bits(),
            5 => fmt.inf_bits() - 1, // largest finite (saturation boundary)
            _ => rng.bits(fmt.width()),
        }
    }

    #[test]
    fn normal_product_matches_dynamic_fast_path() {
        fn probe<const E: u32, const M: u32>(fmt: FpFormat, rng: &mut Rng) {
            for _ in 0..4000 {
                let a = rng.bits(fmt.width());
                let b = rng.bits(fmt.width());
                assert_eq!(
                    normal_product::<E, M>(a, b),
                    fast_normal_product(fmt, a, b),
                    "{} a={a:#x} b={b:#x}",
                    fmt.name
                );
            }
        }
        let mut rng = Rng::new(0x6b65726e);
        probe::<8, 7>(FpFormat::BF16, &mut rng);
        probe::<5, 10>(FpFormat::FP16, &mut rng);
        probe::<4, 3>(FpFormat::FP8E4M3, &mut rng);
        probe::<5, 2>(FpFormat::FP8E5M2, &mut rng);
        probe::<8, 23>(FpFormat::FP32, &mut rng);
    }

    #[test]
    fn mono_step_is_bit_identical_to_generic_both_datapaths() {
        fn probe<const E: u32, const M: u32>(fmt: FpFormat, rng: &mut Rng) {
            let cfg = chain_for(fmt);
            let mut base = PsumSignal::zero(&cfg);
            let mut mono_b = base;
            let mut skew = PsumSignal::zero(&cfg);
            let mut mono_s = skew;
            for step in 0..600 {
                let a = interesting_bits(fmt, rng);
                let w = interesting_bits(fmt, rng);
                base = BaselineFmaPath.step(&cfg, &base, a, w);
                mono_b = MonoKernel::<E, M, false>::step(&cfg, &mono_b, a, w);
                assert_eq!(mono_b, base, "{} baseline step {step}", fmt.name);
                skew = SkewedFmaPath.step(&cfg, &skew, a, w);
                mono_s = MonoKernel::<E, M, true>::step(&cfg, &mono_s, a, w);
                assert_eq!(mono_s, skew, "{} skewed step {step}", fmt.name);
            }
            let ru = RoundingUnit::new(cfg);
            assert_eq!(ru.round(&mono_b), ru.round(&base));
            assert_eq!(ru.round(&mono_s), ru.round(&skew));
        }
        let mut rng = Rng::new(0x706172);
        probe::<8, 7>(FpFormat::BF16, &mut rng);
        probe::<5, 10>(FpFormat::FP16, &mut rng);
        probe::<4, 3>(FpFormat::FP8E4M3, &mut rng);
        probe::<5, 2>(FpFormat::FP8E5M2, &mut rng);
        probe::<8, 23>(FpFormat::FP32, &mut rng);
    }

    #[test]
    fn mac_slice_equals_stepwise_fold() {
        let mut rng = Rng::new(0x51);
        for fmt in FpFormat::ALL {
            let cfg = chain_for(fmt);
            for _ in 0..50 {
                let n = rng.below(40) as usize;
                let a: Vec<u64> = (0..n).map(|_| interesting_bits(fmt, &mut rng)).collect();
                let w: Vec<u64> = (0..n).map(|_| interesting_bits(fmt, &mut rng)).collect();
                let mut want = PsumSignal::zero(&cfg);
                for (&av, &wv) in a.iter().zip(w.iter()) {
                    want = BaselineFmaPath.step(&cfg, &want, av, wv);
                }
                let got = mac_slice(&cfg, &PsumSignal::zero(&cfg), &a, &w);
                assert_eq!(got, want, "{} n={n}", fmt.name);
            }
        }
    }

    #[test]
    fn mac_block_equals_per_column_chains() {
        let mut rng = Rng::new(0x7733);
        for fmt in FpFormat::ALL {
            let cfg = chain_for(fmt);
            for case in 0..30 {
                let k = 1 + rng.below(24) as usize;
                let cols = 1 + rng.below(19) as usize; // crosses BLOCK_LANES
                // Half the cases all-normal (fast band), half salted with
                // specials (slow band).
                let salted = case % 2 == 1;
                let sample = |rng: &mut Rng| {
                    if salted {
                        interesting_bits(fmt, rng)
                    } else {
                        let mut b = rng.bits(fmt.width());
                        while !fmt.is_fast_normal(b) {
                            b = rng.bits(fmt.width());
                        }
                        b
                    }
                };
                let a: Vec<u64> = (0..k).map(|_| sample(&mut rng)).collect();
                let wdata: Vec<Vec<u64>> =
                    (0..cols).map(|_| (0..k).map(|_| sample(&mut rng)).collect()).collect();
                let wcols: Vec<&[u64]> = wdata.iter().map(|w| w.as_slice()).collect();
                let mut got = vec![PsumSignal::zero(&cfg); cols];
                mac_block(&cfg, &a, &wcols, &mut got);
                for (j, w) in wdata.iter().enumerate() {
                    let mut want = PsumSignal::zero(&cfg);
                    for (&av, &wv) in a.iter().zip(w.iter()) {
                        want = BaselineFmaPath.step(&cfg, &want, av, wv);
                    }
                    assert_eq!(got[j], want, "{} col {j} salted={salted}", fmt.name);
                }
            }
        }
    }

    #[test]
    fn quantize_decode_round_trip_is_the_codec() {
        let mut rng = Rng::new(0xdead);
        for fmt in FpFormat::ALL {
            let xs: Vec<f64> = (0..500)
                .map(|i| match i % 5 {
                    0 => rng.normal_scaled(0.0, 1.0),
                    1 => rng.normal_scaled(0.0, 1e-6),
                    2 => rng.normal_scaled(0.0, 1e6),
                    3 => 0.0,
                    _ => rng.normal_scaled(0.0, 448.0),
                })
                .collect();
            let q = quantize_matrix(fmt, &xs);
            for (x, &b) in xs.iter().zip(q.iter()) {
                assert_eq!(b, fmt.from_f64(*x));
            }
            let d = decode_matrix(fmt, &q);
            for (&b, &v) in q.iter().zip(d.iter()) {
                assert_eq!(v, fmt.to_f64(b));
            }
        }
    }
}
