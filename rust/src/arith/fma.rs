//! Structural chained fused multiply-add datapaths.
//!
//! This module implements the paper's two contenders as *value-level but
//! structurally faithful* datapaths:
//!
//! * [`BaselineFmaPath`] — the state-of-the-art two-stage pipeline of
//!   Fig. 3(b): stage 1 computes the multiplication and the exponent
//!   compare against the *normalized* incoming partial sum; stage 2
//!   aligns, adds, runs the LZA and normalizes, forwarding a normalized
//!   partial sum (and its corrected exponent) to the next PE.
//! * [`SkewedFmaPath`] — the proposed skewed pipeline of Figs. 5/6:
//!   stage 1 compares against the *unnormalized* speculative exponent
//!   `ê_{i−1}` producing speculative `e′_i`/`d′_i`; stage 2's **Fix Sign &
//!   Exponent** block receives the previous PE's LZA count `L_{i−1}` and
//!   corrects (`d_i = d′_i + L_{i−1}` or `L_{i−1} − d′_i`, paper §III-B),
//!   while the incoming sum's normalization left-shift is retimed to merge
//!   with the alignment shift (Fig. 6) — a single net left-*or*-right
//!   shift.  The PE forwards the raw adder output, `ê_i`, and `L_i`.
//!
//! Both paths bottom out in the same window primitives ([`WindowVal`],
//! [`add_same_top`]), differing only in *which exponent reference they use
//! when* — exactly the paper's structural distinction.  Because the fix
//! equations recover the corrected alignment exactly, the two paths are
//! **bit-identical**; `tests/prop_arith.rs` enforces this over random and
//! adversarial chains, and the cycle-level models in [`crate::pe`] reuse
//! these steps inside their stage registers.

use super::format::FpFormat;
use super::lza::lzc;
use super::softfloat::{exact_product, ExactProduct, Special};

/// Configuration of a reduction chain: input element format, output/
/// accumulation format, and the accumulator significand window width.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChainCfg {
    /// Format of the streamed inputs and the stationary weights.
    pub in_fmt: FpFormat,
    /// Format the column rounds to at the South edge (double-width per the
    /// paper: FP32 for Bfloat16 inputs).
    pub out_fmt: FpFormat,
    /// Accumulator/adder significand width in bits (hidden bit included).
    /// Must satisfy `window ≥ 2·in_fmt.man_bits + 4` (raw product fits)
    /// and `window ≥ out_fmt.man_bits + 4` (rounding has G/R/S headroom).
    pub window: u32,
}

impl ChainCfg {
    /// The paper's evaluation configuration: Bfloat16 inputs reduced in
    /// FP32 (§IV), with a 28-bit adder window (24-bit FP32 significand +
    /// 3 G/R/S positions + 1 carry headroom bit).
    pub const BF16_FP32: ChainCfg =
        ChainCfg { in_fmt: FpFormat::BF16, out_fmt: FpFormat::FP32, window: 28 };

    /// Construct a chain config with the canonical window for the pair.
    pub fn new(in_fmt: FpFormat, out_fmt: FpFormat) -> ChainCfg {
        let window = (2 * in_fmt.man_bits + 4).max(out_fmt.man_bits + 4);
        ChainCfg { in_fmt, out_fmt, window }
    }

    /// Validate width invariants (called by constructors of the PE models).
    pub fn check(&self) {
        assert!(self.window <= 60, "window too wide for u64 arithmetic");
        assert!(self.window >= 2 * self.in_fmt.man_bits + 4, "product does not fit window");
        assert!(self.window >= self.out_fmt.man_bits + 4, "no rounding headroom");
    }
}

/// A fixed-point *window value*: magnitude `sig` occupying `window` bits
/// whose top bit (index `window−1`) has unbiased weight `exp_top`, plus a
/// sticky bit recording any magnitude lost below the window.
///
/// `sig == 0 && !sticky` is exact zero; `exp_top` is then meaningless and
/// kept at 0 canonically.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WindowVal {
    pub sign: bool,
    pub exp_top: i32,
    pub sig: u64,
    pub sticky: bool,
}

impl WindowVal {
    /// Exact +0.
    pub const ZERO: WindowVal = WindowVal { sign: false, exp_top: 0, sig: 0, sticky: false };

    /// True iff the magnitude window is empty (sticky may still be set
    /// after catastrophic cancellation of previously-lost bits).
    pub fn sig_zero(&self) -> bool {
        self.sig == 0
    }

    /// The represented magnitude-with-sign as f64, given the window width
    /// (exact when the magnitude fits f64; `sticky` contributes nothing —
    /// callers that care check it separately).  Test/diagnostic helper.
    pub fn value_f64(&self, window: u32) -> f64 {
        use super::softfloat::pow2;
        if self.sig == 0 {
            return if self.sign { -0.0 } else { 0.0 };
        }
        let mut x = 0.0;
        for k in 0..64u32 {
            if (self.sig >> k) & 1 == 1 {
                x += pow2(self.exp_top - (window as i32 - 1 - k as i32));
            }
        }
        if self.sign {
            -x
        } else {
            x
        }
    }

    /// Re-express the value with the window top at weight `new_top`,
    /// shifting the significand and folding lost bits into sticky.
    /// A *left* shift (new_top < exp_top) asserts the required leading
    /// zeros exist — in the datapaths this is exactly the ≤ `L` left
    /// normalization shift of Fig. 6.
    #[inline]
    pub fn reexpress(&self, window: u32, new_top: i32) -> WindowVal {
        if self.sig == 0 {
            return WindowVal { sign: self.sign, exp_top: new_top, sig: 0, sticky: self.sticky };
        }
        let mut v = *self;
        if new_top >= v.exp_top {
            // Right alignment shift: bits falling off the window bottom
            // fold into the sticky flag (kept *separate* from the window
            // bits, unlike `shift_right_sticky` which ORs into bit 0).
            let d = (new_top - self.exp_top) as u32;
            if d >= 64 {
                v.sig = 0;
                v.sticky = self.sticky || self.sig != 0;
            } else if d > 0 {
                let lost = self.sig & ((1u64 << d) - 1);
                v.sig = self.sig >> d;
                v.sticky = self.sticky || lost != 0;
            }
        } else {
            let up = (v.exp_top - new_top) as u32;
            debug_assert!(
                lzc(v.sig, window) >= up,
                "left re-express would drop MSBs (lzc={} up={up})",
                lzc(v.sig, window)
            );
            v.sig <<= up;
        }
        v.exp_top = new_top;
        v
    }
}

/// Magnitude add/sub of two window values already expressed at the same
/// `exp_top` (the adder of either pipeline's stage 2).  Returns the raw,
/// **unnormalized** result plus its leading-zero count — precisely the
/// adder + LZA pair of the paper's Fig. 3/5/6.  A carry-out renormalizes
/// by one position (folding the shifted-out bit into sticky).
#[inline]
pub fn add_same_top(cfg: &ChainCfg, x: WindowVal, y: WindowVal) -> (WindowVal, u32) {
    debug_assert!(x.sig == 0 || y.sig == 0 || x.exp_top == y.exp_top, "operands not aligned");
    let w = cfg.window;
    let top = if x.sig != 0 { x.exp_top } else { y.exp_top };
    let (sign, sig, sticky);
    if x.sign == y.sign {
        let mut s = x.sig + y.sig;
        let mut st = x.sticky || y.sticky;
        let mut t = top;
        if s >> w != 0 {
            let lost = s & 1;
            s >>= 1;
            st |= lost != 0;
            t += 1;
        }
        let out = WindowVal { sign: x.sign, exp_top: t, sig: s, sticky: st };
        let l = lzc(out.sig, w);
        return (out, l);
    } else {
        // Effective subtraction: subtract the smaller magnitude.  A sticky
        // bit on the subtrahend borrows one ULP from the difference and
        // leaves a non-zero fraction below the window (standard G/R/S
        // subtract semantics).
        let (hi, lo) = if x.sig >= y.sig { (x, y) } else { (y, x) };
        if hi.sig == lo.sig && hi.sticky == lo.sticky {
            // Exact cancellation (or equal-with-equal-sticky: the lost
            // fractions are unknowable; hardware emits zero + sticky).
            let st = hi.sticky;
            let out = WindowVal { sign: false, exp_top: top, sig: 0, sticky: st };
            return (out, w);
        }
        sign = hi.sign;
        if lo.sticky && !hi.sticky {
            if hi.sig == lo.sig {
                // hi − (lo + δ) < 0: the subtrahend's fraction flips the
                // sign; magnitude is the sub-window fraction itself.
                let out = WindowVal { sign: lo.sign, exp_top: top, sig: 0, sticky: true };
                return (out, w);
            }
            sig = hi.sig - lo.sig - 1;
            sticky = true;
        } else {
            sig = hi.sig - lo.sig;
            sticky = hi.sticky || lo.sticky;
        }
        if sig == 0 && !sticky {
            let out = WindowVal { sign: false, exp_top: top, sig: 0, sticky: false };
            return (out, w);
        }
        let out = WindowVal { sign, exp_top: top, sig, sticky };
        let l = lzc(sig, w);
        (out, l)
    }
}

/// Place an exact mantissa product into the window: the product's nominal
/// `2^1` position (products of normals lie in `[1, 4)`) lands at the
/// window top, so `exp_top = e_M + 1`.  Lossless by the `ChainCfg::check`
/// width invariant.
#[inline]
pub fn product_to_window(cfg: &ChainCfg, p: &ExactProduct) -> WindowVal {
    if p.zero {
        return WindowVal { sign: p.sign, ..WindowVal::ZERO };
    }
    let up = cfg.window - 2 - p.frac_bits;
    WindowVal { sign: p.sign, exp_top: p.exp + 1, sig: p.sig << up, sticky: false }
}

/// The partial-sum bundle that physically flows from one PE to the next
/// in a column (South direction).
///
/// * Baseline (Fig. 3b): `val` is **normalized** (MSB at the window top or
///   zero) and `lza == 0`; `val.exp_top` is the corrected exponent
///   `e_i = ê_i − L_i`.
/// * Skewed (Figs. 5/6): `val` is the **raw adder output** — unnormalized,
///   `val.exp_top` is the speculative `ê_i`, and `lza` carries `L_i` for
///   the next PE's fix logic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PsumSignal {
    pub val: WindowVal,
    /// `L_i` — leading-zero count of `val.sig` in the window; maintained
    /// as a *separate physical signal* because the skewed pipeline
    /// forwards it in place of pre-normalizing (`lza == lzc(sig)` is an
    /// invariant checked in debug builds).
    pub lza: u32,
    pub special: Special,
}

impl PsumSignal {
    /// Chain seed: exact +0 (a column starts from zero partial sum).
    pub fn zero(cfg: &ChainCfg) -> PsumSignal {
        PsumSignal { val: WindowVal::ZERO, lza: cfg.window, special: Special::None }
    }

    /// Corrected (normalized-reference) exponent of the window top:
    /// `e = ê − L`.  Meaningful only for non-zero magnitudes.
    pub fn corrected_top(&self) -> i32 {
        self.val.exp_top - self.lza as i32
    }
}

/// Common interface of the two chained datapaths: one multiply-add step
/// (`psum_out = psum_in + a×w`) at the value level.  The cycle-level PE
/// models wrap these steps in stage registers.
pub trait ChainDatapath {
    /// Human-readable datapath name for reports.
    fn name(&self) -> &'static str;

    /// Execute one chained multiply-add step.
    fn step(&self, cfg: &ChainCfg, psum: &PsumSignal, a_bits: u64, w_bits: u64) -> PsumSignal;

    /// Whether the forwarded partial sums are normalized (baseline) or
    /// raw/speculative (skewed) — drives the rounding unit's final fix.
    fn forwards_normalized(&self) -> bool;
}

/// Merge the special-value state of a product and an accumulating sum
/// (IEEE semantics, resolved at the value level — see DESIGN.md §7).
fn merge_step_special(
    cfg: &ChainCfg,
    psum: &PsumSignal,
    a_bits: u64,
    w_bits: u64,
) -> (Special, super::format::Unpacked, super::format::Unpacked) {
    let a = cfg.in_fmt.decode(a_bits);
    let w = cfg.in_fmt.decode(w_bits);
    (psum.special.merge_product(&a, &w), a, w)
}

/// Fast path for the overwhelmingly common case: both operands are
/// *normal* finite numbers, whose product needs no class analysis, no
/// subnormal renormalization, and cannot change the chain's special
/// state.  Returns `None` for anything else (zero, subnormal, special,
/// E4M3 top-exponent finites) — the caller falls back to the exact
/// decode path.  §Perf iteration 3: the full decode pair was ~25% of
/// the coordinator's numeric hot loop.
#[inline]
pub(crate) fn fast_normal_product(fmt: FpFormat, a: u64, b: u64) -> Option<ExactProduct> {
    let em = fmt.exp_field_max() as u64;
    let mb = fmt.man_bits;
    let ea = (a >> mb) & em;
    let eb = (b >> mb) & em;
    if ea == 0 || eb == 0 || ea == em || eb == em {
        return None;
    }
    let frac_mask = (1u64 << mb) - 1;
    let fa = (1u64 << mb) | (a & frac_mask);
    let fb = (1u64 << mb) | (b & frac_mask);
    Some(ExactProduct {
        sign: ((a ^ b) >> (fmt.width() - 1)) & 1 == 1,
        exp: ea as i32 + eb as i32 - 2 * fmt.bias(),
        sig: fa * fb,
        frac_bits: 2 * mb,
        zero: false,
    })
}

/// Shared operand stage: produce the (special-state, product-window)
/// pair, or the early-out passthrough signal for non-finite operands.
#[inline]
pub(crate) fn step_operands(
    cfg: &ChainCfg,
    psum: &PsumSignal,
    a_bits: u64,
    w_bits: u64,
) -> Result<(Special, WindowVal), PsumSignal> {
    if let Some(p) = fast_normal_product(cfg.in_fmt, a_bits, w_bits) {
        return Ok((psum.special, product_to_window(cfg, &p)));
    }
    step_operands_slow(cfg, psum, a_bits, w_bits)
}

/// Outlined slow path: zeros, subnormals, specials, E4M3 top-exponent
/// finites.  Kept out of the hot loop's instruction stream.
#[cold]
#[inline(never)]
fn step_operands_slow(
    cfg: &ChainCfg,
    psum: &PsumSignal,
    a_bits: u64,
    w_bits: u64,
) -> Result<(Special, WindowVal), PsumSignal> {
    let (special, a, w) = merge_step_special(cfg, psum, a_bits, w_bits);
    if !(a.is_finite() && w.is_finite()) {
        return Err(PsumSignal { val: psum.val, lza: psum.lza, special });
    }
    let p = exact_product(cfg.in_fmt, &a, cfg.in_fmt, &w);
    Ok((special, product_to_window(cfg, &p)))
}

// ---------------------------------------------------------------------------
// Baseline: the state-of-the-art reduced-precision pipeline of Fig. 3(b).
// ---------------------------------------------------------------------------

/// Fig. 3(b): stage 1 = multiply ∥ exponent compute (against the
/// *corrected* incoming exponent); stage 2 = align + add + LZA + normalize.
/// Forwards a normalized partial sum.  Chain spacing between consecutive
/// PEs is 2 cycles (the serialization problem of §III-A).
#[derive(Clone, Copy, Debug, Default)]
pub struct BaselineFmaPath;

impl ChainDatapath for BaselineFmaPath {
    fn name(&self) -> &'static str {
        "baseline-3b"
    }

    fn forwards_normalized(&self) -> bool {
        true
    }

    fn step(&self, cfg: &ChainCfg, psum: &PsumSignal, a_bits: u64, w_bits: u64) -> PsumSignal {
        debug_assert!(psum.val.sig == 0 || psum.lza == 0, "baseline expects normalized input");
        // ---- stage 1: multiplier ∥ exponent compute --------------------
        let (special, pwin) = match step_operands(cfg, psum, a_bits, w_bits) {
            Ok(v) => v,
            Err(passthrough) => return passthrough,
        };
        baseline_combine(cfg, psum, special, pwin)
    }
}

/// Baseline stage 1 (exponent compare) + stage 2 (align/add/LZA/
/// normalize) after the operand stage resolved the product window:
/// the shared tail of [`BaselineFmaPath::step`], factored out so the
/// monomorphized kernels in [`crate::arith::kernel`] can reuse it
/// verbatim (bit-identity by construction, not by re-derivation).
#[inline]
pub(crate) fn baseline_combine(
    cfg: &ChainCfg,
    psum: &PsumSignal,
    special: Special,
    pwin: WindowVal,
) -> PsumSignal {
    // ê_i = max(e_Mi, e_{i−1}); d_i = |e_Mi − e_{i−1}| (§III-B, the
    // non-speculative originals).
    let e_hat = match (pwin.sig != 0, psum.val.sig != 0) {
        (false, false) => 0,
        (true, false) => pwin.exp_top,
        (false, true) => psum.val.exp_top,
        (true, true) => pwin.exp_top.max(psum.val.exp_top),
    };

    // ---- stage 2: align + add + LZA + normalize --------------------
    let xa = pwin.reexpress(cfg.window, e_hat);
    let ya = psum.val.reexpress(cfg.window, e_hat);
    let (sum, l) = add_same_top(cfg, xa, ya);
    // Normalize: shift left by L, correct the exponent e_i = ê_i − L_i.
    let out = if sum.sig == 0 {
        WindowVal { sign: sum.sign, exp_top: sum.exp_top, sig: 0, sticky: sum.sticky }
    } else {
        let norm_top = sum.exp_top - l as i32;
        sum.reexpress(cfg.window, norm_top)
    };
    PsumSignal { val: out, lza: if out.sig == 0 { cfg.window } else { 0 }, special }
}

// ---------------------------------------------------------------------------
// Skewed: the proposed pipeline of Figs. 5/6.
// ---------------------------------------------------------------------------

/// Figs. 5/6: stage 1 computes the multiplication and the **speculative**
/// exponent compare against `ê_{i−1}`; stage 2's fix logic corrects the
/// alignment with the now-available `L_{i−1}` and merges the incoming
/// sum's normalization into the alignment shift (retimed normalization).
/// Forwards the raw adder output + `ê_i` + `L_i`.  Chain spacing is 1.
#[derive(Clone, Copy, Debug, Default)]
pub struct SkewedFmaPath;

impl ChainDatapath for SkewedFmaPath {
    fn name(&self) -> &'static str {
        "skewed"
    }

    fn forwards_normalized(&self) -> bool {
        false
    }

    fn step(&self, cfg: &ChainCfg, psum: &PsumSignal, a_bits: u64, w_bits: u64) -> PsumSignal {
        debug_assert!(
            psum.val.sig == 0 || psum.lza == lzc(psum.val.sig, cfg.window),
            "forwarded L_i does not match the unnormalized sum"
        );
        // ---- stage 1: multiplier ∥ *speculative* exponent compute ------
        let (special, pwin) = match step_operands(cfg, psum, a_bits, w_bits) {
            Ok(v) => v,
            Err(passthrough) => return passthrough,
        };
        skewed_combine(cfg, psum, special, pwin)
    }
}

/// Skewed stage 1 (speculative compare) + stage 2 (fix + merged
/// align/normalize + add) after the operand stage resolved the product
/// window: the shared tail of [`SkewedFmaPath::step`], factored out for
/// the monomorphized kernels in [`crate::arith::kernel`].
#[inline]
pub(crate) fn skewed_combine(
    cfg: &ChainCfg,
    psum: &PsumSignal,
    special: Special,
    pwin: WindowVal,
) -> PsumSignal {
    // e′_i = max(e_Mi, ê_{i−1}), d′_i = e_Mi − ê_{i−1}: computed from
    // the UNnormalized incoming exponent — these are speculative.
    let in_zero = psum.val.sig == 0;
    let d_spec: i32 = if in_zero || pwin.sig == 0 {
        0
    } else {
        pwin.exp_top - psum.val.exp_top
    };

    // ---- stage 2: Fix Sign & Exponent + merged align/normalize -----
    // L_{i−1} arrives from the previous PE; the fix recovers the true
    // alignment:  d_i = d′_i + L_{i−1}  (signed form of the paper's
    // two-case |·| split), i.e. the corrected incoming exponent is
    // ê_{i−1} − L_{i−1}.
    let l_in = psum.lza as i32;
    let (sum, l) = if pwin.sig == 0 && in_zero {
        // Both magnitudes empty: only sticky residue (if any) flows on.
        (
            WindowVal { sign: false, exp_top: 0, sig: 0, sticky: psum.val.sticky },
            cfg.window,
        )
    } else {
        // Common alignment target from the fix equations.  For live
        // operands: max of product top and the *corrected* incoming
        // top (d_i = d′_i + L_{i−1}); the retimed shifter moves the
        // incoming sum LEFT by up to L_{i−1} (normalization) or RIGHT
        // (alignment); only one direction fires (Fig. 6).  When one
        // magnitude is zero the other's reference wins — but the add
        // still runs, so a zero-with-sticky operand borrows exactly
        // as in the baseline adder (bit-identity demands it).
        let t = match (pwin.sig != 0, !in_zero) {
            (true, true) => {
                let d_fixed = d_spec + l_in; // e_M_top − corrected_in_top
                let in_corr_top = psum.val.exp_top - l_in;
                if d_fixed >= 0 {
                    pwin.exp_top
                } else {
                    in_corr_top
                }
            }
            (true, false) => pwin.exp_top,
            // Zero product: keep the incoming raw reference (no shift
            // of the unnormalized sum — a pure adder passthrough).
            (false, true) => psum.val.exp_top,
            (false, false) => unreachable!(),
        };
        let xa = pwin.reexpress(cfg.window, t);
        let ya = psum.val.reexpress(cfg.window, t);
        add_same_top(cfg, xa, ya)
    };
    // Forward the raw adder output; ê_i = sum.exp_top, plus L_i for
    // the next PE's fix logic.  No normalization happens here — that
    // is the whole point.
    PsumSignal { val: sum, lza: l, special }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::softfloat::{pow2, ExactChain};
    use crate::util::rng::Rng;

    const CFG: ChainCfg = ChainCfg::BF16_FP32;

    fn bf(x: f64) -> u64 {
        FpFormat::BF16.from_f64(x)
    }

    /// Run a full chain through a datapath and return the final signal.
    fn run_chain<D: ChainDatapath>(d: &D, terms: &[(u64, u64)]) -> PsumSignal {
        let mut s = PsumSignal::zero(&CFG);
        for &(a, w) in terms {
            s = d.step(&CFG, &s, a, w);
        }
        s
    }

    /// Normalize a signal for comparison (the skewed path forwards raw
    /// sums; value equality is what bit-identity means at chain end).
    fn canon(cfg: &ChainCfg, s: &PsumSignal) -> (bool, i32, u64, bool, Special) {
        if s.val.sig == 0 {
            return (false, 0, 0, s.val.sticky, s.special);
        }
        let l = lzc(s.val.sig, cfg.window);
        (
            s.val.sign,
            s.val.exp_top - l as i32,
            s.val.sig << l,
            s.val.sticky,
            s.special,
        )
    }

    #[test]
    fn single_step_matches_plain_product() {
        for d in [&BaselineFmaPath as &dyn ChainDatapath, &SkewedFmaPath] {
            let s = run_chain_dyn(d, &[(bf(3.0), bf(5.0))]);
            assert_eq!(s.val.value_f64(CFG.window), 15.0, "{}", d.name());
        }
    }

    fn run_chain_dyn(d: &dyn ChainDatapath, terms: &[(u64, u64)]) -> PsumSignal {
        let mut s = PsumSignal::zero(&CFG);
        for &(a, w) in terms {
            s = d.step(&CFG, &s, a, w);
        }
        s
    }

    #[test]
    fn two_paths_bit_identical_small_chain() {
        let terms: Vec<(u64, u64)> =
            [(1.5, 2.0), (-0.5, 4.0), (3.0, 0.125), (7.0, -1.0), (0.0, 9.0)]
                .iter()
                .map(|&(a, w)| (bf(a), bf(w)))
                .collect();
        let b = run_chain(&BaselineFmaPath, &terms);
        let s = run_chain(&SkewedFmaPath, &terms);
        assert_eq!(canon(&CFG, &b), canon(&CFG, &s));
    }

    #[test]
    fn two_paths_bit_identical_random_chains() {
        let mut rng = Rng::new(0xfaded);
        for chain in 0..300 {
            let len = 1 + (chain % 64);
            let terms: Vec<(u64, u64)> = (0..len)
                .map(|_| (rng.bits(16), rng.bits(16)))
                .filter(|&(a, w)| {
                    // Finite inputs only here; specials are covered below.
                    let fa = FpFormat::BF16.decode(a);
                    let fw = FpFormat::BF16.decode(w);
                    fa.is_finite() && fw.is_finite()
                })
                .collect();
            let b = run_chain(&BaselineFmaPath, &terms);
            let s = run_chain(&SkewedFmaPath, &terms);
            assert_eq!(canon(&CFG, &b), canon(&CFG, &s), "chain {chain}");
        }
    }

    #[test]
    fn adversarial_cancellation_chains_identical() {
        // x − x + tiny, huge + tiny − huge, alternating magnitudes: the
        // cases where speculative alignment would go wrong without the fix.
        let cases: &[&[(f64, f64)]] = &[
            &[(1.0, 1.0), (-1.0, 1.0), (1.0, pow2(-20))],
            &[(pow2(60), 1.0), (1.0, pow2(-60)), (-1.0, pow2(60))],
            &[(1.0, 1.0), (1.0, pow2(-8)), (-1.0, 1.0), (-1.0, pow2(-8))],
            &[(3.0, 3.0), (-9.0, 1.0), (pow2(-30), pow2(-30))],
            &[(1.0, pow2(-14)), (1.0, 1.0), (-1.0, 1.0)],
        ];
        for (i, case) in cases.iter().enumerate() {
            let terms: Vec<(u64, u64)> = case.iter().map(|&(a, w)| (bf(a), bf(w))).collect();
            let b = run_chain(&BaselineFmaPath, &terms);
            let s = run_chain(&SkewedFmaPath, &terms);
            assert_eq!(canon(&CFG, &b), canon(&CFG, &s), "case {i}");
        }
    }

    #[test]
    fn matches_exact_chain_when_no_alignment_loss() {
        // Integer-valued bf16 inputs with small exponent spread: the
        // window never drops bits, so the datapaths equal the exact oracle.
        let mut rng = Rng::new(7);
        for _ in 0..100 {
            let len = 1 + rng.below(32);
            let mut exact = ExactChain::new();
            let mut terms = Vec::new();
            for _ in 0..len {
                let a = rng.range_i64(-16, 16) as f64;
                let w = rng.range_i64(-8, 8) as f64;
                terms.push((bf(a), bf(w)));
                exact.mac(FpFormat::BF16, bf(a), bf(w));
            }
            for d in [&BaselineFmaPath as &dyn ChainDatapath, &SkewedFmaPath] {
                let s = run_chain_dyn(d, &terms);
                assert_eq!(
                    s.val.value_f64(CFG.window),
                    exact.value_f64(),
                    "{} len={len}",
                    d.name()
                );
                assert!(!s.val.sticky);
            }
        }
    }

    #[test]
    fn speculative_exponent_really_is_speculative() {
        // After a cancelling step the skewed forward exponent ê must
        // exceed the corrected exponent by L (i.e. speculation happened).
        let terms = [(bf(1.0), bf(1.0)), (bf(-1.0), bf(1.0 + pow2(-7)))];
        let s = run_chain(&SkewedFmaPath, &terms);
        assert!(s.lza > 0, "expected leading zeros after cancellation");
        let b = run_chain(&BaselineFmaPath, &terms);
        assert_eq!(s.corrected_top(), b.val.exp_top);
    }

    #[test]
    fn specials_flow_identically() {
        let f = FpFormat::BF16;
        let inf = f.inf_bits();
        let one = bf(1.0);
        for d in [&BaselineFmaPath as &dyn ChainDatapath, &SkewedFmaPath] {
            let s = run_chain_dyn(d, &[(one, one), (inf, one)]);
            assert_eq!(s.special, Special::Inf(false), "{}", d.name());
            let n = run_chain_dyn(d, &[(inf, one), ((1 << 15) | inf, one)]);
            assert_eq!(n.special, Special::Nan, "{}", d.name());
            let z = run_chain_dyn(d, &[(bf(0.0), inf)]);
            assert_eq!(z.special, Special::Nan, "{}", d.name());
        }
    }

    #[test]
    fn zero_product_passthrough_preserves_lza() {
        // A zero product must not disturb the forwarded ê/L pair.
        let terms = [(bf(1.0), bf(1.0)), (bf(-1.0), bf(1.0 + pow2(-7)))];
        let s1 = run_chain(&SkewedFmaPath, &terms);
        let s2 = SkewedFmaPath.step(&CFG, &s1, bf(0.0), bf(123.0));
        assert_eq!(s1.val, s2.val);
        assert_eq!(s1.lza, s2.lza);
    }

    #[test]
    fn window_sticky_set_on_alignment_loss() {
        // 2^20 + 2^-20: the small product falls off the 28-bit window.
        let terms = [(bf(pow2(10)), bf(pow2(10))), (bf(pow2(-10)), bf(pow2(-10)))];
        for d in [&BaselineFmaPath as &dyn ChainDatapath, &SkewedFmaPath] {
            let s = run_chain_dyn(d, &terms);
            assert!(s.val.sticky, "{}", d.name());
            assert_eq!(s.val.value_f64(CFG.window), pow2(20), "{}", d.name());
        }
    }

    #[test]
    fn chain_cfg_check_bounds() {
        ChainCfg::BF16_FP32.check();
        ChainCfg::new(FpFormat::FP16, FpFormat::FP32).check();
        ChainCfg::new(FpFormat::FP8E4M3, FpFormat::FP16).check();
        ChainCfg::new(FpFormat::FP8E5M2, FpFormat::BF16).check();
    }

    #[test]
    fn add_same_top_subtract_with_sticky_borrows() {
        let cfg = CFG;
        let x = WindowVal { sign: false, exp_top: 0, sig: 0b1000 << 20, sticky: false };
        let y = WindowVal { sign: true, exp_top: 0, sig: 0b0100 << 20, sticky: true };
        let (r, _) = add_same_top(&cfg, x, y);
        // (8<<20) − ((4<<20) + δ), 0 < δ < 1 window-ULP: the borrow fires
        // at the window LSB → sig = (4<<20) − 1, sticky set.
        assert_eq!(r.sig, (0b0100 << 20) - 1);
        assert!(r.sticky);
        assert!(!r.sign);
    }
}
