//! Column-edge accumulation semantics: the South-edge rounding unit and
//! the value-level column oracle.
//!
//! Per the paper (§II), state-of-the-art SA datapaths do **not** round
//! after each multiply-add step; intermediate partial sums flow in
//! double-width precision and a single normalize + round happens once per
//! column at the South edge.  In the skewed design the final exponent
//! correction (the last PE's `ê`/`L` pair) also lands here, folded into
//! the rounding stage (§III-B, last paragraph).

use super::fma::{BaselineFmaPath, ChainCfg, ChainDatapath, PsumSignal};
use super::format::FpFormat;
use super::lza::lzc;
use super::softfloat::{round_magnitude_rne, Special};

/// The per-column rounding unit at the South edge: final exponent fix,
/// normalization, and one round-to-nearest-even into the output format.
#[derive(Clone, Copy, Debug)]
pub struct RoundingUnit {
    pub cfg: ChainCfg,
}

impl RoundingUnit {
    pub fn new(cfg: ChainCfg) -> Self {
        cfg.check();
        RoundingUnit { cfg }
    }

    /// Round a final partial-sum signal to the output format.  Accepts
    /// both normalized (baseline) and raw/unnormalized (skewed) signals —
    /// the normalization shift here *is* the skewed design's deferred
    /// final fix, and is a no-op for already-normalized inputs.
    pub fn round(&self, s: &PsumSignal) -> u64 {
        let fmt = self.cfg.out_fmt;
        match s.special {
            Special::Nan => fmt.nan_bits(),
            Special::Inf(neg) => ((neg as u64) << (fmt.width() - 1)) | fmt.inf_bits(),
            Special::None => {
                if s.val.sig == 0 {
                    // All-cancelled (possibly with sticky residue below
                    // the window: magnitude < one window ULP → rounds to
                    // zero in any sane output format).
                    return (s.val.sign as u64) << (fmt.width() - 1);
                }
                let l = lzc(s.val.sig, self.cfg.window);
                debug_assert!(
                    s.lza == l || s.lza == 0,
                    "stale L forwarded to the rounding unit"
                );
                let window = s.val.sig << l;
                let exp_msb = s.val.exp_top - l as i32;
                round_magnitude_rne(
                    fmt,
                    s.val.sign,
                    exp_msb,
                    window,
                    self.cfg.window - 1,
                    s.val.sticky,
                )
            }
        }
    }

    /// Round to f32 directly (valid only when `out_fmt` is FP32; the
    /// common convenience on the bf16→fp32 evaluation path).
    pub fn round_f32(&self, s: &PsumSignal) -> f32 {
        debug_assert_eq!(self.cfg.out_fmt, FpFormat::FP32);
        f32::from_bits(self.round(s) as u32)
    }
}

/// Value-level column oracle: the *hardware-exact* reference a cycle-
/// accurate column must reproduce bit-for-bit.  It runs the baseline
/// datapath steps sequentially (which the property suite proves identical
/// to the skewed steps) and rounds once at the end — i.e. it captures the
/// paper's numeric semantics with none of the pipeline timing.
#[derive(Clone, Debug)]
pub struct ColumnOracle {
    cfg: ChainCfg,
    state: PsumSignal,
    steps: usize,
}

impl ColumnOracle {
    pub fn new(cfg: ChainCfg) -> Self {
        cfg.check();
        ColumnOracle { cfg, state: PsumSignal::zero(&cfg), steps: 0 }
    }

    /// Feed one `a × w` term (raw bit patterns in `cfg.in_fmt`).
    pub fn mac(&mut self, a_bits: u64, w_bits: u64) {
        self.state = BaselineFmaPath.step(&self.cfg, &self.state, a_bits, w_bits);
        self.steps += 1;
    }

    /// Feed a whole slice of `a × w` terms through the monomorphized
    /// per-format kernel — bit-identical to calling [`ColumnOracle::mac`]
    /// element-wise, with the format dispatch hoisted out of the loop.
    pub fn mac_slice(&mut self, a_bits: &[u64], w_bits: &[u64]) {
        self.state = super::kernel::mac_slice(&self.cfg, &self.state, a_bits, w_bits);
        self.steps += a_bits.len();
    }

    /// Number of terms accumulated so far.
    pub fn len(&self) -> usize {
        self.steps
    }

    pub fn is_empty(&self) -> bool {
        self.steps == 0
    }

    /// The current (pre-rounding) partial-sum signal.
    pub fn signal(&self) -> &PsumSignal {
        &self.state
    }

    /// Final rounded output bits in `cfg.out_fmt`.
    pub fn result(&self) -> u64 {
        RoundingUnit::new(self.cfg).round(&self.state)
    }

    /// Final output as f32 (bf16→fp32 evaluation path convenience).
    pub fn result_f32(&self) -> f32 {
        RoundingUnit::new(self.cfg).round_f32(&self.state)
    }

    /// Reset to an empty chain (weight-tile switch).
    pub fn reset(&mut self) {
        self.state = PsumSignal::zero(&self.cfg);
        self.steps = 0;
    }

    /// Merge another column-oracle partial sum into this one in the wide
    /// (pre-rounding) domain — the South-edge K-pass accumulator used by
    /// the tiled GEMM path, which keeps "round once per output" semantics
    /// across weight-tile passes.
    pub fn merge(&mut self, other: &ColumnOracle) {
        use super::fma::add_same_top;
        assert_eq!(self.cfg, other.cfg);
        self.state.special = match (self.state.special, other.state.special) {
            (Special::Nan, _) | (_, Special::Nan) => Special::Nan,
            (Special::Inf(a), Special::Inf(b)) if a != b => Special::Nan,
            (Special::Inf(a), _) | (_, Special::Inf(a)) => Special::Inf(a),
            _ => Special::None,
        };
        // Align both wide values to the max corrected top and add.
        let (x, y) = (self.state.val, other.state.val);
        let merged = match (x.sig != 0, y.sig != 0) {
            (false, false) => {
                let mut z = x;
                z.sticky |= y.sticky;
                (z, self.cfg.window)
            }
            (true, false) => {
                let mut z = x;
                z.sticky |= y.sticky;
                (z, lzc(z.sig, self.cfg.window))
            }
            (false, true) => {
                let mut z = y;
                z.sticky |= x.sticky;
                (z, lzc(z.sig, self.cfg.window))
            }
            (true, true) => {
                let xt = x.exp_top - lzc(x.sig, self.cfg.window) as i32;
                let yt = y.exp_top - lzc(y.sig, self.cfg.window) as i32;
                let t = xt.max(yt);
                add_same_top(
                    &self.cfg,
                    x.reexpress(self.cfg.window, t),
                    y.reexpress(self.cfg.window, t),
                )
            }
        };
        self.state.val = merged.0;
        self.state.lza = merged.1;
        self.steps += other.steps;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::softfloat::{pow2, ExactChain};
    use crate::util::rng::Rng;

    const CFG: ChainCfg = ChainCfg::BF16_FP32;

    fn bf(x: f64) -> u64 {
        FpFormat::BF16.from_f64(x)
    }

    #[test]
    fn oracle_small_chain_matches_f64() {
        let mut o = ColumnOracle::new(CFG);
        let mut want = 0.0f64;
        for &(a, w) in &[(1.5, 2.0), (-0.5, 4.0), (3.0, 0.125)] {
            o.mac(bf(a), bf(w));
            want += FpFormat::BF16.to_f64(bf(a)) * FpFormat::BF16.to_f64(bf(w));
        }
        assert_eq!(o.result_f32() as f64, want);
        assert_eq!(o.len(), 3);
    }

    #[test]
    fn oracle_matches_exact_chain_round_for_random_columns() {
        // The window keeps ≥ 24 significant bits and rounds once — for
        // columns whose exact sum fits 24 bits after alignment, the
        // oracle's fp32 result equals the exact chain's single rounding.
        let mut rng = Rng::new(0xabc);
        for _ in 0..200 {
            let len = 1 + rng.below(128) as usize;
            let mut o = ColumnOracle::new(CFG);
            let mut e = ExactChain::new();
            for _ in 0..len {
                let a = bf(rng.range_i64(-32, 32) as f64);
                let w = bf(rng.range_i64(-32, 32) as f64);
                o.mac(a, w);
                e.mac(FpFormat::BF16, a, w);
            }
            assert_eq!(o.result(), e.result(FpFormat::FP32), "len={len}");
        }
    }

    #[test]
    fn rounding_unit_handles_unnormalized_skewed_signals() {
        use crate::arith::fma::SkewedFmaPath;
        let mut s = PsumSignal::zero(&CFG);
        for &(a, w) in &[(1.0, 1.0), (-1.0, 1.0 + pow2(-7)), (2.0, 3.0)] {
            s = SkewedFmaPath.step(&CFG, &s, bf(a), bf(w));
        }
        let ru = RoundingUnit::new(CFG);
        let got = ru.round_f32(&s) as f64;
        let want = 1.0 - (1.0 + pow2(-7)) + 6.0;
        assert_eq!(got, want);
    }

    #[test]
    fn rounding_specials() {
        let ru = RoundingUnit::new(CFG);
        let mut s = PsumSignal::zero(&CFG);
        s.special = Special::Nan;
        assert!(ru.round_f32(&s).is_nan());
        s.special = Special::Inf(true);
        assert_eq!(ru.round_f32(&s), f32::NEG_INFINITY);
        s.special = Special::Inf(false);
        assert_eq!(ru.round_f32(&s), f32::INFINITY);
    }

    #[test]
    fn rounding_zero_and_sticky_residue() {
        let ru = RoundingUnit::new(CFG);
        let z = PsumSignal::zero(&CFG);
        assert_eq!(ru.round_f32(&z), 0.0);
        let mut s = PsumSignal::zero(&CFG);
        s.val.sticky = true; // sub-window residue only
        assert_eq!(ru.round_f32(&s), 0.0);
    }

    #[test]
    fn rounding_overflow_to_inf() {
        // bf16 can hold values whose *sum* exceeds fp32 max.
        let mut o = ColumnOracle::new(CFG);
        let big = bf(pow2(120));
        for _ in 0..4 {
            o.mac(big, big); // 4 × 2^240 ≫ fp32 max
        }
        assert_eq!(o.result_f32(), f32::INFINITY);
    }

    #[test]
    fn merge_equals_unsplit_chain() {
        let mut rng = Rng::new(99);
        for _ in 0..100 {
            let n1 = 1 + rng.below(32) as usize;
            let n2 = 1 + rng.below(32) as usize;
            let terms: Vec<(u64, u64)> = (0..n1 + n2)
                .map(|_| (bf(rng.range_i64(-16, 16) as f64), bf(rng.range_i64(-8, 8) as f64)))
                .collect();
            let mut whole = ColumnOracle::new(CFG);
            for &(a, w) in &terms {
                whole.mac(a, w);
            }
            let mut p1 = ColumnOracle::new(CFG);
            let mut p2 = ColumnOracle::new(CFG);
            for &(a, w) in &terms[..n1] {
                p1.mac(a, w);
            }
            for &(a, w) in &terms[n1..] {
                p2.mac(a, w);
            }
            p1.merge(&p2);
            // Integer-valued inputs: no window loss, so the merged wide
            // sum must round identically to the unsplit chain.
            assert_eq!(p1.result(), whole.result());
            assert_eq!(p1.len(), whole.len());
        }
    }

    #[test]
    fn mac_slice_equals_elementwise_mac() {
        let mut rng = Rng::new(0x5103);
        for _ in 0..50 {
            let n = rng.below(64) as usize;
            let terms: Vec<(u64, u64)> = (0..n)
                .map(|_| (bf(rng.range_i64(-16, 16) as f64), bf(rng.range_i64(-8, 8) as f64)))
                .collect();
            let mut by_elem = ColumnOracle::new(CFG);
            for &(a, w) in &terms {
                by_elem.mac(a, w);
            }
            let a: Vec<u64> = terms.iter().map(|t| t.0).collect();
            let w: Vec<u64> = terms.iter().map(|t| t.1).collect();
            let mut by_slice = ColumnOracle::new(CFG);
            by_slice.mac_slice(&a, &w);
            assert_eq!(by_slice.signal(), by_elem.signal());
            assert_eq!(by_slice.result(), by_elem.result());
            assert_eq!(by_slice.len(), by_elem.len());
        }
    }

    #[test]
    fn reset_clears_state() {
        let mut o = ColumnOracle::new(CFG);
        o.mac(bf(2.0), bf(3.0));
        o.reset();
        assert!(o.is_empty());
        assert_eq!(o.result_f32(), 0.0);
    }
}
