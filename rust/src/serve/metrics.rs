//! Latency recording: percentile summaries and throughput.
//!
//! Closed-loop load-generator clients record one submit→response
//! duration per request; the summary reports p50/p95/p99, which is what
//! serving dashboards quote and what the `BENCH_serve.json` trajectory
//! tracks across PRs.
//!
//! The recorder is backed by the bounded log2 histogram
//! ([`crate::obs::Log2Histogram`]): the pre-fix `Mutex<Vec<u64>>` kept
//! every sample forever — a day-long soak leaked gigabytes and every
//! summary paid an O(n log n) sort under the lock.  Memory is now a
//! fixed ~15 KiB whatever the sample count, recording is a handful of
//! atomic adds (no lock), and the quoted percentiles are within the
//! documented [`crate::obs::REL_QUANTILE_ERROR`] (1/32 ≈ 3.1%) of the
//! exact nearest-rank values — pinned against [`percentile_ns`] by a
//! 1M-sample regression test in `obs::hist`.  `count`/`mean`/`max`
//! remain exact (the histogram tracks sum, min and max as scalars).

use crate::obs::Log2Histogram;
use std::time::{Duration, Instant};

/// Snapshot of recorded latencies.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencySummary {
    pub count: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
    /// Wall-clock seconds since the recorder was created.
    pub wall_s: f64,
    /// Completed requests per wall-clock second.
    pub throughput_rps: f64,
}

/// Nearest-rank percentile of an ascending-sorted sample set; 0 for an
/// empty set.
///
/// The domain is `p ∈ (0, 100]` and it is *enforced*: the pre-fix
/// version silently clamped, so `p = 0` or a negative `p` returned the
/// minimum sample and `p > 100` returned the maximum — a dashboard
/// typo like `p99.9 → 999` would quietly report the max instead of
/// failing loudly.  NaN is rejected for the same reason.
///
/// # Panics
/// If `p` is NaN, `p <= 0` or `p > 100`.
pub fn percentile_ns(sorted: &[u64], p: f64) -> u64 {
    assert!(
        p.is_finite() && p > 0.0 && p <= 100.0,
        "percentile p={p} outside the (0, 100] domain"
    );
    if sorted.is_empty() {
        return 0;
    }
    let n = sorted.len();
    // Nearest rank: ceil(p/100 · n), at least 1 (p > 0 can still round
    // a tiny rank product down to 0 in floating point).
    let rank = ((p / 100.0) * n as f64).ceil() as usize;
    sorted[rank.clamp(1, n) - 1]
}

/// Thread-safe latency recorder shared by the load-generator clients.
/// Bounded memory (one log2 histogram), lock-free recording.
pub struct LatencyRecorder {
    start: Instant,
    hist: Log2Histogram,
}

impl Default for LatencyRecorder {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyRecorder {
    pub fn new() -> LatencyRecorder {
        LatencyRecorder { start: Instant::now(), hist: Log2Histogram::new() }
    }

    pub fn record(&self, d: Duration) {
        self.hist.record(d.as_nanos() as u64);
    }

    pub fn count(&self) -> usize {
        self.hist.count() as usize
    }

    pub fn summary(&self) -> LatencySummary {
        let snap = self.hist.snapshot();
        let wall_s = self.start.elapsed().as_secs_f64();
        if snap.count == 0 {
            return LatencySummary { wall_s, ..LatencySummary::default() };
        }
        let to_us = |ns: u64| ns as f64 / 1_000.0;
        LatencySummary {
            count: snap.count as usize,
            mean_us: snap.mean() / 1_000.0,
            p50_us: to_us(snap.quantile(50.0)),
            p95_us: to_us(snap.quantile(95.0)),
            p99_us: to_us(snap.quantile(99.0)),
            max_us: to_us(snap.max),
            wall_s,
            throughput_rps: if wall_s > 0.0 { snap.count as f64 / wall_s } else { 0.0 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_rank_percentiles() {
        // 1..=100: p50 = 50, p95 = 95, p99 = 99, p100 = 100.
        let s: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile_ns(&s, 50.0), 50);
        assert_eq!(percentile_ns(&s, 95.0), 95);
        assert_eq!(percentile_ns(&s, 99.0), 99);
        assert_eq!(percentile_ns(&s, 100.0), 100);
        // Small sets: nearest rank rounds up.
        let s = vec![10u64, 20, 30];
        assert_eq!(percentile_ns(&s, 50.0), 20);
        assert_eq!(percentile_ns(&s, 99.0), 30);
        assert_eq!(percentile_ns(&s, 1.0), 10);
        assert_eq!(percentile_ns(&[], 50.0), 0);
    }

    #[test]
    fn percentile_boundaries() {
        // n = 1: every in-domain p lands on the single sample.
        let one = [42u64];
        for p in [0.001, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(percentile_ns(&one, p), 42, "p={p}");
        }
        // Non-integer ranks round up (nearest rank): n = 4.
        let s = [1u64, 2, 3, 4];
        assert_eq!(percentile_ns(&s, 50.0), 2); // rank ceil(2.0) = 2
        assert_eq!(percentile_ns(&s, 50.1), 3); // rank ceil(2.004) = 3
        assert_eq!(percentile_ns(&s, 95.0), 4); // rank ceil(3.8) = 4
        assert_eq!(percentile_ns(&s, 99.0), 4);
        assert_eq!(percentile_ns(&s, 25.0), 1);
        assert_eq!(percentile_ns(&s, 25.1), 2);
        // A vanishing p stays in-domain and returns the minimum.
        assert_eq!(percentile_ns(&s, 1e-9), 1);
    }

    #[test]
    #[should_panic(expected = "outside the (0, 100] domain")]
    fn percentile_rejects_zero() {
        percentile_ns(&[1, 2, 3], 0.0);
    }

    #[test]
    #[should_panic(expected = "outside the (0, 100] domain")]
    fn percentile_rejects_negative() {
        percentile_ns(&[1, 2, 3], -5.0);
    }

    #[test]
    #[should_panic(expected = "outside the (0, 100] domain")]
    fn percentile_rejects_above_100() {
        // The pre-fix behaviour silently returned the max here.
        percentile_ns(&[1, 2, 3], 100.1);
    }

    #[test]
    #[should_panic(expected = "outside the (0, 100] domain")]
    fn percentile_rejects_nan() {
        percentile_ns(&[1, 2, 3], f64::NAN);
    }

    #[test]
    fn summary_orders_and_counts() {
        let r = LatencyRecorder::new();
        for us in [300u64, 100, 200] {
            r.record(Duration::from_micros(us));
        }
        let s = r.summary();
        assert_eq!(s.count, 3);
        // count/mean/max are exact; quantiles carry the histogram's
        // documented relative error (one sub-bucket width, rounded down).
        assert_eq!(s.max_us, 300.0);
        assert_eq!(s.mean_us, 200.0);
        let err = crate::obs::REL_QUANTILE_ERROR;
        assert!((s.p50_us - 200.0).abs() <= 200.0 * err, "p50 {}", s.p50_us);
        assert!((s.p99_us - 300.0).abs() <= 300.0 * err, "p99 {}", s.p99_us);
        assert!(s.p50_us <= s.p95_us && s.p95_us <= s.p99_us && s.p99_us <= s.max_us);
        assert!(s.throughput_rps > 0.0);
    }

    #[test]
    fn empty_summary_is_zeroed() {
        let r = LatencyRecorder::new();
        let s = r.summary();
        assert_eq!(s.count, 0);
        assert_eq!(s.p99_us, 0.0);
    }

    /// The soak-leak regression: a million samples through the recorder
    /// cost fixed memory, and the quoted percentiles stay within the
    /// histogram's documented error of the exact nearest-rank values
    /// computed from the same sample set.
    #[test]
    fn million_samples_bounded_and_within_documented_error() {
        let r = LatencyRecorder::new();
        let mut rng = crate::util::rng::Rng::new(0x1a7);
        let mut exact: Vec<u64> = Vec::with_capacity(1_000_000);
        for _ in 0..1_000_000 {
            // Log-uniform over ~1µs..16ms: a realistic latency spread
            // crossing many octaves.
            let ns = 1_000u64 << rng.below(15);
            let ns = ns + rng.below(ns);
            r.record(Duration::from_nanos(ns));
            exact.push(ns);
        }
        exact.sort_unstable();
        let s = r.summary();
        assert_eq!(s.count, 1_000_000);
        let err = crate::obs::REL_QUANTILE_ERROR;
        for (got_us, p) in [(s.p50_us, 50.0), (s.p95_us, 95.0), (s.p99_us, 99.0)] {
            let want_us = percentile_ns(&exact, p) as f64 / 1_000.0;
            assert!(
                (got_us - want_us).abs() <= want_us * err,
                "p{p}: got {got_us}µs want {want_us}µs"
            );
        }
        assert_eq!(s.max_us, *exact.last().unwrap() as f64 / 1_000.0);
    }
}
