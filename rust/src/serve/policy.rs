//! Clock-agnostic serving policy.
//!
//! The serve stack makes four load-management decisions — shed at the
//! overload watermark, pick the batch anchor, admit a member into an
//! open batch, and close the coalescing window early for interactive
//! traffic.  The threaded stack ([`super::request::RequestQueue`],
//! [`super::batcher::Batcher`]) makes them under mutexes against the
//! wall clock; the fleet simulator ([`crate::fleet`]) makes the *same*
//! decisions against a virtual cycle clock over thousands of simulated
//! shards.  Both call the pure functions here, so fleet-level results
//! are produced by the policy being simulated, not by a reimplementation
//! that can drift (DESIGN.md §18).
//!
//! Every function is a total function of its arguments: no clocks, no
//! locks, no I/O.  Time-typed knobs (the batch window) are generic so
//! the threaded caller passes `Duration` and the simulator passes
//! cycle counts.

use super::request::DeadlineClass;
use crate::pe::PipelineKind;

/// Deadline-aware load shedding: with a watermark armed (`> 0`), a
/// `Batch`-class submission is turned away once the queue already holds
/// `queue_len ≥ shed_watermark` requests.  Interactive submissions are
/// never shed here — they keep the queue-full behaviour of the caller
/// (blocking backpressure in the threaded stack, capacity shedding in
/// the open-loop simulator).
pub fn should_shed(shed_watermark: usize, class: DeadlineClass, queue_len: usize) -> bool {
    shed_watermark > 0 && class == DeadlineClass::Batch && queue_len >= shed_watermark
}

/// Anchor selection over the queued deadline classes in queue order:
/// the first interactive request if any, else the front — except that
/// after `max_front_bypass` consecutive bypasses the front request is
/// anchored regardless of class (sustained interactive traffic cannot
/// starve a queued batch request).  Returns `None` on an empty queue.
pub fn anchor_index<I>(classes: I, front_bypassed: usize, max_front_bypass: usize) -> Option<usize>
where
    I: IntoIterator<Item = DeadlineClass>,
{
    let mut len = 0usize;
    let mut first_interactive = None;
    for (i, class) in classes.into_iter().enumerate() {
        len += 1;
        if first_interactive.is_none() && class == DeadlineClass::Interactive {
            first_interactive = Some(i);
        }
    }
    match first_interactive {
        Some(i) if i > 0 && front_bypassed >= max_front_bypass => Some(0),
        Some(i) => Some(i),
        None if len == 0 => None,
        None => Some(0),
    }
}

/// The coalescing window is the *anchor's* deadline-class window.
/// Generic over the time representation: `Duration` in the threaded
/// batcher, cycles in the fleet simulator.
pub fn window_for_anchor<T>(class: DeadlineClass, interactive_window: T, batch_window: T) -> T {
    match class {
        DeadlineClass::Interactive => interactive_window,
        DeadlineClass::Batch => batch_window,
    }
}

/// Size-cap check at the top of every drain step: a batch closes once
/// it holds `max_requests` members or `max_rows` stacked rows.
pub fn batch_caps_reached(parts: usize, rows: usize, max_requests: usize, max_rows: usize) -> bool {
    parts >= max_requests || rows >= max_rows
}

/// Member admission: a queued request joins an open batch iff it shares
/// the batch key (same model, same pipeline organisation — stacking
/// rows across either would run work under the wrong weights or
/// pipeline) and its rows still fit under the row cap.
pub fn member_fits(
    batch_model: usize,
    batch_kind: PipelineKind,
    batch_rows: usize,
    max_rows: usize,
    cand_model: usize,
    cand_kind: PipelineKind,
    cand_rows: usize,
) -> bool {
    cand_model == batch_model && cand_kind == batch_kind && batch_rows + cand_rows <= max_rows
}

/// Early window close: an interactive request — still queued
/// (incompatibly) or absorbed as a *non-anchor* member — flushes an
/// open batch window immediately.  Its flush-now contract must not
/// wait out a batch anchor's window; the anchor itself is exempt
/// (callers pass non-anchor member classes only), since an interactive
/// anchor already chose the interactive window.
pub fn window_closes_early<I>(interactive_waiting: bool, non_anchor_members: I) -> bool
where
    I: IntoIterator<Item = DeadlineClass>,
{
    interactive_waiting
        || non_anchor_members.into_iter().any(|c| c == DeadlineClass::Interactive)
}

/// Shape-aware shard selection ([`Policy::ShapeAware`]): given each
/// candidate shard's *predicted stream cycles* for the batch under that
/// shard's geometry (from the geometry-keyed plan cache), pick the
/// fewest-cycles shard, ties toward the lower index.  Deliberately
/// *deterministic* — no in-flight or queue-depth term — so the fleet
/// DES replays the threaded server's routing decisions
/// request-for-request (the §18 differential pin, extended to geometry
/// scoring).  Skipping unhealthy shards is the caller's job: pass only
/// eligible `(shard, cycles)` pairs.  Returns `None` only for an empty
/// candidate set.
///
/// [`Policy::ShapeAware`]: crate::coordinator::router::Policy::ShapeAware
pub fn best_fit_shard<I>(scored: I) -> Option<usize>
where
    I: IntoIterator<Item = (usize, u64)>,
{
    scored
        .into_iter()
        .min_by_key(|&(shard, cycles)| (cycles, shard))
        .map(|(shard, _)| shard)
}

#[cfg(test)]
mod tests {
    use super::*;

    const I: DeadlineClass = DeadlineClass::Interactive;
    const B: DeadlineClass = DeadlineClass::Batch;

    #[test]
    fn shed_is_batch_class_only_and_armed_only() {
        assert!(should_shed(2, B, 2));
        assert!(should_shed(2, B, 5));
        assert!(!should_shed(2, B, 1));
        assert!(!should_shed(2, I, 5), "interactive is never watermark-shed");
        assert!(!should_shed(0, B, 100), "watermark 0 disarms shedding");
    }

    #[test]
    fn anchor_prefers_first_interactive_then_fifo() {
        assert_eq!(anchor_index([B, B, I, I], 0, 64), Some(2));
        assert_eq!(anchor_index([B, B], 0, 64), Some(0));
        assert_eq!(anchor_index([I, B], 0, 64), Some(0));
        assert_eq!(anchor_index(std::iter::empty(), 0, 64), None);
    }

    #[test]
    fn anchor_starvation_guard_falls_back_to_front() {
        // At the bypass bound, a non-front interactive no longer wins.
        assert_eq!(anchor_index([B, I], 64, 64), Some(0));
        assert_eq!(anchor_index([B, I], 63, 64), Some(1));
        // A front interactive is position 0 either way.
        assert_eq!(anchor_index([I, B], 64, 64), Some(0));
    }

    #[test]
    fn window_follows_anchor_class() {
        assert_eq!(window_for_anchor(I, 1u64, 500u64), 1);
        assert_eq!(window_for_anchor(B, 1u64, 500u64), 500);
    }

    #[test]
    fn caps_and_fit() {
        assert!(batch_caps_reached(4, 0, 4, 64));
        assert!(batch_caps_reached(0, 64, 4, 64));
        assert!(!batch_caps_reached(3, 63, 4, 64));
        use crate::pe::PipelineKind::{Deep3, Skewed};
        assert!(member_fits(0, Skewed, 4, 8, 0, Skewed, 4));
        assert!(!member_fits(0, Skewed, 4, 8, 0, Skewed, 5), "row cap");
        assert!(!member_fits(0, Skewed, 4, 8, 1, Skewed, 1), "model key");
        assert!(!member_fits(0, Skewed, 4, 8, 0, Deep3, 1), "kind key");
    }

    #[test]
    fn best_fit_is_min_cycles_low_index_ties() {
        assert_eq!(best_fit_shard([(0, 6560), (1, 5520), (2, 8832)]), Some(1));
        // Ties break toward the lower shard index, whatever the order
        // the candidates arrive in.
        assert_eq!(best_fit_shard([(2, 100), (0, 100), (1, 100)]), Some(0));
        // Exclusions are the caller's: a filtered set still resolves.
        assert_eq!(best_fit_shard([(2, 9), (3, 9)]), Some(2));
        assert_eq!(best_fit_shard(std::iter::empty::<(usize, u64)>()), None);
    }

    #[test]
    fn early_close_on_waiting_or_absorbed_interactive() {
        assert!(window_closes_early(true, std::iter::empty()));
        assert!(window_closes_early(false, [B, I]));
        assert!(!window_closes_early(false, [B, B]));
        assert!(!window_closes_early(false, std::iter::empty()));
    }
}
