//! Dynamic batching: coalesce compatible requests into one stacked GEMM.
//!
//! Two requests are *compatible* when they target the same serving
//! model (same weight matrix, same format) under the same pipeline
//! kind: stacking their activation rows is then bit-exact per row
//! (DESIGN.md §7/§11), and the weight-stationary array amortises its
//! per-tile fixed costs (plan, preload, fill/drain, dispatch) across
//! every stacked row.
//!
//! The window policy is anchor-driven: the batcher pops one anchor
//! request, then keeps draining compatible arrivals until the anchor's
//! deadline-class window closes or a size cap is hit.  Interactive
//! anchors default to a zero window — they leave with whatever is
//! already queued.

use super::policy;
use super::request::{Pending, RequestQueue};
use crate::obs::Phase;
use crate::pe::PipelineKind;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Batch compatibility key: same weights, same pipeline organisation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchKey {
    pub model: usize,
    pub kind: PipelineKind,
}

/// A coalesced batch ready for planning and shard dispatch.
pub struct Batch {
    pub key: BatchKey,
    /// Member requests in arrival order (row offsets follow this order).
    pub parts: Vec<Pending>,
    /// Total stacked activation rows.
    pub rows: usize,
}

/// Size/time bounds on batch formation.
#[derive(Clone, Copy, Debug)]
pub struct BatchLimits {
    pub max_requests: usize,
    pub max_rows: usize,
    pub batch_window: Duration,
    pub interactive_window: Duration,
}

/// The batcher: drains a [`RequestQueue`] into [`Batch`]es.
pub struct Batcher {
    queue: Arc<RequestQueue>,
    limits: BatchLimits,
}

impl Batcher {
    pub fn new(queue: Arc<RequestQueue>, limits: BatchLimits) -> Batcher {
        assert!(limits.max_requests >= 1 && limits.max_rows >= 1);
        Batcher { queue, limits }
    }

    /// Form the next batch; blocks until at least one request is
    /// available.  Returns `None` once the queue is closed and drained.
    pub fn next_batch(&self) -> Option<Batch> {
        let anchor = self.queue.pop_anchor()?;
        let key = BatchKey { model: anchor.req.model, kind: anchor.req.kind };
        // The anchor's deadline class decides the coalescing window.
        let window = policy::window_for_anchor(
            anchor.req.class,
            self.limits.interactive_window,
            self.limits.batch_window,
        );
        let mut rows = anchor.req.rows();
        let mut parts = vec![anchor];
        let deadline = Instant::now() + window;
        loop {
            let (seen, interactive_waiting) = self.queue.take_matching(
                key.model,
                key.kind,
                self.limits.max_requests,
                self.limits.max_rows,
                &mut parts,
                &mut rows,
            );
            if policy::batch_caps_reached(
                parts.len(),
                rows,
                self.limits.max_requests,
                self.limits.max_rows,
            ) {
                break;
            }
            // An interactive request — absorbed into this batch or
            // waiting (incompatibly) in the queue — closes the window
            // early: its flush-now contract must not wait out a batch
            // anchor's window.  The anchor itself is exempt (`skip(1)`):
            // an interactive *anchor* already chose the interactive
            // window above, which would otherwise be dead config.
            if policy::window_closes_early(
                interactive_waiting,
                parts.iter().skip(1).map(|p| p.req.class),
            ) {
                break;
            }
            if self.queue.wait_new_push(seen, deadline).is_none() {
                break;
            }
        }
        // The window is closed: every member's batch-formation wait
        // (admission → dispatch) ends together, here.
        for p in &mut parts {
            p.span.mark(Phase::Batch);
        }
        Some(Batch { key, parts, rows })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::request::{DeadlineClass, Request, Response};
    use std::sync::mpsc::{channel, Receiver};

    fn pending(
        id: u64,
        model: usize,
        kind: PipelineKind,
        class: DeadlineClass,
        m: usize,
    ) -> (Pending, Receiver<Response>) {
        let (tx, rx) = channel();
        let p = Pending {
            req: Request { id, model, kind, class, a: vec![vec![0u64; 4]; m] },
            reply: tx,
            span: crate::obs::TraceSpan::disabled(),
        };
        (p, rx)
    }

    fn limits(max_requests: usize, max_rows: usize, window_us: u64) -> BatchLimits {
        BatchLimits {
            max_requests,
            max_rows,
            batch_window: Duration::from_micros(window_us),
            interactive_window: Duration::ZERO,
        }
    }

    #[test]
    fn queued_compatibles_coalesce_into_one_batch() {
        let queue = Arc::new(RequestQueue::new(16));
        let mut rxs = Vec::new();
        for id in 0..5 {
            let (p, rx) = pending(id, 3, PipelineKind::Skewed, DeadlineClass::Batch, 2);
            queue.push(p).unwrap();
            rxs.push(rx);
        }
        let b = Batcher::new(Arc::clone(&queue), limits(8, 64, 0));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.parts.len(), 5);
        assert_eq!(batch.rows, 10);
        assert_eq!(batch.key, BatchKey { model: 3, kind: PipelineKind::Skewed });
        // Arrival order preserved (row offsets depend on it).
        let ids: Vec<u64> = batch.parts.iter().map(|p| p.req.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
        assert!(queue.is_empty());
    }

    #[test]
    fn incompatible_kinds_split_batches() {
        let queue = Arc::new(RequestQueue::new(16));
        let mut rxs = Vec::new();
        for (id, kind) in
            [(0, PipelineKind::Skewed), (1, PipelineKind::Baseline3b), (2, PipelineKind::Skewed)]
        {
            let (p, rx) = pending(id, 0, kind, DeadlineClass::Batch, 1);
            queue.push(p).unwrap();
            rxs.push(rx);
        }
        let b = Batcher::new(Arc::clone(&queue), limits(8, 64, 0));
        let first = b.next_batch().unwrap();
        assert_eq!(first.parts.len(), 2, "both skewed requests coalesce");
        let second = b.next_batch().unwrap();
        assert_eq!(second.parts.len(), 1);
        assert_eq!(second.key.kind, PipelineKind::Baseline3b);
    }

    #[test]
    fn request_cap_bounds_batches() {
        let queue = Arc::new(RequestQueue::new(16));
        let mut rxs = Vec::new();
        for id in 0..6 {
            let (p, rx) = pending(id, 0, PipelineKind::Skewed, DeadlineClass::Batch, 1);
            queue.push(p).unwrap();
            rxs.push(rx);
        }
        let b = Batcher::new(Arc::clone(&queue), limits(4, 64, 0));
        assert_eq!(b.next_batch().unwrap().parts.len(), 4);
        assert_eq!(b.next_batch().unwrap().parts.len(), 2);
    }

    #[test]
    fn oversized_single_request_still_runs_alone() {
        let queue = Arc::new(RequestQueue::new(4));
        let (p, _rx) = pending(0, 0, PipelineKind::Skewed, DeadlineClass::Batch, 100);
        queue.push(p).unwrap();
        let b = Batcher::new(Arc::clone(&queue), limits(8, 16, 0));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.parts.len(), 1);
        assert_eq!(batch.rows, 100, "row cap never rejects an anchor");
    }

    #[test]
    fn window_collects_late_arrivals() {
        let queue = Arc::new(RequestQueue::new(16));
        let (p, _rx0) = pending(0, 0, PipelineKind::Skewed, DeadlineClass::Batch, 1);
        queue.push(p).unwrap();
        let q2 = Arc::clone(&queue);
        let pusher = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            let (late, rx) = pending(1, 0, PipelineKind::Skewed, DeadlineClass::Batch, 1);
            q2.push(late).unwrap();
            std::mem::forget(rx);
        });
        // A generous window: the late push lands well inside it; the
        // request cap of 2 then closes the batch without waiting out
        // the rest of the window.
        let b = Batcher::new(Arc::clone(&queue), limits(2, 64, 500_000));
        let batch = b.next_batch().unwrap();
        pusher.join().unwrap();
        assert_eq!(batch.parts.len(), 2, "window admitted the late arrival");
    }

    #[test]
    fn interactive_arrival_closes_an_open_batch_window() {
        let queue = Arc::new(RequestQueue::new(16));
        let (p, _rx0) = pending(0, 0, PipelineKind::Skewed, DeadlineClass::Batch, 1);
        queue.push(p).unwrap();
        let q2 = Arc::clone(&queue);
        let pusher = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            // Incompatible (different model) interactive arrival.
            let (late, rx) = pending(1, 9, PipelineKind::Skewed, DeadlineClass::Interactive, 1);
            q2.push(late).unwrap();
            std::mem::forget(rx);
        });
        // A very long batch window that must NOT be waited out.
        let b = Batcher::new(Arc::clone(&queue), limits(8, 64, 30_000_000));
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        pusher.join().unwrap();
        assert_eq!(batch.parts.len(), 1);
        assert!(t0.elapsed() < Duration::from_secs(10), "interactive must close the window");
        // The interactive request anchors the next batch immediately.
        let next = b.next_batch().unwrap();
        assert_eq!(next.parts[0].req.id, 1);
    }

    #[test]
    fn absorbed_interactive_flushes_the_batch_immediately() {
        let queue = Arc::new(RequestQueue::new(16));
        let (p, _rx0) = pending(0, 0, PipelineKind::Skewed, DeadlineClass::Batch, 1);
        queue.push(p).unwrap();
        let q2 = Arc::clone(&queue);
        let pusher = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            // Compatible interactive: rides along, and flushes the batch.
            let (late, rx) = pending(1, 0, PipelineKind::Skewed, DeadlineClass::Interactive, 1);
            q2.push(late).unwrap();
            std::mem::forget(rx);
        });
        let b = Batcher::new(Arc::clone(&queue), limits(8, 64, 30_000_000));
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        pusher.join().unwrap();
        assert_eq!(batch.parts.len(), 2, "interactive coalesced into the open batch");
        assert!(t0.elapsed() < Duration::from_secs(10), "absorption must flush the window");
    }

    #[test]
    fn nonzero_interactive_window_coalesces_for_interactive_anchors() {
        // The interactive window applies to the *anchor*: with a
        // nonzero value, an interactive anchor waits for compatible
        // arrivals (the flush-early rule exempts the anchor itself,
        // else this knob would be dead config).
        let queue = Arc::new(RequestQueue::new(16));
        let (p, _rx0) = pending(0, 0, PipelineKind::Skewed, DeadlineClass::Interactive, 1);
        queue.push(p).unwrap();
        let q2 = Arc::clone(&queue);
        let pusher = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            let (late, rx) = pending(1, 0, PipelineKind::Skewed, DeadlineClass::Batch, 1);
            q2.push(late).unwrap();
            std::mem::forget(rx);
        });
        let lim = BatchLimits {
            max_requests: 2,
            max_rows: 64,
            batch_window: Duration::ZERO,
            interactive_window: Duration::from_secs(30),
        };
        let b = Batcher::new(Arc::clone(&queue), lim);
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        pusher.join().unwrap();
        assert_eq!(batch.parts.len(), 2, "interactive window admitted the late arrival");
        assert!(t0.elapsed() < Duration::from_secs(10), "request cap closed the window");
    }

    #[test]
    fn closed_empty_queue_ends_batching() {
        let queue = Arc::new(RequestQueue::new(4));
        queue.close();
        let b = Batcher::new(queue, limits(4, 16, 0));
        assert!(b.next_batch().is_none());
    }
}
