//! Shard layer: N independent simulated array "chips" behind a router.
//!
//! Each shard is a long-lived thread owning a persistent
//! [`WorkerPool`] — the executor-reuse half of the serve tentpole:
//! worker threads are spawned once per shard and stream any number of
//! batches, instead of the per-GEMM spawn/teardown the one-shot
//! [`crate::coordinator::Executor`] pays.  The existing [`Router`]
//! policies are lifted to the shard level: the dispatcher picks a shard
//! round-robin or least-loaded (by in-flight batches), and the shard
//! reports completion back to the router when its batch retires.
//!
//! The shard also owns reply fan-out: a batch's stacked output rows are
//! sliced back per member request and sent down each request's reply
//! channel, so responses leave as soon as *their* batch retires.

use super::cache::CachedPlan;
use super::health::{HealthBoard, HealthPolicy, ShardState};
use super::request::{Response, ResponseStatus};
use crate::arith::fma::ChainCfg;
use crate::config::NumericMode;
use crate::coordinator::router::{Policy, Router};
use crate::coordinator::{FaultModel, FaultPlan, WorkerPool};
use crate::obs::{Obs, Phase, SpanStatus, TraceSpan};
use crate::pe::PipelineKind;
use crate::workloads::gemm::GemmData;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Sender, SyncSender};
use std::sync::Arc;

/// One request's slice of a batch: which stacked rows reply where.
pub struct ReplyPart {
    pub id: u64,
    pub rows: usize,
    /// The member request's trace span.  The shard closes it right
    /// before the reply send; if the batch is dropped on a failed run,
    /// the span's `Drop` closes it as failed — either way, exactly
    /// once.  Declared before `reply` so the drop path also closes the
    /// span before the client's receiver can observe the disconnect: a
    /// client holding a response (or a hangup) is guaranteed the span
    /// is already in the sink.
    pub span: TraceSpan,
    pub reply: Sender<Response>,
}

/// A planned batch handed to a shard for execution.
pub struct BatchJob {
    pub chain: ChainCfg,
    pub mode: NumericMode,
    pub kind: PipelineKind,
    /// Weight-preload discipline of the modeled array: selects which of
    /// the cached plan's service-time numbers is reported (and, in
    /// cycle-accurate mode, how the streaming simulator chains tiles).
    pub double_buffer: bool,
    /// Stacked activations + shared weights.
    pub data: Arc<GemmData>,
    /// Memoised plan + schedules (from the [`super::cache::PlanCache`]).
    pub plan: Arc<CachedPlan>,
    /// Reply routing, in stacking order.
    pub parts: Vec<ReplyPart>,
    pub cache_hit: bool,
}

/// Per-shard counters, snapshotted for reports.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShardSnapshot {
    pub batches: u64,
    pub requests: u64,
    pub rows: u64,
    pub retries: u64,
    /// Silent corruptions injected into this shard's tile evaluations.
    pub sdc_injected: u64,
    /// Suspect blocks the ABFT checksums flagged.
    pub sdc_detected: u64,
    /// Flagged blocks cleared by recomputation.
    pub sdc_recovered: u64,
    /// Blocks still failing the checksums when recovery gave up.
    pub sdc_unresolved: u64,
    /// Batches dropped wholesale (retry exhaustion / timing mismatch).
    pub failed_batches: u64,
    /// Times this shard entered quarantine.
    pub quarantines: u64,
    /// Where the shard stands in the quarantine state machine.
    pub health: ShardState,
}

#[derive(Default)]
struct ShardCounters {
    batches: AtomicU64,
    requests: AtomicU64,
    rows: AtomicU64,
    retries: AtomicU64,
    sdc_injected: AtomicU64,
    sdc_detected: AtomicU64,
    sdc_recovered: AtomicU64,
    sdc_unresolved: AtomicU64,
    failed_batches: AtomicU64,
}

struct Shard {
    tx: Option<SyncSender<BatchJob>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

/// The pool of shards plus the shard-level router and health board.
pub struct ShardPool {
    shards: Vec<Shard>,
    router: Arc<Router>,
    counters: Arc<Vec<ShardCounters>>,
    health: Arc<HealthBoard>,
}

impl ShardPool {
    /// Spawn `shards` shard threads, each owning a persistent
    /// `workers_per_shard`-thread [`WorkerPool`].
    pub fn new(
        shards: usize,
        workers_per_shard: usize,
        queue_depth: usize,
        policy: Policy,
    ) -> ShardPool {
        Self::with_fault(shards, workers_per_shard, queue_depth, policy, FaultPlan::default())
    }

    /// As [`ShardPool::new`], injecting `fault` into every shard's
    /// worker pool (resilience tests: served results must survive a
    /// permanently failing worker via retry + exclusion).
    pub fn with_fault(
        shards: usize,
        workers_per_shard: usize,
        queue_depth: usize,
        policy: Policy,
        fault: FaultPlan,
    ) -> ShardPool {
        Self::with_fault_model(
            shards,
            workers_per_shard,
            queue_depth,
            policy,
            FaultModel::from_plan(fault),
            HealthPolicy::default(),
        )
    }

    /// As [`ShardPool::new`] under a full [`FaultModel`]: each shard's
    /// worker pool gets a decorrelated copy
    /// ([`FaultModel::for_shard`]), and every batch outcome feeds the
    /// shard's rolling health window — a shard whose window crosses
    /// `health.fault_threshold` is quarantined out of dispatch, then
    /// re-admitted through probation.
    pub fn with_fault_model(
        shards: usize,
        workers_per_shard: usize,
        queue_depth: usize,
        policy: Policy,
        fault: FaultModel,
        health: HealthPolicy,
    ) -> ShardPool {
        Self::with_obs(
            shards,
            workers_per_shard,
            workers_per_shard,
            queue_depth,
            policy,
            fault,
            health,
            &Obs::new(),
        )
    }

    /// As [`ShardPool::with_fault_model`] under an observability handle:
    /// the health board publishes its transitions to `obs`
    /// (counters + trace events), and each member request's trace span
    /// — travelling inside its [`ReplyPart`] — has its dispatch/execute/
    /// reply phases and cycle attribution recorded by the shard loop.
    ///
    /// `sim_threads` is the tile-parallelism of each shard's
    /// cycle-accurate streaming path (`--threads`); the non-obs
    /// constructors default it to `workers_per_shard`.
    #[allow(clippy::too_many_arguments)]
    pub fn with_obs(
        shards: usize,
        workers_per_shard: usize,
        sim_threads: usize,
        queue_depth: usize,
        policy: Policy,
        fault: FaultModel,
        health: HealthPolicy,
        obs: &Obs,
    ) -> ShardPool {
        let shards = shards.max(1);
        let router = Arc::new(Router::new(policy, shards));
        let health = Arc::new(HealthBoard::with_obs(health, shards, obs));
        let counters: Arc<Vec<ShardCounters>> =
            Arc::new((0..shards).map(|_| ShardCounters::default()).collect());
        let built = (0..shards)
            .map(|idx| {
                // A small mailbox: the batcher backpressures instead of
                // queueing unboundedly ahead of a busy shard.
                let (tx, rx) = sync_channel::<BatchJob>(2);
                let router = Arc::clone(&router);
                let counters = Arc::clone(&counters);
                let health = Arc::clone(&health);
                let fault = fault.for_shard(idx);
                let handle = std::thread::spawn(move || {
                    let mut pool = WorkerPool::with_fault_model(
                        workers_per_shard,
                        queue_depth,
                        Policy::LeastLoaded,
                        fault,
                    );
                    pool.set_sim_threads(sim_threads);
                    while let Ok(mut job) = rx.recv() {
                        // The batch left the dispatcher's mailbox: every
                        // member's dispatch-wait phase ends here.
                        let batch_size = job.parts.len();
                        for part in &mut job.parts {
                            part.span.mark(Phase::Dispatch);
                            part.span.set_batch(idx, batch_size, job.cache_hit);
                        }
                        let run = pool.run_gemm(
                            job.chain,
                            job.mode,
                            job.kind,
                            &job.data,
                            &job.plan.plan,
                            job.double_buffer,
                        );
                        let out = match run {
                            Ok(out) => out,
                            Err(e) => {
                                // Dropping `job` drops every member's
                                // reply sender: clients see a recv
                                // error instead of a hung server.
                                eprintln!("serve: shard {idx} dropped a batch: {e}");
                                counters[idx].failed_batches.fetch_add(1, Ordering::Relaxed);
                                health.record(idx, 1);
                                router.complete(idx);
                                continue;
                            }
                        };
                        // One number everywhere: the reported service
                        // time is the cached closed form for the
                        // configured preload discipline, and the
                        // cycle-accurate streaming path must agree with
                        // it exactly (it already checked itself against
                        // the layer model; this ties the *reported*
                        // value to the simulated one).  A mismatch
                        // drops the batch like any other failed run —
                        // never a panic on a detached shard thread.
                        let batch_stream_cycles = job.plan.stream_cycles(job.double_buffer);
                        if let Some(simulated) = out.stream_cycles {
                            if simulated != batch_stream_cycles {
                                eprintln!(
                                    "serve: shard {idx} dropped a batch: simulated service \
                                     time {simulated} != plan-cache {batch_stream_cycles}"
                                );
                                counters[idx].failed_batches.fetch_add(1, Ordering::Relaxed);
                                health.record(idx, 1);
                                router.complete(idx);
                                continue;
                            }
                        }
                        // Execution is over: close every member's
                        // execute phase and attach the batch's cycle
                        // attribution — the clean plan decomposition
                        // (whose stream total is exactly the reported
                        // service time) plus the ABFT recovery
                        // recompute cycles the executor tallied.
                        let mut attribution = job.plan.breakdown(job.double_buffer);
                        attribution.recovery = out.recovery_cycles;
                        let sdc = (out.sdc.detected, out.sdc.recovered, out.sdc.unresolved);
                        for part in &mut job.parts {
                            part.span.set_exec(attribution, out.retries, sdc);
                            part.span.mark(Phase::Execute);
                        }
                        let n = job.data.shape.n;
                        let total_rows: usize = job.parts.iter().map(|p| p.rows).sum();
                        // Account *before* fanning replies out: a client
                        // unblocked by its reply must already see this
                        // batch in the counters (tests read stats right
                        // after the last recv).
                        let c = &counters[idx];
                        c.batches.fetch_add(1, Ordering::Relaxed);
                        c.requests.fetch_add(batch_size as u64, Ordering::Relaxed);
                        c.rows.fetch_add(total_rows as u64, Ordering::Relaxed);
                        c.retries.fetch_add(out.retries as u64, Ordering::Relaxed);
                        c.sdc_injected.fetch_add(out.sdc.injected as u64, Ordering::Relaxed);
                        c.sdc_detected.fetch_add(out.sdc.detected as u64, Ordering::Relaxed);
                        c.sdc_recovered.fetch_add(out.sdc.recovered as u64, Ordering::Relaxed);
                        c.sdc_unresolved.fetch_add(out.sdc.unresolved as u64, Ordering::Relaxed);
                        // A batch with detected-but-recovered SDCs still
                        // counts against the shard's health window: the
                        // hardware is flipping bits even if ABFT caught
                        // them this time.
                        health.record(idx, (out.sdc.detected + out.sdc.unresolved) as u64);
                        router.complete(idx);
                        let mut row0 = 0usize;
                        for part in &mut job.parts {
                            let y = out.y[row0 * n..(row0 + part.rows) * n].to_vec();
                            row0 += part.rows;
                            // Close the span first: once the client
                            // holds the response, its span is in the
                            // sink (the tests lean on this ordering).
                            part.span.finish(SpanStatus::Ok);
                            let _ = part.reply.send(Response {
                                id: part.id,
                                status: ResponseStatus::Ok,
                                y,
                                shard: idx,
                                batch_size,
                                cache_hit: job.cache_hit,
                                retries: out.retries,
                                batch_stream_cycles,
                            });
                        }
                    }
                });
                Shard { tx: Some(tx), handle: Some(handle) }
            })
            .collect();
        ShardPool { shards: built, router, counters, health }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The shared health board (quarantine state, for reports/tests).
    pub fn health(&self) -> &HealthBoard {
        &self.health
    }

    /// Route a batch to a healthy shard (policy decides which) and
    /// enqueue it; blocks when the chosen shard's mailbox is full.
    /// Quarantined shards are excluded — unless *every* shard is
    /// quarantined, in which case the exclusion is void and a degraded
    /// pool keeps serving.
    pub fn dispatch(&self, job: BatchJob) {
        let s = self.choose();
        self.enqueue_on(s, job);
    }

    /// Pick (and account for) the next shard under the pool's policy
    /// and health exclusions, *before* the job exists — heterogeneous
    /// pools plan the batch under the chosen shard's geometry, then
    /// enqueue with [`ShardPool::enqueue_on`].
    pub fn choose(&self) -> usize {
        self.health.tick();
        let excluded = self.health.excluded();
        self.router.dispatch_excluding(&excluded)
    }

    /// Enqueue a job on a shard that [`ShardPool::choose`] or
    /// [`ShardPool::dispatch_to`] already accounted for; blocks when
    /// the shard's mailbox is full.
    pub fn enqueue_on(&self, s: usize, job: BatchJob) {
        self.shards[s].tx.as_ref().expect("pool alive").send(job).expect("shard alive");
    }

    /// Tick the health board and return the dispatch-eligible shard
    /// indices in index order — the candidate set a shape-aware
    /// dispatcher scores before calling [`ShardPool::dispatch_to`].
    /// Mirrors [`ShardPool::dispatch`]'s quarantine rule: when *every*
    /// shard is quarantined the exclusion is void and all shards are
    /// eligible (a degraded pool keeps serving).
    pub fn eligible_shards(&self) -> Vec<usize> {
        self.health.tick();
        let excluded = self.health.excluded();
        let n = self.shards.len();
        if excluded.len() >= n {
            return (0..n).collect();
        }
        (0..n).filter(|s| !excluded.contains(s)).collect()
    }

    /// Enqueue a batch on an externally chosen shard — the shape-aware
    /// pick, scored by the dispatcher over [`ShardPool::eligible_shards`]
    /// via [`crate::serve::policy::best_fit_shard`] — with the same
    /// router in-flight accounting as [`ShardPool::dispatch`] (the shard
    /// loop's `complete` call stays symmetric either way).
    pub fn dispatch_to(&self, s: usize, job: BatchJob) {
        self.router.dispatch_to(s);
        self.enqueue_on(s, job);
    }

    /// Snapshot per-shard counters, merged with the health board.
    pub fn snapshots(&self) -> Vec<ShardSnapshot> {
        let quarantines = self.health.quarantine_counts();
        self.counters
            .iter()
            .enumerate()
            .map(|(i, c)| ShardSnapshot {
                batches: c.batches.load(Ordering::Relaxed),
                requests: c.requests.load(Ordering::Relaxed),
                rows: c.rows.load(Ordering::Relaxed),
                retries: c.retries.load(Ordering::Relaxed),
                sdc_injected: c.sdc_injected.load(Ordering::Relaxed),
                sdc_detected: c.sdc_detected.load(Ordering::Relaxed),
                sdc_recovered: c.sdc_recovered.load(Ordering::Relaxed),
                sdc_unresolved: c.sdc_unresolved.load(Ordering::Relaxed),
                failed_batches: c.failed_batches.load(Ordering::Relaxed),
                quarantines: quarantines[i],
                health: self.health.state(i),
            })
            .collect()
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        for s in &mut self.shards {
            s.tx = None; // close the mailbox; the shard loop exits
        }
        for s in &mut self.shards {
            if let Some(h) = s.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::format::FpFormat;
    use crate::sa::geometry::ArrayGeometry;
    use crate::sa::tile::GemmShape;
    use crate::serve::cache::{PlanCache, PlanKey};
    use std::sync::mpsc::channel;

    fn one_request_job(
        m: usize,
        reply: Sender<Response>,
        cache: &PlanCache,
    ) -> (BatchJob, GemmData) {
        let shape = GemmShape::new(m, 12, 6);
        let data = GemmData::integer_valued(shape, FpFormat::BF16, 9);
        let key = PlanKey {
            shape,
            fmt: FpFormat::BF16,
            kind: PipelineKind::Skewed,
            geom: ArrayGeometry { rows: 8, cols: 8 },
        };
        let (plan, hit) = cache.get(key);
        let job = BatchJob {
            chain: ChainCfg::BF16_FP32,
            mode: NumericMode::Oracle,
            kind: PipelineKind::Skewed,
            double_buffer: true,
            data: Arc::new(data.clone()),
            plan,
            parts: vec![ReplyPart { id: 0, rows: m, reply, span: TraceSpan::disabled() }],
            cache_hit: hit,
        };
        (job, data)
    }

    #[test]
    fn shard_executes_and_replies() {
        let pool = ShardPool::new(2, 2, 4, Policy::RoundRobin);
        let cache = PlanCache::new(4);
        let (tx, rx) = channel();
        let (job, data) = one_request_job(3, tx, &cache);
        pool.dispatch(job);
        let resp = rx.recv().unwrap();
        assert_eq!(resp.batch_size, 1);
        assert_eq!(resp.y.len(), 3 * 6);
        let want = data.reference_f64();
        for m in 0..3 {
            for n in 0..6 {
                assert_eq!(resp.y[m * 6 + n] as f64, want[m][n]);
            }
        }
        let snaps = pool.snapshots();
        let total: u64 = snaps.iter().map(|s| s.batches).sum();
        assert_eq!(total, 1);
    }

    #[test]
    fn round_robin_spreads_batches_across_shards() {
        let pool = ShardPool::new(3, 1, 2, Policy::RoundRobin);
        let cache = PlanCache::new(4);
        let mut rxs = Vec::new();
        for _ in 0..6 {
            let (tx, rx) = channel();
            let (job, _) = one_request_job(2, tx, &cache);
            pool.dispatch(job);
            rxs.push(rx);
        }
        for rx in rxs {
            rx.recv().unwrap();
        }
        let snaps = pool.snapshots();
        assert_eq!(snaps.len(), 3);
        for s in &snaps {
            assert_eq!(s.batches, 2, "round-robin splits 6 batches 2/2/2: {snaps:?}");
        }
    }

    #[test]
    fn externally_scored_dispatch_lands_on_the_chosen_shard() {
        let pool = ShardPool::new(3, 1, 2, Policy::ShapeAware);
        let cache = PlanCache::new(4);
        assert_eq!(pool.eligible_shards(), vec![0, 1, 2]);
        for _ in 0..3 {
            let (tx, rx) = channel();
            let (job, _) = one_request_job(2, tx, &cache);
            pool.dispatch_to(1, job);
            rx.recv().unwrap();
        }
        let snaps = pool.snapshots();
        assert_eq!(snaps[1].batches, 3, "every scored pick landed on shard 1");
        assert_eq!(snaps[0].batches + snaps[2].batches, 0);
    }

    #[test]
    fn failing_shard_is_quarantined_and_pool_keeps_serving() {
        // One shard, one worker that always dies: every batch fails.
        let policy = HealthPolicy {
            window: 4,
            fault_threshold: 3,
            quarantine_batches: 4,
            probation_batches: 2,
        };
        let pool = ShardPool::with_fault_model(
            1,
            1,
            4,
            Policy::RoundRobin,
            FaultModel::from_plan(FaultPlan::always(0)),
            policy,
        );
        let cache = PlanCache::new(4);
        for _ in 0..3 {
            let (tx, rx) = channel();
            let (job, _) = one_request_job(2, tx, &cache);
            pool.dispatch(job);
            assert!(rx.recv().is_err(), "dropped batch closes the reply channel");
        }
        let snap = pool.snapshots()[0];
        assert_eq!(snap.failed_batches, 3);
        assert_eq!(snap.quarantines, 1);
        assert!(matches!(snap.health, ShardState::Quarantined { .. }), "health: {}", snap.health);
        // The sole shard is quarantined, but exclusion of every shard is
        // void: dispatch still routes (and the batch still fails).
        let (tx, rx) = channel();
        let (job, _) = one_request_job(2, tx, &cache);
        pool.dispatch(job);
        assert!(rx.recv().is_err());
    }

    #[test]
    fn faulty_worker_inside_every_shard_is_survived() {
        let pool = ShardPool::with_fault(2, 2, 4, Policy::RoundRobin, FaultPlan::always(0));
        let cache = PlanCache::new(4);
        let (tx, rx) = channel();
        let (job, data) = one_request_job(4, tx, &cache);
        pool.dispatch(job);
        let resp = rx.recv().unwrap();
        assert!(resp.retries >= 1, "the failing worker forced retries");
        let want = data.reference_f64();
        for m in 0..4 {
            for n in 0..6 {
                assert_eq!(resp.y[m * 6 + n] as f64, want[m][n]);
            }
        }
    }
}
