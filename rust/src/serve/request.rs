//! Serving request/response types and the bounded request queue.
//!
//! The queue is the front door of the serve stack (DESIGN.md §11):
//! client threads [`RequestQueue::push`] concurrently (blocking when the
//! queue is full — closed-loop backpressure), the batcher thread pops an
//! *anchor* request (interactive requests jump the line) and then drains
//! compatible requests into the same batch.  A monotone push sequence
//! number lets the batcher sleep between arrivals instead of spinning.

use super::policy;
use crate::obs::{Phase, TraceSpan};
use crate::pe::PipelineKind;
use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Latency class a client attaches to a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeadlineClass {
    /// Flush as soon as possible: the batcher coalesces only what is
    /// already queued.
    Interactive,
    /// Throughput-oriented: the batcher may hold the request for the
    /// configured window to grow the batch.
    Batch,
}

/// One GEMM inference request against a registered serving model.
#[derive(Clone, Debug)]
pub struct Request {
    /// Server-assigned id (also the reply correlation key).
    pub id: u64,
    /// Index into the server's [`crate::workloads::serving::WeightStore`].
    pub model: usize,
    /// Pipeline organisation to run under.
    pub kind: PipelineKind,
    pub class: DeadlineClass,
    /// Activation rows `m × k`, bit patterns in the model's format.
    pub a: Vec<Vec<u64>>,
}

impl Request {
    /// Activation rows this request contributes to a batch.
    pub fn rows(&self) -> usize {
        self.a.len()
    }
}

/// How a request left the server.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResponseStatus {
    /// Served normally; `y` holds the result.
    Ok,
    /// Shed at the overload watermark before entering the queue
    /// (graceful degradation: `Batch`-class only, `y` is empty).
    Shed,
    /// The queue was already closed when the request arrived (server
    /// shutting down; `y` is empty).
    Closed,
}

/// The served result for one request.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// How the request left the server; every field below is only
    /// meaningful for [`ResponseStatus::Ok`].
    pub status: ResponseStatus,
    /// Row-major `m × n`, f32 semantics of the output format — bit-exact
    /// with a solo `Coordinator::run_gemm` of the same request.
    pub y: Vec<f32>,
    /// Shard that executed the batch.
    pub shard: usize,
    /// Requests coalesced into the producing batch (1 = ran alone).
    pub batch_size: usize,
    /// Whether the batch's plan came from the plan cache.
    pub cache_hit: bool,
    /// Tile-job retries observed by the producing batch.
    pub retries: usize,
    /// Simulated service time of the producing batch in array cycles —
    /// [`crate::timing::layer_timing`] for the batch's plan under the
    /// server's weight-preload discipline, equal to the streaming cycle
    /// simulator's total in cycle-accurate mode (asserted by the shard).
    pub batch_stream_cycles: u64,
}

impl Response {
    /// A rejection (shed or shutdown): no payload, no producing shard.
    pub fn rejected(id: u64, status: ResponseStatus) -> Response {
        Response {
            id,
            status,
            y: Vec::new(),
            shard: usize::MAX,
            batch_size: 0,
            cache_hit: false,
            retries: 0,
            batch_stream_cycles: 0,
        }
    }
}

/// Receive a response with a 60-second watchdog: a wedged shard or
/// batcher thread fails the caller with a message naming the wait
/// instead of hanging a test run forever.
///
/// # Panics
/// On timeout or a dropped reply channel.
pub fn recv_response(rx: &Receiver<Response>, what: &str) -> Response {
    match rx.recv_timeout(Duration::from_secs(60)) {
        Ok(r) => r,
        Err(e) => panic!("serve: no response for {what}: {e}"),
    }
}

/// As [`recv_response`], but a *dropped* reply channel returns `None`
/// instead of panicking: a shard that exhausts its retry budget (or
/// fails the stream-cycle cross-check) drops the whole batch, and
/// callers like the load generator count those as failed requests
/// rather than dying mid-run.  A timeout still panics — a wedged
/// pipeline is a bug, not load.
pub fn try_recv_response(rx: &Receiver<Response>, what: &str) -> Option<Response> {
    use std::sync::mpsc::RecvTimeoutError;
    match rx.recv_timeout(Duration::from_secs(60)) {
        Ok(r) => Some(r),
        Err(RecvTimeoutError::Disconnected) => None,
        Err(RecvTimeoutError::Timeout) => panic!("serve: no response for {what}: timed out"),
    }
}

/// A queued request: payload + reply channel + its trace span.
pub struct Pending {
    pub req: Request,
    /// The request's live trace span ([`TraceSpan::disabled`] when
    /// tracing is off).  Travels with the request through every stage;
    /// whichever stage consumes the request closes it.  Declared before
    /// `reply` so dropping a `Pending` closes the span before the
    /// client's receiver can observe the hangup.
    pub span: TraceSpan,
    pub reply: Sender<Response>,
}

struct QueueInner {
    items: VecDeque<Pending>,
    /// Incremented on every push (the batcher's arrival signal).
    seq: u64,
    /// Times the front request was bypassed by an interactive anchor
    /// (starvation guard: see [`RequestQueue::MAX_FRONT_BYPASS`]).
    front_bypassed: usize,
    closed: bool,
}

/// Why a submission did not enter the queue.
pub enum PushError {
    /// The queue is closed (server shutting down).
    Closed(Pending),
    /// Shed at the overload watermark (graceful degradation).
    Shed(Pending),
}

impl PushError {
    /// The request that was turned away.
    pub fn into_pending(self) -> Pending {
        match self {
            PushError::Closed(p) | PushError::Shed(p) => p,
        }
    }
}

impl std::fmt::Debug for PushError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PushError::Closed(p) => write!(f, "Closed(request {})", p.req.id),
            PushError::Shed(p) => write!(f, "Shed(request {})", p.req.id),
        }
    }
}

/// Bounded MPMC request queue (mutex + condvars; std-only).
pub struct RequestQueue {
    cap: usize,
    /// Queue depth at which `Batch`-class pushes are shed instead of
    /// blocking (0 disables shedding).
    shed_watermark: usize,
    inner: Mutex<QueueInner>,
    not_empty: Condvar,
    not_full: Condvar,
}

impl RequestQueue {
    /// Interactive anchors may bypass the front request at most this
    /// many consecutive times before the front is anchored regardless
    /// of class — sustained interactive traffic cannot starve a queued
    /// batch request indefinitely.
    pub const MAX_FRONT_BYPASS: usize = 64;

    pub fn new(cap: usize) -> RequestQueue {
        Self::with_watermark(cap, 0)
    }

    /// As [`RequestQueue::new`] with overload shedding armed: once the
    /// queue holds `shed_watermark` requests, a `Batch`-class push is
    /// rejected with [`PushError::Shed`] instead of blocking, keeping
    /// the deadline-sensitive interactive path responsive under
    /// overload.  `Interactive` pushes always block on the full `cap`.
    pub fn with_watermark(cap: usize, shed_watermark: usize) -> RequestQueue {
        RequestQueue {
            cap: cap.max(1),
            shed_watermark,
            inner: Mutex::new(QueueInner {
                items: VecDeque::new(),
                seq: 0,
                front_bypassed: 0,
                closed: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Current push sequence number.
    pub fn seq(&self) -> u64 {
        self.inner.lock().unwrap().seq
    }

    /// Enqueue, blocking while the queue is full.  Returns the pending
    /// back inside the error if the queue has been closed, or — with a
    /// shed watermark armed — if a `Batch`-class push arrives while the
    /// queue is at or past the watermark (deadline-aware load
    /// shedding: throughput traffic is turned away first, interactive
    /// traffic keeps its blocking backpressure).
    pub fn push(&self, p: Pending) -> Result<(), PushError> {
        let mut q = self.inner.lock().unwrap();
        loop {
            if q.closed {
                return Err(PushError::Closed(p));
            }
            if policy::should_shed(self.shed_watermark, p.req.class, q.items.len()) {
                return Err(PushError::Shed(p));
            }
            if q.items.len() < self.cap {
                q.items.push_back(p);
                q.seq += 1;
                self.not_empty.notify_all();
                return Ok(());
            }
            q = self.not_full.wait(q).unwrap();
        }
    }

    /// Block until a request is available and pop the batch anchor: the
    /// first interactive request if any, else the front — except that
    /// after [`Self::MAX_FRONT_BYPASS`] consecutive bypasses the front
    /// request is anchored regardless of class (no starvation).
    /// Returns `None` once the queue is closed *and* drained.
    pub fn pop_anchor(&self) -> Option<Pending> {
        let mut q = self.inner.lock().unwrap();
        loop {
            let idx = policy::anchor_index(
                q.items.iter().map(|p| p.req.class),
                q.front_bypassed,
                Self::MAX_FRONT_BYPASS,
            );
            if let Some(i) = idx {
                if i == 0 {
                    q.front_bypassed = 0;
                } else {
                    q.front_bypassed += 1;
                }
                let mut p = q.items.remove(i);
                self.not_full.notify_all();
                if let Some(p) = p.as_mut() {
                    // The request leaves the queue: its queue-wait
                    // phase ends here, whoever anchored it owns it now.
                    p.span.mark(Phase::Queue);
                }
                return p;
            }
            if q.closed {
                return None;
            }
            q = self.not_empty.wait(q).unwrap();
        }
    }

    /// Move every queued request compatible with `(model, kind)` into
    /// `parts` (respecting the request-count and row caps), preserving
    /// queue order.  Returns `(seq, interactive_waiting)`, both read
    /// under the same lock: the current push sequence number (so the
    /// caller cannot miss an arrival between the scan and its next
    /// wait) and whether an interactive request is still queued (so an
    /// open batch window can close early instead of holding it up).
    pub fn take_matching(
        &self,
        model: usize,
        kind: PipelineKind,
        max_requests: usize,
        max_rows: usize,
        parts: &mut Vec<Pending>,
        rows: &mut usize,
    ) -> (u64, bool) {
        let mut q = self.inner.lock().unwrap();
        let mut i = 0;
        let mut took = false;
        while i < q.items.len() {
            if policy::batch_caps_reached(parts.len(), *rows, max_requests, max_rows) {
                break;
            }
            let fits = {
                let p = &q.items[i];
                policy::member_fits(
                    model,
                    kind,
                    *rows,
                    max_rows,
                    p.req.model,
                    p.req.kind,
                    p.req.rows(),
                )
            };
            if fits {
                let mut p = q.items.remove(i).expect("scanned index");
                p.span.mark(Phase::Queue);
                *rows += p.req.rows();
                parts.push(p);
                took = true;
            } else {
                i += 1;
            }
        }
        if took {
            self.not_full.notify_all();
        }
        let interactive_waiting =
            q.items.iter().any(|p| p.req.class == DeadlineClass::Interactive);
        (q.seq, interactive_waiting)
    }

    /// Wait until the push sequence number moves past `seen` or
    /// `deadline` passes.  Returns the new sequence number, or `None` on
    /// deadline/closure.  The deadline is checked *first*, so the batch
    /// window is a hard bound: once it passes, the batch dispatches even
    /// if (incompatible) pushes keep arriving — in particular a zero
    /// window never admits a re-scan.
    pub fn wait_new_push(&self, seen: u64, deadline: Instant) -> Option<u64> {
        let mut q = self.inner.lock().unwrap();
        loop {
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            if q.seq != seen {
                return Some(q.seq);
            }
            if q.closed {
                return None;
            }
            let (guard, _timeout) = self.not_empty.wait_timeout(q, deadline - now).unwrap();
            q = guard;
        }
    }

    /// Close the queue: pushes fail from now on; `pop_anchor` drains the
    /// remainder and then returns `None`.
    pub fn close(&self) {
        let mut q = self.inner.lock().unwrap();
        q.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    /// Queue-test request factory: the pipeline organisation under test
    /// is a parameter (a hardcoded kind used to hide batch-key bugs for
    /// every organisation but the one baked in).
    fn pending(
        id: u64,
        model: usize,
        kind: crate::pe::PipelineKind,
        class: DeadlineClass,
        m: usize,
    ) -> Pending {
        let (tx, _rx) = channel();
        // Leak the receiver end deliberately: these queue tests never
        // reply.
        std::mem::forget(_rx);
        Pending {
            req: Request { id, model, kind, class, a: vec![vec![0u64; 4]; m] },
            reply: tx,
            span: TraceSpan::disabled(),
        }
    }

    use crate::pe::PipelineKind;

    /// The organisations the queue tests sweep: the paper's proposed
    /// design plus a related-work registration, so queue semantics are
    /// pinned independent of the pipeline kind in the request.
    const KINDS: [PipelineKind; 2] = [PipelineKind::Skewed, PipelineKind::Deep3];

    #[test]
    fn fifo_anchor_and_interactive_priority() {
        for kind in KINDS {
            let q = RequestQueue::new(8);
            q.push(pending(0, 0, kind, DeadlineClass::Batch, 1)).unwrap();
            q.push(pending(1, 0, kind, DeadlineClass::Batch, 1)).unwrap();
            q.push(pending(2, 1, kind, DeadlineClass::Interactive, 1)).unwrap();
            // Interactive jumps the line …
            assert_eq!(q.pop_anchor().unwrap().req.id, 2, "{kind}");
            // … then FIFO.
            assert_eq!(q.pop_anchor().unwrap().req.id, 0, "{kind}");
            assert_eq!(q.pop_anchor().unwrap().req.id, 1, "{kind}");
        }
    }

    #[test]
    fn interactive_bypass_cannot_starve_the_front_batch_request() {
        let bound = RequestQueue::MAX_FRONT_BYPASS;
        for kind in KINDS {
            let q = RequestQueue::new(bound + 8);
            q.push(pending(0, 0, kind, DeadlineClass::Batch, 1)).unwrap();
            for id in 1..=(bound as u64 + 2) {
                q.push(pending(id, 1, kind, DeadlineClass::Interactive, 1)).unwrap();
            }
            // The first `bound` pops bypass the batch front…
            for n in 0..bound {
                assert_eq!(q.pop_anchor().unwrap().req.id, n as u64 + 1, "{kind}");
            }
            // …then the starved front is anchored regardless of class.
            assert_eq!(q.pop_anchor().unwrap().req.id, 0, "{kind}: front after {bound}");
            // And the counter reset: interactive priority resumes.
            assert_eq!(q.pop_anchor().unwrap().req.id, bound as u64 + 1, "{kind}");
        }
    }

    #[test]
    fn take_matching_respects_key_and_caps() {
        for kind in KINDS {
            let q = RequestQueue::new(16);
            for id in 0..6 {
                let model = if id % 2 == 0 { 0 } else { 1 };
                q.push(pending(id, model, kind, DeadlineClass::Batch, 2)).unwrap();
            }
            let mut parts = Vec::new();
            let mut rows = 0usize;
            q.take_matching(0, kind, 8, 4, &mut parts, &mut rows);
            // Model-0 requests are ids 0, 2, 4 (2 rows each); the row cap
            // of 4 admits exactly two of them.
            assert_eq!(parts.len(), 2, "{kind}");
            assert_eq!(rows, 4, "{kind}");
            assert!(parts.iter().all(|p| p.req.model == 0), "{kind}");
            assert_eq!(q.len(), 4, "{kind}");
        }
    }

    #[test]
    fn take_matching_filters_on_pipeline_kind() {
        // Mixed-kind traffic on one model: the batch key must separate
        // organisations (stacking rows across kinds would silently run
        // one request under the wrong pipeline).
        let (skewed, deep3) = (PipelineKind::Skewed, PipelineKind::Deep3);
        let q = RequestQueue::new(16);
        q.push(pending(0, 0, skewed, DeadlineClass::Batch, 1)).unwrap();
        q.push(pending(1, 0, deep3, DeadlineClass::Batch, 1)).unwrap();
        q.push(pending(2, 0, skewed, DeadlineClass::Batch, 1)).unwrap();
        let mut parts = Vec::new();
        let mut rows = 0usize;
        q.take_matching(0, skewed, 8, 8, &mut parts, &mut rows);
        let ids: Vec<u64> = parts.iter().map(|p| p.req.id).collect();
        assert_eq!(ids, vec![0, 2]);
        assert_eq!(q.len(), 1, "the deep3 request stays queued");
        assert_eq!(q.pop_anchor().unwrap().req.kind, deep3);
    }

    #[test]
    fn close_drains_then_ends() {
        let q = RequestQueue::new(4);
        q.push(pending(0, 0, PipelineKind::Skewed, DeadlineClass::Batch, 1)).unwrap();
        q.close();
        assert!(q.push(pending(1, 0, PipelineKind::Skewed, DeadlineClass::Batch, 1)).is_err());
        assert_eq!(q.pop_anchor().unwrap().req.id, 0);
        assert!(q.pop_anchor().is_none());
    }

    #[test]
    fn shed_watermark_sheds_batch_but_not_interactive() {
        let q = RequestQueue::with_watermark(8, 2);
        q.push(pending(0, 0, PipelineKind::Skewed, DeadlineClass::Batch, 1)).unwrap();
        q.push(pending(1, 0, PipelineKind::Skewed, DeadlineClass::Batch, 1)).unwrap();
        // At the watermark: throughput traffic is shed …
        let turned_away = pending(2, 0, PipelineKind::Skewed, DeadlineClass::Batch, 1);
        let err = q.push(turned_away).unwrap_err();
        assert!(matches!(err, PushError::Shed(_)), "{err:?}");
        assert_eq!(err.into_pending().req.id, 2);
        // … interactive traffic is not (the cap still has room).
        q.push(pending(3, 0, PipelineKind::Skewed, DeadlineClass::Interactive, 1)).unwrap();
        assert_eq!(q.len(), 3);
        // Draining back below the watermark re-admits batch pushes.
        q.pop_anchor().unwrap();
        q.pop_anchor().unwrap();
        q.push(pending(4, 0, PipelineKind::Skewed, DeadlineClass::Batch, 1)).unwrap();
    }

    #[test]
    fn closed_queue_reports_closed_not_shed() {
        let q = RequestQueue::with_watermark(4, 1);
        q.close();
        let err = q.push(pending(0, 0, PipelineKind::Skewed, DeadlineClass::Batch, 1)).unwrap_err();
        assert!(matches!(err, PushError::Closed(_)), "{err:?}");
    }

    #[test]
    fn rejected_response_is_empty_and_tagged() {
        let r = Response::rejected(7, ResponseStatus::Shed);
        assert_eq!(r.id, 7);
        assert_eq!(r.status, ResponseStatus::Shed);
        assert!(r.y.is_empty());
        assert_eq!(r.batch_size, 0);
    }

    #[test]
    fn wait_new_push_times_out_and_wakes() {
        let q = RequestQueue::new(4);
        let seen = q.seq();
        let deadline = Instant::now() + std::time::Duration::from_millis(5);
        assert_eq!(q.wait_new_push(seen, deadline), None, "timeout with no pushes");
        q.push(pending(0, 0, PipelineKind::Skewed, DeadlineClass::Batch, 1)).unwrap();
        let deadline = Instant::now() + std::time::Duration::from_millis(100);
        assert_eq!(q.wait_new_push(seen, deadline), Some(seen + 1));
    }

    #[test]
    fn window_deadline_at_the_boundary_beats_a_pending_arrival() {
        // The batch window is a hard bound: `wait_new_push` checks the
        // deadline *before* the sequence number, so a window that has
        // expired at exactly the boundary instant reports closure even
        // though a new push is already visible.  This is what makes a
        // zero window never admit a re-scan — the edge case the fleet
        // simulator's virtual-clock batcher mirrors tick-for-tick.
        let q = RequestQueue::new(4);
        let seen = q.seq();
        q.push(pending(0, 0, PipelineKind::Skewed, DeadlineClass::Batch, 1)).unwrap();
        assert!(q.seq() > seen, "an arrival is pending");
        let boundary = Instant::now();
        assert_eq!(q.wait_new_push(seen, boundary), None, "expired window wins the race");
        // An open window still observes the same arrival.
        let open = Instant::now() + std::time::Duration::from_millis(100);
        assert_eq!(q.wait_new_push(seen, open), Some(seen + 1));
    }

    #[test]
    fn shed_watermark_hysteresis_under_oscillating_depth() {
        // Drive the queue depth across the watermark repeatedly: at or
        // above the mark every Batch push sheds; dropping one below the
        // mark re-admits exactly until the mark is reached again.  The
        // policy is memoryless in depth (no sticky overload state), and
        // interactive pushes are admitted at any depth below `cap`.
        let q = RequestQueue::with_watermark(8, 3);
        let mut next_id = 0u64;
        let mut push = |q: &RequestQueue, class| {
            let id = next_id;
            next_id += 1;
            q.push(pending(id, 0, PipelineKind::Skewed, class, 1))
        };
        for cycle in 0..4 {
            // Fill to the watermark from the current depth of 0.
            for _ in 0..3 {
                push(&q, DeadlineClass::Batch).unwrap();
            }
            // At the mark: batch sheds, and keeps shedding while there.
            for _ in 0..2 {
                let err = push(&q, DeadlineClass::Batch).unwrap_err();
                assert!(matches!(err, PushError::Shed(_)), "cycle {cycle}: {err:?}");
            }
            // Interactive is admitted above the mark (depth 3 → 4).
            push(&q, DeadlineClass::Interactive).unwrap();
            assert_eq!(q.len(), 4, "cycle {cycle}");
            // Still ≥ watermark: batch continues to shed.
            assert!(push(&q, DeadlineClass::Batch).is_err(), "cycle {cycle}");
            // Drain to one *below* the mark: one batch push fits again …
            q.pop_anchor().unwrap();
            q.pop_anchor().unwrap();
            assert_eq!(q.len(), 2, "cycle {cycle}");
            push(&q, DeadlineClass::Batch).unwrap();
            // … and the queue is right back at the mark.
            assert!(push(&q, DeadlineClass::Batch).is_err(), "cycle {cycle}");
            // Reset for the next oscillation.
            while !q.is_empty() {
                q.pop_anchor().unwrap();
            }
        }
    }
}
