//! Closed-loop load generation against a [`Server`].
//!
//! `clients` threads each issue `requests_per_client` requests
//! back-to-back (closed loop: a client waits for its response before
//! submitting again), drawing models, batch sizes, pipeline kinds and
//! deadline classes from a seeded stream.  Request generation is a pure
//! function of `(spec, client, index)` — [`gen_request`] — so a bench or
//! test can regenerate any request out-of-band and re-run it solo
//! through a [`crate::coordinator::Coordinator`] for bit-exactness
//! checks.

use super::metrics::{LatencyRecorder, LatencySummary};
use super::request::{try_recv_response, DeadlineClass, ResponseStatus};
use super::server::Server;
use crate::pe::PipelineKind;
use crate::util::rng::Rng;
use crate::workloads::serving::WeightStore;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

/// Load-generation parameters.
#[derive(Clone, Debug)]
pub struct LoadSpec {
    pub clients: usize,
    pub requests_per_client: usize,
    /// Pipeline kinds drawn uniformly per request (must be non-empty).
    pub kinds: Vec<PipelineKind>,
    /// Probability a request is `DeadlineClass::Interactive`.
    pub interactive_fraction: f64,
    /// Activation rows per request, drawn uniformly in this range.
    pub min_rows: usize,
    pub max_rows: usize,
    pub seed: u64,
}

impl LoadSpec {
    /// A small deterministic spec for tests.
    pub fn small() -> LoadSpec {
        LoadSpec {
            clients: 4,
            requests_per_client: 8,
            kinds: vec![PipelineKind::Skewed],
            interactive_fraction: 0.25,
            min_rows: 2,
            max_rows: 6,
            seed: 0x5e12e,
        }
    }
}

/// Outcome of a closed-loop run.
#[derive(Clone, Debug, Default)]
pub struct LoadReport {
    pub latency: LatencySummary,
    pub completed: usize,
    /// Responses whose batch coalesced more than one request.
    pub batched_responses: usize,
    pub max_batch: usize,
    pub cache_hit_responses: usize,
    /// Tile-job retries summed over *responses* — response-weighted: a
    /// batch's retries count once per member, so this over-counts under
    /// batching.  The exact count lives in the shard counters
    /// ([`crate::serve::ShardSnapshot::retries`]), which reports use.
    pub retries_observed: usize,
    /// Simulated service time summed over responses (array cycles of
    /// each response's producing batch — response-weighted like
    /// `retries_observed`).  With the timing model and the streaming
    /// simulator pinned equal, this is the load's total simulated
    /// array-time as the serve layer accounts it.
    pub stream_cycles_observed: u64,
    /// Requests answered with a rejection (shed at the overload
    /// watermark, or arriving after shutdown) — not counted in
    /// `completed` and not latency-recorded.
    pub shed: usize,
    /// Requests whose reply channel was dropped (the shard dropped
    /// their whole batch after retry exhaustion or a timing-model
    /// mismatch).  The pre-fix generator panicked here, killing the
    /// load run a fault-injection bench was specifically watching.
    pub failed: usize,
}

impl LoadReport {
    /// Fraction of responses that shared their batch.
    pub fn batched_fraction(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.batched_responses as f64 / self.completed as f64
        }
    }

    /// Fraction of responses served off a cached plan.
    pub fn cache_hit_fraction(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.cache_hit_responses as f64 / self.completed as f64
        }
    }
}

/// Deterministically generate request `i` of client `client`:
/// `(model, kind, class, activations)`.
pub fn gen_request(
    store: &WeightStore,
    spec: &LoadSpec,
    client: usize,
    i: usize,
) -> (usize, PipelineKind, DeadlineClass, Vec<Vec<u64>>) {
    assert!(!spec.kinds.is_empty());
    assert!(spec.min_rows >= 1 && spec.min_rows <= spec.max_rows);
    let mut rng = Rng::new(
        spec.seed
            ^ (client as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15)
            ^ (i as u64 + 1).wrapping_mul(0xcbf2_9ce4_8422_2325),
    );
    let model = rng.below(store.len() as u64) as usize;
    let m = spec.min_rows + rng.below((spec.max_rows - spec.min_rows + 1) as u64) as usize;
    let kind = *rng_choose(&mut rng, &spec.kinds);
    let class = if rng.chance(spec.interactive_fraction) {
        DeadlineClass::Interactive
    } else {
        DeadlineClass::Batch
    };
    let a = store.gen_activations(model, m, &mut rng);
    (model, kind, class, a)
}

fn rng_choose<'a, T>(rng: &mut Rng, items: &'a [T]) -> &'a T {
    &items[rng.below(items.len() as u64) as usize]
}

/// Drive the server with `spec.clients` closed-loop client threads and
/// collect the latency/throughput report.
pub fn run_closed_loop(server: &Server, spec: &LoadSpec) -> LoadReport {
    let recorder = LatencyRecorder::new();
    let completed = AtomicUsize::new(0);
    let batched = AtomicUsize::new(0);
    let max_batch = AtomicUsize::new(0);
    let cache_hits = AtomicUsize::new(0);
    let retries = AtomicUsize::new(0);
    let stream_cycles = std::sync::atomic::AtomicU64::new(0);
    let shed = AtomicUsize::new(0);
    let failed = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for client in 0..spec.clients {
            let recorder = &recorder;
            let completed = &completed;
            let batched = &batched;
            let max_batch = &max_batch;
            let cache_hits = &cache_hits;
            let retries = &retries;
            let stream_cycles = &stream_cycles;
            let shed = &shed;
            let failed = &failed;
            s.spawn(move || {
                for i in 0..spec.requests_per_client {
                    let (model, kind, class, a) = gen_request(server.store(), spec, client, i);
                    let t0 = Instant::now();
                    let rx = server.submit(model, kind, class, a);
                    let Some(resp) = try_recv_response(&rx, "closed-loop client") else {
                        failed.fetch_add(1, Ordering::Relaxed);
                        continue;
                    };
                    if resp.status != ResponseStatus::Ok {
                        shed.fetch_add(1, Ordering::Relaxed);
                        continue;
                    }
                    recorder.record(t0.elapsed());
                    completed.fetch_add(1, Ordering::Relaxed);
                    if resp.batch_size > 1 {
                        batched.fetch_add(1, Ordering::Relaxed);
                    }
                    max_batch.fetch_max(resp.batch_size, Ordering::Relaxed);
                    if resp.cache_hit {
                        cache_hits.fetch_add(1, Ordering::Relaxed);
                    }
                    retries.fetch_add(resp.retries, Ordering::Relaxed);
                    stream_cycles.fetch_add(resp.batch_stream_cycles, Ordering::Relaxed);
                }
            });
        }
    });
    LoadReport {
        latency: recorder.summary(),
        completed: completed.into_inner(),
        batched_responses: batched.into_inner(),
        max_batch: max_batch.into_inner(),
        cache_hit_responses: cache_hits.into_inner(),
        retries_observed: retries.into_inner(),
        stream_cycles_observed: stream_cycles.into_inner(),
        shed: shed.into_inner(),
        failed: failed.into_inner(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::format::FpFormat;
    use crate::config::{RunConfig, ServeConfig};
    use crate::workloads::mobilenet;
    use std::sync::Arc;

    #[test]
    fn gen_request_is_deterministic_and_in_bounds() {
        let store = WeightStore::from_layers(&mobilenet::layers()[..4], FpFormat::BF16, 24, 16);
        let spec = LoadSpec::small();
        let (m1, k1, c1, a1) = gen_request(&store, &spec, 2, 5);
        let (m2, k2, c2, a2) = gen_request(&store, &spec, 2, 5);
        assert_eq!((m1, k1, c1), (m2, k2, c2));
        assert_eq!(a1, a2);
        assert!(m1 < store.len());
        assert!((spec.min_rows..=spec.max_rows).contains(&a1.len()));
        // Distinct indices draw distinct streams.
        let (_, _, _, a3) = gen_request(&store, &spec, 2, 6);
        assert_ne!(a1, a3);
    }

    #[test]
    fn closed_loop_completes_and_reports() {
        let mut run = RunConfig::small();
        run.verify_fraction = 0.0;
        let store = Arc::new(WeightStore::from_layers(
            &mobilenet::layers()[..3],
            FpFormat::BF16,
            24,
            16,
        ));
        let server = Server::start(&run, &ServeConfig::small(), store);
        let spec = LoadSpec { clients: 3, requests_per_client: 5, ..LoadSpec::small() };
        let report = run_closed_loop(&server, &spec);
        assert_eq!(report.completed, 15);
        assert_eq!(report.latency.count, 15);
        assert!(report.latency.p50_us > 0.0);
        assert!(report.max_batch >= 1);
        let stats = server.stats();
        assert_eq!(stats.submitted, 15);
        let served: u64 = stats.shards.iter().map(|s| s.requests).sum();
        assert_eq!(served, 15);
    }
}
