//! The multi-tenant GEMM serving layer (DESIGN.md §11).
//!
//! The coordinator answers "run this one GEMM"; this subsystem answers
//! "serve a stream of them".  The paper's per-tile latency win (skewed
//! pipelines drain an `R`-deep column in half the cycles) only turns
//! into end-to-end throughput if the system in front of the arrays
//! keeps them streaming — which is what this request path does:
//!
//! ```text
//!  clients ──▶ RequestQueue ──▶ Batcher ──▶ PlanCache ──▶ ShardPool
//!   (submit)    (bounded,        (dynamic     (memoised     (N arrays,
//!    ▲           backpressure)    batching)    TilePlan +    persistent
//!    └──────────────── responses ◀─────────────WsSchedule)   pools)
//! ```
//!
//! * [`request`] — request/response types + the bounded front queue;
//! * [`batcher`] — deadline-class-windowed dynamic batching (stacking
//!   compatible requests' activation rows is bit-exact per row);
//! * [`cache`] — the plan cache keyed by
//!   `(GemmShape, FpFormat, PipelineKind, rows, cols)`;
//! * [`shard`] — N simulated array chips behind the shard-level
//!   [`crate::coordinator::Router`], each owning a persistent
//!   [`crate::coordinator::WorkerPool`];
//! * [`server`] — the facade wiring the pipeline together;
//! * [`health`] — per-shard rolling fault windows feeding the
//!   quarantine/probation state machine (DESIGN.md §16);
//! * [`metrics`] — p50/p95/p99 latency + throughput recording;
//! * [`loadgen`] — the closed-loop load generator behind
//!   `skewsa serve` and `bench_serve`;
//! * [`policy`] — the clock-agnostic policy core (shed watermark,
//!   anchor selection, batch admission, early window close) shared
//!   verbatim with the fleet discrete-event simulator
//!   ([`crate::fleet`], DESIGN.md §18).
//!
//! Observability (DESIGN.md §17) threads a [`crate::obs::TraceSpan`]
//! through every request (queue → batch → plan → dispatch → execute →
//! reply, plus per-batch array-cycle attribution) and mirrors every
//! counter scattered across this subsystem into the unified
//! [`crate::obs::MetricsRegistry`] via [`Server::metrics`].
//!
//! Fault tolerance (DESIGN.md §16) threads through the same path: the
//! [`crate::coordinator::FaultModel`] configured on
//! [`crate::config::ServeConfig`] injects SDCs inside each shard's
//! worker pool, ABFT checksums detect and recover them there, shard
//! health feeds quarantine-aware dispatch, and batch-class requests
//! over the queue's shed watermark are answered immediately with
//! [`ResponseStatus::Shed`] instead of deepening the overload.
//!
//! Mixed-precision plans (DESIGN.md §12) deploy through this stack
//! unchanged: [`crate::workloads::serving::WeightStore::from_plan`]
//! registers each layer in its planned format, requests inherit the
//! model's format, and the plan cache — keyed on `FpFormat` — memoises
//! each precision's tile plans separately.
//!
//! End-to-end shape of the API:
//!
//! ```
//! use std::sync::Arc;
//! use skewsa::config::{RunConfig, ServeConfig};
//! use skewsa::serve::{DeadlineClass, Server};
//! use skewsa::workloads::{mobilenet, serving::WeightStore};
//! use skewsa::{FpFormat, PipelineKind};
//!
//! let mut run = RunConfig::small();
//! run.verify_fraction = 0.0;
//! let store = Arc::new(WeightStore::from_layers(
//!     &mobilenet::layers()[..2], FpFormat::BF16, 16, 8));
//! let server = Server::start(&run, &ServeConfig::small(), Arc::clone(&store));
//! let a = store.gen_activations(0, 2, &mut skewsa::util::rng::Rng::new(1));
//! let reply = server.submit(0, PipelineKind::Skewed, DeadlineClass::Interactive, a);
//! let resp = reply.recv().unwrap();
//! assert_eq!(resp.y.len(), 2 * store.get(0).n);
//! ```

pub mod batcher;
pub mod cache;
pub mod health;
pub mod loadgen;
pub mod metrics;
pub mod policy;
pub mod request;
pub mod server;
pub mod shard;

pub use batcher::{Batch, BatchKey, BatchLimits, Batcher};
pub use cache::{CacheStats, CachedPlan, PlanCache, PlanKey};
pub use health::{HealthBoard, HealthPolicy, ShardState};
pub use loadgen::{gen_request, run_closed_loop, LoadReport, LoadSpec};
pub use metrics::{percentile_ns, LatencyRecorder, LatencySummary};
pub use request::{
    recv_response, try_recv_response, DeadlineClass, Pending, PushError, Request, RequestQueue,
    Response, ResponseStatus,
};
pub use server::{Server, ServerStats};
pub use shard::{BatchJob, ReplyPart, ShardPool, ShardSnapshot};
