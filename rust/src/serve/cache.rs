//! Plan cache: memoised `TilePlan` + `WsSchedule` construction.
//!
//! Serving traffic is shape-repetitive — every MobileNet/ResNet50 layer
//! is a fixed `(K, N)` and the batcher quantises `M` through its size
//! caps — so hot shapes re-plan constantly without a cache.  Entries are
//! keyed by `(GemmShape, FpFormat, PipelineKind, ArrayGeometry)` and hold
//! the tile decomposition, the per-tile weight-stationary schedules and
//! the closed-form stream-cycle total.  Eviction is LRU beyond a fixed
//! capacity.
//!
//! The contract the property tests pin down: a cache *hit* is
//! structurally identical to a freshly built plan — caching can never
//! change what runs.
//!
//! Because `FpFormat` is part of the key, mixed-precision serving
//! (DESIGN.md §12: a [`crate::precision::PrecisionPlan`] deployed via
//! `WeightStore::from_plan`) needs no cache changes — each layer's
//! chosen format memoises its own tile plans alongside the others.

use crate::arith::format::FpFormat;
use crate::obs::CycleAttribution;
use crate::pe::PipelineKind;
use crate::sa::dataflow::WsSchedule;
use crate::sa::geometry::ArrayGeometry;
use crate::sa::tile::{GemmShape, TilePlan};
use crate::timing::{layer_timing, TimingConfig};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Cache key: everything plan + schedule construction depends on.
///
/// The key includes the full `GemmShape` — `m` included — so the
/// memoised per-tile `WsSchedule`s (which are `m`-dependent) can be
/// stored ready-to-use.  Variable-size batches of the same model
/// therefore miss across distinct `m` values; that is deliberate: a
/// miss only rebuilds a `TilePlan` (tile decomposition is `m`-free and
/// O(tiles)), microseconds against the batch it plans, while fixed-`m`
/// traffic — the steady state of a shaped client fleet — hits.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub shape: GemmShape,
    pub fmt: FpFormat,
    pub kind: PipelineKind,
    /// The array shape the plan targets.  Heterogeneous pools score one
    /// batch against several geometries, so each shard's shape memoises
    /// its own plans side by side in one cache.
    pub geom: ArrayGeometry,
}

impl PlanKey {
    /// The same batch re-keyed for a different shard geometry (the
    /// shape-aware router's scoring probe).
    pub fn with_geometry(self, geom: ArrayGeometry) -> PlanKey {
        PlanKey { geom, ..self }
    }
}

/// A memoised planning result.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CachedPlan {
    pub plan: TilePlan,
    /// Per-tile weight-stationary schedules, in plan order.
    pub schedules: Vec<WsSchedule>,
    /// Closed-form service time with **double-buffered** weight preload
    /// (tile `i+1`'s fill hides under tile `i`'s stream) — equal to
    /// [`crate::timing::layer_timing`] under the crate-default
    /// `double_buffer: true`.  The pre-fix cache only held the
    /// serialized number, so the serve layer quoted a latency the
    /// timing model (and now the streaming cycle simulator) contradicts.
    pub stream_cycles_overlapped: u64,
    /// Closed-form service time with every reload serialized after the
    /// previous drain (the single-bank ablation).
    pub stream_cycles_serialized: u64,
    /// Cycle-domain decomposition of the overlapped service time
    /// (exposed preload / compute / drain — [`crate::timing::layer_timing`]'s
    /// taxonomy), memoised here so trace spans attribute cycles without
    /// re-deriving schedules per batch.
    pub breakdown_overlapped: CycleAttribution,
    /// As [`CachedPlan::breakdown_overlapped`], serialized reloads.
    pub breakdown_serialized: CycleAttribution,
}

impl CachedPlan {
    /// Build from scratch (what a cache miss does; also what the
    /// property tests compare hits against).  The serialized total is
    /// derived from the memoised schedules — they are built exactly
    /// once per cache entry — and the overlapped total hides every fill
    /// but the first (`T > R` for every tile; see the layer model's
    /// two-buffer audit).
    pub fn build(key: &PlanKey) -> CachedPlan {
        let plan = TilePlan::for_geometry(key.shape, key.geom);
        let schedules = plan.schedules(key.kind);
        let stream_cycles_serialized =
            schedules.iter().map(|s| s.preload_cycles() + s.total_cycles()).sum();
        let stream_cycles_overlapped = plan.stream_cycles(key.kind, true);
        debug_assert_eq!(stream_cycles_serialized, plan.stream_cycles(key.kind, false));
        let tcfg = |db| TimingConfig::for_geometry(key.geom, 1.0, db);
        let breakdown_overlapped =
            CycleAttribution::from_layer_timing(&layer_timing(&tcfg(true), key.kind, &plan));
        let breakdown_serialized =
            CycleAttribution::from_layer_timing(&layer_timing(&tcfg(false), key.kind, &plan));
        debug_assert_eq!(breakdown_overlapped.stream_total(), stream_cycles_overlapped);
        debug_assert_eq!(breakdown_serialized.stream_total(), stream_cycles_serialized);
        CachedPlan {
            plan,
            schedules,
            stream_cycles_overlapped,
            stream_cycles_serialized,
            breakdown_overlapped,
            breakdown_serialized,
        }
    }

    /// The service-time denominator for the configured preload
    /// discipline (one number with the timing model and the streaming
    /// cycle simulator — pinned by `tests/integration_serve.rs`).
    pub fn stream_cycles(&self, double_buffer: bool) -> u64 {
        if double_buffer {
            self.stream_cycles_overlapped
        } else {
            self.stream_cycles_serialized
        }
    }

    /// Cycle attribution for the configured preload discipline; its
    /// [`CycleAttribution::stream_total`] equals
    /// [`CachedPlan::stream_cycles`] for the same `double_buffer`.
    pub fn breakdown(&self, double_buffer: bool) -> CycleAttribution {
        if double_buffer {
            self.breakdown_overlapped
        } else {
            self.breakdown_serialized
        }
    }
}

/// Cache counters (monotone; `entries` is the current size).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub entries: usize,
}

impl CacheStats {
    /// Hit fraction over all lookups (0 when nothing was looked up).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Entry {
    plan: Arc<CachedPlan>,
    last_used: u64,
}

struct CacheInner {
    map: HashMap<PlanKey, Entry>,
    tick: u64,
}

/// Thread-safe memoising plan cache with LRU eviction.
pub struct PlanCache {
    cap: usize,
    inner: Mutex<CacheInner>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl PlanCache {
    pub fn new(cap: usize) -> PlanCache {
        PlanCache {
            cap: cap.max(1),
            inner: Mutex::new(CacheInner { map: HashMap::new(), tick: 0 }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Look up (or build + insert) the plan for `key`.  The second
    /// element is `true` on a hit.
    pub fn get(&self, key: PlanKey) -> (Arc<CachedPlan>, bool) {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(e) = inner.map.get_mut(&key) {
            e.last_used = tick;
            self.hits.fetch_add(1, Ordering::Relaxed);
            return (Arc::clone(&e.plan), true);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        if inner.map.len() >= self.cap {
            // Evict the least-recently-used entry.
            let victim = inner.map.iter().min_by_key(|(_, e)| e.last_used).map(|(k, _)| *k);
            if let Some(victim) = victim {
                inner.map.remove(&victim);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        let plan = Arc::new(CachedPlan::build(&key));
        inner.map.insert(key, Entry { plan: Arc::clone(&plan), last_used: tick });
        (plan, false)
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries: self.inner.lock().unwrap().map.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(m: usize, k: usize, n: usize) -> PlanKey {
        PlanKey {
            shape: GemmShape::new(m, k, n),
            fmt: FpFormat::BF16,
            kind: PipelineKind::Skewed,
            geom: ArrayGeometry { rows: 8, cols: 8 },
        }
    }

    #[test]
    fn hit_returns_identical_plan_and_counts() {
        let c = PlanCache::new(8);
        let (first, hit1) = c.get(key(4, 20, 12));
        assert!(!hit1);
        let (second, hit2) = c.get(key(4, 20, 12));
        assert!(hit2);
        assert_eq!(*first, *second);
        assert_eq!(*second, CachedPlan::build(&key(4, 20, 12)));
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn distinct_keys_do_not_collide() {
        let c = PlanCache::new(8);
        let (a, _) = c.get(key(4, 20, 12));
        let mut k2 = key(4, 20, 12);
        k2.kind = PipelineKind::Baseline3b;
        let (b, hit) = c.get(k2);
        assert!(!hit, "kind is part of the key");
        // Same tiles, different schedules/cycles.
        assert_eq!(a.plan, b.plan);
        assert_ne!(a.stream_cycles_overlapped, b.stream_cycles_overlapped);
        let mut k3 = key(4, 20, 12);
        k3.fmt = FpFormat::FP8E4M3;
        assert!(!c.get(k3).1, "format is part of the key");
        let k4 = key(4, 20, 12).with_geometry(ArrayGeometry { rows: 16, cols: 4 });
        let (d, hit) = c.get(k4);
        assert!(!hit, "geometry is part of the key");
        assert_eq!(d.plan.geometry(), ArrayGeometry { rows: 16, cols: 4 });
        assert_ne!(a.plan, d.plan, "different geometry, different tiles");
    }

    #[test]
    fn lru_eviction_beyond_capacity() {
        let c = PlanCache::new(2);
        c.get(key(1, 8, 8));
        c.get(key(2, 8, 8));
        // Touch the first so the second becomes LRU.
        c.get(key(1, 8, 8));
        c.get(key(3, 8, 8)); // evicts key(2, ..)
        let s = c.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.entries, 2);
        assert!(c.get(key(1, 8, 8)).1, "recently used survived");
        assert!(!c.get(key(2, 8, 8)).1, "LRU victim was evicted");
    }

    #[test]
    fn stream_cycles_match_plan_helpers() {
        let c = PlanCache::new(4);
        let k = key(6, 20, 10);
        let (p, _) = c.get(k);
        for db in [true, false] {
            assert_eq!(p.stream_cycles(db), p.plan.stream_cycles(k.kind, db), "db={db}");
        }
        assert!(p.stream_cycles_overlapped < p.stream_cycles_serialized);
        assert_eq!(p.schedules, p.plan.schedules(k.kind));
    }

    #[test]
    fn breakdown_matches_layer_timing() {
        use crate::timing::{layer_timing, TimingConfig};
        let c = PlanCache::new(4);
        let k = key(6, 20, 10);
        let (p, _) = c.get(k);
        for db in [true, false] {
            let bd = p.breakdown(db);
            assert_eq!(bd.stream_total(), p.stream_cycles(db), "db={db}");
            assert_eq!(bd.recovery, 0, "clean plan carries no recovery cycles");
            let cfg = TimingConfig::for_geometry(k.geom, 1.0, db);
            let lt = layer_timing(&cfg, k.kind, &p.plan);
            assert_eq!(bd.exposed_preload, lt.exposed_preload, "db={db}");
            assert_eq!(bd.drain, lt.drain_cycles, "db={db}");
            assert_eq!(bd.compute, lt.compute_cycles - lt.drain_cycles, "db={db}");
        }
    }
}
