//! Shard health tracking: rolling fault windows feeding a
//! quarantine / probation state machine (DESIGN.md §16).
//!
//! Every completed batch reports a fault count for its shard (detected
//! or unresolved SDCs, or a wholesale batch failure); the board keeps a
//! rolling window of the last few batches per shard.  A shard whose
//! window crosses the fault threshold is *quarantined*: the dispatcher
//! excludes it for a fixed number of dispatch ticks (the board's
//! clock), after which it re-enters on *probation* — it takes traffic
//! again, but a single faulty batch sends it straight back to
//! quarantine, while a run of clean batches re-admits it as healthy.
//!
//! Exclusion is advisory in the limit: if every shard is quarantined at
//! once the exclusion set is void (matching the worker-level
//! [`crate::coordinator::Router`] contract) — a degraded server keeps
//! serving rather than deadlocking.

use crate::obs::{Counter, Obs, SpanSink};
use std::collections::{BTreeSet, VecDeque};
use std::sync::{Arc, Mutex};

/// Knobs of the quarantine state machine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HealthPolicy {
    /// Rolling window length, in batches per shard.
    pub window: usize,
    /// Fault count within the window that triggers quarantine.
    pub fault_threshold: u64,
    /// Dispatch ticks a quarantined shard sits out.
    pub quarantine_batches: u64,
    /// Clean batches a probationary shard must serve to be healthy.
    pub probation_batches: u64,
}

impl Default for HealthPolicy {
    fn default() -> Self {
        HealthPolicy { window: 8, fault_threshold: 3, quarantine_batches: 16, probation_batches: 8 }
    }
}

/// Where a shard stands in the quarantine state machine.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ShardState {
    /// Taking traffic, rolling window armed.
    #[default]
    Healthy,
    /// Excluded from dispatch until the board clock reaches `until`.
    Quarantined { until: u64 },
    /// Taking traffic again; `remaining` clean batches to re-admission,
    /// any fault re-quarantines.
    Probation { remaining: u64 },
}

impl std::fmt::Display for ShardState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShardState::Healthy => write!(f, "healthy"),
            ShardState::Quarantined { until } => write!(f, "quarantined(until tick {until})"),
            ShardState::Probation { remaining } => write!(f, "probation({remaining} to go)"),
        }
    }
}

#[derive(Default)]
struct ShardHealth {
    state: ShardState,
    /// Fault counts of the most recent batches, newest last.
    window: VecDeque<u64>,
    /// Times this shard has entered quarantine (reporting).
    quarantines: u64,
}

struct Inner {
    /// Advances once per dispatch; quarantine expiry is measured in
    /// dispatch ticks so an idle server does not silently pardon shards.
    clock: u64,
    shards: Vec<ShardHealth>,
}

/// Observability hooks of the board: the `health_transitions.*`
/// counter family plus (when tracing) timestamped trace events, so a
/// chaos run shows *when* each shard was benched and re-admitted —
/// not just the final tally.
struct BoardObs {
    sink: Option<Arc<SpanSink>>,
    quarantined: Counter,
    probation: Counter,
    healthy: Counter,
}

impl BoardObs {
    /// Emit one transition: bump its counter, and trace it (timestamped
    /// wall clock + board clock) when a sink is attached.
    fn transition(&self, label: &str, shard: usize, clock: u64) {
        match label {
            "quarantined" => self.quarantined.inc(),
            "probation" => self.probation.inc(),
            _ => self.healthy.inc(),
        }
        if let Some(sink) = &self.sink {
            sink.event("health", label, shard, clock);
        }
    }
}

/// Shared health state: one entry per shard, ticked by the dispatcher.
pub struct HealthBoard {
    policy: HealthPolicy,
    inner: Mutex<Inner>,
    obs: Option<BoardObs>,
}

impl HealthBoard {
    pub fn new(policy: HealthPolicy, shards: usize) -> HealthBoard {
        let shards = (0..shards.max(1)).map(|_| ShardHealth::default()).collect();
        HealthBoard { policy, inner: Mutex::new(Inner { clock: 0, shards }), obs: None }
    }

    /// As [`HealthBoard::new`], publishing every state-machine
    /// transition to `obs`: the `health_transitions.{quarantined,
    /// probation,healthy}` counters (pre-registered so they appear in
    /// snapshots even at zero) and, when tracing is on, a timestamped
    /// trace event per transition.
    pub fn with_obs(policy: HealthPolicy, shards: usize, obs: &Obs) -> HealthBoard {
        let mut b = Self::new(policy, shards);
        b.obs = Some(BoardObs {
            sink: obs.sink.clone(),
            quarantined: obs.registry.counter("health_transitions.quarantined"),
            probation: obs.registry.counter("health_transitions.probation"),
            healthy: obs.registry.counter("health_transitions.healthy"),
        });
        b
    }

    fn emit(&self, label: &str, shard: usize, clock: u64) {
        if let Some(o) = &self.obs {
            o.transition(label, shard, clock);
        }
    }

    pub fn policy(&self) -> HealthPolicy {
        self.policy
    }

    /// Advance the dispatch clock and promote expired quarantines to
    /// probation.  Call once per dispatched batch, before routing.
    pub fn tick(&self) {
        let mut g = self.inner.lock().unwrap();
        g.clock += 1;
        let clock = g.clock;
        let probation = self.policy.probation_batches.max(1);
        for (i, s) in g.shards.iter_mut().enumerate() {
            if let ShardState::Quarantined { until } = s.state {
                if clock >= until {
                    s.state = ShardState::Probation { remaining: probation };
                    self.emit("probation", i, clock);
                }
            }
        }
    }

    /// Shards the router must avoid (currently quarantined).  Void when
    /// every shard is quarantined: a fully degraded pool keeps serving.
    pub fn excluded(&self) -> BTreeSet<usize> {
        let g = self.inner.lock().unwrap();
        let out: BTreeSet<usize> = g
            .shards
            .iter()
            .enumerate()
            .filter(|(_, s)| matches!(s.state, ShardState::Quarantined { .. }))
            .map(|(i, _)| i)
            .collect();
        if out.len() >= g.shards.len() {
            BTreeSet::new()
        } else {
            out
        }
    }

    /// Record one completed batch on `shard` with `faults` health-
    /// relevant events (detected/unresolved SDCs, or 1 for a failed
    /// batch) and run the state machine.
    pub fn record(&self, shard: usize, faults: u64) {
        let mut g = self.inner.lock().unwrap();
        let clock = g.clock;
        let quarantine = ShardState::Quarantined { until: clock + self.policy.quarantine_batches };
        let s = &mut g.shards[shard];
        match s.state {
            // A straggler batch finishing while quarantined neither
            // extends nor clears the sentence.
            ShardState::Quarantined { .. } => {}
            ShardState::Probation { remaining } => {
                if faults > 0 {
                    s.quarantines += 1;
                    s.window.clear();
                    s.state = quarantine;
                    self.emit("quarantined", shard, clock);
                } else if remaining <= 1 {
                    s.state = ShardState::Healthy;
                    self.emit("healthy", shard, clock);
                } else {
                    s.state = ShardState::Probation { remaining: remaining - 1 };
                }
            }
            ShardState::Healthy => {
                s.window.push_back(faults);
                while s.window.len() > self.policy.window {
                    s.window.pop_front();
                }
                if s.window.iter().sum::<u64>() >= self.policy.fault_threshold {
                    s.quarantines += 1;
                    s.window.clear();
                    s.state = quarantine;
                    self.emit("quarantined", shard, clock);
                }
            }
        }
    }

    /// Current state of one shard.
    pub fn state(&self, shard: usize) -> ShardState {
        self.inner.lock().unwrap().shards[shard].state
    }

    /// How many times each shard has been quarantined.
    pub fn quarantine_counts(&self) -> Vec<u64> {
        self.inner.lock().unwrap().shards.iter().map(|s| s.quarantines).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> HealthPolicy {
        HealthPolicy { window: 4, fault_threshold: 3, quarantine_batches: 5, probation_batches: 2 }
    }

    #[test]
    fn crossing_the_threshold_quarantines_and_probation_readmits() {
        let b = HealthBoard::new(policy(), 2);
        // Three faulty batches on shard 1 cross the threshold.
        for _ in 0..3 {
            assert_eq!(b.state(1), ShardState::Healthy);
            b.tick();
            b.record(1, 1);
        }
        assert_eq!(b.state(1), ShardState::Quarantined { until: 3 + 5 });
        assert_eq!(b.excluded(), BTreeSet::from([1]));
        assert_eq!(b.quarantine_counts(), vec![0, 1]);
        // Five more dispatch ticks (served by shard 0) expire the
        // sentence into probation …
        for _ in 0..5 {
            b.tick();
            b.record(0, 0);
        }
        assert_eq!(b.state(1), ShardState::Probation { remaining: 2 });
        assert!(b.excluded().is_empty(), "probation takes traffic");
        // … and two clean batches re-admit the shard.
        b.tick();
        b.record(1, 0);
        assert_eq!(b.state(1), ShardState::Probation { remaining: 1 });
        b.tick();
        b.record(1, 0);
        assert_eq!(b.state(1), ShardState::Healthy);
        assert_eq!(b.quarantine_counts(), vec![0, 1]);
    }

    #[test]
    fn fault_during_probation_requarantines() {
        let b = HealthBoard::new(policy(), 1);
        for _ in 0..3 {
            b.tick();
            b.record(0, 1);
        }
        for _ in 0..5 {
            b.tick();
        }
        assert!(matches!(b.state(0), ShardState::Probation { .. }));
        b.tick();
        b.record(0, 2);
        assert!(matches!(b.state(0), ShardState::Quarantined { .. }));
        assert_eq!(b.quarantine_counts(), vec![2]);
    }

    #[test]
    fn window_rolls_off_old_faults() {
        let b = HealthBoard::new(policy(), 1);
        // Two faults, then enough clean batches to roll them out of the
        // 4-batch window: no quarantine.
        b.tick();
        b.record(0, 2);
        for _ in 0..4 {
            b.tick();
            b.record(0, 0);
        }
        b.tick();
        b.record(0, 2);
        assert_eq!(b.state(0), ShardState::Healthy, "2+2 faults never shared a window");
    }

    #[test]
    fn exclusion_of_every_shard_is_void() {
        let b = HealthBoard::new(policy(), 2);
        for shard in 0..2 {
            for _ in 0..3 {
                b.tick();
                b.record(shard, 1);
            }
        }
        assert!(matches!(b.state(0), ShardState::Quarantined { .. }));
        assert!(matches!(b.state(1), ShardState::Quarantined { .. }));
        assert!(b.excluded().is_empty(), "fully degraded pool keeps serving");
    }

    #[test]
    fn straggler_batches_do_not_extend_quarantine() {
        let b = HealthBoard::new(policy(), 1);
        for _ in 0..3 {
            b.tick();
            b.record(0, 1);
        }
        let ShardState::Quarantined { until } = b.state(0) else {
            panic!("not quarantined");
        };
        b.record(0, 5); // in-flight batch retiring late
        assert_eq!(b.state(0), ShardState::Quarantined { until });
    }

    #[test]
    fn quarantine_expiry_and_fault_on_the_same_dispatch_tick() {
        // The dispatch order is tick-then-record: the tick that ends a
        // shard's quarantine promotes it to probation *before* any
        // batch completing on that same tick reports its faults.  A
        // fault landing on the expiry tick therefore hits a probationary
        // shard and re-quarantines it immediately — the sentence is not
        // silently extended, and the fault is not absorbed by the stale
        // quarantined state (where `record` is a no-op).
        let b = HealthBoard::new(policy(), 1);
        for _ in 0..3 {
            b.tick();
            b.record(0, 1);
        }
        let ShardState::Quarantined { until } = b.state(0) else {
            panic!("not quarantined");
        };
        // Advance to one tick before expiry: still quarantined.
        for _ in 0..policy().quarantine_batches - 1 {
            b.tick();
            assert!(matches!(b.state(0), ShardState::Quarantined { .. }));
        }
        // The expiry tick itself promotes to probation …
        b.tick();
        assert_eq!(b.state(0), ShardState::Probation { remaining: 2 });
        // … and a fault recorded on this same dispatch tick (a batch
        // completing as the sentence ends) re-quarantines immediately.
        b.record(0, 1);
        let ShardState::Quarantined { until: until2 } = b.state(0) else {
            panic!("fault on the expiry tick must re-quarantine");
        };
        assert_eq!(until2, until + 5, "new sentence starts at the expiry tick");
        assert_eq!(b.quarantine_counts(), vec![2]);
        assert_eq!(b.excluded(), BTreeSet::new(), "single-shard exclusion stays void");
    }

    #[test]
    fn transitions_emit_counters_and_timestamped_events() {
        let obs = crate::obs::Obs::with_tracing();
        let b = HealthBoard::with_obs(policy(), 1, &obs);
        for _ in 0..3 {
            b.tick();
            b.record(0, 1); // third record quarantines
        }
        for _ in 0..5 {
            b.tick(); // sentence expires into probation
        }
        b.tick();
        b.record(0, 0);
        b.tick();
        b.record(0, 0); // second clean batch re-admits
        let snap = obs.registry.snapshot();
        assert_eq!(snap.counter("health_transitions.quarantined"), 1);
        assert_eq!(snap.counter("health_transitions.probation"), 1);
        assert_eq!(snap.counter("health_transitions.healthy"), 1);
        let events = obs.sink.as_ref().unwrap().events();
        let labels: Vec<&str> = events.iter().map(|e| e.label.as_str()).collect();
        assert_eq!(labels, ["quarantined", "probation", "healthy"]);
        assert!(events.iter().all(|e| e.kind == "health" && e.shard == 0));
        // Timestamps are monotone and the board clock advances.
        assert!(events.windows(2).all(|w| w[0].t_ns <= w[1].t_ns));
        assert!(events.windows(2).all(|w| w[0].clock < w[1].clock));
    }

    #[test]
    fn counters_exist_at_zero_before_any_transition() {
        let obs = crate::obs::Obs::new();
        let _b = HealthBoard::with_obs(policy(), 2, &obs);
        let snap = obs.registry.snapshot();
        assert!(snap.counters.contains_key("health_transitions.quarantined"));
        assert_eq!(snap.counter_sum("health_transitions."), 0);
    }
}
