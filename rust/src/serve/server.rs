//! The serving facade: queue → batcher → plan cache → shards → replies.
//!
//! [`Server::start`] wires the pipeline up (DESIGN.md §11): a batcher
//! thread drains the bounded [`RequestQueue`] into coalesced batches,
//! memoises planning through the [`PlanCache`], stacks member
//! activations into one `GemmData` sharing the model's weights, and
//! routes the batch to a shard; the shard executes on its persistent
//! worker pool and fans responses back out per request.  Clients only
//! ever see [`Server::submit`] → a reply receiver.
//!
//! Dropping the server closes the queue, drains in-flight work, and
//! joins every thread — no request accepted before shutdown is lost.

use super::batcher::{Batch, Batcher, BatchLimits};
use super::cache::{CacheStats, PlanCache, PlanKey};
use super::health::ShardState;
use super::request::{
    DeadlineClass, Pending, PushError, Request, RequestQueue, Response, ResponseStatus,
};
use super::shard::{BatchJob, ReplyPart, ShardPool, ShardSnapshot};
use crate::arith::fma::ChainCfg;
use crate::arith::format::FpFormat;
use crate::config::{NumericMode, RunConfig, ServeConfig};
use crate::coordinator::router::Policy;
use crate::coordinator::{FaultModel, FaultPlan};
use crate::obs::{MetricsSnapshot, Obs, Phase, SpanStatus};
use crate::pe::PipelineKind;
use crate::sa::geometry::ArrayGeometry;
use crate::sa::tile::GemmShape;
use crate::workloads::gemm::GemmData;
use crate::workloads::serving::WeightStore;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::time::Duration;

/// Aggregate serving statistics.
#[derive(Clone, Debug)]
pub struct ServerStats {
    /// Requests accepted so far.
    pub submitted: u64,
    /// Requests turned away at the shed watermark (overload).
    pub shed: u64,
    pub cache: CacheStats,
    pub shards: Vec<ShardSnapshot>,
}

/// Planning + dispatch context owned by the batcher thread.
struct Dispatcher {
    store: Arc<WeightStore>,
    cache: Arc<PlanCache>,
    shards: Arc<ShardPool>,
    /// Per-shard array geometry ([`ServeConfig::shard_geometry`]); a
    /// uniform pool repeats the run geometry.  Every batch is planned
    /// under the geometry of the shard that will execute it.
    geoms: Vec<ArrayGeometry>,
    /// The shard-level routing policy.  [`Policy::ShapeAware`] scores
    /// this dispatcher's plan-cache predictions; rr/ll let the pool's
    /// router pick first and plan for its choice.
    policy: Policy,
    out_fmt: FpFormat,
    mode: NumericMode,
    /// Weight-preload discipline (from [`RunConfig::double_buffer`]):
    /// selects the service-time number every response reports and, in
    /// cycle-accurate mode, how the streaming simulator chains tiles.
    double_buffer: bool,
}

impl Dispatcher {
    fn dispatch(&self, batch: Batch) {
        let model = self.store.get(batch.key.model);
        let shape = GemmShape::new(batch.rows, model.k, model.n);
        let base = PlanKey { shape, fmt: model.fmt, kind: batch.key.kind, geom: self.geoms[0] };
        let scored = self.policy == Policy::ShapeAware;
        let (target, plan, cache_hit) = if scored {
            // Score every dispatch-eligible shard: this batch's
            // predicted stream cycles under that shard's geometry,
            // straight from the geometry-keyed plan cache.  The pick is
            // deterministic (min cycles, ties toward the lower index,
            // no load term) so the fleet DES replays these routing
            // decisions request-for-request (DESIGN.md §18, §20).
            let probes: Vec<_> = self
                .shards
                .eligible_shards()
                .into_iter()
                .map(|s| {
                    let (plan, hit) = self.cache.get(base.with_geometry(self.geoms[s]));
                    (s, plan, hit)
                })
                .collect();
            let best = crate::serve::policy::best_fit_shard(
                probes.iter().map(|&(s, ref p, _)| (s, p.stream_cycles(self.double_buffer))),
            )
            .expect("a shard pool always has at least one shard");
            let (s, plan, hit) = probes.into_iter().find(|&(s, _, _)| s == best).unwrap();
            (s, plan, hit)
        } else {
            // The router picks first (round-robin / least-loaded over
            // healthy shards); the batch is then planned under the
            // chosen shard's geometry — in a uniform pool this is the
            // same key every time, exactly the pre-geometry behaviour.
            let s = self.shards.choose();
            let (plan, hit) = self.cache.get(base.with_geometry(self.geoms[s]));
            (s, plan, hit)
        };
        // One pass over the owned members: *move* each request's
        // activation rows into the stacked matrix (no clone on the hot
        // path) while building the reply routing in the same order.
        // The weight matrix is still copied per batch — `GemmData`
        // owns `w`, and sharing it via `Arc` would ripple into every
        // constructor and the mutation sites (e.g. the layer
        // cross-check's zero-padding); one K×N copy per *batch* is the
        // amortised cost batching already pays for.
        let mut a = Vec::with_capacity(batch.rows);
        let mut parts = Vec::with_capacity(batch.parts.len());
        for mut p in batch.parts {
            // Planning is done: each member's plan phase closes here
            // and its span rides on into the shard via the reply part.
            p.span.mark(Phase::Plan);
            let rows = p.req.rows();
            a.extend(p.req.a);
            parts.push(ReplyPart { id: p.req.id, rows, reply: p.reply, span: p.span });
        }
        let data = Arc::new(GemmData { shape, fmt: model.fmt, a, w: model.w.clone() });
        let chain = ChainCfg::new(model.fmt, self.out_fmt);
        let job = BatchJob {
            chain,
            mode: self.mode,
            kind: batch.key.kind,
            double_buffer: self.double_buffer,
            data,
            plan,
            parts,
            cache_hit,
        };
        if scored {
            // The scored pick bypassed the router: account for it.
            self.shards.dispatch_to(target, job);
        } else {
            self.shards.enqueue_on(target, job);
        }
    }
}

/// The multi-tenant GEMM serving layer.
pub struct Server {
    queue: Arc<RequestQueue>,
    cache: Arc<PlanCache>,
    store: Arc<WeightStore>,
    shards: Arc<ShardPool>,
    batcher: Option<std::thread::JoinHandle<()>>,
    next_id: AtomicU64,
    shed: AtomicU64,
    obs: Obs,
}

impl Server {
    /// Start the serving pipeline: array geometry / formats / numeric
    /// mode from `run`, serving knobs (including the fault model and
    /// health policy, DESIGN.md §16) from `serve`.  Metrics are always
    /// on (they are a handful of atomics); request tracing is not —
    /// use [`Server::start_obs`] with [`Obs::with_tracing`] for spans.
    pub fn start(run: &RunConfig, serve: &ServeConfig, store: Arc<WeightStore>) -> Server {
        Self::start_obs(run, serve, store, Obs::new())
    }

    /// As [`Server::start`] under an explicit observability handle
    /// (`skewsa serve --trace-out`, the obs bench tier, span tests).
    pub fn start_obs(
        run: &RunConfig,
        serve: &ServeConfig,
        store: Arc<WeightStore>,
        obs: Obs,
    ) -> Server {
        assert!(!store.is_empty(), "serving needs at least one model");
        // Serving accumulates every batch into `run.out_fmt`, while a
        // plan-deployed store (`WeightStore::from_plan`) certified its
        // error budgets under each format's canonical chain
        // (`precision::chain_for`).  For those stores — and only those:
        // a plain `from_layers` store never certified anything — require
        // the serving accumulator to be at least as wide as every
        // model's certified accumulation format.  This is the
        // *necessary* condition for the certified budgets to transfer
        // (a narrower accumulator invalidates them outright); the
        // budgets themselves are statistical — measured on seeded
        // draws of the full layer shape, not on the store's possibly
        // K/N-clamped weights (see `WeightStore::from_plan`).
        if store.is_planned() {
            for id in 0..store.len() {
                let certified = crate::precision::chain_for(store.get(id).fmt).out_fmt;
                assert!(
                    run.out_fmt.man_bits >= certified.man_bits
                        && run.out_fmt.exp_bits >= certified.exp_bits,
                    "serving out_fmt {} is narrower than model {id}'s certified \
                     accumulation format {}",
                    run.out_fmt.name,
                    certified.name
                );
            }
        }
        let queue = Arc::new(RequestQueue::with_watermark(serve.queue_cap, serve.shed_watermark));
        let cache = Arc::new(PlanCache::new(serve.plan_cache_cap));
        let shards = Arc::new(ShardPool::with_obs(
            serve.shards,
            serve.workers_per_shard,
            run.threads,
            run.queue_depth,
            serve.shard_policy,
            serve.fault.clone(),
            serve.health_policy(),
            &obs,
        ));
        let limits = BatchLimits {
            max_requests: serve.max_batch_requests,
            max_rows: serve.max_batch_rows,
            batch_window: Duration::from_micros(serve.batch_window_us),
            interactive_window: Duration::from_micros(serve.interactive_window_us),
        };
        let batcher = Batcher::new(Arc::clone(&queue), limits);
        let geoms: Vec<ArrayGeometry> =
            (0..serve.shards.max(1)).map(|s| serve.shard_geometry(s, run.geometry)).collect();
        let dispatcher = Dispatcher {
            store: Arc::clone(&store),
            cache: Arc::clone(&cache),
            shards: Arc::clone(&shards),
            geoms,
            policy: serve.shard_policy,
            out_fmt: run.out_fmt,
            mode: run.mode,
            double_buffer: run.double_buffer,
        };
        let handle = std::thread::spawn(move || {
            while let Some(batch) = batcher.next_batch() {
                dispatcher.dispatch(batch);
            }
        });
        Server {
            queue,
            cache,
            store,
            shards,
            batcher: Some(handle),
            next_id: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            obs,
        }
    }

    /// As [`Server::start`], injecting a clean-failure [`FaultPlan`]
    /// into every shard's worker pool (resilience tests; the richer
    /// SDC surface lives on [`ServeConfig::fault`]).
    pub fn start_with_fault(
        run: &RunConfig,
        serve: &ServeConfig,
        store: Arc<WeightStore>,
        fault: FaultPlan,
    ) -> Server {
        let mut serve = serve.clone();
        serve.fault = FaultModel::from_plan(fault);
        Self::start(run, &serve, store)
    }

    /// Submit one request; returns the reply receiver.  Blocks while
    /// the request queue is full (closed-loop backpressure) — except
    /// that batch-class requests arriving over the shed watermark, and
    /// any request arriving after shutdown, are answered immediately
    /// with a rejected [`Response`] instead of hanging or panicking.
    pub fn submit(
        &self,
        model: usize,
        kind: PipelineKind,
        class: DeadlineClass,
        a: Vec<Vec<u64>>,
    ) -> Receiver<Response> {
        assert!(model < self.store.len(), "unknown model {model}");
        let entry = self.store.get(model);
        assert!(!a.is_empty(), "a request needs at least one activation row");
        assert!(
            a.iter().all(|row| row.len() == entry.k),
            "activation rows must be K={} wide",
            entry.k
        );
        let (tx, rx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let class_name = match class {
            DeadlineClass::Interactive => "interactive",
            DeadlineClass::Batch => "batch",
        };
        let span = self.obs.open_span(id, model, &kind.to_string(), class_name, a.len());
        let req = Request { id, model, kind, class, a };
        let pending = Pending { req, reply: tx, span };
        match self.queue.push(pending) {
            Ok(()) => {}
            Err(PushError::Shed(mut p)) => {
                self.shed.fetch_add(1, Ordering::Relaxed);
                p.span.finish(SpanStatus::Shed);
                let _ = p.reply.send(Response::rejected(p.req.id, ResponseStatus::Shed));
            }
            Err(PushError::Closed(mut p)) => {
                p.span.finish(SpanStatus::Closed);
                let _ = p.reply.send(Response::rejected(p.req.id, ResponseStatus::Closed));
            }
        }
        rx
    }

    /// The model registry this server fronts.
    pub fn store(&self) -> &WeightStore {
        &self.store
    }

    pub fn stats(&self) -> ServerStats {
        ServerStats {
            submitted: self.next_id.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            cache: self.cache.stats(),
            shards: self.shards.snapshots(),
        }
    }

    /// The server's observability handle (span sink, registry).
    pub fn obs(&self) -> &Obs {
        &self.obs
    }

    /// Publish every serve-layer tally into the metrics registry and
    /// snapshot it — the one number source behind
    /// [`crate::report::serve_summary`] / `faults_summary` and the
    /// `--metrics-out` JSON dump.  Counters are absorbed monotonically
    /// (`fetch_max`), so successive snapshots never regress.
    pub fn metrics(&self) -> MetricsSnapshot {
        let r = &self.obs.registry;
        let stats = self.stats();
        r.counter("serve.submitted").absorb(stats.submitted);
        r.counter("serve.shed").absorb(stats.shed);
        r.counter("cache.hits").absorb(stats.cache.hits);
        r.counter("cache.misses").absorb(stats.cache.misses);
        r.counter("cache.evictions").absorb(stats.cache.evictions);
        r.gauge("cache.entries").set(stats.cache.entries as u64);
        r.gauge("serve.shards").set(stats.shards.len() as u64);
        for (i, s) in stats.shards.iter().enumerate() {
            let c = |name: &str, v: u64| r.counter(&format!("shard.{i}.{name}")).absorb(v);
            c("batches", s.batches);
            c("requests", s.requests);
            c("rows", s.rows);
            c("retries", s.retries);
            c("sdc_injected", s.sdc_injected);
            c("sdc_detected", s.sdc_detected);
            c("sdc_recovered", s.sdc_recovered);
            c("sdc_unresolved", s.sdc_unresolved);
            c("failed_batches", s.failed_batches);
            c("quarantines", s.quarantines);
            r.gauge(&format!("shard.{i}.health")).set(match s.health {
                ShardState::Healthy => 0,
                ShardState::Probation { .. } => 1,
                ShardState::Quarantined { .. } => 2,
            });
        }
        r.snapshot()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // Stop intake, let the batcher drain the queue, then join it;
        // the shard pool (joined by its own Drop once the last Arc
        // falls) finishes every dispatched batch first.
        self.queue.close();
        if let Some(h) = self.batcher.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::mobilenet;

    fn tiny_server(serve: ServeConfig) -> Server {
        let mut run = RunConfig::small();
        run.verify_fraction = 0.0;
        let store = Arc::new(WeightStore::from_layers(
            &mobilenet::layers()[..3],
            FpFormat::BF16,
            24,
            16,
        ));
        Server::start(&run, &serve, store)
    }

    #[test]
    fn submit_roundtrip_serves_a_request() {
        let server = tiny_server(ServeConfig::small());
        let mut rng = crate::util::rng::Rng::new(1);
        let a = server.store().gen_activations(0, 4, &mut rng);
        let rx = server.submit(0, PipelineKind::Skewed, DeadlineClass::Interactive, a);
        let resp = rx.recv().unwrap();
        assert_eq!(resp.y.len(), 4 * server.store().get(0).n);
        assert!(resp.batch_size >= 1);
        assert!(resp.batch_stream_cycles > 0);
        let stats = server.stats();
        assert_eq!(stats.submitted, 1);
        assert_eq!(stats.cache.misses, 1);
    }

    #[test]
    fn shape_aware_routing_picks_the_predicted_fastest_shard() {
        let geoms = [ArrayGeometry::new(16, 4), ArrayGeometry::new(4, 16)];
        let mut serve = ServeConfig::small();
        serve.shards = 2;
        serve.shard_policy = Policy::ShapeAware;
        serve.shard_geometries = geoms.to_vec();
        let server = tiny_server(serve);
        let mut rng = crate::util::rng::Rng::new(7);
        let a = server.store().gen_activations(0, 4, &mut rng);
        let rx = server.submit(0, PipelineKind::Skewed, DeadlineClass::Interactive, a);
        let resp = rx.recv().unwrap();
        // Recompute the two predictions the dispatcher scored; the
        // response must come from the best-fit shard and quote exactly
        // that geometry's service time.
        let run = RunConfig::small();
        let entry = server.store().get(0);
        let shape = GemmShape::new(4, entry.k, entry.n);
        let oracle = PlanCache::new(4);
        let cycles: Vec<u64> = geoms
            .iter()
            .map(|&g| {
                let key = PlanKey { shape, fmt: entry.fmt, kind: PipelineKind::Skewed, geom: g };
                oracle.get(key).0.stream_cycles(run.double_buffer)
            })
            .collect();
        let want = if cycles[1] < cycles[0] { 1 } else { 0 };
        assert_eq!(resp.shard, want, "predictions: {cycles:?}");
        assert_eq!(resp.batch_stream_cycles, cycles[want]);
        assert_ne!(cycles[0], cycles[1], "a 16x4 vs 4x16 split should not tie");
    }

    #[test]
    fn drop_drains_accepted_requests() {
        let server = tiny_server(ServeConfig::small());
        let mut rng = crate::util::rng::Rng::new(2);
        let mut rxs = Vec::new();
        for i in 0..6 {
            let a = server.store().gen_activations(i % 3, 2, &mut rng);
            rxs.push(server.submit(i % 3, PipelineKind::Skewed, DeadlineClass::Batch, a));
        }
        drop(server);
        for rx in rxs {
            let resp = rx.recv().expect("accepted request must be served");
            assert!(!resp.y.is_empty());
        }
    }

    fn planned_store(fmt: FpFormat) -> WeightStore {
        use crate::precision::{LayerPlan, PrecisionPlan};
        let layers = &mobilenet::layers()[..1];
        let plan = PrecisionPlan {
            label: "mixed".into(),
            budget: 1e-2,
            kinds: vec![PipelineKind::Skewed],
            layers: layers
                .iter()
                .map(|l| LayerPlan {
                    layer: l.name.clone(),
                    shape: l.gemm(),
                    fmt,
                    kind: PipelineKind::Skewed,
                    stats: Default::default(),
                    energy_uj: 0.0,
                    cycles: 0,
                    within_budget: true,
                    clock_feasible: true,
                })
                .collect(),
        };
        WeightStore::from_plan(layers, &plan, 8, 8)
    }

    #[test]
    #[should_panic(expected = "narrower")]
    fn narrow_accumulator_rejected_for_plan_deployed_stores() {
        // A plan certified BF16 layers under an FP32 accumulation
        // chain; serving that plan into a BF16 accumulator must refuse.
        let mut run = RunConfig::small();
        run.out_fmt = FpFormat::BF16;
        let store = Arc::new(planned_store(FpFormat::BF16));
        let _ = Server::start(&run, &ServeConfig::small(), store);
    }

    #[test]
    fn uncertified_stores_skip_the_accumulator_guard() {
        // A plain from_layers store never certified a budget: the §12
        // width guard must not reject configs that predate it.
        let mut run = RunConfig::small();
        run.verify_fraction = 0.0;
        run.out_fmt = FpFormat::FP32;
        let store = Arc::new(WeightStore::from_layers(
            &mobilenet::layers()[..1],
            FpFormat::BF16,
            8,
            8,
        ));
        assert!(!store.is_planned());
        let _ = Server::start(&run, &ServeConfig::small(), store);
        // And a planned store under a wide-enough accumulator starts.
        let planned = Arc::new(planned_store(FpFormat::BF16));
        let _ = Server::start(&run, &ServeConfig::small(), planned);
    }

    #[test]
    fn overload_sheds_batch_requests_with_a_tagged_response() {
        // A huge batch window parks the anchor request inside the
        // batcher, so follow-ups pile up in the queue deterministically.
        let mut serve = ServeConfig::small();
        serve.batch_window_us = 2_000_000;
        serve.shed_watermark = 1;
        let server = tiny_server(serve);
        let mut rng = crate::util::rng::Rng::new(3);
        let a = server.store().gen_activations(0, 2, &mut rng);
        let anchor = server.submit(0, PipelineKind::Skewed, DeadlineClass::Batch, a);
        // Wait for the batcher to take the anchor out of the queue.
        while server.queue.len() > 0 {
            std::thread::yield_now();
        }
        let a = server.store().gen_activations(1, 2, &mut rng);
        let queued = server.submit(1, PipelineKind::Skewed, DeadlineClass::Batch, a);
        let a = server.store().gen_activations(1, 2, &mut rng);
        let shed = server.submit(1, PipelineKind::Skewed, DeadlineClass::Batch, a);
        let resp = shed.recv().expect("shed reply arrives immediately");
        assert_eq!(resp.status, ResponseStatus::Shed);
        assert!(resp.y.is_empty());
        assert_eq!(server.stats().shed, 1);
        // Shutdown drains the accepted requests as real responses.
        drop(server);
        assert_eq!(anchor.recv().unwrap().status, ResponseStatus::Ok);
        assert_eq!(queued.recv().unwrap().status, ResponseStatus::Ok);
    }

    #[test]
    #[should_panic(expected = "K=")]
    fn malformed_activation_width_is_rejected() {
        let server = tiny_server(ServeConfig::small());
        let _ = server.submit(
            0,
            PipelineKind::Skewed,
            DeadlineClass::Batch,
            vec![vec![0u64; 3]],
        );
    }
}
