//! Artifact registry: the manifest written by `python/compile/aot.py`.
//!
//! `artifacts/manifest.json` maps artifact names to HLO-text paths and
//! their parameter/result shapes:
//!
//! ```json
//! {
//!   "gemm_bf16_64x128x64": {
//!     "path": "gemm_bf16_64x128x64.hlo.txt",
//!     "params": [[64, 128], [128, 64]],
//!     "result": [64, 64]
//!   }
//! }
//! ```
//!
//! The registry also performs the staleness check backing the Makefile's
//! "`make artifacts` is a no-op when inputs are unchanged" contract: the
//! manifest records the content fingerprint of the python compile
//! sources at build time.

use crate::rt_err;
use crate::runtime::error::{Context, Result};
use crate::util::mini_json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One artifact's manifest entry.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactEntry {
    pub name: String,
    pub path: PathBuf,
    pub param_shapes: Vec<Vec<usize>>,
    pub result_shape: Vec<usize>,
}

/// The artifact registry.
#[derive(Clone, Debug, Default)]
pub struct Artifacts {
    pub dir: PathBuf,
    entries: BTreeMap<String, ArtifactEntry>,
}

fn parse_shape(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()
        .ok_or_else(|| rt_err!("shape is not an array"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| rt_err!("non-integer dim")))
        .collect()
}

impl Artifacts {
    /// Default artifact directory: `$SKEWSA_ARTIFACTS` or `artifacts/`
    /// next to the working directory.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("SKEWSA_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Load the manifest from `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Artifacts> {
        let manifest = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {manifest:?} (run `make artifacts`?)"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {manifest:?}"))?;
        let obj = match &j {
            Json::Obj(m) => m,
            _ => return Err(rt_err!("manifest root is not an object")),
        };
        let mut entries = BTreeMap::new();
        for (name, spec) in obj {
            if name.starts_with('_') {
                continue; // metadata keys (_sources_fingerprint, …)
            }
            let path = dir.join(
                spec.get("path")
                    .and_then(Json::as_str)
                    .ok_or_else(|| rt_err!("artifact '{name}': missing path"))?,
            );
            let params = spec
                .get("params")
                .and_then(Json::as_arr)
                .ok_or_else(|| rt_err!("artifact '{name}': missing params"))?
                .iter()
                .map(parse_shape)
                .collect::<Result<Vec<_>>>()?;
            let result = parse_shape(
                spec.get("result").ok_or_else(|| rt_err!("artifact '{name}': missing result"))?,
            )?;
            entries.insert(
                name.clone(),
                ArtifactEntry {
                    name: name.clone(),
                    path,
                    param_shapes: params,
                    result_shape: result,
                },
            );
        }
        Ok(Artifacts { dir: dir.to_path_buf(), entries })
    }

    /// Load from the default directory, or `None` when artifacts have not
    /// been built (callers degrade to oracle-only verification).
    pub fn try_default() -> Option<Artifacts> {
        let dir = Self::default_dir();
        Self::load(&dir).ok()
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.get(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(String::as_str)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Find a GEMM artifact matching an `(m, k, n)` shape, if present.
    pub fn find_gemm(&self, m: usize, k: usize, n: usize) -> Option<&ArtifactEntry> {
        self.entries.values().find(|e| {
            e.param_shapes.len() == 2
                && e.param_shapes[0] == [m, k]
                && e.param_shapes[1] == [k, n]
                && e.result_shape == [m, n]
        })
    }

    /// Every artifact file exists on disk.
    pub fn all_present(&self) -> bool {
        self.entries.values().all(|e| e.path.is_file())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn parses_manifest() {
        let dir = std::env::temp_dir().join(format!("skewsa_test_{}", std::process::id()));
        write_manifest(
            &dir,
            r#"{
                "_sources_fingerprint": "abc",
                "gemm_bf16_4x8x4": {
                    "path": "g.hlo.txt",
                    "params": [[4, 8], [8, 4]],
                    "result": [4, 4]
                }
            }"#,
        );
        let a = Artifacts::load(&dir).unwrap();
        assert_eq!(a.len(), 1);
        let e = a.get("gemm_bf16_4x8x4").unwrap();
        assert_eq!(e.param_shapes, vec![vec![4, 8], vec![8, 4]]);
        assert_eq!(e.result_shape, vec![4, 4]);
        assert!(a.find_gemm(4, 8, 4).is_some());
        assert!(a.find_gemm(4, 8, 5).is_none());
        assert!(!a.all_present()); // file not written
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_none() {
        let dir = std::env::temp_dir().join("skewsa_definitely_missing");
        assert!(Artifacts::load(&dir).is_err());
    }

    #[test]
    fn malformed_entries_error() {
        let dir = std::env::temp_dir().join(format!("skewsa_test_bad_{}", std::process::id()));
        write_manifest(&dir, r#"{"x": {"path": "p", "params": [[1, "a"]], "result": [1]}}"#);
        assert!(Artifacts::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
