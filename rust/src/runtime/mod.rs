//! PJRT runtime layer (L3 ↔ L2 boundary).
//!
//! Loads the AOT artifacts produced once by `make artifacts`
//! (`python/compile/aot.py`) and executes them on the CPU PJRT client —
//! the golden numeric reference for end-to-end verification.  Python is
//! never on this path.

pub mod artifacts;
pub mod client;
pub mod error;

pub use artifacts::{ArtifactEntry, Artifacts};
pub use client::{LoadedExec, Runtime};
pub use error::{Result, RtError};

use crate::rt_err;

/// Convenience bundle: registry + client + loaded executables on demand.
pub struct GoldenRuntime {
    pub artifacts: Artifacts,
    pub runtime: Runtime,
}

impl GoldenRuntime {
    /// Open the default artifact directory; `None` if artifacts are not
    /// built (callers fall back to oracle verification).
    pub fn try_open() -> Option<GoldenRuntime> {
        let artifacts = Artifacts::try_default()?;
        let runtime = Runtime::cpu().ok()?;
        Some(GoldenRuntime { artifacts, runtime })
    }

    /// Load an artifact by name.
    pub fn load(&self, name: &str) -> Result<LoadedExec> {
        let e = self
            .artifacts
            .get(name)
            .ok_or_else(|| rt_err!("artifact '{name}' not in manifest"))?;
        self.runtime.load_hlo_text(name, &e.path, e.param_shapes.clone(), e.result_shape.clone())
    }

    /// Run a GEMM artifact matching `(m,k,n)` on f32 data, if available.
    pub fn run_gemm_f32(
        &self,
        m: usize,
        k: usize,
        n: usize,
        a: &[f32],
        w: &[f32],
    ) -> Result<Option<Vec<f32>>> {
        let Some(e) = self.artifacts.find_gemm(m, k, n) else {
            return Ok(None);
        };
        let exe = self.load(&e.name)?;
        let y = exe.run_f32(&[(a, &[m, k]), (w, &[k, n])])?;
        Ok(Some(y))
    }
}
