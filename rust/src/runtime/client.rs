//! PJRT runtime: load AOT-compiled JAX/Pallas artifacts and execute them
//! on the CPU client.
//!
//! This is the only place the `xla` crate is touched, and the crate is
//! not in the offline build image's cache — so the whole PJRT leg is
//! gated behind `--cfg skewsa_xla`.  Enabling it takes two steps on a
//! machine that has the crate vendored: add `xla = { ... }` to
//! `rust/Cargo.toml` `[dependencies]` (it is deliberately not declared
//! there, not even as optional — cargo resolves optional deps into the
//! lockfile, which would break the offline default build), then build
//! with `RUSTFLAGS="--cfg skewsa_xla"`.  Without the cfg a stub with
//! the same API is compiled: [`Runtime::cpu`] returns an error and
//! callers degrade to oracle-only verification, exactly as they already
//! do when artifacts have not been built.
//!
//! Artifacts are HLO **text** (see `python/compile/aot.py` and
//! DESIGN.md §3 — jax ≥ 0.5 serialized protos are rejected by
//! xla_extension 0.5.1, text round-trips cleanly).  All artifact entry
//! points take f32 buffers and perform the bf16 casts *inside* the
//! lowered computation, so the rust side never constructs
//! reduced-precision literals.
//!
//! Python never runs at request time: `make artifacts` is the compile
//! path; this module is the serve path.

#[cfg(not(skewsa_xla))]
use crate::rt_err;
use crate::runtime::error::Result;
#[cfg(skewsa_xla)]
use crate::runtime::error::{Context, RtError};

/// A compiled artifact ready to execute.
pub struct LoadedExec {
    #[cfg(skewsa_xla)]
    exe: xla::PjRtLoadedExecutable,
    /// Declared parameter shapes (row-major dims), for call validation.
    pub param_shapes: Vec<Vec<usize>>,
    /// Declared result shape.
    pub result_shape: Vec<usize>,
    pub name: String,
}

/// The PJRT CPU runtime.
pub struct Runtime {
    #[cfg(skewsa_xla)]
    client: xla::PjRtClient,
}

#[cfg(skewsa_xla)]
impl Runtime {
    /// Construct a CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it for this client.
    ///
    /// `param_shapes`/`result_shape` come from the artifact manifest
    /// (written by `aot.py`) — the HLO parser does not expose them in a
    /// stable way through the crate API, so the manifest is the source
    /// of truth and execution validates against it.
    pub fn load_hlo_text(
        &self,
        name: &str,
        path: &std::path::Path,
        param_shapes: Vec<Vec<usize>>,
        result_shape: Vec<usize>,
    ) -> Result<LoadedExec> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| RtError::msg("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        Ok(LoadedExec { exe, param_shapes, result_shape, name: name.to_string() })
    }
}

#[cfg(not(skewsa_xla))]
impl Runtime {
    /// Stub: the build carries no PJRT client.
    pub fn cpu() -> Result<Runtime> {
        Err(rt_err!("built without --cfg skewsa_xla: PJRT runtime unavailable"))
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        "stub (no xla)".to_string()
    }

    /// Stub: always errors (a [`Runtime`] cannot be constructed without
    /// the cfg, so this is unreachable in practice).
    pub fn load_hlo_text(
        &self,
        name: &str,
        _path: &std::path::Path,
        _param_shapes: Vec<Vec<usize>>,
        _result_shape: Vec<usize>,
    ) -> Result<LoadedExec> {
        Err(rt_err!("built without --cfg skewsa_xla: cannot load artifact '{name}'"))
    }
}

impl LoadedExec {
    /// Execute on f32 inputs (row-major, shapes must match the manifest).
    /// Returns the flattened f32 result.
    ///
    /// Artifacts are lowered with `return_tuple=True`, so the raw result
    /// is a 1-tuple that gets unwrapped here.
    #[cfg(skewsa_xla)]
    pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<f32>> {
        if inputs.len() != self.param_shapes.len() {
            return Err(crate::rt_err!(
                "artifact '{}' expects {} params, got {}",
                self.name,
                self.param_shapes.len(),
                inputs.len()
            ));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (data, shape)) in inputs.iter().enumerate() {
            let want = &self.param_shapes[i];
            if *shape != want.as_slice() {
                return Err(crate::rt_err!(
                    "artifact '{}' param {i}: shape {shape:?} != manifest {want:?}",
                    self.name
                ));
            }
            let n: usize = shape.iter().product();
            if data.len() != n {
                return Err(crate::rt_err!(
                    "param {i}: {} elements for shape {shape:?}",
                    data.len()
                ));
            }
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .with_context(|| format!("reshaping param {i}"))?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing '{}'", self.name))?[0][0]
            .to_literal_sync()
            .context("syncing result literal")?;
        let out = result.to_tuple1().context("unwrapping 1-tuple result")?;
        let values = out.to_vec::<f32>().context("reading f32 result")?;
        let expect: usize = self.result_shape.iter().product();
        if values.len() != expect {
            return Err(crate::rt_err!(
                "artifact '{}': result has {} elements, manifest says {expect}",
                self.name,
                values.len()
            ));
        }
        Ok(values)
    }

    /// Stub: always errors (no executable can exist without the cfg).
    #[cfg(not(skewsa_xla))]
    pub fn run_f32(&self, _inputs: &[(&[f32], &[usize])]) -> Result<Vec<f32>> {
        Err(rt_err!("built without --cfg skewsa_xla: cannot execute '{}'", self.name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime tests that need built artifacts live in
    // `tests/integration_runtime.rs` (and skip gracefully when
    // `make artifacts` has not run).  Here: client construction only.
    #[cfg(skewsa_xla)]
    #[test]
    fn cpu_client_constructs() {
        let rt = Runtime::cpu().expect("PJRT CPU client");
        assert!(rt.platform().to_lowercase().contains("cpu"), "{}", rt.platform());
    }

    #[cfg(not(skewsa_xla))]
    #[test]
    fn stub_client_reports_missing_cfg() {
        let err = Runtime::cpu().err().expect("stub must not construct");
        assert!(err.0.contains("skewsa_xla"), "{err}");
    }
}
