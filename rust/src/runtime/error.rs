//! Minimal error plumbing for the runtime layer.
//!
//! The offline build image has no crate cache, so `anyhow` is not
//! available; this module provides the tiny subset the runtime layer
//! needs — a string-backed error type, a `Result` alias, an `anyhow!`-
//! style constructor macro ([`rt_err!`](crate::rt_err)), and a
//! [`Context`] extension trait for `Result`/`Option`.

/// String-backed runtime error.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RtError(pub String);

impl RtError {
    pub fn msg(s: impl Into<String>) -> RtError {
        RtError(s.into())
    }
}

impl std::fmt::Display for RtError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RtError {}

/// Runtime-layer result alias (mirrors `anyhow::Result`).
pub type Result<T, E = RtError> = std::result::Result<T, E>;

/// `anyhow!`-style formatted-error constructor.
#[macro_export]
macro_rules! rt_err {
    ($($arg:tt)*) => {
        $crate::runtime::error::RtError(format!($($arg)*))
    };
}

/// `anyhow::Context`-style error annotation for `Result` and `Option`.
pub trait Context<T> {
    /// Attach a static context message to the error case.
    fn context(self, msg: impl std::fmt::Display) -> Result<T>;

    /// Attach a lazily-built context message to the error case.
    fn with_context<D: std::fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T>;
}

impl<T, E: std::fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl std::fmt::Display) -> Result<T> {
        self.map_err(|e| RtError(format!("{msg}: {e}")))
    }

    fn with_context<D: std::fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T> {
        self.map_err(|e| RtError(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl std::fmt::Display) -> Result<T> {
        self.ok_or_else(|| RtError(msg.to_string()))
    }

    fn with_context<D: std::fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T> {
        self.ok_or_else(|| RtError(f().to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_annotates_errors() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let e = r.context("opening manifest").unwrap_err();
        assert!(e.0.contains("opening manifest"), "{e}");
        assert!(e.0.contains("gone"), "{e}");
    }

    #[test]
    fn option_context() {
        let n: Option<u32> = None;
        assert!(n.context("missing").is_err());
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn macro_formats() {
        let e = rt_err!("bad shape {:?}", [1, 2]);
        assert_eq!(e.0, "bad shape [1, 2]");
    }
}
