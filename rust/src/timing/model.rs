//! Closed-form SA latency model.
//!
//! The per-tile formula is exactly the one the cycle-accurate simulator
//! obeys (asserted in `tests/integration_sa.rs` and, across every
//! registered organisation, `tests/prop_pipelines.rs`), fully
//! determined by the organisation's [`PipelineSpec`] parameters —
//! spacing `S`, pipeline depth `D` and column tail `τ`:
//!
//! ```text
//! T_tile(spec, M, R, C_used) = (M−1) + (C_used−1) + S·(R−1) + D + 1 + τ
//! ```
//!
//! For the paper's pair (`S,D,τ` = 2,2,0 baseline vs 1,2,1 skewed) this
//! collapses to the §III hand-derived forms and
//! `T_base − T_skew = R − 2` per tile — the paper's per-column saving.
//!
//! [`PipelineSpec`]: crate::pe::PipelineSpec
//! Layer latency composes tiles sequentially with (optionally
//! double-buffered) weight preloads, reproducing the §IV observation:
//! layers with large `M` amortize the saving away, layers with small `M`
//! (the late CNN layers, 7×7 spatial) gain the most.

use crate::pe::PipelineKind;
use crate::sa::dataflow::WsSchedule;
use crate::sa::tile::TilePlan;

/// Array + clock configuration for timing/energy evaluation.
#[derive(Clone, Copy, Debug)]
pub struct TimingConfig {
    /// Array rows (reduction depth), paper: 128.
    pub rows: usize,
    /// Array columns, paper: 128.
    pub cols: usize,
    /// Clock frequency in GHz, paper: 1.0.
    pub clock_ghz: f64,
    /// Weight preloads overlap the previous tile's streaming (dedicated
    /// fill path) — the state-of-the-art assumption; `false` serializes
    /// every reload (ablation).
    pub double_buffer: bool,
}

impl TimingConfig {
    /// The paper's evaluation setup: 128×128 PEs @ 1 GHz (§IV).
    pub const PAPER: TimingConfig =
        TimingConfig { rows: 128, cols: 128, clock_ghz: 1.0, double_buffer: true };

    /// Cycle count → nanoseconds at this clock.
    pub fn ns(&self, cycles: u64) -> f64 {
        cycles as f64 / self.clock_ghz
    }
}

/// Timing of a single weight tile.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileTiming {
    /// Streaming cycles (first injection → last rounded output).
    pub compute: u64,
    /// Weight preload cycles (R, the fill).
    pub preload: u64,
}

impl TileTiming {
    /// Closed-form per-tile timing.  `n_used` is the live column count
    /// of (possibly edge-) tiles; the chain always spans the full `rows`
    /// (unused rows stream zeros — the array does not reconfigure).
    pub fn compute_cycles(kind: PipelineKind, m: usize, rows: usize, n_used: usize) -> u64 {
        WsSchedule::new(kind, rows, n_used, m).total_cycles()
    }

    pub fn new(kind: PipelineKind, m: usize, rows: usize, n_used: usize) -> TileTiming {
        TileTiming {
            compute: Self::compute_cycles(kind, m, rows, n_used),
            preload: rows as u64,
        }
    }
}

/// Timing of a full layer (one GEMM) on the array.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LayerTiming {
    /// Total cycles including exposed preloads.
    pub cycles: u64,
    /// Cycles spent streaming (PEs active).
    pub compute_cycles: u64,
    /// Cycles of *exposed* (non-overlapped) weight preload.
    pub exposed_preload: u64,
    /// Number of weight tiles.
    pub tiles: usize,
    /// Wall-clock at the configured clock.
    pub ns: f64,
}

/// Compose a tile plan into layer latency.
///
/// With double-buffering, tile `i+1`'s preload runs during tile `i`'s
/// streaming and is exposed only if the stream is shorter than the fill;
/// the first preload is always exposed.
pub fn layer_timing(cfg: &TimingConfig, kind: PipelineKind, plan: &TilePlan) -> LayerTiming {
    let m = plan.shape.m;
    let mut t: u64 = 0;
    let mut compute_total: u64 = 0;
    let mut exposed: u64 = 0;
    let mut preload_done: u64 = cfg.rows as u64; // first fill
    for (i, tile) in plan.tiles.iter().enumerate() {
        let tt = TileTiming::new(kind, m, cfg.rows, tile.n_len);
        let start = t.max(preload_done);
        exposed += start - t; // stall waiting for weights
        let done = start + tt.compute;
        compute_total += tt.compute;
        // Next preload: overlapped (starts as soon as this tile's weights
        // are committed) or serialized after this tile's drain.
        if i + 1 < plan.tiles.len() {
            preload_done = if cfg.double_buffer { start + tt.preload } else { done + tt.preload };
        }
        t = done;
    }
    LayerTiming {
        cycles: t,
        compute_cycles: compute_total,
        exposed_preload: exposed,
        tiles: plan.tile_count(),
        ns: cfg.ns(t),
    }
}

/// Convenience: latency of a whole GEMM shape under a config.
pub fn gemm_timing(
    cfg: &TimingConfig,
    kind: PipelineKind,
    shape: crate::sa::tile::GemmShape,
) -> LayerTiming {
    layer_timing(cfg, kind, &TilePlan::new(shape, cfg.rows, cfg.cols))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sa::tile::GemmShape;

    #[test]
    fn single_tile_formulas() {
        // T_base = (M−1)+(C−1)+2R+1 ; T_skew = (M−1)+(C−1)+R+3.
        let b = TileTiming::compute_cycles(PipelineKind::Baseline3b, 16, 8, 4);
        assert_eq!(b, 15 + 3 + 17);
        let s = TileTiming::compute_cycles(PipelineKind::Skewed, 16, 8, 4);
        assert_eq!(s, 15 + 3 + 11);
        assert_eq!(b - s, 8 - 2);
    }

    #[test]
    fn generalized_tile_formula_every_kind() {
        // T = (M−1) + (C_used−1) + S·(R−1) + D + 1 + tail for every
        // registered spec, including edge tiles (C_used < cols).
        for kind in PipelineKind::ALL {
            let sp = kind.spec();
            for (m, r, c) in [(16usize, 8usize, 4usize), (1, 1, 1), (49, 128, 128), (7, 12, 3)] {
                let want = (m as u64 - 1)
                    + (c as u64 - 1)
                    + sp.spacing * (r as u64 - 1)
                    + sp.depth
                    + 1
                    + sp.column_tail;
                assert_eq!(
                    TileTiming::compute_cycles(kind, m, r, c),
                    want,
                    "{kind} m={m} r={r} c={c}"
                );
            }
        }
    }

    #[test]
    fn related_work_organisations_order_as_expected() {
        // Per tile: transparent < skewed < baseline < deep3 (spacing
        // dominates; deep3 pays exactly one fill cycle over baseline).
        let t = |k| TileTiming::compute_cycles(k, 49, 128, 128);
        assert_eq!(t(PipelineKind::Skewed) - t(PipelineKind::Transparent), 1);
        assert!(t(PipelineKind::Transparent) < t(PipelineKind::Skewed));
        assert!(t(PipelineKind::Skewed) < t(PipelineKind::Baseline3b));
        assert_eq!(t(PipelineKind::Deep3) - t(PipelineKind::Baseline3b), 1);
    }

    #[test]
    fn paper_scale_tile_saving() {
        // 128×128 array: R−2 = 126 cycles saved per tile.
        let b = TileTiming::compute_cycles(PipelineKind::Baseline3b, 49, 128, 128);
        let s = TileTiming::compute_cycles(PipelineKind::Skewed, 49, 128, 128);
        assert_eq!(b - s, 126);
        // Small M (late CNN layer): the saving is a large fraction.
        assert!((b - s) as f64 / b as f64 > 0.23, "saving {} of {}", b - s, b);
        // Large M (early layer): the saving is diluted.
        let b2 = TileTiming::compute_cycles(PipelineKind::Baseline3b, 12544, 128, 128);
        let s2 = TileTiming::compute_cycles(PipelineKind::Skewed, 12544, 128, 128);
        assert!((b2 - s2) as f64 / (b2 as f64) < 0.01);
    }

    #[test]
    fn layer_composition_double_buffered() {
        let cfg = TimingConfig { rows: 8, cols: 8, clock_ghz: 1.0, double_buffer: true };
        let plan = TilePlan::new(GemmShape::new(32, 16, 16), 8, 8);
        assert_eq!(plan.tile_count(), 4);
        let lt = layer_timing(&cfg, PipelineKind::Baseline3b, &plan);
        let per_tile = TileTiming::compute_cycles(PipelineKind::Baseline3b, 32, 8, 8);
        // Preloads fully hidden except the first (compute ≥ R here).
        assert_eq!(lt.cycles, 8 + 4 * per_tile);
        assert_eq!(lt.exposed_preload, 8);
        assert_eq!(lt.compute_cycles, 4 * per_tile);
    }

    #[test]
    fn layer_composition_serialized_reloads() {
        let cfg = TimingConfig { rows: 8, cols: 8, clock_ghz: 1.0, double_buffer: false };
        let plan = TilePlan::new(GemmShape::new(32, 16, 16), 8, 8);
        let lt = layer_timing(&cfg, PipelineKind::Baseline3b, &plan);
        let per_tile = TileTiming::compute_cycles(PipelineKind::Baseline3b, 32, 8, 8);
        assert_eq!(lt.cycles, 8 + 4 * per_tile + 3 * 8);
    }

    #[test]
    fn headline_direction_holds_for_small_m() {
        // A late-CNN-layer-like GEMM: M=49, K=N=512 on the paper array.
        let cfg = TimingConfig::PAPER;
        let shape = GemmShape::new(49, 512, 512);
        let b = gemm_timing(&cfg, PipelineKind::Baseline3b, shape);
        let s = gemm_timing(&cfg, PipelineKind::Skewed, shape);
        let saving = 1.0 - s.cycles as f64 / b.cycles as f64;
        assert!(saving > 0.2, "late-layer saving {saving}");
    }

    #[test]
    fn ns_conversion() {
        let cfg = TimingConfig { rows: 8, cols: 8, clock_ghz: 2.0, double_buffer: true };
        assert_eq!(cfg.ns(100), 50.0);
    }
}
