//! Closed-form SA latency model.
//!
//! The per-tile formula is exactly the one the cycle-accurate simulator
//! obeys (asserted in `tests/integration_sa.rs` and, across every
//! registered organisation, `tests/prop_pipelines.rs`), fully
//! determined by the organisation's [`PipelineSpec`] parameters —
//! spacing `S`, pipeline depth `D` and column tail `τ`:
//!
//! ```text
//! T_tile(spec, M, R, C_used) = (M−1) + (C_used−1) + S·(R−1) + D + 1 + τ
//! ```
//!
//! For the paper's pair (`S,D,τ` = 2,2,0 baseline vs 1,2,1 skewed) this
//! collapses to the §III hand-derived forms and
//! `T_base − T_skew = R − 2` per tile — the paper's per-column saving.
//!
//! [`PipelineSpec`]: crate::pe::PipelineSpec
//! Layer latency composes tiles sequentially with (optionally
//! double-buffered) weight preloads, reproducing the §IV observation:
//! layers with large `M` amortize the saving away, layers with small `M`
//! (the late CNN layers, 7×7 spatial) gain the most.

use crate::pe::{PipelineKind, PipelineSpec};
use crate::sa::dataflow::WsSchedule;
use crate::sa::geometry::ArrayGeometry;
use crate::sa::tile::TilePlan;

/// Array + clock configuration for timing/energy evaluation.
#[derive(Clone, Copy, Debug)]
pub struct TimingConfig {
    /// Array rows (reduction depth), paper: 128.
    pub rows: usize,
    /// Array columns, paper: 128.
    pub cols: usize,
    /// Clock frequency in GHz, paper: 1.0.
    pub clock_ghz: f64,
    /// Weight preloads overlap the previous tile's streaming (dedicated
    /// fill path) — the state-of-the-art assumption; `false` serializes
    /// every reload (ablation).
    pub double_buffer: bool,
}

impl TimingConfig {
    /// The paper's evaluation setup: 128×128 PEs @ 1 GHz (§IV).
    pub const PAPER: TimingConfig =
        TimingConfig { rows: 128, cols: 128, clock_ghz: 1.0, double_buffer: true };

    /// Config for a validated [`ArrayGeometry`] — the constructor every
    /// geometry-aware caller (sweep, heterogeneous shards) routes
    /// through.
    pub fn for_geometry(geom: ArrayGeometry, clock_ghz: f64, double_buffer: bool) -> TimingConfig {
        TimingConfig { rows: geom.rows, cols: geom.cols, clock_ghz, double_buffer }
    }

    /// The array shape this config evaluates.
    pub fn geometry(&self) -> ArrayGeometry {
        ArrayGeometry { rows: self.rows, cols: self.cols }
    }

    /// Cycle count → nanoseconds at this clock.
    pub fn ns(&self, cycles: u64) -> f64 {
        cycles as f64 / self.clock_ghz
    }
}

/// Timing of a single weight tile.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileTiming {
    /// Streaming cycles (first injection → last rounded output).
    pub compute: u64,
    /// Weight preload cycles (R, the fill).
    pub preload: u64,
}

impl TileTiming {
    /// Closed-form per-tile timing.  `n_used` is the live column count
    /// of (possibly edge-) tiles; the chain always spans the full `rows`
    /// (unused rows stream zeros — the array does not reconfigure).
    pub fn compute_cycles(kind: PipelineKind, m: usize, rows: usize, n_used: usize) -> u64 {
        Self::compute_cycles_spec(*kind.spec(), m, rows, n_used)
    }

    /// As [`TileTiming::compute_cycles`], for any (possibly custom) spec.
    pub fn compute_cycles_spec(spec: PipelineSpec, m: usize, rows: usize, n_used: usize) -> u64 {
        WsSchedule::with_spec(spec, rows, n_used, m).total_cycles()
    }

    pub fn new(kind: PipelineKind, m: usize, rows: usize, n_used: usize) -> TileTiming {
        Self::with_spec(*kind.spec(), m, rows, n_used)
    }

    /// As [`TileTiming::new`], for any (possibly custom) spec.
    pub fn with_spec(spec: PipelineSpec, m: usize, rows: usize, n_used: usize) -> TileTiming {
        TileTiming {
            compute: Self::compute_cycles_spec(spec, m, rows, n_used),
            preload: rows as u64,
        }
    }
}

/// Timing of a full layer (one GEMM) on the array.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LayerTiming {
    /// Total cycles including exposed preloads.
    pub cycles: u64,
    /// Cycles spent streaming (PEs active).
    pub compute_cycles: u64,
    /// Cycles of *exposed* (non-overlapped) weight preload.
    pub exposed_preload: u64,
    /// Cycles of pipeline drain summed over tiles — per tile the stream
    /// outlives its last West-edge injection by `T − M` cycles while the
    /// wavefront crosses the array (the second leg of the streaming
    /// executor's stall taxonomy, DESIGN.md §15).
    pub drain_cycles: u64,
    /// Number of weight tiles.
    pub tiles: usize,
    /// Wall-clock at the configured clock.
    pub ns: f64,
}

/// The model's per-tile schedule on the global clock: when the tile's
/// weight preload occupies the fill path and when its stream runs.
/// `stream_done` is exclusive (the first cycle after the tile's last
/// rounded output).  The streaming cycle simulator
/// ([`crate::sa::stream::StreamingSim`]) reproduces these spans
/// event-for-event and the property suite pins the equality.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileSpanTiming {
    pub preload_start: u64,
    pub preload_done: u64,
    pub stream_start: u64,
    pub stream_done: u64,
}

/// Per-tile spans of the layer composition (see [`layer_timing`] for the
/// discipline being modeled).
///
/// **Two-buffer audit.**  The overlapped branch deliberately lets tile
/// `i+1`'s preload *complete before tile `i` has drained* — in fact it
/// always does: a full-chain tile streams for `T = (M−1) + (C_used−1) +
/// S·(R−1) + D + 1 + τ ≥ R + 2 > R` cycles (any valid spec has `S ≥ 1`,
/// `D ≥ 2`), so the `R`-cycle fill launched at `stream_start_i` lands
/// strictly inside tile `i`'s stream window.  This is safe with exactly
/// two weight banks and one fill path (`tests::two_buffer_constraint_*`
/// pin it on an adversarial short-stream many-tile plan, and the
/// streaming cycle simulator asserts it event-by-event):
///
/// * *Fill path*: preload `i+1` occupies `[stream_start_i,
///   stream_start_i + R)`; preload `i+2` starts at `stream_start_{i+1} =
///   max(stream_done_i, stream_start_i + R) ≥ stream_start_i + R`, so
///   consecutive preload intervals never overlap (the `max` keeps them
///   disjoint even in a hypothetical `T < R` regime).
/// * *Buffer liveness*: tile `i+1` preloads into the bank tile `i−1`
///   streamed from, which went dead at `stream_done_{i−1} ≤
///   stream_start_i` — the fill never shifts into registers that still
///   feed live PEs.  Tile `i`'s own bank is untouched until its drain.
///
/// Note what the discipline does **not** allow: tile `i+1`'s *stream*
/// never starts before tile `i` has fully drained (`stream_start_{i+1} ≥
/// stream_done_i`), because the preload delivers a whole column of
/// shadow registers at once (shift-chain fill) and the per-PE swap to
/// the shadow bank is a single pointer flip that must not yank weights
/// from under in-flight elements.
///
/// Corollary (pinned by the property suite): under double buffering the
/// only exposed preload of a multi-tile layer is the first fill — every
/// later fill hides entirely under the previous stream because `T > R`.
pub fn layer_spans(
    cfg: &TimingConfig,
    spec: PipelineSpec,
    plan: &TilePlan,
) -> Vec<TileSpanTiming> {
    let m = plan.shape.m;
    let mut spans = Vec::with_capacity(plan.tile_count());
    let mut drained: u64 = 0;
    for tile in &plan.tiles {
        let tt = TileTiming::with_spec(spec, m, cfg.rows, tile.n_len);
        let preload_start = match spans.last() {
            None => 0,
            // Overlapped: the fill path and the shadow bank both free up
            // the moment the previous tile's stream begins (see the
            // two-buffer audit above); serialized: one bank, so the
            // reload waits for the drain.
            Some(prev) if cfg.double_buffer => prev.stream_start,
            Some(prev) => prev.stream_done,
        };
        let preload_done = preload_start + tt.preload;
        let stream_start = drained.max(preload_done);
        let stream_done = stream_start + tt.compute;
        spans.push(TileSpanTiming { preload_start, preload_done, stream_start, stream_done });
        drained = stream_done;
    }
    spans
}

/// Compose a tile plan into layer latency.
///
/// With double-buffering, tile `i+1`'s preload runs during tile `i`'s
/// streaming; since a full-chain stream always covers its fill
/// (`T ≥ R + 2`), only the first preload is ever exposed.  See
/// [`layer_spans`] for the audited two-buffer hand-off discipline
/// behind the overlapped branch.
pub fn layer_timing(cfg: &TimingConfig, kind: PipelineKind, plan: &TilePlan) -> LayerTiming {
    layer_timing_spec(cfg, *kind.spec(), plan)
}

/// As [`layer_timing`], for any (possibly custom) pipeline spec.
pub fn layer_timing_spec(cfg: &TimingConfig, spec: PipelineSpec, plan: &TilePlan) -> LayerTiming {
    let m = plan.shape.m;
    let spans = layer_spans(cfg, spec, plan);
    let mut compute_total: u64 = 0;
    let mut exposed: u64 = 0;
    let mut drain: u64 = 0;
    let mut drained: u64 = 0;
    for (s, tile) in spans.iter().zip(&plan.tiles) {
        exposed += s.stream_start - drained; // stall waiting for weights
        compute_total += s.stream_done - s.stream_start;
        // Everything past the tile's last West-edge injection is
        // wavefront drain — one definition, shared with the per-tile
        // schedule helper.
        drain += WsSchedule::with_spec(spec, cfg.rows, tile.n_len, m).drain_cycles();
        drained = s.stream_done;
    }
    LayerTiming {
        cycles: drained,
        compute_cycles: compute_total,
        exposed_preload: exposed,
        drain_cycles: drain,
        tiles: plan.tile_count(),
        ns: cfg.ns(drained),
    }
}

/// Convenience: latency of a whole GEMM shape under a config.
pub fn gemm_timing(
    cfg: &TimingConfig,
    kind: PipelineKind,
    shape: crate::sa::tile::GemmShape,
) -> LayerTiming {
    layer_timing(cfg, kind, &TilePlan::new(shape, cfg.rows, cfg.cols))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sa::tile::GemmShape;

    #[test]
    fn single_tile_formulas() {
        // T_base = (M−1)+(C−1)+2R+1 ; T_skew = (M−1)+(C−1)+R+3.
        let b = TileTiming::compute_cycles(PipelineKind::Baseline3b, 16, 8, 4);
        assert_eq!(b, 15 + 3 + 17);
        let s = TileTiming::compute_cycles(PipelineKind::Skewed, 16, 8, 4);
        assert_eq!(s, 15 + 3 + 11);
        assert_eq!(b - s, 8 - 2);
    }

    #[test]
    fn generalized_tile_formula_every_kind() {
        // T = (M−1) + (C_used−1) + S·(R−1) + D + 1 + tail for every
        // registered spec, including edge tiles (C_used < cols).
        for kind in PipelineKind::ALL {
            let sp = kind.spec();
            for (m, r, c) in [(16usize, 8usize, 4usize), (1, 1, 1), (49, 128, 128), (7, 12, 3)] {
                let want = (m as u64 - 1)
                    + (c as u64 - 1)
                    + sp.spacing * (r as u64 - 1)
                    + sp.depth
                    + 1
                    + sp.column_tail;
                assert_eq!(
                    TileTiming::compute_cycles(kind, m, r, c),
                    want,
                    "{kind} m={m} r={r} c={c}"
                );
            }
        }
    }

    #[test]
    fn related_work_organisations_order_as_expected() {
        // Per tile: transparent < skewed < baseline < deep3 (spacing
        // dominates; deep3 pays exactly one fill cycle over baseline).
        let t = |k| TileTiming::compute_cycles(k, 49, 128, 128);
        assert_eq!(t(PipelineKind::Skewed) - t(PipelineKind::Transparent), 1);
        assert!(t(PipelineKind::Transparent) < t(PipelineKind::Skewed));
        assert!(t(PipelineKind::Skewed) < t(PipelineKind::Baseline3b));
        assert_eq!(t(PipelineKind::Deep3) - t(PipelineKind::Baseline3b), 1);
    }

    #[test]
    fn paper_scale_tile_saving() {
        // 128×128 array: R−2 = 126 cycles saved per tile.
        let b = TileTiming::compute_cycles(PipelineKind::Baseline3b, 49, 128, 128);
        let s = TileTiming::compute_cycles(PipelineKind::Skewed, 49, 128, 128);
        assert_eq!(b - s, 126);
        // Small M (late CNN layer): the saving is a large fraction.
        assert!((b - s) as f64 / b as f64 > 0.23, "saving {} of {}", b - s, b);
        // Large M (early layer): the saving is diluted.
        let b2 = TileTiming::compute_cycles(PipelineKind::Baseline3b, 12544, 128, 128);
        let s2 = TileTiming::compute_cycles(PipelineKind::Skewed, 12544, 128, 128);
        assert!((b2 - s2) as f64 / (b2 as f64) < 0.01);
    }

    #[test]
    fn layer_composition_double_buffered() {
        let cfg = TimingConfig { rows: 8, cols: 8, clock_ghz: 1.0, double_buffer: true };
        let plan = TilePlan::new(GemmShape::new(32, 16, 16), 8, 8);
        assert_eq!(plan.tile_count(), 4);
        let lt = layer_timing(&cfg, PipelineKind::Baseline3b, &plan);
        let per_tile = TileTiming::compute_cycles(PipelineKind::Baseline3b, 32, 8, 8);
        // Preloads fully hidden except the first (compute ≥ R here).
        assert_eq!(lt.cycles, 8 + 4 * per_tile);
        assert_eq!(lt.exposed_preload, 8);
        assert_eq!(lt.compute_cycles, 4 * per_tile);
    }

    #[test]
    fn layer_composition_serialized_reloads() {
        let cfg = TimingConfig { rows: 8, cols: 8, clock_ghz: 1.0, double_buffer: false };
        let plan = TilePlan::new(GemmShape::new(32, 16, 16), 8, 8);
        let lt = layer_timing(&cfg, PipelineKind::Baseline3b, &plan);
        let per_tile = TileTiming::compute_cycles(PipelineKind::Baseline3b, 32, 8, 8);
        assert_eq!(lt.cycles, 8 + 4 * per_tile + 3 * 8);
    }

    #[test]
    fn headline_direction_holds_for_small_m() {
        // A late-CNN-layer-like GEMM: M=49, K=N=512 on the paper array.
        let cfg = TimingConfig::PAPER;
        let shape = GemmShape::new(49, 512, 512);
        let b = gemm_timing(&cfg, PipelineKind::Baseline3b, shape);
        let s = gemm_timing(&cfg, PipelineKind::Skewed, shape);
        let saving = 1.0 - s.cycles as f64 / b.cycles as f64;
        assert!(saving > 0.2, "late-layer saving {saving}");
    }

    #[test]
    fn geometry_constructor_roundtrips() {
        let g = ArrayGeometry::new(256, 64);
        let cfg = TimingConfig::for_geometry(g, 1.0, true);
        assert_eq!((cfg.rows, cfg.cols), (256, 64));
        assert_eq!(cfg.geometry(), g);
        assert_eq!(TimingConfig::PAPER.geometry(), ArrayGeometry::PAPER);
    }

    #[test]
    fn ns_conversion() {
        let cfg = TimingConfig { rows: 8, cols: 8, clock_ghz: 2.0, double_buffer: true };
        assert_eq!(cfg.ns(100), 50.0);
    }

    #[test]
    fn drain_taxonomy_sums_per_tile_wavefront() {
        // drain = Σ (T_i − M): everything past each tile's last West-edge
        // injection, which the streaming executor reports separately from
        // exposed preload.
        let cfg = TimingConfig { rows: 8, cols: 8, clock_ghz: 1.0, double_buffer: true };
        let plan = TilePlan::new(GemmShape::new(32, 16, 16), 8, 8);
        let lt = layer_timing(&cfg, PipelineKind::Skewed, &plan);
        let per_tile = TileTiming::compute_cycles(PipelineKind::Skewed, 32, 8, 8);
        assert_eq!(lt.drain_cycles, 4 * (per_tile - 32));
        assert_eq!(lt.cycles, lt.exposed_preload + lt.compute_cycles);
    }

    /// The satellite audit case: a short-stream (M ≪ R), many-tile plan
    /// — the regime where each overlapped preload completes long before
    /// its predecessor tile drains, which is exactly where a mis-modeled
    /// hand-off would fill a bank that still feeds live PEs.  Pins the
    /// two-buffer constraint legs directly on the model's spans.
    #[test]
    fn two_buffer_constraint_short_stream_many_tiles() {
        let cfg = TimingConfig { rows: 32, cols: 8, clock_ghz: 1.0, double_buffer: true };
        // M = 2 ≪ R = 32; K = 256 → 8 consecutive K-pass tiles.
        let plan = TilePlan::new(GemmShape::new(2, 256, 8), 32, 8);
        assert_eq!(plan.tile_count(), 8);
        for kind in PipelineKind::ALL {
            let spec = *kind.spec();
            let t_tile = TileTiming::compute_cycles(kind, 2, 32, 8);
            // Full-chain streams always cover the fill: T ≥ R + 2.
            assert!(t_tile >= 32 + 2, "{kind}");
            let spans = layer_spans(&cfg, spec, &plan);
            for w in spans.windows(2) {
                let (a, b) = (&w[0], &w[1]);
                // The audited behavior: the next preload *completes*
                // strictly before the current tile drains…
                assert!(b.preload_done < a.stream_done, "{kind}: preload not overlapped");
                // …which is safe: the fill path is free (consecutive
                // preload intervals disjoint)…
                assert!(b.preload_start >= a.preload_done, "{kind}: fill path overlap");
                // …and the next stream still waits for the drain.
                assert!(b.stream_start >= a.stream_done, "{kind}: stream overlap");
            }
            // Buffer-liveness leg: tile i+1 preloads into tile i−1's
            // bank, which must be dead by then.
            for i in 2..spans.len() {
                assert!(
                    spans[i].preload_start >= spans[i - 2].stream_done,
                    "{kind}: preload into a live buffer"
                );
            }
            // Pinned closed form: drain-bound everywhere, so the layer
            // is one exposed fill plus back-to-back streams.
            let lt = layer_timing(&cfg, kind, &plan);
            assert_eq!(lt.cycles, 32 + 8 * t_tile, "{kind}");
            assert_eq!(lt.exposed_preload, 32, "{kind}");
            assert_eq!(lt.drain_cycles, 8 * (t_tile - 2), "{kind}");
        }
    }

    #[test]
    fn serialized_equals_overlapped_plus_hidden_preloads() {
        // When every stream covers its fill (T ≥ R), double buffering
        // hides exactly (tiles−1)·R cycles.
        let plan = TilePlan::new(GemmShape::new(64, 24, 20), 8, 8);
        for kind in PipelineKind::ALL {
            let db = TimingConfig { rows: 8, cols: 8, clock_ghz: 1.0, double_buffer: true };
            let ser = TimingConfig { double_buffer: false, ..db };
            let a = layer_timing(&db, kind, &plan);
            let b = layer_timing(&ser, kind, &plan);
            assert_eq!(
                b.cycles - a.cycles,
                (plan.tile_count() as u64 - 1) * 8,
                "{kind}"
            );
            assert_eq!(a.exposed_preload, 8, "{kind}: only the first fill is exposed");
            assert_eq!(b.exposed_preload, plan.tile_count() as u64 * 8, "{kind}");
        }
    }

    #[test]
    fn custom_spec_layer_timing_composes() {
        use crate::pe::spec::{DatapathId, PipelineSpec};
        const WIDE: PipelineSpec = PipelineSpec {
            spacing: 3,
            depth: 3,
            column_tail: 0,
            name: "custom-s3",
            aliases: &[],
            summary: "test",
            stages: crate::pe::spec::DEEP3.stages,
            regs: crate::pe::spec::DEEP3.regs,
            datapath: DatapathId::Baseline,
        };
        let cfg = TimingConfig { rows: 8, cols: 4, clock_ghz: 1.0, double_buffer: true };
        let plan = TilePlan::new(GemmShape::new(16, 16, 8), 8, 4);
        let lt = layer_timing_spec(&cfg, WIDE, &plan);
        let per_tile = TileTiming::compute_cycles_spec(WIDE, 16, 8, 4);
        assert_eq!(lt.compute_cycles, 4 * per_tile);
        assert!(lt.cycles >= 8 + 4 * per_tile);
    }
}
