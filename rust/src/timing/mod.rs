//! Closed-form latency model, validated cycle-for-cycle against the
//! cycle-accurate simulator by the integration tests.

pub mod model;

pub use model::{LayerTiming, TileTiming, TimingConfig};
