//! Closed-form latency model, validated cycle-for-cycle against the
//! cycle-accurate simulators — per tile by the integration tests, and
//! across whole multi-tile plans (both double-buffer modes) by the
//! streaming executor's property suite (`tests/prop_streaming.rs`).

pub mod model;

pub use model::{
    layer_spans, layer_timing, layer_timing_spec, LayerTiming, TileSpanTiming, TileTiming,
    TimingConfig,
};
