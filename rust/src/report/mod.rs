//! Report emitters: regenerate every table and figure of the paper's
//! evaluation section (experiment index in DESIGN.md §5).
//!
//! Each emitter returns a [`Table`] (rendered by the benches, the CLI,
//! and the examples) plus structured totals where the paper quotes
//! headline numbers.  Rows are `row:`-prefixed and CSV-exportable so
//! the plots can be regenerated externally.

use crate::arith::fma::ChainCfg;
use crate::energy::{AreaModel, LayerComparison, NetworkTotals, PowerModel};
use crate::pe::delay::{StageDelays, CLOCK_PERIOD_FO4, FO4_PS};
use crate::pe::PipelineKind;
use crate::sa::tile::TilePlan;
use crate::timing::model::{gemm_timing, TimingConfig};
use crate::util::table::{fnum, pct, Table};
use crate::workloads::layer::LayerDef;
use crate::workloads::{mobilenet, resnet50};

/// A rendered figure/table: the printable table + network totals.
pub struct Report {
    pub title: String,
    pub table: Table,
    pub totals: Option<NetworkTotals>,
}

impl Report {
    /// Render title + table (the benches' output format).
    pub fn render(&self) -> String {
        let mut s = format!("== {} ==\n", self.title);
        s.push_str(&self.table.render());
        if let Some(t) = &self.totals {
            s.push_str(&format!(
                "total: latency {} energy {}  (cycles {} -> {})\n",
                pct(t.latency_delta()),
                pct(t.energy_delta()),
                t.cycles_baseline,
                t.cycles_skewed
            ));
        }
        s
    }
}

/// Shared per-layer energy comparison over a layer table (Figs. 7/8).
pub fn per_layer_energy(
    title: &str,
    layers: &[LayerDef],
    tcfg: &TimingConfig,
    pmodel: &PowerModel,
) -> Report {
    let mut table = Table::new(&[
        "layer",
        "M",
        "K",
        "N",
        "cyc-base",
        "cyc-skew",
        "lat-delta",
        "E-base(uJ)",
        "E-skew(uJ)",
        "E-delta",
    ])
    .numeric();
    let mut totals = NetworkTotals::default();
    for l in layers {
        let shape = l.gemm();
        let plan = TilePlan::new(shape, tcfg.rows, tcfg.cols);
        let c = LayerComparison::evaluate(tcfg, pmodel, &plan);
        totals.add(&c);
        table.row(&[
            l.name.clone(),
            shape.m.to_string(),
            shape.k.to_string(),
            shape.n.to_string(),
            c.baseline.timing.cycles.to_string(),
            c.skewed.timing.cycles.to_string(),
            pct(c.latency_delta()),
            fnum(c.baseline.energy_uj, 2),
            fnum(c.skewed.energy_uj, 2),
            pct(c.energy_delta()),
        ]);
    }
    Report { title: title.to_string(), table, totals: Some(totals) }
}

/// Fig. 7 — per-layer energy, MobileNetV1.
pub fn fig7_mobilenet(tcfg: &TimingConfig, pmodel: &PowerModel) -> Report {
    per_layer_energy("Fig. 7: MobileNet per-layer energy", &mobilenet::layers(), tcfg, pmodel)
}

/// Fig. 8 — per-layer energy, ResNet-50.
pub fn fig8_resnet50(tcfg: &TimingConfig, pmodel: &PowerModel) -> Report {
    per_layer_energy("Fig. 8: ResNet50 per-layer energy", &resnet50::layers(), tcfg, pmodel)
}

/// §IV area/power overheads (the "+9% area, +7% power" paragraph),
/// with the PE plane (∝ R·C) and the edge logic (∝ R+C) split out.
pub fn table1_area_power(chain: ChainCfg, geom: crate::sa::geometry::ArrayGeometry) -> Report {
    let (rows, cols) = (geom.rows, geom.cols);
    let area = AreaModel::new(chain);
    let power = PowerModel::new(area);
    let mut table = Table::new(&[
        "design",
        "PE-area(GE)",
        "array-area(MGE)",
        "edge-area(kGE)",
        "power@0.7(mW)",
    ])
    .numeric();
    for kind in [PipelineKind::Baseline3b, PipelineKind::Skewed] {
        table.row(&[
            kind.name().to_string(),
            fnum(area.pe_area(kind).total(), 0),
            fnum(area.array_area_geom(kind, geom) / 1e6, 3),
            fnum(area.edge_area(geom) / 1e3, 1),
            fnum(power.array_power_geom(kind, geom, 0.7) / 1e3, 1),
        ]);
    }
    table.row(&[
        "overhead".into(),
        pct(area.pe_area(PipelineKind::Skewed).total()
            / area.pe_area(PipelineKind::Baseline3b).total()
            - 1.0),
        pct(area.overhead(rows, cols)),
        "0%".into(), // edge logic is kind-independent
        pct(power.overhead(rows, cols, 0.7)),
    ]);
    Report {
        title: format!("Table: area & power on {geom} (paper §IV: +9% area, +7% power)"),
        table,
        totals: None,
    }
}

/// The shapes the `skewsa geometry` sweep picked (per criterion).
#[derive(Clone, Copy, Debug)]
pub struct GeometryChoice {
    /// Lowest whole-workload latency (total stream cycles).
    pub latency_best: crate::sa::geometry::ArrayGeometry,
    /// Lowest whole-workload energy.
    pub energy_best: crate::sa::geometry::ArrayGeometry,
}

/// Aspect-ratio sweep at a fixed PE budget (DESIGN.md §20): evaluate
/// every candidate geometry on every layer of a workload, mark the
/// per-layer winners, and report per-geometry totals with Pareto
/// markers over the (latency, energy) plane.
pub fn geometry_sweep(
    net: &str,
    layers: &[LayerDef],
    geoms: &[crate::sa::geometry::ArrayGeometry],
    run: &crate::config::RunConfig,
    kind: PipelineKind,
) -> (Report, GeometryChoice) {
    use crate::energy::layer_energy;
    assert!(!geoms.is_empty(), "sweep_geometries returns at least the square shape");
    let pmodel = PowerModel::new(AreaModel::new(run.chain()));
    let tcfgs: Vec<TimingConfig> = geoms
        .iter()
        .map(|&g| TimingConfig::for_geometry(g, run.clock_ghz, run.double_buffer))
        .collect();
    let mut table =
        Table::new(&["layer", "M", "K", "N", "geometry", "cycles", "E(uJ)", "opt"]).numeric();
    // totals[g] = (cycles, energy) of the whole workload on geometry g.
    let mut totals = vec![(0u64, 0.0f64); geoms.len()];
    for l in layers {
        let shape = l.gemm();
        let evals: Vec<_> = geoms
            .iter()
            .zip(&tcfgs)
            .map(|(&g, tcfg)| {
                let plan = TilePlan::for_geometry(shape, g);
                layer_energy(tcfg, &pmodel, kind, &plan)
            })
            .collect();
        let lat_best =
            evals.iter().enumerate().min_by_key(|(_, e)| e.timing.cycles).map(|(i, _)| i);
        let en_best = evals
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.energy_uj.total_cmp(&b.1.energy_uj))
            .map(|(i, _)| i);
        for (i, e) in evals.iter().enumerate() {
            totals[i].0 += e.timing.cycles;
            totals[i].1 += e.energy_uj;
            let opt = match (Some(i) == lat_best, Some(i) == en_best) {
                (true, true) => "lat+en",
                (true, false) => "lat",
                (false, true) => "en",
                (false, false) => "",
            };
            table.row(&[
                l.name.clone(),
                shape.m.to_string(),
                shape.k.to_string(),
                shape.n.to_string(),
                geoms[i].to_string(),
                e.timing.cycles.to_string(),
                fnum(e.energy_uj, 3),
                opt.to_string(),
            ]);
        }
    }
    // Pareto over the totals: a geometry survives when no other one is
    // at least as good on both axes and strictly better on one.
    let pareto = |i: usize| {
        !totals.iter().enumerate().any(|(j, &(c, e))| {
            j != i
                && c <= totals[i].0
                && e <= totals[i].1
                && (c < totals[i].0 || e < totals[i].1)
        })
    };
    for (i, &(cycles, energy)) in totals.iter().enumerate() {
        table.row(&[
            "TOTAL".into(),
            "-".into(),
            "-".into(),
            "-".into(),
            geoms[i].to_string(),
            cycles.to_string(),
            fnum(energy, 3),
            if pareto(i) { "pareto".into() } else { String::new() },
        ]);
    }
    let lat_i = (0..geoms.len()).min_by_key(|&i| totals[i].0).unwrap();
    let en_i = (0..geoms.len()).min_by(|&a, &b| totals[a].1.total_cmp(&totals[b].1)).unwrap();
    let choice = GeometryChoice { latency_best: geoms[lat_i], energy_best: geoms[en_i] };
    let rep = Report {
        title: format!(
            "Geometry sweep: {net} on {} shapes at {} PEs ({})",
            geoms.len(),
            geoms[0].pe_count(),
            kind.name()
        ),
        table,
        totals: None,
    };
    (rep, choice)
}

/// §I/§IV headline: whole-network latency/energy deltas.
pub fn headline(tcfg: &TimingConfig, pmodel: &PowerModel) -> Report {
    let mut table = Table::new(&[
        "network",
        "cyc-base",
        "cyc-skew",
        "latency-delta",
        "E-base(uJ)",
        "E-skew(uJ)",
        "energy-delta",
        "paper",
    ])
    .numeric();
    for (name, layers, paper) in [
        ("MobileNetV1", mobilenet::layers(), "-16% lat / -8% E"),
        ("ResNet50", resnet50::layers(), "-21% lat / -11% E"),
    ] {
        let mut tot = NetworkTotals::default();
        for l in &layers {
            let plan = TilePlan::new(l.gemm(), tcfg.rows, tcfg.cols);
            tot.add(&LayerComparison::evaluate(tcfg, pmodel, &plan));
        }
        table.row(&[
            name.to_string(),
            tot.cycles_baseline.to_string(),
            tot.cycles_skewed.to_string(),
            pct(tot.latency_delta()),
            fnum(tot.energy_baseline_uj, 1),
            fnum(tot.energy_skewed_uj, 1),
            pct(tot.energy_delta()),
            paper.to_string(),
        ]);
    }
    Report { title: "Headline: whole-network latency & energy".into(), table, totals: None }
}

/// A stage-delay cell: the FO4 figure, or a dash past the spec's depth.
fn stage_cell(d: &StageDelays, i: usize) -> String {
    match d.stage(i) {
        Some(v) => fnum(v, 1),
        None => "-".into(),
    }
}

/// Architecture ablation across every registered pipeline organisation:
/// stage delays, clock feasibility at the 1 GHz reference point, and
/// column latency.
pub fn ablation_pipelines(chain: ChainCfg, tcfg: &TimingConfig) -> Report {
    let mut table = Table::new(&[
        "pipeline",
        "s1(FO4)",
        "s2(FO4)",
        "s3(FO4)",
        "min-period(ps)",
        "1GHz-ok",
        "col-cycles(M=1)",
        "tile-cycles(M=49)",
    ])
    .numeric();
    for kind in PipelineKind::ALL {
        let d = StageDelays::for_kind(kind, &chain);
        let col = crate::sa::dataflow::WsSchedule::new(kind, tcfg.rows, 1, 1).total_cycles();
        let tile = gemm_timing(
            tcfg,
            kind,
            crate::sa::tile::GemmShape::new(49, tcfg.rows, tcfg.cols),
        )
        .cycles;
        table.row(&[
            kind.name().to_string(),
            stage_cell(&d, 1),
            stage_cell(&d, 2),
            stage_cell(&d, 3),
            fnum(d.critical() * FO4_PS, 0),
            if d.feasible_at(CLOCK_PERIOD_FO4) { "yes".into() } else { "NO".into() },
            col.to_string(),
            tile.to_string(),
        ]);
    }
    Report {
        title: "Ablation: registered pipeline organisations".into(),
        table,
        totals: None,
    }
}

/// The pipeline-organisation registry (`skewsa pipelines`): one row per
/// registered spec with its scheduling parameters, per-stage delays,
/// clock feasibility, and area inventory at the given chain.
pub fn pipelines_registry(chain: ChainCfg) -> Report {
    let area = AreaModel::new(chain);
    let mut table = Table::new(&[
        "pipeline",
        "aliases",
        "S",
        "depth",
        "tail",
        "datapath",
        "s1(FO4)",
        "s2(FO4)",
        "s3(FO4)",
        "min-period(ps)",
        "1GHz-ok",
        "PE-area(GE)",
        "regs(bits)",
    ])
    .numeric();
    for kind in PipelineKind::ALL {
        let sp = kind.spec();
        let d = StageDelays::for_kind(kind, &chain);
        table.row(&[
            sp.name.to_string(),
            sp.aliases.join(","),
            sp.spacing.to_string(),
            sp.depth.to_string(),
            sp.column_tail.to_string(),
            sp.datapath.name().to_string(),
            stage_cell(&d, 1),
            stage_cell(&d, 2),
            stage_cell(&d, 3),
            fnum(d.critical() * FO4_PS, 0),
            if d.feasible_at(CLOCK_PERIOD_FO4) { "yes".into() } else { "NO".into() },
            fnum(area.pe_area(kind).total(), 0),
            sp.register_bits(&chain).to_string(),
        ]);
    }
    Report {
        title: format!(
            "Pipeline registry: {} organisations ({}->{})",
            PipelineKind::ALL.len(),
            chain.in_fmt.display_name(),
            chain.out_fmt.display_name()
        ),
        table,
        totals: None,
    }
}

/// Format sweep (Fig. 1 context): delay profile inversion across formats.
pub fn format_sweep() -> Report {
    use crate::arith::format::FpFormat;
    let mut table = Table::new(&[
        "format",
        "e",
        "m",
        "mult(FO4)",
        "exp+align(FO4)",
        "inverted",
    ])
    .numeric();
    for (f, out) in [
        (FpFormat::FP32, FpFormat::FP32),
        (FpFormat::BF16, FpFormat::FP32),
        (FpFormat::FP16, FpFormat::FP32),
        (FpFormat::FP8E4M3, FpFormat::FP16),
        (FpFormat::FP8E5M2, FpFormat::FP16),
    ] {
        let chain = ChainCfg::new(f, out);
        let b = crate::pe::delay::BlockDelays::for_cfg(&chain);
        let inverted = b.exp_compute + b.align > b.mult;
        table.row(&[
            f.display_name().to_string(),
            f.exp_bits.to_string(),
            f.man_bits.to_string(),
            fnum(b.mult, 1),
            fnum(b.exp_compute + b.align, 1),
            if inverted { "yes".into() } else { "no".into() },
        ]);
    }
    Report {
        title: "Formats (Fig. 1): delay-profile inversion at reduced precision".into(),
        table,
        totals: None,
    }
}

/// Design-space sweep: whole-network savings of a chosen pipeline
/// organisation over the Fig. 3(b) reference across array sizes and
/// input formats — the exploration a designer adopting a registered
/// organisation would run first (extension beyond the paper's single
/// 128×128/bf16/skewed point).
pub fn design_sweep(clock_ghz: f64, kind: PipelineKind) -> Report {
    use crate::arith::format::FpFormat;
    let mut table = Table::new(&[
        "array",
        "chain",
        "net",
        "latency-delta",
        "energy-delta",
        "area-overhead",
    ])
    .numeric();
    for &r in &[64usize, 128, 256] {
        for (inf, outf) in [
            (FpFormat::BF16, FpFormat::FP32),
            (FpFormat::FP8E4M3, FpFormat::FP16),
        ] {
            let chain = ChainCfg::new(inf, outf);
            let area = AreaModel::new(chain);
            let pmodel = PowerModel::new(area);
            let tcfg = TimingConfig { rows: r, cols: r, clock_ghz, double_buffer: true };
            // Array-level ratio (PE grid + rounding units), the same
            // definition `table1` uses via `AreaModel::overhead`.
            let area_overhead = area.array_area(kind, r, r)
                / area.array_area(PipelineKind::Baseline3b, r, r)
                - 1.0;
            for (net, layers) in
                [("mobilenet", mobilenet::layers()), ("resnet50", resnet50::layers())]
            {
                let mut tot = NetworkTotals::default();
                for l in &layers {
                    let plan = TilePlan::new(l.gemm(), r, r);
                    tot.add(&LayerComparison::evaluate_pair(
                        &tcfg,
                        &pmodel,
                        &plan,
                        PipelineKind::Baseline3b,
                        kind,
                    ));
                }
                table.row(&[
                    format!("{r}x{r}"),
                    format!("{}->{}", inf.display_name(), outf.display_name()),
                    net.to_string(),
                    pct(tot.latency_delta()),
                    pct(tot.energy_delta()),
                    pct(area_overhead),
                ]);
            }
        }
    }
    Report {
        title: format!("Design-space sweep: array size × format ({} vs baseline-3b)", kind.name()),
        table,
        totals: None,
    }
}

/// Scientific-notation cell for error magnitudes (`inf` when a plan
/// overflowed/saturated — the unmeetable-budget marker).
fn sci(x: f64) -> String {
    if x.is_infinite() {
        "inf".into()
    } else {
        format!("{x:.2e}")
    }
}

/// Per-layer mixed-precision plan (DESIGN.md §12): the format the
/// planner assigned each layer, its measured error against the f64
/// oracle, and its modeled energy.  Rendered by `skewsa precision`.
pub fn precision_per_layer(net: &str, study: &crate::precision::PrecisionStudy) -> Report {
    let plan = &study.mixed;
    let mut table = Table::new(&[
        "layer",
        "M",
        "K",
        "N",
        "format",
        "pipeline",
        "max-rel",
        "mean-rel",
        "max-ULP",
        "sat",
        "E(uJ)",
        "in-budget",
    ])
    .numeric();
    for l in &plan.layers {
        // `!clk` marks a layer whose chosen organisation cannot close
        // timing at the costed clock (only possible when *no* candidate
        // could — the walk prefers feasible ones).
        let pipeline = if l.clock_feasible {
            l.kind.name().to_string()
        } else {
            format!("{} !clk", l.kind.name())
        };
        table.row(&[
            l.layer.clone(),
            l.shape.m.to_string(),
            l.shape.k.to_string(),
            l.shape.n.to_string(),
            l.fmt.display_name().to_string(),
            pipeline,
            sci(l.stats.max_rel),
            sci(l.stats.mean_rel),
            l.stats.max_ulp.to_string(),
            l.stats.sat_events.to_string(),
            fnum(l.energy_uj, 2),
            if l.within_budget { "yes".into() } else { "NO (fp32 fallback)".into() },
        ]);
    }
    Report {
        title: format!(
            "Precision plan: {net} (kinds {}, budget {:.1e}, {} layers)",
            plan.kinds_label(),
            plan.budget,
            plan.layers.len()
        ),
        table,
        totals: None,
    }
}

/// Quality-vs-energy-vs-latency Pareto table (DESIGN.md §12): the
/// budgeted mixed plan against every uniform-format plan, with energy
/// deltas versus the all-FP32 baseline and Pareto-efficiency markers.
pub fn precision_pareto(net: &str, study: &crate::precision::PrecisionStudy) -> Report {
    use crate::arith::format::FpFormat;
    let fp32_energy = study
        .uniform
        .iter()
        .find(|p| p.label == FpFormat::FP32.display_name())
        .map(|p| p.total_energy_uj())
        .unwrap_or(f64::NAN);
    let mut table = Table::new(&[
        "plan",
        "formats",
        "pipelines",
        "worst-rel",
        "E(uJ)",
        "E-vs-FP32",
        "cycles",
        "meets-budget",
        "pareto",
    ])
    .numeric();
    for plan in study.plans() {
        let formats = plan
            .format_histogram()
            .iter()
            .map(|(f, n)| format!("{}x{}", n, f.display_name()))
            .collect::<Vec<_>>()
            .join("+");
        let pipelines = plan
            .kind_histogram()
            .iter()
            .map(|(k, n)| format!("{}x{}", n, k.name()))
            .collect::<Vec<_>>()
            .join("+");
        table.row(&[
            plan.label.clone(),
            formats,
            pipelines,
            sci(plan.worst_rel()),
            fnum(plan.total_energy_uj(), 1),
            pct(plan.total_energy_uj() / fp32_energy - 1.0),
            plan.total_cycles().to_string(),
            if plan.meets_budget() { "yes".into() } else { "no".into() },
            if study.is_pareto(plan) { "*".into() } else { "".into() },
        ]);
    }
    Report {
        title: format!("Precision Pareto: {net} — quality vs energy vs latency"),
        table,
        totals: None,
    }
}

/// Serving summary: latency percentiles, throughput, batching and
/// plan-cache effectiveness, per-shard load (DESIGN.md §11; rendered by
/// `skewsa serve` and `bench_serve`).
/// Multi-tile layer latency: serialized vs double-buffered weight
/// preload for every layer of a network (the `skewsa stream`
/// subcommand; the README's "multi-tile latency" walkthrough quotes
/// this table for a ResNet-50 layer).  All numbers are the closed-form
/// [`crate::timing::layer_timing`], which the streaming cycle simulator
/// pins exactly (`tests/prop_streaming.rs`).
pub fn multi_tile_latency(
    title: &str,
    layers: &[LayerDef],
    tcfg: &TimingConfig,
    kind: PipelineKind,
) -> Report {
    use crate::timing::model::layer_timing;
    let mut table = Table::new(&[
        "layer",
        "M",
        "K",
        "N",
        "tiles",
        "cyc-serial",
        "cyc-overlap",
        "saved",
        "exposed",
        "drain",
    ])
    .numeric();
    let overlap = TimingConfig { double_buffer: true, ..*tcfg };
    let serial = TimingConfig { double_buffer: false, ..*tcfg };
    for l in layers {
        let shape = l.gemm();
        let plan = TilePlan::new(shape, tcfg.rows, tcfg.cols);
        let o = layer_timing(&overlap, kind, &plan);
        let s = layer_timing(&serial, kind, &plan);
        // Fraction of the serialized latency that double buffering
        // hides (positive = saved).
        let saved = 1.0 - o.cycles as f64 / s.cycles as f64;
        table.row(&[
            l.name.clone(),
            shape.m.to_string(),
            shape.k.to_string(),
            shape.n.to_string(),
            plan.tile_count().to_string(),
            s.cycles.to_string(),
            o.cycles.to_string(),
            pct(saved),
            o.exposed_preload.to_string(),
            o.drain_cycles.to_string(),
        ]);
    }
    Report { title: title.to_string(), table, totals: None }
}

/// The health-state label a `shard.N.health` gauge code renders as
/// (the codes [`crate::serve::Server::metrics`] publishes).
fn health_label(code: u64) -> &'static str {
    match code {
        0 => "healthy",
        1 => "probation",
        _ => "quarantined",
    }
}

pub fn serve_summary(load: &crate::serve::LoadReport, snap: &crate::obs::MetricsSnapshot) -> Report {
    // Absolute fractions, not deltas: plain percent, no forced sign.
    let frac = |x: f64| format!("{:.1}%", x * 100.0);
    let mut table = Table::new(&["metric", "value"]).numeric();
    let l = &load.latency;
    table.row(&["requests".into(), load.completed.to_string()]);
    table.row(&["throughput (req/s)".into(), fnum(l.throughput_rps, 1)]);
    table.row(&["latency p50 (us)".into(), fnum(l.p50_us, 1)]);
    table.row(&["latency p95 (us)".into(), fnum(l.p95_us, 1)]);
    table.row(&["latency p99 (us)".into(), fnum(l.p99_us, 1)]);
    table.row(&["latency mean (us)".into(), fnum(l.mean_us, 1)]);
    table.row(&["batched responses".into(), frac(load.batched_fraction())]);
    table.row(&["max batch size".into(), load.max_batch.to_string()]);
    let hits = snap.counter("cache.hits");
    let lookups = hits + snap.counter("cache.misses");
    let hit_rate = if lookups == 0 { 0.0 } else { hits as f64 / lookups as f64 };
    table.row(&["plan-cache hit rate".into(), frac(hit_rate)]);
    table.row(&["plan-cache entries".into(), snap.gauge("cache.entries").to_string()]);
    // Simulated array-time under the configured preload discipline —
    // the overlapped-timing number the streaming cycle simulator pins.
    table.row(&[
        "sim service cycles (resp-weighted)".into(),
        load.stream_cycles_observed.to_string(),
    ]);
    let shards = snap.gauge("serve.shards") as usize;
    let shard_sum =
        |name: &str| -> u64 { (0..shards).map(|i| snap.counter(&format!("shard.{i}.{name}"))).sum() };
    // Exact tile-retry count from the shard counters (the per-response
    // sum in LoadReport counts a batch's retries once per member).
    table.row(&["tile retries".into(), shard_sum("retries").to_string()]);
    // Fault-tolerance lifecycle (DESIGN.md §16), aggregated over shards.
    table.row(&["requests shed".into(), snap.counter("serve.shed").to_string()]);
    table.row(&[
        "sdc injected/detected/recovered/unresolved".into(),
        format!(
            "{}/{}/{}/{}",
            shard_sum("sdc_injected"),
            shard_sum("sdc_detected"),
            shard_sum("sdc_recovered"),
            shard_sum("sdc_unresolved")
        ),
    ]);
    table.row(&["failed batches".into(), shard_sum("failed_batches").to_string()]);
    table.row(&["shard quarantines".into(), shard_sum("quarantines").to_string()]);
    for i in 0..shards {
        let c = |name: &str| snap.counter(&format!("shard.{i}.{name}"));
        table.row(&[
            format!("shard {i} batches/requests/rows"),
            format!("{}/{}/{}", c("batches"), c("requests"), c("rows")),
        ]);
    }
    Report { title: "Serve: multi-tenant GEMM serving summary".into(), table, totals: None }
}

/// The `skewsa faults` chaos-run report: the serve summary's fault
/// rows, expanded per shard with the health board's state.
pub fn faults_summary(
    load: &crate::serve::LoadReport,
    snap: &crate::obs::MetricsSnapshot,
) -> Report {
    let mut table = Table::new(&["metric", "value"]).numeric();
    table.row(&["requests completed".into(), load.completed.to_string()]);
    // The server-side counter is authoritative; the client-observed
    // count (load.shed) also includes post-shutdown rejections.
    table.row(&["requests shed".into(), snap.counter("serve.shed").to_string()]);
    table.row(&["latency p99 (us)".into(), fnum(load.latency.p99_us, 1)]);
    table.row(&[
        "health transitions q/p/h".into(),
        format!(
            "{}/{}/{}",
            snap.counter("health_transitions.quarantined"),
            snap.counter("health_transitions.probation"),
            snap.counter("health_transitions.healthy")
        ),
    ]);
    let shards = snap.gauge("serve.shards") as usize;
    for i in 0..shards {
        let c = |name: &str| snap.counter(&format!("shard.{i}.{name}"));
        table.row(&[
            format!("shard {i} sdc inj/det/rec/unres"),
            format!(
                "{}/{}/{}/{}",
                c("sdc_injected"),
                c("sdc_detected"),
                c("sdc_recovered"),
                c("sdc_unresolved")
            ),
        ]);
        table.row(&[
            format!("shard {i} failed batches / quarantines"),
            format!("{}/{}", c("failed_batches"), c("quarantines")),
        ]);
        table.row(&[
            format!("shard {i} health"),
            health_label(snap.gauge(&format!("shard.{i}.health"))).into(),
        ]);
    }
    Report { title: "Faults: chaos run summary".into(), table, totals: None }
}

/// The `skewsa fleet` report: the discrete-event simulator's headline
/// accounting, the latency/service distributions in both the cycle
/// domain and wall microseconds (via `clock_ghz`), and the autoscaler's
/// trajectory.
pub fn fleet_summary(r: &crate::fleet::FleetResult, clock_ghz: f64) -> Report {
    let frac = |x: f64| format!("{:.1}%", x * 100.0);
    let us = |cycles: u64| cycles as f64 / (clock_ghz * 1e3);
    let cyc_us = |cycles: u64| format!("{} / {}", cycles, fnum(us(cycles), 1));
    let mut table = Table::new(&["metric", "value"]).numeric();
    table.row(&["requests submitted".into(), r.submitted.to_string()]);
    table.row(&["requests served".into(), r.served.to_string()]);
    table.row(&[
        "requests shed (bucket/watermark/capacity)".into(),
        format!("{} ({}/{}/{})", r.shed, r.shed_bucket, r.shed_watermark, r.shed_capacity),
    ]);
    table.row(&["requests failed".into(), r.failed.to_string()]);
    let shed_rate = if r.submitted == 0 { 0.0 } else { r.shed as f64 / r.submitted as f64 };
    table.row(&["shed rate".into(), frac(shed_rate)]);
    table.row(&["batches dispatched".into(), r.batches.to_string()]);
    let done = r.served + r.failed;
    let mean_batch = if r.batches == 0 { 0.0 } else { done as f64 / r.batches as f64 };
    table.row(&["mean/max batch size".into(), format!("{}/{}", fnum(mean_batch, 2), r.max_batch)]);
    table.row(&["batched rows".into(), r.batched_rows.to_string()]);
    table.row(&["wall (virtual cycles)".into(), r.wall_cycles.to_string()]);
    table.row(&["latency p50 (cyc / us)".into(), cyc_us(r.latency.quantile(50.0))]);
    table.row(&["latency p99 (cyc / us)".into(), cyc_us(r.latency.quantile(99.0))]);
    table.row(&["latency mean (cycles)".into(), fnum(r.latency.mean(), 1)]);
    table.row(&["service p50 (cyc / us)".into(), cyc_us(r.service.quantile(50.0))]);
    table.row(&["service p99 (cyc / us)".into(), cyc_us(r.service.quantile(99.0))]);
    table.row(&["goodput (req/s)".into(), fnum(r.goodput_rps(clock_ghz), 1)]);
    table.row(&["array energy (uJ)".into(), fnum(r.energy_uj, 1)]);
    table.row(&["goodput per joule".into(), fnum(r.goodput_per_joule(), 1)]);
    table.row(&["plan-cache hit rate".into(), frac(r.cache.hit_rate())]);
    table.row(&["shard quarantines".into(), r.quarantines.to_string()]);
    table.row(&["final active shards".into(), r.final_active.to_string()]);
    table.row(&["total stream cycles (array busy)".into(), r.stream_cycles.to_string()]);
    // Utilization grouped by array geometry: the heterogeneous-fleet
    // view (one line per distinct shape, square fleets collapse to one).
    let mut seen: Vec<crate::sa::geometry::ArrayGeometry> = Vec::new();
    for &g in &r.shard_geoms {
        if !seen.contains(&g) {
            seen.push(g);
        }
    }
    for g in seen {
        let (count, busy) = r
            .shard_geoms
            .iter()
            .zip(&r.shard_busy)
            .filter(|(&sg, _)| sg == g)
            .fold((0u64, 0u64), |(n, b), (_, &sb)| (n + 1, b + sb));
        let util = if r.wall_cycles == 0 {
            0.0
        } else {
            busy as f64 / (r.wall_cycles.saturating_mul(count)) as f64
        };
        table.row(&[format!("utilization {g} ({count} shard(s))"), frac(util)]);
    }
    if !r.autoscale.is_empty() {
        let lo = r.autoscale.iter().map(|p| p.active).min().unwrap_or(0);
        let hi = r.autoscale.iter().map(|p| p.active).max().unwrap_or(0);
        table.row(&[
            "autoscale evals (active lo..hi)".into(),
            format!("{} ({}..{})", r.autoscale.len(), lo, hi),
        ]);
    }
    Report { title: "Fleet: discrete-event serving simulation".into(), table, totals: None }
}

/// The `skewsa trace` critical-path breakdown: per-phase wall-time
/// percentiles over the Ok spans of one trace file, plus the
/// cycle-domain attribution (exposed preload / compute / drain / ABFT
/// recovery) — "where did my p99 go?", answered from data (the README
/// walkthrough).
///
/// Phase percentiles are exact nearest-rank over the span set (a trace
/// file is bounded; no histogram approximation needed here).  The
/// `share` column is each phase's fraction of summed end-to-end time —
/// phases partition a span's lifetime exactly, so the column sums to
/// 100%.
pub fn trace_summary(spans: &[crate::obs::SpanRecord]) -> Report {
    use crate::obs::{Phase, SpanStatus};
    // One distribution row: p50/p99/mean of `vals` (divided by `unit`
    // for display) and `sum(vals)` as a share of `denom`.
    fn dist_row(table: &mut Table, label: String, mut vals: Vec<u64>, denom: u64, unit: f64) {
        use crate::serve::percentile_ns;
        vals.sort_unstable();
        let sum: u64 = vals.iter().sum();
        let mean = if vals.is_empty() { 0.0 } else { sum as f64 / vals.len() as f64 };
        let share = if denom == 0 {
            "-".to_string()
        } else {
            format!("{:.1}%", sum as f64 / denom as f64 * 100.0)
        };
        table.row(&[
            label,
            fnum(percentile_ns(&vals, 50.0) as f64 / unit, 1),
            fnum(percentile_ns(&vals, 99.0) as f64 / unit, 1),
            fnum(mean / unit, 1),
            share,
        ]);
    }
    let ok: Vec<&crate::obs::SpanRecord> =
        spans.iter().filter(|s| s.status == SpanStatus::Ok).collect();
    let count_of = |st: SpanStatus| spans.iter().filter(|s| s.status == st).count();
    let mut table = Table::new(&["component", "p50", "p99", "mean", "share"]).numeric();
    let total_ns: u64 = ok.iter().map(|s| s.total_ns()).sum();
    for ph in Phase::ALL {
        let ns: Vec<u64> = ok.iter().map(|s| s.phases_ns[ph as usize]).collect();
        dist_row(&mut table, format!("{}(us)", ph.name()), ns, total_ns, 1_000.0);
    }
    dist_row(
        &mut table,
        "total(us)".into(),
        ok.iter().map(|s| s.total_ns()).collect(),
        total_ns,
        1_000.0,
    );
    // Cycle-domain attribution: the same percentile/share treatment in
    // the array's clock domain.  Shares are of total attributed cycles
    // (stream total + recovery), so these rows answer "which cycles"
    // the way the phase rows answer "which microseconds".
    let cycles_total: u64 = ok.iter().map(|s| s.cycles.total()).sum();
    let buckets: [(&str, fn(&crate::obs::CycleAttribution) -> u64); 4] = [
        ("cycles:exposed_preload", |c| c.exposed_preload),
        ("cycles:compute", |c| c.compute),
        ("cycles:drain", |c| c.drain),
        ("cycles:recovery", |c| c.recovery),
    ];
    for (label, get) in buckets {
        let cy: Vec<u64> = ok.iter().map(|s| get(&s.cycles)).collect();
        dist_row(&mut table, label.to_string(), cy, cycles_total, 1.0);
    }
    Report {
        title: format!(
            "Trace: {} spans ({} ok, {} shed, {} closed, {} failed)",
            spans.len(),
            ok.len(),
            count_of(SpanStatus::Shed),
            count_of(SpanStatus::Closed),
            count_of(SpanStatus::Failed)
        ),
        table,
        totals: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (TimingConfig, PowerModel) {
        (TimingConfig::PAPER, PowerModel::new(AreaModel::new(ChainCfg::BF16_FP32)))
    }

    #[test]
    fn fig7_has_28_rows_and_reproduces_shape() {
        let (t, p) = setup();
        let r = fig7_mobilenet(&t, &p);
        assert_eq!(r.table.n_rows(), 28);
        let tot = r.totals.unwrap();
        // Paper: −16% latency, −8% energy.  Band: direction + rough factor.
        assert!((-0.25..=-0.10).contains(&tot.latency_delta()), "{}", tot.latency_delta());
        assert!((-0.14..=-0.05).contains(&tot.energy_delta()), "{}", tot.energy_delta());
    }

    #[test]
    fn fig8_has_54_rows_and_reproduces_shape() {
        let (t, p) = setup();
        let r = fig8_resnet50(&t, &p);
        assert_eq!(r.table.n_rows(), 54);
        let tot = r.totals.unwrap();
        // Paper: −21% latency, −11% energy.
        assert!((-0.28..=-0.15).contains(&tot.latency_delta()), "{}", tot.latency_delta());
        assert!((-0.16..=-0.07).contains(&tot.energy_delta()), "{}", tot.energy_delta());
    }

    #[test]
    fn early_layers_lose_late_layers_win() {
        // The per-layer signature of Figs. 7/8 (§IV, last paragraph).
        let (t, p) = setup();
        let layers = mobilenet::layers();
        let first = LayerComparison::evaluate(
            &t,
            &p,
            &TilePlan::new(layers[0].gemm(), t.rows, t.cols),
        );
        let late = LayerComparison::evaluate(
            &t,
            &p,
            &TilePlan::new(layers[26].gemm(), t.rows, t.cols), // conv14/pw, 7×7
        );
        assert!(first.energy_delta() > 0.0, "early: {}", first.energy_delta());
        assert!(late.energy_delta() < -0.1, "late: {}", late.energy_delta());
    }

    #[test]
    fn table1_prints_overheads() {
        let r = table1_area_power(ChainCfg::BF16_FP32, 128, 128);
        let text = r.render();
        assert!(text.contains("overhead"));
        assert_eq!(r.table.n_rows(), 3);
    }

    #[test]
    fn ablation_reports_every_registered_pipeline() {
        let r = ablation_pipelines(ChainCfg::BF16_FP32, &TimingConfig::PAPER);
        let text = r.render();
        let rows: Vec<&str> = text.lines().filter(|l| l.starts_with("row:")).collect();
        assert_eq!(rows.len(), PipelineKind::ALL.len());
        assert!(rows[0].contains("regular-3a"));
        // The paper's two contenders close timing at the 1 GHz point
        // (§IV assumes both designs optimised for 1 GHz)…
        assert!(rows[1].contains("yes"), "{}", rows[1]);
        assert!(rows[2].contains("yes"), "{}", rows[2]);
        // …while the transparent registration trades the clock away and
        // deep3 closes with slack on a third stage.
        assert!(rows[3].contains("transparent") && rows[3].contains("NO"), "{}", rows[3]);
        assert!(rows[4].contains("deep3") && rows[4].contains("yes"), "{}", rows[4]);
        // 3(a)'s stage 1 carries the serial exp+align it can no longer
        // hide under the multiplier (the broken assumption of §II).
        let d3a = StageDelays::for_kind(PipelineKind::Regular3a, &ChainCfg::BF16_FP32);
        let d3b = StageDelays::for_kind(PipelineKind::Baseline3b, &ChainCfg::BF16_FP32);
        assert!(d3a.stage1() > d3b.stage1());
    }

    #[test]
    fn pipelines_registry_renders_every_spec() {
        let r = pipelines_registry(ChainCfg::BF16_FP32);
        let text = r.render();
        let rows: Vec<&str> = text.lines().filter(|l| l.starts_with("row:")).collect();
        assert_eq!(rows.len(), PipelineKind::ALL.len());
        for kind in PipelineKind::ALL {
            assert!(text.contains(kind.name()), "{}", kind.name());
        }
        // Aliases and scheduling parameters surface in the table.
        assert!(text.contains("arrayflex"), "{text}");
        // Two-stage specs leave the s3 column dashed; deep3 fills it.
        let skewed_row = rows.iter().find(|l| l.contains(" skewed")).unwrap();
        assert!(skewed_row.contains('-'), "{skewed_row}");
        let deep3_row = rows.iter().find(|l| l.contains("deep3")).unwrap();
        assert!(!deep3_row.split_whitespace().any(|c| c == "-"), "{deep3_row}");
    }

    #[test]
    fn format_sweep_inversion_pattern() {
        let text = format_sweep().render();
        // Canonical display names (FpFormat::display_name) everywhere.
        let fp32_row = text.lines().find(|l| l.contains("FP32")).unwrap();
        assert!(fp32_row.ends_with("no"));
        let bf16_row = text.lines().find(|l| l.contains("BF16")).unwrap();
        assert!(bf16_row.ends_with("yes"));
        assert!(text.contains("FP8-E4M3"), "canonical FP8 spelling: {text}");
        assert!(!text.contains("fp8e4m3"), "machine names must not leak into tables");
    }

    #[test]
    fn design_sweep_savings_grow_with_depth() {
        let r = design_sweep(1.0, PipelineKind::Skewed);
        assert_eq!(r.table.n_rows(), 12);
        let text = r.render();
        // 256-deep arrays save more than 64-deep ones (R−2 per tile).
        let extract = |needle: &str| -> f64 {
            let row = text
                .lines()
                .find(|l| l.contains(needle) && l.contains("resnet50") && l.contains("BF16"))
                .unwrap();
            let cell = row.split_whitespace().nth(4).unwrap();
            cell.trim_end_matches('%').parse::<f64>().unwrap()
        };
        assert!(extract("256x256") < extract("64x64"));
    }

    #[test]
    fn precision_reports_render_plan_and_pareto() {
        use crate::arith::format::FpFormat;
        use crate::precision::{AnalysisConfig, PlannerConfig, PrecisionStudy};
        let layers = vec![LayerDef::conv("c1", 8, 3, 1, 8, 8), LayerDef::fc("f1", 32, 16)];
        let cfg = PlannerConfig {
            budget: 1e-2,
            kinds: vec![PipelineKind::Skewed, PipelineKind::Deep3],
            candidates: FpFormat::ALL.to_vec(),
            analysis: AnalysisConfig { m_cap: 2, n_cap: 3, seed: 0 },
            tcfg: TimingConfig { rows: 16, cols: 16, clock_ghz: 1.0, double_buffer: true },
        };
        let study = PrecisionStudy::run(&layers, &cfg);
        let per = precision_per_layer("tiny", &study);
        assert_eq!(per.table.n_rows(), 2);
        assert!(per.render().contains("budget"));
        assert!(per.render().contains("skewed+deep3"), "{}", per.render());
        let pareto = precision_pareto("tiny", &study);
        // Mixed plan + one row per candidate format.
        assert_eq!(pareto.table.n_rows(), 1 + FpFormat::ALL.len());
        let text = pareto.render();
        assert!(text.contains("mixed"));
        assert!(text.contains("FP8-E4M3"), "canonical names in the pareto table: {text}");
        assert!(text.contains("+0.0%"), "the FP32 row is its own energy baseline: {text}");
    }

    #[test]
    fn serve_summary_renders_metrics_and_shards() {
        use crate::obs::MetricsRegistry;
        use crate::serve::{LatencySummary, LoadReport};
        let load = LoadReport {
            latency: LatencySummary {
                count: 10,
                mean_us: 120.0,
                p50_us: 100.0,
                p95_us: 200.0,
                p99_us: 250.0,
                max_us: 260.0,
                wall_s: 0.5,
                throughput_rps: 20.0,
            },
            completed: 10,
            batched_responses: 6,
            max_batch: 4,
            cache_hit_responses: 8,
            retries_observed: 0,
            stream_cycles_observed: 12_345,
            shed: 0,
            failed: 0,
        };
        // The registry shape Server::metrics() publishes.
        let r = MetricsRegistry::new();
        r.counter("serve.submitted").add(10);
        r.counter("serve.shed").add(2);
        r.counter("cache.hits").add(4);
        r.counter("cache.misses").add(1);
        r.gauge("cache.entries").set(1);
        r.gauge("serve.shards").set(2);
        r.counter("shard.0.batches").add(3);
        r.gauge("shard.1.health").set(1);
        let snap = r.snapshot();
        let text = serve_summary(&load, &snap).render();
        assert!(text.contains("latency p99"));
        assert!(text.contains("shard 1"));
        assert!(text.contains("requests shed"));
        assert!(text.contains("sdc injected/detected/recovered/unresolved"));
        let faults = faults_summary(&load, &snap).render();
        assert!(faults.contains("shard 0 health"));
        assert!(faults.contains("healthy"), "code 0 renders healthy: {faults}");
        assert!(faults.contains("probation"), "code 1 renders probation: {faults}");
        assert!(faults.contains("health transitions"), "{faults}");
        assert!(text.contains("plan-cache hit rate"));
        assert!(text.contains("sim service cycles"));
        assert!(text.contains("12345"), "stream cycles render: {text}");
        assert!(text.contains("80.0%"), "hit rate 4/5 renders: {text}");
        assert!(!text.contains("+80.0%"), "absolute rate must not carry a delta sign: {text}");
    }

    #[test]
    fn trace_summary_breaks_down_phases_and_cycles() {
        use crate::obs::{CycleAttribution, SpanRecord, SpanStatus};
        let span = |id: u64, status: SpanStatus, queue_ns: u64| SpanRecord {
            id,
            model: 0,
            kind: "skewed".into(),
            class: "batch".into(),
            rows: 2,
            status,
            shard: Some(0),
            batch_size: 1,
            cache_hit: false,
            retries: 0,
            phases_ns: [queue_ns, 10_000, 5_000, 2_000, 40_000, 3_000],
            cycles: CycleAttribution {
                exposed_preload: 8,
                compute: 100,
                drain: 6,
                recovery: 114,
            },
            sdc_detected: 1,
            sdc_recovered: 1,
            sdc_unresolved: 0,
        };
        let spans =
            vec![span(0, SpanStatus::Ok, 20_000), span(1, SpanStatus::Ok, 60_000), span(2, SpanStatus::Shed, 500)];
        let r = trace_summary(&spans);
        assert!(r.title.contains("3 spans"), "{}", r.title);
        assert!(r.title.contains("2 ok") && r.title.contains("1 shed"), "{}", r.title);
        let text = r.render();
        // 6 phases + total + 4 cycle buckets.
        assert_eq!(r.table.n_rows(), 11);
        assert!(text.contains("queue(us)") && text.contains("execute(us)"), "{text}");
        assert!(text.contains("cycles:recovery"), "{text}");
        // Recovery is half of each span's attributed cycles (114 of 228).
        assert!(text.contains("50.0%"), "recovery share: {text}");
        // The total row's share is 100% (phases partition the lifetime).
        assert!(text.contains("100.0%"), "{text}");
    }

    #[test]
    fn headline_renders_both_networks() {
        let (t, p) = setup();
        let text = headline(&t, &p).render();
        assert!(text.contains("MobileNetV1"));
        assert!(text.contains("ResNet50"));
    }

    #[test]
    fn multi_tile_latency_shows_overlap_saving() {
        use crate::timing::model::layer_timing;
        let layers = resnet50::layers();
        let r = multi_tile_latency("stream", &layers, &TimingConfig::PAPER, PipelineKind::Skewed);
        assert_eq!(r.table.n_rows(), layers.len());
        let text = r.render();
        assert!(text.contains("cyc-serial") && text.contains("cyc-overlap"));
        // Every multi-tile ResNet-50 layer streams strictly faster
        // overlapped; exposed preload collapses to one fill (R = 128).
        for l in &layers {
            let plan = TilePlan::new(l.gemm(), 128, 128);
            let o = layer_timing(&TimingConfig::PAPER, PipelineKind::Skewed, &plan);
            let s = layer_timing(
                &TimingConfig { double_buffer: false, ..TimingConfig::PAPER },
                PipelineKind::Skewed,
                &plan,
            );
            assert_eq!(o.exposed_preload, 128, "{}", l.name);
            assert_eq!(s.cycles - o.cycles, (plan.tile_count() as u64 - 1) * 128, "{}", l.name);
        }
    }
}
