//! MobileNetV1 (224×224, width 1.0) layer table [18].
//!
//! 28 compute layers: the stem convolution, 13 depthwise/pointwise
//! pairs, and the classifier.  Shapes follow Table 1 of Howard et al.,
//! arXiv:1704.04861.

use super::layer::LayerDef;

/// The 28 compute layers of MobileNetV1.
pub fn layers() -> Vec<LayerDef> {
    let mut l = Vec::with_capacity(28);
    l.push(LayerDef::conv("conv1", 224, 3, 2, 3, 32));
    // (in_hw, stride, cin, cout) per separable block.
    let blocks: [(usize, usize, usize, usize); 13] = [
        (112, 1, 32, 64),
        (112, 2, 64, 128),
        (56, 1, 128, 128),
        (56, 2, 128, 256),
        (28, 1, 256, 256),
        (28, 2, 256, 512),
        (14, 1, 512, 512),
        (14, 1, 512, 512),
        (14, 1, 512, 512),
        (14, 1, 512, 512),
        (14, 1, 512, 512),
        (14, 2, 512, 1024),
        (7, 1, 1024, 1024),
    ];
    for (i, &(hw, s, cin, cout)) in blocks.iter().enumerate() {
        let n = i + 2; // block numbering matches the paper's layer index
        l.push(LayerDef::dw(&format!("conv{n}/dw"), hw, 3, s, cin));
        l.push(LayerDef::conv(&format!("conv{n}/pw"), hw / s, 1, 1, cin, cout));
    }
    l.push(LayerDef::fc("fc", 1024, 1000));
    l
}

/// Total multiply-accumulates of the network (for sanity checks).
pub fn total_macs() -> u64 {
    layers().iter().map(|l| l.macs()).sum()
}

/// Cross-check representative layers through the fast cycle simulator
/// on the paper's 128×128 array, both pipeline kinds — the per-layer
/// Fig. 7 numbers are built on the closed-form model these checks
/// validate (DESIGN.md §2).
pub fn cross_check_paper_tiles(m_cap: usize, threads: usize) -> Vec<super::layer::TileSimCheck> {
    super::layer::cross_check_paper_tiles(&layers(), m_cap, threads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::layer::LayerKind;

    #[test]
    fn has_28_compute_layers() {
        assert_eq!(layers().len(), 28);
    }

    #[test]
    fn macs_match_published_figure() {
        // MobileNetV1 is cited at ~569M mult-adds (Howard et al. §4).
        let m = total_macs();
        assert!(
            (540_000_000..600_000_000).contains(&m),
            "MobileNet MACs {m} outside published ~569M band"
        );
    }

    #[test]
    fn params_match_published_figure() {
        // ~4.2M parameters (conv + fc, ignoring BN).
        let p: u64 = layers().iter().map(|l| l.params()).sum();
        assert!((4_000_000..4_400_000).contains(&p), "params {p}");
    }

    #[test]
    fn structure_alternates_dw_pw() {
        let ls = layers();
        for i in 0..13 {
            let dw = &ls[1 + 2 * i];
            let pw = &ls[2 + 2 * i];
            assert!(matches!(dw.kind, LayerKind::DwConv { .. }), "{}", dw.name);
            assert!(matches!(pw.kind, LayerKind::Conv { kh: 1, .. }), "{}", pw.name);
            // The pointwise conv consumes the depthwise output resolution.
            assert_eq!(pw.in_hw, dw.out_hw());
        }
    }

    #[test]
    fn paper_tiles_cycle_sim_validates_model() {
        for chk in cross_check_paper_tiles(3, 4) {
            assert!(chk.ok(), "{chk:?}");
        }
    }

    #[test]
    fn final_feature_map_is_7x7x1024() {
        let ls = layers();
        let last_pw = &ls[27 - 1];
        assert_eq!(last_pw.out_hw(), 7);
        assert!(matches!(last_pw.kind, LayerKind::Conv { cout: 1024, .. }));
    }
}
