//! CNN layer descriptors and their im2col GEMM lowering.
//!
//! Layer shapes (not weights or images) determine the timing/energy
//! evaluation — the energy reported in the paper's Figs. 7/8 depends on
//! the per-layer GEMM dimensions `(M, K, N)` and activity factors, not
//! on what the pictures depict (DESIGN.md §2).
//!
//! Lowering conventions:
//! * standard convolution → one GEMM with `M = H_out·W_out`,
//!   `K = C_in·k_h·k_w`, `N = C_out` (im2col);
//! * depthwise convolution → one GEMM with `M = H_out·W_out`,
//!   `K = k_h·k_w`, `N = C` under the channel-per-column mapping (each
//!   array column holds one channel's filter taps and receives that
//!   channel's im2col stream — a West-edge-bandwidth-heavy but standard
//!   way to keep depthwise work on a WS array; see DESIGN.md §13);
//! * fully-connected → `M = batch`, `K = C_in`, `N = C_out`.

use crate::arith::fma::ChainCfg;
use crate::pe::PipelineKind;
use crate::sa::dataflow::WsSchedule;
use crate::sa::fast::FastArraySim;
use crate::sa::tile::GemmShape;
use crate::workloads::gemm::GemmData;

/// The operator types appearing in the evaluated CNNs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    /// Standard convolution.
    Conv { kh: usize, kw: usize, stride: usize, cin: usize, cout: usize },
    /// Depthwise convolution (one filter per channel).
    DwConv { kh: usize, kw: usize, stride: usize, channels: usize },
    /// Fully-connected / linear.
    Fc { cin: usize, cout: usize },
    /// A raw GEMM, already lowered (e.g. a transformer decode
    /// projection with `M` in-flight tokens): no spatial structure,
    /// the stationary weight is the `K×N` matrix itself.
    Gemm { m: usize, k: usize, n: usize },
}

/// One compute layer of a CNN.
#[derive(Clone, Debug)]
pub struct LayerDef {
    /// Short name, e.g. `"conv2_1/3x3"`.
    pub name: String,
    pub kind: LayerKind,
    /// Input spatial size (H == W for the evaluated nets); 1 for FC.
    pub in_hw: usize,
}

impl LayerDef {
    pub fn conv(
        name: &str,
        in_hw: usize,
        kh: usize,
        stride: usize,
        cin: usize,
        cout: usize,
    ) -> LayerDef {
        LayerDef {
            name: name.to_string(),
            kind: LayerKind::Conv { kh, kw: kh, stride, cin, cout },
            in_hw,
        }
    }

    pub fn dw(name: &str, in_hw: usize, kh: usize, stride: usize, channels: usize) -> LayerDef {
        LayerDef {
            name: name.to_string(),
            kind: LayerKind::DwConv { kh, kw: kh, stride, channels },
            in_hw,
        }
    }

    pub fn fc(name: &str, cin: usize, cout: usize) -> LayerDef {
        LayerDef { name: name.to_string(), kind: LayerKind::Fc { cin, cout }, in_hw: 1 }
    }

    pub fn gemm_layer(name: &str, m: usize, k: usize, n: usize) -> LayerDef {
        LayerDef { name: name.to_string(), kind: LayerKind::Gemm { m, k, n }, in_hw: 1 }
    }

    /// Output spatial size ("same" padding for stride 1, halving for
    /// stride 2 — the convention of both evaluated networks).
    pub fn out_hw(&self) -> usize {
        match self.kind {
            LayerKind::Conv { stride, .. } | LayerKind::DwConv { stride, .. } => {
                self.in_hw.div_ceil(stride)
            }
            LayerKind::Fc { .. } | LayerKind::Gemm { .. } => 1,
        }
    }

    /// The layer's GEMM shape under the module's lowering conventions.
    pub fn gemm(&self) -> GemmShape {
        let s = self.out_hw();
        match self.kind {
            LayerKind::Conv { kh, kw, cin, cout, .. } => GemmShape::new(s * s, cin * kh * kw, cout),
            LayerKind::DwConv { kh, kw, channels, .. } => GemmShape::new(s * s, kh * kw, channels),
            LayerKind::Fc { cin, cout } => GemmShape::new(1, cin, cout),
            LayerKind::Gemm { m, k, n } => GemmShape::new(m, k, n),
        }
    }

    /// Multiply-accumulate count of the layer.
    pub fn macs(&self) -> u64 {
        self.gemm().macs()
    }

    /// Parameter (weight) count.
    pub fn params(&self) -> u64 {
        match self.kind {
            LayerKind::Conv { kh, kw, cin, cout, .. } => (kh * kw * cin * cout) as u64,
            LayerKind::DwConv { kh, kw, channels, .. } => (kh * kw * channels) as u64,
            LayerKind::Fc { cin, cout } => (cin * cout) as u64,
            LayerKind::Gemm { k, n, .. } => (k * n) as u64,
        }
    }

    /// Cycle-simulate this layer's first weight tile on a `rows×cols`
    /// array through the fast banded simulator, cross-checking the
    /// closed-form timing model *and* bit-exact numerics in one pass
    /// (DESIGN.md §2: cycle simulation validates the model the
    /// whole-CNN figures are built on; it does not substitute for it).
    ///
    /// The streamed-row count is capped at `m_cap`: tile latency is
    /// linear in `M`, so a capped stream exercises the same per-kind
    /// coefficients (`S`, `tail`) at a fraction of the cost.  Weight
    /// rows beyond the layer's `K` stream zeros, as the timing model
    /// assumes (the array does not reconfigure).
    pub fn cross_check_tile_sim(
        &self,
        chain: &ChainCfg,
        rows: usize,
        cols: usize,
        kind: PipelineKind,
        m_cap: usize,
        threads: usize,
    ) -> TileSimCheck {
        let shape = self.gemm();
        let m = shape.m.min(m_cap.max(1));
        let n_used = shape.n.min(cols);
        let k_used = shape.k.min(rows);
        // Deterministic per-layer seed (FNV-1a over the layer name).
        let seed = self
            .name
            .bytes()
            .fold(0xcbf2_9ce4_8422_2325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100_0000_01b3));
        let data = GemmData::cnn_like(GemmShape::new(m, k_used, n_used), chain.in_fmt, seed);
        let mut w_full = data.w.clone();
        w_full.resize(rows, vec![0u64; n_used]);
        let mut a_full = data.a.clone();
        for row in &mut a_full {
            row.resize(rows, 0);
        }
        let model_cycles = WsSchedule::new(kind, rows, n_used, m).total_cycles();
        let mut sim = FastArraySim::new(*chain, kind, &w_full, &a_full);
        let ran = sim.run_parallel(model_cycles + 16, threads);
        let bit_exact =
            ran.is_ok() && sim.result_bits() == FastArraySim::oracle_bits(chain, &w_full, &a_full);
        TileSimCheck {
            layer: self.name.clone(),
            kind,
            m,
            sim_cycles: sim.cycles(),
            model_cycles,
            bit_exact,
            stalls: sim.stalls(),
        }
    }
}

/// Result of cross-checking one layer's representative weight tile
/// through the fast cycle simulator ([`LayerDef::cross_check_tile_sim`]).
#[derive(Clone, Debug)]
pub struct TileSimCheck {
    pub layer: String,
    pub kind: PipelineKind,
    /// Streamed rows actually simulated (the layer's `M`, capped).
    pub m: usize,
    pub sim_cycles: u64,
    pub model_cycles: u64,
    pub bit_exact: bool,
    pub stalls: u64,
}

impl TileSimCheck {
    /// Simulation and closed-form model agree, bit-exactly and on time.
    pub fn ok(&self) -> bool {
        self.bit_exact && self.sim_cycles == self.model_cycles && self.stalls == 0
    }
}

/// Cross-check representative layers of a network (stem, mid-network,
/// and the small-`M` late layers where the paper's saving concentrates)
/// through the fast cycle simulator on the paper's 128×128 array, both
/// pipeline kinds.  Shared by the MobileNetV1 / ResNet50 tables so the
/// Fig. 7 and Fig. 8 validation legs cannot drift apart.
pub fn cross_check_paper_tiles(
    layers: &[LayerDef],
    m_cap: usize,
    threads: usize,
) -> Vec<TileSimCheck> {
    let chain = ChainCfg::BF16_FP32;
    let picks = [0usize, layers.len() / 2, layers.len() - 2, layers.len() - 1];
    let mut checks = Vec::with_capacity(picks.len() * 2);
    for &i in &picks {
        for kind in [PipelineKind::Baseline3b, PipelineKind::Skewed] {
            checks.push(layers[i].cross_check_tile_sim(&chain, 128, 128, kind, m_cap, threads));
        }
    }
    checks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_gemm_lowering() {
        // 3×3 s2 conv, 224→112, 3→32 channels (MobileNet conv1).
        let l = LayerDef::conv("conv1", 224, 3, 2, 3, 32);
        assert_eq!(l.out_hw(), 112);
        assert_eq!(l.gemm(), GemmShape::new(112 * 112, 27, 32));
        assert_eq!(l.macs(), 112 * 112 * 27 * 32);
    }

    #[test]
    fn dw_gemm_lowering() {
        let l = LayerDef::dw("dw2", 112, 3, 2, 64);
        assert_eq!(l.out_hw(), 56);
        assert_eq!(l.gemm(), GemmShape::new(56 * 56, 9, 64));
        assert_eq!(l.params(), 9 * 64);
    }

    #[test]
    fn fc_lowering() {
        let l = LayerDef::fc("fc", 1024, 1000);
        assert_eq!(l.gemm(), GemmShape::new(1, 1024, 1000));
        assert_eq!(l.params(), 1_024_000);
    }

    #[test]
    fn raw_gemm_lowering_is_the_identity() {
        let l = LayerDef::gemm_layer("q_proj", 4, 4096, 64);
        assert_eq!(l.out_hw(), 1);
        assert_eq!(l.gemm(), GemmShape::new(4, 4096, 64));
        assert_eq!(l.macs(), 4 * 4096 * 64);
        assert_eq!(l.params(), 4096 * 64);
    }

    #[test]
    fn stride_one_preserves_spatial() {
        let l = LayerDef::conv("c", 56, 3, 1, 64, 64);
        assert_eq!(l.out_hw(), 56);
    }

    #[test]
    fn tile_sim_cross_check_validates_model() {
        // K > rows exercises the tile clamp; K < rows (depthwise)
        // exercises the zero-padded chain the model assumes.
        let cases = [LayerDef::conv("c", 8, 3, 1, 4, 6), LayerDef::dw("d", 8, 3, 1, 6)];
        for l in &cases {
            for kind in [PipelineKind::Baseline3b, PipelineKind::Skewed] {
                let chk = l.cross_check_tile_sim(&ChainCfg::BF16_FP32, 16, 8, kind, 5, 2);
                assert!(chk.ok(), "{chk:?}");
                assert_eq!(chk.m, 5);
                assert_eq!(chk.sim_cycles, chk.model_cycles);
            }
        }
    }
}
