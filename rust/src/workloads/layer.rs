//! CNN layer descriptors and their im2col GEMM lowering.
//!
//! Layer shapes (not weights or images) determine the timing/energy
//! evaluation — the energy reported in the paper's Figs. 7/8 depends on
//! the per-layer GEMM dimensions `(M, K, N)` and activity factors, not
//! on what the pictures depict (DESIGN.md §2).
//!
//! Lowering conventions:
//! * standard convolution → one GEMM with `M = H_out·W_out`,
//!   `K = C_in·k_h·k_w`, `N = C_out` (im2col);
//! * depthwise convolution → one GEMM with `M = H_out·W_out`,
//!   `K = k_h·k_w`, `N = C` under the channel-per-column mapping (each
//!   array column holds one channel's filter taps and receives that
//!   channel's im2col stream — a West-edge-bandwidth-heavy but standard
//!   way to keep depthwise work on a WS array; see DESIGN.md
//!   §Depthwise-mapping);
//! * fully-connected → `M = batch`, `K = C_in`, `N = C_out`.

use crate::sa::tile::GemmShape;

/// The operator types appearing in the evaluated CNNs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    /// Standard convolution.
    Conv { kh: usize, kw: usize, stride: usize, cin: usize, cout: usize },
    /// Depthwise convolution (one filter per channel).
    DwConv { kh: usize, kw: usize, stride: usize, channels: usize },
    /// Fully-connected / linear.
    Fc { cin: usize, cout: usize },
}

/// One compute layer of a CNN.
#[derive(Clone, Debug)]
pub struct LayerDef {
    /// Short name, e.g. `"conv2_1/3x3"`.
    pub name: String,
    pub kind: LayerKind,
    /// Input spatial size (H == W for the evaluated nets); 1 for FC.
    pub in_hw: usize,
}

impl LayerDef {
    pub fn conv(
        name: &str,
        in_hw: usize,
        kh: usize,
        stride: usize,
        cin: usize,
        cout: usize,
    ) -> LayerDef {
        LayerDef {
            name: name.to_string(),
            kind: LayerKind::Conv { kh, kw: kh, stride, cin, cout },
            in_hw,
        }
    }

    pub fn dw(name: &str, in_hw: usize, kh: usize, stride: usize, channels: usize) -> LayerDef {
        LayerDef {
            name: name.to_string(),
            kind: LayerKind::DwConv { kh, kw: kh, stride, channels },
            in_hw,
        }
    }

    pub fn fc(name: &str, cin: usize, cout: usize) -> LayerDef {
        LayerDef { name: name.to_string(), kind: LayerKind::Fc { cin, cout }, in_hw: 1 }
    }

    /// Output spatial size ("same" padding for stride 1, halving for
    /// stride 2 — the convention of both evaluated networks).
    pub fn out_hw(&self) -> usize {
        match self.kind {
            LayerKind::Conv { stride, .. } | LayerKind::DwConv { stride, .. } => {
                self.in_hw.div_ceil(stride)
            }
            LayerKind::Fc { .. } => 1,
        }
    }

    /// The layer's GEMM shape under the module's lowering conventions.
    pub fn gemm(&self) -> GemmShape {
        let s = self.out_hw();
        match self.kind {
            LayerKind::Conv { kh, kw, cin, cout, .. } => GemmShape::new(s * s, cin * kh * kw, cout),
            LayerKind::DwConv { kh, kw, channels, .. } => GemmShape::new(s * s, kh * kw, channels),
            LayerKind::Fc { cin, cout } => GemmShape::new(1, cin, cout),
        }
    }

    /// Multiply-accumulate count of the layer.
    pub fn macs(&self) -> u64 {
        self.gemm().macs()
    }

    /// Parameter (weight) count.
    pub fn params(&self) -> u64 {
        match self.kind {
            LayerKind::Conv { kh, kw, cin, cout, .. } => (kh * kw * cin * cout) as u64,
            LayerKind::DwConv { kh, kw, channels, .. } => (kh * kw * channels) as u64,
            LayerKind::Fc { cin, cout } => (cin * cout) as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_gemm_lowering() {
        // 3×3 s2 conv, 224→112, 3→32 channels (MobileNet conv1).
        let l = LayerDef::conv("conv1", 224, 3, 2, 3, 32);
        assert_eq!(l.out_hw(), 112);
        assert_eq!(l.gemm(), GemmShape::new(112 * 112, 27, 32));
        assert_eq!(l.macs(), 112 * 112 * 27 * 32);
    }

    #[test]
    fn dw_gemm_lowering() {
        let l = LayerDef::dw("dw2", 112, 3, 2, 64);
        assert_eq!(l.out_hw(), 56);
        assert_eq!(l.gemm(), GemmShape::new(56 * 56, 9, 64));
        assert_eq!(l.params(), 9 * 64);
    }

    #[test]
    fn fc_lowering() {
        let l = LayerDef::fc("fc", 1024, 1000);
        assert_eq!(l.gemm(), GemmShape::new(1, 1024, 1000));
        assert_eq!(l.params(), 1_024_000);
    }

    #[test]
    fn stride_one_preserves_spatial() {
        let l = LayerDef::conv("c", 56, 3, 1, 64, 64);
        assert_eq!(l.out_hw(), 56);
    }
}
