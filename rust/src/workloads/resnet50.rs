//! ResNet-50 (224×224) layer table [19].
//!
//! 54 compute layers: the 7×7 stem, 16 bottleneck blocks (each 1×1 →
//! 3×3 → 1×1, with a 1×1 projection on the first block of every stage),
//! and the classifier.  53 convolutions + 1 FC, matching He et al.,
//! CVPR 2016, Table 1.

use super::layer::LayerDef;

/// Emit one bottleneck block's convolutions.
fn bottleneck(
    l: &mut Vec<LayerDef>,
    stage: usize,
    block: usize,
    in_hw: usize,
    cin: usize,
    mid: usize,
    stride: usize,
) {
    let tag = |part: &str| format!("conv{stage}_{block}/{part}");
    let cout = 4 * mid;
    // 1×1 reduce (carries the stride in the torchvision/v1.5 convention).
    l.push(LayerDef::conv(&tag("1x1a"), in_hw, 1, 1, cin, mid));
    l.push(LayerDef::conv(&tag("3x3"), in_hw, 3, stride, mid, mid));
    l.push(LayerDef::conv(&tag("1x1b"), in_hw / stride, 1, 1, mid, cout));
    if block == 1 {
        // Projection shortcut on the first block of each stage.
        l.push(LayerDef::conv(&tag("proj"), in_hw, 1, stride, cin, cout));
    }
}

/// The 54 compute layers of ResNet-50.
pub fn layers() -> Vec<LayerDef> {
    let mut l = Vec::with_capacity(54);
    l.push(LayerDef::conv("conv1", 224, 7, 2, 3, 64));
    // conv1 output 112×112 is max-pooled (s2) to 56×56 before stage 2.
    // (stage, blocks, in_hw, mid, stride of first block)
    let stages: [(usize, usize, usize, usize, usize); 4] =
        [(2, 3, 56, 64, 1), (3, 4, 56, 128, 2), (4, 6, 28, 256, 2), (5, 3, 14, 512, 2)];
    for &(stage, blocks, mut in_hw, mid, first_stride) in &stages {
        let mut cin = if stage == 2 { 64 } else { 2 * mid };
        for b in 1..=blocks {
            let stride = if b == 1 { first_stride } else { 1 };
            bottleneck(&mut l, stage, b, in_hw, cin, mid, stride);
            in_hw /= stride;
            cin = 4 * mid;
        }
    }
    l.push(LayerDef::fc("fc", 2048, 1000));
    l
}

/// Total multiply-accumulates (sanity checks).
pub fn total_macs() -> u64 {
    layers().iter().map(|l| l.macs()).sum()
}

/// Cross-check representative layers through the fast cycle simulator
/// on the paper's 128×128 array, both pipeline kinds — the per-layer
/// Fig. 8 numbers are built on the closed-form model these checks
/// validate (DESIGN.md §2).
pub fn cross_check_paper_tiles(m_cap: usize, threads: usize) -> Vec<super::layer::TileSimCheck> {
    super::layer::cross_check_paper_tiles(&layers(), m_cap, threads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::layer::LayerKind;

    #[test]
    fn has_53_convs_plus_fc() {
        let ls = layers();
        assert_eq!(ls.len(), 54);
        let convs =
            ls.iter().filter(|l| matches!(l.kind, LayerKind::Conv { .. })).count();
        assert_eq!(convs, 53);
    }

    #[test]
    fn macs_match_published_figure() {
        // ResNet-50 is cited at ~3.8–4.1 GMACs at 224².
        let m = total_macs();
        assert!(
            (3_700_000_000..4_200_000_000).contains(&m),
            "ResNet50 MACs {m} outside published ~3.8G band"
        );
    }

    #[test]
    fn params_match_published_figure() {
        // ~25.5M parameters; conv+fc (no BN) ≈ 25.0M.
        let p: u64 = layers().iter().map(|l| l.params()).sum();
        assert!((24_000_000..26_000_000).contains(&p), "params {p}");
    }

    #[test]
    fn stage_resolutions_halve() {
        let ls = layers();
        // Last conv of the net runs at 7×7.
        let last_conv = ls.iter().rev().find(|l| matches!(l.kind, LayerKind::Conv { .. })).unwrap();
        assert_eq!(last_conv.out_hw(), 7);
        // Stage 2 runs at 56.
        assert!(ls.iter().any(|l| l.name == "conv2_1/3x3" && l.in_hw == 56));
        assert!(ls.iter().any(|l| l.name == "conv5_3/1x1b" && l.out_hw() == 7));
    }

    #[test]
    fn paper_tiles_cycle_sim_validates_model() {
        for chk in cross_check_paper_tiles(3, 4) {
            assert!(chk.ok(), "{chk:?}");
        }
    }

    #[test]
    fn projection_only_on_first_blocks() {
        let ls = layers();
        let projs: Vec<&str> =
            ls.iter().filter(|l| l.name.ends_with("/proj")).map(|l| l.name.as_str()).collect();
        assert_eq!(projs, vec!["conv2_1/proj", "conv3_1/proj", "conv4_1/proj", "conv5_1/proj"]);
    }
}
