//! Per-layer serving workloads: the request side of `skewsa serve`.
//!
//! A *serving model* is one deployed CNN layer: its weight matrix is
//! fixed at registration (weight-stationary in the large), and requests
//! stream activation row-batches through it — the ML-serving pattern
//! where many users share one set of weights.  That is exactly what
//! makes dynamic batching bit-exact here: tile numerics are
//! row-independent (DESIGN.md §7), so stacking several requests'
//! activation rows into one GEMM produces, row for row, the bits a solo
//! run of each request would.
//!
//! Weights are generated deterministically from the layer name (FNV-1a
//! seed, He/fan-in scale), so a verification run can rebuild the same
//! model out-of-band and compare served bits against a direct
//! [`crate::coordinator::Coordinator::run_gemm`].

use crate::arith::format::FpFormat;
use crate::config::RunConfig;
use crate::coordinator::Coordinator;
use crate::pe::PipelineKind;
use crate::sa::tile::GemmShape;
use crate::util::rng::Rng;
use crate::workloads::gemm::GemmData;
use crate::workloads::layer::LayerDef;
use std::sync::Arc;

/// FNV-1a over a layer name: the deterministic weight seed.
pub fn layer_seed(name: &str) -> u64 {
    name.bytes()
        .fold(0xcbf2_9ce4_8422_2325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100_0000_01b3))
}

/// One servable entry: a fixed `K×N` weight matrix in a given format.
#[derive(Clone, Debug)]
pub struct ServingModel {
    /// Layer name the entry was built from.
    pub name: String,
    /// Element format of weights and request activations.
    pub fmt: FpFormat,
    /// Reduction depth (rows of W).
    pub k: usize,
    /// Output columns (columns of W).
    pub n: usize,
    /// `w[k][n]` bit patterns in `fmt` (He-scaled, seeded by name).
    pub w: Vec<Vec<u64>>,
}

/// The registry of deployed models a [`crate::serve::Server`] fronts.
#[derive(Clone, Debug)]
pub struct WeightStore {
    models: Vec<ServingModel>,
    /// `true` for stores built from a mixed-precision plan
    /// ([`WeightStore::from_plan`]): the plan certified per-layer error
    /// budgets under each format's canonical accumulation chain, and
    /// the server enforces that certification at startup.
    planned: bool,
}

impl WeightStore {
    /// Build a store from CNN layer definitions, clamping each layer's
    /// GEMM to `k_cap × n_cap` (the serving path is identical under the
    /// clamp; the softfloat oracle just stays tractable).
    pub fn from_layers(
        layers: &[LayerDef],
        fmt: FpFormat,
        k_cap: usize,
        n_cap: usize,
    ) -> WeightStore {
        assert!(k_cap >= 1 && n_cap >= 1);
        let models =
            layers.iter().map(|l| Self::build_model(l, fmt, k_cap, n_cap)).collect();
        WeightStore { models, planned: false }
    }

    /// Build a store from a mixed-precision plan: each layer registers
    /// in the format the planner assigned it.  Requests then carry that
    /// model's format implicitly, and the serve-layer plan cache —
    /// already keyed on `FpFormat` — memoises each precision's tile
    /// plans separately, so mixed-precision traffic rides the existing
    /// per-tile cache unchanged (DESIGN.md §12).
    ///
    /// The plan certified each layer's error under its canonical
    /// accumulation chain ([`crate::precision::chain_for`]) on seeded
    /// master draws of the **full** layer GEMM; the server enforces at
    /// startup the *necessary* half of that certification — its
    /// accumulator must be at least as wide as every model's certified
    /// one.  The budgets themselves transfer *statistically*: the
    /// served weights are fresh draws from the same distribution
    /// (He-scaled for the served depth), and with `k_cap`/`n_cap`
    /// below the layer shape the served reduction is shallower than
    /// the certified one — peak-normalized error is dominated by the
    /// format's input roundoff, which is depth-insensitive, but a
    /// clamped deployment is an approximation of the certified layer,
    /// not a bit-level replay of it.
    pub fn from_plan(
        layers: &[LayerDef],
        plan: &crate::precision::PrecisionPlan,
        k_cap: usize,
        n_cap: usize,
    ) -> WeightStore {
        assert!(k_cap >= 1 && n_cap >= 1);
        assert_eq!(layers.len(), plan.layers.len(), "plan does not cover the layer table");
        let models = layers
            .iter()
            .zip(&plan.layers)
            .map(|(l, lp)| {
                assert_eq!(l.name, lp.layer, "plan/layer tables out of order");
                Self::build_model(l, lp.fmt, k_cap, n_cap)
            })
            .collect();
        WeightStore { models, planned: true }
    }

    /// Whether this store was deployed from a mixed-precision plan
    /// (and therefore carries certified error budgets to enforce).
    pub fn is_planned(&self) -> bool {
        self.planned
    }

    /// One layer's serving entry: weights drawn from the deterministic
    /// name seed *before* format quantization, so every format of the
    /// same layer quantizes the same underlying master weights.
    fn build_model(l: &LayerDef, fmt: FpFormat, k_cap: usize, n_cap: usize) -> ServingModel {
        let g = l.gemm();
        let k = g.k.min(k_cap);
        let n = g.n.min(n_cap);
        let mut rng = Rng::new(layer_seed(&l.name));
        let wstd = (2.0 / k as f64).sqrt();
        let w = (0..k)
            .map(|_| (0..n).map(|_| fmt.from_f64(rng.normal_scaled(0.0, wstd))).collect())
            .collect();
        ServingModel { name: l.name.clone(), fmt, k, n, w }
    }

    pub fn len(&self) -> usize {
        self.models.len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    pub fn get(&self, id: usize) -> &ServingModel {
        &self.models[id]
    }

    /// Generate `m` activation rows for a model: post-ReLU half-Gaussian
    /// statistics, matching [`crate::workloads::gemm::GemmData::cnn_like`].
    pub fn gen_activations(&self, model: usize, m: usize, rng: &mut Rng) -> Vec<Vec<u64>> {
        let entry = self.get(model);
        (0..m)
            .map(|_| (0..entry.k).map(|_| entry.fmt.from_f64(rng.normal().max(0.0))).collect())
            .collect()
    }

    /// Run one request's GEMM solo through a fresh [`Coordinator`] and
    /// return the output bit patterns: the *canonical* reference the
    /// serving path must match bit-for-bit (shared by the serve
    /// integration tests and `bench_serve`, so they can never verify
    /// against diverging references).
    pub fn solo_reference_bits(
        &self,
        cfg: &RunConfig,
        model: usize,
        kind: PipelineKind,
        a: &[Vec<u64>],
    ) -> Vec<u32> {
        let entry = self.get(model);
        // The serve dispatcher derives each batch's chain from the
        // *model's* format; mirror that here so mixed-precision stores
        // (`from_plan`) reference the same chain the server ran.
        let mut cfg = cfg.clone();
        cfg.in_fmt = entry.fmt;
        let shape = GemmShape::new(a.len(), entry.k, entry.n);
        let data = Arc::new(GemmData {
            shape,
            fmt: entry.fmt,
            a: a.to_vec(),
            w: entry.w.clone(),
        });
        let r = Coordinator::new(cfg).run_gemm(kind, &data);
        r.y.iter().map(|v| v.to_bits()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::mobilenet;

    #[test]
    fn store_covers_layers_with_caps() {
        let layers = mobilenet::layers();
        let store = WeightStore::from_layers(&layers, FpFormat::BF16, 64, 48);
        assert_eq!(store.len(), layers.len());
        for i in 0..store.len() {
            let m = store.get(i);
            assert!(m.k >= 1 && m.k <= 64);
            assert!(m.n >= 1 && m.n <= 48);
            assert_eq!(m.w.len(), m.k);
            assert_eq!(m.w[0].len(), m.n);
        }
    }

    #[test]
    fn weights_deterministic_per_name() {
        let layers = mobilenet::layers();
        let a = WeightStore::from_layers(&layers[..3], FpFormat::BF16, 32, 32);
        let b = WeightStore::from_layers(&layers[..3], FpFormat::BF16, 32, 32);
        for i in 0..a.len() {
            assert_eq!(a.get(i).w, b.get(i).w);
        }
        // Distinct layers get distinct weights.
        assert_ne!(a.get(1).w, a.get(2).w);
    }

    #[test]
    fn from_plan_registers_per_layer_formats() {
        use crate::precision::{LayerPlan, PrecisionPlan};
        let layers = &mobilenet::layers()[..2];
        let fmts = [FpFormat::BF16, FpFormat::FP8E5M2];
        let plan = PrecisionPlan {
            label: "mixed".into(),
            budget: 1.0,
            kinds: vec![PipelineKind::Skewed],
            layers: layers
                .iter()
                .zip(fmts)
                .map(|(l, fmt)| LayerPlan {
                    layer: l.name.clone(),
                    shape: l.gemm(),
                    fmt,
                    kind: PipelineKind::Skewed,
                    stats: Default::default(),
                    energy_uj: 0.0,
                    cycles: 0,
                    within_budget: true,
                    clock_feasible: true,
                })
                .collect(),
        };
        let store = WeightStore::from_plan(layers, &plan, 16, 8);
        assert_eq!(store.len(), 2);
        assert_eq!(store.get(0).fmt, FpFormat::BF16);
        assert_eq!(store.get(1).fmt, FpFormat::FP8E5M2);
        // Same master weights, different quantization: the bf16 entry
        // decodes to different bits than an fp8 build of layer 0 would.
        let alt = WeightStore::from_layers(&layers[..1], FpFormat::FP8E5M2, 16, 8);
        assert_ne!(store.get(0).w, alt.get(0).w);
    }

    #[test]
    fn activations_are_post_relu_and_sized() {
        let store =
            WeightStore::from_layers(&mobilenet::layers()[..1], FpFormat::BF16, 27, 32);
        let mut rng = Rng::new(7);
        let a = store.gen_activations(0, 5, &mut rng);
        assert_eq!(a.len(), 5);
        for row in &a {
            assert_eq!(row.len(), store.get(0).k);
            for &bits in row {
                assert!(FpFormat::BF16.to_f64(bits) >= 0.0);
            }
        }
    }
}
