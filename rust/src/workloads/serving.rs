//! Per-layer serving workloads: the request side of `skewsa serve`.
//!
//! A *serving model* is one deployed CNN layer: its weight matrix is
//! fixed at registration (weight-stationary in the large), and requests
//! stream activation row-batches through it — the ML-serving pattern
//! where many users share one set of weights.  That is exactly what
//! makes dynamic batching bit-exact here: tile numerics are
//! row-independent (DESIGN.md §7), so stacking several requests'
//! activation rows into one GEMM produces, row for row, the bits a solo
//! run of each request would.
//!
//! Weights are generated deterministically from the layer name (FNV-1a
//! seed, He/fan-in scale), so a verification run can rebuild the same
//! model out-of-band and compare served bits against a direct
//! [`crate::coordinator::Coordinator::run_gemm`].

use crate::arith::format::FpFormat;
use crate::config::RunConfig;
use crate::coordinator::Coordinator;
use crate::pe::PipelineKind;
use crate::sa::tile::GemmShape;
use crate::util::rng::Rng;
use crate::workloads::gemm::GemmData;
use crate::workloads::layer::LayerDef;
use std::sync::Arc;

/// FNV-1a over a layer name: the deterministic weight seed.
pub fn layer_seed(name: &str) -> u64 {
    name.bytes()
        .fold(0xcbf2_9ce4_8422_2325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100_0000_01b3))
}

/// One servable entry: a fixed `K×N` weight matrix in a given format.
#[derive(Clone, Debug)]
pub struct ServingModel {
    /// Layer name the entry was built from.
    pub name: String,
    /// Element format of weights and request activations.
    pub fmt: FpFormat,
    /// Reduction depth (rows of W).
    pub k: usize,
    /// Output columns (columns of W).
    pub n: usize,
    /// `w[k][n]` bit patterns in `fmt` (He-scaled, seeded by name).
    pub w: Vec<Vec<u64>>,
}

/// The registry of deployed models a [`crate::serve::Server`] fronts.
#[derive(Clone, Debug)]
pub struct WeightStore {
    models: Vec<ServingModel>,
}

impl WeightStore {
    /// Build a store from CNN layer definitions, clamping each layer's
    /// GEMM to `k_cap × n_cap` (the serving path is identical under the
    /// clamp; the softfloat oracle just stays tractable).
    pub fn from_layers(
        layers: &[LayerDef],
        fmt: FpFormat,
        k_cap: usize,
        n_cap: usize,
    ) -> WeightStore {
        assert!(k_cap >= 1 && n_cap >= 1);
        let models = layers
            .iter()
            .map(|l| {
                let g = l.gemm();
                let k = g.k.min(k_cap);
                let n = g.n.min(n_cap);
                let mut rng = Rng::new(layer_seed(&l.name));
                let wstd = (2.0 / k as f64).sqrt();
                let w = (0..k)
                    .map(|_| {
                        (0..n).map(|_| fmt.from_f64(rng.normal_scaled(0.0, wstd))).collect()
                    })
                    .collect();
                ServingModel { name: l.name.clone(), fmt, k, n, w }
            })
            .collect();
        WeightStore { models }
    }

    pub fn len(&self) -> usize {
        self.models.len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    pub fn get(&self, id: usize) -> &ServingModel {
        &self.models[id]
    }

    /// Generate `m` activation rows for a model: post-ReLU half-Gaussian
    /// statistics, matching [`crate::workloads::gemm::GemmData::cnn_like`].
    pub fn gen_activations(&self, model: usize, m: usize, rng: &mut Rng) -> Vec<Vec<u64>> {
        let entry = self.get(model);
        (0..m)
            .map(|_| (0..entry.k).map(|_| entry.fmt.from_f64(rng.normal().max(0.0))).collect())
            .collect()
    }

    /// Run one request's GEMM solo through a fresh [`Coordinator`] and
    /// return the output bit patterns: the *canonical* reference the
    /// serving path must match bit-for-bit (shared by the serve
    /// integration tests and `bench_serve`, so they can never verify
    /// against diverging references).
    pub fn solo_reference_bits(
        &self,
        cfg: &RunConfig,
        model: usize,
        kind: PipelineKind,
        a: &[Vec<u64>],
    ) -> Vec<u32> {
        let entry = self.get(model);
        let shape = GemmShape::new(a.len(), entry.k, entry.n);
        let data = Arc::new(GemmData {
            shape,
            fmt: entry.fmt,
            a: a.to_vec(),
            w: entry.w.clone(),
        });
        let r = Coordinator::new(cfg.clone()).run_gemm(kind, &data);
        r.y.iter().map(|v| v.to_bits()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::mobilenet;

    #[test]
    fn store_covers_layers_with_caps() {
        let layers = mobilenet::layers();
        let store = WeightStore::from_layers(&layers, FpFormat::BF16, 64, 48);
        assert_eq!(store.len(), layers.len());
        for i in 0..store.len() {
            let m = store.get(i);
            assert!(m.k >= 1 && m.k <= 64);
            assert!(m.n >= 1 && m.n <= 48);
            assert_eq!(m.w.len(), m.k);
            assert_eq!(m.w[0].len(), m.n);
        }
    }

    #[test]
    fn weights_deterministic_per_name() {
        let layers = mobilenet::layers();
        let a = WeightStore::from_layers(&layers[..3], FpFormat::BF16, 32, 32);
        let b = WeightStore::from_layers(&layers[..3], FpFormat::BF16, 32, 32);
        for i in 0..a.len() {
            assert_eq!(a.get(i).w, b.get(i).w);
        }
        // Distinct layers get distinct weights.
        assert_ne!(a.get(1).w, a.get(2).w);
    }

    #[test]
    fn activations_are_post_relu_and_sized() {
        let store =
            WeightStore::from_layers(&mobilenet::layers()[..1], FpFormat::BF16, 27, 32);
        let mut rng = Rng::new(7);
        let a = store.gen_activations(0, 5, &mut rng);
        assert_eq!(a.len(), 5);
        for row in &a {
            assert_eq!(row.len(), store.get(0).k);
            for &bits in row {
                assert!(FpFormat::BF16.to_f64(bits) >= 0.0);
            }
        }
    }
}
