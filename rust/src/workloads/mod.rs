//! Evaluated workloads: CNN layer tables and synthetic GEMM generators.
//!
//! * [`layer`] — layer descriptors → im2col GEMM lowering.
//! * [`mobilenet`] — MobileNetV1 224² (28 compute layers) [18].
//! * [`resnet50`] — ResNet-50 224² (53 convs + FC) [19].
//! * [`decode`] — transformer decode projections: tall-skinny GEMMs.
//! * [`gemm`] — synthetic GEMM data with ImageNet-like statistics.
//! * [`serving`] — per-layer serving models + request generation for
//!   the `skewsa serve` stack (DESIGN.md §11).

pub mod decode;
pub mod gemm;
pub mod layer;
pub mod mobilenet;
pub mod resnet50;
pub mod serving;

pub use gemm::GemmData;
pub use layer::{LayerDef, LayerKind, TileSimCheck};
pub use serving::{ServingModel, WeightStore};
