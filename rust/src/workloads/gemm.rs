//! Synthetic GEMM workload generation.
//!
//! Produces activation/weight matrices with ImageNet-like statistics for
//! the numeric paths (DESIGN.md §2: the energy figures depend on layer
//! shapes and activity, not image content; the *numeric verification*
//! paths need realistic value distributions, which these generators
//! provide: post-ReLU half-Gaussian activations, fan-in-scaled Gaussian
//! weights).

use crate::arith::format::FpFormat;
use crate::arith::fma::ChainCfg;
use crate::pe::PipelineKind;
use crate::sa::column::SimError;
use crate::sa::fast::FastArraySim;
use crate::sa::tile::GemmShape;
use crate::util::rng::Rng;

/// A generated GEMM problem instance (bit patterns in `fmt`).
#[derive(Clone, Debug)]
pub struct GemmData {
    pub shape: GemmShape,
    pub fmt: FpFormat,
    /// `a[m][k]`.
    pub a: Vec<Vec<u64>>,
    /// `w[k][n]`.
    pub w: Vec<Vec<u64>>,
}

impl GemmData {
    /// ImageNet-CNN-like statistics: activations are post-ReLU
    /// (half-Gaussian, unit scale), weights are Gaussian with He/fan-in
    /// scaling `σ = sqrt(2/K)`.
    pub fn cnn_like(shape: GemmShape, fmt: FpFormat, seed: u64) -> GemmData {
        let mut rng = Rng::new(seed);
        let wstd = (2.0 / shape.k as f64).sqrt();
        let a = (0..shape.m)
            .map(|_| {
                (0..shape.k)
                    .map(|_| fmt.from_f64(rng.normal().max(0.0)))
                    .collect()
            })
            .collect();
        let w = (0..shape.k)
            .map(|_| {
                (0..shape.n)
                    .map(|_| fmt.from_f64(rng.normal_scaled(0.0, wstd)))
                    .collect()
            })
            .collect();
        GemmData { shape, fmt, a, w }
    }

    /// Small-integer-valued inputs: exact in every reduced format and in
    /// f64, used where tests need loss-free reference comparisons.
    pub fn integer_valued(shape: GemmShape, fmt: FpFormat, seed: u64) -> GemmData {
        let mut rng = Rng::new(seed);
        let a = (0..shape.m)
            .map(|_| (0..shape.k).map(|_| fmt.from_f64(rng.range_i64(-8, 8) as f64)).collect())
            .collect();
        let w = (0..shape.k)
            .map(|_| (0..shape.n).map(|_| fmt.from_f64(rng.range_i64(-4, 4) as f64)).collect())
            .collect();
        GemmData { shape, fmt, a, w }
    }

    /// Adversarial values: wide exponent spread and sign flips, to
    /// stress alignment/cancellation paths end-to-end.
    pub fn adversarial(shape: GemmShape, fmt: FpFormat, seed: u64) -> GemmData {
        let mut rng = Rng::new(seed);
        let gen = |rng: &mut Rng| {
            let mag = 2.0f64.powi(rng.range_i64(-20, 20) as i32);
            let sign = if rng.chance(0.5) { -1.0 } else { 1.0 };
            fmt.from_f64(sign * mag * (1.0 + rng.unit_f64()))
        };
        let a = (0..shape.m).map(|_| (0..shape.k).map(|_| gen(&mut rng)).collect()).collect();
        let w = (0..shape.k).map(|_| (0..shape.n).map(|_| gen(&mut rng)).collect()).collect();
        GemmData { shape, fmt, a, w }
    }

    /// Run this GEMM through the fast cycle simulator as a single
    /// `K×N` weight tile (the generated matrices are exactly one tile's
    /// worth of data) and return the rounded `M×N` result.  Practical at
    /// the paper's full 128×128 tile size; `threads` fans the column
    /// strips out across workers.
    pub fn cycle_sim_f32(
        &self,
        chain: &ChainCfg,
        kind: PipelineKind,
        threads: usize,
    ) -> Result<Vec<Vec<f32>>, SimError> {
        let mut sim = FastArraySim::new(*chain, kind, &self.w, &self.a);
        let budget = sim.schedule().total_cycles() + 16;
        sim.run_parallel(budget, threads)?;
        Ok(sim.result_f32())
    }

    /// f64 reference product `A × W` (accumulated in f64 — the *loose*
    /// reference; bit-exact references go through the column oracle).
    pub fn reference_f64(&self) -> Vec<Vec<f64>> {
        let GemmShape { m, k, n } = self.shape;
        let mut y = vec![vec![0.0f64; n]; m];
        for i in 0..m {
            for kk in 0..k {
                let av = self.fmt.to_f64(self.a[i][kk]);
                if av == 0.0 {
                    continue;
                }
                for j in 0..n {
                    y[i][j] += av * self.fmt.to_f64(self.w[kk][j]);
                }
            }
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cnn_like_is_relu_and_scaled() {
        let g = GemmData::cnn_like(GemmShape::new(16, 64, 8), FpFormat::BF16, 1);
        // All activations non-negative (post-ReLU).
        for row in &g.a {
            for &bits in row {
                assert!(FpFormat::BF16.to_f64(bits) >= 0.0);
            }
        }
        // Weight scale ≈ sqrt(2/64) = 0.177.
        let mut s2 = 0.0;
        let mut n = 0;
        for row in &g.w {
            for &bits in row {
                let x = FpFormat::BF16.to_f64(bits);
                s2 += x * x;
                n += 1;
            }
        }
        let std = (s2 / n as f64).sqrt();
        assert!((std - 0.177).abs() < 0.04, "weight std {std}");
    }

    #[test]
    fn deterministic_by_seed() {
        let g1 = GemmData::cnn_like(GemmShape::new(4, 4, 4), FpFormat::BF16, 7);
        let g2 = GemmData::cnn_like(GemmShape::new(4, 4, 4), FpFormat::BF16, 7);
        assert_eq!(g1.a, g2.a);
        assert_eq!(g1.w, g2.w);
        let g3 = GemmData::cnn_like(GemmShape::new(4, 4, 4), FpFormat::BF16, 8);
        assert_ne!(g1.a, g3.a);
    }

    #[test]
    fn integer_reference_is_exact() {
        let g = GemmData::integer_valued(GemmShape::new(3, 16, 3), FpFormat::BF16, 2);
        let y = g.reference_f64();
        for row in &y {
            for &v in row {
                assert_eq!(v, v.round(), "integer inputs give integer outputs");
            }
        }
    }

    #[test]
    fn cycle_sim_single_tile_matches_oracle() {
        let chain = ChainCfg::BF16_FP32;
        let g = GemmData::cnn_like(GemmShape::new(4, 12, 6), FpFormat::BF16, 9);
        let want = FastArraySim::oracle_bits(&chain, &g.w, &g.a);
        for kind in [PipelineKind::Baseline3b, PipelineKind::Skewed] {
            let y = g.cycle_sim_f32(&chain, kind, 2).unwrap();
            for (m, row) in y.iter().enumerate() {
                for (n, v) in row.iter().enumerate() {
                    assert_eq!(v.to_bits() as u64, want[m][n], "{kind} y[{m}][{n}]");
                }
            }
        }
    }

    #[test]
    fn adversarial_spans_exponents() {
        let g = GemmData::adversarial(GemmShape::new(8, 32, 4), FpFormat::BF16, 3);
        let mut min_e = i32::MAX;
        let mut max_e = i32::MIN;
        for row in &g.a {
            for &bits in row {
                let u = FpFormat::BF16.decode(bits);
                min_e = min_e.min(u.exp);
                max_e = max_e.max(u.exp);
            }
        }
        assert!(max_e - min_e > 20, "exponent spread {}", max_e - min_e);
    }
}
