//! Transformer decode-step projections: tall-skinny GEMMs.
//!
//! Token-by-token decoding multiplies a handful of in-flight token
//! vectors (`M` = 1–8) against weight matrices whose reduction depth is
//! the full hidden dimension while the output width is a narrow slice —
//! a per-head query projection (4096 → 64), a grouped-query KV
//! projection (4096 → 128), or a LoRA down-projection (4096 → 16).
//! `K ≫ N` is the defining property: on a weight-stationary array these
//! layers reward tall geometries (more rows to hold the reduction,
//! fewer mostly-idle columns), which is exactly what the
//! `skewsa geometry` sweep and the heterogeneous fleet exploit
//! (DESIGN.md §20).

use super::layer::LayerDef;

/// Hidden dimension of the modeled 7B-class decoder.
pub const HIDDEN: usize = 4096;

/// The (name, output width) of each modeled projection slice.
const PROJECTIONS: [(&str, usize); 3] =
    [("q_head", 64), ("kv_gqa", 128), ("lora_down", 16)];

/// Twelve decode-step layers: each projection at 1, 2, 4 and 8
/// in-flight tokens.
pub fn layers() -> Vec<LayerDef> {
    let mut l = Vec::with_capacity(12);
    for m in [1usize, 2, 4, 8] {
        for &(name, n) in &PROJECTIONS {
            l.push(LayerDef::gemm_layer(&format!("m{m}/{name}"), m, HIDDEN, n));
        }
    }
    l
}

/// Total multiply-accumulates of the table (for sanity checks).
pub fn total_macs() -> u64 {
    layers().iter().map(|l| l.macs()).sum()
}

/// Cross-check representative layers through the fast cycle simulator,
/// same contract as the CNN tables (DESIGN.md §2).
pub fn cross_check_paper_tiles(m_cap: usize, threads: usize) -> Vec<super::layer::TileSimCheck> {
    super::layer::cross_check_paper_tiles(&layers(), m_cap, threads)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twelve_tall_skinny_layers() {
        let ls = layers();
        assert_eq!(ls.len(), 12);
        for l in &ls {
            let g = l.gemm();
            assert_eq!(g.k, HIDDEN);
            assert!(g.k >= 32 * g.n, "{}: K={} N={} is not tall-skinny", l.name, g.k, g.n);
            assert!(g.m <= 8, "{}: decode M stays small", l.name);
        }
    }

    #[test]
    fn macs_match_the_closed_form() {
        // (1+2+4+8) tokens × 4096 × (64+128+16) output columns.
        assert_eq!(total_macs(), 15 * 4096 * 208);
    }

    #[test]
    fn paper_tiles_cycle_sim_validates_model() {
        for chk in cross_check_paper_tiles(2, 4) {
            assert!(chk.ok(), "{chk:?}");
        }
    }
}
