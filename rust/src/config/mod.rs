//! Run configuration: array geometry, chain formats, coordinator knobs.
//!
//! Configs load from mini-JSON files (see `configs/` examples in the
//! README) with CLI overrides layered on top; every run starts from
//! [`RunConfig::paper`] — the paper's §IV evaluation point — so that a
//! bare `skewsa run` reproduces the published setup.

use crate::arith::fma::ChainCfg;
use crate::arith::format::FpFormat;
use crate::timing::model::TimingConfig;
use crate::util::cli::Args;
use crate::util::mini_json::Json;

/// How the coordinator computes tile numerics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NumericMode {
    /// Value-level column oracle (bit-exact semantics, no per-cycle
    /// machinery) — the fast path for large workloads.
    Oracle,
    /// Full cycle-accurate array simulation through the banded fast
    /// simulator (validates the closed-form timing model per tile);
    /// practical at the paper's full 128×128 tile size.
    CycleAccurate,
}

/// Complete run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Array rows.
    pub rows: usize,
    /// Array columns.
    pub cols: usize,
    /// Clock in GHz.
    pub clock_ghz: f64,
    /// Input element format.
    pub in_fmt: FpFormat,
    /// Accumulation/output format.
    pub out_fmt: FpFormat,
    /// Weight-preload double buffering.
    pub double_buffer: bool,
    /// Worker threads in the coordinator pool.
    pub workers: usize,
    /// Numeric evaluation mode.
    pub mode: NumericMode,
    /// Bounded job-queue depth (backpressure).
    pub queue_depth: usize,
    /// RNG seed for workload generation.
    pub seed: u64,
    /// Fraction of output elements verified against the exact oracle
    /// (0 disables, 1 verifies everything).
    pub verify_fraction: f64,
}

impl RunConfig {
    /// The paper's evaluation point: 128×128 bf16→fp32 @ 1 GHz.
    pub fn paper() -> RunConfig {
        RunConfig {
            rows: 128,
            cols: 128,
            clock_ghz: 1.0,
            in_fmt: FpFormat::BF16,
            out_fmt: FpFormat::FP32,
            double_buffer: true,
            workers: std::thread::available_parallelism().map_or(4, |n| n.get().min(16)),
            mode: NumericMode::Oracle,
            queue_depth: 64,
            seed: 0x5eed_2023,
            verify_fraction: 0.02,
        }
    }

    /// A small config for tests and quick examples.
    pub fn small() -> RunConfig {
        RunConfig { rows: 8, cols: 8, workers: 2, queue_depth: 8, ..RunConfig::paper() }
    }

    /// The chain configuration implied by the formats.
    pub fn chain(&self) -> ChainCfg {
        ChainCfg::new(self.in_fmt, self.out_fmt)
    }

    /// The timing configuration implied by geometry + clock.
    pub fn timing(&self) -> TimingConfig {
        TimingConfig {
            rows: self.rows,
            cols: self.cols,
            clock_ghz: self.clock_ghz,
            double_buffer: self.double_buffer,
        }
    }

    fn fmt_by_name(name: &str) -> Result<FpFormat, String> {
        match name {
            "bf16" => Ok(FpFormat::BF16),
            "fp16" => Ok(FpFormat::FP16),
            "fp8e4m3" => Ok(FpFormat::FP8E4M3),
            "fp8e5m2" => Ok(FpFormat::FP8E5M2),
            "fp32" => Ok(FpFormat::FP32),
            _ => Err(format!("unknown format '{name}'")),
        }
    }

    /// Apply a parsed JSON config object over this one.
    pub fn apply_json(&mut self, j: &Json) -> Result<(), String> {
        let get_usize = |key: &str| j.get(key).and_then(Json::as_usize);
        if let Some(v) = get_usize("rows") {
            self.rows = v;
        }
        if let Some(v) = get_usize("cols") {
            self.cols = v;
        }
        if let Some(v) = j.get("clock_ghz").and_then(Json::as_f64) {
            self.clock_ghz = v;
        }
        if let Some(v) = j.get("in_fmt").and_then(Json::as_str) {
            self.in_fmt = Self::fmt_by_name(v)?;
        }
        if let Some(v) = j.get("out_fmt").and_then(Json::as_str) {
            self.out_fmt = Self::fmt_by_name(v)?;
        }
        if let Some(v) = j.get("double_buffer").and_then(Json::as_bool) {
            self.double_buffer = v;
        }
        if let Some(v) = get_usize("workers") {
            self.workers = v.max(1);
        }
        if let Some(v) = get_usize("queue_depth") {
            self.queue_depth = v.max(1);
        }
        if let Some(v) = j.get("seed").and_then(Json::as_f64) {
            self.seed = v as u64;
        }
        if let Some(v) = j.get("verify_fraction").and_then(Json::as_f64) {
            self.verify_fraction = v.clamp(0.0, 1.0);
        }
        if let Some(v) = j.get("mode").and_then(Json::as_str) {
            self.mode = match v {
                "oracle" => NumericMode::Oracle,
                "cycle" => NumericMode::CycleAccurate,
                _ => return Err(format!("unknown mode '{v}'")),
            };
        }
        Ok(())
    }

    /// Load a JSON config file over this config.
    pub fn apply_file(&mut self, path: &str) -> Result<(), String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        let j = Json::parse(&text).map_err(|e| format!("{path}: {e}"))?;
        self.apply_json(&j)
    }

    /// Apply CLI overrides (`--rows`, `--cols`, `--seed`, …).
    pub fn apply_args(&mut self, a: &Args) {
        if let Some(v) = a.get_usize("rows") {
            self.rows = v;
        }
        if let Some(v) = a.get_usize("cols") {
            self.cols = v;
        }
        if let Some(v) = a.get_u64("seed") {
            self.seed = v;
        }
        if let Some(v) = a.get_usize("workers") {
            self.workers = v.max(1);
        }
        if let Some(v) = a.get_f64("verify") {
            self.verify_fraction = v.clamp(0.0, 1.0);
        }
        if let Some(v) = a.get("mode") {
            if v == "cycle" {
                self.mode = NumericMode::CycleAccurate;
            } else if v == "oracle" {
                self.mode = NumericMode::Oracle;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_defaults() {
        let c = RunConfig::paper();
        assert_eq!((c.rows, c.cols), (128, 128));
        assert_eq!(c.in_fmt, FpFormat::BF16);
        assert_eq!(c.out_fmt, FpFormat::FP32);
        assert_eq!(c.chain(), ChainCfg::new(FpFormat::BF16, FpFormat::FP32));
    }

    #[test]
    fn json_overrides() {
        let mut c = RunConfig::paper();
        let j = Json::parse(
            r#"{"rows": 16, "cols": 8, "in_fmt": "fp8e4m3", "out_fmt": "fp16",
                "mode": "cycle", "workers": 3, "verify_fraction": 0.5}"#,
        )
        .unwrap();
        c.apply_json(&j).unwrap();
        assert_eq!((c.rows, c.cols), (16, 8));
        assert_eq!(c.in_fmt, FpFormat::FP8E4M3);
        assert_eq!(c.mode, NumericMode::CycleAccurate);
        assert_eq!(c.workers, 3);
        assert_eq!(c.verify_fraction, 0.5);
    }

    #[test]
    fn bad_format_is_an_error() {
        let mut c = RunConfig::paper();
        let j = Json::parse(r#"{"in_fmt": "fp7"}"#).unwrap();
        assert!(c.apply_json(&j).is_err());
    }

    #[test]
    fn args_overrides() {
        use crate::util::cli::Cli;
        let cli = Cli::new("t", "t")
            .opt("rows", "", None)
            .opt("cols", "", None)
            .opt("seed", "", None)
            .opt("workers", "", None)
            .opt("verify", "", None)
            .opt("mode", "", None);
        let a = cli
            .parse(&["--rows=4".into(), "--seed=9".into(), "--mode=cycle".into()])
            .unwrap();
        let mut c = RunConfig::paper();
        c.apply_args(&a);
        assert_eq!(c.rows, 4);
        assert_eq!(c.seed, 9);
        assert_eq!(c.mode, NumericMode::CycleAccurate);
    }
}
